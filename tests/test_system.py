"""End-to-end behaviour: the paper's full pipeline against oracles."""

import numpy as np
import pytest

from conftest import oracle_instances, random_graph

from repro.core import DDSL, GraphUpdate
from repro.core.pattern import PATTERN_LIBRARY

PATTERNS = sorted(PATTERN_LIBRARY.items())


@pytest.mark.parametrize("pname,pattern", PATTERNS)
def test_initial_calculation_matches_oracle(pname, pattern):
    g = random_graph(50, 140, seed=11)
    eng = DDSL(g, pattern, m=4)
    eng.initial()
    assert eng.count() == oracle_instances(g, pattern)


@pytest.mark.parametrize("pname,pattern", PATTERNS)
def test_incremental_update_matches_oracle(pname, pattern):
    g = random_graph(50, 140, seed=11)
    eng = DDSL(g, pattern, m=4)
    eng.initial()
    r = np.random.default_rng(7)
    edges = g.edges()
    dele = edges[r.choice(edges.shape[0], size=6, replace=False)]
    existing = set(map(tuple, edges.tolist()))
    add = set()
    while len(add) < 6:
        a, b = int(r.integers(50)), int(r.integers(50))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    u = GraphUpdate.make(delete=dele.tolist(), add=sorted(add))
    eng.apply(u)
    g2 = g.apply_update(u)
    assert eng.count() == oracle_instances(g2, pattern)


def test_multiple_sequential_updates():
    pattern = PATTERN_LIBRARY["q2_triangle"]
    g = random_graph(40, 100, seed=3)
    eng = DDSL(g, pattern, m=4)
    eng.initial()
    r = np.random.default_rng(5)
    for round_ in range(3):
        edges = eng.graph.edges()
        dele = edges[r.choice(edges.shape[0], size=3, replace=False)]
        existing = set(map(tuple, edges.tolist()))
        add = set()
        while len(add) < 3:
            a, b = int(r.integers(40)), int(r.integers(40))
            if a != b and (min(a, b), max(a, b)) not in existing:
                add.add((min(a, b), max(a, b)))
        eng.apply(GraphUpdate.make(delete=dele.tolist(), add=sorted(add)))
        assert eng.count() == oracle_instances(eng.graph, pattern), f"round {round_}"


def test_update_cheaper_than_recompute():
    """Paper Fig. 8 claim: patch-set work ≪ initial-listing work."""
    pattern = PATTERN_LIBRARY["q5_house"]
    g = random_graph(120, 480, seed=2)
    eng = DDSL(g, pattern, m=4)
    t = eng.initial()
    initial_ints = t.storage_ints()
    r = np.random.default_rng(1)
    edges = eng.graph.edges()
    dele = edges[r.choice(edges.shape[0], size=2, replace=False)]
    existing = set(map(tuple, edges.tolist()))
    add = set()
    while len(add) < 2:
        a, b = int(r.integers(120)), int(r.integers(120))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    rep = eng.apply(GraphUpdate.make(delete=dele.tolist(), add=sorted(add)))
    # patch matches should be a small fraction of the full match set
    assert rep.nav.patch_matches <= max(10, eng.count() // 2)
    assert initial_ints > 0
