import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import *
from repro.core.pattern import PATTERN_LIBRARY
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.cost import CostModel
from repro.core.join_tree import optimal_join_tree, minimum_unit_decomposition
from repro.dist import jax_engine as je
from repro.dist import sharded

def random_graph(n, m, seed):
    r = np.random.default_rng(seed)
    edges = set()
    while len(edges) < m:
        a, b = int(r.integers(n)), int(r.integers(n))
        if a != b: edges.add((min(a,b), max(a,b)))
    return Graph.from_edges(np.array(sorted(edges)))

g = random_graph(48, 110, seed=5)
M = 8
mesh = jax.make_mesh((M,), ("data",))
caps = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=256, match_cap=2048, group_cap=2048, set_cap=32, pair_cap=64)

for pname in ["q2_triangle", "q1_square", "q5_house"]:
    pat = PATTERN_LIBRARY[pname]
    ord_ = symmetry_break(pat)
    stats = GraphStats.of(g)
    cover = choose_cover(pat, ord_, stats)
    model = CostModel(cover, ord_, stats)
    tree = optimal_join_tree(pat, cover, model)
    prog = sharded.build_tree_program(tree, cover, ord_)
    storage = build_np_storage(g, M)
    pt = sharded.stack_partitions(storage, caps)
    pt = jax.device_put(pt, jax.tree.map(lambda s: NamedSharding(mesh, s), sharded.partition_specs(mesh)))
    step = sharded.make_list_step(prog, mesh, caps)
    out, diag = step(pt)
    assert int(diag["overflow"]) == 0, f"overflow {diag}"
    # gather result to host, decompress, compare with host engine
    skel = np.asarray(out.skeleton).reshape(-1, out.skeleton.shape[-1])
    valid = np.asarray(out.valid).reshape(-1)
    sets = {k: np.asarray(v).reshape(-1, v.shape[-1]) for k, v in out.sets.items()}
    keepi = np.nonzero(valid)[0]
    root = prog.nodes[prog.root]
    t = je.CompTensors(skeleton=jnp.array(skel), valid=jnp.array(valid), sets={k: jnp.array(v) for k,v in sets.items()})
    back = je.comp_to_host(t, root.pattern, cover, root.skel_cols)
    _, jt = back.decompress(ord_)
    eng = DDSL(g, pat, m=M, cover=cover)
    eng.initial()
    _, ht = eng.state.matches.decompress(ord_)
    hs, js = set(map(tuple, ht.tolist())), set(map(tuple, jt.tolist()))
    assert hs == js, f"{pname}: host {len(hs)} vs sharded {len(js)}; missing={list(hs-js)[:3]} extra={list(js-hs)[:3]}"
    print(f"{pname}: distributed list_step OK ({len(hs)} matches, diag={ {k:int(v) for k,v in diag.items()} })")
