import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import *
from repro.core.pattern import PATTERN_LIBRARY
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.cost import CostModel
from repro.core.join_tree import optimal_join_tree, minimum_unit_decomposition
from repro.core.navjoin import nav_join_patch
from repro.core.storage import build_np_storage, update_np_storage
from repro.dist import jax_engine as je
from repro.dist import sharded

def random_graph(n, m, seed):
    r = np.random.default_rng(seed)
    edges = set()
    while len(edges) < m:
        a, b = int(r.integers(n)), int(r.integers(n))
        if a != b: edges.add((min(a,b), max(a,b)))
    return Graph.from_edges(np.array(sorted(edges)))

g = random_graph(48, 110, seed=5)
M = 8
mesh = jax.make_mesh((M,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
caps = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=256, match_cap=2048, group_cap=2048, set_cap=32, pair_cap=64)

rng = np.random.default_rng(9)
for pname in ["q2_triangle", "q1_square", "q5_house"]:
    pat = PATTERN_LIBRARY[pname]
    ord_ = symmetry_break(pat)
    stats = GraphStats.of(g)
    cover = choose_cover(pat, ord_, stats)
    model = CostModel(cover, ord_, stats)
    tree = optimal_join_tree(pat, cover, model)
    units = minimum_unit_decomposition(pat, cover)
    prog = sharded.build_tree_program(tree, cover, ord_)
    storage = build_np_storage(g, M)

    # update batch
    ecur = g.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=4, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < 4:
        a, b = int(rng.integers(48)), int(rng.integers(48))
        if a != b and (min(a,b),max(a,b)) not in existing: add.add((min(a,b),max(a,b)))
    add = np.array(sorted(add)); U = GraphUpdate(delete=dele, add=add)

    # host reference
    storage2, _ = update_np_storage(storage, U)
    patch_host = nav_join_patch(storage2, units, pat, cover, ord_, add)
    _, pht = patch_host.decompress(ord_)

    # sharded — candidate-restricted (delta) path, checked against the
    # full-gather oracle mode AND the host rebuild below
    pt = sharded.stack_partitions(storage, caps)
    pt = jax.device_put(pt, jax.tree.map(lambda s: NamedSharding(mesh, s), sharded.partition_specs(mesh)))
    ushapes = sharded.UpdateShapes(n_add=4, n_del=4)
    step = sharded.make_update_step(prog, units, mesh, caps, ushapes, mode="delta")
    add_j = jnp.array(add.astype(np.int32)); del_j = jnp.array(dele.astype(np.int32))
    pt2, patch, diag = step(pt, add_j, del_j)
    assert int(diag["overflow"]) == 0, f"{pname} overflow {diag}"
    assert int(diag["cand_vertices"]) > 0 and int(diag["cand_edges"]) > 0

    step_full = sharded.make_update_step(prog, units, mesh, caps, ushapes, mode="full")
    pt2_f, patch_f, diag_f = step_full(pt, add_j, del_j)
    for a_, b_ in zip(jax.tree.leaves(pt2), jax.tree.leaves(pt2_f)):
        assert (np.asarray(a_) == np.asarray(b_)).all(), f"{pname}: delta != full storage"
    for a_, b_ in zip(jax.tree.leaves(patch), jax.tree.leaves(patch_f)):
        assert (np.asarray(a_) == np.asarray(b_)).all(), f"{pname}: delta != full patch"

    # check storage vs rebuild
    rebuilt = build_np_storage(storage2.graph, M)
    for j in range(M):
        ehi = np.asarray(pt2.edge_hi)[j]; elo = np.asarray(pt2.edge_lo)[j]
        got = set((int(a),int(b)) for a,b in zip(ehi, elo) if a >= 0)
        und = rebuilt.parts[j].codes
        want = set((int(c >> 32), int(c & 0xFFFFFFFF)) for c in und)
        assert got == want, f"{pname} part {j}: storage mismatch {len(got)} vs {len(want)}; missing={list(want-got)[:3]} extra={list(got-want)[:3]}"

    # check patch matches
    skel = np.asarray(patch.skeleton).reshape(-1, patch.skeleton.shape[-1])
    valid = np.asarray(patch.valid).reshape(-1)
    sets = {k: jnp.array(np.asarray(v).reshape(-1, v.shape[-1])) for k, v in patch.sets.items()}
    t = je.CompTensors(skeleton=jnp.array(skel), valid=jnp.array(valid), sets=sets)
    full_skel = tuple(c for c in sorted(cover) if c in set(pat.vertices))
    back = je.comp_to_host(t, pat, cover, full_skel)
    _, jt = back.decompress(ord_)
    hs, js = set(map(tuple, pht.tolist())), set(map(tuple, jt.tolist()))
    assert hs == js, f"{pname} patch mismatch: host {len(hs)} vs sharded {len(js)}; missing={list(hs-js)[:3]} extra={list(js-hs)[:3]}"
    print(f"{pname}: distributed update_step OK (patch={len(hs)}, diag={ {k:int(v) for k,v in diag.items()} })")
