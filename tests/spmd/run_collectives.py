import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.collectives import bucketed_all_to_all, routed_exchange, ring_all_reduce
from repro.dist.compression import butterfly_compressed_all_reduce

mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
N = 8

# --- bucketed_all_to_all: every valid row arrives exactly once -----------
def body(rows, targets, valid):
    received, rvalid, ovf = bucketed_all_to_all([rows[0]], targets[0], valid[0], "d", N, 16)
    return received[0][None], rvalid[None], ovf

rng = np.random.default_rng(0)
R = 32
rows = jnp.asarray(rng.integers(0, 1000, (N, R, 2)), jnp.int32)
targets = jnp.asarray(rng.integers(0, N, (N, R)), jnp.int32)
valid = jnp.asarray(rng.random((N, R)) < 0.8)
fn = jax.shard_map(body, mesh=mesh, in_specs=(P("d"), P("d"), P("d")),
                   out_specs=(P("d"), P("d"), P()), check_vma=False)
rec, rvalid, ovf = fn(rows, targets, valid)
assert int(ovf) == 0
sent = {tuple(r) for dev in range(N) for r, t, v in
        zip(np.asarray(rows)[dev].tolist(), np.asarray(targets)[dev].tolist(),
            np.asarray(valid)[dev].tolist()) if v}
got = {tuple(r) for dev in range(N) for r, v in
       zip(np.asarray(rec).reshape(N, -1, 2)[dev].tolist(),
           np.asarray(rvalid).reshape(N, -1)[dev].tolist()) if v}
assert sent == got, (len(sent), len(got))
print("bucketed_all_to_all OK")

# --- routed_exchange: restore() returns rows to origin -------------------
def body2(rows, targets):
    rows, targets = rows[0], targets[0]
    valid = jnp.ones(rows.shape[0], bool)
    (r_rows,), rvalid, restore, ovf = routed_exchange([rows], targets, valid, "d", N, 16)
    processed = r_rows * 2
    back = restore(processed)
    return back[None], ovf

fn2 = jax.shard_map(body2, mesh=mesh, in_specs=(P("d"), P("d")),
                    out_specs=(P("d"), P()), check_vma=False)
vals = jnp.asarray(rng.integers(1, 1000, (N, R, 2)), jnp.int32)
back, ovf = fn2(vals, targets)
assert int(ovf) == 0
np.testing.assert_array_equal(np.asarray(back), np.asarray(vals) * 2)
print("routed_exchange OK")

# --- ring all-reduce == psum ---------------------------------------------
x = jnp.asarray(rng.normal(size=(N, 16)), jnp.float32)
fn3 = jax.shard_map(lambda v: ring_all_reduce(v[0], "d", N)[None], mesh=mesh,
                    in_specs=P("d"), out_specs=P("d"), check_vma=False)
want = np.asarray(x).sum(0)
got = np.asarray(fn3(x))
np.testing.assert_allclose(got[0], want, rtol=1e-4, atol=1e-5)  # summation order
print("ring_all_reduce OK")

# --- compressed butterfly all-reduce ≈ psum -------------------------------
fn4 = jax.shard_map(lambda v: butterfly_compressed_all_reduce(v[0], "d", N)[None], mesh=mesh,
                    in_specs=P("d"), out_specs=P("d"), check_vma=False)
got = np.asarray(fn4(x))
rel = np.abs(got[0] - want).max() / (np.abs(want).max() + 1e-9)
assert rel < 0.05, rel  # int8 per stage → few-percent error, absorbed by EF
print(f"butterfly_compressed_all_reduce OK (rel err {rel:.3f})")

print("ALL OK")
