"""8-device WCOJ executor vs the host generic-join reference.

Acceptance check of the distributed generic-join mode (ISSUE 10): on a
near-clique graph, the sharded anchored WCOJ listing
(``make_wcoj_list_step`` → ``make_wcoj_init_store_step``) must be
byte-identical to the host ``list_matches_wcoj`` for K4 and K5 under
both ``use_pallas`` settings — with the calibrated per-level caps
(observed prefix sizes × headroom) never overflowing. A short update
stream then drives the delta-seeded WCOJ slot of
``make_maintain_mega_step`` and re-checks byte parity against a
from-scratch host listing at every committed watermark.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.core import Graph, GraphUpdate, build_np_storage
from repro.core.estimator import GraphStats
from repro.core.match_engine import list_matches_wcoj, wcoj_level_counts
from repro.core.pattern import PATTERN_LIBRARY
from repro.core.storage import update_np_storage
from repro.dist import jax_engine as je
from repro.dist import sharded
from repro.planner import CompileContext, compile_plan
from repro.planner.sizing import quantize_store_caps


def near_clique_graph(n, m, k, p, seed):
    r = np.random.default_rng(seed)
    edges = set()
    while len(edges) < m:
        a, b = int(r.integers(n)), int(r.integers(n))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    core = r.choice(n, size=k, replace=False)
    for i in range(k):
        for j in range(i + 1, k):
            if r.random() < p:
                a, b = int(core[i]), int(core[j])
                edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges), np.int64), n=n)


def sample_batch(graph, rng, n_ops, n):
    ecur = graph.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=n_ops, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < n_ops:
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    return np.array(sorted(add)), dele


def pow2(x):
    v = 64
    while v < x:
        v *= 2
    return v


def host_rows(graph, pat, ord_):
    _, tbl = list_matches_wcoj(graph, pat, ord_)
    return set(map(tuple, tbl.tolist()))


N, M = 48, 8
mesh = jax.make_mesh((M,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                         sharded.partition_specs(mesh))
BASE_CAPS = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=256, match_cap=2048,
                          group_cap=2048, set_cap=32, pair_cap=64)

for use_pallas in (False, True):
    caps = dataclasses.replace(BASE_CAPS, use_pallas=use_pallas)
    batches = 6 if not use_pallas else 2   # interpret-mode kernel is slower
    g = near_clique_graph(N, 110, k=9, p=0.95, seed=7)
    stats = GraphStats.of(g)
    storage = build_np_storage(g, M)
    pt = jax.device_put(sharded.stack_partitions(storage, caps), shardings)

    for pname in ("q4_clique4", "q6_clique5"):
        pat = PATTERN_LIBRARY[pname]
        plan = compile_plan(CompileContext(pattern=pat, stats=stats, m=M,
                                           caps=caps, executor="wcoj"))
        # register-time calibration probe, exactly like the service:
        # observed per-partition level sizes × headroom, pow2-snapped
        observed = [wcoj_level_counts(part, plan.wcoj, anchor_to_centers=True)
                    for part in storage.parts]
        peaks = [max((o[i] for o in observed), default=0)
                 for i in range(len(plan.wcoj_level_caps))]
        lvl = tuple(pow2(int(1.5 * p_)) for p_ in peaks)
        scaps = quantize_store_caps(dataclasses.replace(
            plan.store_caps,
            group_cap=max(plan.store_caps.group_cap, pow2(4 * peaks[-1]))))

        lstep = sharded.make_wcoj_list_step(pat, plan.wcoj, mesh, caps, lvl)
        istep = sharded.make_wcoj_init_store_step(pat, plan.ord, mesh, caps,
                                                  scaps, lvl)
        out, ldiag = lstep(pt)
        assert int(ldiag["overflow"]) == 0, (pname, int(ldiag["overflow"]))
        st, idiag = istep(out)
        assert int(idiag["overflow"]) == 0

        want = host_rows(g, pat, plan.ord)
        assert int(idiag["count"]) == len(want)
        cover_all = plan.storage_cover
        back = je.comp_to_host(st.flatten(), pat, cover_all, cover_all)
        got = set(map(tuple, back.decompress(plan.ord)[1].tolist()))
        assert got == want, f"{pname}: {len(got)} vs {len(want)}"

        # delta-seeded maintenance through the fused megastep: the WCOJ
        # slot re-derives each batch's patch from Φ(d') alone (no
        # unit-table carry), and must agree with a from-scratch host
        # generic join at every committed watermark.
        spec = sharded.MaintainSpec(
            name=pname, prog=plan.program, units=tuple(plan.units),
            store=scaps, unit_caps=plan.unit_caps,
            wcoj=plan.wcoj, wcoj_level_caps=lvl)
        ush = sharded.UpdateShapes(n_add=3, n_del=3)
        sstep = sharded.make_storage_update_step(mesh, caps, ush)
        mstep = sharded.make_maintain_mega_step([spec], mesh, caps)

        rng = np.random.default_rng(17)
        cur, pt2 = storage, pt
        for b in range(batches):
            add, dele = sample_batch(cur.graph, rng, 3, N)
            cur, _ = update_np_storage(cur, GraphUpdate(delete=dele, add=add))
            aj, dj = jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32)
            pt2, sdiag = sstep(pt2, aj, dj)
            assert int(sdiag["overflow"]) == 0
            stores2, patches, _, mdiag = mstep(
                pt2, {pname: st}, {pname: {}}, sdiag["part_dirty"], aj, dj)
            st, d = stores2[pname], mdiag[pname]
            assert int(d["overflow"]) == 0, (pname, b, int(d["overflow"]))
            want = host_rows(cur.graph, pat, plan.ord)
            assert int(d["count"]) == len(want), \
                f"{pname} batch {b}: device {int(d['count'])} != {len(want)}"
            back = je.comp_to_host(st.flatten(), pat, cover_all, cover_all)
            got = set(map(tuple, back.decompress(plan.ord)[1].tolist()))
            assert got == want, f"{pname} batch {b}: maintenance diverged"

        print(f"use_pallas={use_pallas} {pname}: wcoj OK "
              f"({batches} batches, |M|={len(want)}, "
              f"level_caps={'/'.join(map(str, lvl))})")
