"""8-device fused multi-pattern megastep vs. per-pattern maintain steps.

Byte-parity acceptance check of ``make_maintain_mega_step``: over a
randomized update stream, ONE fused SPMD dispatch maintaining every
registered pattern must produce stores, patches, carries and diag
scalars byte-identical to running each pattern's carry-threaded
``make_maintain_step`` separately — and counts equal to the host
incremental oracle at every watermark. Run for both ``use_pallas``
settings (fewer batches under the interpret-mode kernel).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DDSL, Graph, GraphUpdate, build_np_storage, symmetry_break
from repro.core.cost import CostModel
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.incremental import apply_update_to_matches
from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
from repro.core.pattern import PATTERN_LIBRARY
from repro.core.storage import update_np_storage
from repro.dist import jax_engine as je
from repro.dist import sharded
from jax.sharding import NamedSharding


def random_graph(n, m, seed):
    r = np.random.default_rng(seed)
    edges = set()
    while len(edges) < m:
        a, b = int(r.integers(n)), int(r.integers(n))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges)))


def sample_batch(graph, rng, n_ops, n):
    ecur = graph.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=n_ops, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < n_ops:
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    return np.array(sorted(add)), dele


N = 48
M = 8
mesh = jax.make_mesh((M,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
BASE_CAPS = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=256, match_cap=2048,
                          group_cap=2048, set_cap=32, pair_cap=64)
PATTERNS = ("q2_triangle", "q1_square")

for use_pallas in (False, True):
    caps = dataclasses.replace(BASE_CAPS, use_pallas=use_pallas)
    batches = 50 if not use_pallas else 8    # interpret-mode kernel is slower
    g = random_graph(N, 110, seed=5)
    stats = GraphStats.of(g)
    storage = build_np_storage(g, M)
    pt = jax.device_put(
        sharded.stack_partitions(storage, caps),
        jax.tree.map(lambda s: NamedSharding(mesh, s), sharded.partition_specs(mesh)))

    # Per-pattern setup: program, store, carry, and the single-pattern
    # carry-threaded maintain step (the reference implementation).
    specs = []
    ref_steps = {}
    stores = {}
    carries = {}
    hosts = {}
    ords = {}
    for name in PATTERNS:
        pat = PATTERN_LIBRARY[name]
        ord_ = symmetry_break(pat)
        cover = choose_cover(pat, ord_, stats)
        tree = optimal_join_tree(pat, cover, CostModel(cover, ord_, stats))
        prog = sharded.build_tree_program(tree, cover, ord_)
        units = minimum_unit_decomposition(pat, cover)
        out, ldiag = sharded.make_list_step(prog, mesh, caps)(pt)
        assert int(ldiag["overflow"]) == 0
        store_caps = sharded.match_caps(pat, cover, ord_, stats, caps)
        st, idiag = sharded.make_init_store_step(prog, mesh, caps, store_caps)(out)
        assert int(idiag["overflow"]) == 0
        ucaps = sharded.unit_table_caps(units, cover, ord_, stats, caps)
        carry, rdiag = sharded.make_unit_refresh_step(prog, units, mesh, caps,
                                                      ucaps)(pt)
        assert int(rdiag["overflow"]) == 0
        host = DDSL(g, pat, m=M, cover=cover)
        host.initial()
        assert int(idiag["count"]) == host.count()
        specs.append(sharded.MaintainSpec(name=name, prog=prog,
                                          units=tuple(units),
                                          store=store_caps, unit_caps=ucaps))
        ref_steps[name] = sharded.make_maintain_step(
            prog, units, mesh, caps, store_caps, unit_caps=ucaps)
        stores[name] = st
        carries[name] = carry
        hosts[name] = (host.state.matches, units, pat, cover, ord_)
        ords[name] = ord_

    mega = sharded.make_maintain_mega_step(specs, mesh, caps)
    sstep = sharded.make_storage_update_step(
        mesh, caps, sharded.UpdateShapes(n_add=3, n_del=3))

    # The reference path keeps its own copies (the megastep may donate).
    ref_stores = {n: jax.tree.map(lambda x: x, s) for n, s in stores.items()}
    ref_carries = {n: jax.tree.map(lambda x: x, c) for n, c in carries.items()}

    rng = np.random.default_rng(11)
    cur = storage
    for b in range(batches):
        add, dele = sample_batch(cur.graph, rng, 3, N)
        upd = GraphUpdate(delete=dele, add=add)
        cur, _ = update_np_storage(cur, upd)
        aj, dj = jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32)
        pt, sdiag = sstep(pt, aj, dj)
        assert int(sdiag["overflow"]) == 0
        dirty = sdiag["part_dirty"]
        stores, patches, carries, mdiag = mega(pt, stores, carries, dirty,
                                               aj, dj)
        for name in PATTERNS:
            st_r, patch_r, carry_r, rdiag_ = ref_steps[name](
                pt, ref_stores[name], ref_carries[name], dirty, aj, dj)
            ref_stores[name] = st_r
            ref_carries[name] = carry_r
            # byte parity: fused ≡ per-pattern for every output tensor
            for a_, b_ in zip(jax.tree.leaves(stores[name]),
                              jax.tree.leaves(st_r)):
                assert (np.asarray(a_) == np.asarray(b_)).all(), \
                    f"batch {b} {name}: store drift"
            for a_, b_ in zip(jax.tree.leaves(patches[name]),
                              jax.tree.leaves(patch_r)):
                assert (np.asarray(a_) == np.asarray(b_)).all(), \
                    f"batch {b} {name}: patch drift"
            for a_, b_ in zip(jax.tree.leaves(carries[name]),
                              jax.tree.leaves(carry_r)):
                assert (np.asarray(a_) == np.asarray(b_)).all(), \
                    f"batch {b} {name}: carry drift"
            for k in rdiag_:
                assert int(mdiag[name][k]) == int(rdiag_[k]), \
                    f"batch {b} {name}: diag[{k}] drift"
            # …and counts match the host incremental oracle
            matches, units, pat, cover, ord_ = hosts[name]
            matches, _rep = apply_update_to_matches(
                cur, matches, upd, units, pat, cover, ord_)
            hosts[name] = (matches, units, pat, cover, ord_)
            want = matches.count_matches(ord_)
            assert int(mdiag[name]["count"]) == want, \
                f"batch {b} {name}: {int(mdiag[name]['count'])} != {want}"

    print(f"use_pallas={use_pallas}: maintain_mega OK "
          f"({batches} batches, {len(PATTERNS)} patterns)")
