import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import transformer as tf

mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = tf.TransformerConfig(name="tiny-moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                           d_head=16, d_ff=64, vocab=64, moe=True, n_experts=8, top_k=2,
                           n_shared=1, d_expert=32, first_dense=1, remat=False)
params = tf.init_params(cfg, jax.random.PRNGKey(4))
toks = jax.random.randint(jax.random.PRNGKey(5), (4, 8), 0, 64)
ref = tf.forward(params, toks, cfg)  # single-device fallback

specs = tf.param_specs(cfg, mesh.axis_names)
params_s = jax.device_put(params, jax.tree.map(lambda s: NamedSharding(mesh, s), specs))
toks_s = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
with jax.set_mesh(mesh):
    out = jax.jit(lambda p, t: tf.forward(p, t, cfg, mesh))(params_s, toks_s)
np.testing.assert_allclose(np.array(ref, np.float32), np.array(out, np.float32), rtol=5e-2, atol=5e-2)
print("MoE routed (EP=4) == dense fallback OK", out.shape)
