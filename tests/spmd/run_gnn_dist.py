import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import gnn

mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(0)
N, E = 64, 128
for arch, kw in [
    ("gatedgcn", dict(n_layers=2, d_hidden=16, d_in=8, d_out=4)),
    ("graphsage", dict(n_layers=2, d_hidden=16, d_in=8, d_out=4)),
    ("meshgraphnet", dict(n_layers=2, d_hidden=16, d_in=8, d_out=3, d_edge_in=4)),
    ("equiformer_v2", dict(n_layers=2, d_hidden=8, d_in=6, d_out=2, l_max=2, m_max=1, edge_chunk=16)),
]:
    cfg = gnn.GNNConfig(name=arch, arch=arch, remat=False, **kw)
    g = gnn.GraphData(
        x=jnp.asarray(rng.normal(size=(N, cfg.d_in)), jnp.float32),
        src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        edge_attr=jnp.asarray(rng.normal(size=(E, max(cfg.d_edge_in,1))), jnp.float32),
        node_mask=jnp.ones(N, bool), edge_mask=jnp.ones(E, bool),
        positions=jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
    )
    params = gnn.init_params(cfg, jax.random.PRNGKey(1))
    ref_out = gnn.forward(params, g, cfg)  # single-device path
    specs = gnn.graph_specs(mesh.axis_names)
    g_sh = jax.device_put(g, jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs))
    with jax.set_mesh(mesh):
        out = jax.jit(lambda p, gg: gnn.forward(p, gg, cfg, mesh=mesh))(params, g_sh)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(out), rtol=2e-3, atol=2e-3)
    print(f"{arch}: distributed == single-device OK")
print("ALL OK")
