"""8-device fused maintain step vs. the host incremental oracle.

Acceptance check of the device-resident match maintenance: over a
randomized 50-batch update stream, the fused
``make_maintain_step`` (patch ∘ filter ∘ merge ∘ count in one SPMD step
per batch) keeps a sharded :class:`MatchStore` byte-identical to the
host ``apply_update_to_matches`` pipeline — device counts equal host
counts at every watermark, and the materialized store decompresses to
the identical match set. The carry-threaded variant (persistent
per-device unit tables refreshed only on ``part_dirty`` devices) runs
in lock-step and must produce byte-identical stores and patches while
refreshing at most the dirty devices. Run for both ``use_pallas``
settings (fewer batches under the interpret-mode kernel).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DDSL, Graph, GraphUpdate, build_np_storage, symmetry_break
from repro.core.cost import CostModel
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.incremental import apply_update_to_matches
from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
from repro.core.pattern import PATTERN_LIBRARY
from repro.core.storage import update_np_storage
from repro.dist import jax_engine as je
from repro.dist import sharded
from jax.sharding import NamedSharding


def random_graph(n, m, seed):
    r = np.random.default_rng(seed)
    edges = set()
    while len(edges) < m:
        a, b = int(r.integers(n)), int(r.integers(n))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges)))


def sample_batch(graph, rng, n_ops, n):
    ecur = graph.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=n_ops, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < n_ops:
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    return np.array(sorted(add)), dele


N = 48
M = 8
mesh = jax.make_mesh((M,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
BASE_CAPS = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=256, match_cap=2048,
                          group_cap=2048, set_cap=32, pair_cap=64)

for use_pallas in (False, True):
    caps = dataclasses.replace(BASE_CAPS, use_pallas=use_pallas)
    batches = 50 if not use_pallas else 10   # interpret-mode kernel is slower
    g = random_graph(N, 110, seed=5)
    pat = PATTERN_LIBRARY["q2_triangle"]
    ord_ = symmetry_break(pat)
    stats = GraphStats.of(g)
    cover = choose_cover(pat, ord_, stats)
    tree = optimal_join_tree(pat, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    units = minimum_unit_decomposition(pat, cover)
    skel_cols = prog.nodes[prog.root].skel_cols

    storage = build_np_storage(g, M)
    pt = jax.device_put(
        sharded.stack_partitions(storage, caps),
        jax.tree.map(lambda s: NamedSharding(mesh, s), sharded.partition_specs(mesh)))
    out, ldiag = sharded.make_list_step(prog, mesh, caps)(pt)
    assert int(ldiag["overflow"]) == 0
    store_caps = sharded.match_caps(pat, cover, ord_, stats, caps)
    st, idiag = sharded.make_init_store_step(prog, mesh, caps, store_caps)(out)
    assert int(idiag["overflow"]) == 0

    host = DDSL(g, pat, m=M, cover=cover)
    host.initial()
    assert int(idiag["count"]) == host.count(), (int(idiag["count"]), host.count())
    matches = host.state.matches

    ush = sharded.UpdateShapes(n_add=3, n_del=3)
    sstep = sharded.make_storage_update_step(mesh, caps, ush)
    mstep = sharded.make_maintain_step(prog, units, mesh, caps, store_caps)
    ucaps = sharded.unit_table_caps(units, cover, ord_, GraphStats.of(g),
                                    caps)
    carry, rdiag = sharded.make_unit_refresh_step(prog, units, mesh, caps,
                                                  ucaps)(pt)
    assert int(rdiag["overflow"]) == 0
    cstep = sharded.make_maintain_step(prog, units, mesh, caps, store_caps,
                                       unit_caps=ucaps)
    st_c = jax.tree.map(lambda x: x, st)
    refreshes = 0

    rng = np.random.default_rng(11)
    cur = storage
    for b in range(batches):
        add, dele = sample_batch(cur.graph, rng, 3, N)
        upd = GraphUpdate(delete=dele, add=add)
        cur, _ = update_np_storage(cur, upd)
        matches, rep = apply_update_to_matches(
            cur, matches, upd, units, pat, cover, ord_)
        aj, dj = jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32)
        pt, sdiag = sstep(pt, aj, dj)
        st, patch_dev, mdiag = mstep(pt, st, aj, dj)
        st_c, patch_c, carry, cdiag = cstep(pt, st_c, carry,
                                            sdiag["part_dirty"], aj, dj)
        assert int(sdiag["overflow"]) == 0 and int(mdiag["overflow"]) == 0
        assert int(cdiag["overflow"]) == 0
        want = matches.count_matches(ord_)
        assert int(mdiag["count"]) == want, \
            f"batch {b}: device count {int(mdiag['count'])} != host {want}"
        assert int(mdiag["removed_groups"]) == rep.removed_groups
        # carry-threaded step: byte-identical, refreshes ≤ dirty devices
        assert int(cdiag["count"]) == want
        assert int(cdiag["unit_refreshes"]) == int(
            np.asarray(sdiag["part_dirty"]).sum())
        refreshes += int(cdiag["unit_refreshes"])
        for a_, b_ in zip(jax.tree.leaves(st), jax.tree.leaves(st_c)):
            assert (np.asarray(a_) == np.asarray(b_)).all()
        for a_, b_ in zip(jax.tree.leaves(patch_dev), jax.tree.leaves(patch_c)):
            assert (np.asarray(a_) == np.asarray(b_)).all()

    assert refreshes < batches * M, "no batch should dirty every partition"

    # end state: materialized store == host-maintained table, rows exact
    back = je.comp_to_host(st.flatten(), pat, cover, skel_cols)
    hrows = set(map(tuple, matches.decompress(ord_)[1].tolist()))
    drows = set(map(tuple, back.decompress(ord_)[1].tolist()))
    assert hrows == drows, f"pallas={use_pallas}: {len(hrows)} vs {len(drows)}"
    print(f"use_pallas={use_pallas}: maintain_step OK "
          f"({batches} batches, |M|={len(hrows)}, "
          f"carry refreshes {refreshes}/{batches * M} device-batches)")
