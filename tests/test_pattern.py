"""Patterns: automorphisms, SimB, covers, R1 units, linear extensions."""

import math

import pytest

from repro.core.pattern import (
    PATTERN_LIBRARY,
    Pattern,
    automorphisms,
    connected_vertex_covers,
    enumerate_r1_units,
    linear_extension_count,
    symmetry_break,
    vertex_covers,
)

AUT_SIZES = {
    "q1_square": 8,
    "q2_triangle": 6,
    "q3_diamond": 4,
    "q4_clique4": 24,
    "q5_house": 2,
}


@pytest.mark.parametrize("name,expect", sorted(AUT_SIZES.items()))
def test_automorphism_counts(name, expect):
    assert len(automorphisms(PATTERN_LIBRARY[name])) == expect


@pytest.mark.parametrize("name", sorted(PATTERN_LIBRARY))
def test_simb_breaks_all_symmetry(name):
    """Exactly one ord-valid match per instance ⇔ L(ord) · |Aut| = |V|! / …

    Verified directly: the number of automorphisms g s.t. applying g to an
    ord-valid labeling keeps it ord-valid must be 1 — equivalently
    L(ord)/k! == 1/|Aut|.
    """
    p = PATTERN_LIBRARY[name]
    ord_ = symmetry_break(p)
    lec = linear_extension_count(p.vertices, ord_)
    assert lec * len(automorphisms(p)) == math.factorial(p.n)


def test_linear_extension_count_basics():
    assert linear_extension_count((0, 1, 2), ()) == 6
    assert linear_extension_count((0, 1, 2), ((0, 1), (1, 2))) == 1
    assert linear_extension_count((0, 1, 2), ((0, 2),)) == 3


def test_vertex_covers():
    tri = PATTERN_LIBRARY["q2_triangle"]
    covers = vertex_covers(tri)
    # a triangle's covers: any 2 vertices or all 3
    assert {frozenset(c) for c in covers} == {
        frozenset({0, 1}), frozenset({0, 2}), frozenset({1, 2}), frozenset({0, 1, 2})
    }
    for c in connected_vertex_covers(tri):
        assert tri.induced(c).is_connected()


def test_r1_units_cover_pattern():
    for name, p in PATTERN_LIBRARY.items():
        units = enumerate_r1_units(p)
        assert units, name
        covered = frozenset().union(*[u.pattern.edges for u in units])
        assert covered == p.edges, name
        for u in units:
            a = u.anchor
            assert set(u.pattern.neighbors(a)) | {a} == set(u.pattern.vertices)


def test_r1_unit_requires_no_join_for_house():
    """Fig. 2c: the house pattern IS an R1 unit? No — but the diamond is."""
    diamond = PATTERN_LIBRARY["q3_diamond"]
    units = enumerate_r1_units(diamond)
    assert any(u.pattern.key() == diamond.key() for u in units)
    clique = PATTERN_LIBRARY["q4_clique4"]
    units = enumerate_r1_units(clique)
    assert any(u.pattern.key() == clique.key() for u in units)


def test_union_and_induced():
    p = Pattern.make([(0, 1), (1, 2)])
    q = Pattern.make([(2, 3)])
    u = p.union(q)
    assert u.vertices == (0, 1, 2, 3) and len(u.edges) == 3
    ind = u.induced([1, 2, 3])
    assert ind.edges == frozenset({(1, 2), (2, 3)})
