"""repro.stream: journal semantics, shared-delta sharing, service parity.

The streaming contract under test: at every committed watermark the
service's match sets **byte-match** a from-scratch ``DDSL.initial()`` on
the graph obtained by replaying the journal to that watermark — for the
host backend, the single-device sharded backend, and any micro-batch
split the scheduler chooses. Shared-delta sharing is asserted through
the :data:`repro.stream.scheduler.PROBE` counters, not trusted.
"""

import dataclasses

import numpy as np
import pytest

from conftest import random_graph

from repro.core import DDSL, Graph, GraphUpdate
from repro.core.graph import decode_edges
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import sample_update
from repro.stream import (
    BatchScheduler,
    CountDeltaSink,
    ListingService,
    MatchDeltaSink,
    UpdateJournal,
)
from repro.stream import scheduler as stream_scheduler

try:  # hypothesis fuzzing runs where available (CI); deterministic
    # twins of both property tests below always run.
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _rows(table: np.ndarray) -> set:
    return set(map(tuple, np.asarray(table).tolist()))


def _stream(svc, rounds, d, a, seed0=0):
    """Ingest `rounds` sampled updates; returns the per-round tail marks."""
    marks = []
    for b in range(rounds):
        upd = sample_update(svc.projected_graph(), d, a, seed=seed0 + b)
        marks.append(svc.ingest(upd))
    return marks


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------

def test_journal_nets_insert_then_delete_to_nothing():
    j = UpdateJournal()
    j.append_edges(add=[(1, 2)])
    j.append_edges(delete=[(1, 2)])
    net = j.window(0)
    assert net.add.shape[0] == 0 and net.delete.shape[0] == 0


def test_journal_nets_delete_then_reinsert_to_nothing():
    j = UpdateJournal()
    j.append_edges(delete=[(3, 4)])
    j.append_edges(add=[(3, 4)])
    net = j.window(0)
    assert net.size == 0


def test_journal_odd_touches_net_to_first_kind():
    j = UpdateJournal()
    j.append_edges(add=[(1, 2)])
    j.append_edges(delete=[(1, 2)])
    j.append_edges(add=[(1, 2)])
    net = j.window(0)
    assert _rows(net.add) == {(1, 2)} and net.delete.shape[0] == 0


def test_journal_windows_and_watermarks():
    j = UpdateJournal()
    w1 = j.append_edges(add=[(0, 1), (2, 3)])
    w2 = j.append_edges(delete=[(0, 1)])
    assert (w1, w2) == (2, 3) and j.tail == 3
    assert j.pending(0) == 3 and j.pending(w1) == 1
    # Split windows compose to the same net as the full window.
    net_a, net_b = j.window(0, w1), j.window(w1, w2)
    assert _rows(net_a.add) == {(0, 1), (2, 3)} and _rows(net_b.delete) == {(0, 1)}
    full = j.window(0)
    assert _rows(full.add) == {(2, 3)} and full.delete.shape[0] == 0


def test_journal_truncate_bounds_replay():
    j = UpdateJournal()
    j.append_edges(add=[(0, 1)])
    j.append_edges(add=[(1, 2)])
    dropped = j.truncate(1)
    assert dropped == 1 and j.base == 1 and len(j) == 1
    assert _rows(j.replay(1).add) == {(1, 2)}
    with pytest.raises(ValueError):
        j.window(0)


def _check_replay_matches_sequential(ops, lo_frac, hi_frac):
    """Netted replay of any window == applying the raw ops one by one."""
    g0 = random_graph(12, 18, seed=5)
    j = UpdateJournal()
    cur = {int(c) for c in g0.codes}
    states = [set(cur)]          # edge-code state after each op
    applied = 0
    for a, b in ops:
        if a == b:
            continue
        code = (min(a, b) << 32) | max(a, b)
        if code in cur:
            j.append_edges(delete=[(a, b)])
            cur.discard(code)
        else:
            j.append_edges(add=[(a, b)])
            cur.add(code)
        applied += 1
        states.append(set(cur))
    lo = int(round(lo_frac * applied))
    hi = lo + int(round(hi_frac * (applied - lo)))
    net = j.window(lo, hi)
    start, end = states[lo], states[hi]
    g_lo = Graph._from_codes(12, np.array(sorted(start), np.int64))
    g_hi = g_lo.apply_update(net)
    assert {int(c) for c in g_hi.codes} == end


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_journal_replay_matches_sequential_apply(seed):
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(12)), int(rng.integers(12))) for _ in range(30)]
    _check_replay_matches_sequential(ops, float(rng.random()), float(rng.random()))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.tuples(st.integers(0, 11), st.integers(0, 11)),
                    min_size=0, max_size=30),
           st.floats(0, 1), st.floats(0, 1))
    def test_journal_replay_matches_sequential_apply_fuzz(ops, lo_frac, hi_frac):
        _check_replay_matches_sequential(ops, lo_frac, hi_frac)


# ---------------------------------------------------------------------------
# Shared delta: computed once per batch, no matter how many patterns
# ---------------------------------------------------------------------------

def test_shared_delta_decoded_once_per_batch_two_patterns():
    g = random_graph(28, 70, seed=2)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=6))
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    svc.register("sq", PATTERN_LIBRARY["q1_square"])
    _stream(svc, rounds=4, d=3, a=3, seed0=11)
    pending = svc.journal.pending(0)
    stream_scheduler.reset_probe()
    svc.advance()
    n_batches = len(svc.metrics)
    assert n_batches >= 2, "scheduler must have split the stream"
    probe = stream_scheduler.PROBE
    # One decode + one Φ(d') update + one stats refresh per batch —
    # NOT per (batch × pattern).
    assert probe["delta_decodes"] == n_batches
    assert probe["storage_updates"] == n_batches
    assert probe["stats_refreshes"] == n_batches
    assert sum(m.n_ops for m in svc.metrics) == pending


def test_shared_seed_listings_are_cached_across_patterns():
    g = random_graph(28, 70, seed=3)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=100))
    # The same pattern twice: every per-unit seed listing is shareable.
    svc.register("tri_a", PATTERN_LIBRARY["q2_triangle"])
    svc.register("tri_b", PATTERN_LIBRARY["q2_triangle"])
    _stream(svc, rounds=1, d=4, a=4, seed0=21)
    stream_scheduler.reset_probe()
    svc.advance()
    assert len(svc.metrics) == 1
    n_units = len(svc.backend.meta("tri_a").units)
    assert stream_scheduler.PROBE["seed_listings"] == n_units  # not 2 × n_units
    assert svc.count("tri_a") == svc.count("tri_b")


# ---------------------------------------------------------------------------
# Service parity vs. from-scratch listing
# ---------------------------------------------------------------------------

def _assert_byte_match(svc, specs):
    for name, pattern in specs:
        fresh = DDSL(svc.graph, pattern, m=4)
        fresh.initial()
        assert fresh.count() == svc.count(name)
        assert _rows(fresh.matches_plain()) == _rows(svc.backend.matches_plain(name))


def _check_stream_byte_match(seed0, rounds):
    """Random streams: at every committed watermark, journal replay and
    the service's tables byte-match a from-scratch DDSL.initial()."""
    g = random_graph(20, 40, seed=7)
    svc = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=5))
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"])]
    for name, pat in specs:
        svc.register(name, pat)
    for b in range(rounds):
        upd = sample_update(svc.projected_graph(), 2, 2, seed=seed0 + b)
        svc.ingest(upd)
        svc.advance()
        # journal replay to the committed watermark == committed graph
        replayed = Graph._from_codes(
            max(g.n, svc.graph.n), g.apply_update(
                svc.journal.replay(0, svc.committed_watermark)).codes)
        assert {int(c) for c in replayed.codes} == {int(c) for c in svc.graph.codes}
        _assert_byte_match(svc, specs)


@pytest.mark.parametrize("seed0,rounds", [(100, 3), (4242, 2), (77, 4)])
def test_random_stream_counts_byte_match_scratch(seed0, rounds):
    _check_stream_byte_match(seed0, rounds)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 10_000), st.integers(1, 4))
    def test_hypothesis_stream_counts_byte_match_scratch(seed0, rounds):
        _check_stream_byte_match(seed0, rounds)


def test_multi_pattern_shared_delta_parity():
    """Three patterns over one journal advance together and all stay exact."""
    g = random_graph(26, 60, seed=9)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=7), audit_every=2)
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"]),
             ("house", PATTERN_LIBRARY["q5_house"])]
    for name, pat in specs:
        svc.register(name, pat)
    _stream(svc, rounds=5, d=3, a=3, seed0=31)
    svc.advance()
    assert len(svc.metrics) >= 2
    _assert_byte_match(svc, specs)
    assert svc.audits and all(ok for _, _, ok in svc.audits)


def test_50_batch_stream_host_backend_counts_match_scratch():
    """Acceptance: 50 micro-batches, 2 patterns, host backend."""
    g = random_graph(20, 45, seed=13)
    svc = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1))
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"])]
    for name, pat in specs:
        svc.register(name, pat)
    stream_scheduler.reset_probe()
    batches = 0
    b = 0
    while batches < 50:
        upd = sample_update(svc.projected_graph(), 2, 2, seed=1000 + b)
        svc.ingest(upd)
        batches += len(svc.advance())
        b += 1
    assert len(svc.metrics) >= 50
    # Φ updates once per batch with a net effect; no-op windows skip it.
    nonempty = sum(1 for m in svc.metrics if m.net_add + m.net_delete)
    assert stream_scheduler.PROBE["storage_updates"] == nonempty
    _assert_byte_match(svc, specs)


@pytest.mark.slow
def test_50_batch_stream_sharded_backend_counts_match_scratch():
    """Acceptance: 50 micro-batches, 2 patterns, single-device sharded
    backend sharing one device storage step; overflow stays zero."""
    g = random_graph(20, 45, seed=13)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1),
                         max_add=4, max_del=4)
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"])]
    for name, pat in specs:
        svc.register(name, pat)
    batches = 0
    b = 0
    while batches < 50:
        upd = sample_update(svc.projected_graph(), 2, 2, seed=2000 + b)
        svc.ingest(upd)
        batches += len(svc.advance())
        b += 1
    assert len(svc.metrics) >= 50
    assert all(bm.overflow == 0 for bm in svc.metrics)
    # candidate counters are per-batch (delta-bounded), never cumulative
    dcap = svc.backend.caps.deg_cap
    for bm in svc.metrics:
        net = bm.net_add + bm.net_delete
        if net:
            assert 0 < bm.cand_vertices <= 2 * net * (dcap + 1)
            assert 0 < bm.cand_edges <= 2 * net * dcap
        else:
            assert bm.cand_vertices == -1 and bm.cand_edges == -1
    _assert_byte_match(svc, specs)


# ---------------------------------------------------------------------------
# No-op windows: adds/deletes netting to nothing move only the watermark
# ---------------------------------------------------------------------------

def _absent_edges(graph, k, seed=0):
    rng = np.random.default_rng(seed)
    existing = set(map(tuple, graph.edges().tolist()))
    out = set()
    while len(out) < k:
        a, b = int(rng.integers(graph.n)), int(rng.integers(graph.n))
        if a != b and (min(a, b), max(a, b)) not in existing:
            out.add((min(a, b), max(a, b)))
    return sorted(out)


def _check_noop_window(svc, k=2, seed=5):
    edges = _absent_edges(svc.projected_graph(), k, seed=seed)
    svc.ingest(GraphUpdate.make(add=edges))
    svc.ingest(GraphUpdate.make(delete=edges))
    before = dict(svc.counts())
    stream_scheduler.reset_probe()
    svc.advance()
    bm = svc.metrics[-1]
    assert svc.committed_watermark == svc.journal.tail
    assert stream_scheduler.PROBE["storage_updates"] == 0
    assert stream_scheduler.PROBE["delta_decodes"] >= 1
    assert bm.net_add == 0 and bm.net_delete == 0
    assert bm.cand_vertices == -1 and bm.storage_overflow == 0
    assert svc.counts() == before
    for rep in bm.patterns.values():
        assert rep.count_before == rep.count_after
    assert all(svc.audit().values())


def test_noop_window_host_backend():
    g = random_graph(20, 40, seed=31)
    svc = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(min_ops=4, max_ops=64))
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    _check_noop_window(svc, k=2, seed=5)


def test_noop_window_sharded_backend():
    g = random_graph(18, 35, seed=37)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(min_ops=4, max_ops=64),
                         max_add=4, max_del=4)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    _check_noop_window(svc, k=2, seed=7)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 10_000), st.integers(1, 4))
    def test_hypothesis_noop_windows_keep_watermark_parity(seed, k):
        g = random_graph(14, 25, seed=9)
        svc = ListingService(g, m=2, backend="host",
                             scheduler=BatchScheduler(min_ops=2 * k, max_ops=64))
        svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
        _check_noop_window(svc, k=k, seed=seed)


# ---------------------------------------------------------------------------
# Journal truncation at the committed watermark
# ---------------------------------------------------------------------------

def _toggle_ops(journal, graph, ops):
    """Apply toggle ops (delete-if-present / add-if-absent) to a journal."""
    cur = {int(c) for c in graph.codes}
    for a, b in ops:
        if a == b:
            continue
        code = (min(a, b) << 32) | max(a, b)
        if code in cur:
            journal.append_edges(delete=[(a, b)])
            cur.discard(code)
        else:
            journal.append_edges(add=[(a, b)])
            cur.add(code)


def _check_truncate_at_watermark(ops, w_frac):
    """Truncating at a watermark must leave every later window's netting
    (and appended continuation) identical to an untruncated twin."""
    g = random_graph(12, 18, seed=5)
    full = UpdateJournal()
    cut = UpdateJournal()
    _toggle_ops(full, g, ops)
    _toggle_ops(cut, g, ops)
    w = int(round(w_frac * full.tail))
    dropped = cut.truncate(w)
    assert dropped == w and cut.base == w
    assert len(cut) == full.tail - w
    # continuation: both journals keep ingesting the same stream
    for j in (full, cut):
        j.append_edges(add=[(100, 101)])
        j.append_edges(delete=[(100, 101)])
    assert full.tail == cut.tail
    for hi in range(w, full.tail + 1):
        net_f = full.window(w, hi)
        net_c = cut.window(w, hi)
        assert _rows(net_f.add) == _rows(net_c.add)
        assert _rows(net_f.delete) == _rows(net_c.delete)
    assert full.pending(w) == cut.pending(w)
    assert [e.seq for e in cut.entries(w)] == [e.seq for e in full.entries(w)]
    # truncating again at (or below) the same watermark is a no-op
    assert cut.truncate(w) == 0
    # replay below the truncation point is refused, not silently wrong
    if w > 0:
        with pytest.raises(ValueError):
            cut.window(w - 1)


@pytest.mark.parametrize("w_frac", [0.0, 0.33, 0.5, 1.0])
def test_journal_truncate_at_watermark_replay_parity(w_frac):
    rng = np.random.default_rng(11)
    ops = [(int(rng.integers(12)), int(rng.integers(12))) for _ in range(24)]
    _check_truncate_at_watermark(ops, w_frac)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.tuples(st.integers(0, 11), st.integers(0, 11)),
                    min_size=1, max_size=24),
           st.floats(0, 1))
    def test_journal_truncate_replay_parity_fuzz(ops, w_frac):
        _check_truncate_at_watermark(ops, w_frac)


def _check_save_load_replay_parity(ops, w_frac, tmp_path):
    """A saved+loaded journal is indistinguishable from its in-memory
    twin: same watermarks, same netting of every window, same
    continuation after further ingests."""
    g = random_graph(12, 18, seed=5)
    mem = UpdateJournal()
    _toggle_ops(mem, g, ops)
    w = int(round(w_frac * mem.tail))
    mem.truncate(w)
    path = str(tmp_path / "journal.jsonl")
    mem.save(path)
    disk = UpdateJournal.load(path)
    assert (disk.base, disk.tail, len(disk)) == (mem.base, mem.tail, len(mem))
    for j in (mem, disk):
        j.append_edges(add=[(100, 101)])
    for hi in range(mem.base, mem.tail + 1):
        net_m = mem.window(mem.base, hi)
        net_d = disk.window(disk.base, hi)
        assert _rows(net_m.add) == _rows(net_d.add)
        assert _rows(net_m.delete) == _rows(net_d.delete)
    assert [dataclasses.astuple(e) for e in disk.entries(disk.base)] == \
           [dataclasses.astuple(e) for e in mem.entries(mem.base)]


@pytest.mark.parametrize("seed,w_frac", [(0, 0.0), (1, 0.4), (2, 1.0)])
def test_journal_save_load_replay_parity(seed, w_frac, tmp_path):
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(12)), int(rng.integers(12))) for _ in range(20)]
    _check_save_load_replay_parity(ops, w_frac, tmp_path)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.tuples(st.integers(0, 11), st.integers(0, 11)),
                    min_size=1, max_size=20),
           st.floats(0, 1))
    def test_journal_save_load_replay_parity_fuzz(ops, w_frac):
        import tempfile
        from pathlib import Path

        with tempfile.TemporaryDirectory() as d:
            _check_save_load_replay_parity(ops, w_frac, Path(d))


def test_journal_load_rejects_corruption(tmp_path):
    j = UpdateJournal()
    j.append_edges(add=[(0, 1), (1, 2)])
    path = str(tmp_path / "journal.jsonl")
    j.save(path)
    # not a journal
    other = tmp_path / "other.jsonl"
    other.write_text('{"kind": "something-else"}\n')
    with pytest.raises(ValueError):
        UpdateJournal.load(str(other))
    # a torn tail (crashed writer) leaves a sequence gap vs the header
    lines = open(path).read().splitlines()
    (tmp_path / "torn.jsonl").write_text("\n".join(lines[:-1]) + "\n")
    with pytest.raises(ValueError):
        UpdateJournal.load(str(tmp_path / "torn.jsonl"))
    # a bad op kind is refused
    bad = lines[:1] + [lines[1].replace('"op": 1', '"op": 7')] + lines[2:]
    (tmp_path / "bad.jsonl").write_text("\n".join(bad) + "\n")
    with pytest.raises(ValueError):
        UpdateJournal.load(str(tmp_path / "bad.jsonl"))
    # a future format revision fails fast instead of mis-parsing
    fut = [lines[0].replace('"version": 1', '"version": 2')] + lines[1:]
    (tmp_path / "future.jsonl").write_text("\n".join(fut) + "\n")
    with pytest.raises(ValueError, match="version"):
        UpdateJournal.load(str(tmp_path / "future.jsonl"))


def test_journal_truncate_at_tail_then_window_is_empty():
    j = UpdateJournal()
    j.append_edges(add=[(0, 1), (1, 2)])
    assert j.truncate(j.tail) == 2
    assert len(j) == 0 and j.base == j.tail
    net = j.window(j.tail)
    assert net.size == 0
    # appends continue the sequence numbering seamlessly
    assert j.append_edges(add=[(2, 3)]) == 3
    assert _rows(j.window(2).add) == {(2, 3)}


# ---------------------------------------------------------------------------
# Seed-table memo keying (shared-delta correctness oracle)
# ---------------------------------------------------------------------------

def test_seed_provider_key_distinguishes_anchor_and_ord():
    """Two patterns sharing a unit shape but differing in anchor or in
    the ord restriction must each get their own seed table — every
    cached result is checked against a direct listing oracle."""
    from repro.core.match_engine import list_matches
    from repro.core.pattern import Pattern, R1Unit
    from repro.core.storage import build_np_storage
    from repro.core.vcbc import compress_table
    from repro.stream.scheduler import compute_shared_delta

    g = random_graph(20, 45, seed=3)
    storage = build_np_storage(g, 3)
    j = UpdateJournal()
    j.append_edges(add=_absent_edges(g, 2, seed=4))
    delta = compute_shared_delta(j, 0, j.tail)
    delta.ensure_storage(storage)

    tri = Pattern.make([(0, 1), (0, 2), (1, 2)])
    unit = R1Unit(pattern=tri, anchors=(0, 1, 2))
    cases = [
        ((0, 1), ((1, 2),)),   # anchor 0, ord {1<2}
        ((0, 1), ()),          # anchor 0, no ord — must NOT reuse case 1
        ((1, 2), ((1, 2),)),   # anchor 1 — must NOT reuse case 1
        ((0, 1), ((0, 1), (1, 2))),
    ]
    for cover, ord_ in cases:
        got = delta.seed_provider(cover, ord_)(unit)
        anchor = unit.anchor_in(tuple(sorted(cover)))
        pieces = []
        cols = None
        for part in delta.storage.parts:
            cols, t = list_matches(part, tri, ord_, anchor=anchor,
                                   anchor_to_centers=True,
                                   require_edge_codes=delta.add_codes)
            pieces.append(t)
        table = np.concatenate(pieces, axis=0)
        want = compress_table(tri, tuple(sorted(cover)), cols, table)
        assert _rows(got.decompress(ord_)[1]) == _rows(want.decompress(ord_)[1])


def test_seed_provider_key_is_order_canonical():
    """Ord pairs in a different order are the same restriction — the
    memo must share (one listing, not two)."""
    from repro.core.pattern import Pattern, R1Unit
    from repro.core.storage import build_np_storage
    from repro.stream.scheduler import compute_shared_delta

    g = random_graph(20, 45, seed=6)
    storage = build_np_storage(g, 3)
    j = UpdateJournal()
    j.append_edges(add=_absent_edges(g, 2, seed=8))
    delta = compute_shared_delta(j, 0, j.tail)
    delta.ensure_storage(storage)

    tri = Pattern.make([(0, 1), (0, 2), (1, 2)])
    unit = R1Unit(pattern=tri, anchors=(0, 1, 2))
    stream_scheduler.reset_probe()
    a = delta.seed_provider((0, 1), ((0, 1), (1, 2)))(unit)
    b = delta.seed_provider((0, 1), ((1, 2), (0, 1)))(unit)
    assert stream_scheduler.PROBE["seed_listings"] == 1
    assert _rows(a.decompress(((0, 1), (1, 2)))[1]) == _rows(
        b.decompress(((0, 1), (1, 2)))[1])


# ---------------------------------------------------------------------------
# Sinks, metrics, scheduler behavior
# ---------------------------------------------------------------------------

def test_sinks_receive_consistent_deltas():
    g = random_graph(24, 55, seed=15)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=5))
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    counts = svc.subscribe(CountDeltaSink())
    deltas = svc.subscribe(MatchDeltaSink(patterns=["tri"]))
    before = svc.count("tri")
    before_rows = _rows(svc.backend.matches_plain("tri"))
    _stream(svc, rounds=3, d=3, a=3, seed0=41)
    svc.advance()
    # count deltas telescope to the final count
    assert before + counts.totals.get("tri", 0) == svc.count("tri")
    # row deltas replay (in batch order: removes, then adds) to the
    # final match set
    rows = set(before_rows)
    by_hi: dict = {}
    for _, hi, r in deltas.removed:
        by_hi.setdefault(hi, [set(), set()])[0] |= _rows(r)
    for _, hi, r in deltas.added:
        by_hi.setdefault(hi, [set(), set()])[1] |= _rows(r)
    for hi in sorted(by_hi):
        rem, add = by_hi[hi]
        rows -= rem
        rows |= add
    assert rows == _rows(svc.backend.matches_plain("tri"))


def test_ingest_validates_against_projected_graph():
    g = random_graph(12, 20, seed=17)
    svc = ListingService(g, m=2, backend="host")
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    e = tuple(int(x) for x in g.edges()[0])
    with pytest.raises(ValueError):
        svc.ingest(GraphUpdate.make(add=[e]))        # already present
    svc.ingest(GraphUpdate.make(delete=[e]))         # pending delete...
    with pytest.raises(ValueError):
        svc.ingest(GraphUpdate.make(delete=[e]))     # ...can't delete twice
    svc.ingest(GraphUpdate.make(add=[e]))            # re-insert pending is fine
    svc.advance()
    assert svc.audit()["tri"]


def test_scheduler_adapts_batch_size():
    sch = BatchScheduler(target_cost=100.0, target_latency_s=0.010,
                         min_ops=1, max_ops=64)
    tri = PATTERN_LIBRARY["q2_triangle"]
    from repro.core import GraphStats, symmetry_break
    from repro.core.join_tree import minimum_unit_decomposition

    g = random_graph(24, 55, seed=19)
    sch.register("tri", tri, symmetry_break(tri),
                 minimum_unit_decomposition(tri, (0, 1)))
    sch.refresh(GraphStats.of(g))
    k0 = sch.next_batch_size(1_000)
    assert 1 <= k0 <= 64
    # Slow observations shrink the batch; fast ones grow it back.
    sch.observe(k0, elapsed_s=10.0)
    assert sch.next_batch_size(1_000) == 1
    for _ in range(40):
        sch.observe(64, elapsed_s=1e-4)
    assert sch.next_batch_size(1_000) > 1


def test_scheduler_degenerate_bounds_clamp():
    """0/negative bounds or a zero budget must clamp into [1, max_ops],
    never collapse the batch size to 0 (which would spin advance())."""
    sch = BatchScheduler(target_cost=0.0, min_ops=0, max_ops=0)
    assert sch.min_ops == 1 and sch.max_ops == 1
    assert sch.next_batch_size(100) == 1
    assert sch.next_batch_size(0) == 0
    sch2 = BatchScheduler(min_ops=-3, max_ops=-7)
    assert sch2.next_batch_size(50) >= 1
    sch2.clamp_max_ops(0)
    assert sch2.max_ops == 1 and sch2.min_ops == 1


def test_scheduler_empty_graph_estimates_stay_bounded():
    from repro.core import Graph, GraphStats, symmetry_break
    from repro.core.join_tree import minimum_unit_decomposition

    tri = PATTERN_LIBRARY["q2_triangle"]
    sch = BatchScheduler(target_cost=1000.0, min_ops=1, max_ops=32)
    sch.register("tri", tri, symmetry_break(tri),
                 minimum_unit_decomposition(tri, (0, 1)))
    empty = Graph.from_edges(np.empty((0, 2), np.int64), n=0)
    sch.refresh(GraphStats.of(empty))   # zero per-op estimates
    k = sch.next_batch_size(1_000)
    assert 1 <= k <= 32


def test_scheduler_cold_start_ewma_ignores_zero_latency():
    """Batches below clock resolution must not seed (or dilute) the
    latency EWMA — the first *measurable* batch sets the calibration."""
    sch = BatchScheduler(target_cost=1e9, target_latency_s=0.01,
                         min_ops=1, max_ops=1000)
    for _ in range(5):
        sch.observe(10, 0.0)            # zero-resolution clock ticks
    assert sch._sec_per_op is None      # still cold
    assert sch.next_batch_size(10_000) == 1000   # clamped, no div-by-zero
    sch.observe(10, 1.0)                # first real signal: 0.1 s/op
    assert sch._sec_per_op == pytest.approx(0.1)
    sch.observe(10, float("nan"))       # garbage clock reading ignored
    assert sch._sec_per_op == pytest.approx(0.1)
    assert sch.next_batch_size(10_000) == 1      # 0.01s target / 0.1s per op


def test_sharded_per_batch_metrics_reset_each_batch():
    """Candidate counters and overflow are per-micro-batch values, not
    running totals: a small batch after a big one reports the small
    batch's (bounded) numbers, and a no-op batch reports none."""
    g = random_graph(18, 35, seed=41)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(min_ops=1, max_ops=64),
                         max_add=8, max_del=8)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    dcap = svc.backend.caps.deg_cap

    upd = sample_update(svc.projected_graph(), 4, 4, seed=43)   # big batch
    svc.ingest(upd)
    svc.advance()
    big = svc.metrics[-1]
    upd = sample_update(svc.projected_graph(), 1, 1, seed=44)   # small batch
    svc.ingest(upd)
    svc.advance()
    small = svc.metrics[-1]
    assert 0 < big.cand_vertices <= 2 * 8 * (dcap + 1)
    # Were the counters cumulative, the small batch would report at
    # least the big batch's candidate set on top of its own.
    assert 0 < small.cand_vertices <= 2 * 2 * (dcap + 1)
    edges = _absent_edges(svc.projected_graph(), 2, seed=45)    # no-op batch
    svc.ingest(GraphUpdate.make(add=edges))
    svc.ingest(GraphUpdate.make(delete=edges))
    svc.advance(watermark=svc.journal.tail)
    noop = svc.metrics[-1]
    assert noop.cand_vertices == -1 and noop.cand_edges == -1
    assert noop.storage_overflow == 0 and noop.overflow == 0
    assert all(svc.audit().values())


# ---------------------------------------------------------------------------
# Device-resident match maintenance: count-only batches never leave the mesh
# ---------------------------------------------------------------------------

def test_sharded_count_only_batches_keep_matches_on_device():
    """Acceptance: with no match-row subscribers, apply_batch pulls only
    scalars — zero match-state bytes device→host, zero host
    materializations (PROBE), across a multi-batch stream."""
    g = random_graph(18, 35, seed=51)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(min_ops=1, max_ops=8),
                         max_add=4, max_del=4)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    svc.register("sq", PATTERN_LIBRARY["q1_square"])
    svc.subscribe(CountDeltaSink())          # counts only — no rows
    stream_scheduler.reset_probe()
    _stream(svc, rounds=4, d=2, a=2, seed0=53)
    svc.advance()
    assert len(svc.metrics) >= 2
    assert all(bm.host_bytes == 0 for bm in svc.metrics)
    assert stream_scheduler.PROBE["host_materializations"] == 0
    assert svc.backend.total_host_bytes == 0
    # audits ride on the device count reduction — still no pull
    assert all(svc.audit().values())
    assert svc.backend.total_host_bytes == 0
    # on-demand materialization is the only host path, and it is exact
    for name in ("tri", "sq"):
        fresh = DDSL(svc.graph, svc.backend.meta(name).pattern, m=4)
        fresh.initial()
        assert _rows(fresh.matches_plain()) == _rows(svc.backend.matches_plain(name))
    assert svc.backend.total_host_bytes > 0
    assert stream_scheduler.PROBE["host_materializations"] == 2


def test_sharded_match_sink_triggers_lazy_materialization():
    """A wants_matches sink makes exactly the subscribed pattern's rows
    travel: host_bytes goes positive, the deltas replay to the final
    match set, and the materialization cache is per-watermark."""
    g = random_graph(18, 35, seed=55)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(min_ops=1, max_ops=8),
                         max_add=4, max_del=4)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    before_rows = _rows(svc.backend.matches_plain("tri"))
    deltas = svc.subscribe(MatchDeltaSink(patterns=["tri"]))
    _stream(svc, rounds=3, d=2, a=2, seed0=57)
    svc.advance()
    nonempty = [bm for bm in svc.metrics if bm.net_add + bm.net_delete]
    assert nonempty and all(bm.host_bytes > 0 for bm in nonempty)
    rows = set(before_rows)
    by_hi: dict = {}
    for _, hi, r in deltas.removed:
        by_hi.setdefault(hi, [set(), set()])[0] |= _rows(r)
    for _, hi, r in deltas.added:
        by_hi.setdefault(hi, [set(), set()])[1] |= _rows(r)
    for hi in sorted(by_hi):
        rem, add = by_hi[hi]
        rows -= rem
        rows |= add
    assert rows == _rows(svc.backend.matches_plain("tri"))


def _doctored_maintain(be, name="tri", extra=5, store_extra=0):
    """Wrap the backend's fused megastep so one pattern's diag reports
    extra (store-)overflow — the seam every overflow-path test uses."""
    orig = be.maintain_step

    def overflowing_step(pt2, stores, carries, dirty, add, dele):
        stores2, patches, carries2, diag = orig(pt2, stores, carries,
                                                dirty, add, dele)
        d = dict(diag[name])
        d["overflow"] = d["overflow"] + extra
        d["store_overflow"] = d["store_overflow"] + store_extra
        return stores2, patches, carries2, {**diag, name: d}

    return overflowing_step


def _small_sharded_service(seed, **kw):
    g = random_graph(18, 35, seed=seed)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(min_ops=1, max_ops=8),
                         max_add=4, max_del=4, **kw)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    return svc


def test_sharded_strict_overflow_aborts_batch_and_stays_usable():
    """Capped device state is persistent: a maintain overflow would
    lose match groups forever. Strict mode (the fail-stop opt-in) must
    raise before committing the lossy batch — and because the fused
    megastep is atomic across patterns AND may have consumed its
    donated store/carry inputs, the abort path rebuilds the
    committed-watermark state from the never-donated partitions: the
    backend stays fully usable (the donation-safety contract)."""
    svc = _small_sharded_service(seed=61, strict_overflow=True)
    be = svc.backend
    orig = be.maintain_step
    count0 = svc.count("tri")
    be.maintain_step = _doctored_maintain(be)
    _stream(svc, rounds=1, d=2, a=2, seed0=63)
    with pytest.raises(RuntimeError, match="overflowed device caps"):
        svc.advance()
    # nothing committed; the rebuilt pre-batch state still answers
    assert svc.committed_watermark == 0
    assert svc.count("tri") == count0
    assert be.entries["tri"].store is not None
    assert all(svc.audit().values())
    assert svc.backend.matches_plain("tri").shape[1] == 3
    # un-doctored, the SAME pending batch replays over the rebuilt
    # stores/carries and the stream resumes exactly
    be.maintain_step = orig
    svc.advance()
    assert svc.committed_watermark == svc.journal.tail
    assert all(svc.audit().values())


def test_sharded_strict_storage_overflow_raises_before_commit():
    """Storage-step overflow escalates before any store moves (nothing
    committed → not poisoned; a fixed backend can retry). Pin
    never-overflow ushapes: estimator caps would fall back + retry."""
    from repro.dist import sharded as _sharded

    svc = _small_sharded_service(seed=61, strict_overflow=True)
    be = svc.backend
    be.ushapes = _sharded.UpdateShapes(n_add=4, n_del=4)
    orig_storage = be.storage_step

    def overflowing_storage(pt, add, dele):
        pt2, diag = orig_storage(pt, add, dele)
        return pt2, {**diag, "overflow": diag["overflow"] + 3}

    be.storage_step = overflowing_storage
    _stream(svc, rounds=1, d=2, a=2, seed0=63)
    with pytest.raises(RuntimeError, match="storage update overflowed"):
        svc.advance()
    # undoctored backend recovers — the batch was never committed
    be.storage_step = orig_storage
    svc.advance()
    assert svc.committed_watermark == svc.journal.tail
    assert all(svc.audit().values())


def test_sharded_best_effort_mode_downgrades_overflow_to_metric():
    """Non-store overflow (engine caps) in best-effort mode stays a
    counted metric — no resize can fix it, so none is attempted."""
    svc = _small_sharded_service(seed=61, strict_overflow=False)
    svc.backend.maintain_step = _doctored_maintain(svc.backend)
    _stream(svc, rounds=1, d=2, a=2, seed0=63)
    svc.advance()
    assert svc.metrics[-1].overflow >= 5
    assert svc.backend.store_resizes == 0
    assert svc.committed_watermark == svc.journal.tail


def test_sharded_store_overflow_auto_resizes_and_retries():
    """Store-cap overflow in best-effort mode (the default) self-heals:
    ×2 caps, stores rebuilt by re-listing over the never-donated
    partitions, megastep recompiled, same batch retried — nothing lossy
    ever commits and the stream stays exact. The recompile also sheds
    the doctored wrapper, so exactly one resize round runs."""
    svc = _small_sharded_service(seed=61)      # best-effort is the default
    be = svc.backend
    e = be.entries["tri"]
    be.maintain_step = _doctored_maintain(be, extra=3, store_extra=3)
    g0, s0 = e.store_caps.group_cap, e.store_caps.set_cap
    _stream(svc, rounds=1, d=2, a=2, seed0=63)
    svc.advance()
    # one resize: the recompiled (undoctored) step retried cleanly
    assert be.store_resizes == 1
    assert (e.store_caps.group_cap, e.store_caps.set_cap) == (2 * g0, 2 * s0)
    assert svc.metrics[-1].overflow == 0
    assert svc.committed_watermark == svc.journal.tail
    assert all(svc.audit().values())
    # the resized store keeps streaming exactly
    _stream(svc, rounds=1, d=2, a=2, seed0=64)
    svc.advance()
    assert all(svc.audit().values())


def test_estimator_cap_overflow_falls_back_and_retries():
    """A batch that outruns the estimator-sized candidate caps must not
    kill the stream: nothing is committed, the backend permanently
    falls back to the never-overflow derivation, retries the same
    batch, and stays exact."""
    from repro.dist import sharded

    g = random_graph(18, 35, seed=71)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(min_ops=1, max_ops=8),
                         max_add=4, max_del=4)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    # Force caps far below any real candidate set (as if the estimator
    # badly undershot a hub-heavy delta).
    be = svc.backend
    be.ushapes = sharded.UpdateShapes(n_add=4, n_del=4, cand_cap=2, cedge_cap=2)
    be.storage_step = be._sharded.make_storage_update_step(
        be.mesh, be.caps, be.ushapes, mode=be.update_mode)
    _stream(svc, rounds=2, d=2, a=2, seed0=73)
    svc.advance()
    assert be.cap_fallbacks == 1                       # one permanent fallback
    assert be.ushapes.cand_cap is None                 # never-overflow now
    assert svc.committed_watermark == svc.journal.tail
    assert all(bm.storage_overflow == 0 for bm in svc.metrics)
    assert all(svc.audit().values())


def test_update_shapes_from_estimator_clamped_and_fallback():
    """Estimator-sized candidate caps never exceed the never-overflow
    bound (they only shrink the psum payload) and degenerate stats fall
    back to the never-overflow derivation."""
    from repro.core import Graph, GraphStats
    from repro.dist import jax_engine as je
    from repro.dist.sharded import UpdateShapes

    caps = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=512, match_cap=128,
                         group_cap=128, set_cap=16, pair_cap=16)
    g = random_graph(30, 70, seed=3)
    est = UpdateShapes.from_estimator(4, 4, GraphStats.of(g), caps, m=2)
    exact = UpdateShapes(n_add=4, n_del=4)
    c1, cand_e, cedge_e = est.delta_caps(caps, 2)
    _, cand_x, cedge_x = exact.delta_caps(caps, 2)
    assert est.cand_cap is not None and est.cedge_cap is not None
    assert 0 < cand_e <= cand_x and 0 < cedge_e <= cedge_x
    # a heavy-tailed histogram: size-biased mean ≪ deg_cap ⇒ real shrink
    heavy = GraphStats(n=10_000, m=5_776,
                       deg_hist=tuple([0, 9000, 900, 0, 0, 99] + [0] * 250 + [1]))
    big_caps = dataclasses.replace(caps, deg_cap=256, v_cap=8192)
    est_h = UpdateShapes.from_estimator(4, 4, heavy, big_caps, m=2)
    _, cand_h, _ = est_h.delta_caps(big_caps, 2)
    _, cand_nh, _ = UpdateShapes(4, 4).delta_caps(big_caps, 2)
    assert cand_h < cand_nh
    # empty graph: estimator degenerates → never-overflow fallback
    empty = GraphStats(n=0, m=0, deg_hist=(0,))
    fb = UpdateShapes.from_estimator(4, 4, empty, caps, m=2)
    assert fb.cand_cap is None and fb.cedge_cap is None


# ---------------------------------------------------------------------------
# Delta-maintained unit-table cache: cold/warm/invalidation + parity
# ---------------------------------------------------------------------------

def test_unit_cache_cold_warm_invalidation_probe():
    """Acceptance: the first batch cold-fills the cache (|units|·m
    listings); every warm batch re-lists exactly |units| tables per
    *invalidated* partition — the §IV-D `fixed` term scales with the
    dirty set, not the graph — and the PROBE counters prove it."""
    g = random_graph(24, 55, seed=111)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=8))
    svc.register("sq", PATTERN_LIBRARY["q1_square"])
    n_units = len(svc.backend.meta("sq").units)
    m = svc.backend.storage.m

    # --- cold: the cache is empty, every (unit, partition) lists once
    stream_scheduler.reset_probe()
    svc.ingest(sample_update(svc.projected_graph(), 2, 2, seed=112))
    svc.advance()
    cold = svc.metrics[-1]
    assert cold.cache_misses == n_units * m
    assert stream_scheduler.PROBE["cache_misses"] == n_units * m

    # --- warm: only the partitions this delta dirtied re-list
    for b in range(4):
        svc.ingest(sample_update(svc.projected_graph(), 2, 2, seed=120 + b))
        stream_scheduler.reset_probe()
        svc.advance()
        warm = svc.metrics[-1]
        dirty = warm.invalidated_parts
        assert 0 <= dirty <= m
        assert warm.cache_misses == n_units * dirty
        assert warm.cache_hits + warm.cache_misses >= n_units * m
        assert stream_scheduler.PROBE["invalidated_parts"] == dirty
    # warm batches calibrated the scheduler's fixed term downward
    assert svc.scheduler.fixed_miss_rate() < 1.0
    assert svc.scheduler.fixed_cost_warm() < svc.scheduler.fixed_cost_cold() \
        or svc.scheduler.fixed_cost_cold() == 0.0
    # and the cached path stayed exact
    _assert_byte_match(svc, [("sq", PATTERN_LIBRARY["q1_square"])])


def _check_cached_patch_parity(seed0, rounds):
    """nav_join_patch through a delta-maintained PartitionUnitCache ==
    the direct-listing path, byte-for-byte, at every watermark."""
    from repro.core import PartitionUnitCache, build_np_storage
    from repro.core.ddsl import choose_cover
    from repro.core.estimator import GraphStats
    from repro.core.join_tree import minimum_unit_decomposition
    from repro.core.navjoin import NavReport, nav_join_patch
    from repro.core.pattern import symmetry_break
    from repro.core.storage import update_np_storage

    g = random_graph(20, 45, seed=7)
    pat = PATTERN_LIBRARY["q1_square"]
    ord_ = symmetry_break(pat)
    cover = choose_cover(pat, ord_, GraphStats.of(g))
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, 3)
    cache = PartitionUnitCache(storage)
    want_misses = 0
    for b in range(rounds):
        upd = sample_update(storage.graph, 2, 2, seed=seed0 + b)
        storage2, rep = update_np_storage(storage, upd)
        cache.advance(storage2, rep.dirty_parts)
        # cold fill on batch 0, then exactly the dirty partitions
        want_misses += len(units) * (3 if b == 0 else len(rep.dirty_parts))
        r_c, r_p = NavReport(), NavReport()
        cached = nav_join_patch(
            storage2, units, pat, cover, ord_, upd.add, report=r_c,
            provider=cache, seed_fn=cache.seed_fn(cover, ord_, upd.add_codes()))
        plain = nav_join_patch(storage2, units, pat, cover, ord_, upd.add,
                               report=r_p)
        assert _rows(cached.decompress(ord_)[1]) == _rows(plain.decompress(ord_)[1])
        # same tables flowed through the joins — cost metering intact
        assert r_c.local_unit_ints == r_p.local_unit_ints
        assert r_c.patch_matches == r_p.patch_matches
        # warm re-listing is bounded by the dirty partitions
        assert cache.entries() <= len(units) * 3
        storage = storage2
    # exactly cold fill + |units| listings per invalidated partition —
    # never |units|·m per batch
    assert cache.stats.misses == want_misses
    return cache


@pytest.mark.parametrize("seed0", [300, 4711])
def test_cached_patch_byte_parity_50_batches(seed0):
    _check_cached_patch_parity(seed0, rounds=50)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 10_000), st.integers(1, 6))
    def test_cached_patch_byte_parity_fuzz(seed0, rounds):
        _check_cached_patch_parity(seed0, rounds)


def test_provider_bound_to_stale_storage_is_refused():
    """A provider that wasn't advanced to the Φ(d') being patched must
    fail loudly — silently serving stale tables would corrupt patches."""
    from repro.core import PartitionUnitCache, build_np_storage
    from repro.core.ddsl import choose_cover
    from repro.core.estimator import GraphStats
    from repro.core.join_tree import minimum_unit_decomposition
    from repro.core.navjoin import nav_join_patch
    from repro.core.pattern import symmetry_break
    from repro.core.storage import update_np_storage

    g = random_graph(16, 30, seed=9)
    pat = PATTERN_LIBRARY["q2_triangle"]
    ord_ = symmetry_break(pat)
    cover = choose_cover(pat, ord_, GraphStats.of(g))
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, 2)
    cache = PartitionUnitCache(storage)      # bound to Φ(d), not Φ(d')
    upd = sample_update(g, 2, 2, seed=10)
    storage2, _ = update_np_storage(storage, upd)
    with pytest.raises(ValueError, match="different Φ"):
        nav_join_patch(storage2, units, pat, cover, ord_, upd.add,
                       provider=cache)


# ---------------------------------------------------------------------------
# Service snapshot/restore at a watermark
# ---------------------------------------------------------------------------

def test_service_snapshot_restore_host_roundtrip(tmp_path):
    """Snapshot mid-stream (with ops pending beyond the watermark),
    restore, and the restored service is indistinguishable: same
    counts, same committed watermark, the pending ops fold in on the
    next advance, and an identical continuation stays byte-matched."""
    g = random_graph(20, 40, seed=91)
    svc = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=5))
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"])]
    for name, pat in specs:
        svc.register(name, pat)
    _stream(svc, rounds=3, d=2, a=2, seed0=93)
    svc.advance()
    svc.ingest(sample_update(svc.projected_graph(), 2, 2, seed=97))  # pending
    snap = str(tmp_path / "snap")
    svc.snapshot(snap)

    svc2 = ListingService.restore(snap, backend="host", m=3,
                                  scheduler=BatchScheduler(max_ops=5))
    assert svc2.committed_watermark == svc.committed_watermark
    assert svc2.counts() == svc.counts()
    assert svc2.journal.tail == svc.journal.tail
    # identical continuation: drain the pending ops, then keep streaming
    svc.advance()
    svc2.advance()
    assert svc2.counts() == svc.counts()
    upd = sample_update(svc.projected_graph(), 2, 2, seed=98)
    svc.ingest(upd)
    svc2.ingest(upd)
    svc.advance()
    svc2.advance()
    assert svc2.counts() == svc.counts()
    _assert_byte_match(svc2, specs)
    assert all(svc2.audit().values())


def test_service_snapshot_restore_is_backend_neutral(tmp_path):
    """A host snapshot restores into a sharded backend: the MatchStore
    is rebuilt from the snapshot table via stack_matches (no
    from-scratch listing), the stream resumes, and stays exact."""
    g = random_graph(18, 35, seed=101)
    svc = ListingService(g, m=2, backend="host",
                         scheduler=BatchScheduler(max_ops=4))
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    _stream(svc, rounds=2, d=2, a=2, seed0=103)
    svc.advance()
    snap = str(tmp_path / "snap")
    svc.snapshot(snap)

    svc2 = ListingService.restore(snap, backend="sharded",
                                  scheduler=BatchScheduler(max_ops=4),
                                  max_add=4, max_del=4)
    assert svc2.counts() == svc.counts()
    upd = sample_update(svc2.projected_graph(), 2, 2, seed=105)
    svc2.ingest(upd)
    svc2.advance()
    assert all(svc2.audit().values())
    fresh = DDSL(svc2.graph, PATTERN_LIBRARY["q2_triangle"], m=4)
    fresh.initial()
    assert _rows(fresh.matches_plain()) == _rows(svc2.backend.matches_plain("tri"))


def test_service_snapshot_reuses_directory_safely(tmp_path):
    """Re-snapshotting into the same directory must commit the *new*
    watermark — and because the old meta.json is deleted before any
    artifact is rewritten, a crash mid-rewrite can never leave a stale
    commit record over newer tables (the restore-accepts-half-snapshot
    hazard)."""
    import os

    g = random_graph(16, 30, seed=121)
    svc = ListingService(g, m=2, backend="host",
                         scheduler=BatchScheduler(max_ops=4))
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    snap = str(tmp_path / "snap")
    _stream(svc, rounds=1, d=2, a=2, seed0=123)
    svc.advance()
    svc.snapshot(snap)
    w1 = svc.committed_watermark
    _stream(svc, rounds=1, d=2, a=2, seed0=124)
    svc.advance()
    svc.snapshot(snap)                      # reuse the directory
    assert svc.committed_watermark > w1
    svc2 = ListingService.restore(snap, backend="host", m=2)
    assert svc2.committed_watermark == svc.committed_watermark
    assert svc2.counts() == svc.counts()
    # crash simulation: artifacts rewritten but meta.json gone (it is
    # deleted first) — restore refuses instead of replaying stale state
    os.remove(os.path.join(snap, "meta.json"))
    with pytest.raises(FileNotFoundError):
        ListingService.restore(snap, backend="host", m=2)


def test_service_restore_rejects_bad_snapshot(tmp_path):
    (tmp_path / "meta.json").write_text('{"kind": "something-else"}\n')
    with pytest.raises(ValueError):
        ListingService.restore(str(tmp_path))
    (tmp_path / "meta.json").write_text(
        '{"kind": "repro.stream.snapshot", "version": 9}\n')
    with pytest.raises(ValueError, match="version"):
        ListingService.restore(str(tmp_path))


def test_journal_compaction_through_service():
    g = random_graph(16, 30, seed=23)
    svc = ListingService(g, m=2, backend="host")
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    _stream(svc, rounds=2, d=2, a=2, seed0=51)
    svc.advance()
    assert svc.compact() == 8
    assert len(svc.journal) == 0 and svc.journal.base == svc.committed_watermark
    # service keeps running after compaction
    _stream(svc, rounds=1, d=2, a=2, seed0=61)
    svc.advance()
    assert svc.audit()["tri"]
