"""repro.stream: journal semantics, shared-delta sharing, service parity.

The streaming contract under test: at every committed watermark the
service's match sets **byte-match** a from-scratch ``DDSL.initial()`` on
the graph obtained by replaying the journal to that watermark — for the
host backend, the single-device sharded backend, and any micro-batch
split the scheduler chooses. Shared-delta sharing is asserted through
the :data:`repro.stream.scheduler.PROBE` counters, not trusted.
"""

import numpy as np
import pytest

from conftest import random_graph

from repro.core import DDSL, Graph, GraphUpdate
from repro.core.graph import decode_edges
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import sample_update
from repro.stream import (
    BatchScheduler,
    CountDeltaSink,
    ListingService,
    MatchDeltaSink,
    UpdateJournal,
)
from repro.stream import scheduler as stream_scheduler

try:  # hypothesis fuzzing runs where available (CI); deterministic
    # twins of both property tests below always run.
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _rows(table: np.ndarray) -> set:
    return set(map(tuple, np.asarray(table).tolist()))


def _stream(svc, rounds, d, a, seed0=0):
    """Ingest `rounds` sampled updates; returns the per-round tail marks."""
    marks = []
    for b in range(rounds):
        upd = sample_update(svc.projected_graph(), d, a, seed=seed0 + b)
        marks.append(svc.ingest(upd))
    return marks


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------

def test_journal_nets_insert_then_delete_to_nothing():
    j = UpdateJournal()
    j.append_edges(add=[(1, 2)])
    j.append_edges(delete=[(1, 2)])
    net = j.window(0)
    assert net.add.shape[0] == 0 and net.delete.shape[0] == 0


def test_journal_nets_delete_then_reinsert_to_nothing():
    j = UpdateJournal()
    j.append_edges(delete=[(3, 4)])
    j.append_edges(add=[(3, 4)])
    net = j.window(0)
    assert net.size == 0


def test_journal_odd_touches_net_to_first_kind():
    j = UpdateJournal()
    j.append_edges(add=[(1, 2)])
    j.append_edges(delete=[(1, 2)])
    j.append_edges(add=[(1, 2)])
    net = j.window(0)
    assert _rows(net.add) == {(1, 2)} and net.delete.shape[0] == 0


def test_journal_windows_and_watermarks():
    j = UpdateJournal()
    w1 = j.append_edges(add=[(0, 1), (2, 3)])
    w2 = j.append_edges(delete=[(0, 1)])
    assert (w1, w2) == (2, 3) and j.tail == 3
    assert j.pending(0) == 3 and j.pending(w1) == 1
    # Split windows compose to the same net as the full window.
    net_a, net_b = j.window(0, w1), j.window(w1, w2)
    assert _rows(net_a.add) == {(0, 1), (2, 3)} and _rows(net_b.delete) == {(0, 1)}
    full = j.window(0)
    assert _rows(full.add) == {(2, 3)} and full.delete.shape[0] == 0


def test_journal_truncate_bounds_replay():
    j = UpdateJournal()
    j.append_edges(add=[(0, 1)])
    j.append_edges(add=[(1, 2)])
    dropped = j.truncate(1)
    assert dropped == 1 and j.base == 1 and len(j) == 1
    assert _rows(j.replay(1).add) == {(1, 2)}
    with pytest.raises(ValueError):
        j.window(0)


def _check_replay_matches_sequential(ops, lo_frac, hi_frac):
    """Netted replay of any window == applying the raw ops one by one."""
    g0 = random_graph(12, 18, seed=5)
    j = UpdateJournal()
    cur = {int(c) for c in g0.codes}
    states = [set(cur)]          # edge-code state after each op
    applied = 0
    for a, b in ops:
        if a == b:
            continue
        code = (min(a, b) << 32) | max(a, b)
        if code in cur:
            j.append_edges(delete=[(a, b)])
            cur.discard(code)
        else:
            j.append_edges(add=[(a, b)])
            cur.add(code)
        applied += 1
        states.append(set(cur))
    lo = int(round(lo_frac * applied))
    hi = lo + int(round(hi_frac * (applied - lo)))
    net = j.window(lo, hi)
    start, end = states[lo], states[hi]
    g_lo = Graph._from_codes(12, np.array(sorted(start), np.int64))
    g_hi = g_lo.apply_update(net)
    assert {int(c) for c in g_hi.codes} == end


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_journal_replay_matches_sequential_apply(seed):
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(12)), int(rng.integers(12))) for _ in range(30)]
    _check_replay_matches_sequential(ops, float(rng.random()), float(rng.random()))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.tuples(st.integers(0, 11), st.integers(0, 11)),
                    min_size=0, max_size=30),
           st.floats(0, 1), st.floats(0, 1))
    def test_journal_replay_matches_sequential_apply_fuzz(ops, lo_frac, hi_frac):
        _check_replay_matches_sequential(ops, lo_frac, hi_frac)


# ---------------------------------------------------------------------------
# Shared delta: computed once per batch, no matter how many patterns
# ---------------------------------------------------------------------------

def test_shared_delta_decoded_once_per_batch_two_patterns():
    g = random_graph(28, 70, seed=2)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=6))
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    svc.register("sq", PATTERN_LIBRARY["q1_square"])
    _stream(svc, rounds=4, d=3, a=3, seed0=11)
    pending = svc.journal.pending(0)
    stream_scheduler.reset_probe()
    svc.advance()
    n_batches = len(svc.metrics)
    assert n_batches >= 2, "scheduler must have split the stream"
    probe = stream_scheduler.PROBE
    # One decode + one Φ(d') update + one stats refresh per batch —
    # NOT per (batch × pattern).
    assert probe["delta_decodes"] == n_batches
    assert probe["storage_updates"] == n_batches
    assert probe["stats_refreshes"] == n_batches
    assert sum(m.n_ops for m in svc.metrics) == pending


def test_shared_seed_listings_are_cached_across_patterns():
    g = random_graph(28, 70, seed=3)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=100))
    # The same pattern twice: every per-unit seed listing is shareable.
    svc.register("tri_a", PATTERN_LIBRARY["q2_triangle"])
    svc.register("tri_b", PATTERN_LIBRARY["q2_triangle"])
    _stream(svc, rounds=1, d=4, a=4, seed0=21)
    stream_scheduler.reset_probe()
    svc.advance()
    assert len(svc.metrics) == 1
    n_units = len(svc.backend.meta("tri_a").units)
    assert stream_scheduler.PROBE["seed_listings"] == n_units  # not 2 × n_units
    assert svc.count("tri_a") == svc.count("tri_b")


# ---------------------------------------------------------------------------
# Service parity vs. from-scratch listing
# ---------------------------------------------------------------------------

def _assert_byte_match(svc, specs):
    for name, pattern in specs:
        fresh = DDSL(svc.graph, pattern, m=4)
        fresh.initial()
        assert fresh.count() == svc.count(name)
        assert _rows(fresh.matches_plain()) == _rows(svc.backend.matches_plain(name))


def _check_stream_byte_match(seed0, rounds):
    """Random streams: at every committed watermark, journal replay and
    the service's tables byte-match a from-scratch DDSL.initial()."""
    g = random_graph(20, 40, seed=7)
    svc = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=5))
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"])]
    for name, pat in specs:
        svc.register(name, pat)
    for b in range(rounds):
        upd = sample_update(svc.projected_graph(), 2, 2, seed=seed0 + b)
        svc.ingest(upd)
        svc.advance()
        # journal replay to the committed watermark == committed graph
        replayed = Graph._from_codes(
            max(g.n, svc.graph.n), g.apply_update(
                svc.journal.replay(0, svc.committed_watermark)).codes)
        assert {int(c) for c in replayed.codes} == {int(c) for c in svc.graph.codes}
        _assert_byte_match(svc, specs)


@pytest.mark.parametrize("seed0,rounds", [(100, 3), (4242, 2), (77, 4)])
def test_random_stream_counts_byte_match_scratch(seed0, rounds):
    _check_stream_byte_match(seed0, rounds)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 10_000), st.integers(1, 4))
    def test_hypothesis_stream_counts_byte_match_scratch(seed0, rounds):
        _check_stream_byte_match(seed0, rounds)


def test_multi_pattern_shared_delta_parity():
    """Three patterns over one journal advance together and all stay exact."""
    g = random_graph(26, 60, seed=9)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=7), audit_every=2)
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"]),
             ("house", PATTERN_LIBRARY["q5_house"])]
    for name, pat in specs:
        svc.register(name, pat)
    _stream(svc, rounds=5, d=3, a=3, seed0=31)
    svc.advance()
    assert len(svc.metrics) >= 2
    _assert_byte_match(svc, specs)
    assert svc.audits and all(ok for _, _, ok in svc.audits)


def test_50_batch_stream_host_backend_counts_match_scratch():
    """Acceptance: 50 micro-batches, 2 patterns, host backend."""
    g = random_graph(20, 45, seed=13)
    svc = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1))
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"])]
    for name, pat in specs:
        svc.register(name, pat)
    stream_scheduler.reset_probe()
    batches = 0
    b = 0
    while batches < 50:
        upd = sample_update(svc.projected_graph(), 2, 2, seed=1000 + b)
        svc.ingest(upd)
        batches += len(svc.advance())
        b += 1
    assert len(svc.metrics) >= 50
    assert stream_scheduler.PROBE["storage_updates"] == len(svc.metrics)
    _assert_byte_match(svc, specs)


@pytest.mark.slow
def test_50_batch_stream_sharded_backend_counts_match_scratch():
    """Acceptance: 50 micro-batches, 2 patterns, single-device sharded
    backend sharing one device storage step; overflow stays zero."""
    g = random_graph(20, 45, seed=13)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1),
                         max_add=4, max_del=4)
    specs = [("tri", PATTERN_LIBRARY["q2_triangle"]),
             ("sq", PATTERN_LIBRARY["q1_square"])]
    for name, pat in specs:
        svc.register(name, pat)
    batches = 0
    b = 0
    while batches < 50:
        upd = sample_update(svc.projected_graph(), 2, 2, seed=2000 + b)
        svc.ingest(upd)
        batches += len(svc.advance())
        b += 1
    assert len(svc.metrics) >= 50
    assert all(bm.overflow == 0 for bm in svc.metrics)
    _assert_byte_match(svc, specs)


# ---------------------------------------------------------------------------
# Sinks, metrics, scheduler behavior
# ---------------------------------------------------------------------------

def test_sinks_receive_consistent_deltas():
    g = random_graph(24, 55, seed=15)
    svc = ListingService(g, m=4, backend="host",
                         scheduler=BatchScheduler(max_ops=5))
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    counts = svc.subscribe(CountDeltaSink())
    deltas = svc.subscribe(MatchDeltaSink(patterns=["tri"]))
    before = svc.count("tri")
    before_rows = _rows(svc.backend.matches_plain("tri"))
    _stream(svc, rounds=3, d=3, a=3, seed0=41)
    svc.advance()
    # count deltas telescope to the final count
    assert before + counts.totals.get("tri", 0) == svc.count("tri")
    # row deltas replay (in batch order: removes, then adds) to the
    # final match set
    rows = set(before_rows)
    by_hi: dict = {}
    for _, hi, r in deltas.removed:
        by_hi.setdefault(hi, [set(), set()])[0] |= _rows(r)
    for _, hi, r in deltas.added:
        by_hi.setdefault(hi, [set(), set()])[1] |= _rows(r)
    for hi in sorted(by_hi):
        rem, add = by_hi[hi]
        rows -= rem
        rows |= add
    assert rows == _rows(svc.backend.matches_plain("tri"))


def test_ingest_validates_against_projected_graph():
    g = random_graph(12, 20, seed=17)
    svc = ListingService(g, m=2, backend="host")
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    e = tuple(int(x) for x in g.edges()[0])
    with pytest.raises(ValueError):
        svc.ingest(GraphUpdate.make(add=[e]))        # already present
    svc.ingest(GraphUpdate.make(delete=[e]))         # pending delete...
    with pytest.raises(ValueError):
        svc.ingest(GraphUpdate.make(delete=[e]))     # ...can't delete twice
    svc.ingest(GraphUpdate.make(add=[e]))            # re-insert pending is fine
    svc.advance()
    assert svc.audit()["tri"]


def test_scheduler_adapts_batch_size():
    sch = BatchScheduler(target_cost=100.0, target_latency_s=0.010,
                         min_ops=1, max_ops=64)
    tri = PATTERN_LIBRARY["q2_triangle"]
    from repro.core import GraphStats, symmetry_break
    from repro.core.join_tree import minimum_unit_decomposition

    g = random_graph(24, 55, seed=19)
    sch.register("tri", tri, symmetry_break(tri),
                 minimum_unit_decomposition(tri, (0, 1)))
    sch.refresh(GraphStats.of(g))
    k0 = sch.next_batch_size(1_000)
    assert 1 <= k0 <= 64
    # Slow observations shrink the batch; fast ones grow it back.
    sch.observe(k0, elapsed_s=10.0)
    assert sch.next_batch_size(1_000) == 1
    for _ in range(40):
        sch.observe(64, elapsed_s=1e-4)
    assert sch.next_batch_size(1_000) > 1


def test_journal_compaction_through_service():
    g = random_graph(16, 30, seed=23)
    svc = ListingService(g, m=2, backend="host")
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    _stream(svc, rounds=2, d=2, a=2, seed0=51)
    svc.advance()
    assert svc.compact() == 8
    assert len(svc.journal) == 0 and svc.journal.base == svc.committed_watermark
    # service keeps running after compaction
    _stream(svc, rounds=1, d=2, a=2, seed0=61)
    svc.advance()
    assert svc.audit()["tri"]
