"""Graph substrate property tests (hypothesis)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.graph import Graph, GraphUpdate, decode_edges, edge_codes


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)), max_size=80))
def test_from_edges_canonical(pairs):
    edges = [(a, b) for a, b in pairs if a != b]
    g = Graph.from_edges(np.asarray(edges or [(0, 1)], dtype=np.int64))
    # symmetric adjacency
    for u in range(g.n):
        for v in g.neighbors(u):
            assert u in g.neighbors(int(v)).tolist()
    # codes are sorted + unique
    assert (np.diff(g.codes) > 0).all() if g.codes.size > 1 else True
    # degree sum == 2|E|
    assert int(g.degrees.sum()) == 2 * g.num_edges


def test_edge_codes_roundtrip():
    e = np.array([[3, 7], [9, 2], [0, 5]], dtype=np.int64)
    codes = edge_codes(e)
    back = decode_edges(codes)
    assert set(map(tuple, back.tolist())) == {(3, 7), (2, 9), (0, 5)}


def test_has_edges_and_common_neighbors():
    g = Graph.from_edges([(0, 1), (0, 2), (1, 2), (2, 3)])
    assert g.has_edges(np.array([0, 1, 0]), np.array([1, 2, 3])).tolist() == [True, True, False]
    assert g.common_neighbors(0, 1).tolist() == [2]
    assert g.triangle_count() == 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_triangle_count_matches_networkx(seed):
    import networkx as nx

    r = np.random.default_rng(seed)
    edges = set()
    for _ in range(60):
        a, b = int(r.integers(16)), int(r.integers(16))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    g = Graph.from_edges(np.asarray(sorted(edges), dtype=np.int64))
    G = nx.Graph()
    G.add_edges_from(edges)
    assert g.triangle_count() == sum(nx.triangles(G).values()) // 3


def test_apply_update_grows_vertex_space():
    g = Graph.from_edges([(0, 1)])
    g2 = g.apply_update(GraphUpdate.make(add=[(1, 9)]))
    assert g2.n == 10 and g2.num_edges == 2
