"""VCBC (§IV): roundtrip, CC-join correctness, compression-ratio bound."""

import numpy as np
import pytest

from conftest import oracle_instances, random_graph

from repro.core import Graph, choose_cover
from repro.core.cost import CostModel
from repro.core.estimator import GraphStats
from repro.core.join_tree import optimal_join_tree
from repro.core.listing import execute_join_tree, list_unit_all_parts
from repro.core.match_engine import list_matches
from repro.core.pattern import PATTERN_LIBRARY, symmetry_break
from repro.core.storage import build_np_storage
from repro.core.vcbc import cc_join, compress_table, r_lower


def test_compress_decompress_roundtrip():
    g = random_graph(40, 120, seed=0)
    p = PATTERN_LIBRARY["q5_house"]
    ord_ = symmetry_break(p)
    cols, table = list_matches(g, p, ord_)
    for cover in [(0, 1, 2, 3), (0, 1, 2, 3, 4)]:
        t = compress_table(p, cover, cols, table)
        cols2, back = t.decompress(ord_)
        assert cols2 == cols
        assert set(map(tuple, back.tolist())) == set(map(tuple, table.tolist()))


def test_compression_saves_storage():
    """Lemma 4.1 in aggregate: compressed ints ≤ plain ints."""
    g = random_graph(60, 220, seed=3)
    p = PATTERN_LIBRARY["q1_square"]
    ord_ = symmetry_break(p)
    cols, table = list_matches(g, p, ord_)
    stats = GraphStats.of(g)
    cover = choose_cover(p, ord_, stats)
    t = compress_table(p, cover, cols, table)
    plain_ints = table.size
    if table.shape[0]:
        assert t.storage_ints() <= plain_ints
        # Thm 4.1 guarantee: actual ratio ≥ R_lower estimate structure
        ratio = plain_ints / max(t.storage_ints(), 1)
        assert ratio >= 1.0


def test_cc_join_equals_plain_join():
    """Joining unit tables with CC-join == listing the union pattern."""
    g = random_graph(40, 110, seed=5)
    p = PATTERN_LIBRARY["q1_square"]
    ord_ = symmetry_break(p)
    stats = GraphStats.of(g)
    cover = choose_cover(p, ord_, stats)
    storage = build_np_storage(g, 4)
    tree = optimal_join_tree(p, cover, CostModel(cover, ord_, stats))
    result = execute_join_tree(storage, tree, cover, ord_)
    _, joined = result.decompress(ord_)
    _, direct = list_matches(g, p, ord_)
    assert set(map(tuple, joined.tolist())) == set(map(tuple, direct.tolist()))


def test_r_lower_formula():
    # |V|=4, |Vc|=2, |M|=10, |M_skel|=30 → R = 40/(40 + 2*20) = 0.5
    assert r_lower(4, 2, 10, 30) == pytest.approx(0.5)
    assert r_lower(4, 2, 10, 10) == pytest.approx(1.0)
    assert r_lower(4, 4, 10, 10) == pytest.approx(1.0)
