"""PR-model match-count estimator (§IV-D) and the cost model / join tree."""

import numpy as np
import pytest

from conftest import oracle_instances, random_graph

from repro.core.cost import CostModel, storage_estimate
from repro.core.estimator import GraphStats, match_size_estimate
from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
from repro.core.pattern import PATTERN_LIBRARY, Pattern, symmetry_break
from repro.core.ddsl import choose_cover


def test_edge_count_estimate_exact():
    """For p = single edge, E|M| must equal |E(d)| exactly under the model."""
    g = random_graph(60, 200, seed=0)
    stats = GraphStats.of(g)
    p = Pattern.make([(0, 1)])
    ord_ = symmetry_break(p)
    est = match_size_estimate(p, ord_, stats)
    # Σ_i Σ_j deg_i deg_j ρ / 2 == |E| exactly when self-pairs are excluded;
    # the PR model includes them, so allow a small relative slack.
    assert est == pytest.approx(g.num_edges, rel=0.15)


def test_symmetry_correction_ratio():
    """ord-valid triangle estimate must be 1/6 of the unordered one."""
    g = random_graph(60, 200, seed=1)
    stats = GraphStats.of(g)
    tri = PATTERN_LIBRARY["q2_triangle"]
    est_ord = match_size_estimate(tri, symmetry_break(tri), stats)
    est_free = match_size_estimate(tri, (), stats)
    assert est_free / est_ord == pytest.approx(6.0, rel=1e-9)


def test_estimator_tracks_triangle_counts():
    """Right order of magnitude on power-law-ish random graphs."""
    from repro.data.graphs import rmat_graph

    g = rmat_graph(9, 2000, seed=0)
    stats = GraphStats.of(g)
    tri = PATTERN_LIBRARY["q2_triangle"]
    est = match_size_estimate(tri, symmetry_break(tri), stats)
    actual = g.triangle_count()
    if actual > 10:
        assert est / actual < 30 and actual / max(est, 1e-9) < 30


def test_optimal_tree_beats_worst_tree():
    g = random_graph(80, 300, seed=2)
    stats = GraphStats.of(g)
    p = PATTERN_LIBRARY["q5_house"]
    ord_ = symmetry_break(p)
    cover = choose_cover(p, ord_, stats)
    model = CostModel(cover, ord_, stats)
    tree = optimal_join_tree(p, cover, model)
    # optimal tree cost must not exceed a triangle-only decomposition cost
    tree_small_units = optimal_join_tree(p, cover, model, max_unit_size=3)
    assert tree.cost <= tree_small_units.cost + 1e-6


def test_minimum_unit_decomposition_covers():
    for name, p in PATTERN_LIBRARY.items():
        g = random_graph(30, 60, seed=0)
        stats = GraphStats.of(g)
        ord_ = symmetry_break(p)
        cover = choose_cover(p, ord_, stats)
        units = minimum_unit_decomposition(p, cover)
        covered = frozenset().union(*[u.pattern.edges for u in units])
        assert covered == p.edges


def test_storage_estimate_monotone_in_pattern_size():
    g = random_graph(100, 400, seed=3)
    stats = GraphStats.of(g)
    tri = PATTERN_LIBRARY["q2_triangle"]
    sq = PATTERN_LIBRARY["q1_square"]
    s_tri = storage_estimate(tri, (0, 1, 2), symmetry_break(tri), stats)
    assert s_tri > 0
    s_sq = storage_estimate(sq, (0, 1, 2, 3), symmetry_break(sq), stats)
    assert s_sq > 0
