"""Observability stack: metrics registry, span tracer, device profiling.

Covers the `repro.obs` instruments in isolation (golden Prometheus
exposition, ProbeView shim semantics, span-tree mechanics,
compile-vs-execute attribution) and threaded through the streaming
service (span skeleton per batch on both backends, span counters
reconciled against registry deltas, unit-cache LRU budget accounting,
scheduler drift gauge).
"""

import json

import numpy as np
import pytest
from conftest import random_graph

from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import GraphUpdate, sample_update
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS,
    JaxProfiler,
    MetricsRegistry,
    Observability,
    ProbeView,
    ProfiledStep,
    Tracer,
)
from repro.obs.trace import NULL_SPAN
from repro.stream import BatchMetrics, BatchScheduler, ListingService
from repro.stream import scheduler as stream_scheduler


def _stream(svc, rounds, d, a, seed0=0):
    for b in range(rounds):
        svc.ingest(sample_update(svc.projected_graph(), d, a, seed=seed0 + b))


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    r = MetricsRegistry()
    c = r.counter("c_total", "a counter")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)

    lc = r.counter("lc_total", labels=("pattern",))
    lc.labels(pattern="tri").inc(4)
    lc.labels(pattern="sq").inc(1)
    assert lc.value_for(pattern="tri") == 4
    assert lc.value_for(pattern="absent") == 0
    with pytest.raises(ValueError):
        lc.labels(wrong="x")
    with pytest.raises(ValueError):
        lc.inc()            # labeled counter requires labels()

    g = r.gauge("g")
    g.set(2.0)
    g.inc()
    g.dec(0.5)
    assert g.value == 2.5

    h = r.histogram("h_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    cell = h.cell()
    assert cell.counts == [1, 2, 1]     # ≤0.1, ≤1.0, +Inf
    assert cell.count == 4
    assert cell.sum == pytest.approx(6.05)


def test_registry_idempotent_and_kind_conflicts():
    r = MetricsRegistry()
    c1 = r.counter("x_total", "first help wins")
    c2 = r.counter("x_total", "ignored")
    assert c1 is c2 and c1.help == "first help wins"
    with pytest.raises(TypeError):
        r.gauge("x_total")
    with pytest.raises(TypeError):
        r.histogram("x_total")
    assert sorted(r.names()) == ["x_total"]
    r.reset()
    assert r.names() == []
    # buckets must be ascending and unique
    with pytest.raises(ValueError):
        r.histogram("bad", buckets=(1.0, 0.5))


def test_golden_prometheus_exposition():
    """Exposition is deterministic text — byte-exact golden comparison."""
    r = MetricsRegistry()
    r.counter("a_total", "help a").inc(3)
    r.counter("b_total", labels=("p",)).labels(p="x").inc(2.5)
    r.gauge("g", "a gauge").set(1.5)
    h = r.histogram("h_seconds", "hist", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert r.to_prometheus() == (
        "# HELP a_total help a\n"
        "# TYPE a_total counter\n"
        "a_total 3\n"
        "# TYPE b_total counter\n"
        'b_total{p="x"} 2.5\n'
        "# HELP g a gauge\n"
        "# TYPE g gauge\n"
        "g 1.5\n"
        "# HELP h_seconds hist\n"
        "# TYPE h_seconds histogram\n"
        'h_seconds_bucket{le="0.1"} 1\n'
        'h_seconds_bucket{le="1"} 2\n'
        'h_seconds_bucket{le="+Inf"} 3\n'
        "h_seconds_sum 5.55\n"
        "h_seconds_count 3\n"
    )


def test_snapshot_and_json_roundtrip(tmp_path):
    r = MetricsRegistry()
    r.counter("c_total").inc(2)
    r.histogram("h", buckets=(1.0,)).observe(0.5)
    p = tmp_path / "m.json"
    r.save_json(str(p))
    data = json.loads(p.read_text())
    assert data["metrics"]["c_total"]["values"]["{}"] == 2
    assert data["metrics"]["h"]["values"]["{}"]["counts"] == [1, 0]


# ---------------------------------------------------------------------------
# ProbeView — the legacy PROBE dict shim
# ---------------------------------------------------------------------------

def test_probe_view_preserves_dict_surface():
    r = MetricsRegistry()
    pv = ProbeView(r, ("hits", "misses"))
    pv["hits"] += 2
    pv["hits"] += 1
    pv["misses"] += 5
    assert pv["hits"] == 3 and pv["misses"] == 5
    assert pv.copy() == {"hits": 3, "misses": 5}
    assert set(pv) == {"hits", "misses"} and len(pv) == 2
    assert "hits" in pv and "absent" not in pv
    # the actual storage is registry counters
    assert r.get("probe_hits").value == 3
    with pytest.raises(KeyError):
        pv["absent"]
    with pytest.raises(KeyError):
        pv["absent"] = 1
    with pytest.raises(ValueError):
        pv["hits"] = 0       # counters are monotone between resets
    pv.reset()
    assert pv["hits"] == 0 and pv["misses"] == 0
    pv["hits"] += 1
    assert pv["hits"] == 1


def test_global_probe_shim_and_reset():
    stream_scheduler.reset_probe()
    PROBE = stream_scheduler.PROBE
    assert set(PROBE.keys()) == {
        "delta_decodes", "storage_updates", "stats_refreshes",
        "seed_listings", "host_materializations", "cache_hits",
        "cache_misses", "invalidated_parts",
    }
    PROBE["cache_hits"] += 7
    assert PROBE["cache_hits"] == 7
    stream_scheduler.reset_probe()
    assert all(v == 0 for v in PROBE.values())


def test_two_services_keep_isolated_registries():
    """The PROBE clobbering bug: two services in one process used to
    share one global dict. Per-service registries must not cross."""
    stream_scheduler.reset_probe()
    g = random_graph(16, 30, seed=3)
    svcs = []
    for k in range(2):
        svc = ListingService(g, m=2, backend="host",
                             scheduler=BatchScheduler(max_ops=4, min_ops=1))
        svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
        svcs.append(svc)
    _stream(svcs[0], rounds=2, d=1, a=2, seed0=11)
    svcs[0].advance()
    _stream(svcs[1], rounds=1, d=1, a=2, seed0=31)
    svcs[1].advance()
    b0 = svcs[0].obs.metrics.get("stream_batches_total").value
    b1 = svcs[1].obs.metrics.get("stream_batches_total").value
    assert b0 == len(svcs[0].metrics) and b1 == len(svcs[1].metrics)
    assert b0 != b1                       # different work → different books
    # the global shim aggregates across both services
    agg = svcs[0].obs.metrics.get("stream_delta_decodes_total").value \
        + svcs[1].obs.metrics.get("stream_delta_decodes_total").value
    assert stream_scheduler.PROBE["delta_decodes"] == agg > 0


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_disabled_is_noop():
    tr = Tracer(enabled=False)
    sp = tr.span("anything", attr=1)
    assert sp is NULL_SPAN
    with sp as s:
        s.add("k")
        s.set(x=2)
    assert tr.roots == []


def test_tracer_nesting_counters_and_exception_safety():
    tr = Tracer(enabled=True)
    with tr.span("a", idx=0) as a:
        with tr.span("b") as b:
            b.add("k", 2)
            b.add("k")
        try:
            with tr.span("c"):
                raise RuntimeError("boom")
        except RuntimeError:
            pass
        a.add("n_ops", 4)
    assert len(tr.roots) == 1 and tr._stack == []
    root = tr.roots[0]
    assert root.skeleton() == ("a", (("b", ()), ("c", ())))
    assert root.attrs == {"idx": 0}
    assert root.counters == {"n_ops": 4.0}
    assert root.child("b").counters == {"k": 3.0}
    assert root.dur_ns >= root.child("b").dur_ns + root.child("c").dur_ns
    # parent links are consistent
    for sp in root.walk():
        for c in sp.children:
            assert c.parent_id == sp.span_id


def test_tracer_bounds_roots():
    tr = Tracer(enabled=True, max_roots=2)
    for i in range(5):
        with tr.span("r", i=i):
            pass
    assert len(tr.roots) == 2 and tr.dropped_roots == 3
    assert [r.attrs["i"] for r in tr.roots] == [3, 4]


def test_tracer_exports(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("batch", batch_index=0) as b:
        b.add("n_ops", 3)
        with tr.span("shared_delta"):
            pass
    jp = tmp_path / "t.jsonl"
    assert tr.to_jsonl(str(jp)) == 2
    recs = [json.loads(line) for line in jp.read_text().splitlines()]
    assert [r["name"] for r in recs] == ["batch", "shared_delta"]
    assert recs[1]["parent_id"] == recs[0]["span_id"]
    assert recs[0]["counters"] == {"n_ops": 3.0}

    cp = tmp_path / "t_chrome.json"
    assert tr.to_chrome_trace(str(cp)) == 2
    doc = json.loads(cp.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X" and ev["cat"] == "stream"
        assert ev["dur"] >= 0 and ev["ts"] > 0
    # the child event nests inside the parent on the timeline
    par = next(e for e in evs if e["name"] == "batch")
    kid = next(e for e in evs if e["name"] == "shared_delta")
    assert par["ts"] <= kid["ts"]
    assert kid["ts"] + kid["dur"] <= par["ts"] + par["dur"] + 1e-3
    assert par["args"]["n_ops"] == 3.0


# ---------------------------------------------------------------------------
# JaxProfiler — compile vs execute split
# ---------------------------------------------------------------------------

def test_profiled_step_splits_compile_from_execute():
    import jax
    import jax.numpy as jnp

    reg = MetricsRegistry()
    prof = JaxProfiler(reg, enabled=True)
    fn = jax.jit(lambda x: x * 2 + 1)
    step = ProfiledStep("toy", fn, lambda: prof)
    x = jnp.arange(8)
    for _ in range(3):
        step(x)
    rec = prof.steps["toy"]
    assert rec.compiles == 1 and rec.calls == 3
    assert not rec.heuristic
    assert rec.compile_seconds > 0 and rec.execute_seconds > 0
    # AOT analysis of the compiled executable is recorded
    assert rec.cost is not None and rec.memory is not None
    assert rec.memory.get("output_size_in_bytes", 0) > 0
    assert reg.get("jax_compiles_total").value_for(step="toy") == 1
    assert reg.get("jax_execute_calls_total").value_for(step="toy") == 3
    assert reg.get("jax_compile_seconds_total").value_for(step="toy") \
        == pytest.approx(rec.compile_seconds)


def test_profiled_step_recompile_accumulates_under_same_name():
    """Cap fallbacks / store resizes rewrap the jitted step in a NEW
    ProfiledStep under the SAME name — compile #2 must land in the same
    StepProfile, not a fresh one."""
    import jax
    import jax.numpy as jnp

    reg = MetricsRegistry()
    prof = JaxProfiler(reg, enabled=True)
    fn = jax.jit(lambda x: x * 2 + 1)
    s1 = ProfiledStep("toy", fn, lambda: prof)
    s1(jnp.arange(8))
    s2 = ProfiledStep("toy", fn, lambda: prof)   # the rewrap
    s2(jnp.arange(16))                           # new shape → real recompile
    rec = prof.steps["toy"]
    assert rec.compiles == 2 and rec.calls == 2
    assert reg.get("jax_compiles_total").value_for(step="toy") == 2


def test_profiled_step_heuristic_fallback_and_disable():
    import jax.numpy as jnp

    prof = JaxProfiler(MetricsRegistry(), enabled=True)
    # a plain python callable has no .lower() — AOT fails, the split
    # degrades to first-call≈compile and is flagged
    step = ProfiledStep("plain", lambda x: x + 1, lambda: prof)
    step(jnp.ones(3))
    step(jnp.ones(3))
    rec = prof.steps["plain"]
    assert rec.heuristic
    assert rec.compiles == 1 and rec.calls == 1

    # disabled profiler → pure passthrough, zero accounting
    off = JaxProfiler(None, enabled=False)
    s2 = ProfiledStep("off", lambda x: x - 1, lambda: off)
    out = s2(jnp.ones(2))
    assert float(out[0]) == 0.0 and off.steps == {}


# ---------------------------------------------------------------------------
# Observability umbrella
# ---------------------------------------------------------------------------

def test_observability_defaults_and_export(tmp_path):
    obs = Observability()
    assert not obs.tracer.enabled and obs.jaxprof.enabled
    assert Observability.full().tracer.enabled
    assert not Observability.disabled().jaxprof.enabled

    obs.metrics.counter("c_total").inc()
    out = obs.export(str(tmp_path / "a"))
    assert set(out) == {"metrics_json", "metrics_prom"}

    full = Observability.full()
    with full.tracer.span("batch"):
        pass
    out = full.export(str(tmp_path / "b"), prefix="run")
    assert set(out) == {"metrics_json", "metrics_prom",
                        "trace_jsonl", "trace_chrome"}
    for p in out.values():
        assert (tmp_path / "b").joinpath(p.split("/")[-1]).exists()


# ---------------------------------------------------------------------------
# BatchMetrics / scheduler satellite fixes
# ---------------------------------------------------------------------------

def test_throughput_is_zero_not_inf_on_zero_latency():
    bm = BatchMetrics(batch_index=0, lo=0, hi=4, n_ops=4, net_add=2,
                      net_delete=0, latency_s=0.0, patterns={})
    assert bm.throughput_ops_s == 0.0
    bm2 = BatchMetrics(batch_index=0, lo=0, hi=4, n_ops=4, net_add=2,
                       net_delete=0, latency_s=2.0, patterns={})
    assert bm2.throughput_ops_s == 2.0


def test_scheduler_drift_monitor_calibrates_then_tracks():
    s = BatchScheduler(min_ops=1, max_ops=8)
    assert s.predict_seconds(4) is None and s.drift() is None
    # constant-rate observations: after calibration the prediction
    # matches and the drift EWMA sits at 1
    for _ in range(6):
        s.observe(4, 0.1)
    assert s.predict_seconds(4) > 0
    assert s.drift() == pytest.approx(1.0, rel=0.05)
    assert s.last_predicted_s == pytest.approx(s.last_observed_s, rel=0.3)
    # a sustained 3× slowdown pulls the EWMA visibly above 1
    for _ in range(6):
        s.observe(4, 0.3)
    assert s.drift() > 1.2


# ---------------------------------------------------------------------------
# Unit-cache LRU budget
# ---------------------------------------------------------------------------

def _host_pair(seed, **budget):
    g = random_graph(20, 45, seed=seed)
    ref = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1))
    cap = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1),
                         **budget)
    for svc in (ref, cap):
        svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
        svc.register("sq", PATTERN_LIBRARY["q1_square"])
    return ref, cap


def test_unit_cache_entry_budget_evicts_lru_and_stays_exact():
    ref, cap = _host_pair(seed=13, cache_max_entries=2)
    cache = cap.backend.unit_cache
    for b in range(12):
        upd = sample_update(ref.projected_graph(), 2, 2, seed=400 + b)
        ref.ingest(upd)
        cap.ingest(upd)
        ref.advance()
        cap.advance()
        assert cap.counts() == ref.counts()     # eviction never changes results
        assert len(cache._lru) <= 2
    assert cache.stats.evictions > 0
    assert cache.resident_bytes >= 0
    # evictions and footprint surface in the service registry
    m = cap.obs.metrics
    assert m.get("unit_cache_evictions_total").value == cache.stats.evictions
    assert m.get("unit_cache_resident_bytes").value == cache.resident_bytes
    # the capped run re-lists more: misses strictly above the unbudgeted run
    assert m.get("unit_cache_misses_total").value \
        >= ref.obs.metrics.get("unit_cache_misses_total").value


def test_unit_cache_byte_budget_tracks_resident_bytes():
    ref, cap = _host_pair(seed=17, cache_max_bytes=1)
    cache = cap.backend.unit_cache
    for b in range(6):
        upd = sample_update(ref.projected_graph(), 2, 2, seed=900 + b)
        ref.ingest(upd)
        cap.ingest(upd)
        ref.advance()
        cap.advance()
        assert cap.counts() == ref.counts()
        # a 1-byte budget keeps at most the single most-recent entry
        assert len(cache._lru) <= 1
    assert cache.stats.evictions > 0
    assert sum(cache._entry_bytes.values()) == cache.resident_bytes


def test_unit_cache_unbudgeted_never_evicts():
    ref, _ = _host_pair(seed=19)
    for b in range(6):
        ref.ingest(sample_update(ref.projected_graph(), 2, 2, seed=50 + b))
        ref.advance()
    assert ref.backend.unit_cache.stats.evictions == 0
    assert ref.obs.metrics.get("unit_cache_evictions_total") is None


# ---------------------------------------------------------------------------
# Span skeleton over a 50-batch host stream
# ---------------------------------------------------------------------------

_NONEMPTY_SKEL = ("batch", (("shared_delta", ()), ("storage_update", ()),
                            ("maintain", ()), ("maintain", ()), ("sinks", ())))
_NOOP_SKEL = ("batch", (("shared_delta", ()), ("sinks", ())))


def _drive_50(svc, seed0=1000):
    b = 0
    while len(svc.metrics) < 50:
        svc.ingest(sample_update(svc.projected_graph(), 2, 2, seed=seed0 + b))
        b += 1
        svc.advance()
    return svc


def _check_stream_spans(svc):
    roots = svc.obs.tracer.roots
    ms = svc.metrics
    assert len(roots) == len(ms) >= 50
    for root, bm in zip(roots, ms):
        assert root.attrs["batch_index"] == bm.batch_index
        if bm.net_add + bm.net_delete:
            assert root.skeleton() == _NONEMPTY_SKEL
        else:
            # windows netting to nothing skip storage/maintain entirely
            assert root.skeleton() == _NOOP_SKEL
        assert root.counters["n_ops"] == bm.n_ops
        # the batch span covers the measured latency (plus bookkeeping)
        assert root.dur_s >= bm.latency_s * 0.9
        assert root.dur_s <= bm.latency_s + 0.5
        assert sum(c.dur_ns for c in root.children) <= root.dur_ns
    # ---- span counters reconcile with the registry deltas
    m = svc.obs.metrics
    assert m.get("stream_batches_total").value == len(ms)
    assert sum(r.counters["n_ops"] for r in roots) \
        == m.get("stream_ops_total").value == sum(bm.n_ops for bm in ms)
    n_updates = sum(1 for r in roots if r.child("storage_update"))
    assert n_updates == m.get("stream_storage_updates_total").value
    for key, metric in (("cache_hits", "unit_cache_hits_total"),
                        ("cache_misses", "unit_cache_misses_total"),
                        ("invalidated_parts",
                         "unit_cache_invalidated_parts_total")):
        inst = m.get(metric)
        span_total = sum(r.counters.get(key, 0) for r in roots)
        # registry includes register()-time cold fills outside any batch
        assert inst is not None and inst.value >= span_total
    # drift gauge populated once the cost model calibrated
    assert svc.scheduler.drift() is not None
    assert m.get("scheduler_drift_ewma") is not None
    assert m.get("stream_batch_latency_seconds").cell().count \
        == sum(1 for bm in ms if bm.latency_s > 0)


def test_host_stream_span_tree_and_registry_reconcile():
    g = random_graph(20, 45, seed=13)
    svc = ListingService(g, m=3, backend="host",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1),
                         obs=Observability.full())
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    svc.register("sq", PATTERN_LIBRARY["q1_square"])
    _check_stream_spans(_drive_50(svc))


def test_host_noop_batch_has_reduced_skeleton():
    g = random_graph(16, 30, seed=5)
    svc = ListingService(g, m=2, backend="host", obs=Observability.full())
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    # an add+delete of the same absent edges nets to an empty window
    rng = np.random.default_rng(7)
    existing = set(map(tuple, svc.projected_graph().edges().tolist()))
    absent = []
    while len(absent) < 2:
        a, b = int(rng.integers(16)), int(rng.integers(16))
        e = (min(a, b), max(a, b))
        if a != b and e not in existing and e not in absent:
            absent.append(e)
    svc.ingest(GraphUpdate.make(add=absent))
    svc.ingest(GraphUpdate.make(delete=absent))
    svc.advance()
    assert [r.skeleton() for r in svc.obs.tracer.roots] == [_NOOP_SKEL]


def test_default_service_records_no_spans():
    """Tracing is off by default — zero roots, zero span overhead."""
    g = random_graph(16, 30, seed=5)
    svc = ListingService(g, m=2, backend="host")
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    _stream(svc, rounds=2, d=1, a=2, seed0=21)
    svc.advance()
    assert svc.obs.tracer.roots == []
    assert svc.obs.tracer.span("x") is NULL_SPAN
    # metrics still flow on the default (cheap) configuration
    assert svc.obs.metrics.get("stream_batches_total").value == len(svc.metrics)


# ---------------------------------------------------------------------------
# Sharded stream: spans + compile/execute split (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_stream_spans_profile_and_chrome_export(tmp_path):
    g = random_graph(20, 45, seed=13)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(max_ops=4, min_ops=1),
                         max_add=4, max_del=4, obs=Observability.full())
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    _drive_50(svc)
    roots = svc.obs.tracer.roots
    ms = svc.metrics
    assert len(roots) == len(ms) >= 50
    skel = ("batch", (("shared_delta", ()), ("storage_update", ()),
                      ("maintain_mega", ()), ("maintain", ()), ("sinks", ())))
    for root, bm in zip(roots, ms):
        if bm.net_add + bm.net_delete:
            assert root.skeleton() == skel
        else:
            assert root.skeleton() == _NOOP_SKEL
        assert root.counters["n_ops"] == bm.n_ops
        assert root.dur_s >= bm.latency_s * 0.9
    # per-batch spans sum (within tolerance) to the measured latencies
    span_total = sum(r.dur_s for r in roots)
    lat_total = sum(bm.latency_s for bm in ms)
    assert span_total >= lat_total * 0.9
    assert span_total <= lat_total * 1.5 + 1.0

    # ---- compile vs execute split populated for EVERY jitted step
    prof = svc.obs.jaxprof
    expected = {"storage_update", "maintain_mega", "list:tri",
                "init_store:tri", "unit_refresh:tri"}
    assert expected <= set(prof.steps)
    # exactly ONE maintain profile per service — no per-pattern ghosts
    assert not any(n.startswith("maintain:") for n in prof.steps)
    # …but the fused profile still attributes per-pattern cost shares
    assert prof.steps["maintain_mega"].subs == {"tri": 1.0}
    m = svc.obs.metrics
    for name in expected:
        rec = prof.steps[name]
        assert rec.compiles >= 1 and rec.compile_seconds > 0
        assert rec.calls >= 1 and rec.execute_seconds > 0
        assert not rec.heuristic
        assert rec.cost is not None and rec.memory is not None
        assert m.get("jax_compiles_total").value_for(step=name) == rec.compiles
        assert m.get("jax_execute_calls_total").value_for(step=name) == rec.calls
    # steady state: executing a batch is far cheaper than compiling it
    su = prof.steps["storage_update"]
    assert su.execute_seconds / su.calls < su.compile_seconds

    # drift gauge calibrated on the sharded path too
    assert svc.scheduler.drift() is not None
    assert m.get("scheduler_drift_ewma") is not None

    # ---- the whole bundle exports; Chrome trace is Perfetto-loadable
    out = svc.obs.export(str(tmp_path), prefix="sharded")
    doc = json.loads(open(out["trace_chrome"]).read())
    evs = doc["traceEvents"]
    assert len(evs) == sum(1 for r in roots for _ in r.walk())
    assert {e["name"] for e in evs} >= {"batch", "shared_delta",
                                        "storage_update", "maintain", "sinks"}
    assert all(e["ph"] == "X" for e in evs)
    prof_doc = json.loads(open(out["jaxprof_json"]).read())
    assert set(prof_doc["steps"]) == set(prof.steps)


@pytest.mark.slow
def test_sharded_store_resize_recompile_lands_in_same_profile():
    """A store resize recompiles the fused megastep mid-batch; the
    second compile must accumulate into the same ``maintain_mega``
    StepProfile (same step name, no per-pattern ghost entries)."""
    g = random_graph(18, 35, seed=61)
    svc = ListingService(g, backend="sharded",
                         scheduler=BatchScheduler(min_ops=1, max_ops=8),
                         max_add=4, max_del=4)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    be = svc.backend
    orig = be.maintain_step

    def overflowing_step(pt2, stores, carries, dirty, add, dele):
        stores2, patches, carries2, diag = orig(pt2, stores, carries,
                                                dirty, add, dele)
        d = dict(diag["tri"])
        d["overflow"] = d["overflow"] + 3
        d["store_overflow"] = d["store_overflow"] + 3
        return stores2, patches, carries2, {**diag, "tri": d}

    be.maintain_step = overflowing_step
    _stream(svc, rounds=1, d=2, a=2, seed0=63)
    svc.advance()
    assert be.store_resizes == 1
    rec = svc.obs.jaxprof.steps["maintain_mega"]
    assert rec.compiles == 2                      # initial + post-resize
    assert rec.calls >= 2                         # overflowing try + retry
    assert rec.subs == {"tri": 1.0}               # sub-attribution survives
    assert not any(n.startswith("maintain:")
                   for n in svc.obs.jaxprof.steps)
    assert svc.obs.metrics.get("jax_compiles_total") \
              .value_for(step="maintain_mega") == 2
    assert all(svc.audit().values())
