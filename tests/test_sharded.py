"""Sharded tree programs on a single-device mesh (no fake-device flags).

The full 8-device parity checks live in ``tests/spmd/`` (slow,
subprocess-isolated). These tests exercise the same
``stack_partitions → make_list_step / make_update_step`` path in-process
on whatever devices exist, so the sharded layer gets coverage on every
plain ``pytest`` run.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from conftest import random_graph

from repro.core import DDSL, build_np_storage, symmetry_break
from repro.core.cost import CostModel
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.graph import GraphUpdate
from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
from repro.core.navjoin import nav_join_patch
from repro.core.pattern import PATTERN_LIBRARY
from repro.core.storage import update_np_storage
from repro.dist import jax_engine as je
from repro.dist import sharded

CAPS = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=512, match_cap=2048,
                     group_cap=2048, set_cap=32, pair_cap=64)


def _mesh_and_m():
    m = jax.local_device_count()
    mesh = jax.make_mesh((m,), ("data",))
    return mesh, m


def _setup(pname, seed=7):
    g = random_graph(36, 90, seed=seed)
    pat = PATTERN_LIBRARY[pname]
    ord_ = symmetry_break(pat)
    stats = GraphStats.of(g)
    cover = choose_cover(pat, ord_, stats)
    tree = optimal_join_tree(pat, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    return g, pat, ord_, cover, tree, prog


def _shard_input(pt, mesh):
    specs = sharded.partition_specs(mesh)
    return jax.device_put(pt, jax.tree.map(lambda s: NamedSharding(mesh, s), specs))


@pytest.mark.parametrize("pname", ["q2_triangle", "q1_square", "q5_house"])
def test_list_step_matches_host(pname):
    mesh, m = _mesh_and_m()
    g, pat, ord_, cover, tree, prog = _setup(pname)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    step = sharded.make_list_step(prog, mesh, CAPS)
    out, diag = step(pt)
    assert int(diag["overflow"]) == 0

    root = prog.nodes[prog.root]
    skel = np.asarray(out.skeleton).reshape(-1, out.skeleton.shape[-1])
    valid = np.asarray(out.valid).reshape(-1)
    sets = {k: jnp.array(np.asarray(v).reshape(-1, v.shape[-1]))
            for k, v in out.sets.items()}
    t = je.CompTensors(skeleton=jnp.array(skel), valid=jnp.array(valid), sets=sets)
    back = je.comp_to_host(t, root.pattern, cover, root.skel_cols)
    _, jt = back.decompress(ord_)

    eng = DDSL(g, pat, m=m, cover=cover)
    eng.initial()
    _, ht = eng.state.matches.decompress(ord_)
    assert set(map(tuple, ht.tolist())) == set(map(tuple, jt.tolist()))


def test_input_specs_match_stacked_shapes():
    mesh, m = _mesh_and_m()
    g, *_ = _setup("q2_triangle")
    storage = build_np_storage(g, m)
    pt = sharded.stack_partitions(storage, CAPS)
    specs = sharded.ddsl_input_specs(CAPS, m)
    flat_a = jax.tree.leaves(pt)
    flat_s = jax.tree.leaves(specs)
    for a, s in zip(flat_a, flat_s):
        assert tuple(a.shape) == tuple(s.shape)
        assert a.dtype == s.dtype


def test_update_step_matches_host():
    mesh, m = _mesh_and_m()
    g, pat, ord_, cover, tree, prog = _setup("q1_square")
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, m)

    rng = np.random.default_rng(3)
    ecur = g.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=3, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < 3:
        a, b = int(rng.integers(36)), int(rng.integers(36))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    add = np.array(sorted(add))
    upd = GraphUpdate(delete=dele, add=add)

    storage2, _ = update_np_storage(storage, upd)
    patch_host = nav_join_patch(storage2, units, pat, cover, ord_, add)
    _, pht = patch_host.decompress(ord_)

    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    step = sharded.make_update_step(prog, units, mesh, CAPS,
                                    sharded.UpdateShapes(n_add=3, n_del=3))
    pt2, patch, diag = step(pt, jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32))
    assert int(diag["overflow"]) == 0

    # storage delta == rebuild of Φ(d')
    rebuilt = build_np_storage(storage2.graph, m)
    for j in range(m):
        ehi = np.asarray(pt2.edge_hi)[j]
        elo = np.asarray(pt2.edge_lo)[j]
        got = set((int(a), int(b)) for a, b in zip(ehi, elo) if a >= 0)
        want = set((int(c >> 32), int(c & 0xFFFFFFFF)) for c in rebuilt.parts[j].codes)
        assert got == want

    # patch == host Nav-join
    skel = np.asarray(patch.skeleton).reshape(-1, patch.skeleton.shape[-1])
    valid = np.asarray(patch.valid).reshape(-1)
    sets = {k: jnp.array(np.asarray(v).reshape(-1, v.shape[-1]))
            for k, v in patch.sets.items()}
    t = je.CompTensors(skeleton=jnp.array(skel), valid=jnp.array(valid), sets=sets)
    full_skel = tuple(c for c in sorted(cover) if c in set(pat.vertices))
    back = je.comp_to_host(t, pat, cover, full_skel)
    _, jt = back.decompress(ord_)
    assert set(map(tuple, pht.tolist())) == set(map(tuple, jt.tolist()))
