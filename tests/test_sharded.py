"""Sharded tree programs on a single-device mesh (no fake-device flags).

The full 8-device parity checks live in ``tests/spmd/`` (slow,
subprocess-isolated). These tests exercise the same
``stack_partitions → make_list_step / make_update_step`` path in-process
on whatever devices exist, so the sharded layer gets coverage on every
plain ``pytest`` run.
"""

import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from conftest import random_graph

from repro.core import DDSL, build_np_storage, symmetry_break
from repro.core.cost import CostModel
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.graph import GraphUpdate
from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
from repro.core.navjoin import nav_join_patch
from repro.core.pattern import PATTERN_LIBRARY, Pattern
from repro.core.storage import update_np_storage
from repro.dist import jax_engine as je
from repro.dist import sharded

CAPS = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=512, match_cap=2048,
                     group_cap=2048, set_cap=32, pair_cap=64)


def _mesh_and_m():
    m = jax.local_device_count()
    mesh = jax.make_mesh((m,), ("data",))
    return mesh, m


def _setup(pname, seed=7):
    g = random_graph(36, 90, seed=seed)
    pat = PATTERN_LIBRARY[pname]
    ord_ = symmetry_break(pat)
    stats = GraphStats.of(g)
    cover = choose_cover(pat, ord_, stats)
    tree = optimal_join_tree(pat, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    return g, pat, ord_, cover, tree, prog


def _shard_input(pt, mesh):
    specs = sharded.partition_specs(mesh)
    return jax.device_put(pt, jax.tree.map(lambda s: NamedSharding(mesh, s), specs))


@pytest.mark.parametrize("pname", ["q2_triangle", "q1_square", "q5_house"])
def test_list_step_matches_host(pname):
    mesh, m = _mesh_and_m()
    g, pat, ord_, cover, tree, prog = _setup(pname)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    step = sharded.make_list_step(prog, mesh, CAPS)
    out, diag = step(pt)
    assert int(diag["overflow"]) == 0

    root = prog.nodes[prog.root]
    skel = np.asarray(out.skeleton).reshape(-1, out.skeleton.shape[-1])
    valid = np.asarray(out.valid).reshape(-1)
    sets = {k: jnp.array(np.asarray(v).reshape(-1, v.shape[-1]))
            for k, v in out.sets.items()}
    t = je.CompTensors(skeleton=jnp.array(skel), valid=jnp.array(valid), sets=sets)
    back = je.comp_to_host(t, root.pattern, cover, root.skel_cols)
    _, jt = back.decompress(ord_)

    eng = DDSL(g, pat, m=m, cover=cover)
    eng.initial()
    _, ht = eng.state.matches.decompress(ord_)
    assert set(map(tuple, ht.tolist())) == set(map(tuple, jt.tolist()))


def test_input_specs_match_stacked_shapes():
    mesh, m = _mesh_and_m()
    g, *_ = _setup("q2_triangle")
    storage = build_np_storage(g, m)
    pt = sharded.stack_partitions(storage, CAPS)
    specs = sharded.ddsl_input_specs(CAPS, m)
    flat_a = jax.tree.leaves(pt)
    flat_s = jax.tree.leaves(specs)
    for a, s in zip(flat_a, flat_s):
        assert tuple(a.shape) == tuple(s.shape)
        assert a.dtype == s.dtype


# ---------------------------------------------------------------------------
# _purge_nonparticipating: exactness for 3 compressed vertices
# ---------------------------------------------------------------------------

def _purge_oracle(sets, ord_pairs):
    """Brute force: value survives iff it appears in some full assignment
    satisfying injectivity + ord over all compressed vertices."""
    labels = sorted(sets)
    ord_set = set(ord_pairs)
    keep = {u: set() for u in labels}
    for combo in itertools.product(*[sets[u] for u in labels]):
        asg = dict(zip(labels, combo))
        if len(set(combo)) != len(combo):
            continue
        ok = True
        for u, w in itertools.permutations(labels, 2):
            if (u, w) in ord_set and not asg[u] < asg[w]:
                ok = False
        if ok:
            for u in labels:
                keep[u].add(asg[u])
    return keep


def _run_purge(sets, ord_pairs, set_cap=8):
    labels = sorted(sets)
    g_sets = {}
    for u in labels:
        arr = np.full((1, set_cap), je.PAD, np.int32)
        vals = sorted(sets[u])
        arr[0, :len(vals)] = vals
        g_sets[u] = jnp.asarray(arr)
    tc = je.CompTensors(skeleton=jnp.zeros((1, 1), jnp.int32),
                        valid=jnp.ones((1,), bool), sets=g_sets)
    out = sharded._purge_nonparticipating(tc, tuple(labels), tuple(ord_pairs), set_cap)
    got = {u: set(int(x) for x in np.asarray(out.sets[u])[0] if x >= 0) for u in labels}
    return got, bool(np.asarray(out.valid)[0])


def test_purge_three_compressed_vertices_exact_on_crafted_case():
    # Pairwise screening keeps 3 ∈ S₁ (partners exist in S₂ and S₃
    # separately) but no full triple satisfies 1≺2≺3 — the ≤2-exact
    # purge of PR 1 would leave the value (and the group) alive.
    sets = {1: {3}, 2: {5}, 3: {5}}
    ord_pairs = [(1, 2), (2, 3)]
    got, valid = _run_purge(sets, ord_pairs)
    assert not valid and all(not v for v in got.values())


@pytest.mark.parametrize("seed", range(8))
def test_purge_three_compressed_vertices_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    sets = {u: set(rng.choice(10, size=rng.integers(1, 5), replace=False).tolist())
            for u in (1, 2, 3)}
    all_ords = [(1, 2), (1, 3), (2, 3)]
    ord_pairs = [p for p in all_ords if rng.random() < 0.5]
    want = _purge_oracle(sets, ord_pairs)
    got, valid = _run_purge(sets, ord_pairs)
    assert got == {u: set(v) for u, v in want.items()}
    assert valid == any(want[u] for u in want)


def test_purge_two_compressed_vertices_matches_oracle():
    for seed in range(6):
        rng = np.random.default_rng(100 + seed)
        sets = {1: set(rng.choice(8, size=rng.integers(1, 4), replace=False).tolist()),
                2: set(rng.choice(8, size=rng.integers(1, 4), replace=False).tolist())}
        ord_pairs = [(1, 2)] if seed % 2 else []
        want = _purge_oracle(sets, ord_pairs)
        got, valid = _run_purge(sets, ord_pairs)
        assert got == {u: set(v) for u, v in want.items()}


# A cover leaving THREE compressed vertices: V_c = {0, 1}, comp = {2, 3, 4},
# decomposed into two overlapping R1 units — chains share skeletons, so the
# patch path exercises the generalized purge end to end.
PAT_3COMP = Pattern.make([(0, 1), (0, 2), (0, 3), (1, 3), (1, 4)])


def test_update_step_matches_host_three_compressed_vertices():
    mesh, m = _mesh_and_m()
    g = random_graph(30, 75, seed=11)
    pat = PAT_3COMP
    ord_ = symmetry_break(pat)
    cover = (0, 1)
    stats = GraphStats.of(g)
    tree = optimal_join_tree(pat, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    units = minimum_unit_decomposition(pat, cover)
    assert len(set(pat.vertices) - set(cover)) == 3 and len(units) >= 2
    storage = build_np_storage(g, m)

    for seed in (0, 1, 2):
        rng = np.random.default_rng(seed)
        ecur = storage.graph.edges()
        dele = ecur[rng.choice(ecur.shape[0], size=4, replace=False)]
        existing = set(map(tuple, ecur.tolist()))
        add = set()
        while len(add) < 4:
            a, b = int(rng.integers(30)), int(rng.integers(30))
            if a != b and (min(a, b), max(a, b)) not in existing:
                add.add((min(a, b), max(a, b)))
        add = np.array(sorted(add))
        upd = GraphUpdate(delete=dele, add=add)

        storage2, _ = update_np_storage(storage, upd)
        patch_host = nav_join_patch(storage2, units, pat, cover, ord_, add)
        _, pht = patch_host.decompress(ord_)

        pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
        step = sharded.make_update_step(prog, units, mesh, CAPS,
                                        sharded.UpdateShapes(n_add=4, n_del=4))
        _, patch, diag = step(pt, jnp.asarray(add, jnp.int32),
                              jnp.asarray(dele, jnp.int32))
        assert int(diag["overflow"]) == 0
        skel = np.asarray(patch.skeleton).reshape(-1, patch.skeleton.shape[-1])
        valid = np.asarray(patch.valid).reshape(-1)
        sets = {k: jnp.array(np.asarray(v).reshape(-1, v.shape[-1]))
                for k, v in patch.sets.items()}
        t = je.CompTensors(skeleton=jnp.array(skel), valid=jnp.array(valid), sets=sets)
        back = je.comp_to_host(t, pat, cover, (0, 1))
        _, jt = back.decompress(ord_)
        assert set(map(tuple, pht.tolist())) == set(map(tuple, jt.tolist()))
        storage = storage2   # stream the next update over the new state


def test_split_steps_compose_to_fused_update_step():
    """make_storage_update_step + make_patch_step == make_update_step."""
    mesh, m = _mesh_and_m()
    g, pat, ord_, cover, tree, prog = _setup("q2_triangle")
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, m)
    rng = np.random.default_rng(5)
    ecur = g.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=2, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < 2:
        a, b = int(rng.integers(36)), int(rng.integers(36))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    add = np.array(sorted(add))

    ush = sharded.UpdateShapes(n_add=2, n_del=2)
    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    addj = jnp.asarray(add, jnp.int32)
    delj = jnp.asarray(dele, jnp.int32)

    fused = sharded.make_update_step(prog, units, mesh, CAPS, ush)
    pt2_f, patch_f, diag_f = fused(pt, addj, delj)

    sstep = sharded.make_storage_update_step(mesh, CAPS, ush)
    pstep = sharded.make_patch_step(prog, units, mesh, CAPS)
    pt2_s, sdiag = sstep(pt, addj, delj)
    patch_s, pdiag = pstep(pt2_s, addj)

    for a_, b_ in zip(jax.tree.leaves(pt2_f), jax.tree.leaves(pt2_s)):
        assert (np.asarray(a_) == np.asarray(b_)).all()
    for a_, b_ in zip(jax.tree.leaves(patch_f), jax.tree.leaves(patch_s)):
        assert (np.asarray(a_) == np.asarray(b_)).all()
    assert int(diag_f["overflow"]) == int(sdiag["overflow"]) + int(pdiag["overflow"])
    assert int(diag_f["patch_groups"]) == int(pdiag["patch_groups"])


# ---------------------------------------------------------------------------
# Candidate-restricted storage update (Alg. 4 C1–C3 on device)
# ---------------------------------------------------------------------------

def _sample_batch(graph, rng, n_ops, n):
    """One well-formed update batch: delete existing, add absent edges."""
    ecur = graph.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=n_ops, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < n_ops:
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    return np.array(sorted(add)), dele


@pytest.mark.parametrize("use_pallas", [False, True])
def test_delta_storage_step_byte_matches_full_oracle_50_batches(use_pallas):
    """Acceptance: the candidate-restricted update and the full-gather
    oracle produce byte-identical partitions over a randomized 50-batch
    update stream, under both Pallas settings."""
    from repro.core.storage import update_np_storage

    import dataclasses as _dc

    mesh, m = _mesh_and_m()
    n = 30
    g = random_graph(n, 70, seed=21)
    caps = _dc.replace(CAPS, use_pallas=use_pallas)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, caps), mesh)
    ush = sharded.UpdateShapes(n_add=3, n_del=3)
    full = sharded.make_storage_update_step(mesh, caps, ush, mode="full")
    delta = sharded.make_storage_update_step(mesh, caps, ush, mode="delta")

    rng = np.random.default_rng(33)
    cur = storage
    batches = 50 if not use_pallas else 12   # interpret-mode kernel is slower
    for b in range(batches):
        add, dele = _sample_batch(cur.graph, rng, 3, n)
        aj = jnp.asarray(add, jnp.int32)
        dj = jnp.asarray(dele, jnp.int32)
        ptf, diag_f = full(pt, aj, dj)
        ptd, diag_d = delta(pt, aj, dj)
        for xf, xd in zip(jax.tree.leaves(ptf), jax.tree.leaves(ptd)):
            assert (np.asarray(xf) == np.asarray(xd)).all()
        assert int(diag_f["overflow"]) == 0 and int(diag_d["overflow"]) == 0
        # per-batch candidate counters: fresh each call, delta-bounded
        c1 = 2 * (add.shape[0] + dele.shape[0])
        assert 0 < int(diag_d["cand_vertices"]) <= c1 * (caps.deg_cap + 1)
        assert 0 < int(diag_d["cand_edges"]) <= c1 * caps.deg_cap
        pt = ptd
        cur, _ = update_np_storage(cur, GraphUpdate(delete=dele, add=add))

    # end state still equals a from-scratch host rebuild
    rebuilt = build_np_storage(cur.graph, m)
    for j in range(m):
        ehi = np.asarray(pt.edge_hi)[j]
        elo = np.asarray(pt.edge_lo)[j]
        got = set((int(a), int(b)) for a, b in zip(ehi, elo) if a >= 0)
        want = set((int(c >> 32), int(c & 0xFFFFFFFF)) for c in rebuilt.parts[j].codes)
        assert got == want


def test_delta_step_edge_cases_match_oracle():
    """Fresh vertex ids, padded batch slots, and out-of-bounds inserts
    all behave identically to the full-gather oracle (including the
    overflow count for the oob insert)."""
    mesh, m = _mesh_and_m()
    g = random_graph(20, 40, seed=2)
    caps = je.EngineCaps(v_cap=64, deg_cap=16, e_cap=256, match_cap=1024,
                         group_cap=1024, set_cap=16, pair_cap=32)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, caps), mesh)
    ush = sharded.UpdateShapes(n_add=2, n_del=2)
    full = sharded.make_storage_update_step(mesh, caps, ush, mode="full")
    delta = sharded.make_storage_update_step(mesh, caps, ush, mode="delta")

    # brand-new vertices 40/55 + a padded delete slot
    add = jnp.asarray([[40, 55], [3, 40]], jnp.int32)
    dele = jnp.asarray(np.concatenate([g.edges()[:1], [[-1, -1]]]), jnp.int32)
    ptf, df = full(pt, add, dele)
    ptd, dd = delta(pt, add, dele)
    for x, y in zip(jax.tree.leaves(ptf), jax.tree.leaves(ptd)):
        assert (np.asarray(x) == np.asarray(y)).all()
    assert int(df["overflow"]) == 0 and int(dd["overflow"]) == 0

    # an out-of-bounds insert is counted, skipped, and corrupts nothing
    addo = jnp.asarray([[0, m * 64 + 5], [-1, -1]], jnp.int32)
    delz = jnp.full((2, 2), -1, jnp.int32)
    ptf2, df2 = full(pt, addo, delz)
    ptd2, dd2 = delta(pt, addo, delz)
    for x, y in zip(jax.tree.leaves(ptf2), jax.tree.leaves(ptd2)):
        assert (np.asarray(x) == np.asarray(y)).all()
    assert int(df2["overflow"]) == int(dd2["overflow"]) == 1


def test_delta_step_tight_candidate_caps_count_overflow():
    """Explicit (too small) candidate caps must surface in diag, never
    silently truncate."""
    mesh, m = _mesh_and_m()
    g = random_graph(30, 75, seed=5)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    ush = sharded.UpdateShapes(n_add=3, n_del=3, cand_cap=2, cedge_cap=2)
    step = sharded.make_storage_update_step(mesh, CAPS, ush, mode="delta")
    rng = np.random.default_rng(8)
    add, dele = _sample_batch(g, rng, 3, 30)
    _, diag = step(pt, jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32))
    assert int(diag["overflow"]) > 0


def test_update_step_mode_full_and_delta_agree_end_to_end():
    """Fused make_update_step: both modes give identical partitions,
    patches, and patch_groups."""
    mesh, m = _mesh_and_m()
    g, pat, ord_, cover, tree, prog = _setup("q1_square")
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, m)
    rng = np.random.default_rng(17)
    add, dele = _sample_batch(g, rng, 3, 36)
    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    ush = sharded.UpdateShapes(n_add=3, n_del=3)
    aj, dj = jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32)
    pt2_f, patch_f, diag_f = sharded.make_update_step(prog, units, mesh, CAPS,
                                                      ush, mode="full")(pt, aj, dj)
    pt2_d, patch_d, diag_d = sharded.make_update_step(prog, units, mesh, CAPS,
                                                      ush, mode="delta")(pt, aj, dj)
    for a_, b_ in zip(jax.tree.leaves(pt2_f), jax.tree.leaves(pt2_d)):
        assert (np.asarray(a_) == np.asarray(b_)).all()
    for a_, b_ in zip(jax.tree.leaves(patch_f), jax.tree.leaves(patch_d)):
        assert (np.asarray(a_) == np.asarray(b_)).all()
    assert int(diag_f["patch_groups"]) == int(diag_d["patch_groups"])


# ---------------------------------------------------------------------------
# Device-resident match maintenance: filter / merge / count primitives
# ---------------------------------------------------------------------------

def _pad_table(table, group_cap, set_cap):
    """Host CompressedTable → padded CompTensors (test-only helper)."""
    G = table.n_groups
    assert G <= group_cap
    S = len(table.skeleton_cols)
    skel = np.full((group_cap, S), je.PAD, np.int32)
    skel[:G] = table.skeleton
    valid = np.zeros(group_cap, bool)
    valid[:G] = True
    sets = {}
    for v, r in table.comp.items():
        arr = np.full((group_cap, set_cap), je.PAD, np.int32)
        for g in range(G):
            vals = r.values[r.offsets[g]: r.offsets[g + 1]]
            assert vals.shape[0] <= set_cap
            arr[g, : vals.shape[0]] = vals
        sets[v] = jnp.asarray(arr)
    return je.CompTensors(skeleton=jnp.asarray(skel), valid=jnp.asarray(valid),
                          sets=sets)


def _table_rows(table, ord_):
    return set(map(tuple, table.decompress(ord_)[1].tolist()))


def _tensor_rows(tc, pattern, cover, skel_cols, ord_):
    back = je.comp_to_host(tc, pattern, cover, skel_cols)
    return _table_rows(back, ord_)


def _maintenance_fixture(pname_or_pat, seed, cover=None):
    from repro.core.incremental import incremental_update  # noqa: F401

    g = random_graph(30, 70, seed=seed)
    pat = (PATTERN_LIBRARY[pname_or_pat] if isinstance(pname_or_pat, str)
           else pname_or_pat)
    ord_ = symmetry_break(pat)
    stats = GraphStats.of(g)
    cover = choose_cover(pat, ord_, stats) if cover is None else cover
    eng = DDSL(g, pat, m=1, cover=cover)
    eng.initial()
    return g, pat, ord_, cover, eng


@pytest.mark.parametrize("use_pallas", [False, True])
def test_filter_deleted_dev_matches_host(use_pallas):
    from repro.core.incremental import filter_deleted

    g, pat, ord_, cover, eng = _maintenance_fixture("q1_square", seed=19)
    table = eng.state.matches
    tc = _pad_table(table, 256, 16)
    skel_pairs, comp_pairs = je.deleted_edge_cols(pat, table.skeleton_cols)
    rng = np.random.default_rng(3)
    dele = g.edges()[rng.choice(g.num_edges, size=5, replace=False)]
    d = np.stack([dele.min(axis=1), dele.max(axis=1)], axis=1)
    d_tbl, _, _ = je.dedup_rows(jnp.asarray(d, jnp.int32), jnp.ones(5, bool), 5)
    out, removed = je.filter_deleted_dev(
        tc, skel_pairs, comp_pairs, d_tbl[:, 0], d_tbl[:, 1], 16,
        use_pallas=use_pallas)
    want = filter_deleted(table, dele)
    assert _tensor_rows(out, pat, cover, table.skeleton_cols, ord_) == \
        _table_rows(want, ord_)
    assert int(removed) == table.n_groups - want.n_groups


def test_merge_tables_dev_matches_host():
    from repro.core.incremental import merge_tables

    g, pat, ord_, cover, eng = _maintenance_fixture("q2_triangle", seed=23)
    # two overlapping halves of the match set (unequal set widths on
    # purpose: store-wide vs patch-narrow)
    table = eng.state.matches
    cols, rows = table.decompress(ord_)
    from repro.core.vcbc import compress_table
    h = rows.shape[0] // 2
    ta = compress_table(pat, cover, cols, rows[: 2 * h])
    tb = compress_table(pat, cover, cols, rows[h:])
    ca = _pad_table(ta, 128, 16)
    cb = _pad_table(tb, 128, 8)
    out, ovf = je.merge_tables_dev(ca, cb, 256, 16)
    want = merge_tables(ta, tb)
    assert int(ovf) == 0
    assert _tensor_rows(out, pat, cover, table.skeleton_cols, ord_) == \
        _table_rows(want, ord_)
    # forced-small caps overflow loudly, never silently
    _, ovf2 = je.merge_tables_dev(ca, cb, max(want.n_groups - 3, 1), 16)
    assert int(ovf2) > 0


@pytest.mark.parametrize("pat,cover", [
    ("q2_triangle", None),          # 1 compressed vertex
    ("q1_square", None),            # 2 compressed vertices
    (PAT_3COMP, (0, 1)),            # 3 compressed vertices (einsum path)
])
def test_count_matches_dev_matches_host(pat, cover):
    g, p, ord_, cover, eng = _maintenance_fixture(pat, seed=29, cover=cover)
    table = eng.state.matches
    tc = _pad_table(table, 512, 32)
    got = int(je.count_matches_dev(tc, table.skeleton_cols, ord_))
    assert got == table.count_matches(ord_) == eng.count()


def test_count_matches_dev_seven_compressed_vertices():
    """k=7 walks the einsum alphabet past 'g' — the group axis label
    must never collide with a vertex label (regression)."""
    rng = np.random.default_rng(7)
    labels = list(range(1, 8))
    sets = {u: sorted(rng.choice(12, size=3, replace=False).tolist())
            for u in labels}
    ord_pairs = [(1, 2), (3, 4)]
    arrs = {}
    for u in labels:
        a = np.full((1, 4), je.PAD, np.int32)
        a[0, :3] = sets[u]
        arrs[u] = jnp.asarray(a)
    tc = je.CompTensors(skeleton=jnp.full((1, 1), 99, jnp.int32),
                        valid=jnp.ones((1,), bool), sets=arrs)
    got = int(je.count_matches_dev(tc, (0,), ord_pairs))
    want = 0
    for combo in itertools.product(*[sets[u] for u in labels]):
        if len(set(combo)) != len(combo) or 99 in combo:
            continue
        asg = dict(zip(labels, combo))
        if all(asg[a] < asg[b] for a, b in ord_pairs):
            want += 1
    assert got == want and want > 0


def _random_count_tensors(G, S, k, seed=0):
    rng = np.random.default_rng(seed)
    sets = {}
    for u in range(1, k + 1):
        a = np.full((G, S), je.PAD, np.int32)
        for g in range(G):
            w = int(rng.integers(2, S + 1))
            a[g, :w] = np.sort(rng.choice(40, size=w, replace=False))
        sets[u] = jnp.asarray(a)
    skel = jnp.asarray(rng.integers(50, 60, size=(G, 1)).astype(np.int32))
    return je.CompTensors(skeleton=skel, valid=jnp.ones((G,), bool), sets=sets)


@pytest.mark.parametrize("k", [4, 5])
def test_count_matches_dev_chunked_matches_bruteforce(k, monkeypatch):
    """k ≥ 4 routes through the lax.map group chunking — counts must be
    exact for any chunk/G alignment (including a ragged last chunk)."""
    monkeypatch.setattr(je, "_COUNT_CHUNK", 4)
    G, S = 10, 4                      # G = 10 ⇒ chunks of 4, 4, 2
    tc = _random_count_tensors(G, S, k, seed=k)
    ord_pairs = ((1, 2), (3, 4))
    got = int(je.count_matches_dev(tc, (0,), ord_pairs))
    want = 0
    skel = np.asarray(tc.skeleton)
    for g in range(G):
        vals = {u: [int(x) for x in np.asarray(tc.sets[u])[g] if x >= 0]
                for u in tc.sets}
        for combo in itertools.product(*[vals[u] for u in sorted(vals)]):
            if len(set(combo)) != len(combo) or int(skel[g, 0]) in combo:
                continue
            asg = dict(zip(sorted(vals), combo))
            if all(asg[a] < asg[b] for a, b in ord_pairs):
                want += 1
    assert got == want and want > 0


def test_count_matches_dev_chunked_memory_bounded():
    """Regression: at k = 5 the contraction intermediate is O(G·S⁴);
    the chunked lax.map keeps compiled temp memory under the full
    G-sized intermediate (it was ~G/chunk × that before chunking)."""
    G, S, k = 256, 8, 5
    tc = _random_count_tensors(G, S, k, seed=3)
    fn = jax.jit(lambda t: je.count_matches_dev(t, (0,), ((1, 2),)))
    ma = fn.lower(tc).compile().memory_analysis()
    if ma is None or not hasattr(ma, "temp_size_in_bytes"):
        pytest.skip("backend exposes no memory analysis")
    full_intermediate = G * S ** (k - 1) * 4
    assert ma.temp_size_in_bytes < full_intermediate, \
        f"temp {ma.temp_size_in_bytes}B >= unchunked intermediate {full_intermediate}B"


@pytest.mark.parametrize("use_pallas", [False, True])
def test_maintain_step_carry_matches_uncached(use_pallas):
    """Cached-vs-uncached parity: the carry-threaded maintain step
    (persistent unit tables, lax.cond refresh on part_dirty) must
    byte-match the carry-free oracle — stores, patches and counts —
    over a streamed batch sequence, under both Pallas settings."""
    import dataclasses as _dc

    mesh, m = _mesh_and_m()
    g, p, ord_, cover, eng = _maintenance_fixture("q1_square", seed=47)
    caps = _dc.replace(CAPS, use_pallas=use_pallas)
    stats = GraphStats.of(g)
    tree = optimal_join_tree(p, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    units = minimum_unit_decomposition(p, cover)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, caps), mesh)
    out, _ = sharded.make_list_step(prog, mesh, caps)(pt)
    store_caps = sharded.match_caps(p, cover, ord_, stats, caps)
    st, _ = sharded.make_init_store_step(prog, mesh, caps, store_caps)(out)
    st_c = jax.tree.map(lambda x: x, st)

    ucaps = sharded.unit_table_caps(units, cover, ord_, stats, caps)
    carry, rdiag = sharded.make_unit_refresh_step(prog, units, mesh, caps,
                                                  ucaps)(pt)
    assert int(rdiag["overflow"]) == 0
    ush = sharded.UpdateShapes(n_add=3, n_del=3)
    sstep = sharded.make_storage_update_step(mesh, caps, ush)
    oracle = sharded.make_maintain_step(prog, units, mesh, caps, store_caps)
    cached = sharded.make_maintain_step(prog, units, mesh, caps, store_caps,
                                        unit_caps=ucaps)

    rng = np.random.default_rng(49)
    cur = storage
    batches = 2 if use_pallas else 5
    for b in range(batches):
        add, dele = _sample_batch(cur.graph, rng, 3, 30)
        upd = GraphUpdate(delete=dele, add=add)
        cur, _ = update_np_storage(cur, upd)
        aj, dj = jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32)
        pt, sdiag = sstep(pt, aj, dj)
        st, patch_o, odiag = oracle(pt, st, aj, dj)
        st_c, patch_c, carry, cdiag = cached(pt, st_c, carry,
                                             sdiag["part_dirty"], aj, dj)
        assert int(odiag["count"]) == int(cdiag["count"])
        assert int(cdiag["unit_refreshes"]) <= m
        for a_, b_ in zip(jax.tree.leaves(patch_o), jax.tree.leaves(patch_c)):
            assert (np.asarray(a_) == np.asarray(b_)).all()
        for a_, b_ in zip(jax.tree.leaves(st), jax.tree.leaves(st_c)):
            assert (np.asarray(a_) == np.asarray(b_)).all()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_maintain_mega_step_matches_per_pattern(use_pallas):
    """Fused multi-pattern megastep ≡ per-pattern maintain steps: one
    dispatch maintaining triangle + square over a randomized batch
    stream must be byte-identical — stores, patches, carries and diag
    scalars — to running each pattern's carry-threaded step alone,
    under both Pallas settings."""
    import dataclasses as _dc

    mesh, m = _mesh_and_m()
    g = random_graph(30, 70, seed=47)
    caps = _dc.replace(CAPS, use_pallas=use_pallas)
    stats = GraphStats.of(g)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, caps), mesh)

    specs, ref_steps, stores, carries = [], {}, {}, {}
    for name in ("q2_triangle", "q1_square"):
        p = PATTERN_LIBRARY[name]
        ord_ = symmetry_break(p)
        cover = choose_cover(p, ord_, stats)
        tree = optimal_join_tree(p, cover, CostModel(cover, ord_, stats))
        prog = sharded.build_tree_program(tree, cover, ord_)
        units = minimum_unit_decomposition(p, cover)
        out, _ = sharded.make_list_step(prog, mesh, caps)(pt)
        store_caps = sharded.match_caps(p, cover, ord_, stats, caps)
        st, idiag = sharded.make_init_store_step(prog, mesh, caps, store_caps)(out)
        assert int(idiag["overflow"]) == 0
        ucaps = sharded.unit_table_caps(units, cover, ord_, stats, caps)
        carry, _ = sharded.make_unit_refresh_step(prog, units, mesh, caps,
                                                  ucaps)(pt)
        specs.append(sharded.MaintainSpec(name=name, prog=prog,
                                          units=tuple(units),
                                          store=store_caps, unit_caps=ucaps))
        ref_steps[name] = sharded.make_maintain_step(
            prog, units, mesh, caps, store_caps, unit_caps=ucaps)
        stores[name] = st
        carries[name] = carry

    mega = sharded.make_maintain_mega_step(specs, mesh, caps)
    sstep = sharded.make_storage_update_step(mesh, caps,
                                             sharded.UpdateShapes(n_add=3, n_del=3))
    ref_stores = {n: jax.tree.map(lambda x: x, s) for n, s in stores.items()}
    ref_carries = {n: jax.tree.map(lambda x: x, c) for n, c in carries.items()}

    rng = np.random.default_rng(53)
    cur = storage
    batches = 2 if use_pallas else 5
    for b in range(batches):
        add, dele = _sample_batch(cur.graph, rng, 3, 30)
        upd = GraphUpdate(delete=dele, add=add)
        cur, _ = update_np_storage(cur, upd)
        aj, dj = jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32)
        pt, sdiag = sstep(pt, aj, dj)
        dirty = sdiag["part_dirty"]
        stores, patches, carries, mdiag = mega(pt, stores, carries, dirty,
                                               aj, dj)
        for name in ref_steps:
            st_r, patch_r, carry_r, rdiag = ref_steps[name](
                pt, ref_stores[name], ref_carries[name], dirty, aj, dj)
            ref_stores[name], ref_carries[name] = st_r, carry_r
            for got, want in ((stores[name], st_r), (patches[name], patch_r),
                              (carries[name], carry_r)):
                for a_, b_ in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                    assert (np.asarray(a_) == np.asarray(b_)).all(), \
                        f"batch {b} {name}: megastep output drift"
            for k in rdiag:
                assert int(mdiag[name][k]) == int(rdiag[k]), \
                    f"batch {b} {name}: diag[{k}] drift"


def test_patch_step_carry_matches_uncached():
    """Same parity for the standalone patch step: (patch, carry', diag)
    from the carry variant == the carry-free patch, with the carry
    refreshed only on dirty devices."""
    mesh, m = _mesh_and_m()
    g, pat, ord_, cover, tree, prog = _setup("q2_triangle")
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, m)
    stats = GraphStats.of(g)
    rng = np.random.default_rng(5)
    add, dele = _sample_batch(g, rng, 2, 36)
    ush = sharded.UpdateShapes(n_add=2, n_del=2)
    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    addj = jnp.asarray(add, jnp.int32)
    delj = jnp.asarray(dele, jnp.int32)

    sstep = sharded.make_storage_update_step(mesh, CAPS, ush)
    pt2, sdiag = sstep(pt, addj, delj)
    ucaps = sharded.unit_table_caps(units, cover, ord_, stats, CAPS)
    carry, _ = sharded.make_unit_refresh_step(prog, units, mesh, CAPS,
                                              ucaps)(pt2)
    plain = sharded.make_patch_step(prog, units, mesh, CAPS)
    withc = sharded.make_patch_step(prog, units, mesh, CAPS, unit_caps=ucaps)
    patch_p, pdiag = plain(pt2, addj)
    patch_c, carry2, cdiag = withc(pt2, carry, sdiag["part_dirty"], addj)
    for a_, b_ in zip(jax.tree.leaves(patch_p), jax.tree.leaves(patch_c)):
        assert (np.asarray(a_) == np.asarray(b_)).all()
    assert int(pdiag["patch_groups"]) == int(cdiag["patch_groups"])


def test_match_store_stack_and_flatten_roundtrip():
    from repro.core.incremental import merge_tables  # noqa: F401

    g, pat, ord_, cover, eng = _maintenance_fixture("q1_square", seed=31)
    table = eng.state.matches
    store_caps = sharded.StoreCaps(group_cap=128, set_cap=16)
    st = sharded.stack_matches(table, 4, store_caps)
    assert st.skeleton.shape[0] == 4
    assert _tensor_rows(st.flatten(), pat, cover, table.skeleton_cols, ord_) == \
        _table_rows(table, ord_)
    # shard too small for its owners → loud sizing error
    with pytest.raises(ValueError):
        sharded.stack_matches(table, 1, sharded.StoreCaps(group_cap=2, set_cap=16))


@pytest.mark.parametrize("pat,cover,use_pallas", [
    ("q2_triangle", None, False),
    ("q1_square", None, True),
    (PAT_3COMP, (0, 1), False),
])
def test_maintain_step_matches_host_apply_update(pat, cover, use_pallas):
    """Fused maintain (patch ∘ filter ∘ merge ∘ count) over a streamed
    sequence of batches == host apply_update_to_matches, counts from
    the device reduction, store stays exact across batches."""
    import dataclasses as _dc

    from repro.core.incremental import apply_update_to_matches

    mesh, m = _mesh_and_m()
    g, p, ord_, cover, _ = _maintenance_fixture(pat, seed=37, cover=cover)
    caps = _dc.replace(CAPS, use_pallas=use_pallas)
    stats = GraphStats.of(g)
    tree = optimal_join_tree(p, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    units = minimum_unit_decomposition(p, cover)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, caps), mesh)

    list_step = sharded.make_list_step(prog, mesh, caps)
    out, ldiag = list_step(pt)
    assert int(ldiag["overflow"]) == 0
    store_caps = sharded.match_caps(p, cover, ord_, stats, caps)
    init_step = sharded.make_init_store_step(prog, mesh, caps, store_caps)
    st, idiag = init_step(out)
    assert int(idiag["overflow"]) == 0

    host = DDSL(g, p, m=m, cover=cover)
    host.initial()
    assert int(idiag["count"]) == host.count()
    matches = host.state.matches

    ush = sharded.UpdateShapes(n_add=3, n_del=3)
    sstep = sharded.make_storage_update_step(mesh, caps, ush)
    mstep = sharded.make_maintain_step(prog, units, mesh, caps, store_caps)
    rng = np.random.default_rng(41)
    cur = storage
    skel_cols = prog.nodes[prog.root].skel_cols
    batches = 3 if use_pallas else 6       # interpret-mode kernel is slower
    for b in range(batches):
        add, dele = _sample_batch(cur.graph, rng, 3, 30)
        aj, dj = jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32)
        upd = GraphUpdate(delete=dele, add=add)
        cur, _ = update_np_storage(cur, upd)
        matches, rep = apply_update_to_matches(
            cur, matches, upd, units, p, cover, ord_)
        pt, sdiag = sstep(pt, aj, dj)
        st, patch_dev, mdiag = mstep(pt, st, aj, dj)
        assert int(sdiag["overflow"]) == 0 and int(mdiag["overflow"]) == 0
        assert int(mdiag["count"]) == matches.count_matches(ord_)
        assert int(mdiag["removed_groups"]) == rep.removed_groups
        assert int(mdiag["patch_groups"]) == rep.patch.n_groups
        assert _tensor_rows(st.flatten(), p, cover, skel_cols, ord_) == \
            _table_rows(matches, ord_)


def test_maintain_step_store_overflow_is_counted():
    """A store too small for the running match set reports overflow in
    diag — never a silent truncation."""
    mesh, m = _mesh_and_m()
    g, p, ord_, cover, _ = _maintenance_fixture("q2_triangle", seed=43)
    stats = GraphStats.of(g)
    tree = optimal_join_tree(p, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    storage = build_np_storage(g, m)
    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    out, _ = sharded.make_list_step(prog, mesh, CAPS)(pt)
    tiny = sharded.StoreCaps(group_cap=2, set_cap=2)
    _, idiag = sharded.make_init_store_step(prog, mesh, CAPS, tiny)(out)
    assert int(idiag["overflow"]) > 0


def test_update_step_matches_host():
    mesh, m = _mesh_and_m()
    g, pat, ord_, cover, tree, prog = _setup("q1_square")
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, m)

    rng = np.random.default_rng(3)
    ecur = g.edges()
    dele = ecur[rng.choice(ecur.shape[0], size=3, replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    add = set()
    while len(add) < 3:
        a, b = int(rng.integers(36)), int(rng.integers(36))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    add = np.array(sorted(add))
    upd = GraphUpdate(delete=dele, add=add)

    storage2, _ = update_np_storage(storage, upd)
    patch_host = nav_join_patch(storage2, units, pat, cover, ord_, add)
    _, pht = patch_host.decompress(ord_)

    pt = _shard_input(sharded.stack_partitions(storage, CAPS), mesh)
    step = sharded.make_update_step(prog, units, mesh, CAPS,
                                    sharded.UpdateShapes(n_add=3, n_del=3))
    pt2, patch, diag = step(pt, jnp.asarray(add, jnp.int32), jnp.asarray(dele, jnp.int32))
    assert int(diag["overflow"]) == 0

    # storage delta == rebuild of Φ(d')
    rebuilt = build_np_storage(storage2.graph, m)
    for j in range(m):
        ehi = np.asarray(pt2.edge_hi)[j]
        elo = np.asarray(pt2.edge_lo)[j]
        got = set((int(a), int(b)) for a, b in zip(ehi, elo) if a >= 0)
        want = set((int(c >> 32), int(c & 0xFFFFFFFF)) for c in rebuilt.parts[j].codes)
        assert got == want

    # patch == host Nav-join
    skel = np.asarray(patch.skeleton).reshape(-1, patch.skeleton.shape[-1])
    valid = np.asarray(patch.valid).reshape(-1)
    sets = {k: jnp.array(np.asarray(v).reshape(-1, v.shape[-1]))
            for k, v in patch.sets.items()}
    t = je.CompTensors(skeleton=jnp.array(skel), valid=jnp.array(valid), sets=sets)
    full_skel = tuple(c for c in sorted(cover) if c in set(pat.vertices))
    back = je.comp_to_host(t, pat, cover, full_skel)
    _, jt = back.decompress(ord_)
    assert set(map(tuple, pht.tolist())) == set(map(tuple, jt.tolist()))
