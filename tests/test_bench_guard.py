"""Bench-regression guard: tolerance-band comparison logic.

Pure-function coverage of :func:`benchmarks.common.compare_baseline` —
the CI stream-smoke job relies on this to turn `BENCH_*.json` artifacts
into a pass/fail signal, so the band semantics (multiplicative
tolerance + absolute noise slack, new/missing row handling) are pinned
here instead of trusted.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import Row, compare_baseline  # noqa: E402


def _baseline(**rows):
    return {"benchmark": "x",
            "rows": [{"name": k, "us_per_call": v, "derived": {}}
                     for k, v in rows.items()]}


def test_within_band_passes():
    base = _baseline(a=1000.0, b=50_000.0)
    fresh = [Row("a", 1800.0), Row("b", 99_000.0)]   # < 2x + 500us
    reg, missing, diff = compare_baseline(fresh, base)
    assert reg == [] and missing == []
    assert {d["name"]: d["status"] for d in diff["rows"]} == {"a": "ok", "b": "ok"}


def test_regression_beyond_band_fails():
    base = _baseline(a=1000.0)
    reg, _, diff = compare_baseline([Row("a", 2600.0)], base)   # > 2x + 500us
    assert reg == ["a"]
    row = diff["rows"][0]
    assert row["status"] == "regression" and row["ratio"] == pytest.approx(2.6)


def test_abs_slack_protects_noisy_fast_rows():
    """A 1.2us row jumping to 100us is >80x but inside the 500us noise
    floor — exactly the journal_net-style rows that would flake CI."""
    base = _baseline(tiny=1.2)
    reg, _, _ = compare_baseline([Row("tiny", 100.0)], base)
    assert reg == []
    reg2, _, _ = compare_baseline([Row("tiny", 600.0)], base)
    assert reg2 == ["tiny"]


def test_new_rows_pass_and_missing_rows_warn():
    base = _baseline(old=1000.0)
    reg, missing, diff = compare_baseline([Row("brand_new", 1e9)], base)
    assert reg == [] and missing == ["old"]
    status = {d["name"]: d["status"] for d in diff["rows"]}
    assert status == {"brand_new": "new", "old": "missing"}


def test_uniform_machine_slowdown_is_normalized_out():
    """A runner uniformly 2.5x slower than the baseline machine must
    not flag anything (the median ratio is divided out), but a single
    row regressing on top of that slowdown still trips."""
    base = _baseline(a=10_000.0, b=20_000.0, c=40_000.0, d=80_000.0)
    uniform = [Row(n, v * 2.5) for n, v in
               [("a", 10_000.0), ("b", 20_000.0), ("c", 40_000.0), ("d", 80_000.0)]]
    reg, _, diff = compare_baseline(uniform, base)
    assert reg == [] and diff["machine_scale"] == pytest.approx(2.5)
    one_bad = [Row("a", 25_000.0), Row("b", 50_000.0), Row("c", 100_000.0),
               Row("d", 80_000.0 * 2.5 * 3.0)]          # d regressed 3x on top
    reg2, _, _ = compare_baseline(one_bad, base)
    assert reg2 == ["d"]


def test_module_wide_regression_is_not_absorbed_as_machine_speed():
    """Every row 10x slower is beyond any plausible runner-speed gap:
    the scale clamps at 4x and the remaining 2.5x trips each row."""
    base = _baseline(a=10_000.0, b=20_000.0, c=40_000.0, d=80_000.0)
    fresh = [Row(n, v * 10.0) for n, v in
             [("a", 10_000.0), ("b", 20_000.0), ("c", 40_000.0), ("d", 80_000.0)]]
    reg, _, diff = compare_baseline(fresh, base)
    assert diff["machine_scale"] == pytest.approx(4.0)
    assert sorted(reg) == ["a", "b", "c", "d"]


def test_faster_runner_does_not_mask_regression():
    """On a 4x faster machine, a row that regressed 3x still reads
    below its baseline in raw us — normalization exposes it."""
    base = _baseline(a=40_000.0, b=80_000.0, c=160_000.0, d=320_000.0)
    fresh = [Row("a", 10_000.0), Row("b", 20_000.0), Row("c", 40_000.0),
             Row("d", 240_000.0)]                       # d: 3x relative
    reg, _, _ = compare_baseline(fresh, base)
    assert reg == ["d"]


def test_custom_band_parameters():
    base = _baseline(a=100.0)
    reg, _, _ = compare_baseline([Row("a", 160.0)], base,
                                 tolerance=1.5, abs_slack_us=0.0)
    assert reg == ["a"]
    reg2, _, _ = compare_baseline([Row("a", 140.0)], base,
                                  tolerance=1.5, abs_slack_us=0.0)
    assert reg2 == []
