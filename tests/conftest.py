"""Shared fixtures/helpers. NOTE: no XLA device-count flags here —
smoke tests must see the real single-device CPU backend. Multi-device
tests spawn subprocesses (see ``spmd/``)."""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def random_graph(n, m, seed):
    from repro.core import Graph

    r = np.random.default_rng(seed)
    edges = set()
    tries = 0
    while len(edges) < m and tries < 50 * m:
        a, b = int(r.integers(n)), int(r.integers(n))
        tries += 1
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges), dtype=np.int64).reshape(-1, 2), n=n)


def oracle_instances(graph, pattern) -> int:
    """#distinct subgraphs of `graph` isomorphic to `pattern` (networkx)."""
    import networkx as nx

    G = nx.Graph()
    G.add_nodes_from(range(graph.n))
    G.add_edges_from(graph.edges().tolist())
    P = nx.Graph()
    P.add_nodes_from(pattern.vertices)
    P.add_edges_from(list(pattern.edges))
    gm = nx.algorithms.isomorphism.GraphMatcher(G, P)
    found = set()
    for mapping in gm.subgraph_monomorphisms_iter():
        inv = {v: k for k, v in mapping.items()}
        key = frozenset(
            (min(inv[a], inv[b]), max(inv[a], inv[b])) for a, b in P.edges()
        )
        found.add(key)
    return len(found)


def run_spmd_script(name: str, timeout: int = 900) -> str:
    """Run a tests/spmd/ script in a subprocess with 8 fake CPU devices."""
    script = os.path.join(os.path.dirname(os.path.abspath(__file__)), "spmd", name)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True, timeout=timeout
    )
    assert out.returncode == 0, f"{name} failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
