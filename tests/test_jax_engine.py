"""Static-shape JAX engine vs host engine (single device, exact equality)."""

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import random_graph

from repro.core import build_np_storage, symmetry_break
from repro.core.cost import CostModel
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.join_tree import minimum_unit_decomposition
from repro.core.listing import list_unit_all_parts, list_unit_compressed
from repro.core.pattern import PATTERN_LIBRARY
from repro.core.vcbc import cc_join
from repro.dist import jax_engine as je

CAPS = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=512, match_cap=2048,
                     group_cap=1024, set_cap=32, pair_cap=128)


def _setup(pname, seed=3):
    g = random_graph(40, 100, seed=seed)
    pat = PATTERN_LIBRARY[pname]
    ord_ = symmetry_break(pat)
    cover = choose_cover(pat, ord_, GraphStats.of(g))
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, 4)
    return g, pat, ord_, cover, units, storage


@pytest.mark.parametrize("pname", ["q2_triangle", "q1_square", "q5_house", "q3_diamond"])
def test_unit_listing_matches_host(pname):
    g, pat, ord_, cover, units, storage = _setup(pname)
    for u in units:
        plan = je.build_unit_plan(u.pattern, u.anchor_in(cover), ord_)
        for part in storage.parts:
            host_t = list_unit_compressed(part, u, cover, ord_)
            pt = je.pad_partition(part, CAPS)
            tbl, valid, ovf = je.unit_list(pt, plan, CAPS)
            assert int(ovf) == 0
            tc, skel_cols, ovf2 = je.compress_plain(tbl, valid, plan.cols, cover, CAPS)
            assert int(ovf2) == 0
            back = je.comp_to_host(tc, u.pattern, cover, skel_cols)
            _, ht = host_t.decompress(ord_)
            _, jt = back.decompress(ord_)
            assert set(map(tuple, ht.tolist())) == set(map(tuple, jt.tolist()))


@pytest.mark.parametrize("pname", ["q1_square", "q5_house"])
def test_ccjoin_matches_host(pname):
    g, pat, ord_, cover, units, storage = _setup(pname)
    assert len(units) >= 2
    u1, u2 = units[0], units[1]
    hA = list_unit_all_parts(storage, u1, cover, ord_)
    hB = list_unit_all_parts(storage, u2, cover, ord_)
    hj = cc_join(hA, hB, ord_)
    _, hjt = hj.decompress(ord_)

    def to_tensors(ht):
        colsh, t = ht.decompress(ord_)
        tbl = np.full((CAPS.match_cap, len(colsh)), je.PAD, np.int32)
        tbl[: t.shape[0]] = t
        valid = np.zeros(CAPS.match_cap, bool)
        valid[: t.shape[0]] = True
        return je.compress_plain(jnp.array(tbl), jnp.array(valid), tuple(colsh), cover, CAPS)

    tA, _, _ = to_tensors(hA)
    tB, _, _ = to_tensors(hB)
    jplan = je.JoinPlan.make(u1.pattern, u2.pattern, cover, ord_)
    tJ, ovf = je.ccjoin_local(tA, tB, jplan, CAPS)
    assert int(ovf) == 0
    back = je.comp_to_host(tJ, u1.pattern.union(u2.pattern), cover, jplan.skel_out)
    _, jjt = back.decompress(ord_)
    assert set(map(tuple, hjt.tolist())) == set(map(tuple, jjt.tolist()))


@pytest.mark.parametrize("pname", ["q2_triangle", "q5_house"])
def test_pallas_probes_match_host(pname):
    """use_pallas routes set-intersection + edge probes through the
    Pallas kernels (interpret mode on CPU); results stay byte-identical
    to both the host engine and the non-Pallas device engine."""
    import dataclasses

    g, pat, ord_, cover, units, storage = _setup(pname, seed=5)
    pcaps = dataclasses.replace(CAPS, use_pallas=True)
    caps0 = dataclasses.replace(CAPS, use_pallas=False)

    # unit listing (edge-membership probes in unit_list)
    u = max(units, key=lambda x: x.pattern.m)   # most edge checks
    plan = je.build_unit_plan(u.pattern, u.anchor_in(cover), ord_)
    part = storage.parts[0]
    host_t = list_unit_compressed(part, u, cover, ord_)
    outs = {}
    for caps in (caps0, pcaps):
        pt = je.pad_partition(part, caps)
        tbl, valid, ovf = je.unit_list(pt, plan, caps)
        assert int(ovf) == 0
        tc, skel_cols, _ = je.compress_plain(tbl, valid, plan.cols, cover, caps)
        back = je.comp_to_host(tc, u.pattern, cover, skel_cols)
        outs[caps.use_pallas] = set(map(tuple, back.decompress(ord_)[1].tolist()))
    host_rows = set(map(tuple, host_t.decompress(ord_)[1].tolist()))
    assert outs[False] == outs[True] == host_rows

    # CC-join (compressed-set intersection in ccjoin_local)
    if len(units) >= 2:
        u1, u2 = units[0], units[1]
        hA = list_unit_all_parts(storage, u1, cover, ord_)
        hB = list_unit_all_parts(storage, u2, cover, ord_)
        hj = cc_join(hA, hB, ord_)
        host_rows = set(map(tuple, hj.decompress(ord_)[1].tolist()))
        jplan = je.JoinPlan.make(u1.pattern, u2.pattern, cover, ord_)
        for caps in (caps0, pcaps):
            def to_tensors(ht):
                colsh, t = ht.decompress(ord_)
                tbl = np.full((caps.match_cap, len(colsh)), je.PAD, np.int32)
                tbl[: t.shape[0]] = t
                valid = np.zeros(caps.match_cap, bool)
                valid[: t.shape[0]] = True
                tc, skel_cols, o = je.compress_plain(jnp.array(tbl), jnp.array(valid),
                                                     tuple(colsh), cover, caps)
                assert int(o) == 0
                return tc
            tA = to_tensors(hA)
            tB = to_tensors(hB)
            tJ, ovf = je.ccjoin_local(tA, tB, jplan, caps)
            assert int(ovf) == 0
            back = je.comp_to_host(tJ, u1.pattern.union(u2.pattern), cover,
                                   jplan.skel_out)
            assert set(map(tuple, back.decompress(ord_)[1].tolist())) == host_rows


def test_overflow_is_counted_not_silent():
    g, pat, ord_, cover, units, storage = _setup("q2_triangle")
    tiny = je.EngineCaps(v_cap=64, deg_cap=32, e_cap=512, match_cap=4,
                         group_cap=4, set_cap=4, pair_cap=2)
    plan = je.build_unit_plan(units[0].pattern, units[0].anchor_in(cover), ord_)
    total_host = 0
    total_jax = 0
    total_ovf = 0
    for part in storage.parts:
        host_t = list_unit_compressed(part, units[0], cover, ord_)
        total_host += host_t.count_matches(ord_)
        pt = je.pad_partition(part, tiny)
        tbl, valid, ovf = je.unit_list(pt, plan, tiny)
        total_jax += int(np.asarray(valid).sum())
        total_ovf += int(ovf)
    if total_host > total_jax:
        assert total_ovf > 0  # dropped rows must be accounted
