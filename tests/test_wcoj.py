"""WCOJ executor mode (ISSUE 10): generic-join plans for dense patterns.

Covers the executor end to end: host generic join vs the tree-join
reference vs the networkx oracle on K4/K5, a hypothesis twin over
random near-cliques, the compiler's cost-model executor pass
(``executor="auto"``), single-device sharded parity under both
``use_pallas`` settings, delta-seeded incremental maintenance audited
at every committed watermark, tree↔wcoj hot swaps at a watermark, and
the cover-preserving swap carry reuse. The 8-device twin lives in
``spmd/run_wcoj_step.py``.
"""

import numpy as np
import pytest

from conftest import oracle_instances, random_graph

from repro.core import DDSL, Graph
from repro.core.match_engine import list_matches, list_matches_wcoj
from repro.core.pattern import PATTERN_LIBRARY, Pattern


def near_clique_graph(n=64, m=200, k=10, p=0.9, seed=0):
    """Sparse uniform background + a dense ER core: the K4/K5-bearing
    regime the executor pass exists for."""
    r = np.random.default_rng(seed)
    edges = set()
    tries = 0
    while len(edges) < m and tries < 50 * m:
        a, b = int(r.integers(n)), int(r.integers(n))
        tries += 1
        if a != b:
            edges.add((min(a, b), max(a, b)))
    core = r.choice(n, size=k, replace=False)
    for i in range(k):
        for j in range(i + 1, k):
            if r.random() < p:
                a, b = int(core[i]), int(core[j])
                edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges), np.int64), n=n)


# ---------------------------------------------------------------------------
# Host executor parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pname", ["q2_triangle", "q3_diamond",
                                   "q4_clique4", "q6_clique5"])
def test_host_wcoj_matches_tree_and_oracle(pname):
    from repro.core import symmetry_break

    g = near_clique_graph(seed=3)
    pat = PATTERN_LIBRARY[pname]
    # unbroken: every automorphic image, tree == generic join exactly
    cols_t, tt = list_matches(g, pat)
    cols_w, tw = list_matches_wcoj(g, pat)
    assert cols_t == cols_w
    st, sw = set(map(tuple, tt.tolist())), set(map(tuple, tw.tolist()))
    assert st == sw, (pname, len(st), len(sw))
    assert len(sw) == tw.shape[0]            # no duplicate listings
    # symmetry-broken: one row per instance, count == networkx oracle
    ord_ = symmetry_break(pat)
    _, tt = list_matches(g, pat, ord_)
    _, tw = list_matches_wcoj(g, pat, ord_)
    assert (set(map(tuple, tt.tolist())) == set(map(tuple, tw.tolist())))
    assert tw.shape[0] == oracle_instances(g, pat)


def test_host_engine_wcoj_mode_matches_tree_engine():
    """DDSL(executor='wcoj'): initial + a stream of updates stays
    byte-identical to the tree-join engine at every step."""
    from repro.data.graphs import sample_update

    g = near_clique_graph(seed=5)
    pat = PATTERN_LIBRARY["q4_clique4"]
    ew = DDSL(g, pat, m=2, executor="wcoj")
    et = DDSL(g, pat, m=2, executor="tree")
    ew.initial(), et.initial()
    for step in range(4):
        upd = sample_update(ew.graph, 6, 6, seed=40 + step)
        ew.apply(upd), et.apply(upd)
        _, tw = ew.state.matches.decompress(ew.ord_)
        _, tt = et.state.matches.decompress(et.ord_)
        assert set(map(tuple, tw.tolist())) == set(map(tuple, tt.tolist()))
    assert ew.count() == oracle_instances(ew.graph, pat)


try:
    from hypothesis import given, settings, strategies as st_h

    @settings(max_examples=12, deadline=None)
    @given(k=st_h.integers(5, 9), drop=st_h.integers(0, 6),
           seed=st_h.integers(0, 1000))
    def test_hypothesis_near_cliques_wcoj_twin(k, drop, seed):
        """Random near-cliques (a k-clique minus `drop` random edges on
        a sparse background): generic join == tree join, exactly."""
        r = np.random.default_rng(seed)
        core = [(a, b) for a in range(k) for b in range(a + 1, k)]
        r.shuffle(core)
        edges = {(a + 20, b + 20) for a, b in core[drop:]}
        for _ in range(30):                        # background noise
            a, b = int(r.integers(40)), int(r.integers(40))
            if a != b:
                edges.add((min(a, b), max(a, b)))
        g = Graph.from_edges(np.array(sorted(edges), np.int64), n=60)
        for pname in ("q4_clique4", "q6_clique5"):
            pat = PATTERN_LIBRARY[pname]
            _, tt = list_matches(g, pat)
            _, tw = list_matches_wcoj(g, pat)
            assert (set(map(tuple, tt.tolist()))
                    == set(map(tuple, tw.tolist())))
except ImportError:                                  # pragma: no cover
    pass


# ---------------------------------------------------------------------------
# Compiler executor pass
# ---------------------------------------------------------------------------

def test_compiler_auto_selects_wcoj_on_dense_patterns():
    from repro.core.estimator import GraphStats
    from repro.data.graphs import rmat_graph
    from repro.dist.jax_engine import EngineCaps
    from repro.planner import CompileContext, compile_plan

    stats = GraphStats.of(rmat_graph(11, 12000, seed=0))
    caps = EngineCaps(v_cap=2048, deg_cap=64, e_cap=16384, match_cap=4096,
                      group_cap=4096, set_cap=32, pair_cap=64)
    for pname in ("q4_clique4", "q6_clique5"):
        plan = compile_plan(CompileContext(
            pattern=PATTERN_LIBRARY[pname], stats=stats, m=4, caps=caps,
            executor="auto"))
        assert plan.executor == "wcoj", pname
        assert plan.wcoj is not None
        assert len(plan.wcoj_level_caps) == len(plan.wcoj.order)
        # trivial compression: the store covers every pattern vertex
        assert plan.storage_cover == tuple(sorted(plan.pattern.vertices))
    # square has no vertex adjacent to all others: never WCOJ-eligible
    plan = compile_plan(CompileContext(
        pattern=PATTERN_LIBRARY["q1_square"], stats=stats, m=4,
        executor="auto"))
    assert plan.executor == "tree"
    assert plan.storage_cover == plan.cover
    with pytest.raises(ValueError, match="not WCOJ-eligible"):
        compile_plan(CompileContext(
            pattern=PATTERN_LIBRARY["q1_square"], stats=stats, m=4,
            executor="wcoj"))


def test_plan_key_distinguishes_executor_modes():
    from repro.core.estimator import GraphStats
    from repro.planner import CompileContext, compile_plan

    stats = GraphStats.of(near_clique_graph(seed=7))
    kw = dict(pattern=PATTERN_LIBRARY["q4_clique4"], stats=stats, m=2)
    pt = compile_plan(CompileContext(executor="tree", **kw))
    pw = compile_plan(CompileContext(executor="wcoj", **kw))
    assert pt.plan_key() != pw.plan_key()
    assert pt.executor == "tree" and pw.executor == "wcoj"


# ---------------------------------------------------------------------------
# Sharded backend: single-device parity, maintenance, hot swaps
# ---------------------------------------------------------------------------

def _stream_service(use_pallas, patterns, batches=4, seed0=70):
    from repro.data.graphs import sample_update
    from repro.stream import BatchScheduler, ListingService

    g = near_clique_graph(seed=11)
    svc = ListingService(
        g, backend="sharded", max_add=8, max_del=8, executor="wcoj",
        audit_every=1, use_pallas=use_pallas,
        scheduler=BatchScheduler(max_ops=16))
    for nm in patterns:
        svc.register(nm, PATTERN_LIBRARY[nm])
    for b in range(batches):
        upd = sample_update(svc.projected_graph(), 4, 4, seed=seed0 + b)
        svc.ingest(upd)
        svc.advance()
    return svc


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sharded_wcoj_stream_audits_clean(use_pallas):
    """Device WCOJ maintenance == from-scratch host listing at every
    committed watermark (service audit every batch), both kernels."""
    svc = _stream_service(use_pallas, ("q4_clique4", "q2_triangle"))
    assert svc.audits, "no audits ran"
    assert all(ok for _, _, ok in svc.audits), svc.audits
    assert all(bm.overflow == 0 for bm in svc.metrics)
    assert svc.backend.store_resizes == 0
    # final materialized table == host generic join, byte for byte
    g2 = svc.projected_graph()
    for nm in ("q4_clique4", "q2_triangle"):
        table = svc.backend.materialize(nm)
        plan = svc.backend.plan(nm)
        _, rows = table.decompress(plan.ord)
        _, want = list_matches_wcoj(g2, PATTERN_LIBRARY[nm], plan.ord)
        assert (set(map(tuple, rows.tolist()))
                == set(map(tuple, want.tolist()))), nm


def test_sharded_wcoj_k5_counts_match_oracle():
    svc = _stream_service(False, ("q6_clique5",), batches=2)
    g2 = svc.projected_graph()
    assert svc.count("q6_clique5") == oracle_instances(
        g2, PATTERN_LIBRARY["q6_clique5"])


def test_executor_mode_hot_swap_at_watermark():
    """tree → wcoj → tree swaps through materialize → regroup →
    install: count-preserving, and the stream keeps auditing clean
    after each swap."""
    from repro.data.graphs import sample_update
    from repro.stream import BatchScheduler, ListingService

    g = near_clique_graph(seed=13)
    pat = PATTERN_LIBRARY["q4_clique4"]
    svc = ListingService(g, backend="sharded", max_add=8, max_del=8,
                         executor="tree", audit_every=1,
                         scheduler=BatchScheduler(max_ops=16))
    n0 = svc.register("k4", pat)
    backend = svc.backend

    def swap_to(executor):
        backend.executor = executor
        before = backend.count("k4")
        cand = backend.compile(pat)
        assert cand.executor == executor
        table = backend.materialize("k4")
        if table.cover != cand.storage_cover:
            cols, plain = table.decompress(backend.plan("k4").ord)
            from repro.core.vcbc import compress_table
            table = compress_table(cand.pattern, cand.storage_cover,
                                   cols, plain)
        backend.remove_pattern("k4")
        assert backend.install_plan("k4", cand, table) == before

    for step, executor in enumerate(("wcoj", "tree", "wcoj")):
        swap_to(executor)
        assert backend.plan("k4").executor == executor
        upd = sample_update(svc.projected_graph(), 4, 4, seed=90 + step)
        svc.ingest(upd)
        svc.advance()
    assert svc.audits and all(ok for _, _, ok in svc.audits), svc.audits
    assert svc.count("k4") == oracle_instances(svc.projected_graph(), pat)
    assert n0 == oracle_instances(g, pat)


def test_cover_preserving_tree_swap_reuses_carry():
    """Satellite: a tree→tree plan swap that preserves cover/ord/units
    skips the unit-carry re-listing (stash hit), and the stream stays
    correct afterwards."""
    from repro.data.graphs import sample_update
    from repro.obs import Observability
    from repro.stream import BatchScheduler, ListingService

    g = random_graph(48, 160, seed=17)
    pat = PATTERN_LIBRARY["q2_triangle"]
    svc = ListingService(g, backend="sharded", max_add=8, max_del=8,
                         audit_every=1, obs=Observability.full(),
                         scheduler=BatchScheduler(max_ops=16))
    svc.register("tri", pat)
    backend = svc.backend
    reuses = svc.obs.metrics.counter(
        "plan_swap_carry_reuses_total",
        "unit-table carries reused across cover-preserving swaps")
    assert reuses.value == 0

    before = backend.count("tri")
    incumbent = backend.plan("tri")
    cand = backend.compile(pat, cover=incumbent.cover)   # same cover/units
    table = backend.materialize("tri")
    backend.remove_pattern("tri")
    assert backend.install_plan("tri", cand, table) == before
    assert reuses.value == 1

    for b in range(2):                     # stream on: reuse was sound
        upd = sample_update(svc.projected_graph(), 4, 4, seed=50 + b)
        svc.ingest(upd)
        svc.advance()
    assert all(ok for _, _, ok in svc.audits), svc.audits
    # a later same-watermark swap (remove → install with no batch in
    # between) reuses again — the stash only dies when Φ advances
    # between the remove and the install (apply_batch clears it)
    cand2 = backend.compile(pat, cover=incumbent.cover)
    table2 = backend.materialize("tri")
    backend.remove_pattern("tri")
    backend.install_plan("tri", cand2, table2)
    assert reuses.value == 2
    assert not backend._carry_stash     # consumed, nothing left behind


def test_plan_manager_auto_swaps_to_wcoj():
    """PlanManager.reoptimize on a dense-core stream: the executor pass
    recosts the incumbent under its own mode and swaps tree→wcoj when
    the generic join wins the cost model."""
    from repro.stream import BatchScheduler, ListingService, PlanManager

    g = near_clique_graph(n=96, m=300, k=12, p=0.95, seed=19)
    pat = PATTERN_LIBRARY["q6_clique5"]
    svc = ListingService(g, backend="sharded", max_add=8, max_del=8,
                         executor="tree", audit_every=1,
                         scheduler=BatchScheduler(max_ops=16))
    svc.register("k5", pat)
    assert svc.backend.plan("k5").executor == "tree"
    svc.backend.executor = "auto"          # future compiles may flip mode
    pm = PlanManager(improvement=1.0)
    events = pm.reoptimize(svc, trigger="manual")
    assert events
    if events[0].swapped:                  # cost model picked the WCOJ plan
        assert svc.backend.plan("k5").executor == "wcoj"
    from repro.data.graphs import sample_update
    upd = sample_update(svc.projected_graph(), 4, 4, seed=23)
    svc.ingest(upd)
    svc.advance()
    assert all(ok for _, _, ok in svc.audits), svc.audits


# ---------------------------------------------------------------------------
# 8-device SPMD twin
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_distributed_wcoj_matches_host():
    """8 fake devices: the sharded WCOJ list step + delta-seeded
    maintenance equal the host engine on K4/K5, both Pallas settings."""
    from conftest import run_spmd_script

    out = run_spmd_script("run_wcoj_step.py")
    assert out.count("wcoj OK") >= 4, out
