"""Multi-device SPMD tests (8 fake CPU devices via subprocess isolation).

Each script validates a distributed step against the host engine:
- list_step: distributed initial calculation == host DDSL (exact match sets)
- update_step: Alg. 4 storage delta == rebuild + patch == host Nav-join
- MoE: shard_map expert routing == dense fallback
"""

import pytest

from conftest import run_spmd_script


@pytest.mark.slow
def test_distributed_list_step_matches_host():
    out = run_spmd_script("run_list_step.py")
    assert out.count("OK") >= 3, out


@pytest.mark.slow
def test_distributed_update_step_matches_host():
    out = run_spmd_script("run_update_step.py")
    assert out.count("OK") >= 3, out


@pytest.mark.slow
def test_moe_routed_matches_dense():
    out = run_spmd_script("run_moe_routed.py")
    assert "OK" in out, out


@pytest.mark.slow
def test_collectives_and_compression():
    out = run_spmd_script("run_collectives.py")
    assert "ALL OK" in out, out


@pytest.mark.slow
def test_distributed_gnn_matches_single_device():
    out = run_spmd_script("run_gnn_dist.py")
    assert "ALL OK" in out, out
