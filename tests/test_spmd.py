"""Multi-device SPMD tests (8 fake CPU devices via subprocess isolation).

Each script validates a distributed step against the host engine:
- list_step: distributed initial calculation == host DDSL (exact match sets)
- update_step: Alg. 4 storage delta == rebuild + patch == host Nav-join
- maintain_step: device-resident MatchStore maintenance == host
  apply_update_to_matches over a randomized 50-batch stream
- MoE: shard_map expert routing == dense fallback
"""

import pytest

from conftest import run_spmd_script


@pytest.mark.slow
def test_distributed_list_step_matches_host():
    out = run_spmd_script("run_list_step.py")
    assert out.count("OK") >= 3, out


@pytest.mark.slow
def test_distributed_update_step_matches_host():
    out = run_spmd_script("run_update_step.py")
    assert out.count("OK") >= 3, out


@pytest.mark.slow
def test_distributed_maintain_step_matches_host():
    """Device-resident match maintenance: the fused maintain step keeps
    an 8-device MatchStore identical to the host incremental oracle
    over a randomized 50-batch stream, both Pallas settings."""
    out = run_spmd_script("run_maintain_step.py")
    assert out.count("maintain_step OK") == 2, out


@pytest.mark.slow
def test_distributed_maintain_mega_matches_per_pattern():
    """Fused multi-pattern megastep: one 8-device dispatch maintaining
    triangle + square is byte-identical (stores, patches, carries,
    diag) to running each pattern's maintain step separately, and
    count-identical to the host oracle, both Pallas settings."""
    out = run_spmd_script("run_maintain_mega.py")
    assert out.count("maintain_mega OK") == 2, out


@pytest.mark.slow
def test_moe_routed_matches_dense():
    out = run_spmd_script("run_moe_routed.py")
    assert "OK" in out, out


@pytest.mark.slow
def test_collectives_and_compression():
    out = run_spmd_script("run_collectives.py")
    assert "ALL OK" in out, out


@pytest.mark.slow
def test_distributed_gnn_matches_single_device():
    out = run_spmd_script("run_gnn_dist.py")
    assert "ALL OK" in out, out
