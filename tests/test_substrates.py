"""Substrate tests: optimizer, checkpoint/restart, FT modules, data, wigner."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import random_graph


# --------------------------------------------------------------- optimizer
def test_adamw_optimizes_quadratic():
    from repro.optim import adamw_init, adamw_update

    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(params, grads, opt, 5e-2, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clipping():
    from repro.optim import global_norm_clip

    grads = {"a": jnp.full((4,), 100.0)}
    clipped, norm = global_norm_clip(grads, 1.0)
    assert float(norm) == pytest.approx(200.0)
    new_norm = float(jnp.linalg.norm(clipped["a"]))
    assert new_norm == pytest.approx(1.0, rel=1e-3)


def test_warmup_cosine():
    from repro.optim import warmup_cosine

    assert float(warmup_cosine(0, peak=1.0, warmup=10, total=100)) == 0.0
    assert float(warmup_cosine(10, peak=1.0, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(warmup_cosine(100, peak=1.0, warmup=10, total=100)) == pytest.approx(0.0, abs=1e-6)


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_pytree, save_pytree

    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    p = str(tmp_path / "x.npz")
    save_pytree(tree, p)
    back = restore_pytree(tree, p)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_keep_and_resume(tmp_path):
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3):
        mgr.save(s, {"w": jnp.full(3, float(s))})
    assert mgr.latest_step() == 3
    assert not os.path.exists(mgr.path(1))  # pruned
    step, back = mgr.restore_latest(tree)
    assert step == 3 and float(back["w"][0]) == 3.0


def test_checkpoint_torn_file_fallback(tmp_path):
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.zeros(3)}
    mgr.save(5, {"w": jnp.full(3, 5.0)})
    with open(mgr.path(9), "wb") as f:
        f.write(b"garbage")  # simulated crash mid-write of a newer step
    step, back = mgr.restore_latest(tree)
    assert step == 5 and float(back["w"][0]) == 5.0


# --------------------------------------------------------------- FT modules
def test_straggler_monitor_and_rebalance():
    from repro.core.storage import build_np_storage
    from repro.dist.straggler import StragglerMonitor, apply_rebalance, rebalance_plan

    mon = StragglerMonitor(n_hosts=4, window=4, threshold=1.5)
    for _ in range(4):
        mon.record(np.array([1.0, 1.0, 1.0, 4.0]))
    assert mon.stragglers() == [3]

    g = random_graph(32, 80, seed=0)
    storage = build_np_storage(g, 4)
    plan = rebalance_plan(storage, slow=[3], fast=[0], fraction=0.5)
    assert plan and all(v == 0 for v in plan.values())
    s2 = apply_rebalance(storage, plan)
    # moved vertices are now centers of partition 0
    for u in plan:
        assert u in s2.parts[0].center_vertices().tolist()
    # correctness: the rebalanced storage still lists all triangles
    from repro.core import DDSL
    from repro.core.pattern import PATTERN_LIBRARY

    eng1 = DDSL(g, PATTERN_LIBRARY["q2_triangle"], m=4)
    eng1.initial()
    eng2 = DDSL(g, PATTERN_LIBRARY["q2_triangle"], m=4, h=s2.h)
    eng2.initial()
    assert eng1.count() == eng2.count()


def test_elastic_repartition():
    from repro.core.storage import build_np_storage
    from repro.dist.elastic import repartition_delta, repartition_storage

    g = random_graph(40, 100, seed=1)
    storage = build_np_storage(g, 4)
    delta = repartition_delta(storage, 8)
    assert delta["moved_centers"] > 0
    s2 = repartition_storage(storage, 8)
    rebuilt = build_np_storage(g, 8)
    for pa, pb in zip(s2.parts, rebuilt.parts):
        assert np.array_equal(pa.codes, pb.codes)


def test_ef_compression_error_feedback():
    from repro.dist.compression import ef_compress, ef_residual_init

    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    res = ef_residual_init(grads)
    # accumulate decoded grads over steps; EF keeps the running sum honest
    decoded_sum = np.zeros(256)
    true_sum = np.zeros(256)
    for step in range(20):
        g = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
        true_sum += np.asarray(g["w"])
        q, s, res = ef_compress(g, res)
        decoded_sum += np.asarray(q["w"], np.float32) * float(s["w"])
    # residual bounds the drift to one quantization step
    drift = np.abs(decoded_sum - true_sum).max()
    assert drift <= 2 * float(s["w"]) + np.abs(np.asarray(res["w"])).max() + 1e-6


# --------------------------------------------------------------- data
def test_rmat_power_law_and_sampler():
    from repro.data.graphs import NeighborSampler, rmat_graph, sample_update

    g = rmat_graph(8, 1200, seed=0)
    assert g.num_edges > 800
    deg = g.degrees
    assert deg.max() >= 4 * max(np.median(deg[deg > 0]), 1)  # heavy tail
    u = sample_update(g, 10, 10, seed=1)
    assert u.delete.shape == (10, 2) and u.add.shape == (10, 2)
    g2 = g.apply_update(u)
    assert g2.num_edges == g.num_edges  # -10 +10

    feats = np.random.default_rng(0).normal(size=(g.n, 8)).astype(np.float32)
    sampler = NeighborSampler(g, feats, fanouts=(4, 3), seed=0)
    layers = sampler.sample(np.array([1, 2, 3]))
    assert layers[0].shape == (3, 8)
    assert layers[1].shape == (12, 8)
    assert layers[2].shape == (36, 8)


def test_prefetch_pipeline():
    from repro.data.pipeline import prefetch

    out = list(prefetch(iter(range(10)), depth=2))
    assert out == list(range(10))


# --------------------------------------------------------------- wigner
def test_wigner_rotation_properties():
    from repro.models import wigner

    rng = np.random.default_rng(0)
    theta = 0.7
    rz = np.array([[np.cos(theta), -np.sin(theta), 0],
                   [np.sin(theta), np.cos(theta), 0], [0, 0, 1.0]])
    for l in range(0, 5):
        m_fit = wigner._fit_block(l, rz)
        m_an = np.asarray(wigner.rot_z_real(l, jnp.float32(theta)))
        assert np.abs(m_fit - m_an).max() < 1e-5

    dirs = rng.normal(size=(6, 3)).astype(np.float32)
    lmax = 4
    d = np.asarray(wigner.edge_rotation(lmax, jnp.array(dirs)))
    sh_v = wigner.sh_real(lmax, dirs.astype(np.float64))
    sh_y = wigner.sh_real(lmax, np.array([[0.0, 1.0, 0.0]]))
    for e in range(dirs.shape[0]):
        assert np.allclose(d[e] @ sh_v[e], sh_y[0], atol=1e-4)
        assert np.allclose(d[e] @ d[e].T, np.eye(d.shape[1]), atol=1e-4)


# --------------------------------------------------------------- hlo_cost
def test_hlo_cost_counts_scan_bodies():
    from repro.launch.hlo_cost import analyze_text

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), 0
        out, _ = jax.lax.scan(body, x, w)
        return out

    low = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((5, 16, 16), jnp.float32),
    )
    c = analyze_text(low.compile().as_text())
    assert abs(c.flops - 5 * 2 * 8 * 16 * 16) / (5 * 2 * 8 * 16 * 16) < 0.01
    assert 5 in c.while_trips
