"""repro.planner: staged compiler parity, DP oracle, online plan swaps.

Three guarantees under test:

1. **Refactor parity** — :func:`repro.planner.compile_plan` over the
   same GraphStats/mesh/caps produces exactly what the pre-refactor
   scatter produced: ``choose_cover`` + ``optimal_join_tree`` +
   ``minimum_unit_decomposition`` + ``build_tree_program`` +
   ``match_caps``/``unit_table_caps`` called directly (dataclass
   equality, i.e. byte-identical plan IR and caps).
2. **DP optimality oracle** — on every ≤4-vertex library pattern and
   every valid cover, Alg. 3's tree cost equals an exhaustive
   enumeration over all join trees buildable from anchored R1 units.
3. **Online re-optimization** — a drift-triggered swap on a growing
   50-batch stream commits at a watermark with the match set
   byte-matching ``DDSL.initial()`` on the replayed graph, counters and
   the ``plan_swap`` span visible in the obs export.
"""

import dataclasses
import json
import math

import numpy as np
import pytest

from conftest import random_graph

from repro.core import DDSL, GraphStats, PATTERN_LIBRARY
from repro.core.cost import CostModel
from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
from repro.core.pattern import Pattern, enumerate_r1_units, symmetry_break
from repro.data.graphs import sample_update
from repro.obs import Observability
from repro.planner import (
    CompileContext,
    build_tree_program,
    candidate_covers,
    choose_cover,
    compile_plan,
    match_caps,
    tree_key,
    unit_table_caps,
)
from repro.stream import ListingService, PlanManager
from repro.stream.plan_manager import recost_tree


@dataclasses.dataclass(frozen=True)
class _DuckCaps:
    """Stands in for EngineCaps — sizing only reads these two fields."""

    group_cap: int = 128
    set_cap: int = 16


def _stats(seed=3, n=48, m=150):
    return GraphStats.of(random_graph(n, m, seed=seed))


# ---------------------------------------------------------------------------
# 1. Refactor parity: compiler output == pre-refactor direct construction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PATTERN_LIBRARY))
def test_compile_parity_with_direct_construction(name):
    p = PATTERN_LIBRARY[name]
    stats = _stats()
    plan = compile_plan(CompileContext(pattern=p, stats=stats))

    ord_ = symmetry_break(p)
    cover = choose_cover(p, ord_, stats)
    tree = optimal_join_tree(p, cover, CostModel(cover, ord_, stats))
    units = tuple(minimum_unit_decomposition(p, cover))
    prog = build_tree_program(tree, cover, ord_)

    assert plan.ord == tuple(ord_)
    assert plan.cover == tuple(sorted(cover))
    assert plan.tree == tree                  # recursive dataclass equality
    assert plan.cost == tree.cost
    assert plan.units == units
    assert plan.program == prog               # byte-identical plan IR


@pytest.mark.parametrize("name", ["q1_square", "q2_triangle", "q5_house"])
def test_compile_parity_device_caps(name):
    p = PATTERN_LIBRARY[name]
    stats = _stats()
    caps = _DuckCaps()
    plan = compile_plan(CompileContext(pattern=p, stats=stats, m=4, caps=caps))

    # Same args the pre-refactor ShardedBackend passed inline
    # (store_headroom default 4.0, unit headroom default 2.0).
    assert plan.store_caps == match_caps(p, plan.cover, plan.ord, stats, caps)
    assert plan.unit_caps == unit_table_caps(
        list(plan.units), plan.cover, plan.ord, stats, caps)
    assert plan.sharding.m == 4
    assert plan.sharding.key_cols == plan.program.nodes[plan.program.root].skel_cols


def test_compile_deterministic_same_context():
    """Register and restore compile from the same stats — they must be
    incapable of picking different trees (the old inline blocks could)."""
    p = PATTERN_LIBRARY["q3_diamond"]
    stats = _stats()
    ctx = CompileContext(pattern=p, stats=stats, m=2, caps=_DuckCaps())
    a, b = compile_plan(ctx), compile_plan(ctx)
    assert a.plan_key() == b.plan_key()
    assert a.program == b.program
    assert a.store_caps == b.store_caps and a.unit_caps == b.unit_caps


def test_pinned_cover_is_validated():
    p = PATTERN_LIBRARY["q2_triangle"]
    with pytest.raises(ValueError, match="not a vertex cover"):
        compile_plan(CompileContext(pattern=p, stats=_stats(), cover=(0,)))


def test_ddsl_accepts_precompiled_plan():
    g = random_graph(40, 110, seed=5)
    p = PATTERN_LIBRARY["q5_house"]
    plan = compile_plan(CompileContext(pattern=p, stats=GraphStats.of(g)))
    d1 = DDSL(g, p, plan=plan)
    d2 = DDSL(g, p)
    assert d1.cover == d2.cover and d1.tree == d2.tree
    assert d1.initial().count_matches(d1.ord_) == d2.initial().count_matches(d2.ord_)


# ---------------------------------------------------------------------------
# 2. Brute-force oracle for the Alg. 3 DP
# ---------------------------------------------------------------------------

def _brute_force_min_cost(p: Pattern, cover, model: CostModel) -> float:
    """Exhaustive minimum Eq. 11 cost over ALL join trees buildable from
    cover-anchored R1 units (children of a join may overlap — trees are
    built from unions, not partitions, exactly like the DP's space)."""
    vc = set(cover)
    units = [u for u in enumerate_r1_units(p) if u.anchor_in(vc) is not None]
    unit_keys = {u.pattern.key() for u in units}

    # Every pattern the DP could ever materialize: unions of unit subsets.
    buildable = {}
    for u in units:
        buildable[u.pattern.key()] = u.pattern
    grew = True
    while grew:
        grew = False
        for ka in list(buildable):
            for kb in list(buildable):
                pu = buildable[ka].union(buildable[kb])
                if pu.key() not in buildable:
                    buildable[pu.key()] = pu
                    grew = True

    memo = {}

    def best(key):
        if key in memo:
            return memo[key]
        memo[key] = math.inf          # cycle guard; overwritten below
        pat = buildable[key]
        c = model.leaf_cost(pat) if key in unit_keys else math.inf
        for ka, pa in buildable.items():
            for kb, pb in buildable.items():
                if ka == key or kb == key:
                    continue
                if pa.union(pb).key() != key:
                    continue
                if not (set(pa.vertices) & set(pb.vertices) & vc):
                    continue
                c = min(c, model.join_cost(pat, pa, pb, best(ka), best(kb)))
        memo[key] = c
        return c

    return best(p.key())


@pytest.mark.parametrize("name", ["q1_square", "q2_triangle", "q3_diamond",
                                  "q4_clique4"])
def test_optimal_join_tree_matches_brute_force(name):
    p = PATTERN_LIBRARY[name]
    assert p.n <= 4
    stats = _stats(seed=9)
    ord_ = symmetry_break(p)
    for cover in candidate_covers(p):
        model = CostModel(cover, ord_, stats)
        tree = optimal_join_tree(p, cover, model)
        oracle = _brute_force_min_cost(p, cover, model)
        assert tree.cost == pytest.approx(oracle), (
            f"{name} cover={cover}: DP={tree.cost} brute={oracle}")
        # The stored cost must also be the genuine Eq. 11 evaluation of
        # the returned tree (recost under the same stats is an identity).
        assert recost_tree(tree, cover, ord_, stats) == pytest.approx(tree.cost)


def test_cost_objective_never_worse_than_r_lower_cover():
    stats = _stats(seed=11)
    for name, p in PATTERN_LIBRARY.items():
        by_cost = compile_plan(CompileContext(
            pattern=p, stats=stats, cover_objective="cost"))
        by_r = compile_plan(CompileContext(pattern=p, stats=stats))
        assert by_cost.cost <= by_r.cost + 1e-9, name
        assert by_cost.passes[-1].name == "search"


# ---------------------------------------------------------------------------
# 3. Online re-optimization
# ---------------------------------------------------------------------------

def _walk_spans(root):
    yield root["name"]
    for c in root.get("children", []):
        yield from _walk_spans(c)


def test_host_drift_swap_end_to_end(tmp_path):
    """Forced drift-triggered swap on a growing 50-batch stream: commits
    at a watermark, counts and rows byte-match DDSL.initial() on the
    replayed graph, counters + swap span land in the obs export."""
    g = random_graph(48, 150, seed=3)
    p = PATTERN_LIBRARY["q1_square"]
    pm = PlanManager(drift_threshold=0.0, recost_every=0)  # fire on any drift
    svc = ListingService(g, backend="host", plan_manager=pm,
                         obs=Observability.full())
    svc.register("sq", p)
    cover0 = svc.backend.meta("sq").cover
    for b in range(50):
        svc.ingest(sample_update(svc.projected_graph(), 1, 3, seed=100 + b))
        svc.advance()

    swaps = [e for e in pm.events if e.swapped]
    assert swaps, "drift trigger never produced a swap"
    assert svc.backend.meta("sq").cover != cover0   # cover moved too
    for e in swaps:
        assert e.trigger == "drift"
        assert e.candidate_cost < pm.improvement * e.incumbent_cost
        assert e.count is not None   # swap committed with the count intact

    # Byte-match against the from-scratch oracle on the replayed graph.
    fresh = DDSL(svc.graph, p)
    fresh.initial()
    assert svc.count("sq") == fresh.count()
    got = np.asarray(sorted(map(tuple, svc.backend.matches_plain("sq").tolist())))
    want = np.asarray(sorted(map(tuple, fresh.matches_plain().tolist())))
    assert np.array_equal(got, want)

    # Counters + span + plan dump in the export bundle.
    assert svc.obs.metrics.counter("plan_swaps_total").value >= 1
    assert svc.obs.metrics.counter("plan_recompiles_total").value >= len(pm.events)
    out = svc.obs.export(str(tmp_path))
    plans = json.loads(open(out["plans_json"]).read())
    assert plans["sq"]["cover"] == list(svc.backend.meta("sq").cover)
    span_names = set()
    with open(out["trace_jsonl"]) as f:
        for line in f:
            span_names.update(_walk_spans(json.loads(line)))
    assert "plan_swap" in span_names


def test_periodic_recompile_stable_plan_no_swap():
    """The heartbeat recompiles but never swaps while the incumbent is
    still the argmin — estimator noise must not thrash plans."""
    g = random_graph(40, 120, seed=7)
    pm = PlanManager(drift_threshold=float("inf"), recost_every=3,
                     objective="r_lower")
    svc = ListingService(g, backend="host", plan_manager=pm)
    svc.register("tri", PATTERN_LIBRARY["q2_triangle"])
    for b in range(9):
        svc.ingest(sample_update(svc.projected_graph(), 1, 1, seed=200 + b))
        svc.advance()
    assert pm.events, "periodic trigger never fired"
    assert all(e.trigger == "periodic" for e in pm.events)
    assert not any(e.swapped for e in pm.events)
    fresh = DDSL(svc.graph, PATTERN_LIBRARY["q2_triangle"])
    fresh.initial()
    assert svc.count("tri") == fresh.count()


def test_swap_preserves_count_invariant_host():
    """install_plan after remove_pattern with the recompressed table is
    a pure re-plan: counts must be identical before and after."""
    g = random_graph(40, 120, seed=13)
    svc = ListingService(g, backend="host")
    svc.register("sq", PATTERN_LIBRARY["q1_square"])
    before = svc.count("sq")
    pm = PlanManager()
    events = pm.reoptimize(svc, trigger="manual")
    assert len(events) == 1
    assert svc.count("sq") == before
    svc.audit(["sq"])   # raises on divergence


@pytest.mark.slow
def test_sharded_drift_swap_end_to_end():
    """Same swap protocol through the device backend: materialize →
    recompress → stack_matches → carry refresh, audited from scratch."""
    g = random_graph(32, 90, seed=3)
    p = PATTERN_LIBRARY["q1_square"]
    pm = PlanManager(drift_threshold=0.0, recost_every=0, verify=True)
    svc = ListingService(g, backend="sharded", plan_manager=pm,
                         obs=Observability.full())
    svc.register("sq", p)
    for b in range(12):
        svc.ingest(sample_update(svc.projected_graph(), 1, 3, seed=100 + b))
        svc.advance()
    assert any(e.swapped for e in pm.events)
    fresh = DDSL(svc.graph, p)
    fresh.initial()
    assert svc.count("sq") == fresh.count()
    assert svc.obs.metrics.counter("plan_swaps_total").value >= 1


def test_snapshot_restore_same_plan_key(tmp_path):
    """Register and restore route through one compiler entry point, so a
    restored service executes the identical plan (the two old inline
    blocks could diverge)."""
    g = random_graph(40, 120, seed=17)
    svc = ListingService(g, backend="host")
    svc.register("dia", PATTERN_LIBRARY["q3_diamond"])
    key0 = svc.backend.plan("dia").plan_key()
    svc.snapshot(str(tmp_path / "snap"))
    svc2 = ListingService.restore(str(tmp_path / "snap"), backend="host")
    assert svc2.backend.plan("dia").plan_key() == key0
    assert svc2.backend.plan("dia").program == svc.backend.plan("dia").program


def test_tree_key_is_child_order_invariant():
    p = PATTERN_LIBRARY["q1_square"]
    stats = _stats()
    plan = compile_plan(CompileContext(pattern=p, stats=stats))
    t = plan.tree
    if not t.is_leaf:
        flipped = dataclasses.replace(t, left=t.right, right=t.left)
        assert tree_key(flipped) == tree_key(t)


def test_compiled_plan_dump_is_json_and_describes():
    plan = compile_plan(CompileContext(
        pattern=PATTERN_LIBRARY["q5_house"], stats=_stats(), m=2,
        caps=_DuckCaps()))
    dump = plan.to_json()
    json.dumps(dump)   # round-trippable
    assert dump["cover"] == list(plan.cover)
    assert {pr["name"] for pr in dump["passes"]} >= {
        "symmetry", "cover", "decompose", "tree", "lower", "size", "shard"}
    text = plan.describe()
    assert "cover=" in text and "[     tree]" in text
