"""Per-assigned-architecture smoke tests (REQUIRED): reduced configs, one
forward/train step on CPU, asserting output shapes + no NaNs."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import all_archs
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf
from repro.models.common import cross_entropy
from repro.optim import adamw_init, adamw_update

LM = [n for n, s in all_archs().items() if s.family == "lm"]
GNN = [n for n, s in all_archs().items() if s.family == "gnn"]
REC = [n for n, s in all_archs().items() if s.family == "recsys"]


def _finite(x):
    return not np.isnan(np.asarray(x, np.float32)).any()


@pytest.mark.parametrize("name", sorted(LM))
def test_lm_smoke_train_step(name):
    cfg: tf.TransformerConfig = all_archs()[name].smoke
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    def loss_fn(p):
        logits = tf.forward(p, toks, cfg, None)
        assert logits.shape == (2, 16, cfg.vocab)
        return cross_entropy(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert _finite(loss) and float(loss) > 0
    params2, opt2, gnorm = adamw_update(params, grads, opt, 1e-3)
    assert _finite(gnorm)
    # params actually changed
    delta = max(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("name", sorted(LM))
def test_lm_smoke_serve(name):
    cfg: tf.TransformerConfig = all_archs()[name].smoke
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    cache = tf.init_cache(cfg, 2, 12)
    logits, cache = tf.prefill(params, toks[:, :11], cache, cfg, None)
    assert logits.shape == (2, 1, cfg.vocab) and _finite(logits)
    logits2, _ = tf.decode_step(params, toks[:, 11:12], cache, 11, cfg, None)
    assert logits2.shape == (2, 1, cfg.vocab) and _finite(logits2)
    # consistency with teacher-forcing forward
    full = tf.forward(params, toks, cfg, None)
    np.testing.assert_allclose(
        np.asarray(logits2[:, -1], np.float32), np.asarray(full[:, -1], np.float32),
        rtol=5e-2, atol=5e-2,
    )


@pytest.mark.parametrize("name", sorted(GNN))
def test_gnn_smoke_train_step(name):
    cfg: gnn_mod.GNNConfig = all_archs()[name].smoke
    rng = np.random.default_rng(0)
    n, e = 24, 48
    g = gnn_mod.GraphData(
        x=jnp.asarray(rng.normal(size=(n, cfg.d_in)), jnp.float32),
        src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_attr=jnp.asarray(rng.normal(size=(e, max(cfg.d_edge_in, 1))), jnp.float32),
        node_mask=jnp.ones(n, bool),
        edge_mask=jnp.ones(e, bool),
        positions=jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    )
    params = gnn_mod.init_params(cfg, jax.random.PRNGKey(0))
    labels = jnp.asarray(rng.integers(0, max(cfg.d_out, 2), n), jnp.int32)

    def loss_fn(p):
        out = gnn_mod.forward(p, g, cfg)
        assert out.shape == (n, cfg.d_out)
        if cfg.d_out > 1:
            lse = jax.nn.logsumexp(out.astype(jnp.float32), -1)
            ll = jnp.take_along_axis(out.astype(jnp.float32), labels[:, None] % cfg.d_out, -1)[:, 0]
            return jnp.mean(lse - ll)
        return jnp.mean(out.astype(jnp.float32) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert _finite(loss)
    gn = sum(float(jnp.abs(g_).sum()) for g_ in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", sorted(REC))
def test_recsys_smoke_train_step(name):
    cfg: dlrm_mod.DLRMConfig = all_archs()[name].smoke
    params = dlrm_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b = 8
    dense = jnp.asarray(rng.normal(size=(b, cfg.n_dense)), jnp.float32)
    sparse = jnp.asarray(rng.integers(0, cfg.rows_per_table, (b, cfg.n_sparse, cfg.multi_hot)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, b), jnp.float32)

    def loss_fn(p):
        logits = dlrm_mod.forward(p, dense, sparse, cfg).astype(jnp.float32)
        assert logits.shape == (b,)
        return jnp.mean(jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert _finite(loss)
    scores = dlrm_mod.retrieval_scores(params, dense[:1], sparse[:1],
                                       jnp.arange(32, dtype=jnp.int32), cfg)
    assert scores.shape == (32,) and _finite(scores)


def test_mla_absorbed_equals_materialized():
    cfg = all_archs()["minicpm3-4b"].smoke
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 10)), jnp.int32)
    cache = tf.init_cache(cfg, 2, 10)
    _, cache = tf.prefill(params, toks[:, :9], cache, cfg, None)
    lg_m, _ = tf.decode_step(params, toks[:, 9:10], cache, 9, cfg, None)
    cfg_a = dataclasses.replace(cfg, decode_absorbed=True)
    lg_a, _ = tf.decode_step(params, toks[:, 9:10], cache, 9, cfg_a, None)
    np.testing.assert_allclose(np.asarray(lg_m, np.float32), np.asarray(lg_a, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_equiformer_smoke_is_rotation_invariant():
    cfg = all_archs()["equiformer-v2"].smoke
    rng = np.random.default_rng(0)
    n, e = 20, 40
    pos = rng.normal(size=(n, 3)).astype(np.float32)
    base = dict(
        x=jnp.asarray(rng.normal(size=(n, cfg.d_in)), jnp.float32),
        src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_attr=jnp.zeros((e, 1), jnp.float32),
        node_mask=jnp.ones(n, bool),
        edge_mask=jnp.ones(e, bool),
    )
    params = gnn_mod.init_params(cfg, jax.random.PRNGKey(1))
    out1 = gnn_mod.forward(params, gnn_mod.GraphData(positions=jnp.asarray(pos), **base), cfg)
    th = 1.1
    rot = np.array([[np.cos(th), -np.sin(th), 0], [np.sin(th), np.cos(th), 0], [0, 0, 1]], np.float32)
    out2 = gnn_mod.forward(params, gnn_mod.GraphData(positions=jnp.asarray(pos @ rot.T), **base), cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-3, atol=1e-4)


def test_graphsage_minibatch_path():
    cfg = all_archs()["graphsage-reddit"].smoke
    params = gnn_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b = 6
    f1, f2 = cfg.fanouts
    feats = [
        jnp.asarray(rng.normal(size=(b, cfg.d_in)), jnp.float32),
        jnp.asarray(rng.normal(size=(b * f1, cfg.d_in)), jnp.float32),
        jnp.asarray(rng.normal(size=(b * f1 * f2, cfg.d_in)), jnp.float32),
    ]
    out = gnn_mod.sage_minibatch_forward(params, feats, cfg)
    assert out.shape == (b, cfg.d_out) and _finite(out)
