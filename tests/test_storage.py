"""NP storage (paper §III-B, Alg. 4): invariants + property tests."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import random_graph

from repro.core import Graph, GraphUpdate, build_np_storage, update_np_storage
from repro.core.pattern import PATTERN_LIBRARY, symmetry_break
from repro.core.listing import list_unit_compressed
from repro.core.pattern import enumerate_r1_units


def test_space_bound():
    """Σ|E_j| ≤ min(2|E| + 3Δ, m|E|) (§III-B accounting)."""
    for seed in range(3):
        g = random_graph(60, 200, seed=seed)
        for m in (2, 4, 8):
            storage = build_np_storage(g, m)
            rep = storage.space_report()
            assert rep["stored_edges"] <= rep["bound"], rep


def test_completeness_and_independence():
    """Lemma 3.1: M_ac unions are complete and pairwise disjoint."""
    g = random_graph(40, 120, seed=1)
    storage = build_np_storage(g, 4)
    pat = PATTERN_LIBRARY["q2_triangle"]
    ord_ = symmetry_break(pat)
    units = enumerate_r1_units(pat)
    unit = next(u for u in units if u.pattern.n == 3)
    cover = tuple(pat.vertices)
    all_rows = []
    for part in storage.parts:
        t = list_unit_compressed(part, unit, cover, ord_)
        _, rows = t.decompress(ord_)
        all_rows.append(set(map(tuple, rows.tolist())))
    # independence
    for i in range(len(all_rows)):
        for j in range(i + 1, len(all_rows)):
            assert not (all_rows[i] & all_rows[j])
    # completeness vs whole-graph listing
    from repro.core.match_engine import list_matches

    _, full = list_matches(g, unit.pattern, ord_)
    assert set(map(tuple, full.tolist())) == set().union(*all_rows)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    m=st.sampled_from([2, 3, 4, 8]),
    k_del=st.integers(0, 8),
    k_add=st.integers(0, 8),
)
def test_incremental_update_equals_rebuild(seed, m, k_del, k_add):
    """Alg. 4 batch semantics == from-scratch rebuild (bit-identical)."""
    r = np.random.default_rng(seed)
    g = random_graph(36, 90, seed=seed)
    storage = build_np_storage(g, m)
    edges = g.edges()
    k_del = min(k_del, edges.shape[0])
    dele = edges[r.choice(edges.shape[0], size=k_del, replace=False)] if k_del else np.empty((0, 2), np.int64)
    existing = set(map(tuple, edges.tolist()))
    add = set()
    while len(add) < k_add:
        a, b = int(r.integers(36)), int(r.integers(36))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
            existing.add((min(a, b), max(a, b)))
    u = GraphUpdate.make(delete=dele.tolist(), add=sorted(add))
    s2, _ = update_np_storage(storage, u)
    rebuilt = build_np_storage(g.apply_update(u), m)
    for pa, pb in zip(s2.parts, rebuilt.parts):
        assert np.array_equal(pa.codes, pb.codes), f"part {pa.pid}"


def test_update_rejects_bad_batches():
    g = random_graph(20, 40, seed=0)
    storage = build_np_storage(g, 2)
    e0 = tuple(g.edges()[0])
    with pytest.raises(ValueError):
        update_np_storage(storage, GraphUpdate.make(delete=[e0], add=[e0]))
    with pytest.raises(ValueError):
        update_np_storage(storage, GraphUpdate.make(add=[e0]))  # already exists
    with pytest.raises(ValueError):
        update_np_storage(storage, GraphUpdate.make(delete=[(0, 19)] if not g.has_edges(
            np.array([0]), np.array([19]))[0] else [(1, 18)]))


def test_rebalanced_partition_fn():
    from repro.core.storage import PartitionFn

    h = PartitionFn(4)
    h2 = h.rebalanced({0: 3, 5: 2})
    ids = np.arange(8)
    out = h2(ids)
    assert out[0] == 3 and out[5] == 2
    assert out[1] == 1 and out[6] == 2  # untouched follow id % m
