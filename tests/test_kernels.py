"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _lex_sorted_table(rng, m, vmax):
    hi = rng.integers(0, vmax, m).astype(np.int32)
    lo = rng.integers(0, vmax, m).astype(np.int32)
    order = np.lexsort((lo, hi))
    return hi[order], lo[order]


@pytest.mark.parametrize("g,ca,cb", [(7, 4, 4), (37, 13, 9), (129, 32, 16), (64, 1, 64)])
def test_set_intersect_sweep(g, ca, cb):
    rng = np.random.default_rng(g)
    pad = 2**31 - 1
    a = rng.integers(0, 50, size=(g, ca)).astype(np.int32)
    b = rng.integers(0, 50, size=(g, cb)).astype(np.int32)
    a[rng.random((g, ca)) < 0.3] = pad
    b[rng.random((g, cb)) < 0.3] = pad
    got = ops.set_intersect(jnp.array(a), jnp.array(b), pad=pad)
    want = ref.set_intersect_ref(jnp.array(a), jnp.array(b), pad)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("n,m", [(16, 16), (333, 777), (1025, 4099), (5, 1)])
def test_member_probe_sweep(n, m):
    rng = np.random.default_rng(n * 31 + m)
    th, tl = _lex_sorted_table(rng, m, 1000)
    qh = rng.integers(0, 1000, n).astype(np.int32)
    ql = rng.integers(0, 1000, n).astype(np.int32)
    k = min(n, m) // 2
    qh[:k], ql[:k] = th[:k], tl[:k]
    got = ops.member_probe(*map(jnp.array, (qh, ql, th, tl)))
    want = ref.member_probe_ref(*map(jnp.array, (qh, ql, th, tl)))
    # brute-force oracle for extra safety
    brute = np.array([((th == h) & (tl == l)).any() for h, l in zip(qh, ql)])
    assert (np.asarray(want) == brute).all()
    assert (np.asarray(got) == brute).all()


@pytest.mark.parametrize("plat", ["cpu", "tpu"])
def test_autotune_tile_table_parity(plat):
    """Every tile choice in the autotune table is a pure perf knob: for
    shapes landing in each platform's buckets, kernels run with the
    table's tiles (interpret mode here) stay bit-identical to the
    reference oracles — and the lookup itself is deterministic."""
    from repro.kernels import autotune

    pad = 2**31 - 1
    # one shape inside each member_probe bucket of this platform's row
    for bound, _tiles in autotune._MEMBER_PROBE[plat]:
        m = (bound if bound is not None
             else autotune._MEMBER_PROBE[plat][-2][0] * 2)
        m = min(m, 4096)            # keep interpret-mode runtime sane
        tq, tt = autotune.member_probe_tiles(257, m, plat=plat)
        assert (tq, tt) == autotune.member_probe_tiles(257, m, plat=plat)
        rng = np.random.default_rng(m)
        th, tl = _lex_sorted_table(rng, m, 1000)
        qh = rng.integers(0, 1000, 257).astype(np.int32)
        ql = rng.integers(0, 1000, 257).astype(np.int32)
        qh[:64], ql[:64] = th[:64], tl[:64]
        got = ops.member_probe(*map(jnp.array, (qh, ql, th, tl)),
                               tile_q=tq, tile_t=tt)
        want = ref.member_probe_ref(*map(jnp.array, (qh, ql, th, tl)))
        assert (np.asarray(got) == np.asarray(want)).all()
    # …and each set_intersect bucket
    for bound, _tiles in autotune._SET_INTERSECT[plat]:
        g = (bound if bound is not None
             else autotune._SET_INTERSECT[plat][0][0] or 256)
        g = min(g, 2048)
        tg = autotune.set_intersect_tiles(g, plat=plat)
        assert tg == autotune.set_intersect_tiles(g, plat=plat)
        rng = np.random.default_rng(g)
        a = rng.integers(0, 50, size=(g, 8)).astype(np.int32)
        b = rng.integers(0, 50, size=(g, 8)).astype(np.int32)
        a[rng.random((g, 8)) < 0.3] = pad
        b[rng.random((g, 8)) < 0.3] = pad
        got = ops.set_intersect(jnp.array(a), jnp.array(b), pad=pad, tile_g=tg)
        want = ref.set_intersect_ref(jnp.array(a), jnp.array(b), pad)
        assert (np.asarray(got) == np.asarray(want)).all()
    # unknown platforms fall back to the cpu rows
    assert autotune.member_probe_tiles(64, 64, plat="rocm") == \
        autotune.member_probe_tiles(64, 64, plat="cpu")


@pytest.mark.parametrize("e,d,n,dtype", [
    (64, 8, 10, np.float32),
    (500, 16, 37, np.float32),
    (1000, 32, 100, np.float32),
    (128, 128, 3, np.float32),
])
def test_segment_sum_sweep(e, d, n, dtype):
    rng = np.random.default_rng(e + d)
    seg = np.sort(rng.integers(0, n, size=e)).astype(np.int32)
    data = rng.normal(size=(e, d)).astype(dtype)
    got = ops.segment_sum(jnp.array(data), jnp.array(seg), n)
    want = ref.segment_sum_ref(jnp.array(data), jnp.array(seg), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("v,d,b,nb", [(100, 8, 64, 20), (64, 16, 128, 5), (32, 4, 7, 7)])
def test_embedding_bag_sweep(v, d, b, nb):
    rng = np.random.default_rng(v + b)
    table = rng.normal(size=(v, d)).astype(np.float32)
    idx = rng.integers(0, v, size=b).astype(np.int32)
    bag = rng.integers(0, nb, size=b).astype(np.int32)
    got = ops.embedding_bag(jnp.array(table), jnp.array(idx), jnp.array(bag), nb)
    want = ref.embedding_bag_ref(jnp.array(table), jnp.array(idx), jnp.array(bag), nb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,hq,hkv,lq,lk,dh,off,tq,tk", [
    (2, 4, 2, 96, 96, 32, 0, 32, 32),     # train (GQA)
    (1, 8, 8, 64, 64, 16, 0, 16, 16),     # MHA
    (2, 4, 4, 1, 96, 32, 95, 1, 32),      # decode
    (1, 4, 2, 40, 40, 32, 0, 16, 16),     # ragged tail (padding path)
])
def test_flash_attention_sweep(b, hq, hkv, lq, lk, dh, off, tq, tk):
    rng = np.random.default_rng(b * 7 + lq)
    q = rng.normal(size=(b, hq, lq, dh)).astype(np.float32)
    k = rng.normal(size=(b, hkv, lk, dh)).astype(np.float32)
    v = rng.normal(size=(b, hkv, lk, dh)).astype(np.float32)
    got = ops.flash_attention(jnp.array(q), jnp.array(k), jnp.array(v),
                              causal=True, q_offset=off, tile_q=tq, tile_k=tk)
    want = ref.flash_attention_ref(jnp.array(q), jnp.array(k), jnp.array(v),
                                   causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(1, 2, 64, 32)).astype(np.float32)
    k = rng.normal(size=(1, 2, 64, 32)).astype(np.float32)
    v = rng.normal(size=(1, 2, 64, 32)).astype(np.float32)
    qb, kb, vb = (jnp.array(x, jnp.bfloat16) for x in (q, k, v))
    got = ops.flash_attention(qb, kb, vb, causal=True, tile_q=32, tile_k=32)
    want = ref.flash_attention_ref(qb, kb, vb, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )
