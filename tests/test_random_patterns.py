"""Property test: DDSL is exact for *random* connected patterns, not just
the paper's five — initial listing and incremental updates."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import oracle_instances, random_graph

from repro.core import DDSL, GraphUpdate
from repro.core.pattern import Pattern


def _random_connected_pattern(seed: int, n: int) -> Pattern:
    r = np.random.default_rng(seed)
    edges = [(i, int(r.integers(0, i))) for i in range(1, n)]  # random tree
    extra = r.integers(0, n * (n - 1) // 2 - (n - 1) + 1)
    tries = 0
    es = {(min(a, b), max(a, b)) for a, b in edges}
    while len(es) < (n - 1) + extra and tries < 50:
        a, b = r.integers(0, n, 2)
        tries += 1
        if a != b:
            es.add((min(int(a), int(b)), max(int(a), int(b))))
    return Pattern.make(sorted(es))


@settings(max_examples=10, deadline=None)
@given(pseed=st.integers(0, 10_000), n=st.integers(3, 5), gseed=st.integers(0, 100))
def test_random_pattern_initial_and_update(pseed, n, gseed):
    pattern = _random_connected_pattern(pseed, n)
    g = random_graph(30, 70, seed=gseed)
    try:
        eng = DDSL(g, pattern, m=3)
    except ValueError:
        pytest.skip("no anchored R1 decomposition for this cover (allowed)")
    eng.initial()
    assert eng.count() == oracle_instances(g, pattern)

    r = np.random.default_rng(pseed ^ gseed)
    edges = g.edges()
    k = min(3, edges.shape[0])
    dele = edges[r.choice(edges.shape[0], size=k, replace=False)]
    existing = set(map(tuple, edges.tolist()))
    add = set()
    while len(add) < 3:
        a, b = int(r.integers(30)), int(r.integers(30))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
            existing.add((min(a, b), max(a, b)))
    u = GraphUpdate.make(delete=dele.tolist(), add=sorted(add))
    eng.apply(u)
    assert eng.count() == oracle_instances(g.apply_update(u), pattern)
