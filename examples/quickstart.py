"""Quickstart: list subgraphs, apply a dynamic update, inspect the plan.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import DDSL, Graph, GraphUpdate
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import rmat_graph, sample_update


def main() -> None:
    # A power-law data graph (R-MAT) and the paper's "house" pattern.
    graph = rmat_graph(10, 6000, seed=0)
    pattern = PATTERN_LIBRARY["q5_house"]
    print(f"data graph: n={graph.n} m={graph.num_edges}")

    engine = DDSL(graph, pattern, m=4)
    print("chosen cover:", engine.cover)
    print("symmetry-breaking order:", engine.ord_)
    print("optimal join tree:\n" + engine.tree.describe())

    engine.initial()
    print(f"\ninitial |M(p, d)| = {engine.count()}")

    update = sample_update(engine.graph, n_delete=20, n_add=20, seed=1)
    rep = engine.apply(update)
    print(f"after update (+20/-20 edges): |M(p, d')| = {engine.count()}")
    print(f"  patch matches: {rep.nav.patch_matches}, "
          f"navigated ints: {rep.nav.shipped_ints}, "
          f"storage edges moved: ±{rep.storage.edges_removed}/{rep.storage.edges_added}")


if __name__ == "__main__":
    main()
