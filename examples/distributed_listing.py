"""Distributed SPMD listing + incremental update on a multi-device mesh.

Runs the jitted shard_map steps (the same programs the dry-run lowers at
512 chips) on 8 fake CPU devices and cross-checks against the host
engine.

    PYTHONPATH=src python examples/distributed_listing.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.core import DDSL, build_np_storage, symmetry_break  # noqa: E402
from repro.core.cost import CostModel  # noqa: E402
from repro.core.ddsl import choose_cover  # noqa: E402
from repro.core.estimator import GraphStats  # noqa: E402
from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree  # noqa: E402
from repro.core.pattern import PATTERN_LIBRARY  # noqa: E402
from repro.data.graphs import rmat_graph, sample_update  # noqa: E402
from repro.dist import jax_engine as je  # noqa: E402
from repro.dist import sharded  # noqa: E402


def main() -> None:
    m = 8
    mesh = jax.make_mesh((m,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    graph = rmat_graph(7, 320, seed=0)
    pattern = PATTERN_LIBRARY["q1_square"]
    ord_ = symmetry_break(pattern)
    stats = GraphStats.of(graph)
    cover = choose_cover(pattern, ord_, stats)
    tree = optimal_join_tree(pattern, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    units = minimum_unit_decomposition(pattern, cover)

    caps = je.EngineCaps(v_cap=128, deg_cap=64, e_cap=1024, match_cap=8192,
                         group_cap=4096, set_cap=64, pair_cap=256)
    storage = build_np_storage(graph, m)
    pt = sharded.stack_partitions(storage, caps)
    pt = jax.device_put(pt, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                         sharded.partition_specs(mesh)))

    print("compiling distributed list_step ...")
    list_step = sharded.make_list_step(prog, mesh, caps)
    out, diag = list_step(pt)
    host = DDSL(graph, pattern, m=m, cover=cover)
    host.initial()
    print(f"distributed groups={int(diag['matches_lower_bound'])} "
          f"overflow={int(diag['overflow'])} | host |M|={host.count()}")

    update = sample_update(graph, 4, 4, seed=2)
    print("compiling distributed update_step ...")
    upd_step = sharded.make_update_step(prog, units, mesh, caps,
                                        sharded.UpdateShapes(4, 4))
    pt2, patch, diag2 = upd_step(
        pt, jnp.asarray(update.add, jnp.int32), jnp.asarray(update.delete, jnp.int32)
    )
    host.apply(update)
    print(f"patch groups={int(diag2['patch_groups'])} overflow={int(diag2['overflow'])} "
          f"| host |M(p,d')|={host.count()}")
    print("distributed run complete")


if __name__ == "__main__":
    main()
