"""Train a GNN on a synthetic graph (full-batch) — loss must decrease.

    PYTHONPATH=src python examples/train_gnn.py --arch gatedgcn --steps 30
"""

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.graphs import build_graph_data
from repro.models import gnn as gnn_mod
from repro.optim import adamw_init, adamw_update


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gatedgcn")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke
    rng = np.random.default_rng(0)
    raw = build_graph_data(n_nodes=128, n_edges=512, d_feat=cfg.d_in,
                           d_edge=cfg.d_edge_in, seed=0, geometric=True)
    g = gnn_mod.GraphData(**{k: jnp.asarray(v) for k, v in raw.items()})
    # teach it a simple structural signal: label = degree bucket
    deg = np.bincount(raw["dst"][raw["edge_mask"]], minlength=128)
    labels = jnp.asarray(np.minimum(deg, cfg.d_out - 1) if cfg.d_out > 1 else deg, jnp.int32)

    params = gnn_mod.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt):
        def loss_fn(p):
            out = gnn_mod.forward(p, g, cfg).astype(jnp.float32)
            if cfg.d_out > 1:
                lse = jax.nn.logsumexp(out, -1)
                ll = jnp.take_along_axis(out, labels[:, None], -1)[:, 0]
                return jnp.mean(lse - ll)
            return jnp.mean((out[:, 0] - labels) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        p2, o2, _ = adamw_update(params, grads, opt, 3e-3)
        return p2, o2, loss

    first = None
    for i in range(args.steps):
        params, opt, loss = step(params, opt)
        if first is None:
            first = float(loss)
        if i % 5 == 0:
            print(f"step {i}: loss={float(loss):.4f}")
    print(f"loss {first:.4f} → {float(loss):.4f}")
    assert float(loss) < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
