"""Train a small LM end-to-end (few hundred steps) with checkpoint/restart.

Thin wrapper over the production driver at smoke scale:

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    args = ap.parse_args()
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch, "--steps", str(args.steps),
        "--smoke", "--batch", "8", "--seq", "64",
        "--ckpt-dir", "/tmp/repro_lm_ckpt",
    ]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
