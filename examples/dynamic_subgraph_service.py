"""End-to-end driver of ``repro.stream``: a continuous listing service.

The paper's deployment story, productionized: several patterns stay live
over one update stream (§VII-C protocol — batches of half deletions /
half insertions). Updates are ingested into the journal, the scheduler
nets them into cost-model-sized micro-batches, one shared delta drives
every pattern (Alg. 4 once per batch), sinks stream count deltas out,
and a from-scratch audit re-lists one pattern every ``--audit-every``
batches.

``--backend sharded`` is **device-resident**: each pattern's running
match set lives on the mesh as a sharded ``MatchStore`` and every batch
runs one fused maintain step (patch ∘ filter ∘ merge ∘ count) per
pattern on device. With only the count sink subscribed, batches move
scalars device→host — the ``hostB`` field of the per-batch line stays 0
(add a match-delta sink and it jumps: rows materialize lazily, on
demand).

``--obs-dir DIR`` turns on full observability (span tracing included)
and exports the whole bundle on exit: metrics JSON + Prometheus text,
the span tree as JSONL + Chrome trace-event JSON (open in
https://ui.perfetto.dev), and the per-step compile/execute profile on
the sharded backend.

``--reoptimize`` attaches a :class:`repro.stream.PlanManager`: every
committed batch it watches the scheduler's drift EWMA and periodically
recompiles each pattern's join tree from live stats through the staged
plan compiler (``repro.planner``), hot-swapping a plan at the watermark
when the Eq. 11 re-cost says the incumbent has gone stale. Swap
decisions are printed at the end; with ``--obs-dir`` the compiled-plan
dumps and ``plan_swap`` spans land in the export bundle.

    PYTHONPATH=src python examples/dynamic_subgraph_service.py --batches 8
    PYTHONPATH=src python examples/dynamic_subgraph_service.py --backend sharded
    PYTHONPATH=src python examples/dynamic_subgraph_service.py --obs-dir /tmp/obs
    PYTHONPATH=src python examples/dynamic_subgraph_service.py --reoptimize
"""

import argparse

from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import rmat_graph, sample_update
from repro.stream import (
    BatchScheduler,
    CountDeltaSink,
    ListingService,
    Observability,
    PlanManager,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=8, help="ingest rounds")
    ap.add_argument("--batch-size", type=int, default=50)
    ap.add_argument("--patterns", default="q2_triangle,q1_square,q5_house")
    ap.add_argument("--audit-every", type=int, default=4)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--backend", choices=("host", "sharded"), default="host")
    ap.add_argument("--executor", choices=("auto", "tree", "wcoj"),
                    default="tree",
                    help="join executor mode: 'tree' (VCBC join trees), "
                         "'wcoj' (force the worst-case-optimal generic "
                         "join; dense patterns only), or 'auto' (compiler "
                         "picks per pattern from the cost model)")
    ap.add_argument("--target-cost", type=float, default=250_000.0,
                    help="scheduler per-micro-batch work budget (cost units)")
    ap.add_argument("--obs-dir", default=None,
                    help="enable span tracing and export the observability "
                         "bundle (metrics snapshot, Prometheus text, Chrome "
                         "trace, compiled-plan dumps, device-step profile) "
                         "into this directory")
    ap.add_argument("--reoptimize", action="store_true",
                    help="drift-triggered online join-tree re-optimization: "
                         "recompile plans from live stats and hot-swap at "
                         "committed watermarks")
    ap.add_argument("--drift-threshold", type=float, default=1.5,
                    help="scheduler drift EWMA that triggers a recompile")
    ap.add_argument("--recost-every", type=int, default=16,
                    help="also recompile every K batches (0 disables)")
    args = ap.parse_args()

    pm = PlanManager(drift_threshold=args.drift_threshold,
                     recost_every=args.recost_every) if args.reoptimize else None

    if args.backend == "sharded":
        graph = rmat_graph(6, 400, seed=0)     # sharded demo: device-sized
        kw = dict(max_add=args.batch_size, max_del=args.batch_size)
    else:
        graph = rmat_graph(10, 5000, seed=0)
        kw = dict(m=args.m)
    svc = ListingService(
        graph, backend=args.backend, audit_every=args.audit_every,
        scheduler=BatchScheduler(target_cost=args.target_cost,
                                 max_ops=args.batch_size),
        obs=Observability.full() if args.obs_dir else None,
        plan_manager=pm, executor=args.executor, **kw)
    counts = svc.subscribe(CountDeltaSink())

    for name in args.patterns.split(","):
        n0 = svc.register(name, PATTERN_LIBRARY[name])
        meta = svc.backend.meta(name)
        mode = meta.plan.executor if meta.plan is not None else "tree"
        print(f"[init] {name}: |M|={n0} executor={mode}")

    seen_audits = 0
    for b in range(args.batches):
        upd = sample_update(svc.projected_graph(), args.batch_size // 2,
                            args.batch_size // 2, seed=100 + b)
        svc.ingest(upd)
        for bm in svc.advance():
            per = " ".join(
                f"{n}:|M|={r.count_after}(+{r.patch_groups}g)"
                for n, r in bm.patterns.items())
            cand = (f" cand={bm.cand_vertices}v/{bm.cand_edges}e"
                    if bm.cand_vertices >= 0 else "")
            host_b = (f" hostB={bm.host_bytes}"
                      if args.backend == "sharded" else "")
            cache = (f" cache={bm.cache_hits}h/{bm.cache_misses}m"
                     f"/{bm.invalidated_parts}inv"
                     if bm.cache_hits >= 0 else "")
            print(f"[batch {bm.batch_index}] ops={bm.n_ops} "
                  f"(net +{bm.net_add}/-{bm.net_delete}) "
                  f"{bm.latency_s*1e3:.0f}ms {bm.throughput_ops_s:.0f}op/s "
                  f"ovf={bm.overflow}{cand}{host_b}{cache} {per}")
        for bi, name, ok in svc.audits[seen_audits:]:
            print(f"[audit] batch {bi} {name}: {'OK' if ok else 'MISMATCH'}")
        seen_audits = len(svc.audits)

    print(f"service run complete: counts={svc.counts()} "
          f"watermark={svc.committed_watermark} "
          f"journal_compacted={svc.compact()} entries")
    print(f"count deltas seen by sink: {counts.totals}")
    drift = svc.scheduler.drift()
    if drift is not None:
        print(f"scheduler drift (observed/predicted EWMA): {drift:.2f}")
    if pm is not None:
        for ev in pm.events:
            verdict = ("SWAPPED" if ev.swapped else "kept")
            print(f"[replan] batch {ev.batch_index} {ev.pattern} "
                  f"({ev.trigger}, drift={ev.drift and f'{ev.drift:.2f}'}): "
                  f"inc={ev.incumbent_cost:.3g} cand={ev.candidate_cost:.3g} "
                  f"-> {verdict}"
                  + (f" |M|={ev.count} in {ev.elapsed_s*1e3:.0f}ms"
                     if ev.swapped else ""))
    if args.obs_dir:
        for kind, path in sorted(svc.obs.export(args.obs_dir).items()):
            print(f"[obs] {kind}: {path}")


if __name__ == "__main__":
    main()
