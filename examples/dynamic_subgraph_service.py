"""End-to-end driver: a dynamic subgraph-listing *service*.

The paper's deployment story: keep match sets of several patterns live
while the data graph streams batch updates (the §VII-C protocol —
batches of half deletions / half insertions). Every batch is served
incrementally via Alg. 4 + Nav-join; correctness is spot-audited against
a from-scratch engine every ``--audit-every`` batches.

    PYTHONPATH=src python examples/dynamic_subgraph_service.py --batches 8
"""

import argparse
import time

from repro.core import DDSL
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import rmat_graph, sample_update


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=50)
    ap.add_argument("--patterns", default="q2_triangle,q1_square,q5_house")
    ap.add_argument("--audit-every", type=int, default=4)
    ap.add_argument("--m", type=int, default=4)
    args = ap.parse_args()

    graph = rmat_graph(10, 5000, seed=0)
    names = args.patterns.split(",")
    engines = {}
    for name in names:
        t0 = time.perf_counter()
        eng = DDSL(graph, PATTERN_LIBRARY[name], m=args.m)
        eng.initial()
        print(f"[init] {name}: |M|={eng.count()} ({time.perf_counter()-t0:.2f}s)")
        engines[name] = eng

    for b in range(args.batches):
        # all engines share the same stream of updates
        any_eng = engines[names[0]]
        update = sample_update(any_eng.graph, args.batch_size // 2,
                               args.batch_size // 2, seed=100 + b)
        for name, eng in engines.items():
            t0 = time.perf_counter()
            rep = eng.apply(update)
            dt = time.perf_counter() - t0
            print(f"[batch {b}] {name}: |M|={eng.count()} "
                  f"(+{rep.nav.patch_matches} patch, {dt*1e3:.0f}ms)")
        if (b + 1) % args.audit_every == 0:
            name = names[(b // args.audit_every) % len(names)]
            eng = engines[name]
            fresh = DDSL(eng.graph, PATTERN_LIBRARY[name], m=args.m)
            fresh.initial()
            ok = fresh.count() == eng.count()
            print(f"[audit] {name}: incremental={eng.count()} scratch={fresh.count()} "
                  f"{'OK' if ok else 'MISMATCH'}")
            assert ok
    print("service run complete")


if __name__ == "__main__":
    main()
