"""Benchmark utilities: timing, CSV rows, JSON artifacts, shared workloads."""

from __future__ import annotations

import json
import time
from typing import Callable, List

import numpy as np

from repro.data.graphs import rmat_graph

__all__ = ["timeit", "Row", "emit", "emit_json", "compare_baseline", "bench_graphs"]


def timeit(fn: Callable, *, repeat: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


class Row:
    def __init__(self, name: str, us_per_call: float, derived: str = ""):
        self.name = name
        self.us = us_per_call
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def emit(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())


def _parse_derived(derived: str):
    """``k=v;k=v`` → dict with numeric coercion (CI trend tracking)."""
    out = {}
    for item in derived.split(";"):
        if "=" not in item:
            continue
        k, v = item.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def emit_json(path: str, benchmark: str, rows: List[Row]) -> None:
    """Write one ``BENCH_<benchmark>.json`` artifact: machine-readable
    per-benchmark timings so the perf trajectory is trackable across
    commits (the CI stream-smoke job archives these)."""
    doc = {
        "benchmark": benchmark,
        "rows": [
            {"name": r.name, "us_per_call": round(r.us, 3),
             "derived": _parse_derived(r.derived)}
            for r in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def compare_baseline(rows: List[Row], baseline_doc: dict,
                     tolerance: float = 2.0, abs_slack_us: float = 500.0):
    """Compare fresh rows against a checked-in ``BENCH_*.json`` baseline.

    A row regresses when its **machine-normalized** time exceeds
    ``tolerance · baseline_us + abs_slack_us``. Baselines are recorded
    on one machine and checked on another (CI runners vary widely), so
    raw wall-clock comparisons would flag every row on a uniformly
    slower box — instead the fresh times are divided by the median
    fresh/baseline ratio across all compared rows (the machine-speed
    scale, needing ≥3 shared rows; 1.0 otherwise). A uniform slowdown
    cancels out; a *single* row drifting past the band relative to its
    peers — an actual code regression — still trips, and so does one
    masked by an otherwise faster runner. The multiplicative band
    catches real slowdowns; the absolute slack keeps sub-millisecond
    rows (dominated by clock/jit noise) from flaking. Returns
    ``(regressions, missing, diff_doc)``: regressed row names, baseline
    rows that disappeared, and a machine-readable per-row diff for the
    CI artifact.
    """
    base = {r["name"]: float(r["us_per_call"])
            for r in baseline_doc.get("rows", [])}
    fresh = {r.name: float(r.us) for r in rows}
    ratios = [fresh[n] / base[n] for n in fresh if n in base and base[n] > 0]
    scale = float(np.median(ratios)) if len(ratios) >= 3 else 1.0
    if not np.isfinite(scale) or scale <= 0:
        scale = 1.0
    # Clamp the machine scale: CI runners plausibly sit within ~4x of
    # the baseline box, but an unbounded median would also absorb a
    # genuine module-wide regression (every row slower because a shared
    # code path regressed looks exactly like a slow machine). Beyond
    # the band the excess stays in the per-row ratios and trips the
    # tolerance check.
    scale = float(np.clip(scale, 0.25, 4.0))
    regressions, missing, diff = [], [], []
    for name, us in fresh.items():
        if name not in base:
            diff.append({"name": name, "us_per_call": round(us, 3),
                         "baseline_us": None, "status": "new"})
            continue
        b = base[name]
        adj = us / scale
        limit = tolerance * b + abs_slack_us
        status = "regression" if adj > limit else "ok"
        if status == "regression":
            regressions.append(name)
        diff.append({"name": name, "us_per_call": round(us, 3),
                     "normalized_us": round(adj, 3),
                     "baseline_us": round(b, 3),
                     "ratio": round(adj / b, 3) if b > 0 else None,
                     "limit_us": round(limit, 3), "status": status})
    for name in sorted(set(base) - set(fresh)):
        missing.append(name)
        diff.append({"name": name, "us_per_call": None,
                     "baseline_us": round(base[name], 3), "status": "missing"})
    doc = {"tolerance": tolerance, "abs_slack_us": abs_slack_us,
           "machine_scale": round(scale, 4),
           "regressions": regressions, "missing": missing, "rows": diff}
    return regressions, missing, doc


def bench_graphs():
    """Scaled-down stand-ins for the paper's WG/WT/LJ/UK datasets
    (same power-law family via R-MAT, laptop-scale sizes)."""
    return {
        "WG~": rmat_graph(11, 12_000, seed=0),   # ~2k nodes
        "WT~": rmat_graph(12, 10_000, seed=1),
        "LJ~": rmat_graph(12, 24_000, seed=2),
        "UK~": rmat_graph(13, 48_000, seed=3),
    }
