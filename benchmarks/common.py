"""Benchmark utilities: timing, CSV rows, JSON artifacts, shared workloads."""

from __future__ import annotations

import json
import time
from typing import Callable, List

import numpy as np

from repro.data.graphs import rmat_graph

__all__ = ["timeit", "Row", "emit", "emit_json", "bench_graphs"]


def timeit(fn: Callable, *, repeat: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


class Row:
    def __init__(self, name: str, us_per_call: float, derived: str = ""):
        self.name = name
        self.us = us_per_call
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def emit(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())


def _parse_derived(derived: str):
    """``k=v;k=v`` → dict with numeric coercion (CI trend tracking)."""
    out = {}
    for item in derived.split(";"):
        if "=" not in item:
            continue
        k, v = item.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def emit_json(path: str, benchmark: str, rows: List[Row]) -> None:
    """Write one ``BENCH_<benchmark>.json`` artifact: machine-readable
    per-benchmark timings so the perf trajectory is trackable across
    commits (the CI stream-smoke job archives these)."""
    doc = {
        "benchmark": benchmark,
        "rows": [
            {"name": r.name, "us_per_call": round(r.us, 3),
             "derived": _parse_derived(r.derived)}
            for r in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def bench_graphs():
    """Scaled-down stand-ins for the paper's WG/WT/LJ/UK datasets
    (same power-law family via R-MAT, laptop-scale sizes)."""
    return {
        "WG~": rmat_graph(11, 12_000, seed=0),   # ~2k nodes
        "WT~": rmat_graph(12, 10_000, seed=1),
        "LJ~": rmat_graph(12, 24_000, seed=2),
        "UK~": rmat_graph(13, 48_000, seed=3),
    }
