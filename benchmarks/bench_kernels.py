"""Pallas-kernel microbench: interpret-mode sanity + XLA-ref timing.

On CPU the Pallas kernels run interpreted (not representative), so the
timed numbers here are the XLA reference implementations; the kernels'
value on TPU is characterized analytically in EXPERIMENTS.md §Perf
(score-traffic elimination by flash attention, gather-DMA embedding bag).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import Row, timeit


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    # flash attention (ref path timing at bench scale)
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
    t = timeit(lambda: f(q, k, v).block_until_ready())
    flops = 2 * 2 * 8 * 512 * 512 * 64
    rows.append(Row("kernel/attention_ref_512", t * 1e6, f"gflops_s={flops/t/1e9:.1f}"))

    # segment_sum
    seg = jnp.asarray(np.sort(rng.integers(0, 4096, 65536)), jnp.int32)
    data = jnp.asarray(rng.normal(size=(65536, 64)), jnp.float32)
    f2 = jax.jit(lambda d, s: ref.segment_sum_ref(d, s, 4096))
    t = timeit(lambda: f2(data, seg).block_until_ready())
    rows.append(Row("kernel/segment_sum_ref_64k", t * 1e6,
                    f"gbytes_s={(data.nbytes * 2)/t/1e9:.1f}"))

    # member probe (binary search ref)
    m = 1 << 16
    th = jnp.asarray(np.sort(rng.integers(0, 1 << 30, m)), jnp.int32)
    tl = jnp.asarray(rng.integers(0, 1 << 30, m), jnp.int32)
    qh = jnp.asarray(rng.integers(0, 1 << 30, 65536), jnp.int32)
    ql = jnp.asarray(rng.integers(0, 1 << 30, 65536), jnp.int32)
    f3 = jax.jit(ref.member_probe_ref)
    t = timeit(lambda: f3(qh, ql, th, tl).block_until_ready())
    rows.append(Row("kernel/member_probe_ref_64k", t * 1e6,
                    f"mprobes_s={65536/t/1e6:.1f}"))

    # embedding bag
    table = jnp.asarray(rng.normal(size=(100_000, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 100_000, 32768), jnp.int32)
    bag = jnp.asarray(np.sort(rng.integers(0, 8192, 32768)), jnp.int32)
    f4 = jax.jit(lambda t_, i, b: ref.embedding_bag_ref(t_, i, b, 8192))
    t = timeit(lambda: f4(table, idx, bag).block_until_ready())
    rows.append(Row("kernel/embedding_bag_ref_32k", t * 1e6,
                    f"glookups_s={32768/t/1e9:.3f}"))

    # interpret-mode correctness spot checks (tiny, not timed meaningfully)
    a = jnp.asarray(rng.integers(0, 30, (16, 8)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 30, (16, 8)), jnp.int32)
    got = ops.set_intersect(a, b, pad=2**31 - 1)
    want = ref.set_intersect_ref(a, b, 2**31 - 1)
    assert (np.asarray(got) == np.asarray(want)).all()
    rows.append(Row("kernel/set_intersect_interpret_ok", 0.0, "validated"))
    return rows
