"""Pallas-kernel microbench: interpret-mode sanity + XLA-ref timing.

On CPU the Pallas kernels run interpreted (not representative), so the
timed numbers here are the XLA reference implementations; the kernels'
value on TPU is characterized analytically in EXPERIMENTS.md §Perf
(score-traffic elimination by flash attention, gather-DMA embedding bag).

``python -m benchmarks.bench_kernels --sweep-tiles`` additionally runs
the real-hardware tile sweep behind the
:mod:`repro.kernels.autotune` bucket tables: every (shape bucket ×
candidate tile) cell of :func:`repro.kernels.ops.member_probe` /
:func:`~repro.kernels.ops.set_intersect` is timed on the *current*
backend and the winners land in a JSON artifact from which the tables
can be re-recorded (:func:`repro.kernels.autotune.rows_from_sweep`).
"""

from __future__ import annotations

import argparse
import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref

from .common import Row, timeit


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    # flash attention (ref path timing at bench scale)
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
    t = timeit(lambda: f(q, k, v).block_until_ready())
    flops = 2 * 2 * 8 * 512 * 512 * 64
    rows.append(Row("kernel/attention_ref_512", t * 1e6, f"gflops_s={flops/t/1e9:.1f}"))

    # segment_sum
    seg = jnp.asarray(np.sort(rng.integers(0, 4096, 65536)), jnp.int32)
    data = jnp.asarray(rng.normal(size=(65536, 64)), jnp.float32)
    f2 = jax.jit(lambda d, s: ref.segment_sum_ref(d, s, 4096))
    t = timeit(lambda: f2(data, seg).block_until_ready())
    rows.append(Row("kernel/segment_sum_ref_64k", t * 1e6,
                    f"gbytes_s={(data.nbytes * 2)/t/1e9:.1f}"))

    # member probe (binary search ref)
    m = 1 << 16
    th = jnp.asarray(np.sort(rng.integers(0, 1 << 30, m)), jnp.int32)
    tl = jnp.asarray(rng.integers(0, 1 << 30, m), jnp.int32)
    qh = jnp.asarray(rng.integers(0, 1 << 30, 65536), jnp.int32)
    ql = jnp.asarray(rng.integers(0, 1 << 30, 65536), jnp.int32)
    f3 = jax.jit(ref.member_probe_ref)
    t = timeit(lambda: f3(qh, ql, th, tl).block_until_ready())
    rows.append(Row("kernel/member_probe_ref_64k", t * 1e6,
                    f"mprobes_s={65536/t/1e6:.1f}"))

    # embedding bag
    table = jnp.asarray(rng.normal(size=(100_000, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 100_000, 32768), jnp.int32)
    bag = jnp.asarray(np.sort(rng.integers(0, 8192, 32768)), jnp.int32)
    f4 = jax.jit(lambda t_, i, b: ref.embedding_bag_ref(t_, i, b, 8192))
    t = timeit(lambda: f4(table, idx, bag).block_until_ready())
    rows.append(Row("kernel/embedding_bag_ref_32k", t * 1e6,
                    f"glookups_s={32768/t/1e9:.3f}"))

    # interpret-mode correctness spot checks (tiny, not timed meaningfully)
    a = jnp.asarray(rng.integers(0, 30, (16, 8)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 30, (16, 8)), jnp.int32)
    got = ops.set_intersect(a, b, pad=2**31 - 1)
    want = ref.set_intersect_ref(a, b, 2**31 - 1)
    assert (np.asarray(got) == np.asarray(want)).all()
    rows.append(Row("kernel/set_intersect_interpret_ok", 0.0, "validated"))
    return rows


# ---------------------------------------------------------------------------
# --sweep-tiles: real-hardware timings behind the autotune bucket tables
# ---------------------------------------------------------------------------

def _sweep_shapes(plat: str):
    """(shape buckets, candidate tiles) per kernel for this backend.

    On TPU the sweep covers the engine-cap shapes the benchmarks
    exercise (the bucket bounds in the shipped tables); off-TPU the
    kernels run interpreted, so the sweep shrinks to plumbing-sized
    shapes — the artifact still round-trips through
    :func:`~repro.kernels.autotune.rows_from_sweep`, it just isn't a
    perf record.
    """
    if plat == "tpu":
        return {
            "member_probe": {
                "n_t": (4096, 32768, 131072), "n_q": 8192,
                "tile_q": (512, 1024, 2048), "tile_t": (1024, 2048, 4096),
            },
            "set_intersect": {
                "n_g": (1024, 8192, 16384), "width": 64,
                "tile_g": (128, 256, 512, 1024),
            },
        }
    return {
        "member_probe": {
            "n_t": (1024, 2048), "n_q": 512,
            "tile_q": (256, 512), "tile_t": (512, 1024),
        },
        "set_intersect": {
            "n_g": (256, 512), "width": 8,
            "tile_g": (64, 128, 256),
        },
    }


def sweep_tiles(out_path: str, plat: str | None = None) -> dict:
    """Time every (shape bucket × candidate tile) cell on the current
    backend and write the artifact ``autotune.rows_from_sweep`` ingests.
    Returns the document (also written to ``out_path`` when non-empty).
    """
    plat = plat if plat is not None else autotune.platform()
    shapes = _sweep_shapes(plat)
    rng = np.random.default_rng(0)
    doc = {"platform": plat, "member_probe": [], "set_intersect": []}

    mp = shapes["member_probe"]
    for n_t in mp["n_t"]:
        n_q = int(mp["n_q"])
        th = jnp.asarray(np.sort(rng.integers(0, 1 << 30, n_t)), jnp.int32)
        tl = jnp.asarray(rng.integers(0, 1 << 30, n_t), jnp.int32)
        qh = jnp.asarray(rng.integers(0, 1 << 30, n_q), jnp.int32)
        ql = jnp.asarray(rng.integers(0, 1 << 30, n_q), jnp.int32)
        for tile_q in mp["tile_q"]:
            if tile_q > n_q:
                continue
            for tile_t in mp["tile_t"]:
                if tile_t > n_t:
                    continue
                f = jax.jit(lambda a, b, c, d, tq=tile_q, tt=tile_t:
                            ops.member_probe(a, b, c, d, tile_q=tq, tile_t=tt))
                t = timeit(lambda: f(qh, ql, th, tl).block_until_ready())
                doc["member_probe"].append({
                    "n_t": int(n_t), "n_q": n_q,
                    "tile_q": int(tile_q), "tile_t": int(tile_t),
                    "us": round(t * 1e6, 3)})

    si = shapes["set_intersect"]
    for n_g in si["n_g"]:
        w = int(si["width"])
        a = jnp.asarray(np.sort(rng.integers(0, 1 << 20, (n_g, w)), axis=1),
                        jnp.int32)
        b = jnp.asarray(np.sort(rng.integers(0, 1 << 20, (n_g, w)), axis=1),
                        jnp.int32)
        for tile_g in si["tile_g"]:
            if tile_g > n_g:
                continue
            f = jax.jit(lambda x, y, tg=tile_g:
                        ops.set_intersect(x, y, pad=2**31 - 1, tile_g=tg))
            t = timeit(lambda: f(a, b).block_until_ready())
            doc["set_intersect"].append({
                "n_g": int(n_g), "tile_g": int(tile_g),
                "us": round(t * 1e6, 3)})

    doc["best"] = autotune.rows_from_sweep(doc)
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep-tiles", action="store_true",
                    help="run the autotune tile sweep instead of the "
                         "fixed microbench rows")
    ap.add_argument("--out", default="bench_artifacts/BENCH_tile_sweep.json",
                    help="JSON artifact path for --sweep-tiles")
    args = ap.parse_args()
    if args.sweep_tiles:
        import os
        d = os.path.dirname(args.out)
        if d:
            os.makedirs(d, exist_ok=True)
        doc = sweep_tiles(args.out)
        print(json.dumps(doc["best"], indent=2, sort_keys=True))
        print(f"# wrote {args.out}")
    else:
        from .common import emit
        emit(run())


if __name__ == "__main__":
    main()
