"""Host engine vs static-shape JAX engine: unit listing wall-clock.

Times one anchored unit listing (``M_ac`` of the largest R1 unit) per
pattern on one NP partition, three ways:

- host: ragged NumPy ``list_unit_compressed``
- jax:  ``jax_engine.unit_list`` + ``compress_plain`` (jitted, padded)

across a small/large cap model, so the padding overhead and the jit
amortization are both visible. Also reports the caps a match-size
estimate would pick (how ``EngineCaps`` are sized in practice).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import build_np_storage, symmetry_break
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats, match_size_estimate
from repro.core.join_tree import minimum_unit_decomposition
from repro.core.listing import list_unit_compressed
from repro.core.pattern import PATTERN_LIBRARY
from repro.dist import jax_engine as je

from .common import Row, bench_graphs, timeit

def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _cap_models(part):
    """Storage caps fit the partition; match caps are the swept variable."""
    import numpy as np

    v_cap = _pow2(part.vertices.shape[0])
    deg_cap = _pow2(int(np.diff(part.indptr).max(initial=1)))
    e_cap = _pow2(part.codes.shape[0])
    mk = dict(v_cap=v_cap, deg_cap=deg_cap, e_cap=e_cap, set_cap=64, pair_cap=64)
    return {
        "small": je.EngineCaps(match_cap=4096, group_cap=2048, **mk),
        "large": je.EngineCaps(match_cap=16384, group_cap=8192, **mk),
    }


def run() -> list:
    rows = []
    # WT~ has the mildest degree tail of the stand-in datasets, which
    # keeps deg_cap (and the [match_cap × deg_cap] expansion frontier)
    # CPU-benchable; the caps sweep is the point here, not graph scale.
    g = bench_graphs()["WT~"]
    stats = GraphStats.of(g)
    storage = build_np_storage(g, 8)
    part = storage.parts[0]
    cap_models = _cap_models(part)
    for pname, pattern in sorted(PATTERN_LIBRARY.items()):
        ord_ = symmetry_break(pattern)
        cover = choose_cover(pattern, ord_, stats)
        unit = max(minimum_unit_decomposition(pattern, cover),
                   key=lambda u: u.pattern.m)
        est = match_size_estimate(unit.pattern, ord_, stats)

        t_host = timeit(lambda: list_unit_compressed(part, unit, cover, ord_))
        rows.append(Row(f"dist_engine/host/{pname}", t_host * 1e6,
                        f"est_matches={est:.0f}"))

        plan = je.build_unit_plan(unit.pattern, unit.anchor_in(cover), ord_)
        for cname, caps in cap_models.items():
            pt = je.pad_partition(part, caps)

            @jax.jit
            def step(p):
                tbl, valid, o1 = je.unit_list(p, plan, caps)
                tc, _, o2 = je.compress_plain(tbl, valid, plan.cols, cover, caps)
                return tc, o1 + o2

            (tc, ovf) = step(pt)  # compile + correctness probe
            jax.block_until_ready(tc.skeleton)
            t_jax = timeit(lambda: jax.block_until_ready(step(pt)[0].skeleton))
            rows.append(Row(
                f"dist_engine/jax_{cname}/{pname}", t_jax * 1e6,
                f"overflow={int(ovf)};match_cap={caps.match_cap};"
                f"host_ratio={t_jax / max(t_host, 1e-9):.2f}x",
            ))
    return rows
