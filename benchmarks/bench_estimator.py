"""§IV-D estimator accuracy: predicted vs actual match counts."""

from __future__ import annotations

from repro.core import DDSL
from repro.core.estimator import GraphStats, match_size_estimate
from repro.core.pattern import PATTERN_LIBRARY, symmetry_break

from .common import Row, bench_graphs, timeit


def run() -> list:
    rows = []
    g = bench_graphs()["WG~"]
    stats = GraphStats.of(g)
    for pname, pattern in sorted(PATTERN_LIBRARY.items()):
        ord_ = symmetry_break(pattern)
        t = timeit(lambda: match_size_estimate(pattern, ord_, stats), repeat=5)
        est = match_size_estimate(pattern, ord_, stats)
        eng = DDSL(g, pattern, m=4)
        eng.initial()
        actual = eng.count()
        ratio = est / actual if actual else float("nan")
        rows.append(Row(
            f"estimator/{pname}", t * 1e6,
            f"est={est:.1f};actual={actual};ratio={ratio:.2f}",
        ))
    return rows
