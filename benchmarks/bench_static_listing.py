"""Paper Fig. 7: static subgraph listing, 5 patterns × datasets.

Compares DDSL's optimal join tree against a triangle-units-only baseline
(the SEED/Crystal-style decomposition) — the paper's headline claim is
that richer R1 units avoid joins entirely for several patterns.
"""

from __future__ import annotations

from repro.core import DDSL
from repro.core.cost import CostModel
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.join_tree import optimal_join_tree
from repro.core.listing import ExecutionReport, execute_join_tree
from repro.core.pattern import PATTERN_LIBRARY, symmetry_break

from .common import Row, bench_graphs, timeit


def run() -> list:
    rows = []
    graphs = bench_graphs()
    g = graphs["WG~"]
    for pname, pattern in sorted(PATTERN_LIBRARY.items()):
        eng = DDSL(g, pattern, m=4)
        t = timeit(lambda: eng.initial(), repeat=1, warmup=0)
        rep = eng.reports[-1]
        rows.append(Row(
            f"list/{pname}/WG~", t * 1e6,
            f"matches={eng.count()};units={len(eng.tree.leaves())};"
            f"joins={rep.joins};join_cost_ints={rep.total_join_cost()}",
        ))
        # triangle-units-only baseline (k0=3 preprocessing analogue)
        ord_ = symmetry_break(pattern)
        stats = GraphStats.of(g)
        cover = choose_cover(pattern, ord_, stats)
        model = CostModel(cover, ord_, stats)
        try:
            tree3 = optimal_join_tree(pattern, cover, model, max_unit_size=3)
            rep3 = ExecutionReport()
            t3 = timeit(
                lambda: execute_join_tree(eng.state.storage, tree3, cover, ord_, rep3),
                repeat=1, warmup=0,
            )
            rows.append(Row(
                f"list_tri_units/{pname}/WG~", t3 * 1e6,
                f"joins={rep3.joins};join_cost_ints={rep3.total_join_cost()};"
                f"speedup_vs_baseline={t3 / max(t, 1e-9):.2f}x",
            ))
        except ValueError:
            rows.append(Row(f"list_tri_units/{pname}/WG~", -1, "not-decomposable"))
    return rows
