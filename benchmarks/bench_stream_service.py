"""repro.stream service throughput (journal → scheduler → shared delta).

Measures end-to-end `advance()` latency per journal operation for a
multi-pattern service, the shared-delta win (one shared Φ(d') update
per batch vs. per-engine recomputation — the pre-stream `DDSL.apply`
loop), and the device storage-update scaling law: the
candidate-restricted step (Alg. 4 C1–C3) must grow with ``|δ|`` and
stay flat as ``|E(d)|`` grows, while the full-gather oracle grows with
the graph.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import DDSL, Graph
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import rmat_graph, sample_update

from .common import Row, timeit

PATTERNS = ("q2_triangle", "q1_square")


def _drive_service(graph, rounds, ops, scheduler=None):
    from repro.stream import BatchScheduler, ListingService

    svc = ListingService(
        graph, m=4, backend="host",
        scheduler=scheduler or BatchScheduler(max_ops=ops))
    for name in PATTERNS:
        svc.register(name, PATTERN_LIBRARY[name])
    t0 = time.perf_counter()
    total = 0
    for b in range(rounds):
        upd = sample_update(svc.projected_graph(), ops // 2, ops // 2, seed=7 + b)
        svc.ingest(upd)
        total += sum(bm.n_ops for bm in svc.advance())
    return time.perf_counter() - t0, total, svc


def _drive_engines(graph, rounds, ops):
    engines = {}
    for name in PATTERNS:
        eng = DDSL(graph, PATTERN_LIBRARY[name], m=4)
        eng.initial()
        engines[name] = eng
    t0 = time.perf_counter()
    for b in range(rounds):
        any_eng = next(iter(engines.values()))
        upd = sample_update(any_eng.graph, ops // 2, ops // 2, seed=7 + b)
        for eng in engines.values():
            eng.apply(upd)
    return time.perf_counter() - t0, rounds * ops


def _uniform_graph(n, m_edges, seed):
    """Uniform random graph: flat degree tail, so deg_cap (and with it
    the candidate-set bound) stays constant while |E| grows."""
    rng = np.random.default_rng(seed)
    edges = set()
    while len(edges) < m_edges:
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges), np.int64), n=n)


def _device_update_setup(graph, n_ops, mode):
    import jax
    from jax.sharding import NamedSharding

    from repro.core.storage import build_np_storage
    from repro.dist import sharded
    from repro.stream.service import _default_caps

    mesh = jax.make_mesh((1,), ("data",))
    storage = build_np_storage(graph, 1)
    caps = _default_caps(storage, graph, 1, use_pallas=False)
    specs = sharded.partition_specs(mesh)
    pt = jax.device_put(sharded.stack_partitions(storage, caps),
                        jax.tree.map(lambda s: NamedSharding(mesh, s), specs))
    ush = sharded.UpdateShapes(n_add=n_ops, n_del=n_ops)
    step = sharded.make_storage_update_step(mesh, caps, ush, mode=mode)
    return step, pt, caps


def _device_update_batch(graph, n_ops, seed):
    import jax.numpy as jnp

    upd = sample_update(graph, n_ops, n_ops, seed=seed)
    return (jnp.asarray(np.asarray(upd.add), jnp.int32),
            jnp.asarray(np.asarray(upd.delete), jnp.int32))


def _bench_device_update(rows):
    """Acceptance probe: delta-step cost tracks |δ|, not |E(d)|."""
    import jax

    # ---- |δ| sweep at a fixed graph --------------------------------
    g = _uniform_graph(512, 1536, seed=10)
    for k in (2, 8, 24):
        step, pt, caps = _device_update_setup(g, k, "delta")
        add, dele = _device_update_batch(g, k, seed=11)
        _, diag = step(pt, add, dele)          # compile + probe
        dt = timeit(lambda: jax.block_until_ready(step(pt, add, dele)[0].vertices),
                    repeat=7)
        rows.append(Row(f"stream/device_update_delta/ops{k}", dt * 1e6,
                        f"edges={g.num_edges};cand_v={int(diag['cand_vertices'])};"
                        f"cand_e={int(diag['cand_edges'])};overflow={int(diag['overflow'])}"))

    # ---- |E| sweep at fixed |δ| = 8: delta (flat) vs full (growing) --
    for n in (256, 1024, 4096):
        g = _uniform_graph(n, 3 * n, seed=12)
        for mode in ("delta", "full"):
            if mode == "full" and n > 1024:
                continue                       # oracle cost explodes with |V|
            step, pt, caps = _device_update_setup(g, 8, mode)
            add, dele = _device_update_batch(g, 8, seed=13)
            _, diag = step(pt, add, dele)
            dt = timeit(lambda: jax.block_until_ready(step(pt, add, dele)[0].vertices),
                        repeat=7)
            rows.append(Row(f"stream/device_update_{mode}/n{n}", dt * 1e6,
                            f"edges={g.num_edges};v_cap={caps.v_cap};"
                            f"overflow={int(diag['overflow'])}"))


def run():
    rows = []
    graph = rmat_graph(8, 900, seed=0)
    rounds, ops = 4, 24

    dt_svc, n_ops, svc = _drive_service(graph, rounds, ops)
    rows.append(Row("stream/service_advance", dt_svc / max(n_ops, 1) * 1e6,
                    f"ops={n_ops};batches={len(svc.metrics)};"
                    f"counts={'/'.join(str(svc.count(p)) for p in PATTERNS)}"))

    dt_eng, n_eng = _drive_engines(graph, rounds, ops)
    rows.append(Row("stream/per_engine_apply", dt_eng / max(n_eng, 1) * 1e6,
                    f"ops={n_eng};speedup_x1000={int(dt_eng / dt_svc * 1000)}"))

    # journal-only throughput: netting + replay bookkeeping
    from repro.core.graph import GraphUpdate
    from repro.stream import UpdateJournal

    j = UpdateJournal()
    edges = [(i, i + 1) for i in range(2000)]
    t0 = time.perf_counter()
    j.append(GraphUpdate.make(add=edges))
    j.append(GraphUpdate.make(delete=edges[::2]))
    net = j.window(0)
    dt = time.perf_counter() - t0
    rows.append(Row("stream/journal_net", dt / len(j) * 1e6,
                    f"entries={len(j)};net_add={net.add.shape[0]}"))

    _bench_device_update(rows)
    return rows
