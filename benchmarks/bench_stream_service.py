"""repro.stream service throughput (journal → scheduler → shared delta).

Measures end-to-end `advance()` latency per journal operation for a
multi-pattern service, and the shared-delta win: the same stream served
with one shared Φ(d') update per batch vs. per-engine recomputation
(the pre-stream `DDSL.apply` loop).
"""

from __future__ import annotations

import time

from repro.core import DDSL
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import rmat_graph, sample_update

from .common import Row

PATTERNS = ("q2_triangle", "q1_square")


def _drive_service(graph, rounds, ops, scheduler=None):
    from repro.stream import BatchScheduler, ListingService

    svc = ListingService(
        graph, m=4, backend="host",
        scheduler=scheduler or BatchScheduler(max_ops=ops))
    for name in PATTERNS:
        svc.register(name, PATTERN_LIBRARY[name])
    t0 = time.perf_counter()
    total = 0
    for b in range(rounds):
        upd = sample_update(svc.projected_graph(), ops // 2, ops // 2, seed=7 + b)
        svc.ingest(upd)
        total += sum(bm.n_ops for bm in svc.advance())
    return time.perf_counter() - t0, total, svc


def _drive_engines(graph, rounds, ops):
    engines = {}
    for name in PATTERNS:
        eng = DDSL(graph, PATTERN_LIBRARY[name], m=4)
        eng.initial()
        engines[name] = eng
    t0 = time.perf_counter()
    for b in range(rounds):
        any_eng = next(iter(engines.values()))
        upd = sample_update(any_eng.graph, ops // 2, ops // 2, seed=7 + b)
        for eng in engines.values():
            eng.apply(upd)
    return time.perf_counter() - t0, rounds * ops


def run():
    rows = []
    graph = rmat_graph(8, 900, seed=0)
    rounds, ops = 4, 24

    dt_svc, n_ops, svc = _drive_service(graph, rounds, ops)
    rows.append(Row("stream/service_advance", dt_svc / max(n_ops, 1) * 1e6,
                    f"ops={n_ops};batches={len(svc.metrics)};"
                    f"counts={'/'.join(str(svc.count(p)) for p in PATTERNS)}"))

    dt_eng, n_eng = _drive_engines(graph, rounds, ops)
    rows.append(Row("stream/per_engine_apply", dt_eng / max(n_eng, 1) * 1e6,
                    f"ops={n_eng};speedup_x1000={int(dt_eng / dt_svc * 1000)}"))

    # journal-only throughput: netting + replay bookkeeping
    from repro.core.graph import GraphUpdate
    from repro.stream import UpdateJournal

    j = UpdateJournal()
    edges = [(i, i + 1) for i in range(2000)]
    t0 = time.perf_counter()
    j.append(GraphUpdate.make(add=edges))
    j.append(GraphUpdate.make(delete=edges[::2]))
    net = j.window(0)
    dt = time.perf_counter() - t0
    rows.append(Row("stream/journal_net", dt / len(j) * 1e6,
                    f"entries={len(j)};net_add={net.add.shape[0]}"))
    return rows
