"""repro.stream service throughput (journal → scheduler → shared delta).

Measures end-to-end `advance()` latency per journal operation for a
multi-pattern service, the shared-delta win (one shared Φ(d') update
per batch vs. per-engine recomputation — the pre-stream `DDSL.apply`
loop), the delta-maintained unit-table cache win (warm patches re-list
only invalidated partitions — `stream/unit_cache_warm` must beat
`_cold` at equal ``|δ|``), the staged plan compiler and the hot plan
swap (`stream/plan_compile`, `stream/plan_swap` — a swap must beat the
naive from-scratch re-listing), the fused multi-pattern maintain
megastep (`stream/maintain_mega/*` — one dispatch sharing the storage
gather and delete table across P patterns must beat P separate
per-pattern maintain dispatches), and the device storage-update scaling
law: the candidate-restricted step (Alg. 4 C1–C3) must grow with
``|δ|`` and stay flat as ``|E(d)|`` grows, while the full-gather
oracle grows with the graph.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import DDSL, Graph
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import rmat_graph, sample_update

from .common import Row, timeit

PATTERNS = ("q2_triangle", "q1_square")


def _drive_service(graph, rounds, ops, scheduler=None, obs=None):
    from repro.stream import BatchScheduler, ListingService

    svc = ListingService(
        graph, m=4, backend="host",
        scheduler=scheduler or BatchScheduler(max_ops=ops), obs=obs)
    for name in PATTERNS:
        svc.register(name, PATTERN_LIBRARY[name])
    t0 = time.perf_counter()
    total = 0
    for b in range(rounds):
        upd = sample_update(svc.projected_graph(), ops // 2, ops // 2, seed=7 + b)
        svc.ingest(upd)
        total += sum(bm.n_ops for bm in svc.advance())
    return time.perf_counter() - t0, total, svc


def _drive_engines(graph, rounds, ops):
    engines = {}
    for name in PATTERNS:
        eng = DDSL(graph, PATTERN_LIBRARY[name], m=4)
        eng.initial()
        engines[name] = eng
    t0 = time.perf_counter()
    for b in range(rounds):
        any_eng = next(iter(engines.values()))
        upd = sample_update(any_eng.graph, ops // 2, ops // 2, seed=7 + b)
        for eng in engines.values():
            eng.apply(upd)
    return time.perf_counter() - t0, rounds * ops


def _uniform_graph(n, m_edges, seed):
    """Uniform random graph: flat degree tail, so deg_cap (and with it
    the candidate-set bound) stays constant while |E| grows."""
    rng = np.random.default_rng(seed)
    edges = set()
    while len(edges) < m_edges:
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges), np.int64), n=n)


def _device_update_setup(graph, n_ops, mode):
    import jax
    from jax.sharding import NamedSharding

    from repro.core.storage import build_np_storage
    from repro.dist import sharded
    from repro.stream.service import _default_caps

    mesh = jax.make_mesh((1,), ("data",))
    storage = build_np_storage(graph, 1)
    caps = _default_caps(storage, graph, 1, use_pallas=False)
    specs = sharded.partition_specs(mesh)
    pt = jax.device_put(sharded.stack_partitions(storage, caps),
                        jax.tree.map(lambda s: NamedSharding(mesh, s), specs))
    ush = sharded.UpdateShapes(n_add=n_ops, n_del=n_ops)
    step = sharded.make_storage_update_step(mesh, caps, ush, mode=mode)
    return step, pt, caps


def _device_update_batch(graph, n_ops, seed):
    import jax.numpy as jnp

    upd = sample_update(graph, n_ops, n_ops, seed=seed)
    return (jnp.asarray(np.asarray(upd.add), jnp.int32),
            jnp.asarray(np.asarray(upd.delete), jnp.int32))


def _bench_device_update(rows):
    """Acceptance probe: delta-step cost tracks |δ|, not |E(d)|."""
    import jax

    # ---- |δ| sweep at a fixed graph --------------------------------
    g = _uniform_graph(512, 1536, seed=10)
    for k in (2, 8, 24):
        step, pt, caps = _device_update_setup(g, k, "delta")
        add, dele = _device_update_batch(g, k, seed=11)
        _, diag = step(pt, add, dele)          # compile + probe
        dt = timeit(lambda: jax.block_until_ready(step(pt, add, dele)[0].vertices),
                    repeat=7)
        rows.append(Row(f"stream/device_update_delta/ops{k}", dt * 1e6,
                        f"edges={g.num_edges};cand_v={int(diag['cand_vertices'])};"
                        f"cand_e={int(diag['cand_edges'])};overflow={int(diag['overflow'])}"))

    # ---- |E| sweep at fixed |δ| = 8: delta (flat) vs full (growing) --
    for n in (256, 1024, 4096):
        g = _uniform_graph(n, 3 * n, seed=12)
        for mode in ("delta", "full"):
            if mode == "full" and n > 1024:
                continue                       # oracle cost explodes with |V|
            step, pt, caps = _device_update_setup(g, 8, mode)
            add, dele = _device_update_batch(g, 8, seed=13)
            _, diag = step(pt, add, dele)
            dt = timeit(lambda: jax.block_until_ready(step(pt, add, dele)[0].vertices),
                        repeat=7)
            rows.append(Row(f"stream/device_update_{mode}/n{n}", dt * 1e6,
                            f"edges={g.num_edges};v_cap={caps.v_cap};"
                            f"overflow={int(diag['overflow'])}"))


def _local_update(g, m, nops, seed):
    """A partition-local batch: every endpoint hashes to partition 0, so
    the Alg. 4 dirty set stays small — the §VI-B warm-stream regime."""
    from repro.core import GraphUpdate

    rng = np.random.default_rng(seed)
    ecur = g.edges()
    both0 = ecur[(ecur[:, 0] % m == 0) & (ecur[:, 1] % m == 0)]
    dele = both0[rng.choice(both0.shape[0],
                            size=min(nops, both0.shape[0]), replace=False)]
    existing = set(map(tuple, ecur.tolist()))
    cands = np.arange(0, g.n, m)
    add = set()
    while len(add) < nops:
        a, b = int(rng.choice(cands)), int(rng.choice(cands))
        if a != b and (min(a, b), max(a, b)) not in existing:
            add.add((min(a, b), max(a, b)))
    return GraphUpdate.make(delete=dele, add=sorted(add))


def _bench_unit_cache(rows):
    """Acceptance probe: at equal |δ|, a warm delta-maintained unit-table
    cache (re-listing only invalidated partitions) beats the cold path
    (every chain step re-lists every partition's unit table)."""
    from repro.core import PartitionUnitCache
    from repro.core.ddsl import choose_cover
    from repro.core.estimator import GraphStats
    from repro.core.join_tree import minimum_unit_decomposition
    from repro.core.navjoin import nav_join_patch
    from repro.core.pattern import symmetry_break
    from repro.core.storage import build_np_storage, update_np_storage

    m = 8
    g = _uniform_graph(1024, 6000, seed=30)
    pat = PATTERN_LIBRARY["q1_square"]
    ord_ = symmetry_break(pat)
    cover = choose_cover(pat, ord_, GraphStats.of(g))
    units = minimum_unit_decomposition(pat, cover)
    storage = build_np_storage(g, m)
    upd = _local_update(g, m, 4, seed=31)
    storage2, rep = update_np_storage(storage, upd)

    def cold():
        nav_join_patch(storage2, units, pat, cover, ord_, upd.add)

    cache = PartitionUnitCache(storage2)

    def warm():
        # steady state: each call invalidates this batch's dirty parts
        # and patches through the cache (same |δ| as the cold row)
        cache.advance(storage2, rep.dirty_parts)
        nav_join_patch(storage2, units, pat, cover, ord_, upd.add,
                       provider=cache,
                       seed_fn=cache.seed_fn(cover, ord_, upd.add_codes()))

    warm()                               # cold fill, not timed
    t_cold = timeit(cold, repeat=3)
    t_warm = timeit(warm, repeat=3)
    base = (f"units={len(units)};m={m};dirty={len(rep.dirty_parts)};"
            f"ops={upd.size}")
    rows.append(Row("stream/unit_cache_cold", t_cold * 1e6, base))
    rows.append(Row("stream/unit_cache_warm", t_warm * 1e6,
                    f"{base};speedup_x1000={int(t_cold / t_warm * 1000)}"))


def _bench_maintain(rows):
    """Acceptance probe: the fused device maintain step (patch ∘ filter
    ∘ merge ∘ count over a device-resident MatchStore) is flat in
    |matches| — its work is bound by the fixed static caps — while the
    host maintenance path (filter_deleted + merge_tables +
    count_matches over the materialized table) grows with |M|."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.core import DDSL, build_np_storage, symmetry_break
    from repro.core.cost import CostModel
    from repro.core.estimator import GraphStats
    from repro.core.incremental import filter_deleted, merge_tables
    from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
    from repro.core.navjoin import nav_join_patch
    from repro.core.storage import update_np_storage
    from repro.dist import jax_engine as je
    from repro.dist import sharded

    pat = PATTERN_LIBRARY["q2_triangle"]
    ord_ = symmetry_break(pat)
    cover = (0, 1)                         # fixed cover → one program, one compile
    units = minimum_unit_decomposition(pat, cover)
    # Caps sized once for the LARGEST match set and shared across all
    # sizes: the device step's work is a function of the caps, not of
    # |M|. |M| is scaled by density at a fixed vertex count (a uniform
    # graph with mean degree d holds ≈ d³/6 triangles).
    NV = 512
    caps = je.EngineCaps(v_cap=512, deg_cap=96, e_cap=8192, match_cap=16384,
                         group_cap=8192, set_cap=64, pair_cap=64)
    store_caps = sharded.StoreCaps(group_cap=8192, set_cap=64)
    mesh = jax.make_mesh((1,), ("data",))
    ush = sharded.UpdateShapes(n_add=8, n_del=8)

    prog = None
    list_step = sstep = mstep = init_step = None
    for n in (256, 1024, 4096):
        mean_deg = (6.0 * n) ** (1.0 / 3.0)
        g = _uniform_graph(NV, int(NV * mean_deg / 2), seed=20)
        storage = build_np_storage(g, 1)
        if prog is None:
            stats = GraphStats.of(g)
            tree = optimal_join_tree(pat, cover, CostModel(cover, ord_, stats))
            prog = sharded.build_tree_program(tree, cover, ord_)
            list_step = sharded.make_list_step(prog, mesh, caps)
            init_step = sharded.make_init_store_step(prog, mesh, caps, store_caps)
            sstep = sharded.make_storage_update_step(mesh, caps, ush)
            mstep = sharded.make_maintain_step(prog, units, mesh, caps, store_caps)
        pt = jax.device_put(
            sharded.stack_partitions(storage, caps),
            jax.tree.map(lambda s: NamedSharding(mesh, s),
                         sharded.partition_specs(mesh)))
        out, ldiag = list_step(pt)
        st, idiag = init_step(out)
        n_matches = int(idiag["count"])
        upd = sample_update(g, 8, 8, seed=21)
        add = np.full((8, 2), -1, np.int32)
        dele = np.full((8, 2), -1, np.int32)
        add[: upd.add.shape[0]] = upd.add
        dele[: upd.delete.shape[0]] = upd.delete
        aj, dj = jnp.asarray(add), jnp.asarray(dele)
        pt2, _ = sstep(pt, aj, dj)
        # probe run: the timed row must report the maintain step's OWN
        # overflow too — a lossy (truncated) flat timing would be
        # meaningless evidence.
        _, _, mdiag = mstep(pt2, st, aj, dj)
        ovf = (int(ldiag["overflow"]) + int(idiag["overflow"])
               + int(mdiag["overflow"]))

        def dev_maintain():
            st2, _, mdiag = mstep(pt2, st, aj, dj)
            jax.block_until_ready(mdiag["count"])

        dt = timeit(dev_maintain, repeat=3)
        rows.append(Row(f"stream/maintain_device/n{n}", dt * 1e6,
                        f"matches={n_matches};edges={g.num_edges};"
                        f"overflow={ovf}"))

        # host path: filter + merge + count over the materialized table
        eng = DDSL(g, pat, m=1, cover=cover)
        eng.initial()
        storage2, _ = update_np_storage(storage, upd)
        patch = nav_join_patch(storage2, units, pat, cover, ord_, upd.add)

        def host_maintain():
            kept = filter_deleted(eng.state.matches, upd.delete)
            merged = merge_tables(kept, patch)
            return merged.count_matches(ord_)

        dt = timeit(host_maintain, repeat=3)
        rows.append(Row(f"stream/maintain_host/n{n}", dt * 1e6,
                        f"matches={eng.count()};edges={g.num_edges}"))


def _bench_maintain_mega(rows):
    """Acceptance probe for the fused multi-pattern megastep: ONE jitted
    dispatch per batch maintains every registered pattern, sharing the
    partition gather and the Lemma-6.1 delete table. The triangle-clone
    workload matches the ``stream/maintain_device`` rows exactly, so the
    hard gate reads the checked-in baseline (recorded on the pre-fusion
    per-pattern path) and requires the fused 3-pattern batch at n4096 to
    come in at <= 0.5x the summed per-pattern baseline. Baselines are
    same-machine recordings (the harness's ``compare_baseline`` already
    leans on rough machine comparability); at the recorded ~0.3x there
    is wide margin before a slower runner could false-fail the gate."""
    import json
    import os
    import sys

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.core import build_np_storage, symmetry_break
    from repro.core.cost import CostModel
    from repro.core.estimator import GraphStats
    from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
    from repro.dist import jax_engine as je
    from repro.dist import sharded

    NV = 512
    caps = je.EngineCaps(v_cap=512, deg_cap=96, e_cap=8192, match_cap=16384,
                         group_cap=8192, set_cap=64, pair_cap=64)
    store_caps = sharded.StoreCaps(group_cap=8192, set_cap=64)
    mesh = jax.make_mesh((1,), ("data",))
    ush = sharded.UpdateShapes(n_add=8, n_del=8)
    # Clones of the maintain_device triangle workload under distinct
    # registration names (the megastep is keyed by name, exactly like
    # the service registry): P patterns = P full maintain pipelines in
    # one dispatch, directly comparable to P separate baseline rows.
    PSETS = {
        1: ("q2_triangle",),
        3: ("q2_triangle", "q2_triangle:b", "q2_triangle:c"),
        6: ("q2_triangle", "q2_triangle:b", "q2_triangle:c",
            "q2_triangle:d", "q2_triangle:e", "q2_triangle:f"),
    }

    pat = PATTERN_LIBRARY["q2_triangle"]
    ord_ = symmetry_break(pat)
    cover = (0, 1)                  # same fixed cover as _bench_maintain
    units = minimum_unit_decomposition(pat, cover)

    def ladder_graph(n):
        mean_deg = (6.0 * n) ** (1.0 / 3.0)
        return _uniform_graph(NV, int(NV * mean_deg / 2), seed=20)

    stats = GraphStats.of(ladder_graph(256))
    tree = optimal_join_tree(pat, cover, CostModel(cover, ord_, stats))
    prog = sharded.build_tree_program(tree, cover, ord_)
    ucaps = sharded.unit_table_caps(units, cover, ord_, stats, caps)
    list_step = sharded.make_list_step(prog, mesh, caps)
    init_step = sharded.make_init_store_step(prog, mesh, caps, store_caps)
    refresh_step = sharded.make_unit_refresh_step(prog, units, mesh, caps,
                                                  ucaps)
    sstep = sharded.make_storage_update_step(mesh, caps, ush)
    # the pre-fusion backend dispatch: one carry-threaded maintain step
    # per pattern (all clones share one compilation)
    sep_step = sharded.make_maintain_step(prog, units, mesh, caps,
                                          store_caps, unit_caps=ucaps)

    def make_mega(names):
        specs = [sharded.MaintainSpec(name=nm, prog=prog,
                                      units=tuple(units), store=store_caps,
                                      unit_caps=ucaps) for nm in names]
        # donate=False: the timed closure calls the step repeatedly on
        # the same buffers (production donates; CPU donation is a no-op
        # anyway, but the bench must stay valid on donating backends)
        return sharded.make_maintain_mega_step(specs, mesh, caps,
                                               donate=False)

    def state_for(g, names):
        storage = build_np_storage(g, 1)
        pt = jax.device_put(
            sharded.stack_partitions(storage, caps),
            jax.tree.map(lambda s: NamedSharding(mesh, s),
                         sharded.partition_specs(mesh)))
        out, _ = list_step(pt)
        st, idiag = init_step(out)
        assert int(idiag["overflow"]) == 0
        carry, _ = refresh_step(pt)
        upd = sample_update(g, 8, 8, seed=21)
        add = np.full((8, 2), -1, np.int32)
        dele = np.full((8, 2), -1, np.int32)
        add[: upd.add.shape[0]] = upd.add
        dele[: upd.delete.shape[0]] = upd.delete
        aj, dj = jnp.asarray(add), jnp.asarray(dele)
        pt2, sdiag = sstep(pt, aj, dj)
        stores = {nm: st for nm in names}
        carries = {nm: carry for nm in names}
        return pt2, stores, carries, sdiag["part_dirty"], aj, dj

    base_us = {}
    base_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baselines", "BENCH_stream_service.json")
    if os.path.exists(base_path):
        with open(base_path) as f:
            base_us = {r["name"]: float(r["us_per_call"])
                       for r in json.load(f).get("rows", [])}

    mega3 = make_mega(PSETS[3])

    # ---- density ladder at 3 patterns ------------------------------
    for n in (256, 1024, 4096):
        g = ladder_graph(n)
        pt2, stores, carries, dirty, aj, dj = state_for(g, PSETS[3])

        def fused():
            out = mega3(pt2, stores, carries, dirty, aj, dj)
            jax.block_until_ready(out[3])
            return out

        def separate():
            for nm in PSETS[3]:
                out = sep_step(pt2, stores[nm], carries[nm], dirty, aj, dj)
            jax.block_until_ready(out[3])

        _, _, _, mdiag = fused()           # probe: fused must be lossless
        ovf = sum(int(mdiag[nm]["overflow"]) + int(mdiag[nm]["store_overflow"])
                  for nm in mdiag)
        t_mega = timeit(fused, repeat=3)
        t_sep = timeit(separate, repeat=3)
        base_sum = 3.0 * base_us.get(f"stream/maintain_device/n{n}", 0.0)
        extra = (f";base_sum_us={int(base_sum)};"
                 f"vs_base_x1000={int(t_mega * 1e6 / base_sum * 1000)}"
                 if base_sum else "")
        rows.append(Row(f"stream/maintain_mega/n{n}", t_mega * 1e6,
                        f"patterns=3;edges={g.num_edges};overflow={ovf};"
                        f"sep_us={int(t_sep * 1e6)}" + extra))
        if n == 4096:
            if not base_sum:
                print("# maintain_mega: no maintain_device/n4096 baseline; "
                      "0.5x gate skipped", file=sys.stderr)
            elif t_mega * 1e6 > 0.5 * base_sum:
                raise RuntimeError(
                    f"megastep acceptance failed: fused {t_mega * 1e6:.0f}us "
                    f"> 0.5 x summed per-pattern baseline {base_sum:.0f}us "
                    "at n4096/p3")

    # ---- pattern-count scaling at the n1024 density ----------------
    g = ladder_graph(1024)
    for p, names in sorted(PSETS.items()):
        mega = mega3 if p == 3 else make_mega(names)
        pt2, stores, carries, dirty, aj, dj = state_for(g, names)

        def fused_p():
            out = mega(pt2, stores, carries, dirty, aj, dj)
            jax.block_until_ready(out[3])

        fused_p()
        t = timeit(fused_p, repeat=3)
        rows.append(Row(f"stream/maintain_mega_p{p}", t * 1e6,
                        f"patterns={p};edges={g.num_edges};"
                        f"us_per_pattern={int(t * 1e6 / p)}"))


def _bench_planner(rows):
    """Acceptance probe: a hot plan swap (regroup the running table under
    the new cover + install, no re-listing) must beat the naive re-plan
    (from-scratch ``DDSL.initial()``) — that gap is what makes online
    re-optimization affordable at a watermark."""
    from repro.core.estimator import GraphStats
    from repro.planner import CompileContext, candidate_covers, compile_plan
    from repro.stream import ListingService, PlanManager
    from repro.stream.plan_manager import SwapEvent

    g = rmat_graph(8, 900, seed=0)
    stats = GraphStats.of(g)
    pat = PATTERN_LIBRARY["q1_square"]

    dt = timeit(lambda: compile_plan(
        CompileContext(pattern=pat, stats=stats, m=4)), repeat=5)
    dt_search = timeit(lambda: compile_plan(
        CompileContext(pattern=pat, stats=stats, m=4, cover_objective="cost")),
        repeat=5)
    rows.append(Row("stream/plan_compile", dt * 1e6,
                    f"covers={len(candidate_covers(pat))};"
                    f"cost_search_us={int(dt_search * 1e6)}"))

    svc = ListingService(g, m=4, backend="host")
    svc.register("sq", pat)
    pm = PlanManager()
    # Two pinned-cover plans; alternating between them makes every timed
    # call exercise the full protocol including the VCBC regroup.
    plans = [svc.backend.compile(pat, cover=c) for c in ((0, 1, 3), (0, 1, 2, 3))]
    state = {"i": 0}

    def swap_once():
        cand = plans[state["i"] % 2]
        state["i"] += 1
        inc = svc.backend.plan("sq")
        if cand.cover == inc.cover:      # only possibly on the first call
            return
        ev = SwapEvent(batch_index=0, pattern="sq", trigger="bench",
                       drift=None, incumbent_cost=inc.cost,
                       candidate_cost=cand.cost, swapped=True)
        pm._swap(svc, "sq", inc, cand, ev)

    def from_scratch():
        eng = DDSL(svc.graph, pat, m=4)
        eng.initial()

    swap_once()                          # ensure a real swap per timed call
    t_swap = timeit(swap_once, repeat=3)
    t_scratch = timeit(from_scratch, repeat=3)
    rows.append(Row("stream/plan_swap", t_swap * 1e6,
                    f"count={svc.count('sq')};relist_us={int(t_scratch * 1e6)};"
                    f"speedup_x1000={int(t_scratch / t_swap * 1000)}"))


def _bench_obs_overhead(rows):
    """Acceptance probe: full observability (metrics registry + span
    tracer + step profiling) must stay within a few percent of the
    all-off configuration on the host streaming path — the instruments
    ride the per-batch boundary, never the per-match inner loops."""
    from repro.obs import Observability

    graph = rmat_graph(8, 900, seed=0)
    rounds, ops = 4, 24
    _drive_service(graph, 1, ops, obs=Observability.disabled())  # warm, untimed
    dt_off, n_off, _ = _drive_service(graph, rounds, ops,
                                      obs=Observability.disabled())
    dt_on, n_on, svc_on = _drive_service(graph, rounds, ops,
                                         obs=Observability.full())
    rows.append(Row("stream/obs_overhead_off", dt_off / max(n_off, 1) * 1e6,
                    f"ops={n_off};batches={len(svc_on.metrics)}"))
    n_spans = sum(1 for r in svc_on.obs.tracer.roots for _ in r.walk())
    rows.append(Row("stream/obs_overhead_on", dt_on / max(n_on, 1) * 1e6,
                    f"ops={n_on};spans={n_spans};"
                    f"overhead_pct_x100={int((dt_on / max(dt_off, 1e-12) - 1) * 10000)}"))


def run():
    rows = []
    graph = rmat_graph(8, 900, seed=0)
    rounds, ops = 4, 24

    dt_svc, n_ops, svc = _drive_service(graph, rounds, ops)
    rows.append(Row("stream/service_advance", dt_svc / max(n_ops, 1) * 1e6,
                    f"ops={n_ops};batches={len(svc.metrics)};"
                    f"counts={'/'.join(str(svc.count(p)) for p in PATTERNS)}"))

    dt_eng, n_eng = _drive_engines(graph, rounds, ops)
    rows.append(Row("stream/per_engine_apply", dt_eng / max(n_eng, 1) * 1e6,
                    f"ops={n_eng};speedup_x1000={int(dt_eng / dt_svc * 1000)}"))

    # journal-only throughput: netting + replay bookkeeping
    from repro.core.graph import GraphUpdate
    from repro.stream import UpdateJournal

    j = UpdateJournal()
    edges = [(i, i + 1) for i in range(2000)]
    t0 = time.perf_counter()
    j.append(GraphUpdate.make(add=edges))
    j.append(GraphUpdate.make(delete=edges[::2]))
    net = j.window(0)
    dt = time.perf_counter() - t0
    rows.append(Row("stream/journal_net", dt / len(j) * 1e6,
                    f"entries={len(j)};net_add={net.add.shape[0]}"))

    _bench_planner(rows)
    _bench_obs_overhead(rows)
    _bench_unit_cache(rows)
    _bench_device_update(rows)
    _bench_maintain(rows)
    _bench_maintain_mega(rows)
    return rows
