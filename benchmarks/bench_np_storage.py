"""Paper Fig. 6: NP-storage construction cost (a) and space cost (b)."""

from __future__ import annotations

from repro.core.storage import build_np_storage

from .common import Row, bench_graphs, timeit


def run() -> list:
    rows = []
    for name, g in bench_graphs().items():
        for m in (4, 16):
            t = timeit(lambda: build_np_storage(g, m), repeat=1, warmup=0)
            storage = build_np_storage(g, m)
            rep = storage.space_report()
            overhead = rep["stored_edges"] / max(rep["edges"], 1)
            rows.append(Row(
                f"np_build/{name}/m{m}", t * 1e6,
                f"edges={rep['edges']};stored={rep['stored_edges']};"
                f"overhead_x={overhead:.2f};bound={rep['bound']};"
                f"within_bound={rep['stored_edges'] <= rep['bound']}",
            ))
    return rows
