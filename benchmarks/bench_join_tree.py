"""§V: join-tree DP — cost-model fidelity + planner runtime."""

from __future__ import annotations

from repro.core.cost import CostModel
from repro.core.ddsl import choose_cover
from repro.core.estimator import GraphStats
from repro.core.join_tree import optimal_join_tree
from repro.core.pattern import PATTERN_LIBRARY, symmetry_break

from .common import Row, bench_graphs, timeit


def run() -> list:
    rows = []
    g = bench_graphs()["WG~"]
    stats = GraphStats.of(g)
    for pname, pattern in sorted(PATTERN_LIBRARY.items()):
        ord_ = symmetry_break(pattern)
        cover = choose_cover(pattern, ord_, stats)
        model = CostModel(cover, ord_, stats)
        t = timeit(lambda: optimal_join_tree(pattern, cover, model), repeat=3)
        tree = optimal_join_tree(pattern, cover, model)
        rows.append(Row(
            f"join_tree/{pname}", t * 1e6,
            f"units={len(tree.leaves())};depth={tree.depth()};est_cost={tree.cost:.3g}",
        ))
    return rows
