"""Paper Fig. 8a: NP-storage update time vs batch size (10²..10⁴)."""

from __future__ import annotations

from repro.core.storage import build_np_storage, update_np_storage
from repro.data.graphs import sample_update

from .common import Row, bench_graphs, timeit


def run() -> list:
    rows = []
    graphs = bench_graphs()
    for name in ("WG~", "LJ~"):
        g = graphs[name]
        storage = build_np_storage(g, 4)
        build_t = timeit(lambda: build_np_storage(g, 4), repeat=1, warmup=0)
        for b in (100, 1000, 10000):
            if b // 2 > g.num_edges // 2:
                continue
            u = sample_update(g, b // 2, b // 2, seed=b)
            t = timeit(lambda: update_np_storage(storage, u), repeat=1, warmup=0)
            rows.append(Row(
                f"np_update/{name}/b{b}", t * 1e6,
                f"vs_build={t / max(build_t, 1e-9):.3f}x;"
                f"shuffled_ints={update_np_storage(storage, u)[1].shuffled_neighbor_ints}",
            ))
    return rows
