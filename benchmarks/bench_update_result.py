"""Paper Fig. 8b–e: result-update time vs batch size, vs from-scratch."""

from __future__ import annotations

from repro.core import DDSL
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import sample_update

from .common import Row, bench_graphs, timeit


def run() -> list:
    rows = []
    g = bench_graphs()["WG~"]
    for pname in ("q1_square", "q2_triangle", "q3_diamond", "q5_house"):
        pattern = PATTERN_LIBRARY[pname]
        eng = DDSL(g, pattern, m=4)
        scratch_t = timeit(lambda: eng.initial(), repeat=1, warmup=0)
        for b in (100, 1000):
            eng2 = DDSL(g, pattern, m=4)
            eng2.initial()
            u = sample_update(eng2.graph, b // 2, b // 2, seed=b)
            t = timeit(lambda: eng2.apply(u), repeat=1, warmup=0)
            rep = eng2.reports[-1]
            rows.append(Row(
                f"update_result/{pname}/b{b}", t * 1e6,
                f"vs_scratch={t / max(scratch_t, 1e-9):.3f}x;"
                f"patch={rep.nav.patch_matches};shipped_ints={rep.nav.shipped_ints}",
            ))
    return rows
