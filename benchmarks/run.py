"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus derived metrics per row)
and writes one machine-readable ``BENCH_<module>.json`` per module run
into ``--json-dir`` (default ``bench_artifacts/``, gitignored; disable
with ``--json-dir ''``), so CI can archive per-benchmark timings and
the perf trajectory is tracked, not eyeballed — and a rerun never
litters the repo root with artifacts.

``--check-baseline`` additionally compares every fresh row against the
checked-in baseline under ``--baseline-dir`` (default
``benchmarks/baselines/``): a row slower than ``tolerance × baseline +
abs-slack`` fails the run (exit 1) and the per-row diff lands in
``BENCH_baseline_diff_<module>.json`` next to the timings — the CI
stream-smoke job runs this and archives the diff. Regenerate a baseline
by copying a trusted ``BENCH_<module>.json`` into the baseline dir.

    PYTHONPATH=src python -m benchmarks.run [--only np_storage,...]
                                           [--json-dir DIR]
                                           [--check-baseline]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .common import compare_baseline, emit, emit_json

MODULES = [
    "bench_np_storage",      # Fig. 6a/6b
    "bench_static_listing",  # Fig. 7
    "bench_update_storage",  # Fig. 8a
    "bench_update_result",   # Fig. 8b–e
    "bench_estimator",       # §IV-D
    "bench_join_tree",       # §V
    "bench_kernels",         # kernels micro
    "bench_dist_engine",     # host vs static-shape JAX engine
    "bench_stream_service",  # repro.stream service throughput
    "bench_wcoj",            # WCOJ executor vs join trees on K4/K5
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module suffixes")
    ap.add_argument("--json-dir", default="bench_artifacts",
                    help="directory for BENCH_<module>.json timing and "
                         "baseline-diff artifacts ('' disables)")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail on rows regressing past the tolerance band "
                         "vs the checked-in baseline")
    ap.add_argument("--baseline-dir", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "baselines"),
        help="directory holding baseline BENCH_<module>.json files")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="multiplicative regression band (fail above tol×base)")
    ap.add_argument("--abs-slack-us", type=float, default=500.0,
                    help="absolute slack added to the band (noise floor)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)
    rows = []
    failures = []
    for mod in MODULES:
        if only and mod.removeprefix("bench_") not in only and mod not in only:
            continue
        print(f"# running {mod} ...", file=sys.stderr, flush=True)
        m = __import__(f"benchmarks.{mod}", fromlist=["run"])
        mod_rows = m.run()
        rows.extend(mod_rows)
        suffix = mod.removeprefix("bench_")
        if args.json_dir:
            path = os.path.join(args.json_dir, f"BENCH_{suffix}.json")
            emit_json(path, suffix, mod_rows)
            print(f"# wrote {path}", file=sys.stderr, flush=True)
        if args.check_baseline:
            base_path = os.path.join(args.baseline_dir, f"BENCH_{suffix}.json")
            if not os.path.exists(base_path):
                print(f"# no baseline for {suffix} ({base_path}); skipping check",
                      file=sys.stderr, flush=True)
                continue
            with open(base_path) as f:
                baseline = json.load(f)
            regressions, missing, diff = compare_baseline(
                mod_rows, baseline, tolerance=args.tolerance,
                abs_slack_us=args.abs_slack_us)
            if args.json_dir:
                dpath = os.path.join(args.json_dir,
                                     f"BENCH_baseline_diff_{suffix}.json")
                with open(dpath, "w") as f:
                    json.dump(diff, f, indent=2, sort_keys=True)
                    f.write("\n")
                print(f"# wrote {dpath}", file=sys.stderr, flush=True)
            for name in missing:
                print(f"# WARNING {suffix}: baseline row {name!r} missing from "
                      "fresh run", file=sys.stderr, flush=True)
            for name in regressions:
                failures.append(f"{suffix}:{name}")
                print(f"# REGRESSION {suffix}: {name} exceeded "
                      f"{args.tolerance}x baseline (+{args.abs_slack_us}us)",
                      file=sys.stderr, flush=True)
    emit(rows)
    if failures:
        print(f"# {len(failures)} benchmark regression(s): "
              + ", ".join(failures), file=sys.stderr, flush=True)
        sys.exit(1)


if __name__ == '__main__':
    main()
