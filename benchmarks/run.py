"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus derived metrics per row).
    PYTHONPATH=src python -m benchmarks.run [--only np_storage,...]
"""

from __future__ import annotations

import argparse
import sys

from .common import emit

MODULES = [
    "bench_np_storage",      # Fig. 6a/6b
    "bench_static_listing",  # Fig. 7
    "bench_update_storage",  # Fig. 8a
    "bench_update_result",   # Fig. 8b–e
    "bench_estimator",       # §IV-D
    "bench_join_tree",       # §V
    "bench_kernels",         # kernels micro
    "bench_dist_engine",     # host vs static-shape JAX engine
    "bench_stream_service",  # repro.stream service throughput
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module suffixes")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    rows = []
    for mod in MODULES:
        if only and mod.removeprefix("bench_") not in only and mod not in only:
            continue
        print(f"# running {mod} ...", file=sys.stderr, flush=True)
        m = __import__(f"benchmarks.{mod}", fromlist=["run"])
        rows.extend(m.run())
    emit(rows)


if __name__ == '__main__':
    main()
