"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus derived metrics per row)
and writes one machine-readable ``BENCH_<module>.json`` per module run
(disable with ``--json-dir ''``), so CI can archive per-benchmark
timings and the perf trajectory is tracked, not eyeballed.

    PYTHONPATH=src python -m benchmarks.run [--only np_storage,...]
                                           [--json-dir DIR]
"""

from __future__ import annotations

import argparse
import os
import sys

from .common import emit, emit_json

MODULES = [
    "bench_np_storage",      # Fig. 6a/6b
    "bench_static_listing",  # Fig. 7
    "bench_update_storage",  # Fig. 8a
    "bench_update_result",   # Fig. 8b–e
    "bench_estimator",       # §IV-D
    "bench_join_tree",       # §V
    "bench_kernels",         # kernels micro
    "bench_dist_engine",     # host vs static-shape JAX engine
    "bench_stream_service",  # repro.stream service throughput
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module suffixes")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<module>.json artifacts ('' disables)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    rows = []
    for mod in MODULES:
        if only and mod.removeprefix("bench_") not in only and mod not in only:
            continue
        print(f"# running {mod} ...", file=sys.stderr, flush=True)
        m = __import__(f"benchmarks.{mod}", fromlist=["run"])
        mod_rows = m.run()
        rows.extend(mod_rows)
        if args.json_dir:
            suffix = mod.removeprefix("bench_")
            path = os.path.join(args.json_dir, f"BENCH_{suffix}.json")
            emit_json(path, suffix, mod_rows)
            print(f"# wrote {path}", file=sys.stderr, flush=True)
    emit(rows)


if __name__ == '__main__':
    main()
