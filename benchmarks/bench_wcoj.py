"""WCOJ executor vs binary join trees on dense patterns (K4/K5).

The paper's §V join trees blow up on cliques: intermediate match
tables grow super-linearly even when the final result is small, and
the padded device engine pays for that as match-cap-sized tensors.
The generic-join executor bounds every level by the observed prefix
sizes instead. Two row families over one planted near-clique graph
(n=4096 uniform background + a dense ER core — the regime where
Eq. 11's degree-moment estimates break and worst-case-optimality
matters):

- ``static/wcoj_vs_tree{,_k4}``: steady-state device listing
  (list + init_store execute, compile excluded) under each executor,
  both lossless — the tree side's caps are escalated in-run until its
  own overflow counters read zero, so the timing is never of a lossy
  configuration. **Hard gate**: WCOJ must beat the tree executor ≥2×
  on K5 (the ISSUE-10 acceptance bar).
- ``stream/wcoj_{k4,k5}``: per-batch ``advance()`` latency of the
  sharded streaming service maintaining the clique under
  ``executor="wcoj"`` — asserts zero cap overflow and zero store
  resizes across the run (AGM-bounded device memory, no resize loop).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Graph
from repro.core.pattern import PATTERN_LIBRARY
from repro.data.graphs import sample_update

from .common import Row, timeit

#: planted near-clique benchmark graph: flat-tail uniform background
#: (keeps deg_cap device-benchable) + a dense ER core that holds the
#: cliques. K4/K5 counts are in the thousands while background noise
#: contributes almost none.
N, M_BG, CORE_K, CORE_P = 4096, 12000, 32, 0.8


def planted_graph(seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    edges = set()
    while len(edges) < M_BG:
        a, b = int(rng.integers(N)), int(rng.integers(N))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    core = rng.choice(N, size=CORE_K, replace=False)
    for i in range(CORE_K):
        for j in range(i + 1, CORE_K):
            if rng.random() < CORE_P:
                a, b = int(core[i]), int(core[j])
                edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(np.array(sorted(edges), np.int64), n=N)


def _bench_static(rows):
    """Lossless steady-state listing, tree vs WCOJ, on one device."""
    import jax
    from jax.sharding import NamedSharding

    from repro.core.estimator import GraphStats
    from repro.core.match_engine import wcoj_level_counts
    from repro.core.storage import build_np_storage
    from repro.dist import jax_engine as je
    from repro.dist import sharded
    from repro.planner import CompileContext, compile_plan
    from repro.planner.sizing import quantize_store_caps
    from repro.stream.service import _default_caps

    g = planted_graph()
    storage = build_np_storage(g, 1)
    base = _default_caps(storage, g, 1, use_pallas=False)
    stats = GraphStats.of(g)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), sharded.partition_specs(mesh))

    def ctx(pattern, executor):
        return CompileContext(pattern=pattern, stats=stats, m=1, caps=base,
                              executor=executor)

    def pow2(x: int) -> int:
        n = 64
        while n < x:
            n *= 2
        return n

    def tree_time(pattern):
        """Escalate match/group/store caps until the tree listing is
        lossless, then time execute-only. The escalation itself is the
        story: the tree executor's intermediates outgrow any
        output-sized cap model on a dense core."""
        plan = compile_plan(ctx(pattern, "tree"))
        mc, store_g = 8192, plan.store_caps.group_cap
        while True:
            assert mc <= (1 << 22), "tree caps escalated past 4M rows"
            caps = je.EngineCaps(
                v_cap=base.v_cap, deg_cap=base.deg_cap, e_cap=base.e_cap,
                match_cap=mc, group_cap=max(base.group_cap, mc),
                set_cap=64, pair_cap=128)
            pt = jax.device_put(sharded.stack_partitions(storage, caps),
                                shardings)
            lstep = sharded.make_list_step(plan.program, mesh, caps)
            out, ldiag = lstep(pt)
            if int(ldiag["overflow"]):
                mc *= 4
                continue
            scaps = quantize_store_caps(sharded.StoreCaps(
                group_cap=store_g, set_cap=plan.store_caps.set_cap))
            istep = sharded.make_init_store_step(plan.program, mesh, caps,
                                                 scaps)
            _, idiag = istep(out)
            if int(idiag["overflow"]):
                store_g *= 2
                continue
            count = int(idiag["count"])

            def full():
                o, _ = lstep(pt)
                _, d = istep(o)
                jax.block_until_ready(d["count"])

            return timeit(full, repeat=3), count, mc, scaps.group_cap

    def wcoj_time(pattern):
        """Same protocol as ``ShardedBackend._register_wcoj``: a host
        calibration probe sizes every level from the observed prefix
        counts × level_headroom (1.5, transient tensors) and the store
        from × store_headroom (4.0, persistent state); no escalation
        loop needed."""
        plan = compile_plan(ctx(pattern, "wcoj"))
        observed = [wcoj_level_counts(part, plan.wcoj, anchor_to_centers=True)
                    for part in storage.parts]
        peaks = [max((o[i] for o in observed), default=0)
                 for i in range(len(plan.wcoj_level_caps))]
        lvl = tuple(pow2(int(1.5 * p)) for p in peaks)
        pt = jax.device_put(sharded.stack_partitions(storage, base), shardings)
        lstep = sharded.make_wcoj_list_step(pattern, plan.wcoj, mesh, base,
                                            lvl)
        scaps = quantize_store_caps(sharded.StoreCaps(
            group_cap=max(plan.store_caps.group_cap, pow2(int(4.0 * peaks[-1]))),
            set_cap=plan.store_caps.set_cap))
        istep = sharded.make_wcoj_init_store_step(pattern, plan.ord, mesh,
                                                  base, scaps, lvl)
        out, ldiag = lstep(pt)
        _, idiag = istep(out)
        ovf = int(ldiag["overflow"]) + int(idiag["overflow"])
        assert not ovf, f"calibrated WCOJ caps overflowed ({ovf})"
        count = int(idiag["count"])

        def full():
            o, _ = lstep(pt)
            _, d = istep(o)
            jax.block_until_ready(d["count"])

        return timeit(full, repeat=3), count, lvl, scaps.group_cap

    for pname, suffix, gate in (("q6_clique5", "", True),
                                ("q4_clique4", "_k4", False)):
        pattern = PATTERN_LIBRARY[pname]
        t_tree, n_tree, mc, sg = tree_time(pattern)
        t_wcoj, n_wcoj, lvl, wsg = wcoj_time(pattern)
        assert n_tree == n_wcoj, (pname, n_tree, n_wcoj)
        ratio = t_tree / t_wcoj
        rows.append(Row(
            f"static/wcoj_vs_tree{suffix}", t_wcoj * 1e6,
            f"count={n_wcoj};tree_us={int(t_tree * 1e6)};"
            f"speedup_x1000={int(ratio * 1000)};tree_match_cap={mc};"
            f"tree_store_g={sg};wcoj_caps={'/'.join(map(str, lvl))};"
            f"wcoj_store_g={wsg}"))
        if gate and ratio < 2.0:
            raise RuntimeError(
                f"WCOJ acceptance failed on {pname}: wcoj "
                f"{t_wcoj * 1e6:.0f}us vs tree {t_tree * 1e6:.0f}us — "
                f"{ratio:.2f}x < the required 2x")


def _bench_stream(rows):
    """Incremental maintenance under executor='wcoj': delta-seeded
    generic-join patches through the fused megastep. Hard-asserts that
    the n=4096 run never overflows a cap or enters the store-resize
    loop — the calibrated level caps ARE the memory bound."""
    from repro.stream import BatchScheduler, ListingService

    for pname, rname in (("q4_clique4", "stream/wcoj_k4"),
                         ("q6_clique5", "stream/wcoj_k5")):
        g = planted_graph()
        svc = ListingService(
            g, backend="sharded", max_add=16, max_del=16, executor="wcoj",
            audit_every=0, scheduler=BatchScheduler(max_ops=32))
        n0 = svc.register(pname, PATTERN_LIBRARY[pname])
        entry = svc.backend.entries[pname]
        overflow = 0
        lat = []
        for b in range(4):
            upd = sample_update(svc.projected_graph(), 8, 8, seed=100 + b)
            svc.ingest(upd)
            t0 = time.perf_counter()
            batches = svc.advance()
            dt = time.perf_counter() - t0
            overflow += sum(bm.overflow for bm in batches)
            if b > 0:                    # batch 0 pays the megastep compile
                lat.append(dt / max(len(batches), 1))
        assert overflow == 0, f"{pname}: device cap overflow ({overflow})"
        assert svc.backend.store_resizes == 0, \
            f"{pname}: store resize loop ({svc.backend.store_resizes})"
        rows.append(Row(
            rname, float(np.mean(lat)) * 1e6,
            f"count0={n0};count={svc.count(pname)};overflow=0;"
            f"store_resizes=0;level_caps="
            f"{'/'.join(map(str, entry.wcoj_level_caps))};"
            f"store_g={entry.store_caps.group_cap}"))


def run() -> list:
    rows = []
    _bench_static(rows)
    _bench_stream(rows)
    return rows
