"""Staged plan compiler: (pattern, live stats, machine shape) → CompiledPlan.

Plan construction used to be scattered — cover selection in
``core/ddsl.py``, two divergent inline ``optimal_join_tree`` blocks in
``stream/service.py`` (register vs restore), cap sizing in
``dist/sharded.py``.  :func:`compile_plan` is now the single entry point
all three consumers (``DDSL``, ``HostBackend``, ``ShardedBackend``) go
through: an explicit pipeline of inspectable passes over a
:class:`CompileContext` (the architecture description — live
:class:`~repro.core.estimator.GraphStats`, mesh width ``m``, engine
caps), each pass recorded as a :class:`PassReport` in the resulting
immutable :class:`CompiledPlan`::

    symmetry   SimB total order (ord)
    cover      optimal connected compression (§IV-F, R_lower argmax)
    decompose  minimum Nav-join unit decomposition (§VI-B)
    tree       optimal join tree DP (Alg. 3, Eq. 10/11 cost)
    lower      UnitPlan/JoinPlan IR (TreeProgram)
    size       match_caps / unit_table_caps from the §IV-D estimators
    shard      full-skeleton owner-hash placement descriptor

Because every pass is a pure function of the context, compiling twice
from the same stats is deterministic — registration and restore can
never pick different trees — and the stream-layer
:class:`~repro.stream.plan_manager.PlanManager` can re-run the pipeline
from *live* stats to detect when the incumbent tree has gone stale.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cost import CostModel
from repro.core.estimator import GraphStats, match_size_estimate, skeleton_size_estimate
from repro.core.join_tree import JoinTree, minimum_unit_decomposition, optimal_join_tree
from repro.core.pattern import (
    Pattern,
    R1Unit,
    connected_vertex_covers,
    enumerate_r1_units,
    symmetry_break,
)
from repro.core.plan import WcojPlan, build_wcoj_plan, wcoj_eligible
from repro.core.vcbc import r_lower

from .lowering import TreeProgram, build_tree_program
from .sizing import (
    ShardingSpec,
    StoreCaps,
    match_caps,
    unit_table_caps,
    wcoj_level_caps,
    wcoj_prefix_estimates,
)

__all__ = [
    "CompileContext",
    "PassReport",
    "CompiledPlan",
    "compile_plan",
    "choose_cover",
    "candidate_covers",
    "tree_key",
]


def choose_cover(
    pattern: Pattern,
    ord_: Sequence[Tuple[int, int]],
    stats: GraphStats,
) -> Tuple[int, ...]:
    """Optimal connected compression: maximize R_lower over connected covers
    that admit a cover-anchored R1 decomposition."""
    best, best_r = None, -1.0
    full = match_size_estimate(pattern, ord_, stats)
    units = enumerate_r1_units(pattern)
    for vc in connected_vertex_covers(pattern):
        vcs = set(vc)
        anchored = [u for u in units if u.anchor_in(vcs) is not None]
        covered = frozenset().union(*[u.pattern.edges for u in anchored]) if anchored else frozenset()
        if covered != pattern.edges:
            continue
        skel = skeleton_size_estimate(pattern, vc, ord_, stats)
        r = r_lower(pattern.n, len(vc), full, skel)
        if r > best_r or (r == best_r and best is not None and len(vc) < len(best)):
            best, best_r = vc, r
    if best is None:
        raise ValueError("no connected cover admits an anchored R1 decomposition")
    return best


def candidate_covers(pattern: Pattern) -> List[Tuple[int, ...]]:
    """Every cover the compiler may legally pick: connected ``p[V_c]``
    admitting a cover-anchored R1 decomposition (the same feasibility
    filter :func:`choose_cover` applies before its R_lower argmax)."""
    units = enumerate_r1_units(pattern)
    out: List[Tuple[int, ...]] = []
    for vc in connected_vertex_covers(pattern):
        vcs = set(vc)
        anchored = [u for u in units if u.anchor_in(vcs) is not None]
        covered = (frozenset().union(*[u.pattern.edges for u in anchored])
                   if anchored else frozenset())
        if covered == pattern.edges:
            out.append(tuple(sorted(int(c) for c in vc)))
    return out


def tree_key(tree: JoinTree) -> Tuple:
    """Canonical hashable identity of a join tree's *shape* (order of a
    join's children is execution-irrelevant, so they compare unordered)."""
    if tree.is_leaf:
        return ("leaf", tree.pattern.key(), tree.unit.anchor)
    return ("join", tree.pattern.key(),
            frozenset((tree_key(tree.left), tree_key(tree.right))))


@dataclasses.dataclass(frozen=True)
class CompileContext:
    """Everything a compile reads — the pattern, the live graph, the
    machine. Immutable so a :class:`CompiledPlan` fully explains itself.

    ``caps`` is duck-typed on ``group_cap``/``set_cap`` (an
    :class:`~repro.dist.jax_engine.EngineCaps` in practice); ``None``
    skips the size/shard passes — the host engine needs no caps.
    ``cover=None`` lets the cover pass choose; a pinned cover is
    validated and used as-is, exactly like ``DDSL(cover=...)``.

    ``cover_objective`` picks the free-cover policy: ``"r_lower"`` is
    the paper's §IV-F optimal connected compression (minimum *storage*,
    the registration default); ``"cost"`` compiles one plan per valid
    cover and keeps the Eq. 11 *runtime* argmin — what the online
    re-optimizer wants, since a drifted stream is re-planned to run
    fast, not to compress best.

    ``executor`` picks the listing/maintenance executor: ``"tree"``
    (binary join tree, the default — byte-identical to plans compiled
    before the executor pass existed), ``"wcoj"`` (force the generic
    join; errors if the pattern has no vertex adjacent to all others),
    or ``"auto"`` (cost the WCOJ per-prefix AGM-style bound against the
    tree's Eq. 11 estimate under the same ``GraphStats`` and keep the
    cheaper — dense patterns flip to WCOJ, sparse ones stay on trees).
    """

    pattern: Pattern
    stats: GraphStats
    m: int = 1
    caps: Optional[Any] = None
    cover: Optional[Tuple[int, ...]] = None
    cover_objective: str = "r_lower"
    store_headroom: float = 4.0
    unit_headroom: float = 2.0
    max_unit_size: Optional[int] = None
    executor: str = "tree"


@dataclasses.dataclass(frozen=True)
class PassReport:
    """One pipeline stage's receipt: what it decided and what it cost."""

    name: str
    elapsed_ms: float
    detail: str


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """The single immutable artifact every engine consumes.

    ``tree``/``units`` drive the host engine, ``program`` the device
    steps, ``store_caps``/``unit_caps``/``sharding`` the device memory
    layout; ``cost`` is the Eq.-11 estimate under ``stats`` — the number
    the :class:`~repro.stream.plan_manager.PlanManager` compares across
    recompiles. ``passes`` is the per-stage report for the obs export.
    """

    pattern: Pattern
    ord: Tuple[Tuple[int, int], ...]
    cover: Tuple[int, ...]
    units: Tuple[R1Unit, ...]
    tree: JoinTree
    program: TreeProgram
    cost: float
    stats: GraphStats
    m: int
    store_caps: Optional[StoreCaps]
    unit_caps: Optional[StoreCaps]
    sharding: Optional[ShardingSpec]
    passes: Tuple[PassReport, ...]
    executor: str = "tree"
    wcoj: Optional[WcojPlan] = None
    wcoj_level_caps: Optional[Tuple[int, ...]] = None

    def plan_key(self) -> Tuple:
        """Identity for swap decisions: same key ⇒ same execution plan
        (cover + tree shape + executor mode), regardless of the stats
        that produced it."""
        return (self.pattern.key(), self.cover, tree_key(self.tree),
                self.executor)

    @property
    def storage_cover(self) -> Tuple[int, ...]:
        """Cover the match store is laid out under. Tree plans store
        VCBC-compressed under the compile ``cover``; WCOJ plans store
        plain rows — trivial compression whose skeleton is every pattern
        vertex and whose set dict is empty, so the whole device/host
        table machinery (merge, filter, count, snapshot) applies
        unchanged."""
        if self.executor == "wcoj":
            return tuple(int(v) for v in sorted(self.pattern.vertices))
        return self.cover

    def describe(self) -> str:
        lines = [
            f"pattern V={list(self.pattern.vertices)} |E|={self.pattern.m}",
            f"cover={list(self.cover)} units={len(self.units)} "
            f"cost={self.cost:.6g} m={self.m} executor={self.executor}",
            self.tree.describe(),
        ]
        for pr in self.passes:
            lines.append(f"[{pr.name:>9}] {pr.elapsed_ms:7.3f} ms  {pr.detail}")
        return "\n".join(lines)

    def to_json(self) -> Dict[str, Any]:
        """JSON-safe dump for :meth:`repro.obs.Observability.export`."""
        return {
            "pattern": {"vertices": list(self.pattern.vertices),
                        "edges": sorted(map(list, self.pattern.edges))},
            "ord": [list(e) for e in self.ord],
            "cover": list(self.cover),
            "units": [{"vertices": list(u.pattern.vertices),
                       "anchor": int(u.anchor)} for u in self.units],
            "tree": self.tree.describe(),
            "cost": self.cost,
            "stats": {"n": self.stats.n, "m": self.stats.m},
            "m": self.m,
            "store_caps": dataclasses.asdict(self.store_caps) if self.store_caps else None,
            "unit_caps": dataclasses.asdict(self.unit_caps) if self.unit_caps else None,
            "sharding": dataclasses.asdict(self.sharding) if self.sharding else None,
            "executor": self.executor,
            "wcoj": None if self.wcoj is None else {
                "anchor": int(self.wcoj.anchor),
                "order": [int(v) for v in self.wcoj.order],
                "level_caps": (list(self.wcoj_level_caps)
                               if self.wcoj_level_caps is not None else None),
            },
            "passes": [dataclasses.asdict(pr) for pr in self.passes],
        }


def compile_plan(ctx: CompileContext) -> CompiledPlan:
    """Run the staged pipeline over ``ctx`` and return the artifact.

    Deterministic: two calls with equal contexts produce plans whose
    ``tree``/``program``/caps compare equal (dataclass equality) — the
    refactor-parity and register-vs-restore guarantees rest on this.
    """
    if ctx.cover_objective not in ("r_lower", "cost"):
        raise ValueError(
            f"unknown cover_objective {ctx.cover_objective!r} "
            "(expected 'r_lower' or 'cost')")
    if ctx.executor not in ("tree", "wcoj", "auto"):
        raise ValueError(
            f"unknown executor {ctx.executor!r} "
            "(expected 'tree', 'wcoj' or 'auto')")
    if ctx.cover is None and ctx.cover_objective == "cost":
        # Joint cover+tree search: one full compile per valid cover,
        # keep the Eq. 11 argmin (first wins ties — candidate_covers
        # enumerates deterministically).
        t0 = time.perf_counter()
        best: Optional[CompiledPlan] = None
        covers = candidate_covers(ctx.pattern)
        for vc in covers:
            cand = compile_plan(dataclasses.replace(ctx, cover=vc))
            if best is None or cand.cost < best.cost:
                best = cand
        if best is None:
            raise ValueError("no connected cover admits an anchored R1 decomposition")
        search = PassReport(
            name="search", elapsed_ms=(time.perf_counter() - t0) * 1e3,
            detail=f"{len(covers)} covers compiled, kept {list(best.cover)} "
                   f"(cost={best.cost:.6g})")
        return dataclasses.replace(best, passes=best.passes + (search,))

    passes: List[PassReport] = []

    def stage(name: str):
        t0 = time.perf_counter()

        def done(detail: str) -> None:
            passes.append(PassReport(name=name,
                                     elapsed_ms=(time.perf_counter() - t0) * 1e3,
                                     detail=detail))
        return done

    p = ctx.pattern

    done = stage("symmetry")
    ord_ = symmetry_break(p)
    done(f"ord={list(ord_)}")

    done = stage("cover")
    if ctx.cover is not None:
        cover = tuple(sorted(int(c) for c in ctx.cover))
        if not all(int(a) in cover or int(b) in cover for a, b in p.edges):
            raise ValueError(f"pinned cover {cover} is not a vertex cover")
        done(f"pinned cover={list(cover)}")
    else:
        cover = choose_cover(p, ord_, ctx.stats)
        done(f"chose cover={list(cover)} (R_lower argmax)")

    done = stage("decompose")
    units = tuple(minimum_unit_decomposition(p, cover, ctx.max_unit_size))
    done(f"{len(units)} Nav-join units, anchors={[u.anchor for u in units]}")

    done = stage("tree")
    model = CostModel(cover, ord_, ctx.stats)
    tree = optimal_join_tree(p, cover, model, ctx.max_unit_size)
    done(f"Eq.11 cost={tree.cost:.6g}, depth={tree.depth()}, "
         f"{len(tree.leaves())} leaves")

    done = stage("lower")
    program = build_tree_program(tree, cover, ord_)
    done(f"{len(program.nodes)} IR nodes (root skel={list(program.nodes[program.root].skel_cols)})")

    store_caps = unit_caps = sharding = None
    if ctx.caps is not None:
        done = stage("size")
        store_caps = match_caps(p, cover, ord_, ctx.stats, ctx.caps,
                                headroom=ctx.store_headroom)
        unit_caps = unit_table_caps(units, cover, ord_, ctx.stats, ctx.caps,
                                    headroom=ctx.unit_headroom)
        done(f"store={store_caps.group_cap}x{store_caps.set_cap} "
             f"units={unit_caps.group_cap}x{unit_caps.set_cap}")

        done = stage("shard")
        sharding = ShardingSpec(m=ctx.m,
                                key_cols=program.nodes[program.root].skel_cols)
        done(f"m={ctx.m} key_cols={list(sharding.key_cols)}")

    executor = "tree"
    wcoj = None
    level_caps = None
    cost = tree.cost
    if ctx.executor != "tree":
        done = stage("executor")
        if not wcoj_eligible(p):
            if ctx.executor == "wcoj":
                raise ValueError(
                    "executor='wcoj' but pattern has no vertex adjacent to "
                    "all others (not WCOJ-eligible)")
            done("pattern not WCOJ-eligible; kept tree-join")
        else:
            wp = build_wcoj_plan(p, None, ord_)
            wcost = float(sum(wcoj_prefix_estimates(p, wp.order, ord_, ctx.stats)))
            if ctx.executor == "wcoj" or wcost < tree.cost:
                executor, wcoj, cost = "wcoj", wp, wcost
                if ctx.caps is not None:
                    level_caps = wcoj_level_caps(
                        p, wp.order, ord_, ctx.stats, ctx.m,
                        headroom=ctx.store_headroom)
                    # Trivial-compression store: groups = full match
                    # rows, bounded by the final-level AGM-style cap;
                    # sets are empty so set_cap is a floor only.
                    store_caps = StoreCaps(
                        group_cap=max(ctx.caps.group_cap, level_caps[-1]),
                        set_cap=8)
                done(f"picked wcoj anchor={wp.anchor} "
                     f"(wcoj={wcost:.6g} vs tree={tree.cost:.6g}"
                     + (f", level_caps={list(level_caps)}" if level_caps else "")
                     + ")")
            else:
                done(f"kept tree (tree={tree.cost:.6g} <= wcoj={wcost:.6g})")

    plan = CompiledPlan(
        pattern=p, ord=tuple(ord_), cover=cover, units=units, tree=tree,
        program=program, cost=cost, stats=ctx.stats, m=ctx.m,
        store_caps=store_caps, unit_caps=unit_caps, sharding=sharding,
        passes=tuple(passes),
        executor=executor, wcoj=wcoj, wcoj_level_caps=level_caps,
    )
    # A dump that fails to serialize should fail at compile time, not in
    # Observability.export at shutdown.
    json.dumps(plan.to_json())
    return plan
