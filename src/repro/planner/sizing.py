"""Cap sizing and sharding descriptors (the compiler's device stages).

Pure §IV-D estimator arithmetic — no JAX. :func:`match_caps` sizes a
pattern's device-resident :class:`~repro.dist.sharded.MatchStore`,
:func:`unit_table_caps` its per-device unit-table carries; both return a
:class:`StoreCaps` floored at the engine caps (which must already hold
any single batch's output). ``caps`` only needs ``group_cap``/``set_cap``
attributes, so the compiler can size plans with a plain
:class:`~repro.dist.jax_engine.EngineCaps` without importing the device
runtime. :mod:`repro.dist.sharded` re-exports these names.

:class:`ShardingSpec` is the *descriptor* half of placement: which
columns key the full-skeleton ownership hash and over how many devices.
The mesh-bound ``PartitionSpec`` pytrees stay in
:func:`repro.dist.sharded.match_specs` — they need a live mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.core.estimator import match_size_estimate, skeleton_size_estimate
from repro.core.pattern import Pattern

__all__ = ["StoreCaps", "ShardingSpec", "match_caps", "quantize_store_caps",
           "unit_table_caps", "wcoj_prefix_estimates", "wcoj_level_caps"]


@dataclasses.dataclass(frozen=True)
class StoreCaps:
    """Static shape of one :class:`MatchStore` shard: ``group_cap``
    skeleton groups × ``set_cap`` values per compressed-vertex set."""

    group_cap: int
    set_cap: int


@dataclasses.dataclass(frozen=True)
class ShardingSpec:
    """How a pattern's running match set is placed across the mesh:
    ``key_cols`` (the full skeleton — cover ∩ V(p), sorted) feed the
    int32 ownership hash (:func:`repro.dist.sharded._owner_of`), the
    same rule the patch merge uses, so per-batch maintenance is
    collective-free."""

    m: int
    key_cols: Tuple[int, ...]
    placement: str = "full_skeleton_owner_hash"


def _up(x: float, align: int) -> int:
    return int(-(-max(1.0, x) // align) * align)


def _pow2_at_least(x: int, floor: int) -> int:
    n = floor
    while n < x:
        n *= 2
    return n


def quantize_store_caps(store: StoreCaps) -> StoreCaps:
    """Round a store's caps up to powers of two (floors 64 / 8).

    Multi-pattern deployments compile one fused maintain step whose
    shapes include every pattern's store caps; quantizing to a coarse
    pow2 grid collapses near-identical estimator outputs onto shared
    shapes (fewer megastep variants) and keeps the backend's ×2
    auto-resize on-grid, so a resize is always exactly one step up the
    same ladder instead of a fresh odd shape.
    """
    return StoreCaps(group_cap=_pow2_at_least(int(store.group_cap), 64),
                     set_cap=_pow2_at_least(int(store.set_cap), 8))


def match_caps(pattern: Pattern, cover: Sequence[int],
               ord_: Sequence[Tuple[int, int]], stats, caps,
               headroom: float = 4.0) -> StoreCaps:
    """Size a match store from the §IV-D estimators.

    Groups come from the skeleton-size estimate, per-group set widths
    from the match/skeleton ratio, both scaled by ``headroom`` (the
    store outlives many update batches) and floored at the engine caps
    (which already hold any single batch's output). Overflow remains
    counted, never silent — a growing stream that outruns the estimate
    surfaces in ``diag``/metrics, and re-registering with a larger
    ``headroom`` is the documented reaction.
    """
    est_m = match_size_estimate(pattern, ord_, stats)
    est_g = skeleton_size_estimate(pattern, cover, ord_, stats)
    group_cap = max(caps.group_cap, _up(headroom * est_g, 64))
    set_cap = max(caps.set_cap, _up(headroom * est_m / max(est_g, 1.0), 8))
    return StoreCaps(group_cap=group_cap, set_cap=set_cap)


def wcoj_prefix_estimates(pattern: Pattern, order: Sequence[int],
                          ord_: Sequence[Tuple[int, int]], stats):
    """Expected partial-match table size after each generic-join level.

    Entry ``ℓ`` is the §IV-D estimate of the pattern *induced by the
    first ``ℓ+1`` order vertices* (``ord`` restricted by the estimator),
    clamped by the mean-degree expansion chain ``est[ℓ-1] · d̄``: every
    level's candidates come from a single pivot adjacency before the
    intersections shrink them, so a level can't plausibly exceed the
    previous level's size times the mean degree — while the raw PR
    estimator compounds heavy-tail degree correlations per added edge
    and overshoots dense (clique) prefixes by orders of magnitude.
    The clamped sequence is the WCOJ executor's per-level (AGM-style)
    bound and, summed, its cost model. Entry 0 (the bare anchor seed)
    is ``stats.n``; overflow past these estimates stays counted, never
    silent, like every other cap in the engine.
    """
    order = tuple(order)
    dbar = 2.0 * stats.m / max(stats.n, 1)
    out = [float(stats.n)]
    prev = None
    for l in range(2, len(order) + 1):
        sub = pattern.induced(order[:l])
        est = match_size_estimate(sub, ord_, stats)
        chain = float(stats.m) if prev is None else prev * max(dbar, 1.0)
        prev = min(est, chain) if est > 0 else chain
        out.append(prev)
    return tuple(out)


def wcoj_level_caps(pattern: Pattern, order: Sequence[int],
                    ord_: Sequence[Tuple[int, int]], stats, m: int = 1,
                    headroom: float = 4.0) -> Tuple[int, ...]:
    """Per-level candidate caps for the device WCOJ executor.

    One cap per placed prefix length (cap 0 bounds the anchor seeds),
    from the per-prefix estimates divided across the ``m`` mesh devices,
    scaled by ``headroom``, and rounded up the pow2 ladder (floor 64) so
    multi-pattern megasteps share shapes — the WCOJ analogue of
    :func:`match_caps` + :func:`quantize_store_caps`.
    """
    ests = wcoj_prefix_estimates(pattern, order, ord_, stats)
    return tuple(
        _pow2_at_least(_up(headroom * est / max(int(m), 1), 1), 64)
        for est in ests
    )


def unit_table_caps(units, cover: Sequence[int],
                    ord_: Sequence[Tuple[int, int]], stats, caps,
                    headroom: float = 2.0) -> StoreCaps:
    """Size the compressed unit-table carries from the §IV-D estimators.

    Groups from the per-unit skeleton-size estimate, set widths from the
    match/skeleton ratio, scaled by ``headroom`` (the carry outlives
    many batches) and floored at the engine caps (which must hold any
    single listing anyway) — like :func:`match_caps` for the store.
    Overflow of a refresh stays counted in ``diag``, never silent.
    """
    est_g = max((skeleton_size_estimate(u.pattern, cover, ord_, stats)
                 for u in units), default=1.0)
    est_m = max((match_size_estimate(u.pattern, ord_, stats)
                 for u in units), default=1.0)
    group_cap = max(caps.group_cap, _up(headroom * est_g, 64))
    set_cap = max(caps.set_cap, _up(headroom * est_m / max(est_g, 1.0), 8))
    return StoreCaps(group_cap=group_cap, set_cap=set_cap)
