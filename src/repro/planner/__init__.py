"""repro.planner — staged plan compiler for DDSL engines.

One entry point, :func:`compile_plan`, turns a
:class:`CompileContext` (pattern + live GraphStats + machine shape)
into an immutable :class:`CompiledPlan` through inspectable passes:
symmetry → cover → decompose → tree → lower → size → shard. The host
facade (:class:`repro.core.ddsl.DDSL`), the stream backends, and the
device runtime all consume the same artifact; the stream layer's
:class:`~repro.stream.plan_manager.PlanManager` recompiles it from live
stats to drive drift-triggered online re-optimization.

JAX-free by construction (imports only ``repro.core`` submodules) so
host-only consumers never pay a device-runtime import.
"""

from .compiler import (
    CompileContext,
    CompiledPlan,
    PassReport,
    candidate_covers,
    choose_cover,
    compile_plan,
    tree_key,
)
from .lowering import TreeNode, TreeProgram, build_tree_program
from .sizing import ShardingSpec, StoreCaps, match_caps, unit_table_caps

__all__ = [
    "CompileContext",
    "CompiledPlan",
    "PassReport",
    "candidate_covers",
    "choose_cover",
    "compile_plan",
    "tree_key",
    "TreeNode",
    "TreeProgram",
    "build_tree_program",
    "ShardingSpec",
    "StoreCaps",
    "match_caps",
    "unit_table_caps",
]
