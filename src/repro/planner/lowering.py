"""Join-tree → plan-IR lowering (the compiler's backend-neutral stage).

A :class:`~repro.core.join_tree.JoinTree` names *what* to join;
:func:`build_tree_program` lowers it into the executable
:class:`~repro.core.plan.UnitPlan` / :class:`~repro.core.plan.JoinPlan`
IR both engines consume: a post-order :class:`TreeProgram` whose leaves
carry anchored listing plans and whose internal nodes carry CC-join
plans. Everything here is plain Python tuples — no JAX — so the host
:class:`~repro.core.ddsl.DDSL` path and the staged compiler can lower
plans without a device runtime; :mod:`repro.dist.sharded` re-exports
these names for its jitted step builders.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.join_tree import JoinTree
from repro.core.pattern import Pattern
from repro.core.plan import JoinPlan, UnitPlan, build_unit_plan

__all__ = ["TreeNode", "TreeProgram", "build_tree_program"]


@dataclasses.dataclass(frozen=True)
class TreeNode:
    """One node of a compiled join-tree program (leaf or join)."""

    pattern: Pattern
    skel_cols: Tuple[int, ...]
    unit_plan: Optional[UnitPlan] = None
    join_plan: Optional[JoinPlan] = None
    left: int = -1
    right: int = -1


@dataclasses.dataclass(frozen=True)
class TreeProgram:
    """Post-order node list; ``nodes[root]`` is the full pattern."""

    nodes: Tuple[TreeNode, ...]
    root: int
    cover: Tuple[int, ...]
    ord: Tuple[Tuple[int, int], ...]


def build_tree_program(
    tree: JoinTree,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
) -> TreeProgram:
    """Compile an optimal join tree into plan-IR nodes."""
    cover = tuple(sorted(int(c) for c in cover))
    ord_t = tuple((int(a), int(b)) for a, b in ord_)
    nodes: List[TreeNode] = []

    def rec(jt: JoinTree) -> int:
        if jt.is_leaf:
            anchor = jt.unit.anchor_in(cover)
            if anchor is None:
                raise ValueError("unit anchor must lie inside the cover")
            up = build_unit_plan(jt.unit.pattern, anchor, ord_t)
            skel = tuple(c for c in cover if c in set(jt.pattern.vertices))
            nodes.append(TreeNode(pattern=jt.pattern, skel_cols=skel, unit_plan=up))
            return len(nodes) - 1
        li = rec(jt.left)
        ri = rec(jt.right)
        jp = JoinPlan.make(jt.left.pattern, jt.right.pattern, cover, ord_t)
        if not jp.key_cols:
            raise ValueError("CC-join requires a non-empty cover join key (Lemma 4.2)")
        nodes.append(TreeNode(pattern=jt.pattern, skel_cols=jp.skel_out,
                              join_plan=jp, left=li, right=ri))
        return len(nodes) - 1

    root = rec(tree)
    return TreeProgram(nodes=tuple(nodes), root=root, cover=cover, ord=ord_t)
