"""Three-term roofline analysis from the compiled dry-run artifact.

    compute    = HLO_FLOPs            / (chips · 197e12 FLOP/s bf16)
    memory     = HLO_bytes_accessed   / (chips · 819e9  B/s HBM)
    collective = collective_bytes     / (chips · 50e9   B/s per ICI link)

``cost_analysis`` supplies flops/bytes; collective bytes are *not* in
cost_analysis, so :func:`collective_bytes` parses the optimized HLO text
and sums operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (per-chip: HLO is SPMD, shapes are
already per-participant).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["HW", "collective_bytes", "analyze", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-class chip constants (per the brief)."""

    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 50e9            # B/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|[^\s(]+)\s+([\w\-]+)(\(|\.)")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by kind."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # async pair: count the -start only
        # bytes moved ≈ result shape (per participant)
        shape_part = m.group(1)
        out[kind] += _shape_bytes(shape_part)
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    hw: HW = dataclasses.field(default_factory=HW)

    @property
    def t_compute(self) -> float:
        # flops is per-chip (SPMD module)
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        # HLO shapes are per-participant already → no /chips
        return self.coll_bytes / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory, "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_ratio(self) -> float:
        # model_flops is global; compare per-chip shares
        return (self.model_flops / self.chips) / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs MFU bound: (model_flops/chips) / (peak · t_bound)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_flops / self.chips) / (self.hw.peak_flops * t)

    def row(self) -> Dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "hlo_gflops": round(self.flops / 1e9, 3),
            "hlo_gbytes": round(self.bytes_accessed / 1e9, 3),
            "coll_mbytes": round(self.coll_bytes / 1e6, 3),
            "t_compute_ms": round(self.t_compute * 1e3, 4),
            "t_memory_ms": round(self.t_memory * 1e3, 4),
            "t_collective_ms": round(self.t_collective * 1e3, 4),
            "bottleneck": self.bottleneck,
            "useful_ratio": round(self.useful_ratio, 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
        }


def analyze(name: str, compiled, chips: int, model_flops: float) -> RooflineReport:
    """Corrected three-term roofline.

    ``cost_analysis()`` counts while bodies once (scan layers would be
    undercounted L×), so flops/bytes/collectives come from the
    trip-count-aware text analyzer (``hlo_cost``). The SPMD module is
    per-participant, so terms are per-chip already — ``model_flops``
    (global) is divided by chips for the useful-work comparisons.
    """
    from .hlo_cost import analyze_text

    text = compiled.as_text()
    hc = analyze_text(text)
    return RooflineReport(
        name=name,
        chips=chips,
        flops=hc.flops,           # per-chip (SPMD module)
        bytes_accessed=hc.bytes_accessed,
        coll_bytes=hc.collective_bytes,
        coll_breakdown=hc.collectives,
        model_flops=model_flops,
    )
