"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.json.

    PYTHONPATH=src python -m repro.launch.report > results/roofline.md
"""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun.json")


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def main() -> None:
    with open(RESULTS) as f:
        data = json.load(f)
    entries = {(e["cell"], e["mesh"]): e for e in data if e.get("ok")}
    fails = [e for e in data if not e.get("ok")]

    print("## §Dry-run (memory analysis, per device)\n")
    print("| cell | mesh | arg GiB | temp GiB | peak GiB | fits v5e (16 GiB) |")
    print("|---|---|---:|---:|---:|---|")
    for (cell, mesh), e in sorted(entries.items()):
        m = e["memory"]
        peak = m["peak_bytes"]
        print(f"| {cell} | {mesh} | {fmt_bytes(m['argument_bytes'])} "
              f"| {fmt_bytes(m['temp_bytes'])} | {fmt_bytes(peak)} "
              f"| {'yes' if peak <= 16 * 2**30 else 'NO'} |")
    if fails:
        print("\nFailed cells:")
        for e in fails:
            print(f"- {e['cell']} [{e['mesh']}]: {e.get('error')}")

    print("\n## §Roofline (single-pod 16×16, per chip; while-trip-corrected)\n")
    print("| cell | t_comp ms | t_mem ms | t_coll ms | bottleneck | useful/HLO | roofline frac |")
    print("|---|---:|---:|---:|---|---:|---:|")
    for (cell, mesh), e in sorted(entries.items()):
        if mesh != "single_pod_16x16" or "roofline" not in e:
            continue
        r = e["roofline"]
        print(f"| {cell} | {r['t_compute_ms']:.1f} | {r['t_memory_ms']:.1f} "
              f"| {r['t_collective_ms']:.1f} | {r['bottleneck']} "
              f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} |")

    print("\n## Collective breakdown (single-pod, GiB per chip per step)\n")
    print("| cell | all-gather | all-reduce | reduce-scatter | all-to-all | permute |")
    print("|---|---:|---:|---:|---:|---:|")
    for (cell, mesh), e in sorted(entries.items()):
        if mesh != "single_pod_16x16" or "collectives" not in e:
            continue
        c = e["collectives"]
        print(f"| {cell} | {fmt_bytes(c.get('all-gather', 0))} "
              f"| {fmt_bytes(c.get('all-reduce', 0))} "
              f"| {fmt_bytes(c.get('reduce-scatter', 0))} "
              f"| {fmt_bytes(c.get('all-to-all', 0))} "
              f"| {fmt_bytes(c.get('collective-permute', 0))} |")


if __name__ == "__main__":
    main()
