"""While-loop-aware HLO cost analyzer (text-based).

``compiled.cost_analysis()`` counts every computation **once**, so
``lax.scan`` bodies (our transformer layer stacks, attention chunk loops)
are undercounted by their trip counts. This module re-derives
flops / HBM bytes / collective bytes from ``compiled.as_text()`` with a
call-graph multiplier pass:

- computations are parsed into per-instruction records with a local
  symbol table (operand shapes resolve through it);
- ``while`` ops multiply their body/condition by the trip count read
  from the condition's comparison constant;
- ``fusion``/``call``/conditional edges propagate multipliers ×1;
- flops: ``dot`` = 2·prod(result)·prod(contracted lhs dims) (plus a
  cheap elementwise estimate); post-fusion instruction operands+results
  approximate HBM traffic (fusion internals stay on-chip);
- collectives: result bytes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute (per-participant, as lowered).

Validated against hand-computed scan programs in tests.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_text"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]"
)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_NO_TRAFFIC = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "iota", "broadcast", "reshape",
}


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, List[int]]]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: List[_Instr]
    symbols: Dict[str, List[Tuple[str, List[int]]]]


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        # computation header: `%name (...) -> ... {` or `ENTRY %name ... {`
        if s.endswith("{") and ("(" in s) and "=" not in s.split("(")[0]:
            header = s
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", header)
            if m:
                cur = _Computation(name=m.group(1), instrs=[], symbols={})
                comps[cur.name] = cur
                if header.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if s == "}":
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(s)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # result shapes = shapes before the opcode's '('
        om = _OPCODE_RE.search(re.sub(r"^[^=]*", "", "=" + rhs) or "")
        # opcode: first token after shapes — find `<shape tokens> opcode(`
        opm = re.search(r"\)\s*([\w\-]+)\(", rhs) or re.search(r"\]\S*\s+([\w\-]+)\(", rhs)
        opcode = opm.group(1) if opm else (rhs.split("(")[0].split()[-1] if "(" in rhs else rhs.split()[0])
        paren = rhs.find("(")
        result_part = rhs[:paren] if paren > 0 else rhs
        result_shapes = _shapes_in(result_part)
        cur.symbols[name] = result_shapes
        cur.instrs.append(_Instr(name=name, opcode=opcode, result_shapes=result_shapes, line=s))
    return comps


def _trip_count(cond: _Computation) -> int:
    """Heuristic: max integer constant in the loop condition computation."""
    best = 1
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, comp: _Computation) -> float:
    res = 1
    for _, dims in ins.result_shapes:
        for d in dims:
            res *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if not m:
        return 2.0 * res
    cdims = [int(x) for x in m.group(1).split(",") if x]
    # lhs operand = first %ref inside the call parens
    paren = ins.line.find("(", ins.line.find(ins.opcode))
    operands = _OPERANDS_RE.findall(ins.line[paren:])
    contracted = 1
    if operands:
        lhs = comp.symbols.get(operands[0])
        if lhs:
            dims = lhs[0][1]
            for c in cdims:
                if c < len(dims):
                    contracted *= dims[c]
    # ragged_dot lowers to dot+masks; group dim already in result
    return 2.0 * res * contracted


def _instr_bytes(ins: _Instr, comp: _Computation) -> int:
    if ins.opcode in _NO_TRAFFIC:
        return 0
    total = _shape_bytes(ins.result_shapes)
    paren = ins.line.find("(", ins.line.find(ins.opcode) if ins.opcode in ins.line else 0)
    if paren >= 0:
        args = ins.line[paren:].split(")")[0]
        for ref in _OPERANDS_RE.findall(args):
            shp = comp.symbols.get(ref)
            if shp:
                total += _shape_bytes(shp)
    return total


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collectives: Dict[str, int]
    while_trips: List[int]


def analyze_text(text: str) -> HloCost:
    comps = _parse_computations(text)
    entry = comps.get("__entry__")
    if entry is None:
        # fall back: the last computation is usually main
        entry = list(comps.values())[-1]

    # --- propagate call multipliers from the entry ---------------------------
    # Two multiplier planes: flops count everywhere; HBM bytes only at
    # materialization boundaries (entry/while/conditional bodies) — fusion
    # internals stay on-chip, so edges via `calls=`/`to_apply` zero the
    # byte multiplier while preserving the flop multiplier.
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    bmult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    bmult[entry.name] = 1.0
    trips: List[int] = []
    order = [entry.name]
    seen = {entry.name}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        bm_ = bmult[cname]
        for ins in comp.instrs:
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trip = 1
                if cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)])
                    trips.append(trip)
                for tgt in (bm.group(1) if bm else None, cm.group(1) if cm else None):
                    if tgt and tgt in comps:
                        mult[tgt] = mult.get(tgt, 0.0) + m * trip
                        bmult[tgt] = bmult.get(tgt, 0.0) + bm_ * trip
                        if tgt not in seen:
                            seen.add(tgt)
                            order.append(tgt)
                continue
            called = list(_CALLED_RE.findall(ins.line))
            bt = _BRANCHES_RE.search(ins.line)
            branch = []
            if bt:
                branch = [x.strip().lstrip("%") for x in bt.group(1).split(",")]
            for tgt in called + branch:
                if tgt in comps:
                    mult[tgt] = mult.get(tgt, 0.0) + m
                    # fused bodies don't touch HBM; conditional branches do
                    bmult[tgt] = bmult.get(tgt, 0.0) + (bm_ if tgt in branch else 0.0)
                    if tgt not in seen:
                        seen.add(tgt)
                        order.append(tgt)

    flops = 0.0
    nbytes = 0.0
    coll = {k: 0 for k in _COLLECTIVES}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        bm_ = bmult.get(cname, 0.0)
        if m <= 0 and bm_ <= 0:
            continue
        for ins in comp.instrs:
            if ins.opcode.startswith("dot"):
                flops += m * _dot_flops(ins, comp)
            if bm_ > 0:
                nbytes += bm_ * _instr_bytes(ins, comp)
            for ck in _COLLECTIVES:
                if ins.opcode == ck or ins.opcode.startswith(ck + "-"):
                    if ins.opcode.endswith("-done"):
                        continue
                    coll[ck] += int(m * _shape_bytes(ins.result_shapes))
    return HloCost(
        flops=flops,
        bytes_accessed=nbytes,
        collective_bytes=float(sum(coll.values())),
        collectives=coll,
        while_trips=trips,
    )
