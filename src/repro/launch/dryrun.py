import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # Keep per-layer converts inside the layer loop: hoisting them
    # materializes whole-stack f32 copies (24 GiB on command-r-class
    # models) that no TPU build would allocate.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,while-loop-expensive-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on
first init, and the production meshes need 512 placeholder host devices.
Never set this flag globally: smoke tests and benches see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                   # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch dlrm-rm2   # filter
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi      # 512 chips
    PYTHONPATH=src python -m repro.launch.dryrun --include-ddsl

Results (memory analysis, cost analysis, collective bytes, roofline
terms) accumulate in ``results/dryrun.json`` — incremental: finished
cells are skipped unless ``--force``.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.registry import all_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.steps import build_cell

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun.json")


def run_cell(spec, shape, mesh, mesh_name, *, capture_roofline=True):
    t0 = time.time()
    cell = build_cell(spec, shape, mesh)
    with jax.set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    entry = {
        "cell": cell.name,
        "mesh": mesh_name,
        "ok": True,
        "seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
            ),
        },
        "meta": cell.meta,
    }
    if capture_roofline:
        chips = 1
        for v in mesh.shape.values():
            chips *= v
        rep = analyze(cell.name, compiled, chips, cell.meta.get("model_flops", 0.0))
        entry["roofline"] = rep.row()
        entry["collectives"] = rep.coll_breakdown
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--include-ddsl", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(os.path.abspath(RESULTS)), exist_ok=True)
    done = {}
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            for e in json.load(f):
                done[(e["cell"], e["mesh"])] = e
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    for name, spec in sorted(all_archs().items()):
        if args.arch and name != args.arch:
            continue
        if spec.family == "ddsl" and not (args.include_ddsl or args.arch == "ddsl-paper"):
            continue
        for shape in spec.shapes:
            if args.shape and shape.name != args.shape:
                continue
            for mesh_name, mesh in meshes:
                key = (f"{name}:{shape.name}", mesh_name)
                if key in done and done[key].get("ok") and not args.force:
                    print(f"SKIP {key[0]} [{mesh_name}] (cached)", flush=True)
                    continue
                print(f"RUN  {key[0]} [{mesh_name}] ...", flush=True)
                try:
                    entry = run_cell(spec, shape, mesh, mesh_name)
                    rf = entry.get("roofline", {})
                    print(
                        f"OK   {key[0]} [{mesh_name}] {entry['seconds']}s "
                        f"peak={entry['memory']['peak_bytes']/2**30:.2f}GiB/dev "
                        f"bottleneck={rf.get('bottleneck')} "
                        f"frac={rf.get('roofline_fraction')}",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001 — record and continue
                    entry = {
                        "cell": key[0], "mesh": mesh_name, "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    print(f"FAIL {key[0]} [{mesh_name}]: {entry['error']}", flush=True)
                done[key] = entry
                with open(RESULTS, "w") as f:
                    json.dump(list(done.values()), f, indent=1)

    n_ok = sum(1 for e in done.values() if e.get("ok"))
    print(f"\n{n_ok}/{len(done)} cells OK → {os.path.abspath(RESULTS)}")


if __name__ == "__main__":
    main()
