"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required for the
smoke tests, which must see the real single-device CPU backend.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (256-chip pod) or 2×16×16 (two-pod, 512-chip) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
