"""Batched serving driver: prefill + decode loop with a latent/KV cache.

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b --smoke \
        --batch 2 --prompt-len 16 --gen 8
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models import transformer as tf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--absorbed", action="store_true", help="MLA absorbed decode")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg: tf.TransformerConfig = spec.smoke if args.smoke else spec.config
    if args.absorbed and cfg.attn == "mla":
        cfg = dataclasses.replace(cfg, decode_absorbed=True)

    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen
    cache = tf.init_cache(cfg, args.batch, max_len)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    prefill = jax.jit(lambda p, t, c: tf.prefill(p, t, c, cfg, None))
    decode = jax.jit(
        lambda p, t, c, pos: tf.decode_step(p, t, c, pos, cfg, None),
        static_argnames=(),
    )

    t0 = time.time()
    logits, cache = prefill(params, prompt, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    print(f"prefill {args.prompt_len} tokens: {time.time()-t0:.2f}s")
    for i in range(args.gen - 1):
        t0 = time.time()
        logits, cache = decode(params, tok, cache, args.prompt_len + i)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
        print(f"decode step {i}: {1e3*(time.time()-t0):.0f}ms")
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print("generated ids:\n", gen)


if __name__ == "__main__":
    main()
