"""Fault-tolerant training driver.

Runs any LM/GNN/recsys arch at smoke or full scale with:
- checkpoint/restart (atomic saves; auto-resume from the newest intact
  step — kill -9 mid-run and relaunch to test);
- elastic restarts (mesh shape may differ across runs; state reshards on
  load via the new shardings);
- straggler monitoring (per-step timing window; on a real pod the hook
  re-balances DDSL partitions / excludes slow hosts before re-meshing);
- host-side double-buffered data prefetch.

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --steps 20 --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_arch
from repro.data.pipeline import prefetch
from repro.data.tokens import token_batches
from repro.dist.straggler import StragglerMonitor
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tf
from repro.models.common import cross_entropy
from repro.optim import adamw_init, adamw_update, warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=5)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    assert spec.family == "lm", "train.py drives LM archs; see examples/ for GNN/recsys"
    cfg: tf.TransformerConfig = spec.smoke if args.smoke else spec.config
    mesh = make_local_mesh()
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    monitor = StragglerMonitor(n_hosts=jax.process_count())

    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    start_step = 0
    latest, restored = mgr.restore_latest({"params": params, "opt": opt})
    if restored is not None:
        params, opt = restored["params"], restored["opt"]
        start_step = latest
        print(f"resumed from checkpoint step {latest}")

    @jax.jit
    def step_fn(params, opt, tokens, labels, lr):
        def loss_fn(p):
            logits = tf.forward(p, tokens, cfg, None)
            return cross_entropy(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt2, gnorm = adamw_update(params, grads, opt, lr)
        return params2, opt2, loss, gnorm

    data = prefetch(token_batches(cfg.vocab, args.batch, args.seq, seed=start_step))
    for i, (toks, labels) in enumerate(data):
        step = start_step + i
        if step >= args.steps:
            break
        t0 = time.time()
        lr = warmup_cosine(step, peak=3e-4, warmup=10, total=args.steps)
        params, opt, loss, gnorm = step_fn(params, opt, jnp.asarray(toks), jnp.asarray(labels), lr)
        loss = float(loss)
        dt = time.time() - t0
        monitor.record(np.array([dt]))
        if monitor.stragglers():
            print(f"step {step}: straggler hosts {monitor.stragglers()} (would rebalance)")
        print(f"step {step}: loss={loss:.4f} gnorm={float(gnorm):.3f} {dt*1e3:.0f}ms")
        assert not np.isnan(loss), "NaN loss"
        if (step + 1) % args.ckpt_every == 0:
            path = mgr.save(step + 1, {"params": params, "opt": opt})
            print(f"checkpointed → {path}")
    print("done")


if __name__ == "__main__":
    main()
