"""Cell builders: (architecture × input shape × mesh) → compile-ready program.

Every cell resolves to a :class:`CellProgram` — a jit-able function plus
``ShapeDtypeStruct`` argument stand-ins and ``NamedSharding`` pytrees —
which the dry-run lowers and compiles without allocating anything.

Sharding policy (see DESIGN.md):
- LM params TP over ``model``; activations batch over ``('pod','data')``;
  optimizer moments ZeRO-1 (extra data-axis sharding on the first
  divisible dim); KV caches shard batch over data when divisible, else
  sequence over every axis (long_500k, batch=1).
- GNN node/edge arrays shard over *all* axes (pure graph-data
  parallelism); weights replicate.
- DLRM tables model-shard on rows; batch over data axes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeSpec
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf
from repro.models.common import cross_entropy, data_axes
from repro.optim import adamw_init, adamw_update

__all__ = ["CellProgram", "build_cell"]

SD = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellProgram:
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    meta: Dict[str, float]


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fix_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on dims the shape doesn't divide (GSPMD-safe subset)."""
    fixed = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            fixed.append(ax)
            continue
        if shape[i] % _axis_size(mesh, ax) == 0:
            fixed.append(ax)
        else:
            fixed.append(None)
    while len(fixed) < len(shape):
        fixed.append(None)
    return P(*fixed)


def _ns_tree(mesh: Mesh, specs, shapes):
    """NamedSharding pytree with divisibility fixes applied leaf-wise."""
    def one(spec, shp):
        return NamedSharding(mesh, _fix_spec(spec, tuple(shp.shape), mesh))

    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def _zero1_specs(specs, shapes, mesh: Mesh):
    """Add data-axis sharding on the first free, divisible dim (ZeRO-1)."""
    daxes = data_axes(mesh.axis_names)
    dsize = _axis_size(mesh, daxes)

    def one(spec, shp):
        dims = tuple(shp.shape)
        entries = list(spec) + [None] * (len(dims) - len(spec))
        for i, (ax, n) in enumerate(zip(entries, dims)):
            if ax is None and n % dsize == 0 and n > 0 and dsize > 1:
                entries[i] = daxes if len(daxes) > 1 else daxes[0]
                break
        return P(*entries)

    return jax.tree.map(one, specs, shapes, is_leaf=lambda x: isinstance(x, P))


def _batch_axes(batch: int, mesh: Mesh):
    daxes = data_axes(mesh.axis_names)
    if daxes and batch % _axis_size(mesh, daxes) == 0:
        return daxes if len(daxes) > 1 else daxes[0]
    return None


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_flops(cfg: tf.TransformerConfig, shape: ShapeSpec) -> Dict[str, float]:
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        useful = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        attn = 2.0 * shape.global_batch * cfg.n_layers * cfg.n_heads * shape.seq_len ** 2 * (cfg.d_head + (cfg.v_head or cfg.d_head)) / 2
        useful = 2.0 * n_active * tokens + attn
    else:  # decode: one token against a seq_len cache
        b = shape.global_batch
        if cfg.attn == "mla":
            attn = 2.0 * b * cfg.n_layers * cfg.n_heads * shape.seq_len * (cfg.qk_nope + cfg.qk_rope + cfg.v_head)
        else:
            attn = 2.0 * b * cfg.n_layers * cfg.n_heads * shape.seq_len * 2 * cfg.d_head
        useful = 2.0 * n_active * b + attn
    return {"model_flops": useful, "params": float(n_total), "active_params": float(n_active)}


def _lm_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh, smoke: bool) -> CellProgram:
    cfg: tf.TransformerConfig = spec.smoke if smoke else spec.config
    daxes = data_axes(mesh.axis_names)
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda: tf.init_params(cfg, key))
    p_specs = tf.param_specs(cfg, mesh.axis_names)
    p_shard = _ns_tree(mesh, p_specs, p_shapes)

    if shape.kind == "train":
        b, s = shape.global_batch, shape.seq_len
        o_shapes = jax.eval_shape(lambda: adamw_init(p_shapes))
        o_specs = type(o_shapes)(
            step=P(),
            mu=_zero1_specs(p_specs, p_shapes, mesh),
            nu=_zero1_specs(p_specs, p_shapes, mesh),
        )
        o_shard = _ns_tree(mesh, o_specs, o_shapes)
        bax = _batch_axes(b, mesh)
        tok_shard = NamedSharding(mesh, P(bax, None))
        dsize = _axis_size(mesh, data_axes(mesh.axis_names)) or 1
        # Microbatch count: keep the per-device saved-residual stack
        # (L·B_micro_loc·S·D·2B, scan bwd) under ~2 GiB — tighter for MoE,
        # whose dispatch buffers scale with the microbatch token count.
        stack_per_example = 2 * cfg.n_layers * s * cfg.d_model
        target = 5e8 if cfg.moe else 2e9
        micro_bs = max(1, int(target // max(stack_per_example, 1)))
        n_micro = 1
        while (b // (n_micro * 2)) >= dsize and (b // (dsize * n_micro)) > micro_bs:
            n_micro *= 2

        def step(params, opt, tokens, labels):
            def loss_fn(p, t, l):
                logits = tf.forward(p, t, cfg, mesh)
                return cross_entropy(logits, l)

            if n_micro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
            else:
                mt = tokens.reshape(n_micro, b // n_micro, s)
                ml = labels.reshape(n_micro, b // n_micro, s)
                acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def micro(acc, tl):
                    t, l = tl
                    li, gi = jax.value_and_grad(loss_fn)(params, t, l)
                    acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, gi)
                    return acc, li

                gacc, losses = jax.lax.scan(micro, acc0, (mt, ml))
                grads = jax.tree.map(lambda g, p: (g / n_micro).astype(p.dtype), gacc, params)
                loss = losses.mean()
            params2, opt2, gnorm = adamw_update(params, grads, opt, 3e-4)
            return params2, opt2, loss, gnorm

        args = (
            p_shapes,
            o_shapes,
            SD((b, s), jnp.int32),
            SD((b, s), jnp.int32),
        )
        shards = (p_shard, o_shard, tok_shard, tok_shard)
        return CellProgram(
            name=f"{spec.name}:{shape.name}", fn=step, args=args,
            in_shardings=shards, meta=_lm_flops(cfg, shape),
        )

    # serving cells
    b, s = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: tf.init_cache(cfg, b, s))
    bax = _batch_axes(b, mesh)

    def cache_specs(shp):
        dims = tuple(shp.shape)
        if cfg.attn == "gqa" and len(dims) == 5:
            # [L, B, Hkv, S, Dh]
            if bax is not None:
                return P(None, bax, None, "model", None)
            return P(None, None, None, tuple(mesh.axis_names), None)
        if len(dims) == 4:
            # MLA latent [L, B, S, r]
            if bax is not None:
                return P(None, bax, "model", None)
            return P(None, None, tuple(mesh.axis_names), None)
        return P(*([None] * len(dims)))

    c_specs = jax.tree.map(cache_specs, cache_shapes)
    c_shard = _ns_tree(mesh, c_specs, cache_shapes)

    if shape.kind == "prefill":
        def step(params, tokens, cache):
            return tf.prefill_chunked(params, tokens, cache, cfg, mesh, chunk=4096)

        args = (p_shapes, SD((b, s), jnp.int32), cache_shapes)
        shards = (p_shard, NamedSharding(mesh, P(bax, None)), c_shard)
    else:  # decode: one new token against a full cache
        def step(params, token, cache):
            return tf.decode_step(params, token, cache, s - 1, cfg, mesh)

        args = (p_shapes, SD((b, 1), jnp.int32), cache_shapes)
        shards = (p_shard, NamedSharding(mesh, P(bax, None)), c_shard)

    return CellProgram(
        name=f"{spec.name}:{shape.name}", fn=step, args=args,
        in_shardings=shards, meta=_lm_flops(cfg, shape),
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _gnn_counts(shape: ShapeSpec, n_dev: int, smoke: bool):
    if shape.kind == "minibatch":
        b = shape.batch_nodes if not smoke else 32
        f1, f2 = shape.fanouts
        nodes = b + b * f1 + b * f1 * f2
        edges = b * f1 + b * f1 * f2
    elif shape.kind == "batched_graphs":
        g = shape.batch_graphs if not smoke else 4
        nodes = g * shape.n_nodes
        edges = g * shape.n_edges * 2
    else:
        nodes = shape.n_nodes if not smoke else min(shape.n_nodes, 256)
        edges = shape.n_edges * 2 if not smoke else min(shape.n_edges, 512)
    return _pad_to(nodes, n_dev), _pad_to(edges, n_dev)


def _gnn_flops(cfg: gnn_mod.GNNConfig, nodes: int, edges: int, train: bool) -> Dict[str, float]:
    d = cfg.d_hidden
    if cfg.arch == "equiformer_v2":
        dim = (cfg.l_max + 1) ** 2
        per_edge = 2 * dim * dim * d + 2 * 3 * (cfg.m_max * 2 + 1) * d * d * dim
        per_node = 2 * d * d * 2
    elif cfg.arch == "meshgraphnet":
        per_edge = 2 * (3 * d) * d + 2 * d * d
        per_node = 2 * (2 * d) * d + 2 * d * d
    elif cfg.arch == "gatedgcn":
        per_edge = 2 * 3 * d * d
        per_node = 2 * 2 * d * d
    else:  # graphsage
        per_edge = 2 * d
        per_node = 2 * 2 * d * d
    fwd = cfg.n_layers * (edges * per_edge + nodes * per_node)
    useful = 3.0 * fwd if train else fwd
    n_params = 0
    return {"model_flops": float(useful), "params": float(n_params), "active_params": float(n_params)}


def _gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh, smoke: bool) -> CellProgram:
    base: gnn_mod.GNNConfig = spec.smoke if smoke else spec.config
    d_feat = shape.d_feat if not smoke else base.d_in
    cfg = dataclasses.replace(base, d_in=d_feat)
    n_dev = int(np.prod(list(mesh.shape.values())))
    nodes, edges = _gnn_counts(shape, n_dev, smoke)

    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda: gnn_mod.init_params(cfg, key))
    p_specs = gnn_mod.param_specs(cfg, mesh.axis_names)
    p_shard = _ns_tree(mesh, p_specs, p_shapes)

    all_ax = tuple(mesh.axis_names)
    g_shapes = gnn_mod.GraphData(
        x=SD((nodes, cfg.d_in), jnp.float32),
        src=SD((edges,), jnp.int32),
        dst=SD((edges,), jnp.int32),
        edge_attr=SD((edges, max(cfg.d_edge_in, 1)), jnp.float32),
        node_mask=SD((nodes,), jnp.bool_),
        edge_mask=SD((edges,), jnp.bool_),
        positions=SD((nodes, 3), jnp.float32),
    )
    g_specs = gnn_mod.graph_specs(mesh.axis_names)
    g_shard = _ns_tree(mesh, g_specs, g_shapes)

    o_shapes = jax.eval_shape(lambda: adamw_init(p_shapes))
    o_shard = _ns_tree(
        mesh,
        type(o_shapes)(step=P(), mu=_zero1_specs(p_specs, p_shapes, mesh),
                       nu=_zero1_specs(p_specs, p_shapes, mesh)),
        o_shapes,
    )

    def step(params, opt, graph, labels):
        def loss_fn(p):
            out = gnn_mod.forward(p, graph, cfg, backend="ref", mesh=mesh)
            if cfg.d_out > 1:
                lse = jax.nn.logsumexp(out.astype(jnp.float32), axis=-1)
                ll = jnp.take_along_axis(out.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
                per = lse - ll
            else:
                per = (out[:, 0].astype(jnp.float32) - labels.astype(jnp.float32)) ** 2
            return jnp.sum(per * graph.node_mask) / jnp.maximum(graph.node_mask.sum(), 1)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt2, gnorm = adamw_update(params, grads, opt, 1e-3)
        return params2, opt2, loss, gnorm

    labels = SD((nodes,), jnp.int32)
    args = (p_shapes, o_shapes, g_shapes, labels)
    shards = (p_shard, o_shard, g_shard, NamedSharding(mesh, _fix_spec(P(all_ax), (nodes,), mesh)))
    return CellProgram(
        name=f"{spec.name}:{shape.name}", fn=step, args=args,
        in_shardings=shards, meta=_gnn_flops(cfg, nodes, edges, True),
    )


# ---------------------------------------------------------------------------
# DLRM cells
# ---------------------------------------------------------------------------

def _dlrm_flops(cfg: dlrm_mod.DLRMConfig, batch: int, train: bool) -> Dict[str, float]:
    dims_b = (cfg.n_dense,) + cfg.bot_mlp
    dims_t = (cfg.n_interact + cfg.bot_mlp[-1],) + cfg.top_mlp
    mlp = sum(2 * a * b for a, b in zip(dims_b, dims_b[1:]))
    mlp += sum(2 * a * b for a, b in zip(dims_t, dims_t[1:]))
    inter = 2 * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
    per_ex = mlp + inter
    useful = batch * per_ex * (3.0 if train else 1.0)
    params = cfg.n_sparse * cfg.rows_per_table * cfg.embed_dim
    return {"model_flops": float(useful), "params": float(params), "active_params": float(params)}


def _dlrm_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh, smoke: bool) -> CellProgram:
    cfg: dlrm_mod.DLRMConfig = spec.smoke if smoke else spec.config
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda: dlrm_mod.init_params(cfg, key))
    p_specs = dlrm_mod.param_specs(cfg, mesh.axis_names)
    p_shard = _ns_tree(mesh, p_specs, p_shapes)
    b = shape.batch if not smoke else min(shape.batch, 64)
    bax = _batch_axes(b, mesh)

    if shape.kind == "recsys_train":
        o_shapes = jax.eval_shape(lambda: adamw_init(p_shapes))
        o_shard = _ns_tree(
            mesh,
            type(o_shapes)(step=P(), mu=_zero1_specs(p_specs, p_shapes, mesh),
                           nu=_zero1_specs(p_specs, p_shapes, mesh)),
            o_shapes,
        )

        def step(params, opt, dense, sparse, labels):
            def loss_fn(p):
                logits = dlrm_mod.forward(p, dense, sparse, cfg).astype(jnp.float32)
                return jnp.mean(
                    jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
                )

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params2, opt2, gnorm = adamw_update(params, grads, opt, 1e-3)
            return params2, opt2, loss, gnorm

        args = (
            p_shapes, o_shapes,
            SD((b, cfg.n_dense), jnp.float32),
            SD((b, cfg.n_sparse, cfg.multi_hot), jnp.int32),
            SD((b,), jnp.float32),
        )
        shards = (
            p_shard, o_shard,
            NamedSharding(mesh, P(bax, None)),
            NamedSharding(mesh, P(bax, None, None)),
            NamedSharding(mesh, P(bax)),
        )
        return CellProgram(name=f"{spec.name}:{shape.name}", fn=step, args=args,
                           in_shardings=shards, meta=_dlrm_flops(cfg, b, True))

    if shape.kind == "recsys_serve":
        def step(params, dense, sparse):
            return dlrm_mod.forward(params, dense, sparse, cfg)

        args = (
            p_shapes,
            SD((b, cfg.n_dense), jnp.float32),
            SD((b, cfg.n_sparse, cfg.multi_hot), jnp.int32),
        )
        shards = (p_shard, NamedSharding(mesh, P(bax, None)), NamedSharding(mesh, P(bax, None, None)))
        return CellProgram(name=f"{spec.name}:{shape.name}", fn=step, args=args,
                           in_shardings=shards, meta=_dlrm_flops(cfg, b, False))

    # retrieval: 1 query × n_candidates
    nc = shape.n_candidates if not smoke else 1024
    all_ax = tuple(mesh.axis_names)

    def step(params, dense, sparse, candidates):
        return dlrm_mod.retrieval_scores(params, dense, sparse, candidates, cfg)

    args = (
        p_shapes,
        SD((1, cfg.n_dense), jnp.float32),
        SD((1, cfg.n_sparse, cfg.multi_hot), jnp.int32),
        SD((nc,), jnp.int32),
    )
    shards = (
        p_shard,
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P(None, None, None)),
        NamedSharding(mesh, _fix_spec(P(all_ax), (nc,), mesh)),
    )
    return CellProgram(name=f"{spec.name}:{shape.name}", fn=step, args=args,
                       in_shardings=shards, meta=_dlrm_flops(cfg, nc, False))


# ---------------------------------------------------------------------------
# DDSL cells (the paper's own technique)
# ---------------------------------------------------------------------------

def _ddsl_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh, smoke: bool) -> CellProgram:
    from repro.core.cost import CostModel
    from repro.core.ddsl import choose_cover
    from repro.core.estimator import GraphStats
    from repro.core.join_tree import minimum_unit_decomposition, optimal_join_tree
    from repro.core.pattern import PATTERN_LIBRARY, symmetry_break
    from repro.dist import sharded
    from repro.dist.jax_engine import EngineCaps

    wl = spec.smoke if smoke else spec.config
    pattern = PATTERN_LIBRARY[wl.pattern]
    ord_ = symmetry_break(pattern)
    # Estimator statistics for a representative power-law graph (the cost
    # model only needs a degree histogram, not the graph itself).
    stats = GraphStats(n=1 << 20, m=8 << 20,
                       deg_hist=tuple(int(1e6 / (w ** 2.2) + 1) for w in range(1, 256)))
    cover = choose_cover(pattern, ord_, stats)
    model = CostModel(cover, ord_, stats)
    tree = optimal_join_tree(pattern, cover, model)
    prog = sharded.build_tree_program(tree, cover, ord_)
    m = int(np.prod(list(mesh.shape.values())))
    caps: EngineCaps = wl.caps
    pt_shapes = sharded.ddsl_input_specs(caps, m)
    pt_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), sharded.partition_specs(mesh))

    if shape.kind == "ddsl_list":
        fn = sharded.make_list_step(prog, mesh, caps)
        args = (pt_shapes,)
        shards = (pt_shard,)
    else:
        units = minimum_unit_decomposition(pattern, cover)
        fn = sharded.make_update_step(
            prog, units, mesh, caps, sharded.UpdateShapes(wl.n_add, wl.n_del)
        )
        args = (
            pt_shapes,
            SD((wl.n_add, 2), jnp.int32),
            SD((wl.n_del, 2), jnp.int32),
        )
        shards = (pt_shard, NamedSharding(mesh, P()), NamedSharding(mesh, P()))

    # Useful work ∝ candidate probes: match_cap × deg_cap per extension.
    k = pattern.n
    useful = float(m) * caps.match_cap * caps.deg_cap * (k - 1) * 4
    return CellProgram(
        name=f"{spec.name}:{shape.name}", fn=fn, args=args, in_shardings=shards,
        meta={"model_flops": useful, "params": 0.0, "active_params": 0.0},
    )


# ---------------------------------------------------------------------------

def build_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh, *, smoke: bool = False) -> CellProgram:
    if spec.family == "lm":
        return _lm_cell(spec, shape, mesh, smoke)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, mesh, smoke)
    if spec.family == "recsys":
        return _dlrm_cell(spec, shape, mesh, smoke)
    if spec.family == "ddsl":
        return _ddsl_cell(spec, shape, mesh, smoke)
    raise ValueError(spec.family)
