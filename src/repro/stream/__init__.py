"""repro.stream — continuous dynamic-update subgraph listing.

Turns the batch Alg. 4 machinery of :mod:`repro.core` /
:mod:`repro.dist` into a service::

    journal   append-only edge-op log: sequence numbers, watermarks,
              add/delete netting, replay, truncation
    scheduler cost-model-driven micro-batching + the per-batch
              SharedDelta (netted update, Φ(d'), stats, seed cache)
              computed once and shared by all registered patterns
    service   ListingService over a host or sharded backend:
              ingest() / advance() / counts() / audits / metrics
    sinks     incremental result delivery: count deltas, decompressed
              match deltas, callbacks
    plan_manager  drift-triggered online join-tree re-optimization:
              recompile from live stats via repro.planner, hot-swap at
              a committed watermark

Observability: every ``ListingService`` owns a
:class:`repro.obs.Observability` (``obs=`` constructor hook) — a typed
metrics registry, a hierarchical span tracer (off by default), and a
device profiler splitting compile from execute per jitted SPMD step.
The legacy process-global ``scheduler.PROBE`` dict survives as a
deprecation shim over a registry; isolated per-service counts live on
``service.obs.metrics``.
"""

from repro.obs import Observability

from .journal import JournalEntry, UpdateJournal
from .plan_manager import PlanManager, SwapEvent
from .scheduler import (
    PROBE,
    BatchScheduler,
    SharedDelta,
    compute_shared_delta,
    reset_probe,
)
from .service import (
    BatchMetrics,
    HostBackend,
    ListingService,
    PatternMeta,
    PatternReport,
    ShardedBackend,
    StreamBackend,
)
from .sinks import BatchEvent, CallbackSink, CountDeltaSink, MatchDeltaSink, Sink

__all__ = [
    "JournalEntry",
    "UpdateJournal",
    "PlanManager",
    "SwapEvent",
    "Observability",
    "PROBE",
    "reset_probe",
    "BatchScheduler",
    "SharedDelta",
    "compute_shared_delta",
    "BatchMetrics",
    "HostBackend",
    "ListingService",
    "PatternMeta",
    "PatternReport",
    "ShardedBackend",
    "StreamBackend",
    "BatchEvent",
    "CallbackSink",
    "CountDeltaSink",
    "MatchDeltaSink",
    "Sink",
]
