"""Match-delta subscriptions — results leave the service incrementally too.

Every committed micro-batch produces one :class:`BatchEvent` per
registered pattern. Sinks subscribe to the service and receive events as
they commit; a sink that sets ``wants_matches`` makes the service
materialize the *decompressed* new/removed match rows for its patterns
(otherwise only count deltas and reports travel, keeping the hot path
compressed end to end — the same discipline as the paper's VCBC story).

Sinks are also the *trigger* of the lazy device→host contract: on the
sharded backend the running match sets live on the mesh
(:class:`~repro.dist.sharded.MatchStore`), and only a ``wants_matches``
sink (or an explicit ``backend.materialize(name)`` call) pulls a table
to host — the pull is byte-accounted in ``BatchMetrics.host_bytes``.
Count-delta sinks ride entirely on the device count reduction: a
count-only batch moves scalars, never match state.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["BatchEvent", "Sink", "CountDeltaSink", "MatchDeltaSink", "CallbackSink"]


@dataclasses.dataclass(frozen=True)
class BatchEvent:
    """One (micro-batch, pattern) result delta."""

    batch_index: int
    lo: int                     # watermark range (lo, hi] of the batch
    hi: int
    pattern: str
    count_before: int
    count_after: int
    n_ops: int                  # journal ops in the window
    net_add: int                # netted inserts / deletes actually applied
    net_delete: int
    latency_s: float
    overflow: int = 0           # device-cap overflow (sharded backend)
    added: Optional[np.ndarray] = None    # [k, |V(p)|] decompressed new matches
    removed: Optional[np.ndarray] = None  # [k, |V(p)|] decompressed dead matches

    @property
    def count_delta(self) -> int:
        return self.count_after - self.count_before


class Sink:
    """Subscription base. Override :meth:`emit`; set ``wants_matches``
    to request decompressed added/removed rows on events."""

    wants_matches: bool = False

    def __init__(self, patterns: Optional[Sequence[str]] = None):
        self._patterns = set(patterns) if patterns is not None else None

    def accepts(self, pattern: str) -> bool:
        return self._patterns is None or pattern in self._patterns

    def emit(self, event: BatchEvent) -> None:  # pragma: no cover - interface
        raise NotImplementedError


class CountDeltaSink(Sink):
    """Accumulates per-pattern count deltas; the cheapest subscription."""

    def __init__(self, patterns: Optional[Sequence[str]] = None):
        super().__init__(patterns)
        self.events: List[Tuple[str, int, int]] = []  # (pattern, hi, delta)
        self.totals: dict = {}

    def emit(self, event: BatchEvent) -> None:
        self.events.append((event.pattern, event.hi, event.count_delta))
        self.totals[event.pattern] = self.totals.get(event.pattern, 0) + event.count_delta


class MatchDeltaSink(Sink):
    """Collects the decompressed new/removed match rows per batch."""

    wants_matches = True

    def __init__(self, patterns: Optional[Sequence[str]] = None):
        super().__init__(patterns)
        self.added: List[Tuple[str, int, np.ndarray]] = []    # (pattern, hi, rows)
        self.removed: List[Tuple[str, int, np.ndarray]] = []

    def emit(self, event: BatchEvent) -> None:
        if event.added is not None and event.added.shape[0]:
            self.added.append((event.pattern, event.hi, event.added))
        if event.removed is not None and event.removed.shape[0]:
            self.removed.append((event.pattern, event.hi, event.removed))

    def added_rows(self, pattern: str) -> np.ndarray:
        rows = [r for p, _, r in self.added if p == pattern]
        return np.concatenate(rows, axis=0) if rows else np.empty((0, 0), np.int64)

    def removed_rows(self, pattern: str) -> np.ndarray:
        rows = [r for p, _, r in self.removed if p == pattern]
        return np.concatenate(rows, axis=0) if rows else np.empty((0, 0), np.int64)


class CallbackSink(Sink):
    """Adapts a plain callable; ``wants_matches`` is per-instance."""

    def __init__(self, fn: Callable[[BatchEvent], None],
                 patterns: Optional[Sequence[str]] = None,
                 wants_matches: bool = False):
        super().__init__(patterns)
        self._fn = fn
        self.wants_matches = bool(wants_matches)

    def emit(self, event: BatchEvent) -> None:
        self._fn(event)
