"""Append-only update journal — the durable front door of ``repro.stream``.

Every edge operation (insert or delete) ingested into the streaming
service is recorded as one :class:`JournalEntry` with a monotonically
increasing *sequence number*. A **watermark** ``w`` names the prefix of
the stream with ``seq ≤ w``; the service's *committed* watermark is the
prefix already folded into the match sets.

The journal is where batch semantics come from:

- :meth:`UpdateJournal.window` nets the operations of a ``(lo, hi]``
  window into one canonical :class:`~repro.core.graph.GraphUpdate`. For
  a well-formed stream (deletes target present edges, inserts target
  absent edges — both relative to the state at ``lo``) the operations on
  one edge strictly alternate, so the net effect is parity: an even
  number of touches cancels (insert→delete or delete→insert nets out),
  an odd number reduces to the first (= last) operation kind. Netting
  is what makes multi-ingest windows valid Alg.-4 batches: the netted
  update never deletes a missing edge or inserts a present one.
- :meth:`UpdateJournal.replay` is ``window`` from an arbitrary
  watermark, used for recovery and for from-scratch audits.
- :meth:`UpdateJournal.truncate` drops entries at or below a durable
  watermark so the journal stays bounded while the stream is infinite.
- :meth:`UpdateJournal.save` / :meth:`UpdateJournal.load` persist the
  log as JSONL (one header + one line per op, watermark-aware) so a
  service can restart from a durable journal: load, rebuild state by
  replaying from the committed watermark, keep ingesting. Truncation
  state survives the round-trip.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.graph import GraphUpdate, decode_edges, edge_codes

__all__ = ["OP_ADD", "OP_DELETE", "JournalEntry", "UpdateJournal"]

OP_ADD = 1
OP_DELETE = -1


@dataclasses.dataclass(frozen=True)
class JournalEntry:
    """One edge operation: ``op`` is :data:`OP_ADD` or :data:`OP_DELETE`."""

    seq: int
    op: int
    code: int  # int64 edge code (min << 32 | max)

    def edge(self) -> Tuple[int, int]:
        e = decode_edges(np.array([self.code], np.int64))[0]
        return int(e[0]), int(e[1])


class UpdateJournal:
    """Append-only, watermarked edge-operation log with replay."""

    def __init__(self) -> None:
        self._seqs: List[int] = []
        self._ops: List[int] = []
        self._codes: List[int] = []
        self._tail = 0        # seq of the last appended op
        self._base = 0        # all ops with seq <= _base have been truncated

    # ------------------------------------------------------------------ write
    def append(self, update: GraphUpdate) -> int:
        """Record one :class:`GraphUpdate` (deletes first, then adds).

        Returns the new tail watermark. Ordering inside one update is
        irrelevant to netting — ``E_d`` and ``E_a`` are disjoint by
        contract — but deletes-first matches the batch semantics of
        :func:`repro.core.graph.Graph.apply_update`.
        """
        return self.append_edges(delete=np.asarray(update.delete),
                                 add=np.asarray(update.add))

    def append_edges(
        self,
        *,
        delete: Iterable[Sequence[int]] | np.ndarray = (),
        add: Iterable[Sequence[int]] | np.ndarray = (),
    ) -> int:
        dele = np.asarray(list(delete) if not isinstance(delete, np.ndarray) else delete,
                          np.int64).reshape(-1, 2)
        adds = np.asarray(list(add) if not isinstance(add, np.ndarray) else add,
                          np.int64).reshape(-1, 2)
        for op, edges in ((OP_DELETE, dele), (OP_ADD, adds)):
            for code in edge_codes(edges):
                self._tail += 1
                self._seqs.append(self._tail)
                self._ops.append(op)
                self._codes.append(int(code))
        return self._tail

    # ------------------------------------------------------------------- read
    @property
    def tail(self) -> int:
        return self._tail

    @property
    def base(self) -> int:
        """Truncation watermark: entries with ``seq ≤ base`` are gone."""
        return self._base

    def __len__(self) -> int:
        return len(self._seqs)

    def pending(self, watermark: int) -> int:
        """Number of operations with ``seq > watermark``."""
        return max(self._tail - max(watermark, self._base), 0)

    def _slice(self, lo: int, hi: int | None):
        """Index range of ops with ``lo < seq ≤ hi`` — sequence numbers
        are consecutive, so a window is a list slice, not a scan."""
        hi = self._tail if hi is None else min(hi, self._tail)
        if lo < self._base:
            raise ValueError(f"window start {lo} precedes truncation base {self._base}")
        return max(lo, self._base) - self._base, max(hi, self._base) - self._base

    def entries(self, lo: int = 0, hi: int | None = None) -> List[JournalEntry]:
        i, j = self._slice(lo, hi)
        return [JournalEntry(s, o, c)
                for s, o, c in zip(self._seqs[i:j], self._ops[i:j], self._codes[i:j])]

    def window(self, lo: int, hi: int | None = None) -> GraphUpdate:
        """Net the ops with ``lo < seq ≤ hi`` into one canonical update.

        Per edge code: an even number of touches cancels, an odd number
        nets to the kind of the first touch in the window.
        """
        i, j = self._slice(lo, hi)
        first_op: dict = {}
        count: dict = {}
        for o, c in zip(self._ops[i:j], self._codes[i:j]):
            if c not in count:
                count[c] = 0
                first_op[c] = o
            count[c] += 1
        dels = sorted(c for c, k in count.items() if k % 2 and first_op[c] == OP_DELETE)
        adds = sorted(c for c, k in count.items() if k % 2 and first_op[c] == OP_ADD)
        return GraphUpdate(
            delete=decode_edges(np.asarray(dels, np.int64)),
            add=decode_edges(np.asarray(adds, np.int64)),
        )

    def replay(self, watermark: int = 0, hi: int | None = None) -> GraphUpdate:
        """Alias of :meth:`window` with recovery naming: everything after
        ``watermark`` (up to ``hi``) as one netted update."""
        return self.window(watermark, hi)

    # ---------------------------------------------------------------- durable
    _MAGIC = "repro.stream.journal"

    def save(self, path: str) -> int:
        """Persist the journal as JSONL; returns the entry count written.

        Line 1 is a header carrying the truncation base and the tail
        watermark; every further line is one edge operation. The write
        is atomic (temp file + ``os.replace``) so a crash mid-save
        leaves the previous durable copy intact — and if a torn file
        does appear some other way, :meth:`load` rejects it loudly
        (sequence gap vs the header), never replaying a silently
        shorter stream.
        """
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            f.write(json.dumps({"kind": self._MAGIC, "version": 1,
                                "base": self._base, "tail": self._tail}) + "\n")
            for s, o, c in zip(self._seqs, self._ops, self._codes):
                f.write(json.dumps({"seq": s, "op": o, "code": c}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return len(self._seqs)

    @classmethod
    def load(cls, path: str) -> "UpdateJournal":
        """Rebuild a journal saved by :meth:`save` (integrity-checked:
        header magic, op kinds, and gapless ``base+1 … tail`` sequence
        numbers — corruption raises instead of replaying wrongly)."""
        j = cls()
        with open(path) as f:
            head = json.loads(f.readline())
            if head.get("kind") != cls._MAGIC:
                raise ValueError(f"{path} is not a journal file")
            if head.get("version") != 1:
                raise ValueError(
                    f"{path}: unsupported journal version {head.get('version')!r} "
                    "(this reader understands version 1)")
            for line in f:
                if not line.strip():
                    continue
                e = json.loads(line)
                if e["op"] not in (OP_ADD, OP_DELETE):
                    raise ValueError(f"corrupt journal entry op={e['op']!r}")
                j._seqs.append(int(e["seq"]))
                j._ops.append(int(e["op"]))
                j._codes.append(int(e["code"]))
        j._base = int(head["base"])
        j._tail = int(head["tail"])
        if j._seqs != list(range(j._base + 1, j._tail + 1)):
            raise ValueError(
                f"corrupt journal {path}: expected seqs ({j._base}, {j._tail}], "
                f"got {len(j._seqs)} entries")
        return j

    # ------------------------------------------------------------------ bound
    def truncate(self, up_to: int) -> int:
        """Drop entries with ``seq ≤ up_to``; returns #entries dropped.

        The caller must only truncate at or below its committed
        watermark — replay below ``up_to`` becomes impossible.
        """
        up_to = min(up_to, self._tail)
        if up_to <= self._base:
            return 0
        cut = up_to - self._base
        dropped = min(cut, len(self._seqs))
        self._seqs = self._seqs[cut:]
        self._ops = self._ops[cut:]
        self._codes = self._codes[cut:]
        self._base = up_to
        return dropped
