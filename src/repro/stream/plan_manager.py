"""Drift-triggered online join-tree re-optimization.

DDSL picks the optimal join tree once, from initial
:class:`~repro.core.estimator.GraphStats` — on a drifting stream the
tree goes stale. :class:`PlanManager` closes the loop the scheduler's
§IV-D monitor opened: every committed batch it reads the
observed/predicted drift EWMA (``scheduler_drift_ewma``), and when it
crosses ``drift_threshold`` — or every ``recost_every`` watermarks as a
slow heartbeat — it re-runs the staged plan compiler
(:func:`repro.planner.compile_plan`, via the backend's single
``compile`` entry point) from *live* stats and compares the candidate
against the incumbent **re-costed under the same live stats** (Eq. 11 is
only comparable at one stats snapshot).

A winning candidate is hot-swapped at the committed watermark — the only
collective-safe point — without any from-scratch listing::

    materialize(name)            # running table, device pulls byte-accounted
    recompress under new cover   # exact: a vertex cover touches every
                                 # edge, so VCBC regrouping loses nothing
    remove_pattern(name)
    install_plan(name, cand, table)   # host: new DDSL around the same
                                 # table; sharded: stack_matches + one
                                 # unit-carry refresh
    scheduler re-register + reset_drift()

The swap is delta-cheap (one table regroup + one carry refresh, no
re-listing) and byte-verified in tests against ``DDSL.initial()`` on the
replayed graph. Observability: ``plan_recompiles_total`` /
``plan_swaps_total`` counters, a ``plan_swap`` span, and the new plan's
dump re-recorded for the export bundle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

from repro.core.cost import CostModel
from repro.core.estimator import GraphStats
from repro.core.join_tree import JoinTree
from repro.core.vcbc import compress_table
from repro.planner.sizing import wcoj_prefix_estimates

__all__ = ["PlanManager", "SwapEvent", "recost_tree"]


def recost_tree(tree: JoinTree, cover: Sequence[int],
                ord_: Sequence[Tuple[int, int]], stats: GraphStats) -> float:
    """Eq. 11 cost of a *fixed* tree under fresh stats — what the
    incumbent plan would cost if compiled today. The DP's stored
    ``tree.cost`` froze the registration-time stats; comparing it
    directly against a live-stats candidate would conflate graph growth
    with plan quality."""
    model = CostModel(cover, ord_, stats)

    def rec(jt: JoinTree) -> float:
        if jt.is_leaf:
            return model.leaf_cost(jt.pattern)
        cl, cr = rec(jt.left), rec(jt.right)
        return model.join_cost(jt.pattern, jt.left.pattern, jt.right.pattern, cl, cr)

    return rec(tree)


@dataclasses.dataclass(frozen=True)
class SwapEvent:
    """One re-optimization decision (kept whether or not it swapped)."""

    batch_index: int
    pattern: str
    trigger: str                 # "drift" | "periodic"
    drift: Optional[float]
    incumbent_cost: float        # incumbent tree re-costed at live stats
    candidate_cost: float
    swapped: bool
    count: Optional[int] = None  # match count after the swap (unchanged!)
    elapsed_s: float = 0.0


class PlanManager:
    """Recompile-and-maybe-swap policy over a running ListingService.

    ``drift_threshold`` — fire when the scheduler's drift EWMA (observed
    / predicted latency) exceeds this; drift ≈ 1.0 means the §IV-D model
    still describes the stream, sustained excursions mean the stats the
    incumbent plan was costed on no longer do. ``recost_every`` — also
    fire unconditionally every K committed batches (0 disables the
    heartbeat). ``improvement`` — swap only when the candidate's Eq. 11
    cost is below ``improvement ×`` the incumbent's live re-cost, so
    estimator noise can't thrash plans. ``objective`` — the free-cover
    policy for candidate compiles: default ``"cost"`` (Eq. 11 runtime
    argmin over all valid covers — a drifted stream is re-planned to run
    fast), or ``"r_lower"`` to keep §IV-F's storage objective. ``verify``
    — after each swap, run the service's from-scratch audit for the
    swapped pattern (expensive; tests and paranoid deployments).
    """

    def __init__(self, drift_threshold: float = 1.5, recost_every: int = 16,
                 improvement: float = 0.95, objective: str = "cost",
                 verify: bool = False):
        self.drift_threshold = float(drift_threshold)
        self.recost_every = int(recost_every)
        self.improvement = float(improvement)
        self.objective = str(objective)
        self.verify = bool(verify)
        self.events: List[SwapEvent] = []
        self._batches_seen = 0
        self._last_recost = 0

    # ------------------------------------------------------------------ hook
    def on_batch(self, service) -> List[SwapEvent]:
        """Called by :meth:`ListingService.advance` after each committed
        batch; returns the decisions made now (also kept in ``events``)."""
        self._batches_seen += 1
        drift = service.scheduler.drift()
        if drift is not None and drift >= self.drift_threshold:
            trigger = "drift"
        elif (self.recost_every > 0
              and self._batches_seen - self._last_recost >= self.recost_every):
            trigger = "periodic"
        else:
            return []
        self._last_recost = self._batches_seen
        return self.reoptimize(service, trigger=trigger, drift=drift)

    # ---------------------------------------------------------------- recost
    def reoptimize(self, service, trigger: str = "manual",
                   drift: Optional[float] = None) -> List[SwapEvent]:
        """Recompile every registered pattern from live stats and swap
        the ones whose candidate plan beats the incumbent."""
        backend = service.backend
        stats = GraphStats.of(service.graph)
        out: List[SwapEvent] = []
        for name in list(backend.names()):
            incumbent = backend.plan(name)
            if incumbent is None:
                continue
            t0 = time.perf_counter()
            # Free-cover recompile: drift may have moved the optimal
            # cover too, not just the tree shape.
            cand = backend.compile(incumbent.pattern, cover=None, stats=stats,
                                   objective=self.objective)
            service.obs.metrics.counter(
                "plan_recompiles_total",
                "staged-compiler runs from live stats (drift/periodic/manual)",
            ).inc()
            if incumbent.executor == "wcoj":
                # The incumbent runs the generic join — its live cost is
                # the WCOJ prefix-estimate sum, the same quantity the
                # compiler's executor pass minimizes, not the Eq. 11
                # tree cost it replaced.
                inc_cost = float(sum(wcoj_prefix_estimates(
                    incumbent.pattern, incumbent.wcoj.order,
                    incumbent.ord, stats)))
            else:
                inc_cost = recost_tree(incumbent.tree, incumbent.cover,
                                       incumbent.ord, stats)
            better = (cand.plan_key() != incumbent.plan_key()
                      and cand.cost < self.improvement * inc_cost)
            ev = SwapEvent(
                batch_index=service.committed_watermark, pattern=name,
                trigger=trigger, drift=drift,
                incumbent_cost=inc_cost, candidate_cost=cand.cost,
                swapped=better,
            )
            if better:
                count = self._swap(service, name, incumbent, cand, ev)
                ev = dataclasses.replace(
                    ev, count=count, elapsed_s=time.perf_counter() - t0)
            self.events.append(ev)
            out.append(ev)
        return out

    # ------------------------------------------------------------------ swap
    def _swap(self, service, name: str, incumbent, cand, ev: SwapEvent) -> int:
        backend = service.backend
        with service.obs.tracer.span(
                "plan_swap", pattern=name, trigger=ev.trigger) as sp:
            before = backend.count(name)
            table = backend.materialize(name)
            if table.cover != cand.storage_cover:
                # VCBC compression is exact under ANY vertex cover (a
                # cover touches every edge), so regrouping the running
                # table under the new *storage* cover loses nothing — no
                # re-listing, just a host-side group-by. Executor-mode
                # swaps land here too: WCOJ stores trivially compressed
                # (storage cover = every pattern vertex), so tree↔wcoj
                # is the same exact regroup.
                cols, plain = table.decompress(incumbent.ord)
                table = compress_table(cand.pattern, cand.storage_cover,
                                       cols, plain)
            backend.remove_pattern(name)
            count = backend.install_plan(name, cand, table)
            if count != before:
                raise RuntimeError(
                    f"plan swap changed the match count for {name!r}: "
                    f"{before} -> {count} (swap must be a pure re-plan)")
            service.scheduler.unregister(name)
            service.scheduler.register(name, cand.pattern, cand.ord, cand.units)
            service.scheduler.refresh(cand.stats)
            # The drift EWMA measured the *old* plan's predictions;
            # carrying it over would instantly re-fire against the new.
            service.scheduler.reset_drift()
            service.obs.record_plan(name, cand.to_json())
            service.obs.metrics.counter(
                "plan_swaps_total",
                "join-tree plans hot-swapped at a committed watermark",
            ).inc()
            sp.add("incumbent_cost", int(ev.incumbent_cost))
            sp.add("candidate_cost", int(ev.candidate_cost))
            sp.add("count", count)
        if self.verify:
            service.audit([name])
        return count
