"""Adaptive micro-batching + the per-batch **shared update delta**.

Two jobs:

1. :class:`BatchScheduler` picks batch boundaries. The *model* half uses
   the paper's §IV-D PR estimator: the expected number of Nav-join seed
   matches per inserted edge for unit ``q`` is ``|E(q)|·E|M(q,d)|/|E(d)|``
   (each unit edge is equally likely to be the one mapped onto the
   insert), and each seed is pushed through a chain of ``len(units)-1``
   CC-joins — summed over units and registered patterns this gives a
   per-operation work estimate in "cost units" (integers touched, the
   same currency as :mod:`repro.core.cost`). The *measurement* half
   calibrates cost units to wall-clock with an EWMA of observed batch
   latency, so a latency target turns into a batch size that tracks the
   actual hardware and the actual graph.

2. :func:`compute_shared_delta` decodes one journal window into a
   :class:`SharedDelta` — netted update, sorted edge codes, and (lazily)
   the updated NP storage Φ(d'), fresh :class:`GraphStats`, and memoized
   per-unit Nav-join seed listings. The delta is computed **once per
   batch** and handed to every registered pattern; :data:`PROBE`
   counters make "once" an assertable fact rather than a comment
   (``tests/test_stream.py`` checks them).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimator import GraphStats, match_size_estimate
from repro.core.graph import GraphUpdate
from repro.core.match_engine import list_matches
from repro.core.pattern import Pattern, R1Unit
from repro.core.storage import NPStorage, UpdateCostReport
from repro.core.unit_cache import (
    PartitionUnitCache,
    _restrict_ord,
    require_edge_rows,
)
from repro.core.vcbc import CompressedTable, compress_table

from repro.obs.metrics import MetricsRegistry, ProbeView

from .journal import UpdateJournal

__all__ = ["PROBE", "reset_probe", "SharedDelta", "compute_shared_delta", "BatchScheduler"]


# Instrumentation counters: how many times per-batch work actually ran.
# The multi-pattern service tests assert these advance by exactly one
# per micro-batch no matter how many patterns are registered.
#
# ``PROBE`` keys and what they count:
#
# - ``delta_decodes``     — journal window → netted GraphUpdate
# - ``storage_updates``   — Φ(d) → Φ(d') (Alg. 4)
# - ``stats_refreshes``   — GraphStats.of(d')
# - ``seed_listings``     — per-unit Nav-join seed *derivations* (one per
#                           distinct unit per batch; with a unit cache
#                           the actual listings behind them are
#                           cache_misses)
# - ``host_materializations`` — device→host pulls of a sharded backend's
#                           running match set (`StreamBackend.materialize`).
#                           Count-only batches must not advance this —
#                           the match sets stay on the mesh end to end.
# - ``cache_hits`` / ``cache_misses`` / ``invalidated_parts`` —
#                           delta-maintained unit-table cache traffic
#                           (core.unit_cache / the sharded per-device
#                           carries). On a warm stream, cache_misses per
#                           batch is bounded by |units| · |dirty parts|,
#                           not |units| · m — asserted in tests.
#
# **Deprecated surface.** ``PROBE`` is now a :class:`~repro.obs.metrics.ProbeView`
# — a dict-shaped shim over a module-level legacy registry — kept so
# existing tests/scripts using ``PROBE["k"]`` / ``reset_probe()`` work
# unchanged. It is still process-global: two ``ListingService`` instances
# in one process both advance it (aggregate view). *Isolated* counts
# live on each service's own registry (``service.obs.metrics``, names
# like ``stream_storage_updates_total`` / ``unit_cache_hits_total``) —
# new code should read those. Reset semantics are explicit:
# :func:`reset_probe` zeroes exactly these eight global counters and
# never touches any service's registry.
_PROBE_KEYS = (
    "delta_decodes",
    "storage_updates",
    "stats_refreshes",
    "seed_listings",
    "host_materializations",
    "cache_hits",
    "cache_misses",
    "invalidated_parts",
)

#: metric name each PROBE key mirrors into a per-service registry
PROBE_METRIC_NAMES: Dict[str, str] = {
    "delta_decodes": "stream_delta_decodes_total",
    "storage_updates": "stream_storage_updates_total",
    "stats_refreshes": "stream_stats_refreshes_total",
    "seed_listings": "stream_seed_listings_total",
    "host_materializations": "stream_host_materializations_total",
    "cache_hits": "unit_cache_hits_total",
    "cache_misses": "unit_cache_misses_total",
    "invalidated_parts": "unit_cache_invalidated_parts_total",
}

_LEGACY_REGISTRY = MetricsRegistry()
PROBE: ProbeView = ProbeView(_LEGACY_REGISTRY, _PROBE_KEYS)


def reset_probe() -> None:
    """Zero the global legacy ``PROBE`` counters (and nothing else)."""
    PROBE.reset()


def probe_inc(key: str, n: int = 1,
              metrics: Optional[MetricsRegistry] = None) -> None:
    """Advance a legacy ``PROBE`` counter and, when a per-service
    registry is given, its isolated mirror counter too."""
    PROBE._inc(key, n)
    if metrics is not None:
        metrics.counter(PROBE_METRIC_NAMES[key],
                        f"per-service mirror of PROBE[{key!r}]").inc(n)


@dataclasses.dataclass
class SharedDelta:
    """Everything derivable from one journal window, computed once.

    ``storage``/``stats`` are filled lazily by :meth:`ensure_storage`
    (the host backend calls it; the sharded backend applies the update
    on device and never materializes a host Φ(d')). ``seed_provider``
    returns a ``seed_fn`` for :func:`repro.core.navjoin.nav_join_patch`
    that memoizes the *plain* per-unit seed tables across patterns —
    keyed by (unit pattern, anchor, restricted ord), so two patterns
    sharing a triangle unit list its seeds once.
    """

    lo: int
    hi: int
    update: GraphUpdate
    add_codes: np.ndarray
    delete_codes: np.ndarray
    storage: Optional[NPStorage] = None
    storage_report: Optional[UpdateCostReport] = None
    stats: Optional[GraphStats] = None
    #: the owning service's registry — per-batch work counters mirror
    #: into it alongside the legacy global ``PROBE`` (None = global only)
    metrics: Optional[MetricsRegistry] = None
    _seed_plain: Dict[Tuple, Tuple[Tuple[int, ...], np.ndarray]] = dataclasses.field(default_factory=dict)

    @property
    def n_ops(self) -> int:
        return self.hi - self.lo

    @property
    def net_size(self) -> int:
        return self.update.size

    def ensure_storage(self, storage: NPStorage) -> NPStorage:
        """Φ(d) → Φ(d') exactly once per batch, shared across patterns.

        A window that nets to the empty update is a no-op: Φ(d') is
        Φ(d) itself, so no storage update (and no ``PROBE`` advance)
        happens — the watermark still moves, but nothing is recomputed.
        """
        if self.storage is None:
            if self.update.size == 0:
                self.storage = storage
                return self.storage
            self.storage, self.storage_report = storage.updated(self.update)
            probe_inc("storage_updates", metrics=self.metrics)
            self.stats = GraphStats.of(self.storage.graph)
            probe_inc("stats_refreshes", metrics=self.metrics)
        return self.storage

    def seed_provider(self, cover: Sequence[int], ord_: Sequence[Tuple[int, int]],
                      cache: "PartitionUnitCache | None" = None):
        """A memoizing Nav-join ``seed_fn`` for one pattern's (cover, ord).

        The plain (uncompressed) seed tables are shared across patterns;
        only the cheap VCBC regrouping is cover-specific. With ``cache``
        (the backend's delta-maintained
        :class:`~repro.core.unit_cache.PartitionUnitCache`, already
        advanced to this batch's Φ(d')) the seeds are *derived* from the
        cached full per-partition unit tables by the inserted-edge row
        filter — re-listing only the partitions this delta invalidated
        instead of all ``m`` (byte-identical either way: the engine
        applies ``require_edge_codes`` as the same post-filter).
        """
        if self.storage is None:
            raise RuntimeError("call ensure_storage() before seed_provider()")
        if cache is not None and cache.storage is not self.storage:
            raise RuntimeError("unit cache is bound to a different Φ(d') "
                               "than this delta — advance() it first")
        storage = self.storage
        cover_t = tuple(sorted(int(c) for c in cover))
        ins_codes = self.add_codes
        sorted_codes = np.sort(np.asarray(ins_codes, np.int64).reshape(-1))

        def seed_fn(unit: R1Unit) -> CompressedTable:
            anchor = unit.anchor_in(cover_t)
            if anchor is None:
                raise ValueError("unit anchor must lie inside the cover")
            # Canonical memo key: the listing depends on the unit
            # pattern, the anchor, and the *set* of ord pairs restricted
            # to the unit's vertices (ord checks are conjunctive, so
            # pair order is irrelevant). Anything less (dropping the
            # anchor or the restricted ord) would serve a stale table to
            # a pattern sharing the unit shape; anything order-sensitive
            # would miss legitimate sharing across patterns.
            # _restrict_ord (shared with the unit cache, so the memo key
            # and the cache key can never diverge) already yields the
            # canonical frozenset.
            key = (unit.pattern.key(), anchor,
                   _restrict_ord(ord_, unit.pattern.vertices))
            if key not in self._seed_plain:
                probe_inc("seed_listings", metrics=self.metrics)
                cols: Tuple[int, ...] | None = None
                pieces = []
                for pi, part in enumerate(storage.parts):
                    if cache is not None:
                        cols, t = cache.unit_plain(pi, unit, anchor, ord_)
                        t = require_edge_rows(cols, t, unit.pattern, sorted_codes)
                    else:
                        cols, t = list_matches(
                            part, unit.pattern, ord_, anchor=anchor,
                            anchor_to_centers=True, require_edge_codes=ins_codes,
                        )
                    pieces.append(t)
                table = (np.concatenate(pieces, axis=0) if pieces
                         else np.empty((0, unit.pattern.n), np.int64))
                self._seed_plain[key] = (cols, table)
            cols, table = self._seed_plain[key]
            return compress_table(unit.pattern, cover_t, cols, table)

        return seed_fn


def compute_shared_delta(journal: UpdateJournal, lo: int, hi: int,
                         metrics: Optional[MetricsRegistry] = None) -> SharedDelta:
    """Decode one ``(lo, hi]`` journal window into a :class:`SharedDelta`."""
    update = journal.window(lo, hi)
    probe_inc("delta_decodes", metrics=metrics)
    return SharedDelta(
        lo=lo, hi=hi, update=update,
        add_codes=update.add_codes(), delete_codes=update.delete_codes(),
        metrics=metrics,
    )


@dataclasses.dataclass
class _PatternCost:
    pattern: Pattern
    ord_: Tuple[Tuple[int, int], ...]
    units: Tuple[R1Unit, ...]
    per_op: float = 1.0   # marginal cost of one more journal op in a batch
    fixed: float = 0.0    # batch-size-independent cost (chain unit listings)


class BatchScheduler:
    """Cost-model-seeded, latency-calibrated micro-batch sizing.

    ``target_cost`` is the per-batch work budget in estimator cost
    units; ``target_latency_s`` (optional) further shrinks batches once
    wall-clock observations exist. ``max_ops`` is the hard ceiling —
    the sharded backend sets it to its static ``UpdateShapes`` so a
    batch always fits the compiled device step.

    The `fixed` term of the §IV-D model (chain-step unit listings) is
    split into **cold** and **warm** halves: *cold* assumes every unit
    table is re-listed per batch (a cache-less backend, or one whose
    cache a batch fully invalidated), *warm* scales it by the miss rate
    the backend actually observes on its delta-maintained unit-table
    cache (:meth:`observe_cache`). On a steady-state stream where
    deltas dirty few partitions, warm `fixed` → ~0, so the budget binds
    on the marginal ``per_op`` term and micro-batches can shrink at
    constant throughput instead of being forced wide to amortize
    re-listing.
    """

    def __init__(
        self,
        target_cost: float = 250_000.0,
        target_latency_s: float | None = None,
        min_ops: int = 1,
        max_ops: int = 256,
    ):
        # Degenerate configs (0/negative bounds, zero budget) must not
        # collapse the batch size to 0 — that would spin advance()
        # forever — nor let it explode past the static device shapes.
        self.target_cost = max(float(target_cost), 1.0)
        self.target_latency_s = target_latency_s
        self.min_ops = max(1, int(min_ops))
        self.max_ops = max(self.min_ops, int(max_ops))
        self._patterns: Dict[str, _PatternCost] = {}
        self._sec_per_op: float | None = None   # EWMA of observed batch latency
        self._miss_rate: float | None = None    # EWMA of unit-cache miss rate
        # §IV-D cost-model drift monitor: `_unit_scale` calibrates cost
        # units (fixed_warm + k·per_op) to wall-clock seconds; each
        # observed batch is compared against the *pre-update* prediction
        # and the observed/predicted ratio feeds a drift EWMA — the
        # sensor the future online plan re-compiler reads (drift ≈ 1.0
        # means the model still describes this graph + hardware).
        self._unit_scale: float | None = None   # EWMA seconds per cost unit
        self._drift: float | None = None        # EWMA of observed/predicted
        self.last_predicted_s: float | None = None
        self.last_observed_s: float | None = None
        self.last_drift: float | None = None

    def clamp_max_ops(self, cap: int) -> None:
        """Impose a hard batch ceiling (e.g. a backend's static shapes),
        keeping ``min_ops ≤ max_ops ≥ 1`` invariant."""
        self.max_ops = max(1, min(self.max_ops, int(cap)))
        self.min_ops = min(self.min_ops, self.max_ops)

    # ---------------------------------------------------------------- model
    def register(self, name: str, pattern: Pattern,
                 ord_: Sequence[Tuple[int, int]], units: Sequence[R1Unit]) -> None:
        self._patterns[name] = _PatternCost(
            pattern=pattern, ord_=tuple(ord_), units=tuple(units))

    def unregister(self, name: str) -> None:
        self._patterns.pop(name, None)

    def refresh(self, stats: GraphStats) -> None:
        """Re-estimate batch cost terms from fresh graph stats (§IV-D).

        A micro-batch for one pattern costs ``fixed + k · per_op``:
        *fixed* is the chain-step unit listings of the Nav-join (every
        non-seed unit's ``M_ac`` table is listed per batch, independent
        of batch size — Eq. 10's local listing term), *per_op* is the
        seed matches one more inserted edge contributes, pushed through
        the chain (``|E(q)|·E|M(q,d)|/|E(d)|`` seeds per op per unit).
        """
        edges = max(stats.m, 1)
        for pc in self._patterns.values():
            chain = max(len(pc.units), 1)
            per_op = 0.0
            fixed = 0.0
            size_of = {u: match_size_estimate(u.pattern, pc.ord_, stats)
                       for u in pc.units}
            for u in pc.units:
                seeds_per_op = u.pattern.m * size_of[u] / edges
                per_op += seeds_per_op * u.pattern.n * chain
                fixed += sum(size_of[k] * k.pattern.n
                             for k in pc.units if k is not u)
            pc.per_op = max(per_op, 1.0)
            pc.fixed = fixed

    def cost_per_op(self) -> float:
        """Estimated marginal cost units per journal op, over all patterns."""
        return sum(pc.per_op for pc in self._patterns.values()) or 1.0

    def fixed_cost_cold(self) -> float:
        """Batch-size-independent cost with every unit table re-listed."""
        return sum(pc.fixed for pc in self._patterns.values())

    def fixed_miss_rate(self) -> float:
        """Calibrated fraction of unit tables a batch actually re-lists
        (1.0 until the backend reports cache observations)."""
        return 1.0 if self._miss_rate is None else self._miss_rate

    def fixed_cost_warm(self) -> float:
        """Cold `fixed` scaled by the observed cache-miss rate — the
        expected re-listing cost of the *next* batch."""
        return self.fixed_cost_cold() * self.fixed_miss_rate()

    def fixed_cost(self) -> float:
        """Estimated batch-size-independent cost units per micro-batch
        (the warm, hit-rate-calibrated term — what sizing decisions use)."""
        return self.fixed_cost_warm()

    # ------------------------------------------------------------- decisions
    def next_batch_size(self, pending: int) -> int:
        if pending <= 0:
            return 0
        fixed = self.fixed_cost()
        per_op = self.cost_per_op()
        if self.target_cost > fixed and per_op > 0:
            k = (self.target_cost - fixed) / per_op
        else:
            # The per-batch fixed cost alone blows the budget (or the
            # estimator degenerated to zero marginal cost — empty
            # graph): the only lever left is amortization — take the
            # largest batch allowed.
            k = float(self.max_ops)
        if (self.target_latency_s is not None
                and self._sec_per_op is not None and self._sec_per_op > 0):
            k = min(k, self.target_latency_s / self._sec_per_op)
        if not np.isfinite(k):
            k = float(self.max_ops)
        k = int(max(self.min_ops, min(self.max_ops, round(k))))
        return min(k, pending)

    def observe(self, n_ops: int, elapsed_s: float, alpha: float = 0.3) -> None:
        """Fold one measured batch into the wall-clock calibration.

        Batches that complete below clock resolution (``elapsed_s ≤ 0``)
        carry no calibration signal and are skipped — seeding the
        cold-start EWMA with a zero would poison every later average
        (and a zero ``_sec_per_op`` would otherwise make the latency
        target divide by zero / explode the batch size).
        """
        if n_ops <= 0 or not np.isfinite(elapsed_s):
            return
        per_op = elapsed_s / n_ops
        if per_op <= 0.0:
            return
        # Drift bookkeeping first, against the *pre-observation* model:
        # the prediction a caller could have made before this batch ran.
        units = self.fixed_cost() + n_ops * self.cost_per_op()
        pred = self.predict_seconds(n_ops)
        self.last_predicted_s = pred
        self.last_observed_s = elapsed_s
        if pred is not None and pred > 0:
            ratio = elapsed_s / pred
            self.last_drift = ratio
            self._drift = (ratio if self._drift is None
                           else (1 - alpha) * self._drift + alpha * ratio)
        if units > 0:
            scale = elapsed_s / units
            self._unit_scale = (scale if self._unit_scale is None
                                else (1 - alpha) * self._unit_scale + alpha * scale)
        if self._sec_per_op is None:
            self._sec_per_op = per_op
        else:
            self._sec_per_op = (1 - alpha) * self._sec_per_op + alpha * per_op

    def predict_seconds(self, n_ops: int) -> float | None:
        """§IV-D model prediction for a ``n_ops``-op batch in seconds:
        ``unit_scale · (fixed_warm + k · per_op)``. None until at least
        one batch has calibrated the cost-unit → seconds scale."""
        if self._unit_scale is None:
            return None
        return self._unit_scale * (self.fixed_cost()
                                   + max(int(n_ops), 0) * self.cost_per_op())

    def drift(self) -> float | None:
        """EWMA of observed/predicted batch latency (None until two
        calibrated batches exist). ≈1.0 while the cost model tracks
        reality; sustained excursions are the re-optimization trigger."""
        return self._drift

    def reset_drift(self) -> None:
        """Zero the drift EWMA (keep the wall-clock calibration). The
        plan manager calls this after a swap — the old drift measured
        the *old* plan, and carrying it over would immediately re-fire
        the trigger against the new one."""
        self._drift = None
        self.last_drift = None

    def observe_cache(self, hits: int, misses: int, alpha: float = 0.3) -> None:
        """Fold one batch's unit-cache hit/miss counts into the warm
        `fixed` calibration. Batches that consulted the cache zero times
        (no-op windows) carry no signal and are skipped.
        """
        total = int(hits) + int(misses)
        if total <= 0:
            return
        rate = float(np.clip(int(misses) / total, 0.0, 1.0))
        if self._miss_rate is None:
            self._miss_rate = rate
        else:
            self._miss_rate = (1 - alpha) * self._miss_rate + alpha * rate
