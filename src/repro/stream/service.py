"""`ListingService` — continuous multi-pattern subgraph listing.

The streaming composition of the paper's two stages::

    ingest()  →  UpdateJournal  →  BatchScheduler  →  SharedDelta
                                                        │ once per batch
                      ┌─────────────────────────────────┤
                      ▼                                 ▼
               HostBackend                       ShardedBackend
         (NumPy Alg. 4 + Nav-join;       (device make_storage_update_step
          shared Φ(d') + seed cache +     once + ONE fused multi-pattern
          delta-maintained                maintain megastep over every
          PartitionUnitCache)             device-resident MatchStore +
                      │                   per-device unit-table carries)
                      └────────────── sinks ────────────┘
                           (count deltas, match deltas)

Both backends obey the same contract (:class:`StreamBackend`): register
patterns, apply one shared delta to all of them, report per-pattern
results, and :meth:`~StreamBackend.materialize` full match tables only
on demand — the sharded backend keeps running match sets on the mesh
end to end and byte-accounts every device→host pull
(``BatchMetrics.host_bytes``). The service owns the journal, the
committed watermark, batch metrics, periodic from-scratch audits, and
sink fan-out.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ddsl import DDSL
from repro.core.estimator import GraphStats
from repro.core.graph import Graph, GraphUpdate, decode_edges, edge_codes
from repro.core.incremental import removed_rows
from repro.core.pattern import Pattern, R1Unit
from repro.core.storage import build_np_storage
from repro.core.vcbc import CompressedTable, Ragged, compress_table
from repro.planner import CompileContext, CompiledPlan, compile_plan
from repro.planner.sizing import quantize_store_caps

from repro.obs import Observability, ProfiledStep

from .journal import UpdateJournal
from .scheduler import (
    BatchScheduler,
    SharedDelta,
    compute_shared_delta,
    probe_inc,
)
from .sinks import BatchEvent, Sink

__all__ = [
    "PatternMeta",
    "PatternReport",
    "BatchMetrics",
    "StreamBackend",
    "HostBackend",
    "ShardedBackend",
    "ListingService",
]


@dataclasses.dataclass(frozen=True)
class PatternMeta:
    """Static per-pattern facts shared by backends, scheduler, audits.

    ``cover``/``ord_``/``units`` are views into ``plan`` (kept flat
    because every consumer reads them); the full
    :class:`~repro.planner.CompiledPlan` — tree, IR program, caps,
    per-pass report — rides along for the obs export and plan swaps.
    """

    name: str
    pattern: Pattern
    cover: Tuple[int, ...]
    ord_: Tuple[Tuple[int, int], ...]
    units: Tuple[R1Unit, ...]
    plan: Optional[CompiledPlan] = None


@dataclasses.dataclass
class PatternReport:
    """One pattern's outcome for one committed micro-batch."""

    name: str
    count_before: int
    count_after: int
    latency_s: float
    patch_groups: int = 0
    removed_groups: int = 0
    overflow: int = 0
    added: Optional[np.ndarray] = None
    removed: Optional[np.ndarray] = None


@dataclasses.dataclass
class BatchMetrics:
    """Service-level record of one committed micro-batch."""

    batch_index: int
    lo: int
    hi: int
    n_ops: int
    net_add: int
    net_delete: int
    latency_s: float
    patterns: Dict[str, PatternReport]
    storage_overflow: int = 0   # device storage-step overflow (once per batch)
    # Candidate-set sizes of the delta-restricted device update (C1–C3);
    # -1 where not applicable (host backend / full-gather mode). Reset
    # every micro-batch — these are per-batch sizes, not running totals.
    cand_vertices: int = -1
    cand_edges: int = -1
    # Bytes of match/patch state pulled device→host while applying this
    # batch (sharded backend; always 0 on the host backend). Count-only
    # batches keep the running match sets on the mesh, so this is 0
    # unless a sink demanded decompressed rows — asserted in tests.
    host_bytes: int = 0
    # Delta-maintained unit-table cache traffic of this batch: tables
    # served from cache vs re-listed, and partitions the netted delta
    # invalidated. On a warm stream cache_misses is bounded by
    # |units| · invalidated_parts — the §IV-D `fixed` term scales with
    # the delta, not the graph. -1 where the backend has no cache.
    cache_hits: int = -1
    cache_misses: int = -1
    invalidated_parts: int = -1
    # §IV-D scheduler prediction for this batch (seconds); -1 until the
    # cost-unit → wall-clock scale is calibrated (first batches). The
    # drift EWMA over observed/predicted is the scheduler gauge.
    predicted_s: float = -1.0

    @property
    def throughput_ops_s(self) -> float:
        # Batches finishing below clock resolution have no measurable
        # rate: report 0.0, never inf (they are likewise excluded from
        # the throughput gauge — dashboards must not render infinities).
        return self.n_ops / self.latency_s if self.latency_s > 0 else 0.0

    @property
    def overflow(self) -> int:
        return self.storage_overflow + sum(r.overflow for r in self.patterns.values())


def _save_table(path: str, table: CompressedTable) -> None:
    """One pattern's compressed match set as an ``.npz`` (snapshot half;
    the pattern itself travels in the snapshot's ``meta.json``)."""
    arrs = {
        "skeleton": np.asarray(table.skeleton, np.int64),
        "skeleton_cols": np.asarray(table.skeleton_cols, np.int64),
        "cover": np.asarray(table.cover, np.int64),
        "comp_labels": np.asarray(sorted(table.comp), np.int64),
    }
    for v, r in table.comp.items():
        arrs[f"offsets_{int(v)}"] = np.asarray(r.offsets, np.int64)
        arrs[f"values_{int(v)}"] = np.asarray(r.values, np.int64)
    np.savez(path, **arrs)


def _load_table(path: str, pattern: Pattern) -> CompressedTable:
    z = np.load(path)
    comp = {int(v): Ragged(offsets=z[f"offsets_{int(v)}"],
                           values=z[f"values_{int(v)}"])
            for v in z["comp_labels"]}
    return CompressedTable(
        pattern=pattern,
        cover=tuple(int(c) for c in z["cover"]),
        skeleton_cols=tuple(int(c) for c in z["skeleton_cols"]),
        skeleton=z["skeleton"], comp=comp,
    )


def _meta_from_plan(name: str, plan: CompiledPlan) -> PatternMeta:
    return PatternMeta(name=name, pattern=plan.pattern, cover=plan.cover,
                       ord_=plan.ord, units=plan.units, plan=plan)


class StreamBackend:
    """Interface both execution backends implement (duck-typed)."""

    #: scheduler batch ceiling imposed by static shapes (None = unbounded)
    max_batch_ops: Optional[int] = None
    #: the owning service's observability object. The service assigns it
    #: in ``__init__`` (before any pattern registers); a backend driven
    #: standalone lazily grows its own default (registry on, tracing
    #: off) so instrumentation never needs None guards.
    obs: Optional[Observability] = None
    #: overflow of the last batch's shared (pattern-independent) storage
    #: update — reported once per batch, not per pattern
    last_storage_overflow: int = 0
    #: device→host bytes of the last batch / of the backend's lifetime.
    #: Host backends never move anything (0); sharded backends account
    #: every match-set / patch materialization here.
    last_host_bytes: int = 0
    total_host_bytes: int = 0
    #: unit-table cache traffic of the last batch (-1 = no cache)
    last_cache_hits: int = -1
    last_cache_misses: int = -1
    last_invalidated_parts: int = -1

    def _obs(self) -> Observability:
        o = self.obs
        if o is None:
            o = self.obs = Observability()
        return o

    def _jaxprof(self):
        """Late-bound profiler resolver for :class:`ProfiledStep` — the
        service attaches ``obs`` after backend construction, so wrapped
        steps must look it up at call time."""
        o = self.obs
        return o.jaxprof if o is not None else None

    def register(self, name: str, pattern: Pattern, cover=None) -> int:
        raise NotImplementedError

    def compile(self, pattern: Pattern, cover=None,
                stats: GraphStats | None = None,
                objective: str = "r_lower") -> CompiledPlan:
        """Run the staged plan compiler against this backend's machine
        shape (mesh width, engine caps, store headroom). The **single
        entry point** for plan construction: register, restore, and the
        plan manager's live recompiles all come through here, so no two
        paths can ever pick different trees from the same stats.
        ``objective`` is the free-cover policy (§IV-F ``"r_lower"``
        storage argmax, or ``"cost"`` — the Eq. 11 runtime argmin the
        online re-optimizer uses)."""
        raise NotImplementedError

    def plan(self, name: str) -> Optional[CompiledPlan]:
        """The compiled plan the pattern is currently executing."""
        return self.meta(name).plan

    def remove_pattern(self, name: str) -> None:
        """Forget a pattern (engine/device state and counts). The swap
        half-step between :meth:`materialize` and :meth:`install_plan`;
        the caller owns scheduler bookkeeping."""
        raise NotImplementedError

    def install_plan(self, name: str, plan: CompiledPlan, table) -> int:
        """Install a precompiled plan with a known match set at the
        committed watermark (``table.cover`` must equal ``plan.cover``)
        — :meth:`restore_pattern` with the compile step factored out, so
        a plan swap can install the exact plan it costed."""
        raise NotImplementedError

    def apply_batch(self, delta: SharedDelta, want_matches) -> Dict[str, PatternReport]:
        raise NotImplementedError

    def materialize(self, name: str):
        """The pattern's current match set as a host
        :class:`~repro.core.vcbc.CompressedTable` — the **on-demand**
        half of the contract. Backends keeping results device-resident
        pull (and byte-account) them only when this is called; sinks
        that set ``wants_matches`` and from-scratch parity checks are
        the intended triggers."""
        raise NotImplementedError

    def restore_pattern(self, name: str, pattern: Pattern,
                        cover: Tuple[int, ...], table) -> int:
        """Register a pattern whose match set is already known (a
        snapshot table at the service's committed watermark) — skips the
        from-scratch initial listing."""
        raise NotImplementedError

    def _noop_reports(self) -> Dict[str, PatternReport]:
        """Per-pattern reports for a window that netted to the empty
        update: counts unchanged, no deltas, no device/engine work."""
        return {name: PatternReport(
            name=name, count_before=self.count(name),
            count_after=self.count(name), latency_s=0.0,
        ) for name in self.names()}

    def meta(self, name: str) -> PatternMeta:
        raise NotImplementedError

    def count(self, name: str) -> int:
        raise NotImplementedError

    def names(self) -> List[str]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Host backend: NumPy engines over one shared NP storage
# ---------------------------------------------------------------------------

class HostBackend(StreamBackend):
    """All patterns share one Φ(d); Alg. 4 runs once per batch.

    One :class:`~repro.core.unit_cache.PartitionUnitCache` fronts every
    per-partition unit listing of every registered pattern: Nav-join
    chain steps and seed derivations pull through it, and each batch
    invalidates exactly the partitions its Alg. 4 update dirtied
    (``UpdateCostReport.dirty_parts``) — the §IV-D `fixed` term becomes
    delta-bounded. Cached and uncached paths byte-match at every
    watermark (property-tested).
    """

    kind = "host"

    def __init__(self, graph: Graph, m: int = 4, h=None,
                 cache_max_entries: Optional[int] = None,
                 cache_max_bytes: Optional[int] = None,
                 executor: str = "tree"):
        from repro.core.unit_cache import PartitionUnitCache

        self.executor = executor
        self.storage = build_np_storage(graph, m, h)
        self.unit_cache = PartitionUnitCache(
            self.storage, max_entries=cache_max_entries,
            max_bytes=cache_max_bytes)
        self.engines: Dict[str, DDSL] = {}
        self._meta: Dict[str, PatternMeta] = {}
        self._counts: Dict[str, int] = {}   # carried across batches

    @property
    def m(self) -> int:
        return self.storage.m

    @property
    def graph(self) -> Graph:
        return self.storage.graph

    def compile(self, pattern: Pattern, cover=None,
                stats: GraphStats | None = None,
                objective: str = "r_lower") -> CompiledPlan:
        return compile_plan(CompileContext(
            pattern=pattern,
            stats=stats if stats is not None else GraphStats.of(self.graph),
            m=self.m,
            cover=tuple(sorted(int(c) for c in cover)) if cover is not None else None,
            cover_objective=objective,
            executor=self.executor,
        ))

    def register(self, name: str, pattern: Pattern, cover=None) -> int:
        if name in self.engines:
            raise ValueError(f"pattern {name!r} already registered")
        meta = _meta_from_plan(name, self.compile(pattern, cover))
        eng = DDSL(self.graph, pattern, m=self.m, storage=self.storage,
                   plan=meta.plan)
        eng.initial()
        self.engines[name] = eng
        self._meta[name] = meta
        self._counts[name] = eng.count()
        return self._counts[name]

    def restore_pattern(self, name: str, pattern: Pattern,
                        cover: Tuple[int, ...], table) -> int:
        return self.install_plan(name, self.compile(pattern, cover), table)

    def install_plan(self, name: str, plan: CompiledPlan, table) -> int:
        if name in self.engines:
            raise ValueError(f"pattern {name!r} already registered")
        if table.cover != plan.storage_cover:
            # Snapshot from a different cover or executor mode (WCOJ
            # stores under trivial compression) — recompress to the
            # plan's storage layout.
            cols, rows = table.decompress(plan.ord)
            table = compress_table(plan.pattern, plan.storage_cover, cols, rows)
        meta = _meta_from_plan(name, plan)
        eng = DDSL(self.graph, plan.pattern, m=self.m, storage=self.storage,
                   plan=plan)
        eng.state.matches = table          # the known table replaces initial()
        self.engines[name] = eng
        self._meta[name] = meta
        self._counts[name] = eng.count()
        return self._counts[name]

    def remove_pattern(self, name: str) -> None:
        del self.engines[name]
        del self._meta[name]
        del self._counts[name]

    def meta(self, name: str) -> PatternMeta:
        return self._meta[name]

    def names(self) -> List[str]:
        return list(self.engines)

    def count(self, name: str) -> int:
        return self._counts[name]

    def materialize(self, name: str):
        return self.engines[name].state.matches

    def matches_plain(self, name: str) -> np.ndarray:
        return self.engines[name].matches_plain()

    def apply_batch(self, delta: SharedDelta, want_matches) -> Dict[str, PatternReport]:
        obs = self._obs()
        tr = obs.tracer
        self.last_cache_hits = 0
        self.last_cache_misses = 0
        self.last_invalidated_parts = 0
        if delta.update.size == 0:
            # The window netted to nothing: Φ, stats, and every match
            # set are unchanged — commit the watermark without work
            # (the unit cache stays fully warm too).
            return self._noop_reports()
        ev0 = self.unit_cache.stats.evictions
        with tr.span("storage_update") as ssp:
            storage2 = delta.ensure_storage(self.storage)   # Alg. 4 — once
            # Advance the unit-table cache to Φ(d'): exactly the
            # partitions whose stored edge set changed lose their cached
            # listings.
            dirty = (delta.storage_report.dirty_parts
                     if delta.storage_report is not None
                     else tuple(range(self.storage.m)))
            stats0 = self.unit_cache.stats.snapshot()
            self.unit_cache.advance(storage2, dirty)
            ssp.add("dirty_parts", len(dirty))
        reports: Dict[str, PatternReport] = {}
        for name, eng in self.engines.items():
            with tr.span("maintain", pattern=name) as msp:
                t0 = time.perf_counter()
                before = self._counts[name]
                want = name in want_matches
                removed = (removed_rows(eng.state.matches, delta.update.delete, eng.ord_)
                           if want else None)
                rep = eng.apply_shared(
                    storage2, delta.update,
                    stats=delta.stats, storage_report=delta.storage_report,
                    seed_fn=delta.seed_provider(eng.cover, eng.ord_,
                                                cache=self.unit_cache),
                    provider=self.unit_cache,
                )
                added = rep.patch.decompress(eng.ord_)[1] if (want and rep.patch is not None) else None
                self._counts[name] = eng.count()
                patch_groups = rep.patch.n_groups if rep.patch is not None else 0
                msp.add("patch_groups", patch_groups)
                msp.add("removed_groups", rep.removed_groups)
                reports[name] = PatternReport(
                    name=name, count_before=before, count_after=self._counts[name],
                    latency_s=time.perf_counter() - t0,
                    patch_groups=patch_groups,
                    removed_groups=rep.removed_groups,
                    added=added, removed=removed,
                )
        self.storage = storage2
        hits, misses, inval = (b - a for a, b in
                               zip(stats0, self.unit_cache.stats.snapshot()))
        self.last_cache_hits = hits
        self.last_cache_misses = misses
        self.last_invalidated_parts = inval
        probe_inc("cache_hits", hits, metrics=obs.metrics)
        probe_inc("cache_misses", misses, metrics=obs.metrics)
        probe_inc("invalidated_parts", inval, metrics=obs.metrics)
        evictions = self.unit_cache.stats.evictions - ev0
        if evictions:
            obs.metrics.counter(
                "unit_cache_evictions_total",
                "unit-cache LRU evictions under the entry/byte budget",
            ).inc(evictions)
        obs.metrics.gauge(
            "unit_cache_resident_bytes",
            "bytes held by cached unit tables (plain + compressed)",
        ).set(self.unit_cache.resident_bytes)
        obs.metrics.gauge(
            "unit_cache_entries", "live plain unit-cache entries",
        ).set(self.unit_cache.entries())
        return reports


# ---------------------------------------------------------------------------
# Sharded backend: device storage step once + per-pattern patch steps
# ---------------------------------------------------------------------------

def _default_caps(storage, graph: Graph, m: int, use_pallas: bool):
    """Size EngineCaps from the built storage with growth headroom."""
    from repro.dist import jax_engine as je

    nv = max(max((int(p.vertices.shape[0]) for p in storage.parts), default=1), graph.n // m + 1)
    ne = max((int(p.codes.shape[0]) for p in storage.parts), default=1)
    dg = max((int(np.diff(p.indptr).max(initial=0)) for p in storage.parts), default=1)

    def up(x, mult, align):
        return int(-(-max(1, int(x * mult)) // align) * align)

    v_cap = up(max(nv, graph.n / m), 1.5, 64)
    return je.EngineCaps(
        v_cap=v_cap, deg_cap=up(dg, 2.0, 8), e_cap=up(ne, 2.0, 64),
        match_cap=4096, group_cap=4096, set_cap=64, pair_cap=128,
        use_pallas=use_pallas,
    )


@dataclasses.dataclass
class _ShardedEntry:
    meta: PatternMeta
    prog: object
    full_skel: Tuple[int, ...]
    store: object                   # device-resident MatchStore
    store_caps: object
    unit_caps: object               # StoreCaps of the unit-table carry
    carry: object                   # persistent per-device unit tables
    n_unit_plans: int               # distinct unit plans behind the carry
    refresh_step: object            # cold carry refresh (also crash recovery)
    list_step: object = None        # lazy initial-calculation step (rebuilds)
    host_table: object = None       # lazy comp_to_host cache (per watermark)
    wcoj_level_caps: object = None  # calibrated per-level caps (wcoj mode)


class ShardedBackend(StreamBackend):
    """Drives the ``repro.dist`` SPMD steps behind the backend contract.

    One jitted :func:`~repro.dist.sharded.make_storage_update_step`
    (pattern-independent) advances Φ(d') on device once per batch;
    *every* registered pattern is then maintained by ONE jitted
    :func:`~repro.dist.sharded.make_maintain_mega_step` — per pattern,
    carry refresh ∘ patch ∘ delete filter ∘ merge ∘ count over its
    device-resident :class:`~repro.dist.sharded.MatchStore`, all fused
    into a single SPMD dispatch that shares the updated partitions and
    the delete-table dedup across patterns. Running match sets never
    leave the mesh: a count-only batch pulls scalars, and full tables
    materialize on host only through :meth:`materialize` (lazy, valid
    prefix only, byte-accounted in ``last_host_bytes``). Each pattern
    also carries its per-device **unit tables** (the Nav-join `fixed`
    cost): the megastep re-lists them only on devices whose partition
    the storage step's ``part_dirty`` flag marks, so a warm batch's
    listing work is delta-bounded.

    The megastep donates the store and carry buffers on platforms where
    XLA honors donation (:func:`repro._jax_compat.donate_jit`), keeping
    per-batch device memory flat. The backend therefore treats the
    passed-in stores/carries as consumed: every retry/abort path
    rebuilds them from the never-donated committed partitions
    (``self.pt``), so a failed batch always leaves a usable backend at
    the committed watermark.

    Device cap overflow is surfaced per batch in the reports — never
    silent. A *store* overflow (a running match set outgrowing its
    ``StoreCaps``) is self-healing by default: nothing commits, the
    overflowing patterns' caps double (on the pow2 grid of
    :func:`~repro.planner.sizing.quantize_store_caps`), the stores are
    rebuilt by re-listing over Φ, the megastep recompiles (counted
    under the same ``maintain_mega`` profile) and the batch is retried
    (counted in ``store_resizes``, like ``cap_fallbacks``).
    ``strict_overflow=True`` opts back into fail-stop semantics: any
    storage/maintain overflow raises before committing lossy state
    (capped device state is persistent — a dropped candidate or store
    group stays wrong forever).
    """

    kind = "sharded"

    #: candidate-set sizes of the last batch's storage step (delta mode;
    #: -1 in full-gather mode). Reset at the top of every apply_batch.
    last_cand_vertices: int = -1
    last_cand_edges: int = -1
    #: times the estimator-sized candidate caps were outrun and the
    #: backend permanently fell back to the never-overflow derivation
    #: (recompiling the storage step and retrying the batch).
    cap_fallbacks: int = 0
    #: times a MatchStore outgrew its caps and was rebuilt with ×2 caps
    #: (best-effort mode; counted, like cap_fallbacks).
    store_resizes: int = 0
    _max_store_resizes: int = 4

    def __init__(self, graph: Graph, m: int | None = None, caps=None,
                 max_add: int = 64, max_del: int = 64, use_pallas: bool = False,
                 update_mode: str = "delta", cap_sizing: str = "estimator",
                 store_headroom: float = 4.0, strict_overflow: bool = False,
                 executor: str = "tree", level_headroom: float = 1.5):
        import jax
        from jax.sharding import NamedSharding

        from repro.dist import jax_engine as je   # noqa: F401  (caps type)
        from repro.dist import sharded

        self._sharded = sharded
        self._je = je
        self.executor = executor
        self.m = jax.local_device_count() if m is None else int(m)
        self.mesh = jax.make_mesh((self.m,), ("data",))
        storage = build_np_storage(graph, self.m)
        self.caps = caps if caps is not None else _default_caps(storage, graph, self.m, use_pallas)
        self.max_batch_ops = min(max_add, max_del)
        self._max_add, self._max_del = max_add, max_del
        if cap_sizing == "estimator":
            # §IV-D-sized candidate caps (clamped to the never-overflow
            # bound, so this only ever shrinks the psum payload). If a
            # batch does outrun them — a hub-concentrated delta — the
            # step reports overflow BEFORE anything commits and
            # apply_batch falls back to the never-overflow caps
            # permanently (one recompile) and retries the same batch.
            self.ushapes = sharded.UpdateShapes.from_estimator(
                max_add, max_del, GraphStats.of(graph), self.caps, self.m)
        elif cap_sizing == "exact":
            self.ushapes = sharded.UpdateShapes(n_add=max_add, n_del=max_del)
        else:
            raise ValueError(
                f"unknown cap_sizing {cap_sizing!r} (expected 'estimator' or 'exact')")
        self.graph = graph
        if graph.n > self.m * self.caps.v_cap:
            raise ValueError(
                f"graph has {graph.n} vertices > m*v_cap={self.m * self.caps.v_cap}")
        self.update_mode = update_mode
        self.store_headroom = float(store_headroom)
        # Per-level WCOJ listing caps are transient (rebuilt every
        # dispatch, overflow detected before anything commits), so they
        # can hug the observed prefix sizes much tighter than the
        # persistent store caps — the pow2 grid alone already adds
        # slack. This gap is most of the executor's win: each level
        # pays its own prefix size, not a uniform worst-case cap.
        self.level_headroom = float(level_headroom)
        # Device caps make persistent state lossy when exceeded: a
        # dropped candidate vertex corrupts Φ(d') forever, a dropped
        # store group loses matches that no later patch re-derives.
        # Best-effort mode (default) self-heals store overflow by
        # rebuilding with ×2 caps and retrying the batch before
        # anything commits; other overflow stays a counted metric
        # (watch BatchMetrics.overflow). Strict mode raises instead of
        # carrying any potentially corrupted state forward — opt in for
        # fail-stop deployments.
        self.strict_overflow = bool(strict_overflow)
        #: the fused multi-pattern maintain megastep (None until the
        #: first pattern registers) and its per-pattern cost shares
        self.maintain_step: Optional[ProfiledStep] = None
        self._maintain_subs: Dict[str, float] = {}
        # Every jitted SPMD step is wrapped in a ProfiledStep so the
        # device profiler can split compile from execute per step name.
        # The profiler resolves late (self._jaxprof) — the service
        # attaches `obs` after this constructor runs.
        self.storage_step = ProfiledStep(
            "storage_update",
            sharded.make_storage_update_step(
                self.mesh, self.caps, self.ushapes, mode=update_mode),
            self._jaxprof)
        specs = sharded.partition_specs(self.mesh)
        self._shardings = jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)
        self.pt = jax.device_put(
            sharded.stack_partitions(storage, self.caps), self._shardings)
        self.entries: Dict[str, _ShardedEntry] = {}
        self._counts: Dict[str, int] = {}   # carried across batches
        #: entries removed since the last batch, kept for carry reuse on
        #: a same-watermark plan swap (cleared whenever Φ advances)
        self._carry_stash: Dict[str, _ShardedEntry] = {}
        self.last_host_bytes = 0
        self.total_host_bytes = 0

    def _pull(self, arr) -> np.ndarray:
        """Device→host transfer with byte accounting."""
        a = np.asarray(arr)
        self.last_host_bytes += int(a.nbytes)
        self.total_host_bytes += int(a.nbytes)
        self._obs().metrics.counter(
            "host_transfer_bytes_total",
            "device→host bytes pulled through the sharded backend",
        ).inc(int(a.nbytes))
        return a

    def _flatten(self, tc):
        """Pull stacked [M, G, ...] compressed tensors to host form."""
        skel = self._pull(tc.skeleton).reshape(-1, tc.skeleton.shape[-1])
        valid = self._pull(tc.valid).reshape(-1)
        sets = {k: self._pull(v).reshape(-1, v.shape[-1])
                for k, v in tc.sets.items()}
        return self._je.CompTensors(skeleton=skel, valid=valid, sets=sets)

    def compile(self, pattern: Pattern, cover=None,
                stats: GraphStats | None = None,
                objective: str = "r_lower") -> CompiledPlan:
        return compile_plan(CompileContext(
            pattern=pattern,
            stats=stats if stats is not None else GraphStats.of(self.graph),
            m=self.m, caps=self.caps,
            cover=tuple(sorted(int(c) for c in cover)) if cover is not None else None,
            cover_objective=objective,
            store_headroom=self.store_headroom,
            executor=self.executor,
        ))

    def register(self, name: str, pattern: Pattern, cover=None) -> int:
        if name in self.entries:
            raise ValueError(f"pattern {name!r} already registered")
        meta = _meta_from_plan(name, self.compile(pattern, cover))
        if meta.plan.executor == "wcoj":
            return self._register_wcoj(name, meta)
        prog = meta.plan.program
        list_step = ProfiledStep(
            f"list:{name}",
            self._sharded.make_list_step(prog, self.mesh, self.caps),
            self._jaxprof)
        out, diag = list_step(self.pt)
        if int(diag["overflow"]):
            raise ValueError(
                f"initial listing overflowed caps ({int(diag['overflow'])} rows); "
                "re-register with larger EngineCaps")
        # The initial match set goes straight into a device-resident
        # store (sharded by full-skeleton ownership) and is counted on
        # device — registration never materializes matches on host.
        # Caps live on the quantize_store_caps pow2 grid so patterns
        # with near-identical estimates share megastep shapes.
        store_caps = quantize_store_caps(meta.plan.store_caps)
        init_step = ProfiledStep(
            f"init_store:{name}",
            self._sharded.make_init_store_step(
                prog, self.mesh, self.caps, store_caps),
            self._jaxprof)
        store, idiag = init_step(out)
        if int(idiag["overflow"]):
            raise ValueError(
                f"initial match store overflowed caps ({int(idiag['overflow'])} "
                "entries); re-register with a larger store_headroom")
        entry = self._make_entry(name, meta, store, store_caps,
                                 list_step=list_step)
        self._counts[name] = int(idiag["count"])
        return self._counts[name]

    def _register_wcoj(self, name: str, meta: PatternMeta) -> int:
        """Register under the generic-join executor mode: anchored WCOJ
        listing → trivially-compressed device store. No unit-table carry
        — the per-batch patch is a delta-seeded re-run of the same
        generic join, not a Nav-join over cached unit tables."""
        plan = meta.plan
        level_caps, store_floor = self._calibrate_wcoj_caps(plan)
        list_step = ProfiledStep(
            f"list:{name}",
            self._sharded.make_wcoj_list_step(
                plan.pattern, plan.wcoj, self.mesh, self.caps, level_caps),
            self._jaxprof)
        out, diag = list_step(self.pt)
        if int(diag["overflow"]):
            raise ValueError(
                f"initial WCOJ listing overflowed level caps "
                f"({int(diag['overflow'])} rows); re-register with a larger "
                "store_headroom")
        # Store groups are whole matches under trivial compression, so
        # the calibrated bound (observed per-partition match count ×
        # store_headroom) is the honest group sizing — the plan's
        # estimator-derived store caps only set the floor.
        store_caps = quantize_store_caps(dataclasses.replace(
            plan.store_caps,
            group_cap=max(plan.store_caps.group_cap, store_floor)))
        init_step = ProfiledStep(
            f"init_store:{name}",
            self._sharded.make_wcoj_init_store_step(
                plan.pattern, plan.ord, self.mesh, self.caps, store_caps,
                level_caps),
            self._jaxprof)
        store, idiag = init_step(out)
        if int(idiag["overflow"]):
            raise ValueError(
                f"initial WCOJ match store overflowed caps "
                f"({int(idiag['overflow'])} entries); re-register with a "
                "larger store_headroom")
        self._make_entry(name, meta, store, store_caps, list_step=list_step,
                         wcoj_level_caps=level_caps)
        self._counts[name] = int(idiag["count"])
        return self._counts[name]

    def _calibrate_wcoj_caps(self, plan: CompiledPlan):
        """Register-time calibration probe: replace the compile-time
        (estimator-derived) per-level WCOJ caps with the *observed*
        per-partition level sizes. One host pass over the same
        partitions the devices hold
        (:func:`~repro.core.match_engine.wcoj_level_counts`), so the
        unrolled device loop's intermediate tensors track real prefix
        sizes instead of estimator tails — shrinking hub-driven
        overestimates AND growing levels the degree-moment model
        undershoots (a planted dense core breaks Eq. 11 badly; the
        probe is ground truth at the register watermark either way).

        Returns ``(level_caps, store_group_floor)``: levels carry
        ``level_headroom`` (transient tensors, recoverable overflow),
        the store-group floor carries the bigger ``store_headroom``
        (persistent state, lossy overflow)."""
        from repro.core.match_engine import wcoj_level_counts

        storage = build_np_storage(self.graph, self.m)
        observed = [wcoj_level_counts(part, plan.wcoj, anchor_to_centers=True)
                    for part in storage.parts]
        peaks = [max((o[lvl] for o in observed), default=0)
                 for lvl in range(len(plan.wcoj_level_caps))]

        def pow2(x: int) -> int:
            n = 64
            while n < x:
                n *= 2
            return n

        return (tuple(pow2(int(self.level_headroom * p)) for p in peaks),
                pow2(int(self.store_headroom * peaks[-1])))

    def _make_entry(self, name, meta, store, store_caps, list_step=None,
                    wcoj_level_caps=None):
        """Common tail of register/restore/install: cold-fill the
        unit-table carry and fold the pattern into the fused maintain
        megastep. ``store_caps`` may exceed ``meta.plan.store_caps`` (a
        restore grows them to fit a concrete snapshot table). WCOJ-mode
        entries skip the carry entirely (their megastep slot re-derives
        patches from Φ(d') alone): empty carry pytree, no-op refresh."""
        prog = meta.plan.program
        unit_caps = meta.plan.unit_caps
        if meta.plan.executor == "wcoj":
            self._carry_stash.pop(name, None)   # wcoj mode has no carry
            if wcoj_level_caps is None:
                wcoj_level_caps, _ = self._calibrate_wcoj_caps(meta.plan)
            entry = _ShardedEntry(
                meta=meta, prog=prog,
                full_skel=meta.plan.storage_cover,
                store=store, store_caps=store_caps,
                unit_caps=unit_caps, carry={}, n_unit_plans=0,
                refresh_step=lambda pt: ({}, {"overflow": 0}),
                list_step=list_step, wcoj_level_caps=wcoj_level_caps,
            )
            self.entries[name] = entry
            self._rebuild_maintain_step()
            return entry
        refresh_step = ProfiledStep(
            f"unit_refresh:{name}",
            self._sharded.make_unit_refresh_step(
                prog, list(meta.units), self.mesh, self.caps, unit_caps),
            self._jaxprof)
        n_plans = len(self._sharded.unit_plan_registry(prog, list(meta.units))[0])
        stash = self._carry_stash.pop(name, None)
        if stash is not None and self._carry_compatible(stash, meta, unit_caps):
            # Same-watermark plan swap preserving everything the carry
            # depends on (cover, ord, units, unit caps — the chain order
            # is tree-independent): the removed entry's device carry is
            # still exactly right, so skip the cold re-listing entirely.
            carry = stash.carry
            self._obs().metrics.counter(
                "plan_swap_carry_reuses_total",
                "unit-table carries reused across cover-preserving swaps",
            ).inc()
            probe_inc("cache_hits", self.m * n_plans,
                      metrics=self._obs().metrics)
        else:
            carry, rdiag = refresh_step(self.pt)
            if int(rdiag["overflow"]):
                raise ValueError(
                    f"unit-table carry overflowed caps ({int(rdiag['overflow'])} "
                    "entries); enlarge EngineCaps / unit_table_caps headroom")
            # The cold fill lists every unit on every device once — the
            # same accounting as a host-cache cold miss.
            probe_inc("cache_misses", self.m * n_plans,
                      metrics=self._obs().metrics)
        entry = _ShardedEntry(
            meta=meta, prog=prog,
            full_skel=prog.nodes[prog.root].skel_cols,
            store=store, store_caps=store_caps,
            unit_caps=unit_caps, carry=carry, n_unit_plans=n_plans,
            refresh_step=refresh_step, list_step=list_step,
        )
        self.entries[name] = entry
        self._rebuild_maintain_step()
        return entry

    @staticmethod
    def _carry_compatible(stash: _ShardedEntry, meta: PatternMeta,
                          unit_caps) -> bool:
        """True when a stashed entry's unit-table carry is byte-valid
        for the new plan: the carry depends only on (cover, ord, units,
        unit caps) — the Nav-join chain order comes from
        ``left_deep_order(units, ·, cover)``, never the tree shape — and
        only tree-mode plans have one at all."""
        old = stash.meta
        return (old.plan is not None and old.plan.executor != "wcoj"
                and meta.plan.executor != "wcoj"
                and old.cover == meta.cover
                and old.ord_ == meta.ord_
                and len(old.units) == len(meta.units)
                and all(a.pattern.key() == b.pattern.key()
                        and a.anchors == b.anchors
                        for a, b in zip(old.units, meta.units))
                and stash.unit_caps == unit_caps)

    def _rebuild_maintain_step(self) -> None:
        """(Re)compile the fused megastep over the current entry set.

        Called whenever the set of patterns or any store caps change
        (register/remove/install/restore/resize). Always the same
        ``ProfiledStep`` name — recompiles accumulate into the single
        ``maintain_mega`` profile, whose ``subs`` attribute carries the
        per-pattern Eq.-11 cost shares used to attribute the fused
        latency (no per-pattern ghost steps)."""
        if not self.entries:
            self.maintain_step = None
            self._maintain_subs = {}
            return
        specs = [self._sharded.MaintainSpec(
            name=n, prog=e.prog, units=tuple(e.meta.units),
            store=e.store_caps, unit_caps=e.unit_caps,
            wcoj=(e.meta.plan.wcoj
                  if e.meta.plan.executor == "wcoj" else None),
            wcoj_level_caps=e.wcoj_level_caps)
            for n, e in self.entries.items()]
        costs = {n: (max(float(e.meta.plan.cost), 1e-9)
                     if e.meta.plan is not None else 1.0)
                 for n, e in self.entries.items()}
        total = sum(costs.values())
        self._maintain_subs = {n: c / total for n, c in costs.items()}
        self.maintain_step = ProfiledStep(
            "maintain_mega",
            self._sharded.make_maintain_mega_step(specs, self.mesh, self.caps),
            self._jaxprof, subs=self._maintain_subs)

    def restore_pattern(self, name: str, pattern: Pattern,
                        cover: Tuple[int, ...], table) -> int:
        """Rebuild a pattern's device state from a snapshot table: the
        :class:`~repro.dist.sharded.MatchStore` comes from
        ``stack_matches`` (no from-scratch listing), the unit-table
        carry from one refresh over the restored Φ."""
        return self.install_plan(name, self.compile(pattern, cover), table)

    def install_plan(self, name: str, plan: CompiledPlan, table) -> int:
        import jax
        from jax.sharding import NamedSharding

        if name in self.entries:
            raise ValueError(f"pattern {name!r} already registered")
        if table.cover != plan.storage_cover:
            # Snapshot from a different cover or executor mode (WCOJ
            # stores under trivial compression) — recompress to the
            # plan's storage layout before stacking onto the mesh.
            cols, rows = table.decompress(plan.ord)
            table = compress_table(plan.pattern, plan.storage_cover, cols, rows)
        meta = _meta_from_plan(name, plan)
        store_caps = quantize_store_caps(self._fit_store_caps(plan.store_caps, table))
        specs = self._sharded.match_specs(self.mesh, plan.pattern, plan.storage_cover)
        store = jax.device_put(
            self._sharded.stack_matches(table, self.m, store_caps),
            jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs))
        self._make_entry(name, meta, store, store_caps)
        self._counts[name] = table.count_matches(plan.ord)
        return self._counts[name]

    def remove_pattern(self, name: str) -> None:
        # Stash the removed entry until the next batch: a plan swap
        # (remove → install at the same committed watermark) can reuse
        # its unit-table carry when the new plan preserves everything
        # the carry depends on (see _make_entry).
        self._carry_stash[name] = self.entries[name]
        del self.entries[name]        # drops the device store/carry refs
        del self._counts[name]
        self._rebuild_maintain_step()

    def _fit_store_caps(self, est, table):
        """Grow estimator-sized StoreCaps to hold a concrete snapshot
        table (stack_matches fail-stops on a misfit — a restore must
        never lose groups to a sizing guess)."""
        if table.n_groups == 0:
            return est
        owner = self._sharded._owner_rows_np(
            table.skeleton.astype(np.int64), self.m)
        need_g = int(np.bincount(owner, minlength=self.m).max())
        need_s = max((int(r.counts().max(initial=0))
                      for r in table.comp.values()), default=1)

        def up(x, align):
            return int(-(-max(1, int(x)) // align) * align)

        return self._sharded.StoreCaps(
            group_cap=max(est.group_cap, up(need_g, 64)),
            set_cap=max(est.set_cap, up(need_s, 8)))

    def meta(self, name: str) -> PatternMeta:
        return self.entries[name].meta

    def names(self) -> List[str]:
        return list(self.entries)

    def count(self, name: str) -> int:
        return self._counts[name]

    @staticmethod
    def _storage_cover(e: _ShardedEntry) -> Tuple[int, ...]:
        """The cover the entry's *store layout* uses — all vertices for
        WCOJ-mode (trivial compression), the compile cover otherwise."""
        return (e.meta.plan.storage_cover
                if e.meta.plan is not None else e.meta.cover)

    def materialize(self, name: str):
        """Lazy device→host pull of the running match set (cached until
        the next committed batch moves the store).

        Only each shard's **valid prefix** transfers
        (:meth:`_flatten_live`): the store's canonical layout packs
        live groups first, so the pull costs O(live table), not
        O(StoreCaps) — a ``wants_matches`` sink that needs the
        pre-batch table every batch pays for what it reads, and
        ``host_transfer_bytes_total`` reflects actual data moved.
        """
        e = self.entries[name]
        if e.host_table is None:
            obs = self._obs()
            b0 = self.last_host_bytes
            with obs.tracer.span("materialize", pattern=name) as sp:
                e.host_table = self._je.comp_to_host(
                    self._flatten_live(e.store.as_comp()), e.meta.pattern,
                    self._storage_cover(e), e.full_skel)
                sp.add("host_bytes", self.last_host_bytes - b0)
            probe_inc("host_materializations", metrics=obs.metrics)
        return e.host_table

    def _flatten_live(self, tc):
        """Pull only each shard's valid prefix of stacked [M, G, ...]
        compressed tensors (device-side compaction before transfer).

        Engine merge/group outputs pack live groups first, so slicing
        ``arr[i, :k]`` on device and pulling the slice moves O(delta)
        bytes instead of the cap-padded O(StoreCaps) tensors. Any shard
        that is *not* prefix-packed (foreign layout) falls back to the
        exact full-tensor pull — correctness never depends on packing.
        """
        valid = self._pull(tc.valid)
        m = valid.shape[0]
        ks = [int(k) for k in valid.reshape(m, -1).sum(axis=1)]
        if not all(bool(valid[i, :ks[i]].all()) for i in range(m)):
            return self._flatten(tc)
        skel = np.concatenate(
            [self._pull(tc.skeleton[i, :ks[i]]) for i in range(m)], axis=0)
        sets = {key: np.concatenate(
                    [self._pull(v[i, :ks[i]]) for i in range(m)], axis=0)
                for key, v in tc.sets.items()}
        return self._je.CompTensors(
            skeleton=skel, valid=np.ones(skel.shape[0], bool), sets=sets)

    def matches_plain(self, name: str) -> np.ndarray:
        e = self.entries[name]
        return self.materialize(name).decompress(e.meta.ord_)[1]

    def _pad(self, edges: np.ndarray, cap: int):
        import jax.numpy as jnp
        k = edges.shape[0]
        if k > cap:
            raise ValueError(f"batch has {k} edges > static cap {cap}")
        out = np.full((cap, 2), -1, np.int32)
        out[:k] = edges
        return jnp.asarray(out)

    def apply_batch(self, delta: SharedDelta, want_matches) -> Dict[str, PatternReport]:
        obs = self._obs()
        tr = obs.tracer
        upd = delta.update
        # Per-batch diagnostics: reset before any work so a short
        # circuit (or a failure) can't leak last batch's numbers.
        self.last_storage_overflow = 0
        self.last_cand_vertices = -1
        self.last_cand_edges = -1
        self.last_host_bytes = 0
        self.last_cache_hits = 0
        self.last_cache_misses = 0
        self.last_invalidated_parts = 0
        # Stashed carries are pinned to the committed watermark — once a
        # batch runs, Φ moves and they can never be reused.
        self._carry_stash.clear()
        if upd.size == 0:
            return self._noop_reports()
        add = self._pad(np.asarray(upd.add), self.ushapes.n_add)
        dele = self._pad(np.asarray(upd.delete), self.ushapes.n_del)
        # Device Alg. 4 — once per batch, shared by every pattern. The
        # journal-netted SharedDelta codes are what the delta-restricted
        # step consumes: candidate sets are derived from exactly these
        # endpoints.
        with tr.span("storage_update") as ssp:
            pt2, sdiag = self.storage_step(self.pt, add, dele)
            self.last_storage_overflow = int(sdiag["overflow"])
            self.last_cand_vertices = int(sdiag.get("cand_vertices", -1))
            self.last_cand_edges = int(sdiag.get("cand_edges", -1))
            if int(sdiag.get("cand_overflow", 0)) and self.ushapes.cand_cap is not None:
                # Estimator-sized candidate caps outran by this delta
                # (e.g. a hub-concentrated batch) — gated on the
                # candidate-cap counter specifically: e_cap/deg_cap/oob
                # overflow also lands in the summed counter, and no
                # candidate resize can fix those. Nothing has been
                # committed: fall back to the never-overflow derivation
                # permanently (one recompile) and retry the same batch
                # exactly.
                self.cap_fallbacks += 1
                obs.metrics.counter(
                    "sharded_cap_fallbacks_total",
                    "permanent fallbacks to never-overflow candidate caps",
                ).inc()
                ssp.add("cap_fallbacks", 1)
                self.ushapes = self._sharded.UpdateShapes(
                    n_add=self._max_add, n_del=self._max_del)
                # Same step name on purpose: the recompile folds into
                # the existing "storage_update" StepProfile.
                self.storage_step = ProfiledStep(
                    "storage_update",
                    self._sharded.make_storage_update_step(
                        self.mesh, self.caps, self.ushapes,
                        mode=self.update_mode),
                    self._jaxprof)
                pt2, sdiag = self.storage_step(self.pt, add, dele)
                self.last_storage_overflow = int(sdiag["overflow"])
                self.last_cand_vertices = int(sdiag.get("cand_vertices", -1))
                self.last_cand_edges = int(sdiag.get("cand_edges", -1))
            ssp.add("overflow", self.last_storage_overflow)
        if self.strict_overflow and self.last_storage_overflow:
            # Dropped candidates mean Φ(d') is missing patches — wrong
            # forever, not just this batch. Nothing has been committed
            # yet; abort loudly instead.
            raise RuntimeError(
                f"device storage update overflowed caps "
                f"({self.last_storage_overflow} entries) — counts would be "
                "silently wrong from here on. Enlarge EngineCaps, or pass "
                "strict_overflow=False to tolerate undercounts.")
        dirty = sdiag["part_dirty"]
        names = list(self.entries)
        reports: Dict[str, PatternReport] = {}
        if names:
            before = dict(self._counts)
            # Removed rows need the pre-update tables — materialized
            # (and byte-accounted) only when a sink asked for rows AND
            # the netted batch actually deletes something. Must happen
            # BEFORE the megastep: it donates the store buffers.
            removed_by: Dict[str, Optional[np.ndarray]] = {
                name: (removed_rows(self.materialize(name), upd.delete,
                                    self.entries[name].meta.ord_)
                       if name in want_matches and np.asarray(upd.delete).size
                       else None)
                for name in names}
            # ONE fused maintain dispatch for every pattern: per
            # pattern, refresh ∘ patch ∘ filter ∘ merge ∘ count; all
            # stores, patches and unit-table carries stay device
            # arrays, and the updated partitions + delete table are
            # shared across patterns inside the step. Only devices
            # whose partition the storage step dirtied re-list their
            # unit tables.
            t0 = time.perf_counter()
            with tr.span("maintain_mega", patterns=len(names)) as msp:
                stores = {n: self.entries[n].store for n in names}
                carries = {n: self.entries[n].carry for n in names}
                stores2, patches, carries2, mdiag = self.maintain_step(
                    pt2, stores, carries, dirty, add, dele)
                if (not self.strict_overflow and
                        any(int(mdiag[n]["store_overflow"]) for n in names)):
                    # Some running store outgrew its caps. Nothing has
                    # committed (self.pt/self._counts untouched):
                    # double the overflowing patterns' caps, rebuild
                    # the pre-batch stores, recompile the megastep and
                    # retry the same batch. Gated on store_overflow —
                    # the StoreCaps share of the counter — because
                    # engine-cap overflow in the summed counter can't
                    # be fixed by a store resize.
                    stores2, patches, carries2, mdiag = \
                        self._resize_stores_and_retry(pt2, dirty, add, dele,
                                                      mdiag, carries2)
                if self.strict_overflow and any(
                        int(mdiag[n]["overflow"]) for n in names):
                    # A dropped store group is a match set lost forever
                    # (no later patch re-derives it) — refuse to commit
                    # the lossy batch. The megastep may have consumed
                    # (donated) the store/carry inputs, so rebuild the
                    # committed-watermark state from the never-donated
                    # partitions before raising: the backend stays
                    # usable, nothing has advanced.
                    overfull = [n for n in names if int(mdiag[n]["overflow"])]
                    self._rebuild_stores_from_partitions()
                    for e2 in self.entries.values():
                        e2.carry = e2.refresh_step(self.pt)[0]
                    raise RuntimeError(
                        f"maintain step for {overfull!r} overflowed device "
                        f"caps — the running match set would silently lose "
                        "groups. Re-register with a larger store_headroom / "
                        "EngineCaps, or pass strict_overflow=False for "
                        "best-effort auto-resize.")
                msp.add("store_groups",
                        sum(int(mdiag[n]["store_groups"]) for n in names))
            lat = time.perf_counter() - t0
            # Commit — the megastep is atomic across patterns: either
            # every store/carry/count advances or none did.
            for name in names:
                e = self.entries[name]
                e.store = stores2[name]
                e.carry = carries2[name]
                e.host_table = None   # the store moved on; drop the cache
                self._counts[name] = int(mdiag[name]["count"])
            for name in names:
                e = self.entries[name]
                d = mdiag[name]
                refreshed = int(d["unit_refreshes"])
                self.last_cache_hits += (self.m - refreshed) * e.n_unit_plans
                self.last_cache_misses += refreshed * e.n_unit_plans
                self.last_invalidated_parts = refreshed
                added = None
                if name in want_matches:
                    patch = self._je.comp_to_host(
                        self._flatten_live(patches[name]), e.meta.pattern,
                        self._storage_cover(e), e.full_skel)
                    added = patch.decompress(e.meta.ord_)[1]
                with tr.span("maintain", pattern=name) as psp:
                    psp.add("patch_groups", int(d["patch_groups"]))
                    psp.add("removed_groups", int(d["removed_groups"]))
                    psp.add("overflow", int(d["overflow"]))
                    psp.add("unit_refreshes", refreshed)
                reports[name] = PatternReport(
                    name=name, count_before=before[name],
                    count_after=self._counts[name],
                    # The fused step is timed once; per-pattern latency
                    # is the Eq.-11 cost share of the fused wall-clock
                    # (the same shares the profiler publishes in subs).
                    latency_s=lat * self._maintain_subs.get(
                        name, 1.0 / len(names)),
                    patch_groups=int(d["patch_groups"]),
                    removed_groups=int(d["removed_groups"]),
                    overflow=int(d["overflow"]),
                    added=added,
                    removed=removed_by[name],
                )
        self.pt = pt2
        self.graph = self.graph.apply_update(upd)
        probe_inc("cache_hits", self.last_cache_hits, metrics=obs.metrics)
        probe_inc("cache_misses", self.last_cache_misses, metrics=obs.metrics)
        probe_inc("invalidated_parts", self.last_invalidated_parts,
                  metrics=obs.metrics)
        return reports

    def _rebuild_stores_from_partitions(self) -> None:
        """Recreate every pattern's committed-watermark MatchStore by
        re-listing over the never-donated partitions ``self.pt``.

        The donation-era replacement for rebuilding from
        ``materialize()``: after a failed megastep the store inputs may
        already be consumed, but Φ at the committed watermark is
        intact, and the initial-calculation pipeline regenerates the
        same canonical store shards (grouping and merge both
        canonicalize by skeleton key under the same ownership hash).
        Raises if the re-listing itself outruns the engine caps — that
        cannot be fixed by a store resize.
        """
        for name, e in self.entries.items():
            wcoj = (e.meta.plan.wcoj
                    if e.meta.plan.executor == "wcoj" else None)
            if e.list_step is None:
                # Patterns installed from a snapshot never listed; the
                # step is compiled on first rebuild and kept.
                e.list_step = ProfiledStep(
                    f"list:{name}",
                    (self._sharded.make_wcoj_list_step(
                        e.meta.pattern, wcoj, self.mesh, self.caps,
                        e.wcoj_level_caps)
                     if wcoj is not None else
                     self._sharded.make_list_step(e.prog, self.mesh, self.caps)),
                    self._jaxprof)
            out, ldiag = e.list_step(self.pt)
            if int(ldiag["overflow"]):
                raise RuntimeError(
                    f"re-listing {name!r} while rebuilding its store "
                    f"overflowed engine caps ({int(ldiag['overflow'])} rows); "
                    "enlarge EngineCaps")
            init_step = ProfiledStep(
                f"init_store:{name}",
                (self._sharded.make_wcoj_init_store_step(
                    e.meta.pattern, e.meta.ord_, self.mesh, self.caps,
                    e.store_caps, e.wcoj_level_caps)
                 if wcoj is not None else
                 self._sharded.make_init_store_step(
                    e.prog, self.mesh, self.caps, e.store_caps)),
                self._jaxprof)
            store, idiag = init_step(out)
            if int(idiag["overflow"]):
                raise RuntimeError(
                    f"rebuilding {name!r}'s store overflowed its caps "
                    f"({int(idiag['overflow'])} entries)")
            e.store = store
            e.host_table = None

    def _resize_stores_and_retry(self, pt2, dirty, add, dele, mdiag, carries2):
        """Best-effort self-healing, megastep edition: double the
        (quantized) caps of every overflowing pattern, rebuild ALL
        pre-batch stores from the never-donated partitions (the donated
        inputs are consumed), recompile the fused step under the same
        ``maintain_mega`` profile, retry the batch — until the store
        share of the overflow clears or the retry budget is spent
        (engine-cap overflow survives and stays a counted metric).

        The retry reuses the failed attempt's carry *outputs*: the
        carry half of the megastep depends only on Φ(d') and the dirty
        flags, never on the stores, so those outputs are already
        correct for this batch (and refreshing is idempotent).
        """
        out = None
        for _ in range(self._max_store_resizes):
            over = [n for n in self.entries
                    if int(mdiag[n]["store_overflow"])]
            if not over:
                break
            for name in over:
                e = self.entries[name]
                self.store_resizes += 1
                self._obs().metrics.counter(
                    "sharded_store_resizes_total",
                    "MatchStore ×2-cap rebuilds after store overflow",
                ).inc()
                e.store_caps = quantize_store_caps(self._sharded.StoreCaps(
                    group_cap=2 * e.store_caps.group_cap,
                    set_cap=2 * e.store_caps.set_cap))
            self._rebuild_stores_from_partitions()
            self._rebuild_maintain_step()
            stores = {n: e.store for n, e in self.entries.items()}
            out = self.maintain_step(pt2, stores, carries2, dirty, add, dele)
            mdiag = out[3]
            carries2 = out[2]
        if out is None:
            raise AssertionError("resize called without store overflow")
        return out


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------

class ListingService:
    """Continuous multi-pattern subgraph listing over a dynamic graph.

    ``ingest()`` appends edge operations to the journal (validated
    against the *projected* graph — the committed graph plus everything
    pending); ``advance()`` folds pending operations into every
    registered pattern's match set in scheduler-chosen micro-batches,
    computing the decoded update delta **once per batch**; ``counts()``
    reads the live results. Sinks observe per-batch result deltas;
    ``audit_every > 0`` re-lists one pattern from scratch every N
    batches and raises on divergence.
    """

    def __init__(
        self,
        graph: Graph,
        m: int = 4,
        backend: str | StreamBackend = "host",
        scheduler: BatchScheduler | None = None,
        audit_every: int = 0,
        obs: Observability | None = None,
        plan_manager=None,
        **backend_kwargs,
    ):
        # One observability object per service — its own metrics
        # registry (two services in one process never share counters),
        # span tracer (off by default), device profiler. Pass
        # Observability.full() for span tracing, .disabled() to turn
        # every channel off.
        self.obs = obs if obs is not None else Observability()
        if isinstance(backend, str):
            if backend == "host":
                backend_obj: StreamBackend = HostBackend(graph, m=m, **backend_kwargs)
            elif backend == "sharded":
                # `m` here is the host partition count; the sharded mesh
                # size defaults to the device count — pass m explicitly
                # via backend_kwargs to override it.
                backend_obj = ShardedBackend(graph, **backend_kwargs)
            else:
                raise ValueError(f"unknown backend {backend!r}")
        else:
            backend_obj = backend
        self.backend = backend_obj
        # Attach before any register() call so initial listings and
        # device-step compiles are profiled into this service's books.
        self.backend.obs = self.obs
        self.journal = UpdateJournal()
        self.scheduler = scheduler if scheduler is not None else BatchScheduler()
        if self.backend.max_batch_ops is not None:
            self.scheduler.clamp_max_ops(self.backend.max_batch_ops)
        self.audit_every = int(audit_every)
        self.metrics: List[BatchMetrics] = []
        self.audits: List[Tuple[int, str, bool]] = []   # (batch_index, pattern, ok)
        self.sinks: List[Sink] = []
        self._graph = graph                   # committed graph mirror
        self._proj_codes = set(int(c) for c in graph.codes)
        self._proj_n = graph.n
        self._committed = 0
        self._batches = 0
        self._audit_rr = 0
        #: optional drift-triggered online re-optimizer
        #: (:class:`repro.stream.plan_manager.PlanManager`); consulted
        #: after every committed batch.
        self.plan_manager = plan_manager

    # -------------------------------------------------------------- patterns
    def register(self, name: str, pattern: Pattern, cover=None) -> int:
        """Register a pattern; returns its initial match count.

        Patterns join at the *committed* watermark: the initial listing
        runs over the committed graph, and pending journal operations
        apply to the new pattern on the next :meth:`advance` like to
        every other.
        """
        count = self.backend.register(name, pattern, cover)
        meta = self.backend.meta(name)
        self.scheduler.register(name, pattern, meta.ord_, meta.units)
        self.scheduler.refresh(GraphStats.of(self._graph))
        if meta.plan is not None:
            self.obs.record_plan(name, meta.plan.to_json())
        return count

    def patterns(self) -> List[str]:
        return self.backend.names()

    # ---------------------------------------------------------------- ingest
    def ingest(self, update: GraphUpdate | None = None, *,
               add: Iterable = (), delete: Iterable = ()) -> int:
        """Append one update to the journal; returns the tail watermark.

        Validated against the projected graph so any window of the
        journal nets to a well-formed Alg. 4 batch.
        """
        if update is None:
            update = GraphUpdate.make(delete=delete, add=add)
        d_codes = [int(c) for c in edge_codes(np.asarray(update.delete))]
        a_codes = [int(c) for c in edge_codes(np.asarray(update.add))]
        # Duplicates inside one update would double-journal an op and
        # flip the parity netting, desyncing projection from commit.
        if len(set(d_codes)) != len(d_codes) or len(set(a_codes)) != len(a_codes):
            raise ValueError("update contains duplicate edges")
        for c in d_codes:
            if c not in self._proj_codes:
                raise ValueError(f"delete of absent edge {tuple(decode_edges(np.array([c]))[0])}")
        for c in a_codes:
            if c in self._proj_codes:
                raise ValueError(f"insert of present edge {tuple(decode_edges(np.array([c]))[0])}")
        if len(set(d_codes) & set(a_codes)):
            raise ValueError("E_d(U) and E_a(U) must be disjoint")
        self._proj_codes.difference_update(d_codes)
        self._proj_codes.update(a_codes)
        if np.asarray(update.add).size:
            self._proj_n = max(self._proj_n, int(np.asarray(update.add).max()) + 1)
        return self.journal.append(update)

    # --------------------------------------------------------------- advance
    def _wanted(self) -> set:
        want = set()
        for s in self.sinks:
            if s.wants_matches:
                for name in self.backend.names():
                    if s.accepts(name):
                        want.add(name)
        return want

    def advance(self, watermark: int | None = None) -> List[BatchMetrics]:
        """Fold pending journal ops (up to ``watermark``) into all match
        sets, one scheduler-sized micro-batch at a time."""
        target = self.journal.tail if watermark is None else min(int(watermark), self.journal.tail)
        done: List[BatchMetrics] = []
        want = self._wanted()
        tr = self.obs.tracer
        mreg = self.obs.metrics
        while self._committed < target:
            k = self.scheduler.next_batch_size(target - self._committed)
            hi = self._committed + k
            predicted = self.scheduler.predict_seconds(k)
            self.obs.jaxprof.on_batch_start(self._batches)
            with tr.span("batch", batch_index=self._batches,
                         lo=self._committed, hi=hi) as bsp:
                t0 = time.perf_counter()
                with tr.span("shared_delta") as dsp:
                    delta = compute_shared_delta(self.journal, self._committed,
                                                 hi, metrics=mreg)
                    dsp.add("net_add", int(np.asarray(delta.update.add).shape[0]))
                    dsp.add("net_delete",
                            int(np.asarray(delta.update.delete).shape[0]))
                reports = self.backend.apply_batch(delta, want)
                latency = time.perf_counter() - t0
                self.scheduler.observe(k, latency)
                # Both backends already advanced their committed graph
                # while applying the batch — reuse it instead of a
                # second rebuild.
                self._graph = self.backend.graph
                # host backend shares the delta's stats; the sharded
                # backend never materializes Φ(d') on host, so refresh
                # from the mirror
                self.scheduler.refresh(
                    delta.stats if delta.stats is not None else GraphStats.of(self._graph))
                bm = BatchMetrics(
                    batch_index=self._batches, lo=self._committed, hi=hi,
                    n_ops=k, net_add=int(np.asarray(delta.update.add).shape[0]),
                    net_delete=int(np.asarray(delta.update.delete).shape[0]),
                    latency_s=latency, patterns=reports,
                    storage_overflow=getattr(self.backend, "last_storage_overflow", 0),
                    cand_vertices=getattr(self.backend, "last_cand_vertices", -1),
                    cand_edges=getattr(self.backend, "last_cand_edges", -1),
                    host_bytes=getattr(self.backend, "last_host_bytes", 0),
                    cache_hits=getattr(self.backend, "last_cache_hits", -1),
                    cache_misses=getattr(self.backend, "last_cache_misses", -1),
                    invalidated_parts=getattr(self.backend, "last_invalidated_parts", -1),
                    predicted_s=predicted if predicted is not None else -1.0,
                )
                if bm.cache_hits >= 0:
                    # Calibrate the scheduler's warm `fixed` term from
                    # the observed unit-cache traffic (no-op batches
                    # carry none).
                    self.scheduler.observe_cache(bm.cache_hits, bm.cache_misses)
                self._record_batch(bm, bsp)
                self.metrics.append(bm)
                done.append(bm)
                self._committed = hi
                self._batches += 1
                with tr.span("sinks") as ksp:
                    self._emit(bm, delta)
                    ksp.add("sinks", len(self.sinks))
            self.obs.jaxprof.on_batch_end(self._batches - 1)
            if self.audit_every and self._batches % self.audit_every == 0:
                self._periodic_audit()
            if self.plan_manager is not None:
                # Between batches = at the committed watermark, the only
                # point where a plan swap is collective-safe.
                self.plan_manager.on_batch(self)
        return done

    def _record_batch(self, bm: BatchMetrics, bsp) -> None:
        """Fold one committed batch into the service's instruments (and
        annotate its root span so span counters reconcile with registry
        deltas — asserted in tests)."""
        m = self.obs.metrics
        m.counter("stream_batches_total", "committed micro-batches").inc()
        m.counter("stream_ops_total", "journal ops committed").inc(bm.n_ops)
        m.gauge("stream_watermark_lag",
                "journal ops ingested but not yet committed",
                ).set(self.journal.tail - bm.hi)
        if bm.latency_s > 0:
            # Below-clock-resolution batches carry no rate signal: they
            # are excluded from the throughput gauge and the latency
            # histogram rather than rendering as infinities.
            m.histogram("stream_batch_latency_seconds",
                        "end-to-end latency per committed micro-batch",
                        ).observe(bm.latency_s)
            m.gauge("stream_throughput_ops_per_s",
                    "ops/s of the last measurable batch",
                    ).set(bm.throughput_ops_s)
        for name, rep in bm.patterns.items():
            if rep.latency_s > 0:
                m.histogram("stream_pattern_latency_seconds",
                            "per-pattern maintain latency",
                            labels=("pattern",),
                            ).labels(pattern=name).observe(rep.latency_s)
        if bm.overflow:
            m.counter("stream_overflow_total",
                      "summed device cap overflow across batches",
                      ).inc(bm.overflow)
        if bm.cand_vertices >= 0:
            m.gauge("stream_cand_vertices",
                    "candidate vertex-set size of the last delta batch",
                    ).set(bm.cand_vertices)
            m.gauge("stream_cand_edges",
                    "candidate edge-set size of the last delta batch",
                    ).set(bm.cand_edges)
        if bm.predicted_s >= 0:
            m.gauge("scheduler_predicted_seconds",
                    "§IV-D model prediction for the last batch",
                    ).set(bm.predicted_s)
        drift = self.scheduler.drift()
        if drift is not None:
            m.gauge("scheduler_drift_ewma",
                    "EWMA of observed/predicted batch latency — the "
                    "cost-model drift sensor for plan re-optimization",
                    ).set(drift)
        # Root-span counters mirror the registry deltas of this batch.
        bsp.add("n_ops", bm.n_ops)
        bsp.add("net_add", bm.net_add)
        bsp.add("net_delete", bm.net_delete)
        bsp.add("host_bytes", bm.host_bytes)
        if bm.cache_hits >= 0:
            bsp.add("cache_hits", bm.cache_hits)
            bsp.add("cache_misses", bm.cache_misses)
            bsp.add("invalidated_parts", bm.invalidated_parts)

    def _emit(self, bm: BatchMetrics, delta: SharedDelta) -> None:
        for name, rep in bm.patterns.items():
            accepting = [s for s in self.sinks if s.accepts(name)]
            if not accepting:
                continue
            ev = BatchEvent(
                batch_index=bm.batch_index, lo=bm.lo, hi=bm.hi, pattern=name,
                count_before=rep.count_before, count_after=rep.count_after,
                n_ops=bm.n_ops, net_add=bm.net_add, net_delete=bm.net_delete,
                latency_s=rep.latency_s, overflow=rep.overflow,
                added=rep.added, removed=rep.removed,
            )
            for s in accepting:
                s.emit(ev)
            # Retained metrics keep scalars only; the decompressed row
            # deltas live as long as the sinks want them, not forever.
            rep.added = None
            rep.removed = None

    # ---------------------------------------------------------------- results
    def count(self, name: str) -> int:
        return self.backend.count(name)

    def counts(self) -> Dict[str, int]:
        return {name: self.backend.count(name) for name in self.backend.names()}

    def subscribe(self, sink: Sink) -> Sink:
        self.sinks.append(sink)
        return sink

    # ----------------------------------------------------------------- state
    @property
    def committed_watermark(self) -> int:
        return self._committed

    @property
    def graph(self) -> Graph:
        """The committed graph (watermark ``committed_watermark``)."""
        return self._graph

    def projected_graph(self) -> Graph:
        """The graph at the journal tail (committed + pending)."""
        codes = np.array(sorted(self._proj_codes), np.int64)
        return Graph._from_codes(self._proj_n, codes)

    def compact(self) -> int:
        """Truncate the journal below the committed watermark."""
        return self.journal.truncate(self._committed)

    # ------------------------------------------------------------ durability
    _SNAP_MAGIC = "repro.stream.snapshot"

    def snapshot(self, path: str) -> str:
        """Persist the service at its committed watermark into ``path``.

        A snapshot is exactly *materialize() per pattern + journal
        save*: ``graph.npz`` (the committed graph), one
        ``matches_<name>.npz`` per pattern (its compressed match set —
        the sharded backend pulls it through the byte-accounted
        :meth:`~StreamBackend.materialize` contract), ``journal.jsonl``
        (including any ops still pending beyond the watermark — they
        replay after restore), and ``meta.json`` naming the watermark
        and the registered patterns. ``meta.json`` is written last and
        atomically, so its presence is the commit record: a crash
        mid-snapshot leaves no half-snapshot that :meth:`restore` would
        accept — and re-snapshotting into a used directory deletes the
        old ``meta.json`` *first*, so a crash mid-rewrite can never
        leave a stale commit record pointing at newer artifacts.
        """
        os.makedirs(path, exist_ok=True)
        meta_path = os.path.join(path, "meta.json")
        if os.path.exists(meta_path):
            os.remove(meta_path)
        self.journal.save(os.path.join(path, "journal.jsonl"))
        np.savez(os.path.join(path, "graph.npz"),
                 codes=np.asarray(self._graph.codes, np.int64),
                 n=np.int64(self._graph.n))
        patterns = []
        for name in self.backend.names():
            meta = self.backend.meta(name)
            _save_table(os.path.join(path, f"matches_{name}.npz"),
                        self.backend.materialize(name))
            patterns.append({
                "name": name,
                "edges": sorted([int(a), int(b)] for a, b in meta.pattern.edges),
                "cover": [int(c) for c in meta.cover],
            })
        head = {"kind": self._SNAP_MAGIC, "version": 1,
                "watermark": int(self._committed), "patterns": patterns}
        tmp = f"{meta_path}.tmp"
        with open(tmp, "w") as f:
            json.dump(head, f, indent=2, sort_keys=True)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, meta_path)
        return path

    @classmethod
    def restore(cls, path: str, backend: str | StreamBackend = "host",
                scheduler: BatchScheduler | None = None, audit_every: int = 0,
                **backend_kwargs) -> "ListingService":
        """Rebuild a service from a :meth:`snapshot` and resume.

        The backend is reconstructed over the snapshot graph and each
        pattern's match set is installed without a from-scratch listing
        (the sharded backend rebuilds its device
        :class:`~repro.dist.sharded.MatchStore` via ``stack_matches``
        and cold-fills its unit-table carries with one refresh step).
        Journal ops pending beyond the snapshot watermark survive and
        fold in on the next :meth:`advance` — the restored service is
        indistinguishable from one that never stopped (parity-tested).
        The restore backend may differ from the snapshot's (e.g. host
        snapshot → sharded restore): a snapshot is backend-neutral.
        """
        with open(os.path.join(path, "meta.json")) as f:
            head = json.load(f)
        if head.get("kind") != cls._SNAP_MAGIC:
            raise ValueError(f"{path} is not a service snapshot")
        if head.get("version") != 1:
            raise ValueError(
                f"{path}: unsupported snapshot version {head.get('version')!r}")
        gz = np.load(os.path.join(path, "graph.npz"))
        graph = Graph._from_codes(int(gz["n"]), gz["codes"].astype(np.int64))
        svc = cls(graph, backend=backend, scheduler=scheduler,
                  audit_every=audit_every, **backend_kwargs)
        svc.journal = UpdateJournal.load(os.path.join(path, "journal.jsonl"))
        w = int(head["watermark"])
        if w < svc.journal.base:
            raise ValueError(
                f"snapshot watermark {w} precedes journal base {svc.journal.base}")
        svc._committed = w
        for spec in head["patterns"]:
            pat = Pattern.make([tuple(e) for e in spec["edges"]])
            table = _load_table(
                os.path.join(path, f"matches_{spec['name']}.npz"), pat)
            svc.backend.restore_pattern(
                spec["name"], pat, tuple(int(c) for c in spec["cover"]), table)
            meta = svc.backend.meta(spec["name"])
            svc.scheduler.register(spec["name"], pat, meta.ord_, meta.units)
            if meta.plan is not None:
                svc.obs.record_plan(spec["name"], meta.plan.to_json())
        svc.scheduler.refresh(GraphStats.of(graph))
        if svc.journal.tail > w:
            # pending ops re-project on top of the committed graph
            proj = graph.apply_update(svc.journal.window(w))
            svc._proj_codes = {int(c) for c in proj.codes}
            svc._proj_n = proj.n
        return svc

    # ----------------------------------------------------------------- audit
    def audit(self, names: Sequence[str] | None = None,
              raise_on_mismatch: bool = True) -> Dict[str, bool]:
        """From-scratch re-listing on the committed graph vs. live counts."""
        out = {}
        for name in (names if names is not None else self.backend.names()):
            meta = self.backend.meta(name)
            fresh = DDSL(self._graph, meta.pattern, m=4, cover=meta.cover)
            fresh.initial()
            ok = fresh.count() == self.backend.count(name)
            out[name] = ok
            if not ok and raise_on_mismatch:
                raise RuntimeError(
                    f"audit mismatch for {name!r}: incremental={self.backend.count(name)} "
                    f"from-scratch={fresh.count()} at watermark {self._committed}")
        return out

    def _periodic_audit(self) -> None:
        names = self.backend.names()
        if not names:
            return
        name = names[self._audit_rr % len(names)]
        self._audit_rr += 1
        # Record the verdict first so a divergence is visible in
        # `audits` even though it also aborts the service.
        ok = self.audit([name], raise_on_mismatch=False)[name]
        self.audits.append((self._batches - 1, name, ok))
        if not ok:
            raise RuntimeError(
                f"periodic audit mismatch for {name!r} at watermark {self._committed}")
