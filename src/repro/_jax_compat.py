"""Version-compat layer for the JAX SPMD surface this repo targets.

The codebase is written against the modern JAX API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``,
``jax.set_mesh``). Older runtimes (0.4.x) ship the same functionality
under different names (``jax.experimental.shard_map.shard_map`` with
``check_rep``, plain ``Mesh`` context managers). :func:`install` fills
the gaps *only when missing*, so on a current JAX it is a no-op.

Installed from ``repro/__init__.py`` — importing any ``repro`` module is
enough to make the modern spellings usable.
"""

from __future__ import annotations

import enum
import functools

import jax
import jax.sharding

__all__ = ["install", "donate_jit"]

# Backends where XLA buffer donation is real (the donated input's memory
# is aliased to an output). XLA:CPU accepts the annotation but ignores
# it and warns per call about every unused donation.
_DONATING_PLATFORMS = ("tpu", "gpu", "cuda", "rocm")


def donate_jit(fn, donate_argnums):
    """``jax.jit(fn, donate_argnums=...)`` where donation is honored.

    On platforms that implement buffer donation the listed arguments are
    donated — their device buffers are reused for the outputs, so a
    store-sized step updates in place instead of doubling resident
    memory. On CPU the same annotation is a warning-spewing no-op, so
    the shim falls back to a plain ``jax.jit``.

    Either way, callers must treat the donated arguments as *consumed*:
    any retry path has to rebuild them from non-donated state rather
    than re-use the passed-in values. CPU test runs exercise exactly the
    recovery paths the donating platforms need.
    """
    try:
        donate = jax.default_backend() in _DONATING_PLATFORMS
    except Exception:  # pragma: no cover - backend init failure
        donate = False
    if donate:
        try:
            return jax.jit(fn, donate_argnums=tuple(donate_argnums))
        except TypeError:  # pragma: no cover - ancient jit signature
            pass
    return jax.jit(fn)


def _compat_shard_map():
    from jax.experimental.shard_map import shard_map as _sm

    @functools.wraps(_sm)
    def shard_map(f=None, *, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, check_rep=None, **kw):
        if check_vma is not None and check_rep is None:
            check_rep = check_vma
        if check_rep is None:
            check_rep = False
        if f is None:  # decorator form
            return lambda fn: shard_map(fn, mesh=mesh, in_specs=in_specs,
                                        out_specs=out_specs, check_rep=check_rep, **kw)
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_rep, **kw)

    return shard_map


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (Auto/Explicit/Manual)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


class _MeshContext:
    """``with jax.set_mesh(mesh): ...`` on runtimes without ``set_mesh``."""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        return self.mesh.__exit__(*exc)


def install() -> None:
    """Idempotently backfill modern JAX names onto an older runtime."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _compat_shard_map()
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _MeshContext
    if not hasattr(jax, "make_mesh"):
        # Pre-0.4.35: no jax.make_mesh at all — build from mesh_utils.
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
            from jax.experimental import mesh_utils

            devs = mesh_utils.create_device_mesh(tuple(axis_shapes), devices=devices)
            return jax.sharding.Mesh(devs, tuple(axis_names))

        jax.make_mesh = make_mesh
    else:
        # make_mesh without the axis_types kwarg: swallow it.
        try:
            import inspect

            if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
                _mm = jax.make_mesh

                @functools.wraps(_mm)
                def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
                    return _mm(axis_shapes, axis_names, **kw)

                jax.make_mesh = make_mesh
        except (ValueError, TypeError):  # pragma: no cover - exotic runtimes
            pass
