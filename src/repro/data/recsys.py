"""Click-log generator for DLRM (Zipfian sparse ids, synthetic CTR labels)."""

from __future__ import annotations

import numpy as np

__all__ = ["click_batches"]


def click_batches(n_dense: int, n_sparse: int, rows: int, batch: int,
                  *, multi_hot: int = 1, seed: int = 0):
    rng = np.random.default_rng(seed)
    while True:
        dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
        # Zipf-ish ids via exponentiated uniforms (cheap, heavy head)
        u = rng.random(size=(batch, n_sparse, multi_hot))
        ids = np.minimum((u ** 4 * rows).astype(np.int32), rows - 1)
        logits = dense[:, 0] * 0.5 + (ids[:, 0, 0] % 7 == 0) * 0.3
        labels = (rng.random(batch) < 1.0 / (1.0 + np.exp(-logits))).astype(np.float32)
        yield dense, ids, labels
