"""Synthetic but statistically-honest data pipelines.

- ``graphs``  — RMAT power-law generators for DDSL data graphs, update-
  batch samplers, GraphData builders, and a real neighbor sampler for
  GraphSAGE minibatch training;
- ``tokens``  — deterministic LM token streams (Zipfian marginals);
- ``recsys``  — click-log generator for DLRM (Zipfian sparse ids);
- ``pipeline``— double-buffered host prefetcher.
"""

from . import graphs, pipeline, recsys, tokens  # noqa: F401
