"""Host-side double-buffered prefetcher (overlap input copy with compute)."""

from __future__ import annotations

import queue
import threading
from typing import Iterable, Iterator

__all__ = ["prefetch"]


def prefetch(it: Iterable, depth: int = 2) -> Iterator:
    """Run the producer on a background thread with a bounded buffer."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
