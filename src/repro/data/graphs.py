"""Graph generators + samplers (power-law, matching the paper's PR model)."""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.graph import Graph, GraphUpdate, edge_codes

__all__ = [
    "rmat_graph",
    "sample_update",
    "build_graph_data",
    "NeighborSampler",
]


def rmat_graph(n_log2: int, n_edges: int, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """R-MAT generator → power-law degree distribution (Chakrabarti et al.)."""
    rng = np.random.default_rng(seed)
    n = 1 << n_log2
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    for bit in range(n_log2):
        r = rng.random(n_edges)
        src_bit = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        dst_bit = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    mask = src != dst
    return Graph.from_edges(np.stack([src[mask], dst[mask]], 1), n=n)


def sample_update(graph: Graph, n_delete: int, n_add: int, seed: int = 0) -> GraphUpdate:
    """Paper §VII-C protocol: random existing deletions + random fresh inserts."""
    rng = np.random.default_rng(seed)
    edges = graph.edges()
    didx = rng.choice(edges.shape[0], size=min(n_delete, edges.shape[0]), replace=False)
    dele = edges[didx]
    codes = set(graph.codes.tolist())
    add = []
    while len(add) < n_add:
        a_, b_ = rng.integers(graph.n, size=2)
        if a_ == b_:
            continue
        code = (min(int(a_), int(b_)) << 32) | max(int(a_), int(b_))
        if code in codes:
            continue
        codes.add(code)
        add.append((min(int(a_), int(b_)), max(int(a_), int(b_))))
    return GraphUpdate(delete=dele, add=np.asarray(add, np.int64).reshape(-1, 2))


def build_graph_data(n_nodes: int, n_edges: int, d_feat: int, d_edge: int = 0,
                     seed: int = 0, pad_nodes: int | None = None,
                     pad_edges: int | None = None, geometric: bool = False):
    """Padded GraphData arrays (numpy) for the GNN models."""
    rng = np.random.default_rng(seed)
    pn = pad_nodes or n_nodes
    pe = pad_edges or n_edges
    x = np.zeros((pn, d_feat), np.float32)
    x[:n_nodes] = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    src = np.full(pe, pn - 1, np.int32)
    dst = np.full(pe, pn - 1, np.int32)
    src[:n_edges] = rng.integers(0, n_nodes, n_edges)
    dst[:n_edges] = rng.integers(0, n_nodes, n_edges)
    ea = np.zeros((pe, max(d_edge, 1)), np.float32)
    if d_edge:
        ea[:n_edges] = rng.normal(size=(n_edges, d_edge)).astype(np.float32)
    nm = np.zeros(pn, bool)
    nm[:n_nodes] = True
    em = np.zeros(pe, bool)
    em[:n_edges] = True
    pos = np.zeros((pn, 3), np.float32)
    if geometric:
        pos[:n_nodes] = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    return dict(x=x, src=src, dst=dst, edge_attr=ea, node_mask=nm, edge_mask=em, positions=pos)


class NeighborSampler:
    """Uniform k-hop neighbor sampler over CSR (GraphSAGE minibatch_lg).

    Fixed fanouts with replacement → static shapes; the feature gather per
    frontier layer is the host side of the sampled-training pipeline.
    """

    def __init__(self, graph: Graph, features: np.ndarray, fanouts: Tuple[int, ...], seed: int = 0):
        self.g = graph
        self.features = features
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray):
        """Returns per-layer frontier feature arrays [B·Πf..., d_feat]."""
        frontiers = [seeds.astype(np.int64)]
        for f in self.fanouts:
            cur = frontiers[-1]
            starts = self.g.indptr[cur]
            degs = np.maximum(self.g.degrees[cur], 1)
            offs = self.rng.integers(0, 1 << 62, size=(cur.shape[0], f)) % degs[:, None]
            nbrs = self.g.indices[np.minimum(starts[:, None] + offs, self.g.indptr[cur + 1][:, None] - 1)]
            isolated = self.g.degrees[cur] == 0
            nbrs[isolated] = cur[isolated, None]  # self-loop fallback
            frontiers.append(nbrs.reshape(-1))
        return [self.features[f] for f in frontiers]
