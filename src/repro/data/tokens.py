"""Deterministic synthetic LM token streams (Zipfian unigram marginals)."""

from __future__ import annotations

import numpy as np

__all__ = ["token_batches"]


def token_batches(vocab: int, batch: int, seq: int, *, seed: int = 0, zipf_a: float = 1.2):
    """Infinite iterator of (tokens, labels) int32 arrays [batch, seq]."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs).astype(np.int32)
        yield toks[:, :-1], toks[:, 1:]
