"""Jit'd public wrappers around the Pallas kernels.

Each op:
- dispatches to the Pallas kernel (``interpret=True`` automatically on
  CPU hosts so the same call validates everywhere, compiled on TPU);
- can be forced to the pure-jnp reference with ``backend="ref"`` — the
  dry-run/roofline path uses ``ref`` so the lowered HLO reflects the
  XLA-native formulation, and the Pallas path is benchmarked separately.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import autotune, ref
from .embedding_bag import embedding_bag_pallas
from .flash_attention import flash_attention_pallas
from .member_probe import member_probe_pallas
from .segment_sum import segment_sum_pallas
from .set_intersect import set_intersect_pallas

__all__ = [
    "set_intersect",
    "member_probe",
    "segment_sum",
    "embedding_bag",
    "flash_attention",
    "default_backend",
]


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def _interpret(backend: str) -> bool:
    return backend != "pallas"


def set_intersect(
    a: jax.Array,
    b: jax.Array,
    *,
    pad: int,
    backend: str | None = None,
    tile_g: int | None = None,
) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return ref.set_intersect_ref(a, b, pad)
    if tile_g is None:
        tile_g = autotune.set_intersect_tiles(a.shape[0])
    return set_intersect_pallas(a, b, pad=pad, tile_g=tile_g,
                                interpret=_interpret(backend))


def member_probe(
    q_hi: jax.Array,
    q_lo: jax.Array,
    t_hi: jax.Array,
    t_lo: jax.Array,
    *,
    backend: str | None = None,
    tile_q: int | None = None,
    tile_t: int | None = None,
) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return ref.member_probe_ref(q_hi, q_lo, t_hi, t_lo)
    if tile_q is None or tile_t is None:
        tq, tt = autotune.member_probe_tiles(q_hi.shape[0], t_hi.shape[0])
        tile_q = tq if tile_q is None else tile_q
        tile_t = tt if tile_t is None else tile_t
    return member_probe_pallas(q_hi, q_lo, t_hi, t_lo, tile_q=tile_q,
                               tile_t=tile_t, interpret=_interpret(backend))


def segment_sum(
    data: jax.Array, segment_ids: jax.Array, num_segments: int, *, backend: str | None = None
) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return ref.segment_sum_ref(data, segment_ids, num_segments)
    return segment_sum_pallas(data, segment_ids, num_segments, interpret=_interpret(backend))


def embedding_bag(
    table: jax.Array,
    indices: jax.Array,
    bag_ids: jax.Array,
    num_bags: int,
    *,
    backend: str | None = None,
) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return ref.embedding_bag_ref(table, indices, bag_ids, num_bags)
    # Kernel contract: sorted by bag id; bags may be empty → mask after.
    order = jnp.argsort(bag_ids, stable=True)
    idx = indices[order]
    bag = bag_ids[order]
    out = embedding_bag_pallas(table, idx, bag, num_bags, interpret=_interpret(backend))
    counts = jax.ops.segment_sum(jnp.ones_like(bag), bag, num_segments=num_bags)
    return jnp.where(counts[:, None] > 0, out, 0).astype(table.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    backend: str | None = None,
    tile_q: int = 128,
    tile_k: int = 128,
) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, q_offset=q_offset)
    return flash_attention_pallas(
        q, k, v, causal=causal, q_offset=q_offset,
        tile_q=tile_q, tile_k=tile_k, interpret=_interpret(backend),
    )
