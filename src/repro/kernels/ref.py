"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth; kernels are validated
against these in interpret mode across shape/dtype sweeps
(``tests/test_kernels.py``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "set_intersect_ref",
    "member_probe_ref",
    "segment_sum_ref",
    "embedding_bag_ref",
    "flash_attention_ref",
]


def set_intersect_ref(a: jax.Array, b: jax.Array, pad: int) -> jax.Array:
    """mask[g, i] = (a[g, i] != pad) and a[g, i] ∈ {b[g, :]} \\ {pad}."""
    hit = (a[:, :, None] == b[:, None, :]) & (b[:, None, :] != pad)
    return jnp.any(hit, axis=-1) & (a != pad)


def member_probe_ref(
    q_hi: jax.Array, q_lo: jax.Array, t_hi: jax.Array, t_lo: jax.Array
) -> jax.Array:
    """out[i] = (q_hi[i], q_lo[i]) ∈ zip(t_hi, t_lo); pad = (-1, -1).

    The table is sorted lexicographically by (hi, lo) with pads last
    (engine invariant), so this is a vectorized binary search —
    O(N·log M) gathers instead of an N×M outer compare. The Pallas
    kernel keeps the tiled-compare formulation (VPU-friendly for
    per-partition table sizes); both implement the same predicate.
    """
    m = t_hi.shape[0]
    if m == 0:
        return jnp.zeros(q_hi.shape, bool)
    qh = q_hi.astype(jnp.int32)
    ql = q_lo.astype(jnp.int32)
    # pads (-1,-1) sort first numerically; remap them to +inf-like keys
    big = jnp.int32(2**31 - 1)
    th = jnp.where((t_hi == -1) & (t_lo == -1), big, t_hi.astype(jnp.int32))
    tl = jnp.where((t_hi == -1) & (t_lo == -1), big, t_lo.astype(jnp.int32))
    lo = jnp.zeros(qh.shape, jnp.int32)
    hi = jnp.full(qh.shape, m, jnp.int32)
    steps = max(1, int(math.ceil(math.log2(m + 1))) + 1)
    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        midc = jnp.clip(mid, 0, m - 1)
        th_m = th[midc]
        tl_m = tl[midc]
        less = (th_m < qh) | ((th_m == qh) & (tl_m < ql))
        lo = jnp.where(less, mid + 1, lo)
        hi = jnp.where(less, hi, mid)
        return lo, hi
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    pos = jnp.clip(lo, 0, m - 1)
    found = (th[pos] == qh) & (tl[pos] == ql)
    valid_q = ~((q_hi == -1) & (q_lo == -1))
    return found & valid_q


def segment_sum_ref(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    """out[s] = Σ_{i : segment_ids[i] = s} data[i]; ids ≥ num_segments dropped."""
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def embedding_bag_ref(table: jax.Array, indices: jax.Array, bag_ids: jax.Array, num_bags: int) -> jax.Array:
    """out[b] = Σ_{i : bag_ids[i] = b} table[indices[i]] (sum mode)."""
    rows = jnp.take(table, indices, axis=0)
    return jax.ops.segment_sum(rows, bag_ids, num_segments=num_bags)


def flash_attention_ref(
    q: jax.Array,  # [B, Hq, Lq, Dh]
    k: jax.Array,  # [B, Hkv, Lk, Dh]
    v: jax.Array,  # [B, Hkv, Lk, Dh]
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    """Grouped-query attention oracle (fp32 softmax accumulation).

    ``q_offset`` shifts query positions for decode/chunked-prefill masks:
    query i attends to keys j ≤ i + q_offset.
    """
    b, hq, lq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, lq, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) / jnp.sqrt(dh).astype(jnp.float32)
    if causal:
        lk = k.shape[2]
        qpos = jnp.arange(lq)[:, None] + q_offset
        kpos = jnp.arange(lk)[None, :]
        mask = kpos <= qpos
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, lq, dh).astype(q.dtype)
