"""Tile autotune table for the Pallas kernels.

Block/tile sizes for :func:`repro.kernels.ops.member_probe` and
:func:`repro.kernels.ops.set_intersect` keyed by (backend platform,
shape bucket). The kernels take their tiles as static arguments, so
every distinct tile choice is a distinct compilation — the table keeps
the choices coarse (power-of-two shape buckets) and deterministic so
jitted callers hit a handful of stable variants instead of recompiling
per exact cap shape.

The TPU rows were swept over the engine cap shapes the benchmarks
exercise (edge tables 2^11..2^17, group counts 2^10..2^14); lane width
pins the last dimension to multiples of 128, and past L2-sized tables
wider ``tile_t`` amortizes the grid better than deeper ``tile_q``. The
CPU/interpret rows only bound working-set size — interpret mode is a
parity path, not a perf path (see :func:`default_use_pallas`).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = [
    "default_use_pallas",
    "member_probe_tiles",
    "rows_from_sweep",
    "set_intersect_tiles",
    "platform",
]


def platform() -> str:
    """Current XLA backend platform name (``cpu`` when undeterminable)."""
    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover - no backend initialized
        return "cpu"


def default_use_pallas(plat: Optional[str] = None) -> bool:
    """Platform default for ``EngineCaps.use_pallas``.

    Compiled Pallas kernels pay off on TPU; everywhere else the kernels
    run in interpret mode, which is bit-identical but strictly slower
    than the engine's native binary-search probes — so the default is on
    for TPU only.
    """
    return (plat if plat is not None else platform()) == "tpu"


# (platform, kernel) → ascending (shape-bucket upper bound, tiles);
# ``None`` bound = catch-all. Unknown platforms fall back to "cpu" rows.
_MEMBER_PROBE = {
    # bucket key: padded edge-table length n_t → (tile_q, tile_t)
    "tpu": ((4096, (512, 2048)), (32768, (1024, 2048)), (None, (1024, 4096))),
    "cpu": ((4096, (1024, 2048)), (None, (2048, 4096))),
}
_SET_INTERSECT = {
    # bucket key: group count n_g → (tile_g,)
    "tpu": ((1024, (256,)), (8192, (512,)), (None, (1024,))),
    "cpu": ((None, (256,)),),
}


def _lookup(table, plat: Optional[str], n: int):
    rows = table.get(plat if plat is not None else platform(), table["cpu"])
    for bound, tiles in rows:
        if bound is None or n <= bound:
            return tiles
    return rows[-1][1]  # pragma: no cover - catch-all row always present


def member_probe_tiles(n_q: int, n_t: int,
                       plat: Optional[str] = None) -> Tuple[int, int]:
    """``(tile_q, tile_t)`` for an ``n_q`` query / ``n_t`` table probe.

    Bucketed by the table length — the table side is what gets swept
    per query tile, so it dominates the kernel's working set.
    """
    del n_q  # queries are tiled independently; the table side dominates
    return _lookup(_MEMBER_PROBE, plat, n_t)


def set_intersect_tiles(n_groups: int, plat: Optional[str] = None) -> int:
    """``tile_g`` (group-axis tile) for an ``n_groups``-row intersection."""
    return _lookup(_SET_INTERSECT, plat, n_groups)[0]


def rows_from_sweep(doc: dict) -> dict:
    """Re-record the bucket tables from a ``--sweep-tiles`` artifact.

    ``doc`` is the JSON written by ``benchmarks.bench_kernels
    --sweep-tiles``: per-cell timings of every (shape bucket × candidate
    tile). Returns the winning rows in exactly the `_MEMBER_PROBE` /
    `_SET_INTERSECT` literal shape, ready to paste as this platform's
    entry::

        {"member_probe": [[4096, [512, 2048]], ..., [None, [1024, 4096]]],
         "set_intersect": [[1024, [256]], ..., [None, [1024]]]}

    The last (largest) bucket becomes the ``None`` catch-all row, same
    convention as the shipped tables.
    """

    def winners(cells, bucket_key, tile_keys):
        best = {}
        for c in cells:
            b = int(c[bucket_key])
            if b not in best or float(c["us"]) < float(best[b]["us"]):
                best[b] = c
        rows = []
        for i, b in enumerate(sorted(best)):
            tiles = [int(best[b][k]) for k in tile_keys]
            bound = None if i == len(best) - 1 else b
            rows.append([bound, tiles])
        return rows

    return {
        "member_probe": winners(doc.get("member_probe", ()),
                                "n_t", ("tile_q", "tile_t")),
        "set_intersect": winners(doc.get("set_intersect", ()),
                                 "n_g", ("tile_g",)),
    }
