"""Padded-set intersection kernel — the DDSL join/listing hot spot.

TPU adaptation of the paper's adjacency-intersection inner loop: instead
of pointer-chasing sorted-merge (CPU/GPU idiom), each grid step loads a
``(TG, CA)`` tile of query sets and the matching ``(TG, CB)`` tile of
target sets into VMEM and evaluates a broadcast compare-reduce
``any(a[:, :, None] == b[:, None, :])`` on the VPU. Set capacities are the
static paddings the JAX engine derives from the paper's match-size
estimator, so tiles are dense and MXU/VPU-aligned by construction.

Used by: compressed-vertex-set intersection in the distributed CC-join
(Alg. 2 line 4) and candidate filtering in Nav-join expansion.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["set_intersect_pallas"]


def _kernel(a_ref, b_ref, o_ref, *, pad: int):
    a = a_ref[...]
    b = b_ref[...]
    hit = (a[:, :, None] == b[:, None, :]) & (b[:, None, :] != pad)
    o_ref[...] = (jnp.any(hit, axis=-1) & (a != pad)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("pad", "tile_g", "interpret"))
def set_intersect_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    pad: int,
    tile_g: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """mask[g, i] = a[g, i] ∈ b[g, :] (pad-aware). a: [G, CA] b: [G, CB] int32."""
    g, ca = a.shape
    _, cb = b.shape
    tile_g = min(tile_g, g) if g else 1
    pad_g = (-g) % tile_g
    if pad_g:
        a = jnp.pad(a, ((0, pad_g), (0, 0)), constant_values=pad)
        b = jnp.pad(b, ((0, pad_g), (0, 0)), constant_values=pad)
    gp = a.shape[0]
    out = pl.pallas_call(
        functools.partial(_kernel, pad=pad),
        grid=(gp // tile_g,),
        in_specs=[
            pl.BlockSpec((tile_g, ca), lambda i: (i, 0)),
            pl.BlockSpec((tile_g, cb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_g, ca), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, ca), jnp.int8),
        interpret=interpret,
    )(a, b)
    return out[:g].astype(jnp.bool_)
