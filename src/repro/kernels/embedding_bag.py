"""Embedding-bag gather-reduce kernel (DLRM hot path).

JAX has no native ``EmbeddingBag``; this is the TPU-idiomatic
construction: indices ride in scalar-prefetch SMEM and *drive the
BlockSpec index maps*, so each grid step DMAs exactly one embedding row
``table[idx[i]]`` from HBM into VMEM and accumulates it into the output
row ``out[bag[i]]``. With ``(idx, bag)`` sorted by bag id the output
block is revisited consecutively, so the partial sum stays resident in
VMEM between steps (the FBGEMM table-batched-embedding access pattern,
re-expressed as a Pallas pipeline).

The per-row grid is the canonical formulation; production batching packs
R rows per step by blocking the sorted index list — the ops wrapper
exposes ``rows_per_step`` for that.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag_pallas"]


def _kernel(idx_ref, bag_ref, row_ref, o_ref):
    i = pl.program_id(0)
    is_first = jnp.where(i == 0, True, bag_ref[jnp.maximum(i - 1, 0)] != bag_ref[i])

    @pl.when(is_first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += row_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_bags", "interpret"))
def embedding_bag_pallas(
    table: jax.Array,
    indices: jax.Array,
    bag_ids: jax.Array,
    num_bags: int,
    *,
    interpret: bool = False,
) -> jax.Array:
    """out[b] = Σ_{i: bag_ids[i] = b} table[indices[i]]  (sum mode).

    ``indices``/``bag_ids`` must be sorted by ``bag_ids`` (ops wrapper
    sorts). table: [V, D]; indices, bag_ids: [B] int32. → [num_bags, D].
    """
    v, d = table.shape
    b = indices.shape[0]
    idx = indices.astype(jnp.int32)
    bag = bag_ids.astype(jnp.int32)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, idx_ref, bag_ref: (idx_ref[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, idx_ref, bag_ref: (bag_ref[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_bags, d), table.dtype),
        interpret=interpret,
    )(idx, bag, table)
    return out
