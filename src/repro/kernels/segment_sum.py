"""Sorted segment-sum kernel — the GNN message-passing reduction.

``out[s, :] = Σ_{i : seg[i] = s} data[i, :]`` with ``seg`` sorted
ascending. TPU adaptation: scatter-add has no efficient TPU analogue, so
the reduction becomes a *one-hot matmul* per (segment-tile × edge-tile)
pair — ``onehotᵀ @ data`` runs on the MXU. Two structural optimizations:

1. grid steps on TPU are sequential, so the output tile accumulates
   safely across the edge dimension (init at first edge tile);
2. per-edge-tile ``[min_seg, max_seg]`` ranges ride in scalar-prefetch
   SMEM; ``@pl.when`` skips compute for non-intersecting pairs — with
   sorted ids each edge tile touches O(1) segment tiles, so the effective
   work is linear despite the rectangular grid.

Used by: all four assigned GNN architectures and the DLRM embedding
reduction path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["segment_sum_pallas"]


def _kernel(mins_ref, maxs_ref, seg_ref, data_ref, o_ref):
    i = pl.program_id(0)  # segment tile
    j = pl.program_id(1)  # edge tile
    tn = o_ref.shape[0]
    seg_lo = i * tn
    seg_hi = seg_lo + tn - 1

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when((maxs_ref[j] >= seg_lo) & (mins_ref[j] <= seg_hi))
    def _accum():
        seg = seg_ref[...]  # [TE]
        data = data_ref[...]  # [TE, D]
        local = seg - seg_lo
        ids = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], tn), 1)
        onehot = (local[:, None] == ids).astype(data.dtype)  # [TE, TN]
        o_ref[...] += jnp.dot(onehot.T, data, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_segments", "tile_n", "tile_e", "interpret"))
def segment_sum_pallas(
    data: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    tile_n: int = 256,
    tile_e: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """data: [E, D] float; segment_ids: [E] int32 sorted. → [num_segments, D].

    Entries with ``segment_ids >= num_segments`` (padding convention) are
    dropped.
    """
    e, d = data.shape
    tile_e = min(tile_e, max(e, 1))
    tile_n = min(tile_n, max(num_segments, 1))
    ep = (-e) % tile_e
    np_ = (-num_segments) % tile_n
    n_padded = num_segments + np_
    seg = jnp.pad(segment_ids.astype(jnp.int32), (0, ep), constant_values=jnp.int32(2**31 - 1))
    dat = jnp.pad(data, ((0, ep), (0, 0)))
    ne = seg.shape[0] // tile_e
    nn = n_padded // tile_n
    mins = jnp.min(seg.reshape(ne, tile_e), axis=1)
    maxs = jnp.max(jnp.where(seg.reshape(ne, tile_e) == 2**31 - 1, -1, seg.reshape(ne, tile_e)), axis=1)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nn, ne),
            in_specs=[
                pl.BlockSpec((tile_e,), lambda i, j, mins, maxs: (j,)),
                pl.BlockSpec((tile_e, d), lambda i, j, mins, maxs: (j, 0)),
            ],
            out_specs=pl.BlockSpec((tile_n, d), lambda i, j, mins, maxs: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_padded, d), data.dtype),
        interpret=interpret,
    )(mins, maxs, seg, dat)
    return out[:num_segments]
