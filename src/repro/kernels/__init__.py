"""Pallas TPU kernels for the perf-critical compute layers.

Kernels (each validated against ``ref.py`` in interpret mode):
- ``set_intersect``   — padded-set intersection (DDSL join/list hot spot)
- ``member_probe``    — edge-existence / join-key membership probe
- ``segment_sum``     — sorted segment reduction (GNN message passing)
- ``embedding_bag``   — gather-reduce over embedding tables (DLRM)
- ``flash_attention`` — fused online-softmax attention (LM archs)

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
