"""Sorted-table membership probe — edge-existence and join-key probing.

DDSL's hot predicate is ``code(u, v) ∈ E_j`` (match filtering, Lemma 6.1
checks, CC-join probes). Edge codes are pairs of vertex ids; TPUs are
32-bit-native, so codes travel as two int32 lanes ``(hi, lo)`` =
``(min(u,v), max(u,v))`` and the kernel compares both planes. The grid is
2-D ``(query_tiles, table_tiles)`` with an OR accumulation into the
revisited output block — grid steps on TPU execute sequentially, so
read-modify-write across the table dimension is safe.

This is a *membership* probe (equality-any), deliberately not a binary
search: a VPU compare over a VMEM tile beats divergent search loops on
TPU for the table sizes per partition shard, and it needs no layout
beyond padding. Pad convention: ``(-1, -1)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["member_probe_pallas"]


def _kernel(qhi_ref, qlo_ref, thi_ref, tlo_ref, o_ref):
    j = pl.program_id(1)
    qhi = qhi_ref[...]
    qlo = qlo_ref[...]
    thi = thi_ref[...]
    tlo = tlo_ref[...]
    valid_t = ~((thi == -1) & (tlo == -1))
    hit = (
        (qhi[:, :, None] == thi[:, None, :])
        & (qlo[:, :, None] == tlo[:, None, :])
        & valid_t[:, None, :]
    )
    acc = jnp.any(hit, axis=-1).astype(jnp.int8)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(j > 0)
    def _accum():
        o_ref[...] = jnp.maximum(o_ref[...], acc)


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_t", "interpret"))
def member_probe_pallas(
    q_hi: jax.Array,
    q_lo: jax.Array,
    t_hi: jax.Array,
    t_lo: jax.Array,
    *,
    tile_q: int = 1024,
    tile_t: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """out[i] = (q_hi[i], q_lo[i]) ∈ zip(t_hi, t_lo). int32 lanes, bool out."""
    n = q_hi.shape[0]
    m = t_hi.shape[0]
    tile_q = min(tile_q, max(n, 1))
    tile_t = min(tile_t, max(m, 1))
    qp = (-n) % tile_q
    tp = (-m) % tile_t
    qhi = jnp.pad(q_hi.astype(jnp.int32), (0, qp), constant_values=-1).reshape(1, -1)
    qlo = jnp.pad(q_lo.astype(jnp.int32), (0, qp), constant_values=-1).reshape(1, -1)
    thi = jnp.pad(t_hi.astype(jnp.int32), (0, tp), constant_values=-1).reshape(1, -1)
    tlo = jnp.pad(t_lo.astype(jnp.int32), (0, tp), constant_values=-1).reshape(1, -1)
    nq = qhi.shape[1] // tile_q
    nt = thi.shape[1] // tile_t
    out = pl.pallas_call(
        _kernel,
        grid=(nq, nt),
        in_specs=[
            pl.BlockSpec((1, tile_q), lambda i, j: (0, i)),
            pl.BlockSpec((1, tile_q), lambda i, j: (0, i)),
            pl.BlockSpec((1, tile_t), lambda i, j: (0, j)),
            pl.BlockSpec((1, tile_t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, tile_q), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, qhi.shape[1]), jnp.int8),
        interpret=interpret,
    )(qhi, qlo, thi, tlo)
    valid_q = ~((q_hi == -1) & (q_lo == -1))
    return out.reshape(-1)[:n].astype(jnp.bool_) & valid_q
