"""FlashAttention-style fused attention kernel (GQA/MLA-ready).

Online-softmax tiling over the KV sequence: grid is
``(batch·q_heads, q_tiles, kv_tiles)`` with the KV dimension innermost
("arbitrary" semantics → sequential), carrying running ``(m, l, acc)``
statistics in VMEM scratch that persists across KV steps. Output is
written once, at the last KV tile. GQA maps query head ``h`` to KV head
``h // group`` inside the BlockSpec index maps, so grouped heads share
KV tiles without materialising the broadcast.

``q_offset`` shifts absolute query positions — the same kernel serves
training (Lq = Lk, offset 0), chunked prefill (Lq < Lk) and decode
(Lq = 1, offset = cache_len - 1).

Causal skipping: KV tiles strictly above the diagonal are skipped via
``@pl.when``, halving compute for long sequences.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _make_kernel(*, scale, causal, q_offset, tq, tk, n_k):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        qi = pl.program_id(1)
        ki = pl.program_id(2)

        @pl.when(ki == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q_lo = qi * tq + q_offset
        k_lo = ki * tk

        def compute():
            q = q_ref[0].astype(jnp.float32)  # [TQ, D]
            k = k_ref[0].astype(jnp.float32)  # [TK, D]
            v = v_ref[0].astype(jnp.float32)  # [TK, D]
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
                kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
                s = jnp.where(kpos <= qpos, s, _NEG_INF)
            m_prev = m_ref[...]  # [TQ, 1]
            m_cur = jnp.max(s, axis=1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
            acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        if causal:
            # Skip KV tiles strictly above the causal diagonal.
            pl.when(k_lo <= q_lo + tq - 1)(compute)
        else:
            compute()

        @pl.when(ki == n_k - 1)
        def _finalize():
            l = l_ref[...]
            safe = jnp.where(l > 0.0, l, 1.0)
            o_ref[...] = (acc_ref[...] / safe).astype(o_ref.dtype)[None]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "tile_q", "tile_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, Hq, Lq, Dh]
    k: jax.Array,  # [B, Hkv, Lk, Dh]
    v: jax.Array,  # [B, Hkv, Lk, Dh]
    *,
    causal: bool = True,
    q_offset: int = 0,
    tile_q: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, dh = q.shape
    _, hkv, lk, _ = k.shape
    group = hq // hkv
    tile_q = min(tile_q, lq)
    tile_k = min(tile_k, lk)
    pq = (-lq) % tile_q
    pk = (-lk) % tile_k
    if pk and not causal:
        raise NotImplementedError("non-causal KV padding is not needed by the models")
    if causal and q_offset + lq > lk:
        raise ValueError("queries would attend past the last real key")
    qq = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kk = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vv = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    lqp, lkp = lq + pq, lk + pk
    qq = qq.reshape(b * hq, lqp, dh)
    kk = kk.reshape(b * hkv, lkp, dh)
    vv = vv.reshape(b * hkv, lkp, dh)
    n_q, n_k = lqp // tile_q, lkp // tile_k

    kernel = _make_kernel(
        scale=1.0 / (dh ** 0.5), causal=causal, q_offset=q_offset,
        tq=tile_q, tk=tile_k, n_k=n_k,
    )
    kwargs = {}
    if hasattr(pltpu, "CompilerParams"):
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, tile_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, tile_k, dh), lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, tile_k, dh), lambda bh, qi, ki: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, lqp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, dh), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qq, kk, vv)
    return out.reshape(b, hq, lqp, dh)[:, :, :lq]
