"""repro.dist — the distributed execution layer.

Modules:
    jax_engine    static-shape JAX executor of the shared listing/join
                  plan IR (padded partitions, unit listing, VCBC tensors,
                  local CC-join) with explicit overflow counters
    sharded       whole join-tree programs under a ``jax.sharding`` mesh
                  (distributed initial listing + incremental update steps)
    collectives   ring all-reduce, bucketed all-to-all, routed exchange
    compression   error-feedback int8 gradient compression + compressed
                  butterfly all-reduce
    straggler     per-host timing monitor + NP-storage rebalancing
    elastic       elastic re-partitioning (m → m') of NP storage
"""

from . import jax_engine, sharded  # noqa: F401
