"""Gradient compression: error-feedback int8 quantization + compressed
butterfly all-reduce.

``ef_compress`` implements the classic error-feedback scheme: the
residual of each quantization step is added back before the next one, so
the *decoded running sum* tracks the true running sum to within one
quantization step — the drift never accumulates.

``butterfly_compressed_all_reduce`` is a recursive-doubling all-reduce
that quantizes the payload to int8 (with a per-tensor fp scale) at every
stage — log₂(n) hops, ~4× wire traffic reduction, few-percent error
that error feedback absorbs in training loops.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["ef_residual_init", "ef_compress", "butterfly_compressed_all_reduce"]


def ef_residual_init(grads) -> dict:
    """Zero residual pytree matching ``grads`` (fp32 accumulators)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(t: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(t)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads, residual):
    """Quantize ``grads + residual`` to int8; return (q, scales, residual').

    Decoding is ``q * scale``. The new residual is the quantization
    error, re-injected on the next call (error feedback).
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, ss, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        t = g.astype(jnp.float32) + r
        q, scale = _quantize(t)
        qs.append(q)
        ss.append(scale)
        rs.append(t - q.astype(jnp.float32) * scale)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, ss),
            jax.tree.unflatten(treedef, rs))


def butterfly_compressed_all_reduce(x: jnp.ndarray, axis_name, n_devices: int) -> jnp.ndarray:
    """Recursive-doubling all-reduce with int8-compressed stages.

    Requires ``n_devices`` to be a power of two. Each stage exchanges an
    int8 payload plus one fp32 scale with the XOR partner and accumulates
    in fp32.
    """
    if n_devices & (n_devices - 1):
        raise ValueError("butterfly all-reduce needs a power-of-two device count")
    acc = x.astype(jnp.float32)
    stage = 1
    while stage < n_devices:
        perm = [(i, i ^ stage) for i in range(n_devices)]
        q, scale = _quantize(acc)
        qr = lax.ppermute(q, axis_name, perm)
        sr = lax.ppermute(scale, axis_name, perm)
        # Accumulate the *quantized* local value, not `acc` itself: both
        # partners then compute the identical sum, so every replica ends
        # the butterfly with the same tensor (a psum must be replicated;
        # per-device error feedback can't fix cross-replica drift).
        acc = q.astype(jnp.float32) * scale + qr.astype(jnp.float32) * sr
        stage <<= 1
    return acc.astype(x.dtype)
