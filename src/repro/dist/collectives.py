"""Hand-rolled SPMD collectives for sparse/ragged exchange.

The DDSL shuffle moves *rows* (matches, routed tokens) to data-dependent
destinations, which XLA's dense collectives don't express directly.
These primitives run inside ``shard_map`` bodies:

- :func:`bucketed_all_to_all` — each device packs its valid rows into
  per-destination buckets of static capacity and exchanges them with a
  single ``all_to_all``. Rows beyond a bucket's capacity are dropped
  *and counted* (never silently).
- :func:`routed_exchange` — bucketed all-to-all plus an inverse: the
  returned ``restore`` closure routes processed rows back to their
  origin device *and original slot* (the MoE dispatch/combine pattern).
- :func:`ring_all_reduce` — reference ring implementation of ``psum``
  built on ``ppermute`` (summation order differs from XLA's, so float
  results agree only to tolerance).
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax.numpy as jnp
from jax import lax

__all__ = ["bucketed_all_to_all", "routed_exchange", "ring_all_reduce"]

_I32 = jnp.int32


def _bucketize(targets: jnp.ndarray, valid: jnp.ndarray, n: int, cap: int):
    """Per-destination slot assignment for each local row.

    Returns ``(dest, slot, ok, dropped)``: row i goes to bucket
    ``dest[i]`` slot ``slot[i]`` when ``ok[i]``.
    """
    r = targets.shape[0]
    dest = jnp.where(valid, targets.astype(_I32), n)
    order = jnp.argsort(dest, stable=True)
    sdest = dest[order]
    start = jnp.searchsorted(sdest, jnp.arange(n + 1, dtype=_I32))
    slot_sorted = jnp.arange(r, dtype=_I32) - start[jnp.clip(sdest, 0, n)]
    ok_sorted = (sdest < n) & (slot_sorted < cap)
    # scatter back to original row order
    inv = jnp.argsort(order, stable=True)
    slot = slot_sorted[inv]
    ok = ok_sorted[inv]
    dropped = jnp.sum(valid.astype(_I32)) - jnp.sum(ok.astype(_I32))
    return dest, slot, ok, dropped


def _forward_exchange(arrays, targets, valid, axis_name, n: int, cap: int):
    """Shared dispatch: bucketize rows and run the wire exchange.

    Returns ``(received, rvalid, overflow, (dg, sg, ok))`` — the last
    element is the bucket assignment needed to invert the route.
    """
    dest, slot, ok, dropped = _bucketize(targets, valid, n, cap)
    dg = jnp.where(ok, dest, n)
    sg = jnp.where(ok, slot, 0)
    received = []
    for a in arrays:
        buck = jnp.zeros((n + 1, cap) + a.shape[1:], a.dtype).at[dg, sg].set(a)[:n]
        out = lax.all_to_all(buck, axis_name, 0, 0, tiled=False)
        received.append(out.reshape((n * cap,) + a.shape[1:]))
    bval = jnp.zeros((n + 1, cap), bool).at[dg, sg].set(ok)[:n]
    rvalid = lax.all_to_all(bval, axis_name, 0, 0, tiled=False).reshape(n * cap)
    overflow = lax.psum(dropped, axis_name)
    return received, rvalid, overflow, (dg, sg, ok)


def bucketed_all_to_all(
    arrays: Sequence[jnp.ndarray],
    targets: jnp.ndarray,
    valid: jnp.ndarray,
    axis_name,
    n_devices: int,
    capacity: int,
):
    """Exchange rows to per-row target devices (inside ``shard_map``).

    ``arrays``: aligned per-row arrays ``[R, ...]``; ``targets``/``valid``:
    ``[R]``. Returns ``(received_arrays [n*capacity, ...], received_valid
    [n*capacity], overflow)`` where overflow is the global dropped-row
    count (psum'd — identical on every device).
    """
    received, rvalid, overflow, _ = _forward_exchange(
        arrays, targets, valid, axis_name, n_devices, capacity)
    return received, rvalid, overflow


def routed_exchange(
    arrays: Sequence[jnp.ndarray],
    targets: jnp.ndarray,
    valid: jnp.ndarray,
    axis_name,
    n_devices: int,
    capacity: int,
) -> Tuple[List[jnp.ndarray], jnp.ndarray, Callable, jnp.ndarray]:
    """Bucketed all-to-all with an inverse route (dispatch/combine).

    Returns ``(received_arrays, received_valid, restore, overflow)``.
    ``restore(processed)`` takes rows aligned with the received layout
    ``[n*capacity, ...]`` and returns them to the *sending* device in the
    original ``[R, ...]`` row order (dropped rows come back as zeros).
    """
    n, cap = n_devices, capacity
    r = targets.shape[0]
    received, rvalid, overflow, (dg, sg, ok) = _forward_exchange(
        arrays, targets, valid, axis_name, n, cap)

    def restore(processed: jnp.ndarray) -> jnp.ndarray:
        """Send processed rows back and scatter into original slots."""
        y = processed.reshape((n, cap) + processed.shape[1:])
        z = lax.all_to_all(y, axis_name, 0, 0, tiled=False)
        # z[d, c] is the processed version of the row this device put in
        # bucket (d, c) on the way out.
        rows = jnp.where(ok, jnp.arange(r, dtype=_I32), r)
        gathered = z[jnp.clip(dg, 0, n - 1), sg]
        out = jnp.zeros((r + 1,) + processed.shape[1:], processed.dtype)
        out = out.at[rows].set(gathered)
        return out[:r]

    return received, rvalid, restore, overflow


def ring_all_reduce(x: jnp.ndarray, axis_name, n_devices: int) -> jnp.ndarray:
    """Ring implementation of ``psum`` via ``ppermute`` (n-1 hops)."""
    perm = [(i, (i + 1) % n_devices) for i in range(n_devices)]
    acc = x
    cur = x
    for _ in range(n_devices - 1):
        cur = lax.ppermute(cur, axis_name, perm)
        acc = acc + cur
    return acc
