"""Static-shape JAX executor of the shared listing/join plan IR.

This is the device half of the plan/executor split: the *plans*
(:class:`repro.core.plan.UnitPlan` / :class:`repro.core.plan.JoinPlan`)
are compiled once from pattern structure and executed either by the
NumPy host engine (:mod:`repro.core.match_engine`, ragged arrays) or by
this module on padded, statically-shaped tensors that jit/shard_map
cleanly onto a device mesh.

Design rules:

- Every array has a compile-time shape drawn from :class:`EngineCaps`;
  invalid slots hold :data:`PAD` (= -1) and carry explicit validity
  masks.
- Capacity can be exceeded at runtime (a partition listing more matches
  than ``match_cap``, a join producing more groups than ``group_cap``).
  Overflow is **never silent**: every compaction returns a dropped-row
  counter and all public entry points surface the sum. A zero counter is
  a proof that the padded result is exact.
- Results are bit-compatible with the host engine: converting a
  :class:`CompTensors` back with :func:`comp_to_host` and decompressing
  yields the identical match set (tested pattern-by-pattern).

``EngineCaps`` sizing: use the §IV-D match-size estimator
(``repro.core.estimator.match_size_estimate``) for ``match_cap`` /
``group_cap`` and degree statistics for ``deg_cap`` — see
``configs/ddsl_paper.py`` for the paper-scale example.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import decode_edges
from repro.core.pattern import Pattern
from repro.core.plan import (
    LT, NEQ, JoinPlan, UnitPlan, WcojPlan, build_unit_plan, build_wcoj_plan,
)
from repro.core.storage import Partition
from repro.core.vcbc import CompressedTable, Ragged

__all__ = [
    "PAD",
    "EngineCaps",
    "PaddedPartition",
    "pad_partition",
    "build_unit_plan",
    "build_wcoj_plan",
    "UnitPlan",
    "WcojPlan",
    "JoinPlan",
    "unit_list",
    "wcoj_list",
    "require_edges_mask",
    "compress_plain",
    "group_rows",
    "scatter_grouped_values",
    "CompTensors",
    "comp_to_host",
    "ccjoin_local",
    "dedup_rows",
    "lookup_sorted",
    "edge_probe",
    "center_adj_contrib",
    "apply_edge_delta_rows",
    "patch_partition",
    "deleted_edge_cols",
    "filter_deleted_dev",
    "merge_groups",
    "merge_tables_dev",
    "count_matches_dev",
]

PAD = -1
_BIG = np.int32(np.iinfo(np.int32).max)
_I32 = jnp.int32
# Group-axis chunk of the k ≥ 4 count contraction (bounds the
# O(chunk·S^(k-1)) einsum intermediate; the group counts are independent
# so any chunk size is exact).
_COUNT_CHUNK = 64


@dataclasses.dataclass(frozen=True)
class EngineCaps:
    """Static shape model of the device engine.

    v_cap      max vertices per partition
    deg_cap    max adjacency-row length
    e_cap      max edges per partition
    match_cap  max rows of a plain (uncompressed) match table
    group_cap  max skeleton groups of a compressed table
    set_cap    max values per compressed-vertex set
    pair_cap   max side-2 partners per side-1 group in a CC-join
    use_pallas route the engine's membership probes through the Pallas
               kernels (``repro.kernels``): compressed-set intersection
               in :func:`ccjoin_local` and edge-existence probes in
               :func:`unit_list`. Compiled on TPU, interpret-mode
               fallback elsewhere (so parity tests run everywhere);
               results are bit-identical either way. ``None`` (the
               default) resolves to the platform default from the
               kernel autotune table (on where compiled Pallas pays
               off, i.e. TPU; off where only interpret mode exists).
    """

    v_cap: int
    deg_cap: int
    e_cap: int
    match_cap: int
    group_cap: int
    set_cap: int
    pair_cap: int
    use_pallas: Optional[bool] = None

    def __post_init__(self):
        if self.use_pallas is None:
            from repro.kernels.autotune import default_use_pallas

            # frozen dataclass: resolve the platform default in place so
            # downstream tracing only ever sees a concrete bool.
            object.__setattr__(self, "use_pallas", default_use_pallas())


def _register(cls, fields):
    jax.tree_util.register_pytree_node(
        cls,
        lambda x: (tuple(getattr(x, f) for f in fields), None),
        lambda _, ch: cls(**dict(zip(fields, ch))),
    )
    return cls


@dataclasses.dataclass
class PaddedPartition:
    """One NP partition as padded tensors (all ``int32``/``bool``).

    ``vertices`` is ascending with ``PAD`` tail; ``adj`` rows are
    ascending global neighbor ids with ``PAD`` tail. ``edge_hi``/
    ``edge_lo`` are named for the *word of the host's int64 edge code*
    they carry (``code = min << 32 | max``): ``edge_hi`` is the high
    word = **min** endpoint, ``edge_lo`` the low word = **max**
    endpoint — this word-order naming is part of the external contract
    (tests read ``edge_hi`` as the smaller id). Rows are in
    lexicographic (code) order with ``PAD`` tails, the padded analogue
    of the host's sorted edge-code array.
    """

    vertices: jnp.ndarray   # [v_cap]
    center: jnp.ndarray     # [v_cap] bool
    deg: jnp.ndarray        # [v_cap]
    adj: jnp.ndarray        # [v_cap, deg_cap]
    edge_hi: jnp.ndarray    # [e_cap] (min endpoint)
    edge_lo: jnp.ndarray    # [e_cap] (max endpoint)


_register(PaddedPartition, ("vertices", "center", "deg", "adj", "edge_hi", "edge_lo"))


@dataclasses.dataclass
class CompTensors:
    """A VCBC compressed table as padded tensors.

    ``skeleton`` is ``[group_cap, n_skel_cols]`` (column labels travel
    out-of-band as the plan's ``skel_cols``), ``valid`` marks live
    groups, and ``sets`` maps each compressed vertex *label* to its
    ``[group_cap, set_cap]`` per-group value sets (``PAD`` tail, valid
    prefix ascending).
    """

    skeleton: jnp.ndarray
    valid: jnp.ndarray
    sets: Dict[int, jnp.ndarray]


_register(CompTensors, ("skeleton", "valid", "sets"))


# ---------------------------------------------------------------------------
# Padding host partitions
# ---------------------------------------------------------------------------

def pad_partition(part: Partition, caps: EngineCaps) -> PaddedPartition:
    """Pad one host :class:`Partition` to the static shape model.

    Storage caps (``v_cap``/``deg_cap``/``e_cap``) must hold the
    partition — shapes are compile-time, so a misfit here is a sizing
    error and raises instead of truncating.
    """
    nv = int(part.vertices.shape[0])
    ne = int(part.codes.shape[0])
    deg = np.diff(part.indptr).astype(np.int64)
    if nv > caps.v_cap:
        raise ValueError(f"partition has {nv} vertices > v_cap={caps.v_cap}")
    if ne > caps.e_cap:
        raise ValueError(f"partition has {ne} edges > e_cap={caps.e_cap}")
    if nv and int(deg.max(initial=0)) > caps.deg_cap:
        raise ValueError(f"max degree {int(deg.max())} > deg_cap={caps.deg_cap}")

    vertices = np.full(caps.v_cap, PAD, np.int32)
    center = np.zeros(caps.v_cap, bool)
    degs = np.zeros(caps.v_cap, np.int32)
    adj = np.full((caps.v_cap, caps.deg_cap), PAD, np.int32)
    vertices[:nv] = part.vertices
    center[:nv] = part.center_mask
    degs[:nv] = deg
    for r in range(nv):
        row = part.indices[part.indptr[r] : part.indptr[r + 1]]
        adj[r, : row.shape[0]] = row

    edge_hi = np.full(caps.e_cap, PAD, np.int32)
    edge_lo = np.full(caps.e_cap, PAD, np.int32)
    und = decode_edges(part.codes)  # sorted by code == lexicographic (lo, hi)
    edge_hi[:ne] = und[:, 0]
    edge_lo[:ne] = und[:, 1]
    return PaddedPartition(
        vertices=jnp.asarray(vertices), center=jnp.asarray(center),
        deg=jnp.asarray(degs), adj=jnp.asarray(adj),
        edge_hi=jnp.asarray(edge_hi), edge_lo=jnp.asarray(edge_lo),
    )


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def _row_of(pt: PaddedPartition, q: jnp.ndarray) -> jnp.ndarray:
    """Local row index of global vertex ids (callers mask misses)."""
    vs = jnp.where(pt.vertices < 0, _BIG, pt.vertices)
    r = jnp.searchsorted(vs, q.astype(_I32))
    return jnp.clip(r, 0, pt.vertices.shape[0] - 1)


def _lower_bound_pairs(qa: jnp.ndarray, qb: jnp.ndarray,
                       ea: jnp.ndarray, eb: jnp.ndarray) -> jnp.ndarray:
    """Insertion index of ``(qa, qb)`` pairs in a table sorted
    lexicographically ascending (``_BIG`` pads at the tail) — i.e. the
    count of table entries strictly below each query."""
    n = ea.shape[0]
    lo = jnp.zeros(qa.shape, _I32)
    hi = jnp.full(qa.shape, n, _I32)
    steps = max(1, int(math.ceil(math.log2(max(n, 2)))) + 1)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        midc = jnp.clip(mid, 0, n - 1)
        ma, mb = ea[midc], eb[midc]
        less = (ma < qa) | ((ma == qa) & (mb < qb))
        return jnp.where(less, mid + 1, lo), jnp.where(less, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def _search_sorted_pairs(qa: jnp.ndarray, qb: jnp.ndarray,
                         ea: jnp.ndarray, eb: jnp.ndarray) -> jnp.ndarray:
    """Binary-search membership of ``(qa, qb)`` pairs in a table sorted
    lexicographically ascending (``_BIG`` pads at the tail)."""
    idx = jnp.clip(_lower_bound_pairs(qa, qb, ea, eb), 0, ea.shape[0] - 1)
    return (ea[idx] == qa) & (eb[idx] == qb)


def _has_edge(pt: PaddedPartition, u: jnp.ndarray, v: jnp.ndarray,
              use_pallas: bool = False) -> jnp.ndarray:
    """Vectorized edge membership: lexicographic binary search, or the
    Pallas tiled member-probe kernel when ``use_pallas`` is set."""
    qa = jnp.minimum(u, v).astype(_I32)
    qb = jnp.maximum(u, v).astype(_I32)
    if use_pallas:
        from repro.kernels.ops import member_probe

        hit = member_probe(qa.reshape(-1), qb.reshape(-1), pt.edge_hi, pt.edge_lo)
        return hit.reshape(qa.shape)
    ea = jnp.where(pt.edge_hi < 0, _BIG, pt.edge_hi)
    eb = jnp.where(pt.edge_lo < 0, _BIG, pt.edge_lo)
    return _search_sorted_pairs(qa, qb, ea, eb)


def _compact_index(ok: jnp.ndarray, cap: int):
    """Stable first-``cap`` packing of the ``ok`` entries.

    Returns ``(dest, valid, dropped)`` where ``dest`` maps each entry to
    its packed slot (``cap`` = dump slot for masked/overflowing entries)
    — the one compaction primitive behind every row/vector/group pack.
    """
    oki = ok.astype(_I32)
    idx = jnp.cumsum(oki) - 1
    total = jnp.sum(oki)
    dest = jnp.where(ok & (idx < cap), idx, cap)
    valid = jnp.arange(cap) < jnp.minimum(total, cap)
    dropped = jnp.maximum(total - cap, 0)
    return dest, valid, dropped


def _take_index(ok: jnp.ndarray, cap: int):
    """Gather-side twin of :func:`_compact_index`: source indices of the
    first ``cap`` ``ok`` entries.

    Returns ``(src, valid, dropped)`` where ``src[p]`` is the index of
    the ``(p+1)``-th ``ok`` entry (clipped in range — mask with
    ``valid``). Packing via gather (cumsum + ``searchsorted`` on the
    nondecreasing prefix counts) instead of an N-slot scatter matters:
    XLA lowers scatters with serialized update semantics, so packing the
    ``match_cap·deg_cap`` frontier expansion through ``.at[dest].set``
    dominated the whole maintain step on CPU/GPU. The gather form is
    bit-identical (stable, first-``cap`` semantics).
    """
    n = ok.shape[0]
    c = jnp.cumsum(ok.astype(_I32))
    total = c[n - 1]
    src = jnp.searchsorted(c, jnp.arange(1, cap + 1, dtype=_I32))
    valid = jnp.arange(cap) < jnp.minimum(total, cap)
    dropped = jnp.maximum(total - cap, 0)
    return jnp.clip(src, 0, n - 1), valid, dropped


def _compact_rows(rows: jnp.ndarray, ok: jnp.ndarray, cap: int):
    """Keep the first ``cap`` ``ok`` rows; report the dropped count.

    rows: [N, C]; ok: [N] → ([cap, C] PAD-filled, [cap] valid, dropped).
    """
    if rows.shape[0] == 0:
        return (jnp.full((cap, rows.shape[1]), PAD, _I32),
                jnp.zeros((cap,), bool), jnp.int32(0))
    src, valid, dropped = _take_index(ok, cap)
    out = jnp.where(valid[:, None], rows[src].astype(_I32), PAD)
    return out, valid, dropped


def _compact_vec(vals: jnp.ndarray, ok: jnp.ndarray, cap: int, fill=0):
    """1-D variant of :func:`_compact_rows`."""
    if vals.shape[0] == 0:
        return (jnp.full((cap,), fill, vals.dtype),
                jnp.zeros((cap,), bool), jnp.int32(0))
    src, valid, dropped = _take_index(ok, cap)
    out = jnp.where(valid, vals[src], jnp.asarray(fill, vals.dtype))
    return out, valid, dropped


# ---------------------------------------------------------------------------
# Unit listing (plan executor)
# ---------------------------------------------------------------------------

def unit_list(
    pt: PaddedPartition,
    plan: UnitPlan,
    caps: EngineCaps,
    require_edges: jnp.ndarray | None = None,
):
    """Anchored frontier-table listing of one R1 unit (``M_ac``).

    Returns ``(table [match_cap, |V|], valid [match_cap], overflow)``
    with table columns aligned to ``plan.cols`` (the extension order).
    ``require_edges`` (``[k, 2]`` int32) restricts to matches mapping at
    least one pattern edge into the given edge set (Nav-join seeds).
    """
    # --- seed the anchor column ---------------------------------------------
    seed_ok = pt.center & (pt.vertices >= 0) & (pt.deg >= plan.anchor_min_degree)
    tbl, valid, ovf = _compact_rows(pt.vertices[:, None], seed_ok, caps.match_cap)

    # --- extend vertex by vertex --------------------------------------------
    for step in plan.steps:
        rows = _row_of(pt, tbl[:, step.pivot])
        cand = pt.adj[rows]                                   # [R, D]
        ok = valid[:, None] & (cand >= 0)
        crows = _row_of(pt, cand)
        ok &= pt.deg[crows] >= step.min_degree                # MC₁ degree prune
        for j in range(tbl.shape[1]):                         # injectivity
            ok &= cand != tbl[:, j][:, None]
        for j in step.edge_checks:                            # extra edges
            ok &= _has_edge(pt, cand, jnp.broadcast_to(tbl[:, j][:, None], cand.shape),
                            use_pallas=caps.use_pallas)
        for j, greater in step.ord_checks:                    # SimB order
            cu = tbl[:, j][:, None]
            ok &= (cand > cu) if greater else (cand < cu)
        wide = jnp.concatenate(
            [jnp.repeat(tbl[:, None, :], cand.shape[1], axis=1), cand[:, :, None]], axis=2
        ).reshape(-1, tbl.shape[1] + 1)
        tbl, valid, o = _compact_rows(wide, ok.reshape(-1), caps.match_cap)
        ovf = ovf + o

    # --- inserted-edge requirement (Nav-join step 2) ------------------------
    if require_edges is not None:
        valid = valid & require_edges_mask(tbl, plan.edge_cols, require_edges)
    return tbl, valid, ovf


def wcoj_list(
    pt: PaddedPartition,
    plan: WcojPlan,
    caps: EngineCaps,
    level_caps: Sequence[int],
    require_edges: jnp.ndarray | None = None,
    seed_mask: jnp.ndarray | None = None,
):
    """Anchored generic-join listing of a whole pattern (WCOJ executor).

    A padded, static-shape scan over extension levels (unrolled so each
    level owns its shape): every level gathers the pivot's adjacency,
    intersects it against the adjacency of the other placed neighbors
    (the same edge-membership probes as :func:`unit_list`, Pallas-routed
    behind ``caps.use_pallas``), and packs survivors to that level's
    candidate cap. ``level_caps`` has one entry per placed prefix length
    (``level_caps[0]`` caps the seed), sized from the §IV-D per-prefix
    estimates — so each level's table is bounded by *its own* (AGM-style)
    prefix estimate instead of :func:`unit_list`'s single uniform
    ``match_cap``. On cliques the prefix estimates shrink level over
    level, which is exactly where the generic join wins: the tree
    executor pays the max-frontier width on every step.

    Returns ``(table [level_caps[-1], |V|], valid, overflow)`` with
    columns aligned to ``plan.cols``; ``require_edges`` restricts to
    matches mapping ≥1 pattern edge into the given edge set (the
    delta-dataflow seed restriction for incremental maintenance), and
    ``seed_mask`` (``[v_cap]`` bool) further restricts the anchor seeds —
    the incremental path passes the delta-candidate vertex set here so a
    batch only re-explores the neighborhood the delta can touch.
    """
    k = len(plan.order)
    level_caps = tuple(int(c) for c in level_caps)
    if len(level_caps) != k:
        raise ValueError(f"need {k} level caps (incl. seed), got {len(level_caps)}")

    # --- seed the anchor column (level 0) -----------------------------------
    seed_ok = pt.center & (pt.vertices >= 0) & (pt.deg >= plan.anchor_min_degree)
    if seed_mask is not None:
        seed_ok = seed_ok & seed_mask
    tbl, valid, ovf = _compact_rows(pt.vertices[:, None], seed_ok, level_caps[0])

    # --- extend level by level ----------------------------------------------
    for i, lv in enumerate(plan.levels, start=1):
        rows = _row_of(pt, tbl[:, lv.pivot])
        cand = pt.adj[rows]                                   # [W_{i-1}, D]
        ok = valid[:, None] & (cand >= 0)
        crows = _row_of(pt, cand)
        ok &= pt.deg[crows] >= lv.min_degree                  # MC₁ degree prune
        for j in range(tbl.shape[1]):                         # injectivity
            ok &= cand != tbl[:, j][:, None]
        for j in lv.intersect_cols:                           # adjacency intersection
            ok &= _has_edge(pt, cand, jnp.broadcast_to(tbl[:, j][:, None], cand.shape),
                            use_pallas=caps.use_pallas)
        for j, greater in lv.ord_checks:                      # SimB order
            cu = tbl[:, j][:, None]
            ok &= (cand > cu) if greater else (cand < cu)
        wide = jnp.concatenate(
            [jnp.repeat(tbl[:, None, :], cand.shape[1], axis=1), cand[:, :, None]], axis=2
        ).reshape(-1, tbl.shape[1] + 1)
        tbl, valid, o = _compact_rows(wide, ok.reshape(-1), level_caps[i])
        ovf = ovf + o

    # --- inserted-edge requirement (delta-dataflow seeds) -------------------
    if require_edges is not None:
        valid = valid & require_edges_mask(tbl, plan.edge_cols, require_edges)
    return tbl, valid, ovf


def require_edges_mask(
    tbl: jnp.ndarray,
    edge_cols: Sequence[tuple],
    require_edges: jnp.ndarray,
) -> jnp.ndarray:
    """Rows of a plain match table mapping ≥1 pattern edge into a small
    replicated edge set (the Nav-join seed restriction, §VI-B step 2).

    Factored out of :func:`unit_list` so a cached full unit table can be
    re-seeded per batch with the same filter the listing itself would
    have applied — bit-identical either way.
    """
    ra = jnp.minimum(require_edges[:, 0], require_edges[:, 1]).astype(_I32)
    rb = jnp.maximum(require_edges[:, 0], require_edges[:, 1]).astype(_I32)
    hit = jnp.zeros(tbl.shape[0], bool)
    for ia, ib in edge_cols:
        lo = jnp.minimum(tbl[:, ia], tbl[:, ib])
        hi = jnp.maximum(tbl[:, ia], tbl[:, ib])
        hit |= jnp.any((lo[:, None] == ra[None, :]) & (hi[:, None] == rb[None, :]), axis=1)
    return hit


# ---------------------------------------------------------------------------
# Compression (plain table → CompTensors)
# ---------------------------------------------------------------------------

def _lex_order(keys: jnp.ndarray) -> jnp.ndarray:
    """Row order sorting ``keys [N, C]`` lexicographically (col 0 primary)."""
    return jnp.lexsort(tuple(keys[:, j] for j in reversed(range(keys.shape[1]))))


def group_rows(rows: jnp.ndarray, ok: jnp.ndarray, n_groups: int):
    """Assign group ids to the distinct valid rows of ``rows [N, S]``.

    Sorts lexicographically (invalid rows pushed past ``_BIG``), scatters
    one representative per distinct row, and returns
    ``(skeleton [n_groups, S], gvalid, order, g_eff, dropped_groups)``
    where ``order`` is the sort permutation and ``g_eff [N]`` maps each
    *sorted* row to its group (dump index ``n_groups`` for invalid or
    overflowing rows). Shared by plain-table compression and the
    cross-chain patch merge.
    """
    G, S = n_groups, rows.shape[1]
    keys = jnp.where(ok[:, None], rows, _BIG)
    if S:
        order = _lex_order(keys)
    else:
        order = jnp.argsort(~ok)
    ks = keys[order]
    vs_ = ok[order]
    if S:
        prev = jnp.concatenate([jnp.full((1, S), -2, _I32), ks[:-1]], axis=0)
        newg = jnp.any(ks != prev, axis=1) & vs_
    else:
        newg = jnp.concatenate([jnp.ones(1, bool), jnp.zeros(ks.shape[0] - 1, bool)]) & vs_
    gid = jnp.cumsum(newg.astype(_I32)) - 1
    # Representatives = the first G group-leader rows, packed by gather
    # (see _take_index) — identical to the old per-leader scatter.
    skeleton, gvalid, dropped = _compact_rows(ks, newg, G)
    g_eff = jnp.where(vs_ & (gid < G), gid, G)
    return skeleton, gvalid, order, g_eff, dropped


def scatter_grouped_values(g: jnp.ndarray, vals: jnp.ndarray, n_groups: int,
                           set_cap: int):
    """Dedup a ``(group, value)`` stream and pack per-group sorted sets.

    ``g`` uses ``n_groups`` as the dump index for invalid entries.
    Returns ``([n_groups, set_cap]`` PAD-tailed ascending sets,
    dropped-unique-value count)`` — the one packing primitive behind
    both plain-table compression and cross-chain set merging.
    """
    n = g.shape[0]
    o2 = jnp.lexsort((vals, g))
    g2, v2 = g[o2], vals[o2]
    pv = g2 < n_groups
    prevg = jnp.concatenate([jnp.full((1,), -2, _I32), g2[:-1]])
    prevv = jnp.concatenate([jnp.full((1,), -2, _I32), v2[:-1]])
    isnew = pv & ((g2 != prevg) | (v2 != prevv))
    cum = jnp.cumsum(isnew.astype(_I32))            # uniques up to & incl. i
    # Gather pack (see _take_index): per-group bases come from each
    # group's first index in the (group, value)-sorted stream, and the
    # (s+1)-th unique value of group ``gi`` sits where ``cum`` first
    # reaches ``base[gi] + s + 1`` — no N-element scatter anywhere.
    start = jnp.searchsorted(g2, jnp.arange(n_groups + 1, dtype=_I32))
    cum0 = cum - isnew.astype(_I32)                 # uniques strictly before i
    base = jnp.where(start >= n, cum[-1], cum0[jnp.clip(start, 0, n - 1)])
    counts = base[1:] - base[:-1]                   # unique values per group
    dropped = jnp.sum(jnp.maximum(counts - set_cap, 0))
    tgt = base[:-1, None] + jnp.arange(1, set_cap + 1, dtype=_I32)[None, :]
    idx = jnp.searchsorted(cum, tgt.reshape(-1)).reshape(n_groups, set_cap)
    ok = jnp.arange(set_cap)[None, :] < jnp.minimum(counts, set_cap)[:, None]
    out = jnp.where(ok, v2[jnp.clip(idx, 0, n - 1)], PAD)
    return out, dropped


def compress_plain(
    tbl: jnp.ndarray,
    valid: jnp.ndarray,
    cols: Sequence[int],
    cover: Sequence[int],
    caps: EngineCaps,
):
    """Group a plain match table by its skeleton columns (§IV-A).

    Returns ``(CompTensors, skel_cols, overflow)``; ``skel_cols`` is the
    sorted tuple of cover labels present in ``cols``.
    """
    cols = tuple(int(c) for c in cols)
    cover_set = {int(c) for c in cover}
    skel_labels = tuple(c for c in sorted(cols) if c in cover_set)
    comp_labels = tuple(c for c in sorted(cols) if c not in cover_set)
    skel_idx = [cols.index(c) for c in skel_labels]
    G, S = caps.group_cap, len(skel_labels)

    skel = tbl[:, skel_idx] if S else tbl[:, :0]
    skeleton, gvalid, order, g_eff, ovf = group_rows(skel, valid, G)

    sets: Dict[int, jnp.ndarray] = {}
    for c in comp_labels:
        vals = tbl[:, cols.index(c)][order]
        sets[c], dropped = scatter_grouped_values(g_eff, vals, G, caps.set_cap)
        ovf = ovf + dropped
    return CompTensors(skeleton=skeleton, valid=gvalid, sets=sets), skel_labels, ovf


def comp_to_host(
    tc: CompTensors,
    pattern: Pattern,
    cover: Sequence[int],
    skel_cols: Sequence[int],
) -> CompressedTable:
    """Convert padded VCBC tensors back into a host :class:`CompressedTable`."""
    skel = np.asarray(tc.skeleton, np.int64)
    valid = np.asarray(tc.valid, bool)
    keep = np.nonzero(valid)[0]
    rows = skel[keep]
    comp: Dict[int, Ragged] = {}
    for v in sorted(int(k) for k in tc.sets):
        a = np.asarray(tc.sets[v], np.int64)[keep]
        g, s = np.nonzero(a >= 0)
        comp[int(v)] = Ragged.from_group_ids(
            g.astype(np.int64), a[g, s], rows.shape[0]
        )
    return CompressedTable(
        pattern=pattern,
        cover=tuple(sorted(int(c) for c in cover)),
        skeleton_cols=tuple(int(c) for c in skel_cols),
        skeleton=rows,
        comp=comp,
    )


# ---------------------------------------------------------------------------
# Local CC-join (plan executor)
# ---------------------------------------------------------------------------

def _filter_set_rows(vals: jnp.ndarray, ok: jnp.ndarray, set_cap: int):
    """Re-pack each row's surviving values into a valid prefix.

    Row-wise gather pack (per-row cumsum + ``searchsorted``) — see
    :func:`_take_index` for why gathers beat the 2-D scatter here.
    """
    c = jnp.cumsum(ok.astype(_I32), axis=1)              # [N, C] nondecreasing
    counts = c[:, -1]
    tgt = jnp.arange(1, set_cap + 1, dtype=_I32)
    sel = jax.vmap(lambda row: jnp.searchsorted(row, tgt))(c)
    valid = tgt[None, :] <= jnp.minimum(counts, set_cap)[:, None]
    src = jnp.clip(sel, 0, vals.shape[1] - 1)
    out = jnp.where(valid, jnp.take_along_axis(vals.astype(_I32), src, axis=1), PAD)
    return out, counts


def ccjoin_local(
    tA: CompTensors,
    tB: CompTensors,
    plan: JoinPlan,
    caps: EngineCaps,
):
    """Execute one CC-join plan on co-located compressed tensors.

    Returns ``(CompTensors, overflow)``. Overflow counts both pair slots
    beyond ``pair_cap`` and output groups beyond ``group_cap``.
    """
    GA, GB = tA.skeleton.shape[0], tB.skeleton.shape[0]
    eq = tA.valid[:, None] & tB.valid[None, :]
    for ka, kb in zip(plan.key_left_idx, plan.key_right_idx):
        eq &= tA.skeleton[:, ka][:, None] == tB.skeleton[:, kb][None, :]

    # Pack each group's first pair_cap partners by row-wise gather
    # (cumsum + searchsorted): the old formulation scattered a GA×GB
    # index matrix into [GA, pair_cap+1] slots, which XLA serializes —
    # at engine caps that is a multi-10M-element scatter per join. The
    # gather keeps the identical ascending-gb pair order.
    cnt = jnp.cumsum(eq.astype(_I32), axis=1)                # [GA, GB]
    row_tot = cnt[:, -1]
    ovf = jnp.sum(jnp.maximum(row_tot - caps.pair_cap, 0))
    tgt = jnp.arange(1, caps.pair_cap + 1, dtype=_I32)
    sel = jax.vmap(lambda row: jnp.searchsorted(row, tgt))(cnt)
    pslot = tgt[None, :] <= jnp.minimum(row_tot, caps.pair_cap)[:, None]
    pair_b = jnp.where(pslot, jnp.clip(sel, 0, GB - 1), -1).reshape(-1)
    pvalid = pair_b >= 0                                     # [GA * pair_cap]
    ga = jnp.repeat(jnp.arange(GA, dtype=_I32), caps.pair_cap)
    gb = jnp.clip(pair_b, 0, GB - 1)

    n_out = len(plan.skel_out)
    s3 = jnp.zeros((ga.shape[0], n_out), _I32)
    for out_j, left_j in plan.out_from_left:
        s3 = s3.at[:, out_j].set(tA.skeleton[ga, left_j])
    for out_j, right_j in plan.out_from_right:
        s3 = s3.at[:, out_j].set(tB.skeleton[gb, right_j])
    for ja, jb in plan.pair_neq:
        pvalid &= s3[:, ja] != s3[:, jb]
    for ja, jb in plan.pair_ord:
        pvalid &= s3[:, ja] < s3[:, jb]

    # Compact surviving pairs into group slots, then materialize sets.
    triple = jnp.concatenate([s3, ga[:, None], gb[:, None]], axis=1)
    packed, out_valid, o2 = _compact_rows(triple, pvalid, caps.group_cap)
    ovf = ovf + o2
    out_skel = packed[:, :n_out]
    ga_c = jnp.clip(packed[:, n_out], 0, GA - 1)
    gb_c = jnp.clip(packed[:, n_out + 1], 0, GB - 1)

    sets: Dict[int, jnp.ndarray] = {}
    for cp in plan.comp:
        v = cp.vertex
        if cp.source == "both":
            a = tA.sets[v][ga_c]
            b = tB.sets[v][gb_c]
            if caps.use_pallas:
                from repro.kernels.ops import set_intersect

                ok = set_intersect(a, b, pad=PAD)
            else:
                ok = (a >= 0) & jnp.any(a[:, :, None] == b[:, None, :], axis=2)
            vals = a
        elif cp.source == "left":
            vals = tA.sets[v][ga_c]
            ok = vals >= 0
        else:
            vals = tB.sets[v][gb_c]
            ok = vals >= 0
        for col, mode in cp.checks:
            sv = out_skel[:, col][:, None]
            if mode == NEQ:
                ok &= vals != sv
            elif mode == LT:
                ok &= vals < sv
            else:
                ok &= vals > sv
        packed_vals, counts = _filter_set_rows(vals, ok & out_valid[:, None], caps.set_cap)
        sets[v] = packed_vals
        out_valid = out_valid & (counts > 0)   # host drops empty-set groups

    return CompTensors(skeleton=out_skel, valid=out_valid, sets=sets), ovf


# ---------------------------------------------------------------------------
# Candidate-restricted update primitives (Alg. 4 C1–C3 on device)
# ---------------------------------------------------------------------------
#
# The delta-restricted storage update (``repro.dist.sharded``) works on
# *candidate* sets sized by the update batch, not the graph: candidate
# vertex ids, their gathered adjacency rows, and candidate edges whose
# NP membership must be re-evaluated. These are its static-shape
# building blocks; every compaction reports dropped entries.

def dedup_rows(rows: jnp.ndarray, ok: jnp.ndarray, cap: int):
    """Unique valid rows, lexicographically ascending, packed to ``cap``.

    Returns ``([cap, C] PAD-filled, [cap] valid, dropped_unique)`` — the
    candidate-set compaction (C1 endpoints, C1 ∪ N(C1) vertices,
    candidate edge pairs) with an explicit overflow counter.
    """
    skeleton, valid, _, _, dropped = group_rows(rows, ok, cap)
    return skeleton, valid, dropped


def lookup_sorted(table: jnp.ndarray, q: jnp.ndarray):
    """Position of ``q`` in an ascending PAD-tailed id table.

    Returns ``(idx, hit)``; ``idx`` is clipped so callers can gather
    unconditionally and mask with ``hit``.
    """
    t = jnp.where(table < 0, _BIG, table)
    idx = jnp.clip(jnp.searchsorted(t, q.astype(_I32)), 0, table.shape[0] - 1)
    hit = (table[idx] == q) & (q >= 0)
    return idx, hit


def edge_probe(
    q_hi: jnp.ndarray,
    q_lo: jnp.ndarray,
    t_hi: jnp.ndarray,
    t_lo: jnp.ndarray,
    use_pallas: bool = False,
):
    """Membership of ``(hi, lo)`` query pairs in a small edge table.

    The candidate probe path of the delta update: local stored edges are
    probed against the (candidate ∪ deleted) edge table. The table must
    be sorted lexicographically ascending with ``(-1, -1)`` pads at the
    tail (``dedup_rows`` output order); queries may pad anywhere. Routes
    through the Pallas ``member_probe`` kernel when ``use_pallas`` is
    set (a VPU tile sweep, order-insensitive); the binary-search
    fallback keeps the host path at ``O(Q log T)`` — both are
    bit-identical.
    """
    if use_pallas:
        from repro.kernels.ops import member_probe

        hit = member_probe(q_hi.reshape(-1), q_lo.reshape(-1), t_hi, t_lo)
        return hit.reshape(q_hi.shape)
    pad_t = (t_hi == -1) & (t_lo == -1)
    ea = jnp.where(pad_t, _BIG, t_hi.astype(_I32))
    eb = jnp.where(pad_t, _BIG, t_lo.astype(_I32))
    hit = _search_sorted_pairs(q_hi.astype(_I32), q_lo.astype(_I32), ea, eb)
    return hit & ~((q_hi == -1) & (q_lo == -1))


def center_adj_contrib(pt: PaddedPartition, ids: jnp.ndarray, ok: jnp.ndarray):
    """This partition's (+1-encoded) adjacency rows for candidate ids.

    Only the *center* copy of a vertex holds its full neighborhood, so
    exactly one device contributes a non-zero row per id; callers
    ``lax.psum`` the result across the mesh and subtract 1 (absent ids
    come back as all-PAD rows). This is the candidate gather that
    replaces the full-graph adjacency all-reduce.
    """
    row = _row_of(pt, ids)
    hit = ok & (ids >= 0) & (pt.vertices[row] == ids) & pt.center[row]
    return jnp.where(hit[:, None], pt.adj[row] + 1, 0).astype(_I32)


def apply_edge_delta_rows(
    ids: jnp.ndarray,
    rows: jnp.ndarray,
    add: jnp.ndarray,
    dele: jnp.ndarray,
    nv_limit: int,
    count_overflow: bool = True,
):
    """Apply one edge batch to the adjacency rows of ``ids``.

    ``rows`` is ``[K, D]`` PAD-tailed ascending; ``add``/``dele`` are
    ``[T, 2]`` with negative rows as padding. Deletes mask matching
    neighbors; adds insert idempotently into a free slot (rows with no
    free slot count toward the returned overflow). Endpoints ≥
    ``nv_limit`` are skipped like the full-gather oracle. Result rows
    are re-sorted ascending with PAD tails.
    """
    K, D = rows.shape
    r = jnp.where(rows < 0, _BIG, rows.astype(_I32))
    ovf = jnp.int32(0)
    rowidx = jnp.arange(K)
    for t in range(dele.shape[0]):
        a, b = dele[t, 0], dele[t, 1]
        for u, w in ((a, b), (b, a)):
            sel = (ids == u) & (u >= 0)
            r = jnp.where(sel[:, None] & (r == w), _BIG, r)
    for t in range(add.shape[0]):
        a, b = add[t, 0], add[t, 1]
        bad = (a < 0) | (b < 0) | (a >= nv_limit) | (b >= nv_limit)
        for u, w in ((a, b), (b, a)):
            sel = (ids == u) & ~bad
            present = jnp.any(r == w, axis=1)
            free = r == _BIG
            has = jnp.any(free, axis=1)
            slot = jnp.argmax(free, axis=1)
            ins = sel & has & ~present
            if count_overflow:
                ovf = ovf + jnp.sum((sel & ~has & ~present).astype(_I32))
            r_ext = jnp.concatenate([r, jnp.full((K, 1), _BIG, _I32)], axis=1)
            r = r_ext.at[rowidx, jnp.where(ins, slot, D)].set(w)[:, :D]
    r = jnp.sort(r, axis=1)
    return jnp.where(r == _BIG, PAD, r), ovf


def patch_partition(
    pt: PaddedPartition,
    cand: jnp.ndarray,
    cand_valid: jnp.ndarray,
    drop_hi: jnp.ndarray,
    drop_lo: jnp.ndarray,
    ins_hi: jnp.ndarray,
    ins_lo: jnp.ndarray,
    ins_ok: jnp.ndarray,
    nv_glob: int,
    m: int,
    me: jnp.ndarray,
    caps: EngineCaps,
    use_pallas: bool = False,
):
    """Patch a stored partition in place: drop then insert edge sets.

    ``cand`` is the ascending PAD-tailed candidate vertex table — every
    dropped or inserted edge has **both endpoints in it** (the C2
    closure), so only candidate rows can change and everything else is
    a pure gather. ``(drop_hi, drop_lo)`` is a lex-sorted PAD-tailed
    edge table (the :func:`edge_probe` contract); ``(ins_hi, ins_lo,
    ins_ok)`` are (min, max) pairs to insert, already deduped and
    disjoint from the surviving stored edges (the delta update
    guarantees this: every insertion is a candidate edge, and all
    candidate edges are dropped).

    The point of this shape: no index-carrying sort (XLA's
    scalar-comparator argsort/lexsort path) ever touches a graph-sized
    array. Candidate rows are drop-probed, merged with their insertions
    by a candidate-sized row sort, and scattered into the remapped
    layout; the only |V|-sized work is gathers, scatters, and cumsum
    compactions (bandwidth-bound, same order as writing the output at
    all). Produces the oracle's canonical layout (ascending PAD-tailed
    vertices and adjacency rows, lexicographic edge list); returns
    ``(partition, overflow)``.
    """
    D = caps.deg_cap
    K = cand.shape[0]
    # 1. candidate rows in the old layout, drop-probed (delta-sized)
    oci, och = lookup_sorted(pt.vertices, cand)
    crow = jnp.where((och & cand_valid)[:, None], pt.adj[oci], PAD)
    cvv = jnp.broadcast_to(cand[:, None], crow.shape)
    qa = jnp.minimum(cvv, crow)
    qb = jnp.maximum(cvv, crow)
    hit_drop = edge_probe(qa, qb, drop_hi, drop_lo, use_pallas=use_pallas)
    ckeep = jnp.where((crow >= 0) & ~hit_drop, crow, _BIG)

    # 2. insertion neighbor sets grouped by candidate index
    src = jnp.concatenate([ins_hi, ins_lo]).astype(_I32)
    dst = jnp.concatenate([ins_lo, ins_hi]).astype(_I32)
    s_ok = jnp.concatenate([ins_ok, ins_ok])
    gidx, ghit = lookup_sorted(cand, src)
    g = jnp.where(ghit & s_ok & (dst >= 0), gidx, K)
    ins_adj, o2 = scatter_grouped_values(g, dst, K, D)

    # 3. merged candidate member rows (candidate-sized row sort)
    cmerged = jnp.sort(jnp.concatenate(
        [ckeep, jnp.where(ins_adj < 0, _BIG, ins_adj)], axis=1), axis=1)
    ccnt = jnp.sum((cmerged != _BIG).astype(_I32), axis=1)
    o3 = jnp.sum(jnp.where(cand_valid, jnp.maximum(ccnt - D, 0), 0))
    crows = cmerged[:, :D]
    crows = jnp.where(crows == _BIG, PAD, crows)

    # 4. new vertex set: stored vertices survive unless they are
    #    candidates that lost every edge; candidates with members join.
    #    Bitmap + cumsum compaction — no sort.
    mark = jnp.zeros(nv_glob + 1, bool)
    vold = jnp.where((pt.vertices >= 0) & (pt.vertices < nv_glob) & (pt.deg > 0),
                     pt.vertices, nv_glob)
    mark = mark.at[vold].set(True)
    cdump = jnp.where(cand_valid & (cand >= 0) & (cand < nv_glob), cand, nv_glob)
    mark = mark.at[cdump].set(cand_valid & (ccnt > 0))
    vertices, vvalid, o1 = _compact_vec(
        jnp.arange(nv_glob, dtype=_I32), mark[:nv_glob], caps.v_cap, fill=PAD)

    # 5. adjacency in the new layout: gather unchanged rows, overwrite
    #    candidate rows
    oidx, ohit = lookup_sorted(pt.vertices, vertices)
    live = ohit & vvalid
    adj = jnp.where(live[:, None], pt.adj[oidx], PAD)
    deg = jnp.where(live, pt.deg[oidx], 0)
    nidx, nhit = lookup_sorted(vertices, cand)
    wr = jnp.where(cand_valid & nhit, nidx, caps.v_cap)
    adj = jnp.concatenate([adj, jnp.full((1, D), PAD, _I32)], axis=0
                          ).at[wr].set(crows)[: caps.v_cap]
    deg = jnp.concatenate([deg.astype(_I32), jnp.zeros((1,), _I32)]
                          ).at[wr].set(jnp.minimum(ccnt, D))[: caps.v_cap]
    center = vvalid & (vertices >= 0) & (vertices % m == me)

    # 6. canonical edge list: binary-search merge of the (still sorted)
    #    surviving stored list with the (sorted) insertions — the lists
    #    are disjoint, so merge ranks are collision-free. This keeps
    #    the |E|-sized work at one probe + one cumsum instead of
    #    compacting the whole [v_cap · deg_cap] adjacency expansion.
    keep_e = (pt.edge_hi >= 0) & ~edge_probe(pt.edge_hi, pt.edge_lo,
                                             drop_hi, drop_lo,
                                             use_pallas=use_pallas)
    ak, akv, _ = _compact_rows(jnp.stack([pt.edge_hi, pt.edge_lo], axis=1),
                               keep_e, caps.e_cap)
    n_ins = ins_hi.shape[0]
    bk, bkv, _ = _compact_rows(jnp.stack([ins_hi, ins_lo], axis=1),
                               ins_ok, n_ins)
    a_hi = jnp.where(akv, ak[:, 0], _BIG)
    a_lo = jnp.where(akv, ak[:, 1], _BIG)
    b_hi = jnp.where(bkv, bk[:, 0], _BIG)
    b_lo = jnp.where(bkv, bk[:, 1], _BIG)
    pos_a = jnp.arange(caps.e_cap, dtype=_I32) + _lower_bound_pairs(
        a_hi, a_lo, b_hi, b_lo)
    pos_b = jnp.arange(n_ins, dtype=_I32) + _lower_bound_pairs(
        b_hi, b_lo, a_hi, a_lo)
    n_total = jnp.sum(akv.astype(_I32)) + jnp.sum(bkv.astype(_I32))
    o4 = jnp.maximum(n_total - caps.e_cap, 0)
    out = jnp.full((caps.e_cap + 1, 2), PAD, _I32)
    out = out.at[jnp.where(akv & (pos_a < caps.e_cap), pos_a, caps.e_cap)].set(ak)
    out = out.at[jnp.where(bkv & (pos_b < caps.e_cap), pos_b, caps.e_cap)].set(bk)
    part = PaddedPartition(vertices=vertices, center=center, deg=deg, adj=adj,
                           edge_hi=out[:caps.e_cap, 0], edge_lo=out[:caps.e_cap, 1])
    return part, o1 + o2 + o3 + o4


# ---------------------------------------------------------------------------
# Device-resident match maintenance (§VI filter + merge + count)
# ---------------------------------------------------------------------------
#
# The running match set of a streaming pattern lives on the mesh as a
# sharded ``CompTensors``. These primitives are the device halves of
# :func:`repro.core.incremental.filter_deleted`,
# :func:`repro.core.incremental.merge_tables` and
# :meth:`repro.core.vcbc.CompressedTable.count_matches` — same Lemma 6.1
# semantics, padded static shapes, explicit overflow counters. The
# delete-table membership probes route through the Pallas
# ``member_probe`` kernel behind ``use_pallas`` (via :func:`edge_probe`).

def deleted_edge_cols(pattern: Pattern, skel_cols: Sequence[int]):
    """Classify pattern edges for the compressed-form delete filter.

    Every pattern edge has a cover endpoint (the cover is a vertex
    cover), so it is either skeleton–skeleton — returned as a pair of
    *column indices* into ``skel_cols`` — or skeleton–compressed,
    returned as ``(compressed label, skeleton column index)``. This is
    the per-pattern structure :func:`filter_deleted_dev` interprets
    (computed once at trace time, like a plan).
    """
    sidx = {int(c): j for j, c in enumerate(skel_cols)}
    skel_pairs, comp_pairs = set(), set()
    for a, b in pattern.edges:
        if a in sidx and b in sidx:
            skel_pairs.add((sidx[a], sidx[b]))
        elif a in sidx:
            comp_pairs.add((int(b), sidx[a]))
        elif b in sidx:
            comp_pairs.add((int(a), sidx[b]))
        else:
            raise ValueError(f"pattern edge ({a},{b}) has no cover endpoint")
    return tuple(sorted(skel_pairs)), tuple(sorted(comp_pairs))


def filter_deleted_dev(
    tc: CompTensors,
    skel_pairs: Sequence[tuple],
    comp_pairs: Sequence[tuple],
    del_hi: jnp.ndarray,
    del_lo: jnp.ndarray,
    set_cap: int,
    use_pallas: bool = False,
):
    """Drop matches mapping any pattern edge into ``E_d`` (Lemma 6.1).

    Device twin of :func:`repro.core.incremental.filter_deleted`:
    skeleton–skeleton hits invalidate the whole group, skeleton–
    compressed hits shrink the offending per-vertex set (surviving
    values repacked into a valid prefix), and groups whose any set
    empties are invalidated — zero decompression. ``(del_hi, del_lo)``
    is a lex-sorted PAD-tailed edge table (the :func:`edge_probe`
    contract). Returns ``(CompTensors, removed_groups)``; the filter
    never overflows (it only removes).
    """
    valid = tc.valid
    before = jnp.sum(valid.astype(_I32))
    for ia, ib in skel_pairs:
        a = tc.skeleton[:, ia]
        b = tc.skeleton[:, ib]
        hit = edge_probe(jnp.minimum(a, b), jnp.maximum(a, b), del_hi, del_lo,
                         use_pallas=use_pallas)
        valid = valid & ~hit
    keep = {v: tc.sets[v] >= 0 for v in tc.sets}
    for v, j in comp_pairs:
        vals = tc.sets[v]
        sv = jnp.broadcast_to(tc.skeleton[:, j][:, None], vals.shape)
        hit = edge_probe(jnp.minimum(vals, sv), jnp.maximum(vals, sv),
                         del_hi, del_lo, use_pallas=use_pallas)
        keep[v] = keep[v] & ~hit
    sets: Dict[int, jnp.ndarray] = {}
    for v in tc.sets:
        packed, counts = _filter_set_rows(tc.sets[v], keep[v] & valid[:, None],
                                          set_cap)
        sets[v] = packed
        valid = valid & (counts > 0)
    removed = before - jnp.sum(valid.astype(_I32))
    return CompTensors(skeleton=tc.skeleton, valid=valid, sets=sets), removed


def merge_groups(rows: jnp.ndarray, ok: jnp.ndarray,
                 sets_in: Dict[int, jnp.ndarray], group_cap: int, set_cap: int):
    """Regroup rows by identical skeleton, unioning per-vertex sets.

    The one packing primitive behind the cross-chain patch merge, the
    match-store initialization, and :func:`merge_tables_dev`. Returns
    ``(CompTensors, overflow)`` — overflow counts dropped groups beyond
    ``group_cap`` and dropped unique set values beyond ``set_cap``.
    """
    skeleton, gvalid, order, g_eff, ovf = group_rows(rows, ok, group_cap)
    sets_out: Dict[int, jnp.ndarray] = {}
    for v, arr in sets_in.items():
        a = arr[order]                                        # [N, set_cap]
        g_rep = jnp.broadcast_to(g_eff[:, None], a.shape).reshape(-1)
        vals = a.reshape(-1)
        g_rep = jnp.where(vals >= 0, g_rep, group_cap)
        sets_out[v], dropped = scatter_grouped_values(g_rep, vals, group_cap,
                                                      set_cap)
        ovf = ovf + dropped
    return CompTensors(skeleton=skeleton, valid=gvalid, sets=sets_out), ovf


def _pad_set_width(arr: jnp.ndarray, width: int) -> jnp.ndarray:
    if arr.shape[1] >= width:
        return arr
    tail = jnp.full((arr.shape[0], width - arr.shape[1]), PAD, arr.dtype)
    return jnp.concatenate([arr, tail], axis=1)


def merge_tables_dev(tA: CompTensors, tB: CompTensors,
                     group_cap: int, set_cap: int):
    """Union of two compressed tensors of the same pattern (device twin
    of :func:`repro.core.incremental.merge_tables`).

    Groups with equal skeletons are fused and their per-vertex sets
    unioned; the result is a canonical compressed form (lex-sorted
    skeletons, ascending PAD-tailed sets). The two sides may have
    different set widths (e.g. a running store merged with an
    engine-capped patch). Returns ``(CompTensors, overflow)``.

    Contract: each side's *own* valid skeletons must be distinct — the
    form every producer in this module emits (:func:`compress_plain`,
    :func:`merge_groups`, :func:`filter_deleted_dev`). Then every output
    group has at most one source row per side and the set union is a
    pairwise merge of two ascending rows: batched row sorts + gathers,
    instead of routing the full ``2·group_cap·set_cap`` (group, value)
    stream through :func:`scatter_grouped_values`, whose stream-wide
    multi-key sort XLA:CPU executes serially (~10× this formulation on
    the per-batch maintain path).
    """
    GA, GB = tA.skeleton.shape[0], tB.skeleton.shape[0]
    rows = jnp.concatenate([tA.skeleton, tB.skeleton], axis=0)
    ok = jnp.concatenate([tA.valid, tB.valid])
    skeleton, gvalid, order, g_eff, ovf = group_rows(rows, ok, group_cap)
    # Source rows of each output group: rows of one group are adjacent
    # in skeleton-sort order and g_eff is nondecreasing over it, so the
    # group's span starts where g_eff first reaches g — at most two
    # rows, one per side, by the distinct-skeleton contract.
    n = rows.shape[0]
    gids = jnp.arange(group_cap, dtype=_I32)
    first = jnp.searchsorted(g_eff, gids)
    second = jnp.clip(first + 1, 0, n - 1)
    has2 = (first + 1 < n) & (g_eff[second] == gids)
    src1 = order[jnp.clip(first, 0, n - 1)]
    src2 = order[second]

    sets_out: Dict[int, jnp.ndarray] = {}
    for v in tA.sets:
        w = max(tA.sets[v].shape[1], tB.sets[v].shape[1])
        a_all = _pad_set_width(tA.sets[v], w)
        b_all = _pad_set_width(tB.sets[v], w)

        def pick(src):
            a = a_all[jnp.clip(src, 0, GA - 1)]
            b = b_all[jnp.clip(src - GA, 0, GB - 1)]
            return jnp.where((src < GA)[:, None], a, b)

        s1 = pick(src1)                                   # [group_cap, w]
        s2 = jnp.where(has2[:, None], pick(src2), PAD)
        cat = jnp.concatenate([s1, s2], axis=1)
        key = jnp.sort(jnp.where(cat < 0, _BIG, cat), axis=1)
        prev = jnp.concatenate(
            [jnp.full((group_cap, 1), -2, _I32), key[:, :-1]], axis=1)
        uniq = (key != prev) & (key != _BIG) & gvalid[:, None]
        packed, counts = _filter_set_rows(key, uniq, set_cap)
        sets_out[v] = packed
        ovf = ovf + jnp.sum(jnp.maximum(counts - set_cap, 0))
    return CompTensors(skeleton=skeleton, valid=gvalid, sets=sets_out), ovf


def count_matches_dev(
    tc: CompTensors,
    skel_cols: Sequence[int],
    ord_: Sequence[tuple],
) -> jnp.ndarray:
    """``|M|`` of a compressed tensor without materializing rows.

    Device twin of :meth:`repro.core.vcbc.CompressedTable.count_matches`
    — per group, the number of injective compressed-vertex assignments
    satisfying the symmetry-breaking order, summed over valid groups
    (an ``int32`` scalar; callers ``psum`` across the mesh).

    All decompression constraints are pairwise (injectivity + ord), so
    the count factorizes into pairwise compatibility masks contracted
    with one einsum: exact for any number of compressed vertices, with
    peak memory ``O(G·S²)`` for ≤3. Beyond that the contraction
    intermediate is ``O(G·S^(k-1))``, so for ``k ≥ 4`` the group axis is
    chunked with :func:`jax.lax.map` (:data:`_COUNT_CHUNK` groups per
    step) — peak memory drops to ``O(chunk·S^(k-1))`` at identical
    results (the per-group counts are independent; regression-tested at
    k = 4–5).
    """
    ord_set = {(int(a), int(b)) for a, b in ord_}
    comp = sorted(int(v) for v in tc.sets)
    if not comp:
        return jnp.sum(tc.valid.astype(_I32))
    kv: Dict[int, jnp.ndarray] = {}
    for v in comp:
        vals = tc.sets[v]
        ok = (vals >= 0) & tc.valid[:, None]
        for j, c in enumerate(skel_cols):
            sv = tc.skeleton[:, j][:, None]
            ok = ok & (vals != sv)
            if (v, int(c)) in ord_set:
                ok = ok & (vals < sv)
            if (int(c), v) in ord_set:
                ok = ok & (vals > sv)
        kv[v] = ok
    if len(comp) == 1:
        return jnp.sum(kv[comp[0]].astype(_I32))
    # 'g' is the group axis — keep it out of the per-vertex alphabet.
    alphabet = [c for c in "abcdefhijklmnopqrstuvwxyz"]
    if len(comp) > len(alphabet):
        raise ValueError(f"count_matches_dev supports at most {len(alphabet)} "
                         f"compressed vertices, got {len(comp)}")
    letters = {v: alphabet[i] for i, v in enumerate(comp)}
    operands, subs = [], []
    for i, u in enumerate(comp):
        for w in comp[i + 1:]:
            a, b = tc.sets[u], tc.sets[w]
            ok = (kv[u][:, :, None] & kv[w][:, None, :]
                  & (a[:, :, None] != b[:, None, :]))
            if (u, w) in ord_set:
                ok = ok & (a[:, :, None] < b[:, None, :])
            if (w, u) in ord_set:
                ok = ok & (a[:, :, None] > b[:, None, :])
            operands.append(ok.astype(_I32))
            subs.append(f"g{letters[u]}{letters[w]}")
    # greedy path: the optimal-path search is super-exponential in the
    # number of operands (k·(k-1)/2 pair masks) and stalls trace time
    # beyond k ≈ 6; greedy contracts pairwise and stays near-optimal
    # for this regular mask structure.
    expr = ",".join(subs) + "->g"
    if len(comp) < 4:
        return jnp.sum(jnp.einsum(expr, *operands, optimize="greedy"))
    # k ≥ 4: the contraction intermediate grows as O(G·S^(k-1)) — chunk
    # the (independent) group axis so peak memory is bounded by the
    # chunk, not the group cap.
    G = operands[0].shape[0]
    chunk = min(_COUNT_CHUNK, G)
    n_chunks = -(-G // chunk)
    pad = n_chunks * chunk - G

    def pad_op(op):
        if pad:
            # zero rows contribute zero matches — padding is free
            op = jnp.concatenate(
                [op, jnp.zeros((pad,) + op.shape[1:], op.dtype)], axis=0)
        return op.reshape((n_chunks, chunk) + op.shape[1:])

    per_chunk = jax.lax.map(
        lambda ops: jnp.sum(jnp.einsum(expr, *ops, optimize="greedy")),
        tuple(pad_op(op) for op in operands))
    return jnp.sum(per_chunk)
