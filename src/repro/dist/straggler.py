"""Straggler detection + NP-storage rebalancing.

A :class:`StragglerMonitor` keeps a sliding window of per-host step
times; hosts whose windowed mean exceeds ``threshold ×`` the median are
flagged. :func:`rebalance_plan` then moves a fraction of a slow
partition's *center vertices* to fast partitions, and
:func:`apply_rebalance` rebuilds Φ(d) under the overridden partition
function — listed results are invariant (Lemma 3.1 holds for any
partition function), only the per-host work distribution changes.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Sequence

import numpy as np

from repro.core.storage import NPStorage, build_np_storage

__all__ = ["StragglerMonitor", "rebalance_plan", "apply_rebalance"]


class StragglerMonitor:
    """Sliding-window per-host step-time monitor."""

    def __init__(self, n_hosts: int, window: int = 8, threshold: float = 1.5):
        self.n_hosts = int(n_hosts)
        self.window = int(window)
        self.threshold = float(threshold)
        self._times: deque = deque(maxlen=self.window)

    def record(self, step_times: np.ndarray) -> None:
        t = np.asarray(step_times, dtype=np.float64).reshape(self.n_hosts)
        self._times.append(t)

    def means(self) -> np.ndarray:
        if not self._times:
            return np.zeros(self.n_hosts)
        return np.stack(self._times).mean(axis=0)

    def stragglers(self) -> List[int]:
        """Hosts whose windowed mean exceeds threshold × median."""
        if not self._times:
            return []
        m = self.means()
        med = float(np.median(m))
        if med <= 0:
            return []
        return [i for i in range(self.n_hosts) if m[i] > self.threshold * med]


def rebalance_plan(
    storage: NPStorage,
    slow: Sequence[int],
    fast: Sequence[int],
    fraction: float = 0.5,
) -> Dict[int, int]:
    """Move ``fraction`` of each slow partition's centers to fast parts.

    Highest-degree centers move first (they carry the most listing
    work). Returns ``{vertex: new_partition}`` overrides.
    """
    fast = list(fast)
    if not fast:
        return {}
    plan: Dict[int, int] = {}
    g = storage.graph
    k = 0
    for pid in slow:
        centers = storage.parts[pid].center_vertices()
        if centers.size == 0:
            continue
        deg = g.degrees[np.clip(centers, 0, g.n - 1)]
        order = np.argsort(-deg, kind="stable")
        n_move = max(1, int(round(fraction * centers.size)))
        for u in centers[order][:n_move]:
            plan[int(u)] = fast[k % len(fast)]
            k += 1
    return plan


def apply_rebalance(storage: NPStorage, plan: Dict[int, int]) -> NPStorage:
    """Rebuild Φ(d) under the overridden partition function."""
    if not plan:
        return storage
    h2 = storage.h.rebalanced(plan)
    return build_np_storage(storage.graph, storage.m, h2)
