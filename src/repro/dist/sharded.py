"""Whole join-tree programs under a ``jax.sharding`` mesh.

The mesh's devices hold one NP partition each (leading array dim = flat
device index = partition id; the partition function is ``h(v) = v mod
M``). Two jitted SPMD steps execute the paper's two stages:

- :func:`make_list_step` — stage 1, the initial calculation: every
  device lists its anchored unit matches locally (disjoint & complete by
  Lemma 3.1), then each CC-join of the tree program redistributes
  groups by join-key ownership (all-gather + hash filter) and joins
  co-located tensors with :func:`repro.dist.jax_engine.ccjoin_local`.
- :func:`make_update_step` — stage 2, a batch update: the (small,
  replicated) edge batch drives the paper's candidate-restricted
  incremental shuffle (Alg. 4 C1–C3, ``mode="delta"``): the candidate
  vertex set (delta endpoints ∪ their d'-neighborhoods) is gathered
  from the partition centers, the NP membership rule ``(a,b) ∈ E_j ⇔
  h(a)=j ∨ h(b)=j ∨ ∃z ∈ CN(a,b): h(z)=j`` is re-evaluated only for
  d'-edges incident to the delta, and the stored partitions are patched
  in place — per-batch cost scales with ``|δ|``, not ``|E(d)|``.
  ``mode="full"`` keeps the original exact oracle (full global
  adjacency gather + membership recompute); the two byte-match, and the
  Nav-join patch chains (§VI-B, Thm. 6.1 dedup) run on the updated
  partitions either way.

Both steps execute the *same* :class:`~repro.core.plan.UnitPlan` /
:class:`~repro.core.plan.JoinPlan` IR as the host engine and report
capacity overflow through explicit counters in their ``diag`` dict —
never by silent truncation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro._jax_compat import donate_jit
from repro.core.navjoin import left_deep_order
from repro.core.pattern import Pattern, R1Unit
from repro.core.plan import JoinPlan, UnitPlan, WcojPlan, build_unit_plan
from repro.core.storage import NPStorage
from repro.planner.lowering import TreeNode, TreeProgram, build_tree_program
from repro.planner.sizing import StoreCaps, match_caps, unit_table_caps

from . import jax_engine as je
from .jax_engine import PAD, CompTensors, EngineCaps, PaddedPartition, _BIG, _I32

__all__ = [
    "TreeNode",
    "TreeProgram",
    "build_tree_program",
    "stack_partitions",
    "partition_specs",
    "ddsl_input_specs",
    "make_list_step",
    "UpdateShapes",
    "make_update_step",
    "make_storage_update_step",
    "make_patch_step",
    "MatchStore",
    "StoreCaps",
    "match_caps",
    "match_specs",
    "stack_matches",
    "UnitCarry",
    "unit_plan_registry",
    "unit_table_caps",
    "unit_carry_specs",
    "make_unit_refresh_step",
    "make_init_store_step",
    "make_wcoj_list_step",
    "make_wcoj_init_store_step",
    "make_maintain_step",
    "MaintainSpec",
    "make_maintain_mega_step",
]


# ---------------------------------------------------------------------------
# Tree programs: TreeNode / TreeProgram / build_tree_program now live in
# repro.planner.lowering (the compiler's JAX-free lowering stage) and are
# re-imported above — this module keeps them in __all__ for its callers.
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# Input pytrees
# ---------------------------------------------------------------------------

def stack_partitions(storage: NPStorage, caps: EngineCaps) -> PaddedPartition:
    """Pad every partition and stack along a leading device axis [M, ...]."""
    pads = [je.pad_partition(p, caps) for p in storage.parts]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *pads)


def _flat_axes(mesh: Mesh):
    axes = tuple(mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def partition_specs(mesh: Mesh) -> PaddedPartition:
    """PartitionSpecs sharding the leading (device/partition) dim."""
    spec = P(_flat_axes(mesh))
    return PaddedPartition(vertices=spec, center=spec, deg=spec,
                           adj=spec, edge_hi=spec, edge_lo=spec)


def ddsl_input_specs(caps: EngineCaps, m: int) -> PaddedPartition:
    """ShapeDtypeStructs of the stacked input (for dry-run lowering)."""
    sd = jax.ShapeDtypeStruct
    return PaddedPartition(
        vertices=sd((m, caps.v_cap), jnp.int32),
        center=sd((m, caps.v_cap), jnp.bool_),
        deg=sd((m, caps.v_cap), jnp.int32),
        adj=sd((m, caps.v_cap, caps.deg_cap), jnp.int32),
        edge_hi=sd((m, caps.e_cap), jnp.int32),
        edge_lo=sd((m, caps.e_cap), jnp.int32),
    )


def _comp_spec(pattern: Pattern, cover: Sequence[int], spec) -> CompTensors:
    comp = sorted(set(pattern.vertices) - set(cover))
    return CompTensors(skeleton=spec, valid=spec, sets={v: spec for v in comp})


# ---------------------------------------------------------------------------
# Distributed CC-join: all-gather + join-key ownership + local join
# ---------------------------------------------------------------------------

def _mesh_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def _my_index(mesh: Mesh) -> jnp.ndarray:
    idx = jnp.int32(0)
    for ax in mesh.axis_names:
        idx = idx * mesh.shape[ax] + lax.axis_index(ax)
    return idx


def _owner_of(skel: jnp.ndarray, key_idx: Sequence[int], m: int) -> jnp.ndarray:
    """Deterministic join-key → device hash (same on every device)."""
    h = jnp.zeros(skel.shape[0], _I32)
    for j in key_idx:
        h = h * jnp.int32(1000003) + skel[:, j]
    return ((h % m) + m) % m


def _gather_groups(tc: CompTensors, axes) -> CompTensors:
    def g(x):
        y = lax.all_gather(x, axes)
        return y.reshape((-1,) + x.shape[1:])

    return jax.tree.map(g, tc)


def _compact_groups(tc: CompTensors, ok: jnp.ndarray, cap: int):
    """Pack the ``ok`` groups into ``cap`` slots; count drops."""
    dest, valid, dropped = je._compact_index(ok, cap)

    def pack(arr):
        return jnp.full((cap + 1,) + arr.shape[1:], PAD, arr.dtype).at[dest].set(arr)[:cap]

    skel = pack(tc.skeleton)
    sets = {v: pack(a) for v, a in tc.sets.items()}
    return CompTensors(skeleton=skel, valid=valid, sets=sets), dropped


def _dist_join(tcA: CompTensors, tcB: CompTensors, plan: JoinPlan,
               caps: EngineCaps, mesh: Mesh):
    """Redistribute both sides by join-key ownership, then join locally.

    Every input group lives on exactly one device (units by the
    anchor→center rule, join outputs by this very ownership rule), so
    the all-gather + hash-filter keeps exactly one global copy of each
    group and the local joins partition the global join 1:1.
    """
    axes = tuple(mesh.axis_names)
    m = _mesh_size(mesh)
    me = _my_index(mesh)
    gA = _gather_groups(tcA, axes)
    gB = _gather_groups(tcB, axes)
    okA = gA.valid & (_owner_of(gA.skeleton, plan.key_left_idx, m) == me)
    okB = gB.valid & (_owner_of(gB.skeleton, plan.key_right_idx, m) == me)
    tA2, o1 = _compact_groups(gA, okA, caps.group_cap)
    tB2, o2 = _compact_groups(gB, okB, caps.group_cap)
    out, o3 = je.ccjoin_local(tA2, tB2, plan, caps)
    return out, o1 + o2 + o3


# ---------------------------------------------------------------------------
# Stage 1: distributed initial calculation
# ---------------------------------------------------------------------------

def make_list_step(prog: TreeProgram, mesh: Mesh, caps: EngineCaps):
    """Jitted SPMD step: stacked partitions → (root CompTensors, diag)."""
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    root_node = prog.nodes[prog.root]

    def body(pt_st: PaddedPartition):
        pt = jax.tree.map(lambda x: x[0], pt_st)
        ovf = jnp.int32(0)
        res: List[CompTensors] = []
        for node in prog.nodes:
            if node.unit_plan is not None:
                tbl, valid, o1 = je.unit_list(pt, node.unit_plan, caps)
                tc, _, o2 = je.compress_plain(tbl, valid, node.unit_plan.cols,
                                              prog.cover, caps)
                ovf = ovf + o1 + o2
            else:
                tc, o = _dist_join(res[node.left], res[node.right],
                                   node.join_plan, caps, mesh)
                ovf = ovf + o
            res.append(tc)
        root = res[prog.root]
        diag = {
            "overflow": lax.psum(ovf, axes),
            "matches_lower_bound": lax.psum(jnp.sum(root.valid.astype(_I32)), axes),
        }
        return jax.tree.map(lambda x: x[None], root), diag

    out_specs = (_comp_spec(root_node.pattern, prog.cover, P(ax)),
                 {"overflow": P(), "matches_lower_bound": P()})
    fn = jax.shard_map(body, mesh=mesh, in_specs=(partition_specs(mesh),),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Stage 2: distributed batch update + Nav-join patch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UpdateShapes:
    """Static batch-update shape model (|E_a|, |E_d| are compile-time).

    ``cand_cap`` / ``cedge_cap`` bound the candidate vertex and
    candidate edge sets of the delta-restricted update (``mode="delta"``
    of :func:`make_storage_update_step`). ``None`` derives bounds that
    can never overflow: at most ``2·(n_add + n_del)`` C1 endpoints, each
    contributing ≤ ``deg_cap`` neighbors / candidate edges. Tighter
    explicit values trade memory for a counted overflow risk.
    """

    n_add: int
    n_del: int
    cand_cap: Optional[int] = None
    cedge_cap: Optional[int] = None

    def delta_caps(self, caps: EngineCaps, m: int) -> Tuple[int, int, int]:
        """Resolved ``(c1_cap, cand_cap, cedge_cap)`` for a mesh of ``m``."""
        c1_cap = max(2 * (self.n_add + self.n_del), 1)
        nv_glob = m * caps.v_cap
        cand = self.cand_cap if self.cand_cap is not None else min(
            nv_glob, c1_cap * (caps.deg_cap + 1))
        cedge = self.cedge_cap if self.cedge_cap is not None else c1_cap * caps.deg_cap
        return c1_cap, max(cand, 1), max(cedge, 1)

    @staticmethod
    def from_estimator(n_add: int, n_del: int, stats, caps: EngineCaps,
                       m: int, safety: float = 8.0) -> "UpdateShapes":
        """Candidate caps sized from the §IV-D degree statistics.

        The never-overflow derivation bounds every C1 endpoint by
        ``deg_cap`` neighbors — the *maximum* degree with growth
        headroom, far above what a typical delta touches on a power-law
        graph. The endpoints of a random edge operation follow the
        *size-biased* degree distribution, whose mean is
        ``E[deg²]/E[deg] = T(2)/T(1)`` over the empirical histogram
        (:class:`repro.core.estimator.GraphStats`), so the expected
        candidate set is ``|C1|·(1 + T(2)/T(1))``. ``safety`` scales
        that expectation; the result is clamped to the never-overflow
        bound (estimator sizing can only shrink the psum payload, never
        grow it). Degenerate stats (empty graph) fall back to the
        never-overflow derivation, and any overflow that a too-small
        cap does cause is still counted in ``diag`` — never silent.
        """
        c1 = max(2 * (n_add + n_del), 1)
        t1 = stats.t_term(1)
        if t1 <= 0.0:
            return UpdateShapes(n_add=n_add, n_del=n_del)
        sb_deg = max(stats.t_term(2) / t1, 1.0)
        exp_nbrs = int(np.ceil(safety * sb_deg))
        nv_glob = m * caps.v_cap
        cand_no = min(nv_glob, c1 * (caps.deg_cap + 1))
        cedge_no = c1 * caps.deg_cap
        return UpdateShapes(
            n_add=n_add, n_del=n_del,
            cand_cap=max(min(cand_no, c1 * (exp_nbrs + 1)), 1),
            cedge_cap=max(min(cedge_no, c1 * exp_nbrs), 1),
        )


@dataclasses.dataclass(frozen=True)
class _ChainPlan:
    seed_plan: UnitPlan
    steps: Tuple[Tuple[UnitPlan, JoinPlan], ...]
    skel_pairs: Tuple[Tuple[int, int], ...]       # Thm 6.1 dedup, skeleton edges
    comp_pairs: Tuple[Tuple[int, int], ...]       # (comp label, skeleton col idx)


def _chain_plans(units: Sequence[R1Unit], pattern: Pattern,
                 cover: Tuple[int, ...], ord_) -> Tuple[_ChainPlan, ...]:
    full_skel = tuple(c for c in cover if c in set(pattern.vertices))
    sidx = {c: j for j, c in enumerate(full_skel)}
    plans = []
    for i, qi in enumerate(units):
        order = left_deep_order(units, qi, cover)
        seed = build_unit_plan(qi.pattern, qi.anchor_in(cover), ord_)
        steps = []
        cur = qi.pattern
        for qk in order[1:]:
            up = build_unit_plan(qk.pattern, qk.anchor_in(cover), ord_)
            jp = JoinPlan.make(cur, qk.pattern, cover, ord_)
            steps.append((up, jp))
            cur = cur.union(qk.pattern)
        skel_pairs, comp_pairs = set(), set()
        for qj in units[:i]:
            for a, b in qj.pattern.edges:
                if a in sidx and b in sidx:
                    skel_pairs.add((sidx[a], sidx[b]))
                elif a in sidx:
                    comp_pairs.add((b, sidx[a]))
                else:  # b in skeleton (every pattern edge has a cover endpoint)
                    comp_pairs.add((a, sidx[b]))
        plans.append(_ChainPlan(seed_plan=seed, steps=tuple(steps),
                                skel_pairs=tuple(sorted(skel_pairs)),
                                comp_pairs=tuple(sorted(comp_pairs))))
    return tuple(plans)


def _edge_in(lo: jnp.ndarray, hi: jnp.ndarray, ea: jnp.ndarray, eb: jnp.ndarray):
    """Membership of (lo, hi) pairs in a small replicated edge list."""
    if ea.shape[0] == 0:
        return jnp.zeros(lo.shape, bool)
    return jnp.any((lo[..., None] == ea) & (hi[..., None] == eb), axis=-1)


def _pair_compat(cur: CompTensors, u: int, w: int, ord_set) -> jnp.ndarray:
    """``[G, S_u, S_w]`` mask: value pairs compatible under injectivity+ord."""
    a = cur.sets[u]
    b = cur.sets[w]
    ok = (a >= 0)[:, :, None] & (b >= 0)[:, None, :] & (a[:, :, None] != b[:, None, :])
    if (u, w) in ord_set:
        ok &= a[:, :, None] < b[:, None, :]
    if (w, u) in ord_set:
        ok &= a[:, :, None] > b[:, None, :]
    return ok


def _purge_nonparticipating(cur: CompTensors, comp_labels, ord_, set_cap: int):
    """Drop set values participating in no full compressed-vertex assignment.

    Needed so the cross-chain union of sets equals the host's union of
    row-derived values when patch chains share a skeleton group. Exact
    for ≤3 compressed vertices: pairwise partner existence for 2, and
    for 3 the triple-feasibility test ``∃(b,c): ok(a,b) ∧ ok(a,c) ∧
    ok(b,c)`` evaluated as a boolean matmul over the third set (O(G·S³)
    work, O(G·S²) memory). For ≥4 every 3-subset containing the vertex
    is required to be feasible (3-consistency) — a sound
    over-approximation, strictly tighter than pairwise.
    """
    if len(comp_labels) < 2:
        return cur
    ord_set = set(ord_)
    pair: Dict[Tuple[int, int], jnp.ndarray] = {}

    def pok(u, w):
        if (u, w) not in pair:
            pair[(u, w)] = _pair_compat(cur, u, w, ord_set)
            pair[(w, u)] = jnp.swapaxes(pair[(u, w)], 1, 2)
        return pair[(u, w)]

    keeps = {}
    for u in comp_labels:
        others = [w for w in comp_labels if w != u]
        keep = cur.sets[u] >= 0
        if len(others) == 1:
            keep &= jnp.any(pok(u, others[0]), axis=2)
        else:
            # Triple feasibility for every pair of siblings: ∃(b ∈ S_w,
            # c ∈ S_x) with all three pairwise constraints satisfied.
            for i, w in enumerate(others):
                for x in others[i + 1:]:
                    # r[g,a,b] = ∃c: ok(a,c) ∧ ok(b,c) — contraction over x.
                    r = jnp.einsum("gac,gbc->gab", pok(u, x).astype(_I32),
                                   pok(w, x).astype(_I32)) > 0
                    keep &= jnp.any(pok(u, w) & r, axis=2)
        keeps[u] = keep
    valid = cur.valid
    sets = dict(cur.sets)
    for u in comp_labels:
        packed, counts = je._filter_set_rows(cur.sets[u], keeps[u] & valid[:, None], set_cap)
        sets[u] = packed
        valid = valid & (counts > 0)
    return CompTensors(skeleton=cur.skeleton, valid=valid, sets=sets)


# Regrouping rows by identical skeleton (unioning per-vertex sets) is
# now the engine primitive :func:`repro.dist.jax_engine.merge_groups`,
# shared by the patch merge below and the match-store maintenance.


def _storage_update_body(pt: PaddedPartition, add: jnp.ndarray, dele: jnp.ndarray,
                         mesh: Mesh, caps: EngineCaps, ushapes: UpdateShapes):
    """One device's half of Alg. 4: ``Φ(d)_me → Φ(d')_me`` (+ overflow).

    Pattern-independent — compiled once per (mesh, caps, shapes) and
    shared by every registered pattern of a streaming service.
    """
    axes = tuple(mesh.axis_names)
    m = _mesh_size(mesh)
    me = _my_index(mesh)
    nv_glob = m * caps.v_cap
    chunk = 64 if nv_glob % 64 == 0 else caps.v_cap
    n_chunks = nv_glob // chunk
    ovf = jnp.int32(0)

    # ---- exact global adjacency from partition centers --------------
    mine = pt.center & (pt.vertices >= 0)
    ovf = ovf + jnp.sum((mine & (pt.vertices >= nv_glob)).astype(_I32))
    vdest = jnp.where(mine & (pt.vertices < nv_glob), pt.vertices, nv_glob)
    contrib = jnp.zeros((nv_glob + 1, caps.deg_cap), _I32).at[vdest].set(pt.adj + 1)
    gn = lax.psum(contrib[:nv_glob], axes) - 1           # PAD where absent
    gm = jnp.where(gn < 0, _BIG, gn)                     # [NV, deg_cap]

    # ---- apply the replicated batch update --------------------------
    add = add.astype(_I32)
    dele = dele.astype(_I32)
    gmD = jnp.concatenate([gm, jnp.full((1, caps.deg_cap), _BIG, _I32)], axis=0)
    for t in range(ushapes.n_del):
        a, b = dele[t, 0], dele[t, 1]
        for u, w in ((a, b), (b, a)):
            us = jnp.where((u >= 0) & (u < nv_glob), u, nv_glob)
            row = gmD[us]
            gmD = gmD.at[us].set(jnp.where(row == w, _BIG, row))
    for t in range(ushapes.n_add):
        a, b = add[t, 0], add[t, 1]
        oob = (a >= nv_glob) | (b >= nv_glob)
        ovf = ovf + oob.astype(_I32)
        # Negative endpoints mark padding rows (fixed-size batches):
        # route the whole row to the dump slot, uncounted.
        bad = oob | (a < 0) | (b < 0)
        for u, w in ((a, b), (b, a)):
            us = jnp.where(bad | (u < 0) | (u >= nv_glob), nv_glob, u)
            row = gmD[us]
            # Idempotent insert: the host rejects already-present
            # edges with an exception; a jitted step can't, so a
            # duplicate (or twice-listed) add becomes a no-op here
            # instead of corrupting the adjacency multiset.
            present = jnp.any(row == w)
            free = row == _BIG
            has = jnp.any(free)
            ovf = ovf + ((~has) & (~present) & (~bad)).astype(_I32)
            slot = jnp.argmax(free)
            ins = has & ~present & ~bad
            gmD = gmD.at[us, slot].set(jnp.where(ins, w, row[slot]))
    gm = jnp.sort(gmD[:nv_glob], axis=1)                 # valid prefix asc

    # ---- NP membership rule for my part (== rebuild of Φ(d')_me) ----
    def memb_chunk(ids):
        rv = gm[ids]                                     # [C, D] neighbors
        wvalid = rv != _BIG
        m1 = ((ids % m) == me)[:, None] | (wvalid & ((rv % m) == me))
        nw = gm[jnp.clip(rv, 0, nv_glob - 1)]            # [C, Dw, Du]
        zmask = wvalid & ((rv % m) == me)                # z ∈ N(v), h(z)=me
        eqz = nw[:, :, :, None] == rv[:, None, None, :]  # [C, Dw, Du, Dt]
        cond = jnp.any(jnp.any(eqz, axis=2) & zmask[:, None, :], axis=2)
        return (m1 | cond) & wvalid

    ids = jnp.arange(nv_glob).reshape(n_chunks, chunk)
    memb = lax.map(memb_chunk, ids).reshape(nv_glob, caps.deg_cap)

    inpart = jnp.any(memb, axis=1)
    vertices, vvalid, o = je._compact_vec(
        jnp.arange(nv_glob, dtype=_I32), inpart, caps.v_cap, fill=PAD)
    ovf = ovf + o
    vsafe = jnp.where(vertices >= 0, vertices, 0)
    ladj = jnp.where(memb[vsafe] & vvalid[:, None], gm[vsafe], _BIG)
    ladj = jnp.sort(ladj, axis=1)
    ldeg = jnp.sum((ladj != _BIG).astype(_I32), axis=1)
    ladj = jnp.where(ladj == _BIG, PAD, ladj)
    center = vvalid & (vertices % m == me)
    vv = jnp.broadcast_to(vertices[:, None], ladj.shape)
    e_ok = (ladj >= 0) & (ladj > vv)
    epairs = jnp.stack([vv.reshape(-1), ladj.reshape(-1)], axis=1)
    epacked, _, oe = je._compact_rows(epairs, e_ok.reshape(-1), caps.e_cap)
    ovf = ovf + oe
    pt2 = PaddedPartition(vertices=vertices, center=center, deg=ldeg,
                          adj=ladj, edge_hi=epacked[:, 0], edge_lo=epacked[:, 1])
    return pt2, ovf


def _delta_update_body(pt: PaddedPartition, add: jnp.ndarray, dele: jnp.ndarray,
                       mesh: Mesh, caps: EngineCaps, ushapes: UpdateShapes):
    """Candidate-restricted Alg. 4 (C1–C3): ``Φ(d)_me → Φ(d')_me`` from the
    delta alone.

    Instead of re-gathering the whole global adjacency and re-deriving
    NP membership for every vertex (the ``_storage_update_body``
    oracle), only the *candidate* state moves:

    - **C1** — endpoints of inserted/deleted edges (the only vertices
      whose neighborhoods change).
    - **C2** — ``C1 ∪ N_{d'}(C1)``: membership of an edge ``(v, w)``
      depends on ``CN(v, w)``, which can only change when ``v`` or ``w``
      lies in C1; evaluating the rule needs the adjacency rows of both
      endpoints of every affected edge. Rows are shuffled from their
      partition centers (one ``psum`` over ``[cand_cap, deg_cap]``, not
      ``[NV, deg_cap]``).
    - **C3** — the affected NP members: every d'-edge incident to C1.
      Their membership bit is re-evaluated against the candidate rows;
      all other stored edges keep their bit (their common neighborhoods
      are untouched), so the partition is patched in place.

    Byte-identical to the full-gather oracle (tested on randomized
    update streams); per-batch work scales with ``|δ|·deg_cap``, not
    ``|E(d)|``.
    """
    axes = tuple(mesh.axis_names)
    m = _mesh_size(mesh)
    me = _my_index(mesh)
    nv_glob = m * caps.v_cap
    c1_cap, cand_cap, cedge_cap = ushapes.delta_caps(caps, m)
    add = add.astype(_I32)
    dele = dele.astype(_I32)
    ovf = jnp.int32(0)

    # Out-of-bounds inserts are counted (and skipped) like the oracle;
    # negative endpoints mark padding rows of the fixed-size batch.
    ovf = ovf + jnp.sum(jnp.any(add >= nv_glob, axis=1).astype(_I32))

    # ---- C1: endpoints of the delta (replicated) --------------------
    ends = jnp.concatenate([add.reshape(-1), dele.reshape(-1)])
    e_ok = (ends >= 0) & (ends < nv_glob)
    c1_t, c1_valid, o1 = je.dedup_rows(ends[:, None], e_ok, c1_cap)
    c1 = c1_t[:, 0]
    ovf = ovf + o1

    # ---- candidate rows: C1 first, then C = C1 ∪ N_d'(C1) -----------
    rows1 = lax.psum(je.center_adj_contrib(pt, c1, c1_valid), axes) - 1
    rows1, _ = je.apply_edge_delta_rows(c1, rows1, add, dele, nv_glob,
                                        count_overflow=False)
    cids = jnp.concatenate([c1, rows1.reshape(-1)])
    c_ok = cids >= 0
    cand_t, cand_valid, o2 = je.dedup_rows(cids[:, None], c_ok, cand_cap)
    cand = cand_t[:, 0]
    ovf = ovf + o2

    rows_c = lax.psum(je.center_adj_contrib(pt, cand, cand_valid), axes) - 1
    rows_c, o3 = je.apply_edge_delta_rows(cand, rows_c, add, dele, nv_glob)
    ovf = ovf + o3

    # ---- candidate edges: every d'-edge incident to C1 --------------
    i1, h1 = je.lookup_sorted(cand, c1)
    nb = jnp.where(h1[:, None], rows_c[i1], PAD)
    vv = jnp.broadcast_to(c1[:, None], nb.shape)
    pair_ok = c1_valid[:, None] & (nb >= 0)
    pairs = jnp.stack([jnp.minimum(vv, nb).reshape(-1),
                       jnp.maximum(vv, nb).reshape(-1)], axis=1)
    ce, ce_valid, o4 = je.dedup_rows(pairs, pair_ok.reshape(-1), cedge_cap)
    ovf = ovf + o4

    # ---- NP membership rule over the candidate rows -----------------
    ia, ha = je.lookup_sorted(cand, ce[:, 0])
    ib, hb = je.lookup_sorted(cand, ce[:, 1])
    ra = jnp.where((ce_valid & ha)[:, None], rows_c[ia], PAD)
    rb = jnp.where((ce_valid & hb)[:, None], rows_c[ib], PAD)
    direct = ((ce[:, 0] % m) == me) | ((ce[:, 1] % m) == me)
    zmine = (ra >= 0) & ((ra % m) == me)                  # z ∈ N(a), h(z)=me
    zcommon = jnp.any((ra[:, :, None] == rb[:, None, :]) & (rb >= 0)[:, None, :],
                      axis=2)                             # z ∈ N(a) ∩ N(b)
    member = ce_valid & (direct | jnp.any(zmine & zcommon, axis=1))

    # ---- patch the stored partition in place ------------------------
    # Every stored edge whose membership may change is either deleted
    # or a candidate edge; drop those and re-insert candidates that
    # (still or newly) satisfy the rule.
    bad_d = (dele[:, 0] < 0) | (dele[:, 1] < 0)
    d_hi = jnp.where(bad_d, PAD, jnp.minimum(dele[:, 0], dele[:, 1]))
    d_lo = jnp.where(bad_d, PAD, jnp.maximum(dele[:, 0], dele[:, 1]))
    probe_rows = jnp.concatenate([ce, jnp.stack([d_hi, d_lo], axis=1)], axis=0)
    # Re-sorting via dedup keeps the drop table lexicographic (the
    # edge_probe contract); the cap is exact, so nothing can drop.
    tbl, _, _ = je.dedup_rows(probe_rows, probe_rows[:, 0] >= 0,
                              probe_rows.shape[0])
    pt2, o5 = je.patch_partition(
        pt, cand, cand_valid, tbl[:, 0], tbl[:, 1], ce[:, 0], ce[:, 1], member,
        nv_glob, m, me, caps, use_pallas=caps.use_pallas)
    ovf = ovf + o5

    counters = {
        "cand_vertices": jnp.sum(cand_valid.astype(_I32)),
        "cand_edges": jnp.sum(ce_valid.astype(_I32)),
        # Drops attributable to the candidate caps alone (cand_cap /
        # cedge_cap sizing) — callers that auto-fall back to the
        # never-overflow derivation gate on this, not on the summed
        # counter, which also carries e_cap/deg_cap/oob overflow no
        # cap resize can fix.
        "cand_overflow": o1 + o2 + o4,
    }
    return pt2, ovf, counters


def _patch_body(pt2: PaddedPartition, add: jnp.ndarray, prog: TreeProgram,
                chains: Tuple[_ChainPlan, ...], mesh: Mesh, caps: EngineCaps,
                unit_tables: Optional[Dict[Tuple, "UnitCarry"]] = None):
    """One device's Nav-join patch chains (Lemma 6.2 + Thm. 6.1) over the
    already-updated partition ``Φ(d')_me``.

    ``unit_tables`` (keyed by unit-pattern key) supplies this device's
    *carried* unit tables — the plain listing for seeds (re-filtered
    against this batch's ``E_a``) and the compressed form for chain
    steps — so a warm batch runs zero :func:`~repro.dist.jax_engine.unit_list`
    calls. Absent, every table is listed from ``Φ(d')_me`` as before;
    the two paths are bit-identical when the carry is fresh (the carry's
    refresh is exactly this listing).
    """
    axes = tuple(mesh.axis_names)
    m = _mesh_size(mesh)
    me = _my_index(mesh)
    pattern = prog.nodes[prog.root].pattern
    cover = prog.cover
    ord_t = prog.ord
    full_skel = tuple(c for c in cover if c in set(pattern.vertices))
    comp_labels = tuple(sorted(set(pattern.vertices) - set(cover)))
    add = add.astype(_I32)
    add_lo = jnp.minimum(add[:, 0], add[:, 1])
    add_hi = jnp.maximum(add[:, 0], add[:, 1])
    unit_cache: Dict[Tuple, Tuple[CompTensors, jnp.ndarray]] = {}

    def unit_table(up: UnitPlan):
        if unit_tables is not None:
            return unit_tables[up.pattern.key()].comp, jnp.int32(0)
        key = up.pattern.key()
        if key not in unit_cache:
            tbl, valid, o1 = je.unit_list(pt2, up, caps)
            tc, _, o2 = je.compress_plain(tbl, valid, up.cols, cover, caps)
            unit_cache[key] = (tc, o1 + o2)
        return unit_cache[key]

    chain_out: List[CompTensors] = []
    povf = jnp.int32(0)
    for chain in chains:
        if unit_tables is not None:
            uc = unit_tables[chain.seed_plan.pattern.key()]
            tbl = uc.tbl
            valid = uc.valid & je.require_edges_mask(
                tbl, chain.seed_plan.edge_cols, add)
            o1 = jnp.int32(0)
        else:
            tbl, valid, o1 = je.unit_list(pt2, chain.seed_plan, caps,
                                          require_edges=add)
        cur, _, o2 = je.compress_plain(tbl, valid, chain.seed_plan.cols,
                                       cover, caps)
        povf = povf + o1 + o2
        for up, jp in chain.steps:
            tck, o3 = unit_table(up)
            cur, o4 = _dist_join(cur, tck, jp, caps, mesh)
            povf = povf + o3 + o4
        # Thm. 6.1 dedup: drop matches mapping an earlier unit's edge
        # into E_a. Every pattern edge has a cover endpoint, so the
        # row filter factorizes over skeleton pairs / set values.
        valid = cur.valid
        sets = dict(cur.sets)
        for ia, ib in chain.skel_pairs:
            lo = jnp.minimum(cur.skeleton[:, ia], cur.skeleton[:, ib])
            hi = jnp.maximum(cur.skeleton[:, ia], cur.skeleton[:, ib])
            valid = valid & ~_edge_in(lo, hi, add_lo, add_hi)
        for v, iskel in chain.comp_pairs:
            vals = sets[v]
            sv = cur.skeleton[:, iskel][:, None]
            lo = jnp.minimum(vals, sv)
            hi = jnp.maximum(vals, sv)
            ok = (vals >= 0) & ~_edge_in(lo, hi, add_lo, add_hi)
            packed, counts = je._filter_set_rows(vals, ok & valid[:, None],
                                                 caps.set_cap)
            sets[v] = packed
            valid = valid & (counts > 0)
        cur = CompTensors(skeleton=cur.skeleton, valid=valid, sets=sets)
        cur = _purge_nonparticipating(cur, comp_labels, ord_t, caps.set_cap)
        chain_out.append(cur)
    for _, o in unit_cache.values():
        povf = povf + o

    # ---- merge chains: co-locate equal skeletons, union sets --------
    # Pairwise canonical-merge fold: every per-device chain table is
    # canonical within itself (unique skeletons, ascending sets), so the
    # L·m-way union folds through :func:`~repro.dist.jax_engine.merge_tables_dev`
    # — batched row sorts — instead of routing the whole
    # L·m·group_cap·set_cap (group, value) stream through one
    # multi-key sort that XLA:CPU serializes. Bit-identical union; under
    # group overflow the dropped-group identity follows the fold order.
    skel_idx = tuple(range(len(full_skel)))
    blocks: List[CompTensors] = []
    for tc in chain_out:
        g = _gather_groups(tc, axes)
        G = tc.skeleton.shape[0]
        for d in range(m):
            blk = jax.tree.map(lambda x: x[d * G:(d + 1) * G], g)
            mine = blk.valid & (_owner_of(blk.skeleton, skel_idx, m) == me)
            blocks.append(CompTensors(skeleton=blk.skeleton, valid=mine,
                                      sets=blk.sets))
    if len(blocks) == 1:
        blk = blocks[0]
        blocks.append(CompTensors(skeleton=blk.skeleton,
                                  valid=jnp.zeros_like(blk.valid),
                                  sets=blk.sets))
    patch, om = je.merge_tables_dev(blocks[0], blocks[1], caps.group_cap,
                                    caps.set_cap)
    for blk in blocks[2:]:
        patch, o = je.merge_tables_dev(patch, blk, caps.group_cap,
                                       caps.set_cap)
        om = om + o
    return patch, povf + om


def _run_storage_update(pt: PaddedPartition, add: jnp.ndarray, dele: jnp.ndarray,
                        mesh: Mesh, caps: EngineCaps, ushapes: UpdateShapes,
                        mode: str):
    """Dispatch one device's storage update body by ``mode``."""
    if mode == "full":
        pt2, ovf = _storage_update_body(pt, add, dele, mesh, caps, ushapes)
        return pt2, ovf, {}
    if mode == "delta":
        return _delta_update_body(pt, add, dele, mesh, caps, ushapes)
    raise ValueError(f"unknown update mode {mode!r} (expected 'delta' or 'full')")


def make_storage_update_step(mesh: Mesh, caps: EngineCaps, ushapes: UpdateShapes,
                             mode: str = "delta"):
    """Jitted SPMD step: (partitions, E_a, E_d) → (partitions', diag).

    The pattern-independent half of the batch update — a streaming
    service compiles it **once** and shares the resulting Φ(d') across
    every registered pattern's patch step. Assumes ``h(v) = v mod M``.

    ``mode="delta"`` (default) runs the candidate-restricted update
    (:func:`_delta_update_body`): per-batch cost scales with the delta,
    and ``diag`` additionally reports the per-batch ``cand_vertices`` /
    ``cand_edges`` set sizes. ``mode="full"`` keeps the exact
    full-gather oracle; the two byte-match.

    ``diag["part_dirty"]`` is a per-device ``[M]`` bool: whether this
    batch changed the partition's stored edge set. The canonical edge
    list determines the whole partition (adjacency, degrees, live
    centers), so an unchanged list proves every per-partition artifact
    — in particular the carried unit tables of
    :func:`make_maintain_step` — is still exact.
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    counter_keys = (("cand_vertices", "cand_edges", "cand_overflow")
                    if mode == "delta" else ())

    def body(pt_st: PaddedPartition, add: jnp.ndarray, dele: jnp.ndarray):
        pt = jax.tree.map(lambda x: x[0], pt_st)
        pt2, ovf, counters = _run_storage_update(pt, add, dele, mesh, caps,
                                                 ushapes, mode)
        changed = (jnp.any(pt2.edge_hi != pt.edge_hi)
                   | jnp.any(pt2.edge_lo != pt.edge_lo))
        diag = {
            "overflow": lax.psum(ovf, axes),
            "stored_edges": lax.psum(jnp.sum((pt2.edge_hi >= 0).astype(_I32)), axes),
            "part_dirty": changed[None],
            **counters,
        }
        return jax.tree.map(lambda x: x[None], pt2), diag

    diag_specs = {"overflow": P(), "stored_edges": P(), "part_dirty": P(ax),
                  **{k: P() for k in counter_keys}}
    out_specs = (partition_specs(mesh), diag_specs)
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(partition_specs(mesh), P(), P()),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def make_patch_step(prog: TreeProgram, units: Sequence[R1Unit], mesh: Mesh,
                    caps: EngineCaps, unit_caps: Optional[StoreCaps] = None):
    """Jitted SPMD step: (updated partitions, E_a) → (patch, diag).

    The per-pattern half of the batch update: Nav-join patch chains over
    a Φ(d') produced by :func:`make_storage_update_step`.

    With ``unit_caps`` the step threads a persistent unit-table carry:
    signature becomes ``(pt2, carry, dirty, add) → (patch, carry',
    diag)`` where ``dirty`` is the storage step's per-device
    ``part_dirty`` flag — only dirty devices re-run ``unit_list``
    (behind a ``lax.cond``); everyone else joins against the carried
    tables. ``diag`` additionally reports ``unit_refreshes`` (devices
    refreshed this batch).
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    pattern = prog.nodes[prog.root].pattern
    chains = _chain_plans(units, pattern, prog.cover, prog.ord)

    if unit_caps is None:
        def body(pt2_st: PaddedPartition, add: jnp.ndarray):
            pt2 = jax.tree.map(lambda x: x[0], pt2_st)
            patch, povf = _patch_body(pt2, add, prog, chains, mesh, caps)
            diag = {
                "overflow": lax.psum(povf, axes),
                "patch_groups": lax.psum(jnp.sum(patch.valid.astype(_I32)), axes),
            }
            return jax.tree.map(lambda x: x[None], patch), diag

        out_specs = (_comp_spec(pattern, prog.cover, P(ax)),
                     {"overflow": P(), "patch_groups": P()})
        fn = jax.shard_map(body, mesh=mesh,
                           in_specs=(partition_specs(mesh), P()),
                           out_specs=out_specs, check_vma=False)
        return jax.jit(fn)

    plans, names = unit_plan_registry(prog, units)
    carry_specs = unit_carry_specs(prog, units, mesh)

    def body_carry(pt2_st: PaddedPartition, carry_st, dirty_st,
                   add: jnp.ndarray):
        pt2 = jax.tree.map(lambda x: x[0], pt2_st)
        carry = jax.tree.map(lambda x: x[0], carry_st)
        dirty = dirty_st[0]
        carry2, rovf = lax.cond(
            dirty,
            lambda: _refresh_units(pt2, plans, prog.cover, caps, unit_caps),
            lambda: (carry, jnp.int32(0)))
        by_key = {k: carry2[n] for k, n in names.items()}
        patch, povf = _patch_body(pt2, add, prog, chains, mesh, caps,
                                  unit_tables=by_key)
        diag = {
            "overflow": lax.psum(povf + rovf, axes),
            "patch_groups": lax.psum(jnp.sum(patch.valid.astype(_I32)), axes),
            "unit_refreshes": lax.psum(dirty.astype(_I32), axes),
        }
        return (jax.tree.map(lambda x: x[None], patch),
                jax.tree.map(lambda x: x[None], carry2), diag)

    out_specs = (_comp_spec(pattern, prog.cover, P(ax)), carry_specs,
                 {"overflow": P(), "patch_groups": P(), "unit_refreshes": P()})
    fn = jax.shard_map(body_carry, mesh=mesh,
                       in_specs=(partition_specs(mesh), carry_specs,
                                 P(ax), P()),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def make_update_step(prog: TreeProgram, units: Sequence[R1Unit], mesh: Mesh,
                     caps: EngineCaps, ushapes: UpdateShapes,
                     mode: str = "delta"):
    """Jitted SPMD step: (partitions, E_a, E_d) → (partitions', patch, diag).

    Fused composition of :func:`make_storage_update_step` and
    :func:`make_patch_step` for single-pattern callers (``mode`` as in
    :func:`make_storage_update_step`). Assumes the modulo partition
    function ``h(v) = v mod M`` (the default
    :class:`~repro.core.storage.PartitionFn`).
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    pattern = prog.nodes[prog.root].pattern
    cover = prog.cover
    chains = _chain_plans(units, pattern, cover, prog.ord)
    counter_keys = (("cand_vertices", "cand_edges", "cand_overflow")
                    if mode == "delta" else ())

    def body(pt_st: PaddedPartition, add: jnp.ndarray, dele: jnp.ndarray):
        pt = jax.tree.map(lambda x: x[0], pt_st)
        pt2, ovf, counters = _run_storage_update(pt, add, dele, mesh, caps,
                                                 ushapes, mode)
        patch, povf = _patch_body(pt2, add, prog, chains, mesh, caps)
        diag = {
            "overflow": lax.psum(ovf + povf, axes),
            "patch_groups": lax.psum(jnp.sum(patch.valid.astype(_I32)), axes),
            "stored_edges": lax.psum(jnp.sum((pt2.edge_hi >= 0).astype(_I32)), axes),
            **counters,
        }
        return (jax.tree.map(lambda x: x[None], pt2),
                jax.tree.map(lambda x: x[None], patch), diag)

    out_specs = (partition_specs(mesh),
                 _comp_spec(pattern, cover, P(ax)),
                 {"overflow": P(), "patch_groups": P(), "stored_edges": P(),
                  **{k: P() for k in counter_keys}})
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(partition_specs(mesh), P(), P()),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Device-resident match store (§VI maintenance without leaving the mesh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MatchStore:
    """One pattern's running compressed match set, sharded on the mesh.

    Same tensor layout as :class:`~repro.dist.jax_engine.CompTensors`
    with a leading device axis: ``skeleton [M, Gs, S]`` (PAD-filled),
    ``valid [M, Gs]``, ``sets`` mapping each compressed-vertex label to
    ``[M, Gs, set_cap]``. Groups are placed by the join-key ownership
    hash over the **full** skeleton (:func:`_owner_of` over all
    skeleton columns) — the same rule the per-pattern patch merge uses,
    so filter/merge/count of a batch are purely local per device.
    Skeletons are globally unique (each hashes to exactly one owner and
    each shard is regrouped), so flattening the shards yields a valid
    host :class:`~repro.core.vcbc.CompressedTable` on demand.
    """

    skeleton: jnp.ndarray
    valid: jnp.ndarray
    sets: Dict[int, jnp.ndarray]

    def as_comp(self) -> CompTensors:
        """View one device's shard (no leading axis) as plain tensors."""
        return CompTensors(skeleton=self.skeleton, valid=self.valid,
                           sets=dict(self.sets))

    def flatten(self) -> CompTensors:
        """All shards with the device axis folded away (``[M·G, ...]``)
        — the layout :func:`~repro.dist.jax_engine.comp_to_host`
        consumes. Valid because store skeletons are globally unique."""
        return CompTensors(
            skeleton=self.skeleton.reshape(-1, self.skeleton.shape[-1]),
            valid=self.valid.reshape(-1),
            sets={v: a.reshape(-1, a.shape[-1]) for v, a in self.sets.items()})


je._register(MatchStore, ("skeleton", "valid", "sets"))


# StoreCaps / match_caps moved to repro.planner.sizing (re-imported above).


def match_specs(mesh: Mesh, pattern: Pattern, cover: Sequence[int]) -> MatchStore:
    """PartitionSpecs sharding a store's leading (device) dim."""
    spec = P(_flat_axes(mesh))
    comp = sorted(set(pattern.vertices) - set(cover))
    return MatchStore(skeleton=spec, valid=spec, sets={v: spec for v in comp})


def _owner_rows_np(skel: np.ndarray, m: int) -> np.ndarray:
    """Host twin of :func:`_owner_of` (int32 wraparound semantics)."""
    h = np.zeros(skel.shape[0], np.int32)
    with np.errstate(over="ignore"):
        for j in range(skel.shape[1]):
            h = h * np.int32(1000003) + skel[:, j].astype(np.int32)
    return ((h.astype(np.int64) % m) + m) % m


def stack_matches(table, m: int, store: StoreCaps) -> MatchStore:
    """Shard a host :class:`~repro.core.vcbc.CompressedTable` into a
    stacked :class:`MatchStore` by full-skeleton ownership.

    The host-side init/restore path (the in-service path builds the
    store on device via :func:`make_init_store_step`). Store caps must
    hold every owner's shard — a misfit is a sizing error and raises
    instead of truncating, like :func:`~repro.dist.jax_engine.pad_partition`.
    """
    S = len(table.skeleton_cols)
    owner = _owner_rows_np(table.skeleton.astype(np.int64), m)
    comp_labels = sorted(int(v) for v in table.comp)
    shards = []
    for j in range(m):
        idx = np.nonzero(owner == j)[0]
        if idx.shape[0] > store.group_cap:
            raise ValueError(
                f"shard {j} holds {idx.shape[0]} groups > group_cap={store.group_cap}")
        skel = np.full((store.group_cap, S), PAD, np.int32)
        skel[: idx.shape[0]] = table.skeleton[idx]
        valid = np.zeros(store.group_cap, bool)
        valid[: idx.shape[0]] = True
        sets = {}
        for v in comp_labels:
            r = table.comp[v]
            arr = np.full((store.group_cap, store.set_cap), PAD, np.int32)
            for k, g in enumerate(idx):
                vals = r.values[r.offsets[g]: r.offsets[g + 1]]
                if vals.shape[0] > store.set_cap:
                    raise ValueError(
                        f"group set has {vals.shape[0]} values > set_cap={store.set_cap}")
                arr[k, : vals.shape[0]] = vals
            sets[v] = jnp.asarray(arr)
        shards.append(MatchStore(skeleton=jnp.asarray(skel),
                                 valid=jnp.asarray(valid), sets=sets))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)


# ---------------------------------------------------------------------------
# Delta-maintained per-device unit-table carries (the §IV-D `fixed` killer)
# ---------------------------------------------------------------------------
#
# Every Nav-join patch chain step re-lists this device's full unit
# tables M_ac(q, d'_me) — work independent of the batch size, paid per
# pattern per batch. A unit table is a pure function of the partition's
# canonical edge list (Lemma 3.1 anchors units to centers), so the
# tables are *carried* across batches as persistent device buffers and
# refreshed — inside the fused step, behind a `lax.cond` — only when the
# storage step reports the partition dirty (`diag["part_dirty"]`).

@dataclasses.dataclass
class UnitCarry:
    """One unit plan's carried tables on one device: the plain listing
    (``tbl [match_cap, k]`` + ``valid``, what seed re-filtering needs)
    and its VCBC-compressed form (what chain-step CC-joins consume)."""

    tbl: jnp.ndarray
    valid: jnp.ndarray
    comp: CompTensors


je._register(UnitCarry, ("tbl", "valid", "comp"))


def unit_plan_registry(prog: TreeProgram, units: Sequence[R1Unit]):
    """Distinct unit plans of a pattern's patch chains.

    Returns ``(plans, names)``: ``plans`` maps a stable name (``u0``,
    ``u1``, … in sorted-key order) to the :class:`UnitPlan`, ``names``
    maps each unit-pattern key to its name. Seed plans and chain-step
    plans of the same unit shape share one entry — one carried table
    serves both roles.
    """
    pattern = prog.nodes[prog.root].pattern
    chains = _chain_plans(units, pattern, prog.cover, prog.ord)
    reg: Dict[Tuple, UnitPlan] = {}
    for chain in chains:
        for up in (chain.seed_plan, *(u for u, _ in chain.steps)):
            reg.setdefault(up.pattern.key(), up)
    names = {k: f"u{i}" for i, k in enumerate(sorted(reg))}
    return {names[k]: up for k, up in reg.items()}, names


# unit_table_caps moved to repro.planner.sizing (re-imported above).


def unit_carry_specs(prog: TreeProgram, units: Sequence[R1Unit],
                     mesh: Mesh) -> Dict[str, UnitCarry]:
    """PartitionSpecs sharding a carry pytree's leading (device) dim."""
    spec = P(_flat_axes(mesh))
    plans, _ = unit_plan_registry(prog, units)
    return {name: UnitCarry(tbl=spec, valid=spec,
                            comp=_comp_spec(up.pattern, prog.cover, spec))
            for name, up in plans.items()}


def _refresh_units(pt2: PaddedPartition, plans: Dict[str, UnitPlan],
                   cover: Tuple[int, ...], caps: EngineCaps,
                   ucaps: StoreCaps):
    """List + compress every registered unit plan from ``Φ(d')_me`` —
    the (cold) carry refresh, also the oracle a fresh carry must equal."""
    ccaps = dataclasses.replace(caps, group_cap=ucaps.group_cap,
                                set_cap=ucaps.set_cap)
    out: Dict[str, UnitCarry] = {}
    ovf = jnp.int32(0)
    for name in sorted(plans):
        up = plans[name]
        tbl, valid, o1 = je.unit_list(pt2, up, caps)
        tc, _, o2 = je.compress_plain(tbl, valid, up.cols, cover, ccaps)
        out[name] = UnitCarry(tbl=tbl, valid=valid, comp=tc)
        ovf = ovf + o1 + o2
    return out, ovf


def make_unit_refresh_step(prog: TreeProgram, units: Sequence[R1Unit],
                           mesh: Mesh, caps: EngineCaps, ucaps: StoreCaps):
    """Jitted SPMD step: Φ partitions → (unit-table carry, diag).

    The cold fill: a streaming backend runs it once at register/restore
    time; afterwards the fused maintain step keeps the carry fresh by
    refreshing only dirty devices. ``diag``: ``overflow``.
    """
    axes = tuple(mesh.axis_names)
    plans, _ = unit_plan_registry(prog, units)

    def body(pt_st: PaddedPartition):
        pt = jax.tree.map(lambda x: x[0], pt_st)
        carry, ovf = _refresh_units(pt, plans, prog.cover, caps, ucaps)
        diag = {"overflow": lax.psum(ovf, axes)}
        return jax.tree.map(lambda x: x[None], carry), diag

    out_specs = (unit_carry_specs(prog, units, mesh), {"overflow": P()})
    fn = jax.shard_map(body, mesh=mesh, in_specs=(partition_specs(mesh),),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def make_init_store_step(prog: TreeProgram, mesh: Mesh, caps: EngineCaps,
                         store: StoreCaps):
    """Jitted SPMD step: (root CompTensors from the list step) →
    (:class:`MatchStore`, diag).

    Redistributes the initial listing's groups by full-skeleton
    ownership (the store placement rule), regroups each shard into
    canonical form, and counts matches on device — the initial match
    set never visits the host. ``diag`` carries ``count``,
    ``store_groups`` and ``overflow``.
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    m = _mesh_size(mesh)
    root = prog.nodes[prog.root]
    n_s = len(root.skel_cols)

    def body(tc_st: CompTensors):
        tc = jax.tree.map(lambda x: x[0], tc_st)
        me = _my_index(mesh)
        g = _gather_groups(tc, axes)
        mine = g.valid & (_owner_of(g.skeleton, tuple(range(n_s)), m) == me)
        st, ovf = je.merge_groups(g.skeleton, mine, g.sets,
                                  store.group_cap, store.set_cap)
        cnt = je.count_matches_dev(st, root.skel_cols, prog.ord)
        diag = {
            "count": lax.psum(cnt, axes),
            "store_groups": lax.psum(jnp.sum(st.valid.astype(_I32)), axes),
            "overflow": lax.psum(ovf, axes),
        }
        out = MatchStore(skeleton=st.skeleton, valid=st.valid, sets=st.sets)
        return jax.tree.map(lambda x: x[None], out), diag

    out_specs = (match_specs(mesh, root.pattern, prog.cover),
                 {"count": P(), "store_groups": P(), "overflow": P()})
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(_comp_spec(root.pattern, prog.cover, P(ax)),),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def make_wcoj_list_step(pattern: Pattern, plan: WcojPlan, mesh: Mesh,
                        caps: EngineCaps, level_caps: Sequence[int]):
    """Jitted SPMD step: stacked partitions → (listed CompTensors, diag)
    — the WCOJ executor's stage 1, the generic-join twin of
    :func:`make_list_step`.

    Every device runs the anchored generic join over its partition
    (:func:`~repro.dist.jax_engine.wcoj_list`; complete & disjoint by the
    same center-anchoring argument as Lemma 3.1 — the anchor is adjacent
    to every other pattern vertex, so each match is found exactly once,
    at its anchor's center). The plain rows are wrapped as
    trivially-compressed tensors (skeleton = every column, empty sets) so
    the store init/maintain machinery downstream is shared verbatim with
    the tree executor. The group cap is ``level_caps[-1]`` — the rows are
    distinct matches already bounded by the final AGM-style level cap, so
    the wrap itself can never overflow.
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    cover_all = tuple(sorted(int(v) for v in pattern.vertices))
    ccaps = dataclasses.replace(caps, group_cap=int(level_caps[-1]))

    def body(pt_st: PaddedPartition):
        pt = jax.tree.map(lambda x: x[0], pt_st)
        tbl, valid, o1 = je.wcoj_list(pt, plan, caps, level_caps)
        tc, _, o2 = je.compress_plain(tbl, valid, plan.cols, cover_all, ccaps)
        diag = {
            "overflow": lax.psum(o1 + o2, axes),
            "matches_lower_bound": lax.psum(jnp.sum(tc.valid.astype(_I32)), axes),
        }
        return jax.tree.map(lambda x: x[None], tc), diag

    out_specs = (_comp_spec(pattern, cover_all, P(ax)),
                 {"overflow": P(), "matches_lower_bound": P()})
    fn = jax.shard_map(body, mesh=mesh, in_specs=(partition_specs(mesh),),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def make_wcoj_init_store_step(pattern: Pattern, ord_, mesh: Mesh,
                              caps: EngineCaps, store: StoreCaps,
                              level_caps: Sequence[int]):
    """Jitted SPMD step: (WCOJ listing from :func:`make_wcoj_list_step`)
    → (:class:`MatchStore`, diag) — :func:`make_init_store_step` for the
    trivially-compressed layout.

    Identical redistribution logic, but the ownership hash runs over
    *every* column (the WCOJ storage cover is all pattern vertices) and
    the input's group dim is the listing's final level cap rather than
    the engine group cap.
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    m = _mesh_size(mesh)
    cover_all = tuple(sorted(int(v) for v in pattern.vertices))
    n_s = len(cover_all)

    def body(tc_st: CompTensors):
        tc = jax.tree.map(lambda x: x[0], tc_st)
        me = _my_index(mesh)
        g = _gather_groups(tc, axes)
        mine = g.valid & (_owner_of(g.skeleton, tuple(range(n_s)), m) == me)
        st, ovf = je.merge_groups(g.skeleton, mine, g.sets,
                                  store.group_cap, store.set_cap)
        cnt = je.count_matches_dev(st, cover_all, ord_)
        diag = {
            "count": lax.psum(cnt, axes),
            "store_groups": lax.psum(jnp.sum(st.valid.astype(_I32)), axes),
            "overflow": lax.psum(ovf, axes),
        }
        out = MatchStore(skeleton=st.skeleton, valid=st.valid, sets=st.sets)
        return jax.tree.map(lambda x: x[None], out), diag

    out_specs = (match_specs(mesh, pattern, cover_all),
                 {"count": P(), "store_groups": P(), "overflow": P()})
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(_comp_spec(pattern, cover_all, P(ax)),),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def _wcoj_seed_mask(pt2: PaddedPartition, add: jnp.ndarray, axes):
    """Per-device ``[v_cap]`` anchor-seed mask for the delta-dataflow
    WCOJ patch: the candidate set ``C1 ∪ N_{d'}(C1)`` over the inserted
    endpoints.

    Soundness: a new match contains an inserted edge ``(a, b)``, and the
    WCOJ anchor is adjacent to every other match vertex — so the anchor
    is ``a``, ``b``, or a common d'-neighbor of both, hence in
    ``C1 ∪ N_{d'}(C1)``. Both dedup caps are exact (one slot per input
    element), so the mask can never drop a candidate.
    """
    ends = add.astype(_I32).reshape(-1)
    c1_t, c1_valid, _ = je.dedup_rows(ends[:, None], ends >= 0,
                                      max(int(ends.shape[0]), 1))
    c1 = c1_t[:, 0]
    rows1 = lax.psum(je.center_adj_contrib(pt2, c1, c1_valid), axes) - 1
    cids = jnp.concatenate([jnp.where(c1_valid, c1, PAD), rows1.reshape(-1)])
    cand, _, _ = je.dedup_rows(cids[:, None], cids >= 0,
                               max(int(cids.shape[0]), 1))
    _, hit = je.lookup_sorted(cand[:, 0], pt2.vertices)
    return hit


def _delete_table(dele: jnp.ndarray) -> jnp.ndarray:
    """Normalize one replicated delete batch into the lex-sorted
    PAD-tailed ``(hi, lo)`` table :func:`~repro.dist.jax_engine.edge_probe`
    consumes.

    Factored out of the per-pattern maintain body so a fused
    multi-pattern step runs the dedup **once** and fans the table out to
    every pattern's Lemma-6.1 filter. The cap is exact (one slot per
    batch row), so nothing can drop.
    """
    dele = dele.astype(_I32)
    bad = (dele[:, 0] < 0) | (dele[:, 1] < 0)
    d_pairs = jnp.stack(
        [jnp.where(bad, PAD, jnp.minimum(dele[:, 0], dele[:, 1])),
         jnp.where(bad, PAD, jnp.maximum(dele[:, 0], dele[:, 1]))], axis=1)
    d_tbl, _, _ = je.dedup_rows(d_pairs, d_pairs[:, 0] >= 0,
                                max(d_pairs.shape[0], 1))
    return d_tbl


def _maintain_local(st: MatchStore, patch: CompTensors, d_tbl: jnp.ndarray,
                    prog: TreeProgram, store: StoreCaps, skel_pairs,
                    comp_pairs, skel_cols, caps: EngineCaps):
    """One device's filter ∘ merge ∘ count over a precomputed patch and
    delete table — the pattern-specific tail shared by
    :func:`make_maintain_step` and :func:`make_maintain_mega_step`."""
    kept, removed = je.filter_deleted_dev(
        st.as_comp(), skel_pairs, comp_pairs, d_tbl[:, 0], d_tbl[:, 1],
        store.set_cap, use_pallas=caps.use_pallas)
    merged, movf = je.merge_tables_dev(kept, patch,
                                       store.group_cap, store.set_cap)
    cnt = je.count_matches_dev(merged, skel_cols, prog.ord)
    return merged, removed, movf, cnt


def make_maintain_step(prog: TreeProgram, units: Sequence[R1Unit], mesh: Mesh,
                       caps: EngineCaps, store: StoreCaps,
                       unit_caps: Optional[StoreCaps] = None):
    """Jitted SPMD step: (Φ(d'), store, E_a, E_d) → (store', patch, diag).

    The fused per-pattern result-maintenance half of a batch update —
    ``patch ∘ filter ∘ merge ∘ count`` in one compiled step, the device
    twin of :func:`repro.core.incremental.apply_update_to_matches`:

    1. Nav-join **patch** chains over the already-updated partitions
       (:func:`_patch_body`, Lemma 6.2 + Thm. 6.1), merged onto their
       full-skeleton owners;
    2. **filter** the local store shard against ``E_d``
       (:func:`~repro.dist.jax_engine.filter_deleted_dev`, Lemma 6.1 —
       probes through the Pallas kernel behind ``caps.use_pallas``);
    3. **merge** the surviving shard with the local patch shard
       (:func:`~repro.dist.jax_engine.merge_tables_dev`) — both sides
       obey the same ownership hash, so no collective is needed;
    4. **count** on device and ``psum`` (the only thing a count-only
       caller ever pulls to host is this scalar).

    The raw patch tensors are returned too so match-delta sinks can
    materialize exactly the new rows on demand; callers that don't pull
    them pay nothing. ``diag``: ``count``, ``patch_groups``,
    ``removed_groups``, ``store_groups``, ``overflow``, plus
    ``store_overflow`` (the :class:`StoreCaps` share of ``overflow`` —
    what a store auto-resize can actually fix, so resize logic gates on
    it, not on the summed counter).

    With ``unit_caps`` the step additionally threads the persistent
    unit-table carry of this pattern: signature becomes ``(pt2, store,
    carry, dirty, add, dele) → (store', patch, carry', diag)``. The
    chain-step and seed unit tables come from the carry; only devices
    whose ``dirty`` flag (the storage step's ``part_dirty``) is set
    re-run ``unit_list`` — behind a ``lax.cond``, so a clean partition
    pays zero listing work. ``diag`` gains ``unit_refreshes``.
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)
    pattern = prog.nodes[prog.root].pattern
    skel_cols = prog.nodes[prog.root].skel_cols
    chains = _chain_plans(units, pattern, prog.cover, prog.ord)
    skel_pairs, comp_pairs = je.deleted_edge_cols(pattern, skel_cols)
    if unit_caps is not None:
        plans, names = unit_plan_registry(prog, units)
        carry_specs = unit_carry_specs(prog, units, mesh)

    def maintain(pt2, st, patch, dele):
        """filter ∘ merge ∘ count over the already-computed local patch."""
        d_tbl = _delete_table(dele)
        return _maintain_local(st, patch, d_tbl, prog, store,
                               skel_pairs, comp_pairs, skel_cols, caps)

    if unit_caps is None:
        def body(pt2_st: PaddedPartition, st_st: MatchStore,
                 add: jnp.ndarray, dele: jnp.ndarray):
            pt2 = jax.tree.map(lambda x: x[0], pt2_st)
            st = jax.tree.map(lambda x: x[0], st_st)
            patch, povf = _patch_body(pt2, add, prog, chains, mesh, caps)
            merged, removed, movf, cnt = maintain(pt2, st, patch, dele)
            diag = {
                "count": lax.psum(cnt, axes),
                "patch_groups": lax.psum(jnp.sum(patch.valid.astype(_I32)), axes),
                "removed_groups": lax.psum(removed, axes),
                "store_groups": lax.psum(jnp.sum(merged.valid.astype(_I32)), axes),
                "overflow": lax.psum(povf + movf, axes),
                "store_overflow": lax.psum(movf, axes),
            }
            out = MatchStore(skeleton=merged.skeleton, valid=merged.valid,
                             sets=merged.sets)
            return (jax.tree.map(lambda x: x[None], out),
                    jax.tree.map(lambda x: x[None], patch), diag)

        diag_specs = {"count": P(), "patch_groups": P(), "removed_groups": P(),
                      "store_groups": P(), "overflow": P(),
                      "store_overflow": P()}
        out_specs = (match_specs(mesh, pattern, prog.cover),
                     _comp_spec(pattern, prog.cover, P(ax)), diag_specs)
        fn = jax.shard_map(body, mesh=mesh,
                           in_specs=(partition_specs(mesh),
                                     match_specs(mesh, pattern, prog.cover),
                                     P(), P()),
                           out_specs=out_specs, check_vma=False)
        return jax.jit(fn)

    def body_carry(pt2_st: PaddedPartition, st_st: MatchStore, carry_st,
                   dirty_st, add: jnp.ndarray, dele: jnp.ndarray):
        pt2 = jax.tree.map(lambda x: x[0], pt2_st)
        st = jax.tree.map(lambda x: x[0], st_st)
        carry = jax.tree.map(lambda x: x[0], carry_st)
        dirty = dirty_st[0]
        carry2, rovf = lax.cond(
            dirty,
            lambda: _refresh_units(pt2, plans, prog.cover, caps, unit_caps),
            lambda: (carry, jnp.int32(0)))
        by_key = {k: carry2[n] for k, n in names.items()}
        patch, povf = _patch_body(pt2, add, prog, chains, mesh, caps,
                                  unit_tables=by_key)
        merged, removed, movf, cnt = maintain(pt2, st, patch, dele)
        diag = {
            "count": lax.psum(cnt, axes),
            "patch_groups": lax.psum(jnp.sum(patch.valid.astype(_I32)), axes),
            "removed_groups": lax.psum(removed, axes),
            "store_groups": lax.psum(jnp.sum(merged.valid.astype(_I32)), axes),
            "overflow": lax.psum(povf + movf + rovf, axes),
            "store_overflow": lax.psum(movf, axes),
            "unit_refreshes": lax.psum(dirty.astype(_I32), axes),
        }
        out = MatchStore(skeleton=merged.skeleton, valid=merged.valid,
                         sets=merged.sets)
        return (jax.tree.map(lambda x: x[None], out),
                jax.tree.map(lambda x: x[None], patch),
                jax.tree.map(lambda x: x[None], carry2), diag)

    diag_specs = {"count": P(), "patch_groups": P(), "removed_groups": P(),
                  "store_groups": P(), "overflow": P(), "store_overflow": P(),
                  "unit_refreshes": P()}
    out_specs = (match_specs(mesh, pattern, prog.cover),
                 _comp_spec(pattern, prog.cover, P(ax)), carry_specs,
                 diag_specs)
    fn = jax.shard_map(body_carry, mesh=mesh,
                       in_specs=(partition_specs(mesh),
                                 match_specs(mesh, pattern, prog.cover),
                                 carry_specs, P(ax), P(), P()),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


@dataclasses.dataclass(frozen=True)
class MaintainSpec:
    """One pattern's slot in the fused multi-pattern maintain step.

    ``name`` keys this pattern's entries in the megastep's dict-valued
    inputs/outputs; ``prog``/``units`` are its compiled join-tree
    program, ``store`` its :class:`MatchStore` caps and ``unit_caps``
    the caps of its persistent unit-table carry.

    With ``wcoj`` set the slot runs the generic-join executor instead:
    the per-batch patch is a delta-seeded :func:`~repro.dist.jax_engine.wcoj_list`
    over Φ(d') (anchor seeds restricted to ``C1 ∪ N_{d'}(C1)``, matches
    filtered to those containing an inserted edge) with per-level caps
    ``wcoj_level_caps``, and the store holds trivially-compressed rows
    (storage cover = all pattern vertices, empty sets). Such a slot
    carries no unit tables — its carry entry is an empty pytree and its
    ``unit_refreshes`` diag is always 0.
    """

    name: str
    prog: TreeProgram
    units: Tuple[R1Unit, ...]
    store: StoreCaps
    unit_caps: StoreCaps
    wcoj: Optional[WcojPlan] = None
    wcoj_level_caps: Optional[Tuple[int, ...]] = None


def make_maintain_mega_step(specs: Sequence[MaintainSpec], mesh: Mesh,
                            caps: EngineCaps, donate: bool = True):
    """One jitted SPMD step maintaining *every* registered pattern.

    Signature: ``(Φ(d'), {name: store}, {name: carry}, dirty, E_a, E_d)
    → ({name: store'}, {name: patch}, {name: carry'}, {name: diag})``.

    Semantically identical to running each pattern's carry-threaded
    :func:`make_maintain_step` back to back — every per-pattern output
    is byte-identical — but fused into a single compiled program so a
    P-pattern service pays one dispatch, one delete-table dedup
    (:func:`_delete_table`), and one shared view of the updated
    partitions per batch instead of P. XLA additionally overlaps the
    independent per-pattern pipelines inside the one program.

    Per-pattern ``diag`` carries the same keys as the single-pattern
    step (``count``/``patch_groups``/``removed_groups``/``store_groups``
    /``overflow``/``store_overflow``/``unit_refreshes``), so callers
    can attribute cost and gate per-pattern auto-resize unchanged.

    With ``donate=True`` the store and carry dicts (argnums 1 and 2) are
    donated on platforms where XLA honors donation — they are the two
    store-shaped resident buffers, so donation keeps per-batch memory
    flat instead of 2× while the step runs. Callers must then treat the
    passed-in stores/carries as **consumed**: any retry after a failed
    batch (e.g. a strict-overflow abort) has to rebuild them from
    non-donated state (the partitions) rather than re-using the inputs.
    The CPU shim (:func:`repro._jax_compat.donate_jit`) skips donation
    but the contract is exercised there too.
    """
    axes = tuple(mesh.axis_names)
    ax = _flat_axes(mesh)

    m = _mesh_size(mesh)
    pre = []
    for sp in specs:
        prog = sp.prog
        root = prog.nodes[prog.root]
        if sp.wcoj is not None:
            cover_all = tuple(sorted(int(v) for v in root.pattern.vertices))
            skel_pairs, comp_pairs = je.deleted_edge_cols(root.pattern,
                                                          cover_all)
            pre.append((sp, root.pattern, cover_all, None, skel_pairs,
                        comp_pairs, None, None))
            continue
        chains = _chain_plans(sp.units, root.pattern, prog.cover, prog.ord)
        skel_pairs, comp_pairs = je.deleted_edge_cols(root.pattern,
                                                      root.skel_cols)
        plans, names = unit_plan_registry(prog, sp.units)
        pre.append((sp, root.pattern, root.skel_cols, chains, skel_pairs,
                    comp_pairs, plans, names))
    any_wcoj = any(sp.wcoj is not None for sp in specs)

    def body(pt2_st: PaddedPartition, stores_st, carries_st, dirty_st,
             add: jnp.ndarray, dele: jnp.ndarray):
        pt2 = jax.tree.map(lambda x: x[0], pt2_st)
        dirty = dirty_st[0]
        d_tbl = _delete_table(dele)  # shared across patterns
        # One delta-candidate anchor mask shared by every WCOJ slot —
        # pattern-independent (C1 ∪ N_d'(C1) over the inserted edges).
        seed_mask = _wcoj_seed_mask(pt2, add, axes) if any_wcoj else None
        stores2, patches, carries2, diag = {}, {}, {}, {}
        for (sp, pattern, skel_cols, chains, skel_pairs, comp_pairs,
             plans, names) in pre:
            st = jax.tree.map(lambda x: x[0], stores_st[sp.name])
            carry = jax.tree.map(lambda x: x[0], carries_st[sp.name])
            if sp.wcoj is not None:
                # Delta-dataflow generic join: list — over the already
                # updated Φ(d') — exactly the matches that contain an
                # inserted edge and whose anchor is a delta candidate.
                # One pass over the full pattern, so no Thm. 6.1 dedup
                # is needed (a match with several inserted edges is
                # still listed once).
                carry2, rovf = carry, jnp.int32(0)
                me = _my_index(mesh)
                ccaps = dataclasses.replace(
                    caps, group_cap=int(sp.wcoj_level_caps[-1]))
                tbl, valid, o1 = je.wcoj_list(
                    pt2, sp.wcoj, caps, sp.wcoj_level_caps,
                    require_edges=add.astype(_I32), seed_mask=seed_mask)
                tc, _, o2 = je.compress_plain(tbl, valid, sp.wcoj.cols,
                                              skel_cols, ccaps)
                g = _gather_groups(tc, axes)
                mine = g.valid & (_owner_of(g.skeleton,
                                            tuple(range(len(skel_cols))),
                                            m) == me)
                # Store caps bound the merged shard, hence also this
                # patch shard (patch ⊆ merged) — govf is store-sized.
                patch, govf = je.merge_groups(g.skeleton, mine, g.sets,
                                              sp.store.group_cap,
                                              sp.store.set_cap)
                povf, sovf = o1 + o2, govf
            else:
                carry2, rovf = lax.cond(
                    dirty,
                    lambda pl=plans, cv=sp.prog.cover, uc=sp.unit_caps:
                        _refresh_units(pt2, pl, cv, caps, uc),
                    lambda c=carry: (c, jnp.int32(0)))
                by_key = {k: carry2[n] for k, n in names.items()}
                patch, povf = _patch_body(pt2, add, sp.prog, chains, mesh,
                                          caps, unit_tables=by_key)
                sovf = jnp.int32(0)
            merged, removed, movf, cnt = _maintain_local(
                st, patch, d_tbl, sp.prog, sp.store, skel_pairs, comp_pairs,
                skel_cols, caps)
            out = MatchStore(skeleton=merged.skeleton, valid=merged.valid,
                             sets=merged.sets)
            stores2[sp.name] = jax.tree.map(lambda x: x[None], out)
            patches[sp.name] = jax.tree.map(lambda x: x[None], patch)
            carries2[sp.name] = jax.tree.map(lambda x: x[None], carry2)
            refreshed = (jnp.int32(0) if sp.wcoj is not None
                         else dirty.astype(_I32))
            diag[sp.name] = {
                "count": lax.psum(cnt, axes),
                "patch_groups": lax.psum(jnp.sum(patch.valid.astype(_I32)),
                                         axes),
                "removed_groups": lax.psum(removed, axes),
                "store_groups": lax.psum(jnp.sum(merged.valid.astype(_I32)),
                                         axes),
                "overflow": lax.psum(povf + sovf + movf + rovf, axes),
                "store_overflow": lax.psum(sovf + movf, axes),
                "unit_refreshes": lax.psum(refreshed, axes),
            }
        return stores2, patches, carries2, diag

    per_diag = {"count": P(), "patch_groups": P(), "removed_groups": P(),
                "store_groups": P(), "overflow": P(), "store_overflow": P(),
                "unit_refreshes": P()}
    store_specs, patch_specs, carry_specs, diag_specs = {}, {}, {}, {}
    for (sp, pattern, skel_cols, *_rest) in pre:
        if sp.wcoj is not None:
            store_specs[sp.name] = match_specs(mesh, pattern, skel_cols)
            patch_specs[sp.name] = _comp_spec(pattern, skel_cols, P(ax))
            carry_specs[sp.name] = {}
        else:
            store_specs[sp.name] = match_specs(mesh, pattern, sp.prog.cover)
            patch_specs[sp.name] = _comp_spec(pattern, sp.prog.cover, P(ax))
            carry_specs[sp.name] = unit_carry_specs(sp.prog, sp.units, mesh)
        diag_specs[sp.name] = dict(per_diag)
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(partition_specs(mesh), store_specs,
                                 carry_specs, P(ax), P(), P()),
                       out_specs=(store_specs, patch_specs, carry_specs,
                                  diag_specs),
                       check_vma=False)
    if donate:
        return donate_jit(fn, (1, 2))
    return jax.jit(fn)
