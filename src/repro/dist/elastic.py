"""Elastic re-partitioning of NP storage (m → m' hosts).

When the device pool grows or shrinks, the storage must be re-cut under
a new partition count. :func:`repartition_delta` reports how much state
would move (the decision input); :func:`repartition_storage` performs
the cut. The rebuilt storage is bit-identical to building Φ(d) from
scratch at ``new_m`` (tested), so listings before/after agree exactly.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.storage import NPStorage, PartitionFn, build_np_storage

__all__ = ["repartition_delta", "repartition_storage"]


def repartition_delta(storage: NPStorage, new_m: int) -> Dict[str, int]:
    """Cost report of moving from ``m`` to ``new_m`` partitions.

    moved_centers  — vertices whose owning partition changes
    moved_edges    — directed edge stubs that must be re-shipped
                     (edges incident to a moved center)
    old_m/new_m    — partition counts
    """
    g = storage.graph
    ids = np.arange(g.n, dtype=np.int64)
    h_old = storage.h(ids)
    h_new = PartitionFn(new_m)(ids)
    moved = h_old != h_new
    return {
        "old_m": storage.m,
        "new_m": int(new_m),
        "moved_centers": int(np.count_nonzero(moved)),
        "moved_edges": int(g.degrees[moved].sum()),
    }


def repartition_storage(storage: NPStorage, new_m: int) -> NPStorage:
    """Re-cut Φ(d) at ``new_m`` parts (== fresh build at ``new_m``)."""
    return build_np_storage(storage.graph, int(new_m))
