"""Optimizer substrate (no external deps): AdamW, schedules, clipping."""

from .adamw import AdamWState, adamw_init, adamw_update, global_norm_clip
from .schedule import warmup_cosine

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm_clip", "warmup_cosine"]
