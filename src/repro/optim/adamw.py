"""Hand-rolled AdamW with fp32 moments and global-norm clipping.

Moment tensors are stored fp32 regardless of param dtype (mixed-precision
training); the launcher shards them ZeRO-1 style (extra 'data' sharding on
top of the param TP sharding) so optimizer state never replicates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm_clip"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    mu: Any
    nu: Any

    def tree_flatten(self):
        return (self.step, self.mu, self.nu), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(lambda z: z.copy(), zeros))


def global_norm_clip(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_norm: float = 1.0,
) -> Tuple[Any, AdamWState, jax.Array]:
    grads, gnorm = global_norm_clip(grads, max_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        mu_hat = mu / (1 - b1 ** t)
        nu_hat = nu / (1 - b2 ** t)
        delta = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        np_, nmu, nnu = upd(p, g, mu, nu)
        new_p.append(np_)
        new_mu.append(nmu)
        new_nu.append(nnu)
    return (
        jax.tree.unflatten(tdef, new_p),
        AdamWState(step=step, mu=jax.tree.unflatten(tdef, new_mu), nu=jax.tree.unflatten(tdef, new_nu)),
        gnorm,
    )
