"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base].

32L d_model=1536 24H (GQA kv=8) d_expert=512 vocab=49155, MoE 40e top-8.
(The brief's hf id points at the 1b-a400m variant with 32 experts; the
3b-a800m checkpoint named by the arch id has 40 experts top-8 — we follow
the name/primary field; see DESIGN.md §5.)
"""

from repro.models.transformer import TransformerConfig

from .registry import LM_SHAPES, ArchSpec

_FULL = TransformerConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab=49155,
    attn="gqa",
    moe=True,
    n_experts=40,
    top_k=8,
    n_shared=0,
    d_expert=512,
    first_dense=0,
    rope_theta=1e4,
)

_SMOKE = TransformerConfig(
    name="granite-moe-smoke",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, d_head=8, d_ff=64,
    vocab=256, attn="gqa", moe=True, n_experts=5, top_k=2, n_shared=0,
    d_expert=32, first_dense=0, remat=False, dtype="float32",
)

SPEC = ArchSpec(
    name="granite-moe-3b-a800m", family="lm",
    config=_FULL, smoke=_SMOKE, shapes=LM_SHAPES,
    notes="All-MoE layers; 40 experts over EP=16 → 2.5/shard (padded grouping).",
)
