"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse, dot interaction."""

from repro.models.dlrm import DLRMConfig

from .registry import RECSYS_SHAPES, ArchSpec

_FULL = DLRMConfig(
    name="dlrm-rm2",
    n_dense=13, n_sparse=26, embed_dim=64,
    rows_per_table=1_000_000,
    bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1),
)

_SMOKE = DLRMConfig(
    name="dlrm-smoke",
    n_dense=13, n_sparse=4, embed_dim=8, rows_per_table=128,
    bot_mlp=(16, 8), top_mlp=(16, 1),
)

SPEC = ArchSpec(
    name="dlrm-rm2", family="recsys",
    config=_FULL, smoke=_SMOKE, shapes=RECSYS_SHAPES,
    notes="Tables model-sharded on rows; lookup = take + segment_sum (EmbeddingBag built here).",
)
