"""minicpm3-4b [hf:openbmb/MiniCPM3-4B] — dense MLA.

62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64.
"""

from repro.models.transformer import TransformerConfig

from .registry import LM_SHAPES, ArchSpec

_FULL = TransformerConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=96,  # qk_nope + qk_rope
    d_ff=6400,
    vocab=73448,
    attn="mla",
    q_lora=768,
    kv_lora=256,
    qk_nope=64,
    qk_rope=32,
    v_head=64,
    rope_theta=1e4,
)

_SMOKE = TransformerConfig(
    name="minicpm3-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=24, d_ff=128,
    vocab=512, attn="mla", q_lora=48, kv_lora=32, qk_nope=16, qk_rope=8,
    v_head=16, remat=False, dtype="float32",
)

SPEC = ArchSpec(
    name="minicpm3-4b", family="lm",
    config=_FULL, smoke=_SMOKE, shapes=LM_SHAPES,
    notes="Dense MLA with q-lora; deepest assigned LM (62 layers → scan is load-bearing).",
)
