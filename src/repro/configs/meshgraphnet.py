"""meshgraphnet [arXiv:2010.03409]: 15 processor steps, d=128, sum agg."""

from repro.models.gnn import GNNConfig

from .registry import GNN_SHAPES, ArchSpec

_FULL = GNNConfig(
    name="meshgraphnet", arch="meshgraphnet",
    n_layers=15, d_hidden=128, d_in=12, d_out=3, d_edge_in=4,
    aggregator="sum", mlp_layers=2, dtype="bfloat16",
)

_SMOKE = GNNConfig(
    name="meshgraphnet-smoke", arch="meshgraphnet",
    n_layers=3, d_hidden=16, d_in=8, d_out=3, d_edge_in=4, mlp_layers=2,
)

SPEC = ArchSpec(
    name="meshgraphnet", family="gnn",
    config=_FULL, smoke=_SMOKE, shapes=GNN_SHAPES,
    notes="Edge features updated every step (encode-process-decode).",
)
