"""phi4-mini-3.8b [arXiv:2412.08905; hf] — dense GQA, RoPE + SwiGLU.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""

from repro.models.transformer import TransformerConfig

from .registry import LM_SHAPES, ArchSpec

_FULL = TransformerConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=200064,
    attn="gqa",
    rope_theta=1e4,
)

_SMOKE = TransformerConfig(
    name="phi4-mini-smoke",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, d_head=8, d_ff=96,
    vocab=512, attn="gqa", remat=False, dtype="float32",
)

SPEC = ArchSpec(
    name="phi4-mini-3.8b", family="lm",
    config=_FULL, smoke=_SMOKE, shapes=LM_SHAPES,
    notes="Vocab (200k) dominates the embedding/logit shards.",
)
