"""deepseek-v2-lite-16b [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff(dense)=10944→d_expert=1408 vocab=102400.
MLA: kv_lora=512, qk_nope=128, qk_rope=64, v_head=128 (no q-lora in Lite).
MoE: 64 routed top-6 + 2 shared experts, first layer dense.
(The assignment brief lists both "64e top-6" and "160 routed"; the HF
V2-Lite checkpoint has 64 routed — 160 belongs to full V2. We use 64;
see DESIGN.md §5.)
"""

from repro.models.transformer import TransformerConfig

from .registry import LM_SHAPES, ArchSpec

_FULL = TransformerConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=192,  # qk_nope + qk_rope
    d_ff=10944,
    vocab=102400,
    attn="mla",
    q_lora=0,
    kv_lora=512,
    qk_nope=128,
    qk_rope=64,
    v_head=128,
    moe=True,
    n_experts=64,
    top_k=6,
    n_shared=2,
    d_expert=1408,
    first_dense=1,
    rope_theta=1e4,
)

_SMOKE = TransformerConfig(
    name="deepseek-v2-lite-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=24, d_ff=128,
    vocab=512, attn="mla", q_lora=0, kv_lora=32, qk_nope=16, qk_rope=8,
    v_head=16, moe=True, n_experts=8, top_k=2, n_shared=2, d_expert=32,
    first_dense=1, remat=False, dtype="float32",
)

SPEC = ArchSpec(
    name="deepseek-v2-lite-16b", family="lm",
    config=_FULL, smoke=_SMOKE, shapes=LM_SHAPES,
    notes="MLA latent KV cache; MoE EP over 'model'; absorbed decode is a §Perf lever.",
)
