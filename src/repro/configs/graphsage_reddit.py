"""graphsage-reddit [arXiv:1706.02216]: 2L d_hidden=128 mean agg, fanout 25-10."""

from repro.models.gnn import GNNConfig

from .registry import GNN_SHAPES, ArchSpec

_FULL = GNNConfig(
    name="graphsage-reddit", arch="graphsage",
    n_layers=2, d_hidden=128, d_in=602, d_out=41, aggregator="mean",
    fanouts=(25, 10),
)

_SMOKE = GNNConfig(
    name="graphsage-smoke", arch="graphsage",
    n_layers=2, d_hidden=16, d_in=8, d_out=4, aggregator="mean", fanouts=(5, 3),
)

SPEC = ArchSpec(
    name="graphsage-reddit", family="gnn",
    config=_FULL, smoke=_SMOKE, shapes=GNN_SHAPES,
    notes="minibatch_lg uses the real NeighborSampler (fanout 25-10 per paper config).",
)
