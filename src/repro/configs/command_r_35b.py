"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no bias.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""

from repro.models.transformer import TransformerConfig

from .registry import LM_SHAPES, ArchSpec

_FULL = TransformerConfig(
    name="command-r-35b",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22528,
    vocab=256000,
    attn="gqa",
    rope_theta=1e4,
)

_SMOKE = TransformerConfig(
    name="command-r-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8, d_ff=160,
    vocab=512, attn="gqa", remat=False, dtype="float32",
)

SPEC = ArchSpec(
    name="command-r-35b", family="lm",
    config=_FULL, smoke=_SMOKE, shapes=LM_SHAPES,
    notes="Largest assigned LM (35B); ZeRO-1 optimizer sharding is required to fit.",
)
