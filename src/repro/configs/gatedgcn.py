"""gatedgcn [arXiv:2003.00982 benchmarking-gnns]: 16L d_hidden=70 gated agg."""

from repro.models.gnn import GNNConfig

from .registry import GNN_SHAPES, ArchSpec

_FULL = GNNConfig(
    name="gatedgcn", arch="gatedgcn",
    n_layers=16, d_hidden=70, d_in=128, d_out=40, aggregator="gated",
    dtype="bfloat16",
)

_SMOKE = GNNConfig(
    name="gatedgcn-smoke", arch="gatedgcn",
    n_layers=3, d_hidden=16, d_in=8, d_out=4, aggregator="gated",
)

SPEC = ArchSpec(
    name="gatedgcn", family="gnn",
    config=_FULL, smoke=_SMOKE, shapes=GNN_SHAPES,
    notes="d_in is overridden per shape (d_feat); edge gates need two segment sums.",
)
