"""Arch configs (one module per assigned architecture + the paper's own)."""

from .registry import ArchSpec, ShapeSpec, all_archs, get_arch, iter_cells

__all__ = ["ArchSpec", "ShapeSpec", "all_archs", "get_arch", "iter_cells"]
