"""The paper's own workload: DDSL subgraph listing/updating cells.

Shapes follow the experiment scales of §VII (batch sizes 10²..10⁵ on
power-law graphs); the engine caps are the static shape model derived
from the match-size estimator.
"""

import dataclasses

from repro.dist.jax_engine import EngineCaps

from .registry import ArchSpec, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DDSLWorkload:
    name: str
    pattern: str          # key into PATTERN_LIBRARY
    caps: EngineCaps
    n_add: int = 64
    n_del: int = 64


_FULL = DDSLWorkload(
    name="ddsl-paper",
    pattern="q5_house",
    caps=EngineCaps(
        v_cap=4096, deg_cap=128, e_cap=65536,
        match_cap=65536, group_cap=32768, set_cap=128, pair_cap=64,
    ),
    n_add=64, n_del=64,
)

_SMOKE = DDSLWorkload(
    name="ddsl-smoke",
    pattern="q2_triangle",
    caps=EngineCaps(v_cap=64, deg_cap=32, e_cap=256, match_cap=1024,
                    group_cap=1024, set_cap=16, pair_cap=32),
    n_add=4, n_del=4,
)

SPEC = ArchSpec(
    name="ddsl-paper", family="ddsl",
    config=_FULL, smoke=_SMOKE,
    shapes=(
        ShapeSpec(name="list_step", kind="ddsl_list"),
        ShapeSpec(name="update_step", kind="ddsl_update"),
    ),
    notes="The paper's technique as dry-run cells: stage-1 listing and stage-2 incremental update.",
)
