"""equiformer-v2 [arXiv:2306.12059]: 12L d=128 l_max=6 m_max=2 8 heads, eSCN."""

from repro.models.gnn import GNNConfig

from .registry import GNN_SHAPES, ArchSpec

_FULL = GNNConfig(
    name="equiformer-v2", arch="equiformer_v2",
    n_layers=12, d_hidden=128, d_in=16, d_out=1,
    l_max=6, m_max=2, n_heads=8, dtype="bfloat16",
)

_SMOKE = GNNConfig(
    name="equiformer-v2-smoke", arch="equiformer_v2",
    n_layers=2, d_hidden=8, d_in=6, d_out=1, l_max=2, m_max=1, n_heads=2,
)

SPEC = ArchSpec(
    name="equiformer-v2", family="gnn",
    config=_FULL, smoke=_SMOKE, shapes=GNN_SHAPES,
    notes="Wigner-D edge rotations + SO(2) per-m mixing; positions synthesized "
          "for non-geometric shapes (backbone exercise only).",
)
