"""Architecture registry: one spec per assigned arch (+ the paper's own).

Every (arch × shape) cell of the dry-run grid resolves through
:func:`get_arch` / :func:`iter_cells`.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Tuple

__all__ = ["ShapeSpec", "ArchSpec", "get_arch", "all_archs", "iter_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | full_graph | minibatch | batched_graphs
    #         | recsys_train | recsys_serve | retrieval
    seq_len: int = 0
    global_batch: int = 0
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanouts: Tuple[int, ...] = ()
    batch_graphs: int = 0
    batch: int = 0
    n_candidates: int = 0


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                  # lm | gnn | recsys | ddsl
    config: Any
    smoke: Any
    shapes: Tuple[ShapeSpec, ...]
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name}: unknown shape {name}")


_MODULES = [
    "deepseek_v2_lite_16b",
    "granite_moe_3b_a800m",
    "minicpm3_4b",
    "command_r_35b",
    "phi4_mini_3_8b",
    "gatedgcn",
    "graphsage_reddit",
    "meshgraphnet",
    "equiformer_v2",
    "dlrm_rm2",
    "ddsl_paper",
]

_REGISTRY: Dict[str, ArchSpec] = {}


def _load():
    if _REGISTRY:
        return
    for mod in _MODULES:
        m = importlib.import_module(f"repro.configs.{mod}")
        spec = m.SPEC
        _REGISTRY[spec.name] = spec


def get_arch(name: str) -> ArchSpec:
    _load()
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchSpec]:
    _load()
    return dict(_REGISTRY)


def iter_cells(include_ddsl: bool = False):
    """All (arch, shape) cells of the assignment grid."""
    _load()
    for name, spec in _REGISTRY.items():
        if spec.family == "ddsl" and not include_ddsl:
            continue
        for s in spec.shapes:
            yield spec, s


LM_SHAPES = (
    ShapeSpec(name="train_4k", kind="train", seq_len=4096, global_batch=256),
    ShapeSpec(name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32),
    ShapeSpec(name="decode_32k", kind="decode", seq_len=32768, global_batch=128),
    # long_500k lowers serve_step (1 new token against a 512k KV cache) —
    # O(L) per token, runnable for full-attention archs; a 500k *prefill*
    # would need sub-quadratic attention and is not defined here.
    ShapeSpec(name="long_500k", kind="decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeSpec(name="full_graph_sm", kind="full_graph", n_nodes=2708, n_edges=10556, d_feat=1433),
    ShapeSpec(name="minibatch_lg", kind="minibatch", n_nodes=232965, n_edges=114615892,
              batch_nodes=1024, fanouts=(15, 10), d_feat=602),
    ShapeSpec(name="ogb_products", kind="full_graph", n_nodes=2449029, n_edges=61859140, d_feat=100),
    ShapeSpec(name="molecule", kind="batched_graphs", n_nodes=30, n_edges=64, batch_graphs=128, d_feat=16),
)

RECSYS_SHAPES = (
    ShapeSpec(name="train_batch", kind="recsys_train", batch=65536),
    ShapeSpec(name="serve_p99", kind="recsys_serve", batch=512),
    ShapeSpec(name="serve_bulk", kind="recsys_serve", batch=262144),
    ShapeSpec(name="retrieval_cand", kind="retrieval", batch=1, n_candidates=1_000_000),
)
