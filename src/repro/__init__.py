"""repro — DDSL reproduction: distributed & dynamic subgraph listing.

Importing the package installs a small JAX version-compat layer (see
:mod:`repro._jax_compat`) so the modern SPMD API surface used throughout
the code (``jax.shard_map``, ``jax.sharding.AxisType``, ...) also works
on older runtimes.
"""

from . import _jax_compat

_jax_compat.install()

__version__ = "0.1.0"
