"""Fault-tolerant checkpointing.

- pytrees flatten to path-keyed arrays in a single ``.npz`` per step;
- writes are **atomic** (tmp file + rename) so a crash mid-save never
  corrupts the latest checkpoint;
- :class:`CheckpointManager` keeps the last ``keep`` steps and restores
  the newest intact one (a torn file falls back to the previous step);
- restore takes optional target shardings → ``jax.device_put`` reshards,
  which is how elastic re-scaling (different mesh shape on restart)
  re-distributes state.
"""

from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_pytree", "restore_pytree", "CheckpointManager"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes
            flat[key + "::bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save_pytree(tree, path: str) -> None:
    flat = _flatten(tree)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)  # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore_pytree(template, path: str, shardings=None):
    """Restore into the structure of ``template`` (arrays by path key)."""
    import ml_dtypes

    with np.load(path) as data:
        flat = {}
        for k in data.files:
            if k.endswith("::bf16"):
                flat[k[: -len("::bf16")]] = data[k].view(ml_dtypes.bfloat16)
            else:
                flat[k] = data[k]
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_elems, leaf in paths:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_elems)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: shape {arr.shape} != template {np.shape(leaf)}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


class CheckpointManager:
    """Keep-last-k manager with crash-safe latest-step discovery."""

    _PAT = re.compile(r"step_(\d+)\.npz$")

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _steps(self):
        out = []
        for f in os.listdir(self.dir):
            m = self._PAT.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step}.npz")

    def save(self, step: int, tree) -> str:
        p = self.path(step)
        save_pytree(tree, p)
        for s in self._steps()[: -self.keep]:
            try:
                os.unlink(self.path(s))
            except OSError:
                pass
        return p

    def restore_latest(self, template, shardings=None):
        """Restore newest intact checkpoint; torn files fall back."""
        for step in reversed(self._steps()):
            try:
                return step, restore_pytree(template, self.path(step), shardings)
            except Exception:
                continue
        return None, None
