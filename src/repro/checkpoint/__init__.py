"""Checkpointing: atomic sharded save/restore with reshard-on-load."""

from .checkpoint import CheckpointManager, restore_pytree, save_pytree

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree"]
