"""DDSL core — the paper's contribution (storage, listing, joins, updates).

Public surface:
    Graph, GraphUpdate          — data-graph substrate
    Pattern, PATTERN_LIBRARY    — pattern graphs + the paper's five queries
    build_np_storage / update_np_storage — Φ(d) (paper §III-B, Alg. 4)
    DDSL                        — end-to-end facade (initial + incremental)
"""

from .ddsl import DDSL, choose_cover
from .estimator import GraphStats, match_size_estimate
from .graph import Graph, GraphUpdate
from .join_tree import JoinTree, minimum_unit_decomposition, optimal_join_tree
from .pattern import PATTERN_LIBRARY, Pattern, R1Unit, enumerate_r1_units, symmetry_break
from .plan import JoinPlan, UnitPlan, build_unit_plan
from .storage import NPStorage, PartitionFn, build_np_storage, update_np_storage
from .unit_cache import ListingProvider, PartitionUnitCache
from .vcbc import CompressedTable, cc_join, compress_table

__all__ = [
    "DDSL",
    "choose_cover",
    "GraphStats",
    "match_size_estimate",
    "Graph",
    "GraphUpdate",
    "JoinTree",
    "minimum_unit_decomposition",
    "optimal_join_tree",
    "PATTERN_LIBRARY",
    "Pattern",
    "R1Unit",
    "enumerate_r1_units",
    "symmetry_break",
    "JoinPlan",
    "UnitPlan",
    "build_unit_plan",
    "NPStorage",
    "PartitionFn",
    "build_np_storage",
    "update_np_storage",
    "ListingProvider",
    "PartitionUnitCache",
    "CompressedTable",
    "cc_join",
    "compress_table",
]
