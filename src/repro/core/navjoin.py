"""Navigated Join (paper §VI-B) — patch-set extraction on dynamic graphs.

For each join unit ``q_i`` (under the total order of Thm. 6.1) we build a
left-deep tree with ``q_i`` as the lowest leaf, seed it with
``M_new(q_i, d', q_i)`` (unit matches forced to map ≥1 edge into
``E_a(U)``), and then repeatedly *partition-and-expand*: the running match
set is navigated to partitions (via per-vertex partition bitmaps) and
joined there against locally-listed unit matches ``M_ac(q_k, d'_j)``.

Because every unit anchor lies in the cover, the anchor is always a
skeleton column of the local table; the anchor→center constraint then
makes the per-partition join results pairwise disjoint (Lemma 3.1), so
their concatenation needs no dedup. Cross-``q_i`` duplicates are removed
by the inserted-edge total order (Thm. 6.1).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import numpy as np

from .graph import edge_codes
from .listing import list_unit_all_parts, list_unit_compressed
from .pattern import Pattern, R1Unit
from .storage import NPStorage
from .vcbc import CompressedTable, cc_join, compress_table, concat_tables

__all__ = ["NavReport", "nav_join_patch", "left_deep_order"]


@dataclasses.dataclass
class NavReport:
    """Shuffle-cost instrumentation for the Nav-join (paper's I/O terms)."""

    shipped_ints: int = 0        # match integers navigated across partitions
    local_unit_ints: int = 0     # unit matches listed locally (never shipped)
    rounds: int = 0
    patch_matches: int = 0


def left_deep_order(units: Sequence[R1Unit], first: R1Unit, cover: Sequence[int]) -> List[R1Unit]:
    """Order ``units`` into a left-deep chain starting at ``first`` with a
    non-empty cover join key at every step."""
    vc = set(cover)
    order = [first]
    placed = set(first.pattern.vertices)
    rest = [u for u in units if u is not first]
    while rest:
        nxt = next((u for u in rest if set(u.pattern.vertices) & placed & vc), None)
        if nxt is None:
            raise ValueError("units cannot form a connected left-deep tree under this cover")
        order.append(nxt)
        placed |= set(nxt.pattern.vertices)
        rest.remove(nxt)
    return order


def _partition_bitmaps(storage: NPStorage) -> np.ndarray:
    """bitmap[u] = OR of (1 << h(w)) over w ∈ N_{d'}(u) (§VI-B Match Navigation).

    Packed into int64 words; ``m ≤ 64`` uses one word (larger ``m`` falls
    back to multiple words in the JAX engine; the host engine asserts)."""
    g = storage.graph
    if storage.m > 63:
        raise ValueError("host-engine bitmaps support m ≤ 63; use the JAX engine")
    und = g.edges()
    bits = np.zeros(g.n, dtype=np.int64)
    hv_a = storage.h(und[:, 0])
    hv_b = storage.h(und[:, 1])
    np.bitwise_or.at(bits, und[:, 0], np.int64(1) << hv_b)
    np.bitwise_or.at(bits, und[:, 1], np.int64(1) << hv_a)
    return bits


def _navigation_targets(
    cur: CompressedTable,
    unit: R1Unit,
    storage: NPStorage,
    bitmaps: np.ndarray,
) -> np.ndarray:
    """For each skeleton group of ``cur``: bitmap of partitions it must visit."""
    key_cols = sorted(set(cur.skeleton_cols) & set(unit.pattern.vertices) & set(cur.cover))
    anchor = unit.anchor_in(cur.cover)
    if anchor in key_cols:
        vals = cur.skeleton[:, cur.skeleton_cols.index(anchor)]
        return (np.int64(1) << storage.h(vals)).astype(np.int64)
    out = np.full(cur.n_groups, -1, dtype=np.int64)  # all ones
    for c in key_cols:
        vals = cur.skeleton[:, cur.skeleton_cols.index(c)]
        out &= bitmaps[np.clip(vals, 0, bitmaps.shape[0] - 1)]
    return out


def nav_join_patch(
    storage: NPStorage,
    units: Sequence[R1Unit],
    pattern: Pattern,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
    inserted: np.ndarray,
    report: NavReport | None = None,
    seed_fn: Callable[[R1Unit], CompressedTable] | None = None,
    provider=None,
) -> CompressedTable:
    """Compute the deduplicated patch set ``M_new(p, d')`` (Lemma 6.2 + Thm 6.1).

    ``storage`` must already be the *updated* Φ(d'); ``inserted`` is the
    ``[k, 2]`` array of added edges ``E_a(U)``. ``seed_fn`` overrides the
    seed listing ``M_new(q_i, d', q_i)`` — the streaming scheduler passes
    a memoizing provider here so several patterns registered over the
    same graph share one seed listing per unit per batch. ``provider``
    (a :class:`repro.core.unit_cache.ListingProvider`, e.g. the
    delta-maintained :class:`~repro.core.unit_cache.PartitionUnitCache`)
    replaces the chain-step unit listings ``M_ac(q_k, d'_j)`` — the
    batch-size-independent `fixed` cost of every patch — with cached
    tables invalidated only for the partitions the update dirtied. The
    provider must be bound to the same Φ(d') (asserted).
    """
    report = report if report is not None else NavReport()
    if provider is not None and provider.storage is not storage:
        raise ValueError("listing provider is bound to a different Φ(d') "
                         "than the one being patched — call advance() first")
    ins_codes = np.sort(edge_codes(inserted)) if np.asarray(inserted).size else np.empty(0, np.int64)
    bitmaps = _partition_bitmaps(storage) if storage.m <= 63 else None

    plain_patches: List[np.ndarray] = []
    out_cols: Tuple[int, ...] | None = None

    for i, qi in enumerate(units):
        order = left_deep_order(units, qi, cover)
        # Step 2: seed — unit matches mapping ≥1 edge into E_a(U).
        if seed_fn is not None:
            cur = seed_fn(qi)
        else:
            cur = list_unit_all_parts(storage, qi, cover, ord_, require_edge_codes=ins_codes)
        # Steps 3-4: Nav-join up the left-deep chain.
        for qk in order[1:]:
            report.rounds += 1
            if bitmaps is not None and cur.n_groups:
                targets = _navigation_targets(cur, qk, storage, bitmaps)
                ints_per_group = len(cur.skeleton_cols) + sum(
                    int(np.mean(r.counts())) if r.n_groups else 0 for r in cur.comp.values()
                )
                report.shipped_ints += int(
                    sum(bin(int(t) & ((1 << storage.m) - 1)).count("1") for t in targets) * ints_per_group
                )
            anchor = qk.anchor_in(cover)
            key_cols = set(cur.skeleton_cols) & set(qk.pattern.vertices)
            anchor_cands = None
            if anchor in key_cols and cur.n_groups:
                anchor_cands = np.unique(cur.skeleton[:, cur.skeleton_cols.index(anchor)])
            pieces = []
            for pi, part in enumerate(storage.parts):
                if provider is not None:
                    uj = provider.unit_compressed(pi, qk, cover, ord_,
                                                  anchor_candidates=anchor_cands)
                else:
                    uj = list_unit_compressed(part, qk, cover, ord_,
                                              anchor_candidates=anchor_cands)
                report.local_unit_ints += uj.storage_ints()
                if uj.n_groups == 0:
                    continue
                piece = cc_join(cur, uj, ord_)
                if piece.n_groups:
                    pieces.append(piece)
            if pieces:
                cur = concat_tables(pieces)
            else:
                cur = compress_table(cur.pattern.union(qk.pattern), cover,
                                     tuple(sorted(cur.pattern.union(qk.pattern).vertices)),
                                     np.empty((0, len(cur.pattern.union(qk.pattern).vertices)), np.int64))
                break

        # Step 5 (Thm. 6.1): dedup — drop matches that already map an edge of
        # an earlier unit q_j (j < i) to an inserted edge.
        cols, table = cur.decompress(ord_)
        out_cols = cols
        if table.shape[0] and i > 0 and ins_codes.size:
            col_of = {c: j for j, c in enumerate(cols)}
            dup = np.zeros(table.shape[0], dtype=bool)
            for qj in units[:i]:
                for a, b in qj.pattern.edges:
                    fa, fb = table[:, col_of[a]], table[:, col_of[b]]
                    lo, hi = np.minimum(fa, fb), np.maximum(fa, fb)
                    q = (lo << np.int64(32)) | hi
                    pos = np.clip(np.searchsorted(ins_codes, q), 0, ins_codes.shape[0] - 1)
                    dup |= ins_codes[pos] == q
            table = table[~dup]
        plain_patches.append(table)

    merged = (
        np.concatenate([t for t in plain_patches if t.shape[0]], axis=0)
        if any(t.shape[0] for t in plain_patches)
        else np.empty((0, pattern.n), np.int64)
    )
    report.patch_matches = int(merged.shape[0])
    return compress_table(pattern, cover, out_cols or tuple(sorted(pattern.vertices)), merged)
