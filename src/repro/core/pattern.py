"""Pattern graphs, automorphisms, symmetry breaking, vertex covers, R1 units.

Patterns are tiny (|V| ≤ 10) labeled graphs. Subpatterns arising in the
join-tree DP reuse the *parent's vertex labels*, so a subpattern is
identified exactly by its ``(vertices, edges)`` frozensets — no canonical
form needed (paper §V, Alg. 3).

Symmetry breaking (SimB, paper §II-B) follows Grochow–Kellis: repeatedly
pick the vertex with the largest orbit under the current automorphism
stabilizer, order it before its orbit, and descend into the stabilizer.
The resulting partial order ``ord`` admits exactly one valid match per
subgraph instance of ``p``.
"""

from __future__ import annotations

import dataclasses
import itertools
from functools import lru_cache
from typing import Dict, FrozenSet, Iterable, List, Sequence, Tuple

__all__ = [
    "Pattern",
    "automorphisms",
    "symmetry_break",
    "linear_extension_count",
    "vertex_covers",
    "connected_vertex_covers",
    "R1Unit",
    "enumerate_r1_units",
    "PATTERN_LIBRARY",
]

Edge = Tuple[int, int]


def _norm_edge(e: Sequence[int]) -> Edge:
    a, b = int(e[0]), int(e[1])
    if a == b:
        raise ValueError(f"self loop {e}")
    return (a, b) if a < b else (b, a)


@dataclasses.dataclass(frozen=True)
class Pattern:
    """An undirected pattern graph over explicit vertex labels."""

    vertices: Tuple[int, ...]
    edges: FrozenSet[Edge]

    @staticmethod
    def make(edges: Iterable[Sequence[int]], vertices: Iterable[int] | None = None) -> "Pattern":
        es = frozenset(_norm_edge(e) for e in edges)
        vs = set(vertices) if vertices is not None else set()
        for a, b in es:
            vs.add(a)
            vs.add(b)
        return Pattern(vertices=tuple(sorted(vs)), edges=es)

    # ------------------------------------------------------------------ views
    @property
    def n(self) -> int:
        return len(self.vertices)

    @property
    def m(self) -> int:
        return len(self.edges)

    def key(self) -> Tuple[Tuple[int, ...], Tuple[Edge, ...]]:
        return (self.vertices, tuple(sorted(self.edges)))

    def neighbors(self, v: int) -> Tuple[int, ...]:
        out = [b if a == v else a for a, b in self.edges if v in (a, b)]
        return tuple(sorted(out))

    def degree(self, v: int) -> int:
        return len(self.neighbors(v))

    def has_edge(self, a: int, b: int) -> bool:
        return _norm_edge((a, b)) in self.edges

    def adjacency(self) -> Dict[int, Tuple[int, ...]]:
        return {v: self.neighbors(v) for v in self.vertices}

    # ------------------------------------------------------------ operations
    def union(self, other: "Pattern") -> "Pattern":
        return Pattern(
            vertices=tuple(sorted(set(self.vertices) | set(other.vertices))),
            edges=self.edges | other.edges,
        )

    def induced(self, vs: Iterable[int]) -> "Pattern":
        vset = set(vs)
        return Pattern(
            vertices=tuple(sorted(vset)),
            edges=frozenset(e for e in self.edges if e[0] in vset and e[1] in vset),
        )

    def is_connected(self) -> bool:
        if not self.vertices:
            return True
        adj = self.adjacency()
        seen = {self.vertices[0]}
        stack = [self.vertices[0]]
        while stack:
            v = stack.pop()
            for w in adj[v]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return len(seen) == len(self.vertices)

    def is_subpattern_of(self, other: "Pattern") -> bool:
        return set(self.vertices) <= set(other.vertices) and self.edges <= other.edges

    def __repr__(self) -> str:  # pragma: no cover
        return f"Pattern(V={list(self.vertices)}, E={sorted(self.edges)})"


# ---------------------------------------------------------------------------
# Automorphisms and symmetry breaking
# ---------------------------------------------------------------------------

def automorphisms(p: Pattern) -> List[Dict[int, int]]:
    """All automorphisms of ``p`` (brute force with degree pruning; |V| ≤ 10)."""
    vs = list(p.vertices)
    deg = {v: p.degree(v) for v in vs}
    # Group vertices by degree to prune the permutation search.
    by_deg: Dict[int, List[int]] = {}
    for v in vs:
        by_deg.setdefault(deg[v], []).append(v)

    autos: List[Dict[int, int]] = []

    def backtrack(i: int, mapping: Dict[int, int], used: set) -> None:
        if i == len(vs):
            autos.append(dict(mapping))
            return
        v = vs[i]
        for w in by_deg[deg[v]]:
            if w in used:
                continue
            ok = True
            for u in vs[:i]:
                if p.has_edge(v, u) != p.has_edge(w, mapping[u]):
                    ok = False
                    break
            if ok:
                mapping[v] = w
                used.add(w)
                backtrack(i + 1, mapping, used)
                used.discard(w)
                del mapping[v]

    backtrack(0, {}, set())
    return autos


def symmetry_break(p: Pattern) -> Tuple[Tuple[int, int], ...]:
    """Compute the SimB partial order ``ord`` = tuple of (a, b) meaning a ≺ b.

    Guarantees exactly one ord-valid match per subgraph instance of ``p``.
    """
    conditions: List[Tuple[int, int]] = []
    group = automorphisms(p)
    while len(group) > 1:
        # Orbit sizes under the current stabilizer subgroup.
        orbits: Dict[int, set] = {}
        for v in p.vertices:
            orbits[v] = {g[v] for g in group}
        v = max(p.vertices, key=lambda x: (len(orbits[x]), -x))
        for u in sorted(orbits[v]):
            if u != v:
                conditions.append((v, u))
        group = [g for g in group if g[v] == v]
    return tuple(conditions)


def _restrict_ord(ord_: Sequence[Tuple[int, int]], vs: Iterable[int]) -> Tuple[Tuple[int, int], ...]:
    vset = set(vs)
    return tuple((a, b) for a, b in ord_ if a in vset and b in vset)


@lru_cache(maxsize=4096)
def _lec_cached(n: int, rel: Tuple[Tuple[int, int], ...]) -> int:
    # Subset DP over linear extensions of a partial order on n elements.
    preds = [0] * n
    for a, b in rel:
        preds[b] |= 1 << a
    full = (1 << n) - 1
    dp = [0] * (1 << n)
    dp[0] = 1
    for mask in range(1 << n):
        if not dp[mask]:
            continue
        for x in range(n):
            bit = 1 << x
            if mask & bit:
                continue
            if preds[x] & ~mask:
                continue
            dp[mask | bit] += dp[mask]
    return dp[full]


def linear_extension_count(vertices: Sequence[int], ord_: Sequence[Tuple[int, int]]) -> int:
    """#linear extensions of ``ord_`` restricted to ``vertices``.

    The estimator's symmetry correction is ``L(ord|_q) / |V(q)|!`` — for a
    SimB-complete order on ``p`` this equals ``1 / |Aut(p)|`` (the paper's
    ``|Auto(p, ord)| / |Auto(p, ∅)|`` term), and it generalizes smoothly to
    subpatterns whose automorphisms are only partially broken.
    """
    vs = sorted(set(vertices))
    idx = {v: i for i, v in enumerate(vs)}
    rel = tuple(sorted((idx[a], idx[b]) for a, b in _restrict_ord(ord_, vs)))
    return _lec_cached(len(vs), rel)


# ---------------------------------------------------------------------------
# Vertex covers
# ---------------------------------------------------------------------------

def vertex_covers(p: Pattern) -> List[Tuple[int, ...]]:
    """All vertex covers of ``p`` (inclusion-ordered, |V| ≤ 10 ⇒ ≤ 1024 subsets)."""
    vs = list(p.vertices)
    covers = []
    for r in range(len(vs) + 1):
        for sub in itertools.combinations(vs, r):
            sset = set(sub)
            if all(a in sset or b in sset for a, b in p.edges):
                covers.append(tuple(sub))
    return covers


def connected_vertex_covers(p: Pattern) -> List[Tuple[int, ...]]:
    """Vertex covers whose induced subgraph ``p[V_c]`` is connected (Lemma 4.2)."""
    return [c for c in vertex_covers(p) if c and p.induced(c).is_connected()]


# ---------------------------------------------------------------------------
# R1 units (paper §III-A)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class R1Unit:
    """A radius-1 join unit: ``anchor`` is adjacent to every other vertex."""

    pattern: Pattern
    anchors: Tuple[int, ...]  # every vertex adjacent to all others

    @property
    def anchor(self) -> int:
        return self.anchors[0]

    def anchor_in(self, vc: Iterable[int]) -> int | None:
        """Return an anchor contained in ``vc`` (CC condition 3) or None."""
        vset = set(vc)
        for a in self.anchors:
            if a in vset:
                return a
        return None


def _unit_anchors(p: Pattern) -> Tuple[int, ...]:
    out = []
    vset = set(p.vertices)
    for v in p.vertices:
        if set(p.neighbors(v)) | {v} == vset:
            out.append(v)
    return tuple(out)


def enumerate_r1_units(p: Pattern, max_size: int | None = None) -> List[R1Unit]:
    """All R1 units inside ``p``: induced subgraphs ``p[{v} ∪ S]``, S ⊆ N(v).

    Induced subgraphs carry the maximum number of ``p``-edges, which makes
    them maximally selective join units; their union still only needs to
    cover ``E(p)``.
    """
    seen: Dict[Tuple, R1Unit] = {}
    for v in p.vertices:
        nb = p.neighbors(v)
        limit = len(nb) if max_size is None else min(len(nb), max_size - 1)
        for r in range(1, limit + 1):
            for sub in itertools.combinations(nb, r):
                q = p.induced((v,) + sub)
                anchors = _unit_anchors(q)
                if not anchors:
                    continue
                k = q.key()
                if k not in seen:
                    seen[k] = R1Unit(pattern=q, anchors=anchors)
    return list(seen.values())


# ---------------------------------------------------------------------------
# The paper's five benchmark patterns (Fig. 5): square, triangle,
# square-with-diagonal ("house base"), 4-clique, and the 5-vertex "house".
# Exact shapes follow the common choices of [11], [8], [12].
# ---------------------------------------------------------------------------

PATTERN_LIBRARY: Dict[str, Pattern] = {
    # q1: 4-cycle (square)
    "q1_square": Pattern.make([(0, 1), (1, 2), (2, 3), (3, 0)]),
    # q2: triangle
    "q2_triangle": Pattern.make([(0, 1), (1, 2), (2, 0)]),
    # q3: 4-cycle with one diagonal
    "q3_diamond": Pattern.make([(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)]),
    # q4: 4-clique
    "q4_clique4": Pattern.make([(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]),
    # q5: house — 4-cycle + roof triangle
    "q5_house": Pattern.make([(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)]),
    # q6: 5-clique — the dense pattern where the WCOJ executor mode wins
    "q6_clique5": Pattern.make([(a, b) for a in range(5) for b in range(a + 1, 5)]),
}
