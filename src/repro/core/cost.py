"""Join-tree cost model (paper §V, Eq. 10/11).

``S(p_i)`` — the storage (in integers) of the *compressed* match set of a
(sub)pattern ``p_i`` under the global cover — is bounded by
``S_skeleton^max + S_compress^max`` (Thm. 4.1 terms):

    S(p_i) = |V_c ∩ V_i| · E|M(p_i[V_c ∩ V_i], d)|
           + (|V_i| − |V_c ∩ V_i|) · E|M(p_i, d)|

Tree cost (recursive form, Eq. 11):

    Cost(q)  = S(q)                                   (join unit)
    Cost(p)  = Cost(pˡ) + Cost(pʳ) + 5·S(pˡ) + 5·S(pʳ) + S(p)

The constant terms of Eq. 10 (reading Φ(d), final decompression) do not
depend on the tree and are exposed separately.
"""

from __future__ import annotations

from typing import Sequence, Tuple

from .estimator import GraphStats, match_size_estimate, skeleton_size_estimate
from .pattern import Pattern

__all__ = ["storage_estimate", "CostModel"]


def storage_estimate(
    pattern: Pattern,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
    stats: GraphStats,
) -> float:
    vset = set(pattern.vertices)
    vc = [v for v in cover if v in vset]
    n_skel = len(vc)
    n_comp = pattern.n - n_skel
    skel = skeleton_size_estimate(pattern, cover, ord_, stats)
    full = match_size_estimate(pattern, ord_, stats)
    return n_skel * skel + n_comp * full


class CostModel:
    """Memoized S(·) + Eq. 11 combinator for the DP (Alg. 3)."""

    def __init__(self, cover: Sequence[int], ord_: Sequence[Tuple[int, int]], stats: GraphStats):
        self.cover = tuple(sorted(cover))
        self.ord_ = tuple(ord_)
        self.stats = stats
        self._s_cache: dict = {}

    def storage(self, pattern: Pattern) -> float:
        k = pattern.key()
        if k not in self._s_cache:
            self._s_cache[k] = storage_estimate(pattern, self.cover, self.ord_, self.stats)
        return self._s_cache[k]

    def leaf_cost(self, unit_pattern: Pattern) -> float:
        return self.storage(unit_pattern)

    def join_cost(self, parent: Pattern, left: Pattern, right: Pattern,
                  cost_left: float, cost_right: float) -> float:
        return (
            cost_left
            + cost_right
            + 5.0 * self.storage(left)
            + 5.0 * self.storage(right)
            + self.storage(parent)
        )

    def constant_terms(self, pattern: Pattern, storage_phi: float) -> float:
        """The tree-independent terms of Eq. 10."""
        full = match_size_estimate(pattern, self.ord_, self.stats)
        return storage_phi + 2.0 * self.storage(pattern) + pattern.n * full
