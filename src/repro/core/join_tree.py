"""Optimal join tree via dynamic programming (paper Alg. 3).

The DP processes subpatterns in ascending edge count. At round ``r`` every
pattern with exactly ``r`` edges is *finalized* from (a) join units with
``r`` edges and (b) unions ``A ∪ B`` of already-finalized patterns whose
join key ``V(A) ∩ V(B) ∩ V_c(p)`` is non-empty (Lemma 4.2 feasibility).
Children always have strictly fewer edges than the union, so by strong
induction every finalized entry carries its minimum Eq.-11 cost
(Lemma 5.1).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from .cost import CostModel
from .pattern import Pattern, R1Unit, enumerate_r1_units

__all__ = ["JoinTree", "optimal_join_tree", "minimum_unit_decomposition"]


@dataclasses.dataclass
class JoinTree:
    pattern: Pattern
    cost: float
    unit: Optional[R1Unit] = None            # set on leaves
    left: Optional["JoinTree"] = None
    right: Optional["JoinTree"] = None

    @property
    def is_leaf(self) -> bool:
        return self.unit is not None

    def leaves(self) -> List[R1Unit]:
        if self.is_leaf:
            return [self.unit]
        return self.left.leaves() + self.right.leaves()

    def internal_nodes(self) -> List[Pattern]:
        if self.is_leaf:
            return []
        return self.left.internal_nodes() + self.right.internal_nodes() + [self.pattern]

    def depth(self) -> int:
        if self.is_leaf:
            return 1
        return 1 + max(self.left.depth(), self.right.depth())

    def describe(self, indent: int = 0) -> str:
        pad = "  " * indent
        if self.is_leaf:
            return f"{pad}unit V={list(self.pattern.vertices)} anchor={self.unit.anchor} cost={self.cost:.3g}"
        out = f"{pad}join V={list(self.pattern.vertices)} |E|={self.pattern.m} cost={self.cost:.3g}\n"
        out += self.left.describe(indent + 1) + "\n"
        out += self.right.describe(indent + 1)
        return out


@dataclasses.dataclass
class _Entry:
    cost: float
    unit: Optional[R1Unit]
    left: Optional[Tuple]
    right: Optional[Tuple]


def optimal_join_tree(
    p: Pattern,
    cover: Sequence[int],
    model: CostModel,
    max_unit_size: int | None = None,
) -> JoinTree:
    """Alg. 3 — returns the minimum-estimated-cost join tree for ``p``."""
    vc = set(cover)
    units = [u for u in enumerate_r1_units(p, max_size=max_unit_size) if u.anchor_in(vc) is not None]
    if not units:
        raise ValueError("no R1 unit has an anchor inside the cover; pick another cover")

    best: Dict[Tuple, _Entry] = {}
    by_edges: Dict[int, List[Tuple]] = {}

    def consider(key: Tuple, entry: _Entry) -> None:
        cur = best.get(key)
        if cur is None or entry.cost < cur.cost:
            best[key] = entry

    unit_by_key = {}
    for u in units:
        unit_by_key.setdefault(u.pattern.key(), u)

    patterns: Dict[Tuple, Pattern] = {u.pattern.key(): u.pattern for u in units}
    target = p.key()
    max_edges = p.m

    finalized: Dict[Tuple, _Entry] = {}
    for r in range(1, max_edges + 1):
        # (a) units with exactly r edges
        for key, u in unit_by_key.items():
            if patterns[key].m == r:
                consider(key, _Entry(cost=model.leaf_cost(patterns[key]), unit=u, left=None, right=None))
        # (b) unions of finalized pairs with exactly r edges
        fin_keys = list(finalized.keys())
        for ka, kb in itertools.combinations_with_replacement(fin_keys, 2):
            if ka == kb:
                continue
            pa, pb = patterns[ka], patterns[kb]
            if not (set(pa.vertices) & set(pb.vertices) & vc):
                continue
            pu = pa.union(pb)
            if pu.m != r:
                continue
            ku = pu.key()
            if ku == ka or ku == kb:
                continue
            patterns.setdefault(ku, pu)
            cost = model.join_cost(pu, pa, pb, finalized[ka].cost, finalized[kb].cost)
            consider(ku, _Entry(cost=cost, unit=None, left=ka, right=kb))
        # finalize everything with exactly r edges
        for key, entry in list(best.items()):
            if patterns[key].m == r and key not in finalized:
                finalized[key] = entry
                by_edges.setdefault(r, []).append(key)
            elif patterns[key].m == r and entry.cost < finalized[key].cost:
                finalized[key] = entry

    if target not in finalized:
        raise ValueError("pattern is not coverable by R1 units under this cover")

    def build(key: Tuple) -> JoinTree:
        e = finalized[key]
        if e.unit is not None:
            return JoinTree(pattern=patterns[key], cost=e.cost, unit=e.unit)
        return JoinTree(
            pattern=patterns[key], cost=e.cost,
            left=build(e.left), right=build(e.right),
        )

    return build(target)


# ---------------------------------------------------------------------------
# Minimum-cardinality unit decomposition for Nav-join left-deep trees (§VI-B:
# "the optimal left-deep tree is the one involving the minimum number of join
# units"), with the join-key connectivity constraint at every prefix.
# ---------------------------------------------------------------------------

def minimum_unit_decomposition(
    p: Pattern,
    cover: Sequence[int],
    max_unit_size: int | None = None,
) -> List[R1Unit]:
    vc = set(cover)
    units = [u for u in enumerate_r1_units(p, max_size=max_unit_size) if u.anchor_in(vc) is not None]
    # Prefer large units (they cover more edges); exact search over subset
    # sizes — pattern edge counts are tiny.
    units.sort(key=lambda u: -u.pattern.m)
    all_edges = p.edges
    for k in range(1, len(units) + 1):
        for combo in itertools.combinations(units, k):
            covered = frozenset().union(*[u.pattern.edges for u in combo]) if combo else frozenset()
            if covered != all_edges:
                continue
            ordered = _orderable(list(combo), vc)
            if ordered is not None:
                return ordered
    raise ValueError("pattern cannot be decomposed into cover-anchored R1 units")


def _orderable(units: List[R1Unit], vc: set) -> List[R1Unit] | None:
    """Order units so every prefix-join has a non-empty cover join key."""
    for first in units:
        order = [first]
        rest = [u for u in units if u is not first]
        placed = set(first.pattern.vertices)
        ok = True
        while rest:
            nxt = None
            for u in rest:
                if set(u.pattern.vertices) & placed & vc:
                    nxt = u
                    break
            if nxt is None:
                ok = False
                break
            order.append(nxt)
            placed |= set(nxt.pattern.vertices)
            rest.remove(nxt)
        if ok:
            return order
    return None
