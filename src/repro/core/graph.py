"""Undirected, unlabeled data-graph substrate (paper §II-A, §II-C).

The host-side representation is an immutable CSR over ``int64`` vertex
ids with sorted adjacency rows plus a sorted array of *edge codes*
(``(min(u,v) << 32) | max(u,v)``) for O(log E) edge-membership tests.
Everything downstream (NP storage, match engine, incremental updates)
builds on this module.

Batch updates follow §II-C: a :class:`GraphUpdate` carries ``E_d`` (edges
to delete) and ``E_a`` (edges to add); vertex insertion/deletion is
subsumed by edge updates on a connected graph.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Graph",
    "GraphUpdate",
    "edge_codes",
    "decode_edges",
]

_SHIFT = np.int64(32)


def edge_codes(edges: np.ndarray) -> np.ndarray:
    """Fuse an ``[m, 2]`` edge array into sorted-endpoint int64 codes."""
    edges = np.asarray(edges, dtype=np.int64)
    if edges.size == 0:
        return np.empty((0,), dtype=np.int64)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    return (lo << _SHIFT) | hi


def decode_edges(codes: np.ndarray) -> np.ndarray:
    """Inverse of :func:`edge_codes` → ``[m, 2]`` with lo in column 0."""
    codes = np.asarray(codes, dtype=np.int64)
    lo = codes >> _SHIFT
    hi = codes & np.int64(0xFFFFFFFF)
    return np.stack([lo, hi], axis=1)


@dataclasses.dataclass(frozen=True)
class GraphUpdate:
    """A batch update ``U = (E_d(U), E_a(U))`` (paper §II-C)."""

    delete: np.ndarray  # [k, 2] int64
    add: np.ndarray  # [l, 2] int64

    @staticmethod
    def make(delete: Iterable[Sequence[int]] = (), add: Iterable[Sequence[int]] = ()) -> "GraphUpdate":
        d = np.asarray(list(delete), dtype=np.int64).reshape(-1, 2)
        a = np.asarray(list(add), dtype=np.int64).reshape(-1, 2)
        return GraphUpdate(delete=d, add=a)

    @property
    def size(self) -> int:
        return int(self.delete.shape[0] + self.add.shape[0])

    def delete_codes(self) -> np.ndarray:
        return np.sort(edge_codes(self.delete))

    def add_codes(self) -> np.ndarray:
        return np.sort(edge_codes(self.add))

    def touched_vertices(self) -> np.ndarray:
        both = np.concatenate([self.delete.reshape(-1), self.add.reshape(-1)])
        return np.unique(both)


class Graph:
    """Immutable undirected graph in CSR form.

    Attributes
    ----------
    n:        number of vertices (ids are ``0..n-1``; isolated ids allowed).
    indptr:   ``int64[n + 1]`` CSR row pointers.
    indices:  ``int64[2 * m]`` sorted neighbor lists.
    codes:    ``int64[m]`` sorted unique edge codes.
    """

    __slots__ = ("n", "indptr", "indices", "codes", "_degrees")

    def __init__(self, n: int, indptr: np.ndarray, indices: np.ndarray, codes: np.ndarray):
        self.n = int(n)
        self.indptr = indptr
        self.indices = indices
        self.codes = codes
        self._degrees = np.diff(indptr)

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_edges(edges: np.ndarray | Iterable[Sequence[int]], n: int | None = None) -> "Graph":
        edges = np.asarray(list(edges) if not isinstance(edges, np.ndarray) else edges, dtype=np.int64)
        edges = edges.reshape(-1, 2)
        # Drop self loops, dedup symmetric pairs.
        edges = edges[edges[:, 0] != edges[:, 1]]
        codes = np.unique(edge_codes(edges)) if edges.size else np.empty((0,), np.int64)
        und = decode_edges(codes)
        if n is None:
            n = int(und.max()) + 1 if und.size else 0
        return Graph._from_codes(int(n), codes)

    @staticmethod
    def _from_codes(n: int, codes: np.ndarray) -> "Graph":
        und = decode_edges(codes)
        src = np.concatenate([und[:, 0], und[:, 1]])
        dst = np.concatenate([und[:, 1], und[:, 0]])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(n, indptr, dst, codes)

    # ------------------------------------------------------------------ views
    @property
    def num_edges(self) -> int:
        return int(self.codes.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return self._degrees

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]

    def edges(self) -> np.ndarray:
        return decode_edges(self.codes)

    def has_edges(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Vectorized edge membership for aligned id arrays."""
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        lo = np.minimum(u, v)
        hi = np.maximum(u, v)
        q = (lo << _SHIFT) | hi
        pos = np.searchsorted(self.codes, q)
        pos = np.clip(pos, 0, self.codes.shape[0] - 1) if self.codes.size else pos
        if not self.codes.size:
            return np.zeros(q.shape, dtype=bool)
        return self.codes[pos] == q

    def degree_histogram(self) -> np.ndarray:
        """``hist[w]`` = #vertices with degree ``w`` (used by the PR estimator)."""
        if self.n == 0:
            return np.zeros(1, dtype=np.int64)
        return np.bincount(self._degrees)

    # -------------------------------------------------------------- triangles
    def triangle_count(self) -> int:
        """Exact triangle count Δ(d) via the degree-ordered forward algorithm.

        Used for the NP-storage space bound ``min(3·Δ(d), (m-1)·|E(d)|)``
        (paper §III-B).
        """
        return int(self.triangles_per_edge().sum()) // 3

    def triangles_per_edge(self) -> np.ndarray:
        """For each edge (by ``codes`` order) the number of common neighbors."""
        und = decode_edges(self.codes)
        out = np.zeros(und.shape[0], dtype=np.int64)
        for i in range(und.shape[0]):
            a, b = und[i]
            na = self.neighbors(int(a))
            nb = self.neighbors(int(b))
            if na.shape[0] > nb.shape[0]:
                na, nb = nb, na
            pos = np.searchsorted(nb, na)
            pos = np.clip(pos, 0, nb.shape[0] - 1)
            out[i] = int(np.count_nonzero(nb[pos] == na)) if nb.size else 0
        return out

    def common_neighbors(self, a: int, b: int) -> np.ndarray:
        na = self.neighbors(a)
        nb = self.neighbors(b)
        if na.shape[0] > nb.shape[0]:
            na, nb = nb, na
        if nb.size == 0:
            return na[:0]
        pos = np.clip(np.searchsorted(nb, na), 0, nb.shape[0] - 1)
        return na[nb[pos] == na]

    # ---------------------------------------------------------------- updates
    def apply_update(self, update: GraphUpdate) -> "Graph":
        """Return ``d' = d ⊖ E_d ⊕ E_a`` (ids may grow ``n``)."""
        del_codes = update.delete_codes()
        add_codes = update.add_codes()
        keep = self.codes[~np.isin(self.codes, del_codes)] if del_codes.size else self.codes
        merged = np.unique(np.concatenate([keep, add_codes])) if add_codes.size else keep
        n = self.n
        if update.add.size:
            n = max(n, int(update.add.max()) + 1)
        return Graph._from_codes(n, merged)

    # ------------------------------------------------------------------ misc
    def subgraph_codes(self, vertices: np.ndarray) -> np.ndarray:
        """Edge codes of the induced subgraph ``d[vertices]``."""
        vset = np.sort(np.asarray(vertices, dtype=np.int64))
        und = decode_edges(self.codes)
        lo_in = np.searchsorted(vset, und[:, 0])
        hi_in = np.searchsorted(vset, und[:, 1])
        lo_ok = (lo_in < vset.size) & (vset[np.clip(lo_in, 0, vset.size - 1)] == und[:, 0])
        hi_ok = (hi_in < vset.size) & (vset[np.clip(hi_in, 0, vset.size - 1)] == und[:, 1])
        return self.codes[lo_ok & hi_ok]

    def __repr__(self) -> str:  # pragma: no cover
        return f"Graph(n={self.n}, m={self.num_edges})"
