"""Vertex-Cover-Based Compression (VCBC, paper §IV) and the CC-join (Alg. 2).

A :class:`CompressedTable` stores matches of a (sub)pattern grouped by
*skeleton* — the assignment of the vertices in ``V_c(p) ∩ V(p_i)``. Each
non-cover ("compressed") vertex maps to a ragged per-group vertex set.

The CC-join operates directly on this form:

- join key  = assignments of ``V_c(p) ∩ V(p₁) ∩ V(p₂)``;
- skeleton  = union of the two skeletons (+ injectivity / ord filters);
- shared compressed vertices → per-pair set intersection;
- one-sided compressed vertices → carried over, filtered against the
  new skeleton columns (injectivity + ord).

Edge constraints never need re-checking at join time: every edge of
``p₃ = p₁ ∪ p₂`` lies inside the side that contributed it (Thm. 4.3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import plan as plan_ir
from .match_engine import ragged_expand
from .pattern import Pattern

__all__ = [
    "Ragged",
    "CompressedTable",
    "compress_table",
    "cc_join",
    "concat_tables",
    "r_lower",
]


@dataclasses.dataclass
class Ragged:
    """Per-group sorted value sets: group g owns ``values[offsets[g]:offsets[g+1]]``."""

    offsets: np.ndarray  # int64 [g + 1]
    values: np.ndarray   # int64 [total]

    @property
    def n_groups(self) -> int:
        return int(self.offsets.shape[0] - 1)

    def counts(self) -> np.ndarray:
        return np.diff(self.offsets)

    @staticmethod
    def from_group_ids(gids: np.ndarray, values: np.ndarray, n_groups: int) -> "Ragged":
        order = np.lexsort((values, gids))
        gids, values = gids[order], values[order]
        offsets = np.zeros(n_groups + 1, dtype=np.int64)
        np.add.at(offsets, gids + 1, 1)
        return Ragged(offsets=np.cumsum(offsets), values=values)

    def fused(self) -> np.ndarray:
        """``gid << 32 | value`` — sorted; supports batched membership tests."""
        gids = np.repeat(np.arange(self.n_groups, dtype=np.int64), self.counts())
        return (gids << np.int64(32)) | self.values


@dataclasses.dataclass
class CompressedTable:
    """Compressed matches ``{f|s}`` of ``pattern`` under the global cover."""

    pattern: Pattern
    cover: Tuple[int, ...]              # global V_c(p) (full-pattern labels)
    skeleton_cols: Tuple[int, ...]      # sorted(V_c(p) ∩ V(pattern))
    skeleton: np.ndarray                # int64 [g, n_skel_cols]
    comp: Dict[int, Ragged]             # compressed vertex label → per-group sets

    # ------------------------------------------------------------------ stats
    @property
    def n_groups(self) -> int:
        return int(self.skeleton.shape[0])

    def storage_ints(self) -> int:
        """The paper's integer-count storage metric S(p_i)."""
        total = self.n_groups * len(self.skeleton_cols)
        for r in self.comp.values():
            total += int(r.values.shape[0])
        return total

    def _expand_vertex(self, table, gids, cols, v, ord_, materialize=True):
        """Expand one compressed vertex with injectivity + ord filtering.

        Returns ``(table', gids')`` when ``materialize`` else only the
        surviving row count (skipping the concatenate, the expensive
        part of the final expansion step).
        """
        r = self.comp[v]
        starts = r.offsets[gids]
        counts = r.offsets[gids + 1] - starts
        rep, vals = ragged_expand(starts, counts, r.values)
        tb = table[rep]
        mask = np.ones(vals.shape[0], dtype=bool)
        for j, c in enumerate(cols):
            mask &= vals != tb[:, j]  # injectivity
            for a, b in ord_:
                if (a, b) == (v, c):
                    mask &= vals < tb[:, j]
                elif (a, b) == (c, v):
                    mask &= vals > tb[:, j]
        if not materialize:
            return int(np.count_nonzero(mask))
        return (np.concatenate([tb[mask], vals[mask][:, None]], axis=1),
                gids[rep][mask])

    def count_matches(self, ord_: Sequence[Tuple[int, int]] = ()) -> int:
        """|M| without materializing the decompressed table.

        Same expansion as :meth:`decompress` but the last (largest) step
        only counts — matters when the streaming service polls counts of
        multi-million-row match sets every batch.
        """
        comp_vs = sorted(self.comp.keys())
        if not comp_vs:
            return self.n_groups
        cols = list(self.skeleton_cols)
        table = self.skeleton
        gids = np.arange(self.n_groups, dtype=np.int64)
        for v in comp_vs[:-1]:
            table, gids = self._expand_vertex(table, gids, cols, v, ord_)
            cols.append(v)
        return self._expand_vertex(table, gids, cols, comp_vs[-1], ord_,
                                   materialize=False)

    # ------------------------------------------------------------ decompress
    def decompress(self, ord_: Sequence[Tuple[int, int]] = ()) -> Tuple[Tuple[int, ...], np.ndarray]:
        """Cartesian-expand per group with injectivity + ord filtering (§IV-B)."""
        comp_vs = sorted(self.comp.keys())
        cols = list(self.skeleton_cols)
        table = self.skeleton
        gids = np.arange(self.n_groups, dtype=np.int64)
        for v in comp_vs:
            table, gids = self._expand_vertex(table, gids, cols, v, ord_)
            cols.append(v)
        out_cols = tuple(sorted(self.pattern.vertices))
        perm = [cols.index(c) for c in out_cols]
        return out_cols, (table[:, perm] if table.size else np.empty((0, len(out_cols)), np.int64))


def compress_table(
    pattern: Pattern,
    cover: Sequence[int],
    cols: Sequence[int],
    table: np.ndarray,
) -> CompressedTable:
    """Group a plain match table by its skeleton columns (§IV-A)."""
    cover = tuple(sorted(cover))
    vset = set(pattern.vertices)
    skel_cols = tuple(c for c in sorted(cover) if c in vset)
    comp_cols = tuple(c for c in sorted(pattern.vertices) if c not in skel_cols)
    col_of = {c: i for i, c in enumerate(cols)}
    skel = table[:, [col_of[c] for c in skel_cols]] if table.shape[0] else np.empty((0, len(skel_cols)), np.int64)
    if table.shape[0] == 0:
        return CompressedTable(
            pattern=pattern, cover=cover, skeleton_cols=skel_cols,
            skeleton=skel,
            comp={c: Ragged(np.zeros(1, np.int64), np.empty(0, np.int64)) for c in comp_cols},
        )
    uniq, inv = np.unique(skel, axis=0, return_inverse=True)
    comp = {}
    for c in comp_cols:
        vals = table[:, col_of[c]]
        # dedup (group, value) pairs
        fused = (inv.astype(np.int64) << np.int64(32)) | vals
        fu = np.unique(fused)
        g = fu >> np.int64(32)
        vv = fu & np.int64(0xFFFFFFFF)
        comp[c] = Ragged.from_group_ids(g, vv, uniq.shape[0])
    return CompressedTable(pattern=pattern, cover=cover, skeleton_cols=skel_cols, skeleton=uniq, comp=comp)


def concat_tables(tables: List[CompressedTable]) -> CompressedTable:
    """Union of compressed tables of the *same* pattern (e.g. per-partition
    ``M_ac`` shards, which are disjoint by Lemma 3.1)."""
    assert tables, "need at least one table"
    t0 = tables[0]
    if len(tables) == 1:
        return t0
    skel = np.concatenate([t.skeleton for t in tables], axis=0)
    comp: Dict[int, Ragged] = {}
    offset = 0
    parts: Dict[int, List[Tuple[np.ndarray, np.ndarray]]] = {v: [] for v in t0.comp}
    for t in tables:
        for v, r in t.comp.items():
            gids = np.repeat(np.arange(r.n_groups, dtype=np.int64), r.counts()) + offset
            parts[v].append((gids, r.values))
        offset += t.n_groups
    for v, chunks in parts.items():
        g = np.concatenate([c[0] for c in chunks]) if chunks else np.empty(0, np.int64)
        vv = np.concatenate([c[1] for c in chunks]) if chunks else np.empty(0, np.int64)
        comp[v] = Ragged.from_group_ids(g, vv, skel.shape[0])
    return CompressedTable(pattern=t0.pattern, cover=t0.cover, skeleton_cols=t0.skeleton_cols, skeleton=skel, comp=comp)


# ---------------------------------------------------------------------------
# CC-join (Alg. 2)
# ---------------------------------------------------------------------------

def _key_ids(k1: np.ndarray, k2: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Dense integer ids for multi-column join keys across both sides."""
    both = np.concatenate([k1, k2], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    return inv[: k1.shape[0]].astype(np.int64), inv[k1.shape[0] :].astype(np.int64)


def _apply_value_checks(
    vals: np.ndarray,
    pair_rows: np.ndarray,
    s3: np.ndarray,
    checks,
) -> np.ndarray:
    """Per-value validity vs the new skeleton columns (plan-IR checks)."""
    mask = np.ones(vals.shape[0], dtype=bool)
    for col_idx, mode in checks:
        col = s3[pair_rows, col_idx]
        if mode == plan_ir.NEQ:
            mask &= vals != col
        elif mode == plan_ir.LT:
            mask &= vals < col
        else:
            mask &= vals > col
    return mask


def cc_join(
    t1: CompressedTable,
    t2: CompressedTable,
    ord_: Sequence[Tuple[int, int]] = (),
    plan: "plan_ir.JoinPlan | None" = None,
) -> CompressedTable:
    """Join two consistently-compressed tables (paper Alg. 2).

    The join structure (key columns, output skeleton, cross-side masks,
    per-compressed-vertex value checks) comes from the shared
    :class:`repro.core.plan.JoinPlan` IR — the same plan the device
    engine (``repro.dist.jax_engine.ccjoin_local``) executes.
    """
    assert t1.cover == t2.cover, "CC-join requires a shared global cover"
    if plan is None:
        plan = plan_ir.JoinPlan.make(t1.pattern, t2.pattern, t1.cover, ord_)
    assert plan.left_skel == t1.skeleton_cols and plan.right_skel == t2.skeleton_cols
    s3_cols = plan.skel_out

    k1 = t1.skeleton[:, list(plan.key_left_idx)]
    k2 = t2.skeleton[:, list(plan.key_right_idx)]
    id1, id2 = _key_ids(k1, k2)

    # Sort side-2 groups by key id and pair every side-1 group with the
    # matching contiguous run (repeat/gather — the MapReduce shuffle analog).
    order2 = np.argsort(id2, kind="stable")
    id2s = id2[order2]
    starts = np.searchsorted(id2s, id1, side="left")
    ends = np.searchsorted(id2s, id1, side="right")
    rep1, pos2 = ragged_expand(starts, ends - starts, order2)
    # rep1: row into t1.skeleton; pos2: row into t2.skeleton

    # --- assemble the joined skeleton ----------------------------------------
    s3 = np.empty((rep1.shape[0], len(s3_cols)), dtype=np.int64)
    for out_j, left_j in plan.out_from_left:
        s3[:, out_j] = t1.skeleton[rep1, left_j]
    for out_j, right_j in plan.out_from_right:
        s3[:, out_j] = t2.skeleton[pos2, right_j]

    # injectivity across the two skeleton halves + cross-side ord pairs
    mask = np.ones(s3.shape[0], dtype=bool)
    for ja, jb in plan.pair_neq:
        mask &= s3[:, ja] != s3[:, jb]
    for ja, jb in plan.pair_ord:
        mask &= s3[:, ja] < s3[:, jb]
    rep1, pos2, s3 = rep1[mask], pos2[mask], s3[mask]
    n_pairs = s3.shape[0]

    # --- compressed vertices --------------------------------------------------
    comp: Dict[int, Ragged] = {}
    for cp in plan.comp:
        v = cp.vertex
        if cp.source == "both":
            r1, r2 = t1.comp[v], t2.comp[v]
            st = r1.offsets[rep1]
            ct = r1.offsets[rep1 + 1] - st
            prow, vals = ragged_expand(st, ct, r1.values)
            # membership in side-2 set of the paired group
            fused_set = (np.repeat(np.arange(r2.n_groups, dtype=np.int64), r2.counts()) << np.int64(32)) | r2.values
            q = (pos2[prow] << np.int64(32)) | vals
            pos = np.clip(np.searchsorted(fused_set, q), 0, max(fused_set.shape[0] - 1, 0))
            keep = fused_set[pos] == q if fused_set.size else np.zeros(q.shape, bool)
            prow, vals = prow[keep], vals[keep]
        elif cp.source == "left":
            r1 = t1.comp[v]
            st = r1.offsets[rep1]
            ct = r1.offsets[rep1 + 1] - st
            prow, vals = ragged_expand(st, ct, r1.values)
        else:
            r2 = t2.comp[v]
            st = r2.offsets[pos2]
            ct = r2.offsets[pos2 + 1] - st
            prow, vals = ragged_expand(st, ct, r2.values)
        keep = _apply_value_checks(vals, prow, s3, cp.checks)
        comp[v] = Ragged.from_group_ids(prow[keep], vals[keep], n_pairs)

    out = CompressedTable(pattern=plan.pattern, cover=t1.cover, skeleton_cols=s3_cols, skeleton=s3, comp=comp)
    return _drop_empty_groups(out)


def _drop_empty_groups(t: CompressedTable) -> CompressedTable:
    """Remove skeleton rows where any compressed vertex has an empty set."""
    if not t.comp or t.n_groups == 0:
        return t
    alive = np.ones(t.n_groups, dtype=bool)
    for r in t.comp.values():
        alive &= r.counts() > 0
    if alive.all():
        return t
    keep = np.nonzero(alive)[0]
    remap = -np.ones(t.n_groups, dtype=np.int64)
    remap[keep] = np.arange(keep.shape[0])
    comp = {}
    for v, r in t.comp.items():
        gids = np.repeat(np.arange(r.n_groups, dtype=np.int64), r.counts())
        sel = alive[gids]
        comp[v] = Ragged.from_group_ids(remap[gids[sel]], r.values[sel], keep.shape[0])
    return CompressedTable(
        pattern=t.pattern, cover=t.cover, skeleton_cols=t.skeleton_cols,
        skeleton=t.skeleton[keep], comp=comp,
    )


# ---------------------------------------------------------------------------
# Compression-ratio lower bound (Thm. 4.1)
# ---------------------------------------------------------------------------

def r_lower(n_pattern: int, n_cover: int, m_pattern: float, m_cover: float) -> float:
    """``R_lower`` from Thm. 4.1 given |V(p)|, |V_c(p)|, |M(p,d)|, |M(p[V_c],d)|."""
    num = n_pattern * m_pattern
    den = n_pattern * m_pattern + n_cover * max(m_cover - m_pattern, 0.0)
    return float(num / den) if den > 0 else 1.0
