"""Vertex-Cover-Based Compression (VCBC, paper §IV) and the CC-join (Alg. 2).

A :class:`CompressedTable` stores matches of a (sub)pattern grouped by
*skeleton* — the assignment of the vertices in ``V_c(p) ∩ V(p_i)``. Each
non-cover ("compressed") vertex maps to a ragged per-group vertex set.

The CC-join operates directly on this form:

- join key  = assignments of ``V_c(p) ∩ V(p₁) ∩ V(p₂)``;
- skeleton  = union of the two skeletons (+ injectivity / ord filters);
- shared compressed vertices → per-pair set intersection;
- one-sided compressed vertices → carried over, filtered against the
  new skeleton columns (injectivity + ord).

Edge constraints never need re-checking at join time: every edge of
``p₃ = p₁ ∪ p₂`` lies inside the side that contributed it (Thm. 4.3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .match_engine import ragged_expand
from .pattern import Pattern

__all__ = [
    "Ragged",
    "CompressedTable",
    "compress_table",
    "cc_join",
    "concat_tables",
    "r_lower",
]


@dataclasses.dataclass
class Ragged:
    """Per-group sorted value sets: group g owns ``values[offsets[g]:offsets[g+1]]``."""

    offsets: np.ndarray  # int64 [g + 1]
    values: np.ndarray   # int64 [total]

    @property
    def n_groups(self) -> int:
        return int(self.offsets.shape[0] - 1)

    def counts(self) -> np.ndarray:
        return np.diff(self.offsets)

    @staticmethod
    def from_group_ids(gids: np.ndarray, values: np.ndarray, n_groups: int) -> "Ragged":
        order = np.lexsort((values, gids))
        gids, values = gids[order], values[order]
        offsets = np.zeros(n_groups + 1, dtype=np.int64)
        np.add.at(offsets, gids + 1, 1)
        return Ragged(offsets=np.cumsum(offsets), values=values)

    def fused(self) -> np.ndarray:
        """``gid << 32 | value`` — sorted; supports batched membership tests."""
        gids = np.repeat(np.arange(self.n_groups, dtype=np.int64), self.counts())
        return (gids << np.int64(32)) | self.values


@dataclasses.dataclass
class CompressedTable:
    """Compressed matches ``{f|s}`` of ``pattern`` under the global cover."""

    pattern: Pattern
    cover: Tuple[int, ...]              # global V_c(p) (full-pattern labels)
    skeleton_cols: Tuple[int, ...]      # sorted(V_c(p) ∩ V(pattern))
    skeleton: np.ndarray                # int64 [g, n_skel_cols]
    comp: Dict[int, Ragged]             # compressed vertex label → per-group sets

    # ------------------------------------------------------------------ stats
    @property
    def n_groups(self) -> int:
        return int(self.skeleton.shape[0])

    def storage_ints(self) -> int:
        """The paper's integer-count storage metric S(p_i)."""
        total = self.n_groups * len(self.skeleton_cols)
        for r in self.comp.values():
            total += int(r.values.shape[0])
        return total

    def count_matches(self, ord_: Sequence[Tuple[int, int]] = ()) -> int:
        cols, table = self.decompress(ord_)
        return int(table.shape[0])

    # ------------------------------------------------------------ decompress
    def decompress(self, ord_: Sequence[Tuple[int, int]] = ()) -> Tuple[Tuple[int, ...], np.ndarray]:
        """Cartesian-expand per group with injectivity + ord filtering (§IV-B)."""
        comp_vs = sorted(self.comp.keys())
        cols = list(self.skeleton_cols)
        table = self.skeleton
        gids = np.arange(self.n_groups, dtype=np.int64)
        for v in comp_vs:
            r = self.comp[v]
            starts = r.offsets[gids]
            counts = r.offsets[gids + 1] - starts
            rep, vals = ragged_expand(starts, counts, r.values)
            table = table[rep]
            gids = gids[rep]
            mask = np.ones(vals.shape[0], dtype=bool)
            for j, c in enumerate(cols):
                mask &= vals != table[:, j]  # injectivity
                for a, b in ord_:
                    if (a, b) == (v, c):
                        mask &= vals < table[:, j]
                    elif (a, b) == (c, v):
                        mask &= vals > table[:, j]
            table = np.concatenate([table[mask], vals[mask][:, None]], axis=1)
            gids = gids[mask]
            cols.append(v)
        out_cols = tuple(sorted(self.pattern.vertices))
        perm = [cols.index(c) for c in out_cols]
        return out_cols, (table[:, perm] if table.size else np.empty((0, len(out_cols)), np.int64))


def compress_table(
    pattern: Pattern,
    cover: Sequence[int],
    cols: Sequence[int],
    table: np.ndarray,
) -> CompressedTable:
    """Group a plain match table by its skeleton columns (§IV-A)."""
    cover = tuple(sorted(cover))
    vset = set(pattern.vertices)
    skel_cols = tuple(c for c in sorted(cover) if c in vset)
    comp_cols = tuple(c for c in sorted(pattern.vertices) if c not in skel_cols)
    col_of = {c: i for i, c in enumerate(cols)}
    skel = table[:, [col_of[c] for c in skel_cols]] if table.shape[0] else np.empty((0, len(skel_cols)), np.int64)
    if table.shape[0] == 0:
        return CompressedTable(
            pattern=pattern, cover=cover, skeleton_cols=skel_cols,
            skeleton=skel,
            comp={c: Ragged(np.zeros(1, np.int64), np.empty(0, np.int64)) for c in comp_cols},
        )
    uniq, inv = np.unique(skel, axis=0, return_inverse=True)
    comp = {}
    for c in comp_cols:
        vals = table[:, col_of[c]]
        # dedup (group, value) pairs
        fused = (inv.astype(np.int64) << np.int64(32)) | vals
        fu = np.unique(fused)
        g = fu >> np.int64(32)
        vv = fu & np.int64(0xFFFFFFFF)
        comp[c] = Ragged.from_group_ids(g, vv, uniq.shape[0])
    return CompressedTable(pattern=pattern, cover=cover, skeleton_cols=skel_cols, skeleton=uniq, comp=comp)


def concat_tables(tables: List[CompressedTable]) -> CompressedTable:
    """Union of compressed tables of the *same* pattern (e.g. per-partition
    ``M_ac`` shards, which are disjoint by Lemma 3.1)."""
    assert tables, "need at least one table"
    t0 = tables[0]
    if len(tables) == 1:
        return t0
    skel = np.concatenate([t.skeleton for t in tables], axis=0)
    comp: Dict[int, Ragged] = {}
    offset = 0
    parts: Dict[int, List[Tuple[np.ndarray, np.ndarray]]] = {v: [] for v in t0.comp}
    for t in tables:
        for v, r in t.comp.items():
            gids = np.repeat(np.arange(r.n_groups, dtype=np.int64), r.counts()) + offset
            parts[v].append((gids, r.values))
        offset += t.n_groups
    for v, chunks in parts.items():
        g = np.concatenate([c[0] for c in chunks]) if chunks else np.empty(0, np.int64)
        vv = np.concatenate([c[1] for c in chunks]) if chunks else np.empty(0, np.int64)
        comp[v] = Ragged.from_group_ids(g, vv, skel.shape[0])
    return CompressedTable(pattern=t0.pattern, cover=t0.cover, skeleton_cols=t0.skeleton_cols, skeleton=skel, comp=comp)


# ---------------------------------------------------------------------------
# CC-join (Alg. 2)
# ---------------------------------------------------------------------------

def _key_ids(k1: np.ndarray, k2: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Dense integer ids for multi-column join keys across both sides."""
    both = np.concatenate([k1, k2], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    return inv[: k1.shape[0]].astype(np.int64), inv[k1.shape[0] :].astype(np.int64)


def _filter_values(
    vals: np.ndarray,
    pair_rows: np.ndarray,
    skeleton: np.ndarray,
    cols: Tuple[int, ...],
    check_cols: Sequence[int],
    v: int,
    ord_: Sequence[Tuple[int, int]],
) -> np.ndarray:
    """Per-value validity vs the (new) skeleton columns: injectivity + ord."""
    mask = np.ones(vals.shape[0], dtype=bool)
    idx = {c: j for j, c in enumerate(cols)}
    for c in check_cols:
        col = skeleton[pair_rows, idx[c]]
        mask &= vals != col
        for a, b in ord_:
            if (a, b) == (v, c):
                mask &= vals < col
            elif (a, b) == (c, v):
                mask &= vals > col
    return mask


def cc_join(
    t1: CompressedTable,
    t2: CompressedTable,
    ord_: Sequence[Tuple[int, int]] = (),
) -> CompressedTable:
    """Join two consistently-compressed tables (paper Alg. 2)."""
    assert t1.cover == t2.cover, "CC-join requires a shared global cover"
    p3 = t1.pattern.union(t2.pattern)
    v1, v2 = set(t1.pattern.vertices), set(t2.pattern.vertices)
    key_cols = tuple(sorted(set(t1.skeleton_cols) & set(t2.skeleton_cols)))
    s3_cols = tuple(sorted(set(t1.skeleton_cols) | set(t2.skeleton_cols)))

    i1 = [t1.skeleton_cols.index(c) for c in key_cols]
    i2 = [t2.skeleton_cols.index(c) for c in key_cols]
    k1 = t1.skeleton[:, i1]
    k2 = t2.skeleton[:, i2]
    id1, id2 = _key_ids(k1, k2)

    # Sort side-2 groups by key id and pair every side-1 group with the
    # matching contiguous run (repeat/gather — the MapReduce shuffle analog).
    order2 = np.argsort(id2, kind="stable")
    id2s = id2[order2]
    starts = np.searchsorted(id2s, id1, side="left")
    ends = np.searchsorted(id2s, id1, side="right")
    rep1, pos2 = ragged_expand(starts, ends - starts, order2)
    # rep1: row into t1.skeleton; pos2: row into t2.skeleton

    # --- assemble the joined skeleton ----------------------------------------
    s3 = np.empty((rep1.shape[0], len(s3_cols)), dtype=np.int64)
    c1 = {c: j for j, c in enumerate(t1.skeleton_cols)}
    c2 = {c: j for j, c in enumerate(t2.skeleton_cols)}
    for j, c in enumerate(s3_cols):
        if c in c1:
            s3[:, j] = t1.skeleton[rep1, c1[c]]
        else:
            s3[:, j] = t2.skeleton[pos2, c2[c]]

    # injectivity across the two skeleton halves + cross-side ord pairs
    mask = np.ones(s3.shape[0], dtype=bool)
    only1 = [c for c in t1.skeleton_cols if c not in c2]
    only2 = [c for c in t2.skeleton_cols if c not in c1]
    j3 = {c: j for j, c in enumerate(s3_cols)}
    for a in only1:
        for b in only2:
            mask &= s3[:, j3[a]] != s3[:, j3[b]]
    for a, b in ord_:
        if a in j3 and b in j3 and not (
            (a in c1 and b in c1) or (a in c2 and b in c2)
        ):
            mask &= s3[:, j3[a]] < s3[:, j3[b]]
    rep1, pos2, s3 = rep1[mask], pos2[mask], s3[mask]
    n_pairs = s3.shape[0]

    # --- compressed vertices --------------------------------------------------
    comp: Dict[int, Ragged] = {}
    comp3 = sorted((v1 | v2) - set(s3_cols))
    pair_ids = np.arange(n_pairs, dtype=np.int64)
    for v in comp3:
        in1, in2 = v in t1.comp, v in t2.comp
        if in1 and in2:
            r1, r2 = t1.comp[v], t2.comp[v]
            st = r1.offsets[rep1]
            ct = r1.offsets[rep1 + 1] - st
            prow, vals = ragged_expand(st, ct, r1.values)
            # membership in side-2 set of the paired group
            fused_set = (np.repeat(np.arange(r2.n_groups, dtype=np.int64), r2.counts()) << np.int64(32)) | r2.values
            q = (pos2[prow] << np.int64(32)) | vals
            pos = np.clip(np.searchsorted(fused_set, q), 0, max(fused_set.shape[0] - 1, 0))
            keep = fused_set[pos] == q if fused_set.size else np.zeros(q.shape, bool)
            prow, vals = prow[keep], vals[keep]
            new1, new2 = only2, only1  # both sides see the other's new columns
            keep = _filter_values(vals, prow, s3, s3_cols, new1 + new2, v, ord_)
        elif in1:
            r1 = t1.comp[v]
            st = r1.offsets[rep1]
            ct = r1.offsets[rep1 + 1] - st
            prow, vals = ragged_expand(st, ct, r1.values)
            keep = _filter_values(vals, prow, s3, s3_cols, only2, v, ord_)
        else:
            r2 = t2.comp[v]
            st = r2.offsets[pos2]
            ct = r2.offsets[pos2 + 1] - st
            prow, vals = ragged_expand(st, ct, r2.values)
            keep = _filter_values(vals, prow, s3, s3_cols, only1, v, ord_)
        comp[v] = Ragged.from_group_ids(prow[keep], vals[keep], n_pairs)

    out = CompressedTable(pattern=p3, cover=t1.cover, skeleton_cols=s3_cols, skeleton=s3, comp=comp)
    return _drop_empty_groups(out)


def _drop_empty_groups(t: CompressedTable) -> CompressedTable:
    """Remove skeleton rows where any compressed vertex has an empty set."""
    if not t.comp or t.n_groups == 0:
        return t
    alive = np.ones(t.n_groups, dtype=bool)
    for r in t.comp.values():
        alive &= r.counts() > 0
    if alive.all():
        return t
    keep = np.nonzero(alive)[0]
    remap = -np.ones(t.n_groups, dtype=np.int64)
    remap[keep] = np.arange(keep.shape[0])
    comp = {}
    for v, r in t.comp.items():
        gids = np.repeat(np.arange(r.n_groups, dtype=np.int64), r.counts())
        sel = alive[gids]
        comp[v] = Ragged.from_group_ids(remap[gids[sel]], r.values[sel], keep.shape[0])
    return CompressedTable(
        pattern=t.pattern, cover=t.cover, skeleton_cols=t.skeleton_cols,
        skeleton=t.skeleton[keep], comp=comp,
    )


# ---------------------------------------------------------------------------
# Compression-ratio lower bound (Thm. 4.1)
# ---------------------------------------------------------------------------

def r_lower(n_pattern: int, n_cover: int, m_pattern: float, m_cover: float) -> float:
    """``R_lower`` from Thm. 4.1 given |V(p)|, |V_c(p)|, |M(p,d)|, |M(p[V_c],d)|."""
    num = n_pattern * m_pattern
    den = n_pattern * m_pattern + n_cover * max(m_cover - m_pattern, 0.0)
    return float(num / den) if den > 0 else 1.0
