"""Unit Match Listing (paper Alg. 1) and full-tree initial calculation.

``list_unit_compressed`` lists the anchor-center-constrained matches
``M_ac(q, d_j)`` of an R1 unit from one NP partition and groups them into
the consistently-compressed (CC) form under the global cover.
``execute_join_tree`` then runs the optimal join tree bottom-up with
:func:`~repro.core.vcbc.cc_join`, producing the compressed ``M(p, d)``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .join_tree import JoinTree
from .match_engine import list_matches
from .pattern import R1Unit
from .storage import NPStorage, Partition
from .vcbc import CompressedTable, cc_join, compress_table, concat_tables

__all__ = ["list_unit_compressed", "list_unit_all_parts", "execute_join_tree", "ExecutionReport"]


@dataclasses.dataclass
class ExecutionReport:
    """I/O-cost instrumentation mirroring Eq. 10's terms (integer counts)."""

    unit_ints: int = 0          # Σ S(q) over leaves
    intermediate_ints: int = 0  # Σ S(p_i) over internal nodes (excl. root)
    root_ints: int = 0          # S(p)
    joins: int = 0

    def total_join_cost(self) -> int:
        # Eq. 10 rearrangement: 6·S for every non-root node + S(root),
        # ignoring the tree-independent constants.
        return 6 * (self.unit_ints + self.intermediate_ints) + self.root_ints


def list_unit_compressed(
    part: Partition,
    unit: R1Unit,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
    *,
    require_edge_codes: np.ndarray | None = None,
    anchor_candidates: np.ndarray | None = None,
) -> CompressedTable:
    """Alg. 1: compressed ``M_ac(q, d_j)`` listed directly from Φ(d)."""
    anchor = unit.anchor_in(cover)
    if anchor is None:
        raise ValueError("unit anchor must lie inside the cover (CC condition 3)")
    cols, table = list_matches(
        part,
        unit.pattern,
        ord_,
        anchor=anchor,
        anchor_to_centers=True,
        require_edge_codes=require_edge_codes,
    )
    if anchor_candidates is not None and table.shape[0]:
        keep = np.isin(table[:, cols.index(anchor)], anchor_candidates)
        table = table[keep]
    return compress_table(unit.pattern, cover, cols, table)


def list_unit_all_parts(
    storage: NPStorage,
    unit: R1Unit,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
    *,
    require_edge_codes: np.ndarray | None = None,
) -> CompressedTable:
    """Union over partitions — complete & duplicate-free by Lemma 3.1."""
    tables = [
        list_unit_compressed(p, unit, cover, ord_, require_edge_codes=require_edge_codes)
        for p in storage.parts
    ]
    return concat_tables(tables)


def execute_join_tree(
    storage: NPStorage,
    tree: JoinTree,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
    report: ExecutionReport | None = None,
) -> CompressedTable:
    """Bottom-up execution of the optimal join tree (initial calculation)."""
    report = report if report is not None else ExecutionReport()

    def run(node: JoinTree, is_root: bool) -> CompressedTable:
        if node.is_leaf:
            t = list_unit_all_parts(storage, node.unit, cover, ord_)
            report.unit_ints += t.storage_ints()
            return t
        lt = run(node.left, False)
        rt = run(node.right, False)
        out = cc_join(lt, rt, ord_)
        report.joins += 1
        if is_root:
            report.root_ints += out.storage_ints()
        else:
            report.intermediate_ints += out.storage_ints()
        return out

    return run(tree, True)
