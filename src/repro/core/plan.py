"""Backend-agnostic listing/join plan IR — one source of truth for both
executors.

The host engine (:mod:`repro.core.match_engine`, NumPy, ragged) and the
device engine (:mod:`repro.dist.jax_engine`, JAX, padded static shapes)
execute the *same* plans:

- :class:`UnitPlan` describes anchored frontier-table listing of one R1
  join unit: the extension order, and per extension step the pivot
  column, extra edge checks, symmetry-breaking (``ord``) comparisons and
  the degree-prune threshold (MC₁).
- :class:`JoinPlan` describes one CC-join (paper Alg. 2) between two
  consistently-compressed tables under the shared global cover: join-key
  columns, output skeleton layout, cross-side injectivity/ord masks, and
  per compressed-vertex value checks.

Everything in a plan is a small hashable tuple of Python ints, so plans
can be closed over by jitted device programs and interpreted directly by
the NumPy executor. Neither executor re-derives pattern structure.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from .pattern import Pattern

__all__ = [
    "ExtendStep",
    "UnitPlan",
    "build_unit_plan",
    "WcojLevel",
    "WcojPlan",
    "build_wcoj_plan",
    "wcoj_anchors",
    "wcoj_eligible",
    "ValueCheck",
    "CompVertexPlan",
    "JoinPlan",
    "plan_extension_order",
    "NEQ",
    "LT",
    "GT",
]

# Value-check modes: candidate value `x` against a skeleton column `s`.
NEQ = 0  # x != s   (injectivity)
LT = 1   # x <  s   (ord: candidate-vertex ≺ skeleton-vertex)
GT = 2   # x >  s   (ord: skeleton-vertex ≺ candidate-vertex)


def plan_extension_order(pattern: Pattern, start: int) -> List[int]:
    """Vertex matching order: ``start`` first, then greedy max-connectivity
    (ties: higher pattern degree, then lower label)."""
    order = [start]
    rest = [v for v in pattern.vertices if v != start]
    while rest:
        def score(v):
            conn = sum(1 for u in order if pattern.has_edge(u, v))
            return (conn, pattern.degree(v), -v)

        nxt = max(rest, key=score)
        if not any(pattern.has_edge(u, nxt) for u in order):
            raise ValueError("pattern must be connected for frontier listing")
        order.append(nxt)
        rest.remove(nxt)
    return order


@dataclasses.dataclass(frozen=True)
class ExtendStep:
    """One frontier extension: place ``vertex`` from the pivot's adjacency."""

    vertex: int
    pivot: int                                  # prefix column index to expand from
    edge_checks: Tuple[int, ...]                # prefix column indices needing an edge test
    ord_checks: Tuple[Tuple[int, bool], ...]    # (prefix col idx, candidate_must_be_greater)
    min_degree: int                             # MC₁ degree prune threshold


@dataclasses.dataclass(frozen=True)
class UnitPlan:
    """Listing plan of one anchored R1 unit (paper Alg. 1 substrate)."""

    pattern: Pattern
    anchor: int
    order: Tuple[int, ...]                      # extension order; order[0] == anchor
    steps: Tuple[ExtendStep, ...]               # len == |V| - 1
    edge_cols: Tuple[Tuple[int, int], ...]      # pattern edges as (col_i, col_j) pairs
    anchor_min_degree: int

    @property
    def cols(self) -> Tuple[int, ...]:
        """Column labels of the produced match table (== extension order)."""
        return self.order


def _ord_pairs_for(ord_: Sequence[Tuple[int, int]], new_v: int, placed: Sequence[int]):
    placed_idx = {u: j for j, u in enumerate(placed)}
    out = []
    for a, b in ord_:
        if a == new_v and b in placed_idx:
            out.append((placed_idx[b], False))   # f(new) < f(b)
        elif b == new_v and a in placed_idx:
            out.append((placed_idx[a], True))    # f(a) < f(new)
    return tuple(out)


def build_unit_plan(
    pattern: Pattern,
    anchor: int | None,
    ord_: Sequence[Tuple[int, int]] = (),
) -> UnitPlan:
    """Compile an anchored listing plan for ``pattern``.

    ``anchor`` seeds the frontier (for ``M_ac`` it must lie in the cover
    and be an R1 anchor); ``None`` falls back to the max-degree vertex.
    """
    if pattern.m == 0:
        raise ValueError("pattern needs ≥1 edge")
    start = anchor if anchor is not None else max(pattern.vertices, key=pattern.degree)
    order = plan_extension_order(pattern, start)
    steps = []
    for i in range(1, len(order)):
        v = order[i]
        placed = order[:i]
        nbr_cols = tuple(j for j, u in enumerate(placed) if pattern.has_edge(u, v))
        steps.append(ExtendStep(
            vertex=v,
            pivot=nbr_cols[0],
            edge_checks=nbr_cols[1:],
            ord_checks=_ord_pairs_for(ord_, v, placed),
            min_degree=pattern.degree(v),
        ))
    col_of = {u: j for j, u in enumerate(order)}
    edge_cols = tuple(sorted((col_of[a], col_of[b]) for a, b in pattern.edges))
    return UnitPlan(
        pattern=pattern, anchor=start, order=tuple(order), steps=tuple(steps),
        edge_cols=edge_cols, anchor_min_degree=pattern.degree(start),
    )


# ---------------------------------------------------------------------------
# Worst-case-optimal (generic-join) plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WcojLevel:
    """One generic-join extension level: candidates for ``vertex`` are the
    intersection of the adjacency lists of every placed neighbor. ``pivot``
    enumerates (the seed adjacency list); ``intersect_cols`` constrain via
    set membership."""

    vertex: int
    pivot: int                                  # prefix column whose adjacency seeds candidates
    intersect_cols: Tuple[int, ...]             # prefix columns intersected against
    ord_checks: Tuple[Tuple[int, bool], ...]    # (prefix col idx, candidate_must_be_greater)
    min_degree: int                             # MC₁ degree prune threshold


@dataclasses.dataclass(frozen=True)
class WcojPlan:
    """Attribute-at-a-time generic-join plan over a whole pattern.

    Unlike :class:`UnitPlan` (one R1 unit of a decomposition, later CC-
    joined), a WCOJ plan lists the full pattern in one anchored pass:
    each level is a multiway adjacency intersection, so intermediate
    table sizes are bounded per level (AGM-style) instead of per binary
    join. Only R1-anchored patterns qualify (:func:`wcoj_eligible`) —
    the anchor adjacent to every other vertex makes per-partition
    center-anchored listing complete, exactly as for unit plans."""

    pattern: Pattern
    anchor: int
    order: Tuple[int, ...]                      # extension order; order[0] == anchor
    levels: Tuple[WcojLevel, ...]               # len == |V| - 1
    edge_cols: Tuple[Tuple[int, int], ...]      # pattern edges as (col_i, col_j) pairs
    anchor_min_degree: int

    @property
    def cols(self) -> Tuple[int, ...]:
        """Column labels of the produced match table (== extension order)."""
        return self.order


def wcoj_anchors(pattern: Pattern) -> Tuple[int, ...]:
    """Vertices adjacent to every other pattern vertex (R1 anchors of the
    whole pattern): valid WCOJ seeds for partition-complete listing."""
    vset = set(pattern.vertices)
    return tuple(v for v in pattern.vertices
                 if set(pattern.neighbors(v)) | {v} == vset)


def wcoj_eligible(pattern: Pattern) -> bool:
    """True iff the whole pattern admits an anchored generic-join plan."""
    return pattern.m > 0 and bool(wcoj_anchors(pattern))


def build_wcoj_plan(
    pattern: Pattern,
    anchor: int | None = None,
    ord_: Sequence[Tuple[int, int]] = (),
) -> WcojPlan:
    """Compile a generic-join plan for ``pattern``.

    ``anchor`` must be adjacent to all other vertices; ``None`` picks the
    max-degree such vertex. The extension order is the same greedy
    max-connectivity order as frontier listing, so on cliques every
    level intersects against the whole prefix."""
    anchors = wcoj_anchors(pattern)
    if not anchors:
        raise ValueError("pattern has no vertex adjacent to all others; "
                         "not WCOJ-eligible")
    if anchor is None:
        anchor = max(anchors, key=pattern.degree)
    elif anchor not in anchors:
        raise ValueError(f"anchor {anchor} is not adjacent to all other vertices")
    order = plan_extension_order(pattern, anchor)
    levels = []
    for i in range(1, len(order)):
        v = order[i]
        placed = order[:i]
        nbr_cols = tuple(j for j, u in enumerate(placed) if pattern.has_edge(u, v))
        levels.append(WcojLevel(
            vertex=v,
            pivot=nbr_cols[0],
            intersect_cols=nbr_cols[1:],
            ord_checks=_ord_pairs_for(ord_, v, placed),
            min_degree=pattern.degree(v),
        ))
    col_of = {u: j for j, u in enumerate(order)}
    edge_cols = tuple(sorted((col_of[a], col_of[b]) for a, b in pattern.edges))
    return WcojPlan(
        pattern=pattern, anchor=anchor, order=tuple(order),
        levels=tuple(levels), edge_cols=edge_cols,
        anchor_min_degree=pattern.degree(anchor),
    )


# ---------------------------------------------------------------------------
# CC-join plans (paper Alg. 2)
# ---------------------------------------------------------------------------

ValueCheck = Tuple[int, int]  # (output-skeleton column index, mode NEQ/LT/GT)


@dataclasses.dataclass(frozen=True)
class CompVertexPlan:
    """How one compressed vertex of the joined pattern is produced."""

    vertex: int
    source: str                     # 'both' | 'left' | 'right'
    checks: Tuple[ValueCheck, ...]  # validity of each value vs the new skeleton


@dataclasses.dataclass(frozen=True)
class JoinPlan:
    """Static description of one CC-join ``p3 = p1 ∪ p2`` under a cover."""

    pattern: Pattern                              # p3
    left_skel: Tuple[int, ...]                    # sorted(cover ∩ V(p1))
    right_skel: Tuple[int, ...]
    key_cols: Tuple[int, ...]                     # sorted(left_skel ∩ right_skel)
    skel_out: Tuple[int, ...]                     # sorted(left_skel ∪ right_skel)
    only_left: Tuple[int, ...]                    # skeleton cols exclusive to p1
    only_right: Tuple[int, ...]
    key_left_idx: Tuple[int, ...]                 # key cols as indices into left_skel
    key_right_idx: Tuple[int, ...]
    out_from_left: Tuple[Tuple[int, int], ...]    # (out idx, left idx)
    out_from_right: Tuple[Tuple[int, int], ...]   # (out idx, right idx) for only_right
    pair_neq: Tuple[Tuple[int, int], ...]         # cross-side injectivity (out idx pairs)
    pair_ord: Tuple[Tuple[int, int], ...]         # cross-side ord: s3[a] < s3[b]
    comp: Tuple[CompVertexPlan, ...]              # sorted by vertex label

    @staticmethod
    def make(
        p1: Pattern,
        p2: Pattern,
        cover: Sequence[int],
        ord_: Sequence[Tuple[int, int]] = (),
    ) -> "JoinPlan":
        cover_set = set(cover)
        v1, v2 = set(p1.vertices), set(p2.vertices)
        s1 = tuple(c for c in sorted(cover_set & v1))
        s2 = tuple(c for c in sorted(cover_set & v2))
        key = tuple(sorted(set(s1) & set(s2)))
        s3 = tuple(sorted(set(s1) | set(s2)))
        only1 = tuple(c for c in s1 if c not in s2)
        only2 = tuple(c for c in s2 if c not in s1)
        j1 = {c: j for j, c in enumerate(s1)}
        j2 = {c: j for j, c in enumerate(s2)}
        j3 = {c: j for j, c in enumerate(s3)}

        out_from_left = tuple((j3[c], j1[c]) for c in s1)
        out_from_right = tuple((j3[c], j2[c]) for c in only2)
        pair_neq = tuple((j3[a], j3[b]) for a in only1 for b in only2)
        pair_ord = tuple(
            (j3[a], j3[b]) for a, b in ord_
            if a in j3 and b in j3 and not ((a in j1 and b in j1) or (a in j2 and b in j2))
        )

        def checks_for(v: int, cols: Sequence[int]) -> Tuple[ValueCheck, ...]:
            out: List[ValueCheck] = []
            for c in cols:
                out.append((j3[c], NEQ))
                for a, b in ord_:
                    if (a, b) == (v, c):
                        out.append((j3[c], LT))
                    elif (a, b) == (c, v):
                        out.append((j3[c], GT))
            return tuple(out)

        comp_plans: List[CompVertexPlan] = []
        for v in sorted((v1 | v2) - set(s3)):
            in1, in2 = v in v1, v in v2
            if in1 and in2:
                comp_plans.append(CompVertexPlan(v, "both", checks_for(v, only2 + only1)))
            elif in1:
                comp_plans.append(CompVertexPlan(v, "left", checks_for(v, only2)))
            else:
                comp_plans.append(CompVertexPlan(v, "right", checks_for(v, only1)))

        return JoinPlan(
            pattern=p1.union(p2),
            left_skel=s1, right_skel=s2, key_cols=key, skel_out=s3,
            only_left=only1, only_right=only2,
            key_left_idx=tuple(j1[c] for c in key),
            key_right_idx=tuple(j2[c] for c in key),
            out_from_left=out_from_left, out_from_right=out_from_right,
            pair_neq=pair_neq, pair_ord=pair_ord, comp=tuple(comp_plans),
        )
