"""Incremental result maintenance (paper §VI): filter + patch + merge.

    M(p, d') = (M(p, d) − removed) ∪ M_new(p, d')

- *removed* matches are detected fully on the compressed form: every
  pattern edge has a cover endpoint, so each edge is either
  skeleton–skeleton (drop the whole group) or skeleton–compressed
  (drop the offending value) — Lemma 6.1 with zero decompression.
- the *patch set* comes from the Nav-join (Lemma 6.2 + Thm. 6.1).
- *merge* regroups by skeleton so the result stays a canonical
  compressed table.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from .graph import GraphUpdate, edge_codes
from .navjoin import NavReport, nav_join_patch
from .pattern import Pattern, R1Unit
from .storage import NPStorage, UpdateCostReport, update_np_storage
from .vcbc import CompressedTable, Ragged, _drop_empty_groups

__all__ = [
    "filter_deleted",
    "removed_rows",
    "merge_tables",
    "incremental_update",
    "apply_update_to_matches",
    "IncrementalReport",
]


def _codes_of(u: np.ndarray, v: np.ndarray) -> np.ndarray:
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    return (lo << np.int64(32)) | hi


def _in_sorted(q: np.ndarray, sorted_codes: np.ndarray) -> np.ndarray:
    if not sorted_codes.size or not q.size:
        return np.zeros(q.shape, bool)
    pos = np.clip(np.searchsorted(sorted_codes, q), 0, sorted_codes.shape[0] - 1)
    return sorted_codes[pos] == q


def filter_deleted(table: CompressedTable, deleted: np.ndarray) -> CompressedTable:
    """Remove matches mapping any pattern edge into ``E_d(U)`` (Lemma 6.1)."""
    del_codes = np.sort(edge_codes(deleted)) if np.asarray(deleted).size else np.empty(0, np.int64)
    if not del_codes.size or table.n_groups == 0:
        return table
    p = table.pattern
    skel_set = set(table.skeleton_cols)
    jcol = {c: j for j, c in enumerate(table.skeleton_cols)}

    # skeleton–skeleton edges → drop whole groups
    drop = np.zeros(table.n_groups, dtype=bool)
    for a, b in p.edges:
        if a in skel_set and b in skel_set:
            q = _codes_of(table.skeleton[:, jcol[a]], table.skeleton[:, jcol[b]])
            drop |= _in_sorted(q, del_codes)
    keep_groups = np.nonzero(~drop)[0]
    remap = -np.ones(table.n_groups, dtype=np.int64)
    remap[keep_groups] = np.arange(keep_groups.shape[0])

    comp = {}
    for v, r in table.comp.items():
        gids = np.repeat(np.arange(r.n_groups, dtype=np.int64), r.counts())
        vals = r.values
        alive = ~drop[gids]
        gids, vals = gids[alive], vals[alive]
        # skeleton–compressed edges → drop offending values
        bad = np.zeros(vals.shape[0], dtype=bool)
        for a, b in p.edges:
            w = None
            if a == v and b in skel_set:
                w = b
            elif b == v and a in skel_set:
                w = a
            if w is not None:
                q = _codes_of(vals, table.skeleton[gids, jcol[w]])
                bad |= _in_sorted(q, del_codes)
        gids, vals = gids[~bad], vals[~bad]
        comp[v] = Ragged.from_group_ids(remap[gids], vals, keep_groups.shape[0])

    out = CompressedTable(
        pattern=p, cover=table.cover, skeleton_cols=table.skeleton_cols,
        skeleton=table.skeleton[keep_groups], comp=comp,
    )
    return _drop_empty_groups(out)


def removed_rows(table: CompressedTable, deleted: np.ndarray,
                 ord_: Sequence[Tuple[int, int]] = ()) -> np.ndarray:
    """Plain rows of ``table`` that map a pattern edge into ``E_d(U)``.

    The decompressed complement of :func:`filter_deleted` (same Lemma
    6.1 edge test on rows instead of on the compressed form) — used by
    match-delta sinks to report exactly which matches a batch destroyed.
    """
    del_codes = np.sort(edge_codes(deleted)) if np.asarray(deleted).size else np.empty(0, np.int64)
    if not del_codes.size:
        return np.empty((0, table.pattern.n), np.int64)
    cols, rows = table.decompress(ord_)
    if not rows.shape[0]:
        return rows[:0]
    col_of = {c: j for j, c in enumerate(cols)}
    hit = np.zeros(rows.shape[0], dtype=bool)
    for a, b in table.pattern.edges:
        q = _codes_of(rows[:, col_of[a]], rows[:, col_of[b]])
        hit |= _in_sorted(q, del_codes)
    return rows[hit]


def merge_tables(a: CompressedTable, b: CompressedTable) -> CompressedTable:
    """Union of two compressed tables of the same pattern, regrouped by skeleton."""
    assert a.pattern.key() == b.pattern.key() and a.skeleton_cols == b.skeleton_cols
    if a.n_groups == 0:
        return b
    if b.n_groups == 0:
        return a
    skel = np.concatenate([a.skeleton, b.skeleton], axis=0)
    uniq, inv = np.unique(skel, axis=0, return_inverse=True)
    comp = {}
    for v in a.comp:
        pieces = []
        for t, off in ((a, 0), (b, a.n_groups)):
            r = t.comp[v]
            gids = np.repeat(np.arange(r.n_groups, dtype=np.int64), r.counts())
            pieces.append((inv[gids + off].astype(np.int64), r.values))
        g = np.concatenate([p[0] for p in pieces])
        vv = np.concatenate([p[1] for p in pieces])
        fused = np.unique((g << np.int64(32)) | vv)
        comp[v] = Ragged.from_group_ids(fused >> np.int64(32), fused & np.int64(0xFFFFFFFF), uniq.shape[0])
    return CompressedTable(pattern=a.pattern, cover=a.cover, skeleton_cols=a.skeleton_cols, skeleton=uniq, comp=comp)


@dataclasses.dataclass
class IncrementalReport:
    storage: UpdateCostReport
    nav: NavReport
    removed_groups: int = 0
    # The compressed patch set M_new(p, d') of this batch — kept so
    # streaming sinks can decompress exactly the *new* matches without
    # re-deriving them from the merged table.
    patch: Optional[CompressedTable] = None


def apply_update_to_matches(
    storage2: NPStorage,
    matches: CompressedTable,
    update: GraphUpdate,
    units: Sequence[R1Unit],
    pattern: Pattern,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
    storage_report: Optional[UpdateCostReport] = None,
    seed_fn: Optional[Callable] = None,
    provider=None,
) -> Tuple[CompressedTable, IncrementalReport]:
    """Result-maintenance half of the §VI pipeline over a *pre-updated* Φ(d').

    The shared-delta hook for the streaming layer: ``storage2`` is the
    already-updated NP storage (computed **once** per batch and shared
    by every registered pattern), ``seed_fn`` optionally shares per-unit
    Nav-join seed listings across patterns, and ``provider`` (a
    delta-maintained :class:`~repro.core.unit_cache.PartitionUnitCache`)
    serves the Nav-join chain-step unit tables from cache. Filter +
    patch + merge stay per-pattern.
    """
    nav = NavReport()
    kept = filter_deleted(matches, update.delete)
    patch = nav_join_patch(storage2, units, pattern, cover, ord_, update.add,
                           report=nav, seed_fn=seed_fn, provider=provider)
    merged = merge_tables(kept, patch)
    rep = IncrementalReport(
        storage=storage_report if storage_report is not None else UpdateCostReport(),
        nav=nav,
        removed_groups=matches.n_groups - kept.n_groups,
        patch=patch,
    )
    return merged, rep


def incremental_update(
    storage: NPStorage,
    matches: CompressedTable,
    update: GraphUpdate,
    units: Sequence[R1Unit],
    pattern: Pattern,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
) -> Tuple[NPStorage, CompressedTable, IncrementalReport]:
    """Full §VI pipeline: Φ(d)→Φ(d'), patch via Nav-join, filter + merge."""
    storage2, cost = update_np_storage(storage, update)
    merged, rep = apply_update_to_matches(
        storage2, matches, update, units, pattern, cover, ord_, storage_report=cost
    )
    return storage2, merged, rep
