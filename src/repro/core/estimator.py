"""Power-Law-Random match-count estimator (paper §II-E, §IV-D, Eq. 8/9).

Models the data graph as a PR graph where edge ``(v_i, v_j)`` appears with
probability ``deg(v_i)·deg(v_j)·ρ``, ``ρ = 1/(2|E|)``. For a random
injective assignment ``f : V(p) → V(d)``:

    ε = ρ^{|E(p)|} · Π_{v ∈ V(p)}  T(deg_p(v)),
    T(c) = Σ_{w ≥ c} w^c · p_w            (empirical degree histogram)

    E|M(p,d)| = n!/(n-k)! · ε · L(ord|_p) / k!

The last factor is the symmetry correction: ``L`` counts linear
extensions of the symmetry-breaking partial order restricted to ``V(p)``.
For a SimB-complete order it equals the paper's
``|Auto(p,ord)|/|Auto(p,∅)| = 1/|Aut(p)|`` and it extends smoothly to
subpatterns whose automorphisms are only partially broken (see
``pattern.linear_extension_count``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import numpy as np

from .graph import Graph
from .pattern import Pattern, linear_extension_count

__all__ = ["GraphStats", "match_size_estimate", "skeleton_size_estimate"]


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Sufficient statistics of ``d`` for the estimator."""

    n: int
    m: int
    deg_hist: Tuple[int, ...]  # hist[w] = #vertices with degree w

    @staticmethod
    def of(graph: Graph) -> "GraphStats":
        return GraphStats(n=graph.n, m=graph.num_edges, deg_hist=tuple(int(x) for x in graph.degree_histogram()))

    def t_term(self, c: int) -> float:
        """``T(c) = Σ_{w ≥ c} w^c p_w`` over the empirical histogram."""
        hist = np.asarray(self.deg_hist, dtype=np.float64)
        w = np.arange(hist.shape[0], dtype=np.float64)
        if self.n == 0:
            return 0.0
        p_w = hist / float(self.n)
        lo = max(int(c), 1) if c > 0 else 0
        ws = w[lo:]
        with np.errstate(over="ignore"):
            val = float(np.sum(np.power(ws, float(c)) * p_w[lo:]))
        return val


def match_size_estimate(
    pattern: Pattern,
    ord_: Sequence[Tuple[int, int]],
    stats: GraphStats,
) -> float:
    """``E|M(p, d)|`` under the PR model + symmetry correction (Eq. 9)."""
    k = pattern.n
    if k == 0 or stats.m == 0:
        return 0.0
    rho = 1.0 / (2.0 * stats.m)
    log_eps = pattern.m * math.log(rho)
    for v in pattern.vertices:
        t = stats.t_term(pattern.degree(v))
        if t <= 0.0:
            return 0.0
        log_eps += math.log(t)
    # assignments = n! / (n-k)!
    if stats.n < k:
        return 0.0
    log_assign = sum(math.log(stats.n - i) for i in range(k))
    lec = linear_extension_count(pattern.vertices, ord_)
    log_sym = math.log(lec) - math.lgamma(k + 1)
    return math.exp(log_assign + log_eps + log_sym)


def skeleton_size_estimate(
    pattern: Pattern,
    cover: Sequence[int],
    ord_: Sequence[Tuple[int, int]],
    stats: GraphStats,
) -> float:
    """``E|M(p[V_c ∩ V(p)], d)|`` — skeleton-count bound used by Thm. 4.1.

    Isolated cover vertices (no cover-neighbor inside ``p``) contribute a
    degree-0 factor ``T(0) = 1`` and a plain assignment slot, matching the
    worst-case skeleton count.
    """
    vc = [v for v in cover if v in set(pattern.vertices)]
    induced = pattern.induced(vc)
    return match_size_estimate(induced, ord_, stats)
