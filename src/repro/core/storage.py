"""Neighbor-Preserved (NP) storage ``Φ(d)`` — paper §III-B and Alg. 4.

Partition ``j`` stores the union of local graphs ``loc(u) = d[{u} ∪ N(u)]``
over its *center* vertices ``{u : h(u) = j}``. Membership rule for an edge
``(a, b)``::

    (a, b) ∈ E_j  ⇔  h(a) = j ∨ h(b) = j ∨ ∃ z ∈ CN(a, b) : h(z) = j

where ``CN`` is the common-neighbor set (the triangle-closing copies).

Space accounting (§III-B): ``Σ_j |E_j| ≤ min(2·|E| + 3·Δ(d), m·|E|)`` —
the first term is the adjacency-list baseline plus one copy per triangle
corner, the second is the trivial replication bound. Both are asserted in
tests.

The batch update (:func:`update_np_storage`) implements Alg. 4 cases
C1–C3 with *batch* semantics: candidate membership changes are generated
from the update and validated against the post-update graph ``d'``, so
the result is bit-identical to rebuilding ``Φ(d')`` from scratch (tested).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

from .graph import Graph, GraphUpdate, decode_edges, edge_codes

__all__ = [
    "PartitionFn",
    "Partition",
    "NPStorage",
    "build_np_storage",
    "update_np_storage",
    "UpdateCostReport",
]


class PartitionFn:
    """Vertex-id → partition-id map (paper Def. 3.2). Default: ``id % m``."""

    def __init__(self, m: int, table: np.ndarray | None = None):
        self.m = int(m)
        self.table = None if table is None else np.asarray(table, dtype=np.int64)

    def __call__(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        if self.table is None:
            return ids % self.m
        out = np.where(ids < self.table.shape[0], self.table[np.minimum(ids, self.table.shape[0] - 1)], ids % self.m)
        return out.astype(np.int64)

    def rebalanced(self, new_assignment: Dict[int, int]) -> "PartitionFn":
        """Return a copy with explicit overrides (straggler rebalancing)."""
        size = max(new_assignment.keys(), default=-1) + 1
        base = self.table if self.table is not None else np.arange(size, dtype=np.int64) % self.m
        if base.shape[0] < size:
            ext = np.arange(base.shape[0], size, dtype=np.int64) % self.m
            base = np.concatenate([base, ext])
        tab = base.copy()
        for k, v in new_assignment.items():
            tab[k] = v
        return PartitionFn(self.m, tab)


@dataclasses.dataclass
class Partition:
    """One part ``d_j``: a local CSR over the edges assigned to it."""

    pid: int
    vertices: np.ndarray      # sorted global ids appearing in this part
    center_mask: np.ndarray   # bool per local vertex: h(v) == pid
    indptr: np.ndarray        # local CSR row pointers
    indices: np.ndarray       # neighbor GLOBAL ids, sorted per row
    codes: np.ndarray         # sorted edge codes of E_j

    # ------------------------------------------------------------------ views
    @property
    def num_edges(self) -> int:
        return int(self.codes.shape[0])

    def center_vertices(self) -> np.ndarray:
        return self.vertices[self.center_mask]

    def local_ids(self, global_ids: np.ndarray) -> np.ndarray:
        """Map global → local ids (must be present)."""
        pos = np.searchsorted(self.vertices, global_ids)
        return pos

    def contains(self, global_ids: np.ndarray) -> np.ndarray:
        g = np.asarray(global_ids, dtype=np.int64)
        pos = np.searchsorted(self.vertices, g)
        pos_c = np.clip(pos, 0, max(self.vertices.shape[0] - 1, 0))
        if self.vertices.size == 0:
            return np.zeros(g.shape, bool)
        return self.vertices[pos_c] == g

    def neighbors(self, u: int) -> np.ndarray:
        lid = int(np.searchsorted(self.vertices, u))
        if lid >= self.vertices.shape[0] or self.vertices[lid] != u:
            return self.indices[:0]
        return self.indices[self.indptr[lid] : self.indptr[lid + 1]]

    def degrees_of(self, global_ids: np.ndarray) -> np.ndarray:
        lids = self.local_ids(global_ids)
        return (self.indptr[lids + 1] - self.indptr[lids]).astype(np.int64)

    def has_edges(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        lo = np.minimum(u, v)
        hi = np.maximum(u, v)
        q = (lo << np.int64(32)) | hi
        if not self.codes.size:
            return np.zeros(q.shape, bool)
        pos = np.clip(np.searchsorted(self.codes, q), 0, self.codes.shape[0] - 1)
        return self.codes[pos] == q

    @staticmethod
    def from_codes(pid: int, codes: np.ndarray, centers: np.ndarray) -> "Partition":
        und = decode_edges(np.sort(codes))
        verts = np.unique(np.concatenate([und.reshape(-1), centers.astype(np.int64)]))
        src = np.concatenate([und[:, 0], und[:, 1]])
        dst = np.concatenate([und[:, 1], und[:, 0]])
        lsrc = np.searchsorted(verts, src)
        order = np.lexsort((dst, lsrc))
        lsrc, dst = lsrc[order], dst[order]
        indptr = np.zeros(verts.shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, lsrc + 1, 1)
        indptr = np.cumsum(indptr)
        cmask = np.zeros(verts.shape[0], dtype=bool)
        cmask[np.searchsorted(verts, centers)] = True if centers.size else False
        return Partition(pid=pid, vertices=verts, center_mask=cmask, indptr=indptr, indices=dst, codes=np.sort(codes))


@dataclasses.dataclass
class NPStorage:
    """The full NP storage ``Φ(d)`` plus the partition function."""

    graph: Graph
    h: PartitionFn
    parts: List[Partition]

    @property
    def m(self) -> int:
        return self.h.m

    def total_stored_edges(self) -> int:
        return int(sum(p.num_edges for p in self.parts))

    def updated(self, update: GraphUpdate) -> tuple["NPStorage", "UpdateCostReport"]:
        """Apply one batch update → ``(Φ(d'), cost)`` (Alg. 4).

        The shared-delta entry point of the streaming layer: the
        scheduler calls this once per micro-batch and hands the result
        to every registered pattern instead of letting each engine
        recompute Φ(d') from the same update.
        """
        return update_np_storage(self, update)

    def space_report(self) -> Dict[str, int]:
        e = self.graph.num_edges
        tri = self.graph.triangle_count()
        stored = self.total_stored_edges()
        return {
            "edges": e,
            "triangles": tri,
            "stored_edges": stored,
            "bound": int(min(2 * e + 3 * tri, self.m * e)),
            "overhead_ratio_x1000": int(0 if e == 0 else stored * 1000 // e),
        }


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def _edge_part_memberships(graph: Graph, h: PartitionFn, chunk: int = 1 << 18):
    """Yield (edge_code, part) pairs for every edge, including triangle copies.

    Vectorized: for each edge pick the lower-degree endpoint ``a`` and scan
    its adjacency for common neighbors (work = Σ_e min-degree, the
    arboricity-style bound for triangle enumeration).
    """
    und = graph.edges()
    if und.shape[0] == 0:
        return np.empty((0,), np.int64), np.empty((0,), np.int64)
    deg = graph.degrees
    swap = deg[und[:, 0]] > deg[und[:, 1]]
    a = np.where(swap, und[:, 1], und[:, 0])
    b = np.where(swap, und[:, 0], und[:, 1])
    codes = graph.codes

    mem_codes = [codes, codes]  # endpoint copies
    mem_parts = [h(und[:, 0]), h(und[:, 1])]

    # Triangle copies, chunked over edges to bound memory.
    dega = deg[a]
    starts = graph.indptr[a]
    total = dega.sum()
    edge_order = np.argsort(-dega)  # stable work distribution irrelevant; plain chunks fine
    del edge_order, total
    begin = 0
    m_edges = und.shape[0]
    while begin < m_edges:
        end = min(m_edges, begin + chunk)
        da = dega[begin:end]
        rep = np.repeat(np.arange(begin, end), da)
        # gather adjacency slices of a[begin:end]
        offs = np.arange(da.sum()) - np.repeat(np.cumsum(da) - da, da)
        w = graph.indices[np.repeat(starts[begin:end], da) + offs]
        bb = b[rep]
        ok = (w != bb) & graph.has_edges(w, bb)
        rep, w = rep[ok], w[ok]
        mem_codes.append(codes[rep])
        mem_parts.append(h(w))
        begin = end
    return np.concatenate(mem_codes), np.concatenate(mem_parts)


def build_np_storage(graph: Graph, m: int, h: PartitionFn | None = None) -> NPStorage:
    h = h if h is not None else PartitionFn(m)
    assert h.m == m
    mem_codes, mem_parts = _edge_part_memberships(graph, h)
    # Dedup (code, part) pairs.
    if mem_codes.size:
        combo = np.stack([mem_parts, mem_codes], axis=1)
        combo = np.unique(combo, axis=0)
        mem_parts, mem_codes = combo[:, 0], combo[:, 1]
    all_ids = np.arange(graph.n, dtype=np.int64)
    hv = h(all_ids)
    parts = []
    for j in range(m):
        pc = mem_codes[mem_parts == j]
        centers = all_ids[hv == j]
        parts.append(Partition.from_codes(j, pc, centers))
    return NPStorage(graph=graph, h=h, parts=parts)


# ---------------------------------------------------------------------------
# Incremental update (Alg. 4, batch semantics)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UpdateCostReport:
    """Instrumented analogue of the paper's Alg.-4 cost formula."""

    shuffled_neighbor_ints: int = 0   # Σ |N_{d'}(u_i)| messages (map → reduce)
    edges_removed: int = 0
    edges_added: int = 0
    # Partitions whose stored edge set E_j actually changed under this
    # batch — the exact invalidation set for anything derived from a
    # single partition (per-partition unit-match tables cache on this:
    # equal edge sets ⇒ identical Φ(d')_j ⇒ identical listings).
    dirty_parts: Tuple[int, ...] = ()


def update_np_storage(storage: NPStorage, update: GraphUpdate) -> tuple["NPStorage", UpdateCostReport]:
    """Apply a batch update to ``Φ(d)``; returns ``Φ(d')`` + cost report.

    Matches a from-scratch rebuild of ``Φ(d')`` exactly (property-tested).
    """
    g = storage.graph
    h = storage.h
    m = storage.m
    d_codes = update.delete_codes()
    a_codes = update.add_codes()
    if np.intersect1d(d_codes, a_codes).size:
        raise ValueError("E_d(U) and E_a(U) must be disjoint")
    missing = ~np.isin(d_codes, g.codes)
    if missing.any():
        raise ValueError(f"deleting non-existent edges: {decode_edges(d_codes[missing])[:4]}")
    already = np.isin(a_codes, g.codes)
    if already.any():
        raise ValueError(f"inserting existing edges: {decode_edges(a_codes[already])[:4]}")

    g2 = g.apply_update(update)
    report = UpdateCostReport()

    # --- candidate additions per part: (code, part) pairs -------------------
    add_codes: List[np.ndarray] = []
    add_parts: List[np.ndarray] = []
    for code in a_codes:
        ab = decode_edges(np.array([code]))[0]
        a_, b_ = int(ab[0]), int(ab[1])
        ha, hb = int(h(np.array([a_]))[0]), int(h(np.array([b_]))[0])
        z = g2.common_neighbors(a_, b_)
        hz = h(z)
        # (a,b) goes to h(a), h(b), h(z)∀z
        tgt = np.concatenate([[ha, hb], hz])
        add_codes.append(np.full(tgt.shape, code, np.int64))
        add_parts.append(tgt.astype(np.int64))
        # triangle closure: (b,z) -> h(a), (a,z) -> h(b)
        if z.size:
            bz = edge_codes(np.stack([np.full(z.shape, b_), z], axis=1))
            az = edge_codes(np.stack([np.full(z.shape, a_), z], axis=1))
            add_codes.extend([bz, az])
            add_parts.extend([np.full(z.shape, ha, np.int64), np.full(z.shape, hb, np.int64)])
        # cost model: cross-partition inserts ship N_{d'} of each endpoint
        if ha != hb:
            report.shuffled_neighbor_ints += int(g2.degrees[a_] + g2.degrees[b_])

    # --- candidate removals per part ----------------------------------------
    rm_codes: List[np.ndarray] = []
    rm_parts: List[np.ndarray] = []
    for code in d_codes:
        ab = decode_edges(np.array([code]))[0]
        a_, b_ = int(ab[0]), int(ab[1])
        ha, hb = int(h(np.array([a_]))[0]), int(h(np.array([b_]))[0])
        z = g.common_neighbors(a_, b_)  # triangles in d (pre-update)
        hz = h(z)
        # (a,b) leaves every part it was in.
        tgt = np.concatenate([[ha, hb], hz])
        rm_codes.append(np.full(tgt.shape, code, np.int64))
        rm_parts.append(tgt.astype(np.int64))
        # broken triangle closures: (b,z) may leave h(a); (a,z) may leave h(b)
        if z.size:
            bz = edge_codes(np.stack([np.full(z.shape, b_), z], axis=1))
            az = edge_codes(np.stack([np.full(z.shape, a_), z], axis=1))
            rm_codes.extend([bz, az])
            rm_parts.extend([np.full(z.shape, ha, np.int64), np.full(z.shape, hb, np.int64)])

    def _validate(codes: np.ndarray, parts_: np.ndarray) -> np.ndarray:
        """True where edge `codes[i]` belongs to part `parts_[i]` in d'."""
        if codes.size == 0:
            return np.zeros((0,), bool)
        exists = np.isin(codes, g2.codes)
        und = decode_edges(codes)
        keep = exists & ((h(und[:, 0]) == parts_) | (h(und[:, 1]) == parts_))
        # common-neighbor reason (only needed where not yet kept)
        todo = np.nonzero(exists & ~keep)[0]
        for i in todo:
            z = g2.common_neighbors(int(und[i, 0]), int(und[i, 1]))
            if z.size and np.any(h(z) == parts_[i]):
                keep[i] = True
        return keep

    def _pairs(codes_l: List[np.ndarray], parts_l: List[np.ndarray]):
        if not codes_l:
            return np.empty((0,), np.int64), np.empty((0,), np.int64)
        c = np.concatenate(codes_l)
        p = np.concatenate(parts_l)
        combo = np.unique(np.stack([p, c], axis=1), axis=0)
        return combo[:, 1], combo[:, 0]

    acand, apart = _pairs(add_codes, add_parts)
    rcand, rpart = _pairs(rm_codes, rm_parts)
    a_ok = _validate(acand, apart) if acand.size else np.zeros((0,), bool)
    r_keep = _validate(rcand, rpart) if rcand.size else np.zeros((0,), bool)

    all_ids = np.arange(g2.n, dtype=np.int64)
    hv = h(all_ids)
    new_parts: List[Partition] = []
    dirty: List[int] = []
    for j in range(m):
        old = storage.parts[j].codes
        rm_j = rcand[(rpart == j) & ~r_keep]
        ad_j = acand[(apart == j) & a_ok]
        kept = old[~np.isin(old, rm_j)] if rm_j.size else old
        codes_j = np.unique(np.concatenate([kept, ad_j])) if ad_j.size else kept
        centers = all_ids[hv == j]
        new_parts.append(Partition.from_codes(j, codes_j, centers))
        removed_j = int(old.size - kept.size)
        added_j = int(codes_j.size - kept.size)
        report.edges_removed += removed_j
        report.edges_added += added_j
        if removed_j or added_j:
            dirty.append(j)
    report.dirty_parts = tuple(dirty)

    return NPStorage(graph=g2, h=h, parts=new_parts), report
