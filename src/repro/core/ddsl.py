"""DDSL facade — the paper's two stages behind one object.

    engine = DDSL(graph, m=4, pattern=PATTERN_LIBRARY["q5_house"])
    engine.initial()            # stage 1: initial calculation
    engine.apply(update)        # stage 2: incremental updating
    engine.count()              # |M(p, d)| right now

Cover selection implements the *optimal connected compression* (§IV-F):
among vertex covers with connected ``p[V_c]`` and at least one anchored
R1 decomposition, pick the one maximizing the guaranteed compression
ratio ``R_lower`` (Thm. 4.1) under the PR-model estimates.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .cost import CostModel
from .estimator import GraphStats
from .graph import Graph, GraphUpdate, edge_codes
from .incremental import (
    IncrementalReport,
    apply_update_to_matches,
    filter_deleted,
    merge_tables,
)
from .join_tree import JoinTree
from .listing import ExecutionReport, execute_join_tree
from .match_engine import execute_wcoj
from .navjoin import NavReport
from .pattern import Pattern
from .storage import NPStorage, PartitionFn, UpdateCostReport, build_np_storage, update_np_storage
from .vcbc import CompressedTable, compress_table

# Cover selection is the compiler's `cover` pass now; re-exported here
# because it long predates repro.planner and callers import it from core.
from repro.planner.compiler import choose_cover  # noqa: F401

__all__ = ["DDSL", "choose_cover"]


@dataclasses.dataclass
class DDSLState:
    storage: NPStorage
    matches: Optional[CompressedTable] = None


class DDSL:
    """Distributed & Dynamic Subgraph Listing (host reference engine)."""

    def __init__(
        self,
        graph: Graph,
        pattern: Pattern,
        m: int = 4,
        h: PartitionFn | None = None,
        cover: Sequence[int] | None = None,
        storage: NPStorage | None = None,
        plan=None,
        executor: str = "tree",
    ):
        from repro.planner import CompileContext, compile_plan

        self.pattern = pattern
        if plan is None:
            plan = compile_plan(CompileContext(
                pattern=pattern, stats=GraphStats.of(graph), m=m,
                cover=tuple(sorted(cover)) if cover is not None else None,
                executor=executor))
        elif plan.pattern.key() != pattern.key():
            raise ValueError("precompiled plan is for a different pattern")
        self.plan = plan
        self.ord_ = plan.ord
        self.stats = plan.stats
        self.cover = plan.cover
        self.model = CostModel(self.cover, self.ord_, self.stats)
        self.tree: JoinTree = plan.tree
        self.units = list(plan.units)
        if storage is not None and storage.graph is not graph:
            raise ValueError("shared storage must be built over the same graph object")
        self.state = DDSLState(storage=storage if storage is not None else build_np_storage(graph, m, h))
        self.reports: List = []

    # ------------------------------------------------------------------ stage 1
    def initial(self) -> CompressedTable:
        rep = ExecutionReport()
        if self.plan.executor == "wcoj":
            self.state.matches = self._list_wcoj(self.state.storage)
        else:
            self.state.matches = execute_join_tree(
                self.state.storage, self.tree, self.cover, self.ord_, rep
            )
        self.reports.append(rep)
        return self.state.matches

    # ------------------------------------------------------------------ wcoj mode
    def _list_wcoj(
        self,
        storage: NPStorage,
        require_codes: np.ndarray | None = None,
        seed_vertices: np.ndarray | None = None,
    ) -> CompressedTable:
        """List matches via the generic-join executor (executor="wcoj").

        Anchoring seeds to partition centers makes the per-partition
        sweep globally complete and disjoint (Lemma 3.1 analogue: every
        match is found exactly once, at its anchor's center partition).
        The result is stored under *trivial* compression — the storage
        cover is all of ``V(p)``, matching the device WCOJ store layout.
        """
        wcoj = self.plan.wcoj
        tbls = [
            execute_wcoj(
                part, wcoj, anchor_to_centers=True,
                require_edge_codes=require_codes, seed_vertices=seed_vertices,
            )
            for part in storage.parts
        ]
        tbl = (np.concatenate(tbls, axis=0) if tbls
               else np.empty((0, len(wcoj.cols)), np.int64))
        return compress_table(
            self.pattern, self.plan.storage_cover, wcoj.cols, tbl)

    def _apply_wcoj(
        self,
        storage2: NPStorage,
        update: GraphUpdate,
        storage_report: UpdateCostReport | None = None,
    ) -> Tuple[CompressedTable, IncrementalReport]:
        """Stage 2 for executor="wcoj": delta-dataflow generic join.

        Deletes drop whole skeleton groups (every edge is
        skeleton–skeleton under trivial compression); the insert patch
        re-seeds the generic join from ``C1 ∪ N_{d'}(C1)`` (endpoints of
        inserted edges and their Φ(d') neighbors — a new match's anchor
        is adjacent to both endpoints of some contained inserted edge)
        and keeps only rows containing an inserted edge, so each new
        match is listed exactly once with no Thm 6.1 dedup pass.
        """
        matches = self.state.matches
        kept = filter_deleted(matches, update.delete)
        add = np.asarray(update.add, dtype=np.int64).reshape(-1, 2)
        if add.size:
            g2 = storage2.graph
            ends = np.unique(add.reshape(-1))
            nbrs = [g2.indices[g2.indptr[v]:g2.indptr[v + 1]]
                    for v in ends if 0 <= v < g2.n]
            cand = np.unique(np.concatenate([ends, *nbrs]))
            patch = self._list_wcoj(
                storage2, require_codes=np.sort(edge_codes(add)),
                seed_vertices=cand)
        else:
            patch = compress_table(
                self.pattern, self.plan.storage_cover, self.plan.wcoj.cols,
                np.empty((0, len(self.plan.wcoj.cols)), np.int64))
        merged = merge_tables(kept, patch)
        rep = IncrementalReport(
            storage=storage_report if storage_report is not None else UpdateCostReport(),
            nav=NavReport(patch_matches=patch.count_matches(self.ord_)),
            removed_groups=matches.n_groups - kept.n_groups,
            patch=patch,
        )
        return merged, rep

    # ------------------------------------------------------------------ stage 2
    def apply(self, update: GraphUpdate) -> IncrementalReport:
        if self.state.matches is None:
            raise RuntimeError("call initial() before apply()")
        storage2, cost = update_np_storage(self.state.storage, update)
        if self.plan.executor == "wcoj":
            merged, rep = self._apply_wcoj(storage2, update, storage_report=cost)
        else:
            merged, rep = apply_update_to_matches(
                storage2, self.state.matches, update,
                self.units, self.pattern, self.cover, self.ord_,
                storage_report=cost,
            )
        self.state.storage = storage2
        self.state.matches = merged
        self.stats = GraphStats.of(storage2.graph)
        # History keeps counters only — retaining every batch's patch
        # table would grow memory with stream length.
        self.reports.append(dataclasses.replace(rep, patch=None))
        return rep

    def apply_shared(
        self,
        storage2: NPStorage,
        update: GraphUpdate,
        *,
        stats: GraphStats | None = None,
        storage_report: UpdateCostReport | None = None,
        seed_fn=None,
        provider=None,
    ) -> IncrementalReport:
        """Stage 2 over a *shared* pre-updated Φ(d') (streaming hook).

        ``storage2``/``stats`` are computed once per micro-batch by
        :mod:`repro.stream.scheduler` and shared by every registered
        pattern; ``seed_fn`` optionally shares Nav-join seed listings;
        ``provider`` serves the chain-step unit tables from the
        delta-maintained :class:`~repro.core.unit_cache.PartitionUnitCache`.
        """
        if self.state.matches is None:
            raise RuntimeError("call initial() before apply_shared()")
        if self.plan.executor == "wcoj":
            merged, rep = self._apply_wcoj(storage2, update, storage_report=storage_report)
        else:
            merged, rep = apply_update_to_matches(
                storage2, self.state.matches, update,
                self.units, self.pattern, self.cover, self.ord_,
                storage_report=storage_report, seed_fn=seed_fn, provider=provider,
            )
        self.state.storage = storage2
        self.state.matches = merged
        self.stats = stats if stats is not None else GraphStats.of(storage2.graph)
        self.reports.append(dataclasses.replace(rep, patch=None))
        return rep

    # ------------------------------------------------------------------ results
    def count(self) -> int:
        assert self.state.matches is not None
        return self.state.matches.count_matches(self.ord_)

    def matches_plain(self) -> np.ndarray:
        assert self.state.matches is not None
        _, table = self.state.matches.decompress(self.ord_)
        return table

    @property
    def graph(self) -> Graph:
        return self.state.storage.graph
