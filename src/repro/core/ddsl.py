"""DDSL facade — the paper's two stages behind one object.

    engine = DDSL(graph, m=4, pattern=PATTERN_LIBRARY["q5_house"])
    engine.initial()            # stage 1: initial calculation
    engine.apply(update)        # stage 2: incremental updating
    engine.count()              # |M(p, d)| right now

Cover selection implements the *optimal connected compression* (§IV-F):
among vertex covers with connected ``p[V_c]`` and at least one anchored
R1 decomposition, pick the one maximizing the guaranteed compression
ratio ``R_lower`` (Thm. 4.1) under the PR-model estimates.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .cost import CostModel
from .estimator import GraphStats, match_size_estimate, skeleton_size_estimate
from .graph import Graph, GraphUpdate
from .incremental import IncrementalReport, apply_update_to_matches, incremental_update
from .join_tree import JoinTree, minimum_unit_decomposition, optimal_join_tree
from .listing import ExecutionReport, execute_join_tree
from .pattern import Pattern, connected_vertex_covers, enumerate_r1_units, symmetry_break
from .storage import NPStorage, PartitionFn, UpdateCostReport, build_np_storage
from .vcbc import CompressedTable, r_lower

__all__ = ["DDSL", "choose_cover"]


def choose_cover(
    pattern: Pattern,
    ord_: Sequence[Tuple[int, int]],
    stats: GraphStats,
) -> Tuple[int, ...]:
    """Optimal connected compression: maximize R_lower over connected covers
    that admit a cover-anchored R1 decomposition."""
    best, best_r = None, -1.0
    full = match_size_estimate(pattern, ord_, stats)
    units = enumerate_r1_units(pattern)
    for vc in connected_vertex_covers(pattern):
        vcs = set(vc)
        anchored = [u for u in units if u.anchor_in(vcs) is not None]
        covered = frozenset().union(*[u.pattern.edges for u in anchored]) if anchored else frozenset()
        if covered != pattern.edges:
            continue
        skel = skeleton_size_estimate(pattern, vc, ord_, stats)
        r = r_lower(pattern.n, len(vc), full, skel)
        if r > best_r or (r == best_r and best is not None and len(vc) < len(best)):
            best, best_r = vc, r
    if best is None:
        raise ValueError("no connected cover admits an anchored R1 decomposition")
    return best


@dataclasses.dataclass
class DDSLState:
    storage: NPStorage
    matches: Optional[CompressedTable] = None


class DDSL:
    """Distributed & Dynamic Subgraph Listing (host reference engine)."""

    def __init__(
        self,
        graph: Graph,
        pattern: Pattern,
        m: int = 4,
        h: PartitionFn | None = None,
        cover: Sequence[int] | None = None,
        storage: NPStorage | None = None,
    ):
        self.pattern = pattern
        self.ord_ = symmetry_break(pattern)
        self.stats = GraphStats.of(graph)
        self.cover = tuple(sorted(cover)) if cover is not None else choose_cover(pattern, self.ord_, self.stats)
        self.model = CostModel(self.cover, self.ord_, self.stats)
        self.tree: JoinTree = optimal_join_tree(pattern, self.cover, self.model)
        self.units = minimum_unit_decomposition(pattern, self.cover)
        if storage is not None and storage.graph is not graph:
            raise ValueError("shared storage must be built over the same graph object")
        self.state = DDSLState(storage=storage if storage is not None else build_np_storage(graph, m, h))
        self.reports: List = []

    # ------------------------------------------------------------------ stage 1
    def initial(self) -> CompressedTable:
        rep = ExecutionReport()
        self.state.matches = execute_join_tree(
            self.state.storage, self.tree, self.cover, self.ord_, rep
        )
        self.reports.append(rep)
        return self.state.matches

    # ------------------------------------------------------------------ stage 2
    def apply(self, update: GraphUpdate) -> IncrementalReport:
        if self.state.matches is None:
            raise RuntimeError("call initial() before apply()")
        storage2, merged, rep = incremental_update(
            self.state.storage, self.state.matches, update,
            self.units, self.pattern, self.cover, self.ord_,
        )
        self.state.storage = storage2
        self.state.matches = merged
        self.stats = GraphStats.of(storage2.graph)
        # History keeps counters only — retaining every batch's patch
        # table would grow memory with stream length.
        self.reports.append(dataclasses.replace(rep, patch=None))
        return rep

    def apply_shared(
        self,
        storage2: NPStorage,
        update: GraphUpdate,
        *,
        stats: GraphStats | None = None,
        storage_report: UpdateCostReport | None = None,
        seed_fn=None,
        provider=None,
    ) -> IncrementalReport:
        """Stage 2 over a *shared* pre-updated Φ(d') (streaming hook).

        ``storage2``/``stats`` are computed once per micro-batch by
        :mod:`repro.stream.scheduler` and shared by every registered
        pattern; ``seed_fn`` optionally shares Nav-join seed listings;
        ``provider`` serves the chain-step unit tables from the
        delta-maintained :class:`~repro.core.unit_cache.PartitionUnitCache`.
        """
        if self.state.matches is None:
            raise RuntimeError("call initial() before apply_shared()")
        merged, rep = apply_update_to_matches(
            storage2, self.state.matches, update,
            self.units, self.pattern, self.cover, self.ord_,
            storage_report=storage_report, seed_fn=seed_fn, provider=provider,
        )
        self.state.storage = storage2
        self.state.matches = merged
        self.stats = stats if stats is not None else GraphStats.of(storage2.graph)
        self.reports.append(dataclasses.replace(rep, patch=None))
        return rep

    # ------------------------------------------------------------------ results
    def count(self) -> int:
        assert self.state.matches is not None
        return self.state.matches.count_matches(self.ord_)

    def matches_plain(self) -> np.ndarray:
        assert self.state.matches is not None
        _, table = self.state.matches.decompress(self.ord_)
        return table

    @property
    def graph(self) -> Graph:
        return self.state.storage.graph
