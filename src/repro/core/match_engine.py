"""Vectorized anchored subgraph listing (paper Alg. 1 substrate).

The engine lists matches of a small connected pattern inside either a
full :class:`~repro.core.graph.Graph` or one NP :class:`Partition` by
*frontier-table expansion*: a table of partial matches (one column per
matched pattern vertex) is repeatedly extended by gathering candidate
vertices from the adjacency of an already-matched pivot, then filtering
with vectorized edge/injectivity/order masks. This replaces the paper's
per-worker DFS with a data-parallel formulation that maps 1:1 onto the
padded JAX/TPU engine in ``repro.dist.jax_engine``.

Constraints supported:

- **anchor→center** (``M_ac``, Lemma 3.1): the anchor column is seeded
  only with center vertices of the partition;
- **ord** (SimB, §II-B): ``f(a) < f(b)`` for ``(a, b) ∈ ord``;
- **inserted-edge requirement** (Nav-join step 2, §VI-B): at least one
  pattern edge must map into ``E_a(U)``;
- **degree pruning** (MC₁ of §IV-D): candidates whose in-scope degree is
  below the pattern degree are dropped early.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .graph import Graph
from .pattern import Pattern
from .storage import Partition

__all__ = [
    "plan_extension_order",
    "list_matches",
    "ragged_expand",
]

_ROW_CHUNK = 1 << 17


def _rows_of(provider, u: np.ndarray) -> np.ndarray:
    if isinstance(provider, Partition):
        return provider.local_ids(u)
    return u


def _has_edges(provider, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    return provider.has_edges(u, v)


def ragged_expand(starts: np.ndarray, counts: np.ndarray, values: np.ndarray):
    """For row i, yield ``values[starts[i] : starts[i]+counts[i]]``.

    Returns ``(row_index, gathered_values)`` — the core repeat/gather
    primitive shared by adjacency expansion, VCBC decompression, and the
    CC-join pair expansion.
    """
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty((0,), np.int64), values[:0]
    rep = np.repeat(np.arange(counts.shape[0], dtype=np.int64), counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(counts) - counts, counts)
    return rep, values[np.repeat(starts.astype(np.int64), counts) + offs]


def plan_extension_order(pattern: Pattern, start: int) -> List[int]:
    """Vertex matching order: ``start`` first, then greedy max-connectivity
    (ties: higher pattern degree, then lower label)."""
    order = [start]
    rest = [v for v in pattern.vertices if v != start]
    while rest:
        def score(v):
            conn = sum(1 for u in order if pattern.has_edge(u, v))
            return (conn, pattern.degree(v), -v)
        nxt = max(rest, key=score)
        if not any(pattern.has_edge(u, nxt) for u in order):
            # Disconnected pattern piece: fall back to any remaining vertex
            # adjacent to the matched set if one exists (shouldn't happen
            # for connected patterns).
            raise ValueError("pattern must be connected for frontier listing")
        order.append(nxt)
        rest.remove(nxt)
    return order


def _ord_pairs_for(ord_: Sequence[Tuple[int, int]], new_v: int, placed: Sequence[int]):
    placed_set = set(placed)
    out = []
    for a, b in ord_:
        if a == new_v and b in placed_set:
            out.append((b, False))  # f(new) < f(b)  → cand < col(b)
        elif b == new_v and a in placed_set:
            out.append((a, True))   # f(a) < f(new)  → cand > col(a)
    return out


def list_matches(
    provider: Graph | Partition,
    pattern: Pattern,
    ord_: Sequence[Tuple[int, int]] = (),
    *,
    anchor: int | None = None,
    anchor_to_centers: bool = False,
    require_edge_codes: np.ndarray | None = None,
    degree_prune: bool = True,
    row_chunk: int = _ROW_CHUNK,
) -> Tuple[Tuple[int, ...], np.ndarray]:
    """List all matches of ``pattern`` within ``provider``.

    Returns ``(cols, table)`` where ``cols`` is the sorted tuple of pattern
    vertex labels and ``table`` is ``int64[n_matches, len(cols)]`` of data-
    graph vertex ids (columns aligned with ``cols``).
    """
    if pattern.m == 0:
        raise ValueError("pattern needs ≥1 edge")
    start = anchor if anchor is not None else max(pattern.vertices, key=pattern.degree)
    order = plan_extension_order(pattern, start)

    # --- seed the anchor column ---------------------------------------------
    if anchor_to_centers:
        assert isinstance(provider, Partition)
        seeds = provider.center_vertices()
    elif isinstance(provider, Partition):
        seeds = provider.vertices
    else:
        seeds = np.nonzero(provider.degrees > 0)[0].astype(np.int64)
    if degree_prune and seeds.size:
        if isinstance(provider, Partition):
            degs = provider.degrees_of(seeds)
        else:
            degs = provider.degrees[seeds]
        seeds = seeds[degs >= pattern.degree(start)]
    table = seeds.reshape(-1, 1)

    # --- extend vertex by vertex ---------------------------------------------
    for i in range(1, len(order)):
        v = order[i]
        placed = order[:i]
        nbr_cols = [j for j, u in enumerate(placed) if pattern.has_edge(u, v)]
        pivot = nbr_cols[0]
        other_nbrs = nbr_cols[1:]
        ord_checks = _ord_pairs_for(ord_, v, placed)
        col_of = {u: j for j, u in enumerate(placed)}

        chunks = []
        for lo in range(0, table.shape[0], row_chunk):
            sub = table[lo : lo + row_chunk]
            rows = _rows_of(provider, sub[:, pivot])
            starts = provider.indptr[rows]
            counts = provider.indptr[rows + 1] - starts
            rep, cand = ragged_expand(starts, counts, provider.indices)
            if cand.size == 0:
                continue
            mask = np.ones(cand.shape[0], dtype=bool)
            # degree prune (MC₁)
            if degree_prune:
                crow = _rows_of(provider, cand)
                cdeg = provider.indptr[crow + 1] - provider.indptr[crow]
                mask &= cdeg >= pattern.degree(v)
            # injectivity
            for j in range(sub.shape[1]):
                mask &= cand != sub[rep, j]
            # extra edge constraints
            for j in other_nbrs:
                mask &= _has_edges(provider, cand, sub[rep, j])
            # symmetry-breaking order
            for u, greater in ord_checks:
                cu = sub[rep, col_of[u]]
                mask &= (cand > cu) if greater else (cand < cu)
            rep, cand = rep[mask], cand[mask]
            chunks.append(np.concatenate([sub[rep], cand[:, None]], axis=1))
        table = (
            np.concatenate(chunks, axis=0)
            if chunks
            else np.empty((0, i + 1), dtype=np.int64)
        )
        if table.shape[0] == 0:
            break

    # --- optional: at least one pattern edge maps to an inserted edge --------
    if require_edge_codes is not None and table.shape[0]:
        req = np.sort(np.asarray(require_edge_codes, dtype=np.int64))
        col_of = {u: j for j, u in enumerate(order)}
        hit = np.zeros(table.shape[0], dtype=bool)
        for a, b in pattern.edges:
            fa = table[:, col_of[a]]
            fb = table[:, col_of[b]]
            lo = np.minimum(fa, fb)
            hi = np.maximum(fa, fb)
            q = (lo << np.int64(32)) | hi
            pos = np.clip(np.searchsorted(req, q), 0, req.shape[0] - 1)
            hit |= req[pos] == q if req.size else False
        table = table[hit]

    # --- canonical column order ----------------------------------------------
    cols = tuple(sorted(pattern.vertices))
    perm = [order.index(c) for c in cols]
    return cols, table[:, perm] if table.shape[0] else np.empty((0, len(cols)), np.int64)
