"""Vectorized anchored subgraph listing (paper Alg. 1 substrate).

The engine lists matches of a small connected pattern inside either a
full :class:`~repro.core.graph.Graph` or one NP :class:`Partition` by
*frontier-table expansion*: a table of partial matches (one column per
matched pattern vertex) is repeatedly extended by gathering candidate
vertices from the adjacency of an already-matched pivot, then filtering
with vectorized edge/injectivity/order masks.

The *what* of each extension — pivot column, extra edge checks, ord
comparisons, degree thresholds — comes from the backend-agnostic
:class:`~repro.core.plan.UnitPlan` IR, which the padded JAX engine in
``repro.dist.jax_engine`` executes too. This module is only the NumPy
*executor* of that IR.

Constraints supported:

- **anchor→center** (``M_ac``, Lemma 3.1): the anchor column is seeded
  only with center vertices of the partition;
- **ord** (SimB, §II-B): ``f(a) < f(b)`` for ``(a, b) ∈ ord``;
- **inserted-edge requirement** (Nav-join step 2, §VI-B): at least one
  pattern edge must map into ``E_a(U)``;
- **degree pruning** (MC₁ of §IV-D): candidates whose in-scope degree is
  below the pattern degree are dropped early.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from .graph import Graph
from .pattern import Pattern
from .plan import UnitPlan, WcojPlan, build_unit_plan, build_wcoj_plan, plan_extension_order
from .storage import Partition

__all__ = [
    "plan_extension_order",
    "list_matches",
    "list_matches_wcoj",
    "execute_unit_plan",
    "execute_wcoj",
    "wcoj_level_counts",
    "require_edge_rows_mask",
    "ragged_expand",
]

_ROW_CHUNK = 1 << 17


def _rows_of(provider, u: np.ndarray) -> np.ndarray:
    if isinstance(provider, Partition):
        return provider.local_ids(u)
    return u


def _has_edges(provider, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    return provider.has_edges(u, v)


def ragged_expand(starts: np.ndarray, counts: np.ndarray, values: np.ndarray):
    """For row i, yield ``values[starts[i] : starts[i]+counts[i]]``.

    Returns ``(row_index, gathered_values)`` — the core repeat/gather
    primitive shared by adjacency expansion, VCBC decompression, and the
    CC-join pair expansion.
    """
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty((0,), np.int64), values[:0]
    rep = np.repeat(np.arange(counts.shape[0], dtype=np.int64), counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(counts) - counts, counts)
    return rep, values[np.repeat(starts.astype(np.int64), counts) + offs]


def execute_unit_plan(
    provider: Graph | Partition,
    plan: UnitPlan,
    *,
    anchor_to_centers: bool = False,
    require_edge_codes: np.ndarray | None = None,
    degree_prune: bool = True,
    row_chunk: int = _ROW_CHUNK,
) -> np.ndarray:
    """Run a :class:`UnitPlan` on the NumPy substrate.

    Returns ``int64[n_matches, |V|]`` with columns aligned to
    ``plan.cols`` (the extension order).
    """
    # --- seed the anchor column ---------------------------------------------
    if anchor_to_centers:
        assert isinstance(provider, Partition)
        seeds = provider.center_vertices()
    elif isinstance(provider, Partition):
        seeds = provider.vertices
    else:
        seeds = np.nonzero(provider.degrees > 0)[0].astype(np.int64)
    if degree_prune and seeds.size:
        if isinstance(provider, Partition):
            degs = provider.degrees_of(seeds)
        else:
            degs = provider.degrees[seeds]
        seeds = seeds[degs >= plan.anchor_min_degree]
    table = seeds.reshape(-1, 1)

    # --- extend vertex by vertex ---------------------------------------------
    for i, step in enumerate(plan.steps, start=1):
        chunks = []
        for lo in range(0, table.shape[0], row_chunk):
            sub = table[lo : lo + row_chunk]
            rows = _rows_of(provider, sub[:, step.pivot])
            starts = provider.indptr[rows]
            counts = provider.indptr[rows + 1] - starts
            rep, cand = ragged_expand(starts, counts, provider.indices)
            if cand.size == 0:
                continue
            mask = np.ones(cand.shape[0], dtype=bool)
            # degree prune (MC₁)
            if degree_prune:
                crow = _rows_of(provider, cand)
                cdeg = provider.indptr[crow + 1] - provider.indptr[crow]
                mask &= cdeg >= step.min_degree
            # injectivity
            for j in range(sub.shape[1]):
                mask &= cand != sub[rep, j]
            # extra edge constraints
            for j in step.edge_checks:
                mask &= _has_edges(provider, cand, sub[rep, j])
            # symmetry-breaking order
            for j, greater in step.ord_checks:
                cu = sub[rep, j]
                mask &= (cand > cu) if greater else (cand < cu)
            rep, cand = rep[mask], cand[mask]
            chunks.append(np.concatenate([sub[rep], cand[:, None]], axis=1))
        table = (
            np.concatenate(chunks, axis=0)
            if chunks
            else np.empty((0, i + 1), dtype=np.int64)
        )
        if table.shape[0] == 0 and i < len(plan.steps):
            return np.empty((0, len(plan.order)), np.int64)

    # --- optional: at least one pattern edge maps to an inserted edge --------
    if require_edge_codes is not None and table.shape[0]:
        req = np.sort(np.asarray(require_edge_codes, dtype=np.int64))
        table = table[require_edge_rows_mask(table, plan.edge_cols, req)]
    return table if table.shape[0] else np.empty((0, len(plan.order)), np.int64)


def execute_wcoj(
    provider: Graph | Partition,
    plan: WcojPlan,
    *,
    anchor_to_centers: bool = False,
    require_edge_codes: np.ndarray | None = None,
    seed_vertices: np.ndarray | None = None,
    degree_prune: bool = True,
    row_chunk: int = _ROW_CHUNK,
) -> np.ndarray:
    """Run a :class:`WcojPlan` on the NumPy substrate — the reference
    attribute-at-a-time generic join.

    Each level's candidates are the pivot's adjacency list intersected
    (via vectorized membership probes) with the adjacency of every other
    placed neighbor, so intermediate tables are bounded per level rather
    than per binary join. Returns ``int64[n_matches, |V|]`` with columns
    aligned to ``plan.cols``.

    ``seed_vertices`` restricts the anchor seeds to the given vertex set
    — the delta-dataflow hook: a new match's anchor is adjacent to both
    endpoints of some inserted edge (or is one), so seeding from the
    delta-candidate set ``C1 ∪ N_{d'}(C1)`` with ``require_edge_codes``
    set to ``E_a(U)`` yields exactly the batch's new matches.
    """
    if anchor_to_centers:
        assert isinstance(provider, Partition)
        seeds = provider.center_vertices()
    elif isinstance(provider, Partition):
        seeds = provider.vertices
    else:
        seeds = np.nonzero(provider.degrees > 0)[0].astype(np.int64)
    if seed_vertices is not None:
        seeds = seeds[np.isin(seeds, seed_vertices)]
    if degree_prune and seeds.size:
        if isinstance(provider, Partition):
            degs = provider.degrees_of(seeds)
        else:
            degs = provider.degrees[seeds]
        seeds = seeds[degs >= plan.anchor_min_degree]
    table = seeds.reshape(-1, 1)

    for i, level in enumerate(plan.levels, start=1):
        chunks = []
        for lo in range(0, table.shape[0], row_chunk):
            sub = table[lo : lo + row_chunk]
            rows = _rows_of(provider, sub[:, level.pivot])
            starts = provider.indptr[rows]
            counts = provider.indptr[rows + 1] - starts
            rep, cand = ragged_expand(starts, counts, provider.indices)
            if cand.size == 0:
                continue
            mask = np.ones(cand.shape[0], dtype=bool)
            if degree_prune:
                crow = _rows_of(provider, cand)
                cdeg = provider.indptr[crow + 1] - provider.indptr[crow]
                mask &= cdeg >= level.min_degree
            for j in range(sub.shape[1]):
                mask &= cand != sub[rep, j]
            # multiway adjacency intersection against the other placed
            # neighbors — the generic-join step
            for j in level.intersect_cols:
                mask &= _has_edges(provider, cand, sub[rep, j])
            for j, greater in level.ord_checks:
                cu = sub[rep, j]
                mask &= (cand > cu) if greater else (cand < cu)
            rep, cand = rep[mask], cand[mask]
            chunks.append(np.concatenate([sub[rep], cand[:, None]], axis=1))
        table = (
            np.concatenate(chunks, axis=0)
            if chunks
            else np.empty((0, i + 1), dtype=np.int64)
        )
        if table.shape[0] == 0 and i < len(plan.levels):
            return np.empty((0, len(plan.order)), np.int64)

    if require_edge_codes is not None and table.shape[0]:
        req = np.sort(np.asarray(require_edge_codes, dtype=np.int64))
        table = table[require_edge_rows_mask(table, plan.edge_cols, req)]
    return table if table.shape[0] else np.empty((0, len(plan.order)), np.int64)


def wcoj_level_counts(
    provider: Graph | Partition,
    plan: WcojPlan,
    *,
    anchor_to_centers: bool = False,
    degree_prune: bool = True,
    row_chunk: int = _ROW_CHUNK,
) -> Tuple[int, ...]:
    """Exact per-level partial-match table sizes of a WCOJ plan.

    One host pass over ``provider`` recording ``|table|`` after the seed
    and after every extension level — the register-time *calibration
    probe* the sharded backend uses to tighten the compile-time
    (estimator-clamped) device level caps down to the observed sizes.
    Runs the same loop as :func:`execute_wcoj` without keeping the final
    table.
    """
    counts = []
    if anchor_to_centers:
        assert isinstance(provider, Partition)
        seeds = provider.center_vertices()
    elif isinstance(provider, Partition):
        seeds = provider.vertices
    else:
        seeds = np.nonzero(provider.degrees > 0)[0].astype(np.int64)
    if degree_prune and seeds.size:
        if isinstance(provider, Partition):
            degs = provider.degrees_of(seeds)
        else:
            degs = provider.degrees[seeds]
        seeds = seeds[degs >= plan.anchor_min_degree]
    table = seeds.reshape(-1, 1)
    counts.append(int(table.shape[0]))

    for i, level in enumerate(plan.levels, start=1):
        chunks = []
        for lo in range(0, table.shape[0], row_chunk):
            sub = table[lo : lo + row_chunk]
            rows = _rows_of(provider, sub[:, level.pivot])
            starts = provider.indptr[rows]
            cnts = provider.indptr[rows + 1] - starts
            rep, cand = ragged_expand(starts, cnts, provider.indices)
            if cand.size == 0:
                continue
            mask = np.ones(cand.shape[0], dtype=bool)
            if degree_prune:
                crow = _rows_of(provider, cand)
                cdeg = provider.indptr[crow + 1] - provider.indptr[crow]
                mask &= cdeg >= level.min_degree
            for j in range(sub.shape[1]):
                mask &= cand != sub[rep, j]
            for j in level.intersect_cols:
                mask &= _has_edges(provider, cand, sub[rep, j])
            for j, greater in level.ord_checks:
                cu = sub[rep, j]
                mask &= (cand > cu) if greater else (cand < cu)
            rep, cand = rep[mask], cand[mask]
            chunks.append(np.concatenate([sub[rep], cand[:, None]], axis=1))
        table = (
            np.concatenate(chunks, axis=0)
            if chunks
            else np.empty((0, i + 1), dtype=np.int64)
        )
        counts.append(int(table.shape[0]))
    return tuple(counts)


def require_edge_rows_mask(
    table: np.ndarray,
    col_pairs: Sequence[Tuple[int, int]],
    sorted_codes: np.ndarray,
) -> np.ndarray:
    """Rows mapping ≥1 of the given column-pair edges into the *sorted*
    edge-code set — the inserted-edge requirement of a Nav-join seed
    (§VI-B step 2). The one host implementation of this filter: the
    plan executor applies it after a restricted listing and the
    unit-table cache applies it to re-seed a cached full listing, so
    the two stay bit-identical by construction.
    """
    hit = np.zeros(table.shape[0], dtype=bool)
    if not sorted_codes.size or not table.shape[0]:
        return hit
    for ia, ib in col_pairs:
        fa, fb = table[:, ia], table[:, ib]
        lo, hi = np.minimum(fa, fb), np.maximum(fa, fb)
        q = (lo << np.int64(32)) | hi
        pos = np.clip(np.searchsorted(sorted_codes, q), 0, sorted_codes.shape[0] - 1)
        hit |= sorted_codes[pos] == q
    return hit


def list_matches(
    provider: Graph | Partition,
    pattern: Pattern,
    ord_: Sequence[Tuple[int, int]] = (),
    *,
    anchor: int | None = None,
    anchor_to_centers: bool = False,
    require_edge_codes: np.ndarray | None = None,
    degree_prune: bool = True,
    row_chunk: int = _ROW_CHUNK,
) -> Tuple[Tuple[int, ...], np.ndarray]:
    """List all matches of ``pattern`` within ``provider``.

    Compiles a :class:`UnitPlan` and executes it, then permutes columns
    to the canonical sorted label order. Returns ``(cols, table)`` where
    ``cols`` is the sorted tuple of pattern vertex labels and ``table``
    is ``int64[n_matches, len(cols)]`` of data-graph vertex ids.
    """
    plan = build_unit_plan(pattern, anchor, ord_)
    table = execute_unit_plan(
        provider, plan,
        anchor_to_centers=anchor_to_centers,
        require_edge_codes=require_edge_codes,
        degree_prune=degree_prune,
        row_chunk=row_chunk,
    )
    cols = tuple(sorted(pattern.vertices))
    perm = [plan.order.index(c) for c in cols]
    return cols, table[:, perm] if table.shape[0] else np.empty((0, len(cols)), np.int64)


def list_matches_wcoj(
    provider: Graph | Partition,
    pattern: Pattern,
    ord_: Sequence[Tuple[int, int]] = (),
    *,
    anchor: int | None = None,
    anchor_to_centers: bool = False,
    require_edge_codes: np.ndarray | None = None,
    degree_prune: bool = True,
    row_chunk: int = _ROW_CHUNK,
) -> Tuple[Tuple[int, ...], np.ndarray]:
    """List all matches of ``pattern`` via the generic-join executor.

    Same contract as :func:`list_matches`: compiles a :class:`WcojPlan`,
    executes it, and permutes columns to sorted label order.
    """
    plan = build_wcoj_plan(pattern, anchor, ord_)
    table = execute_wcoj(
        provider, plan,
        anchor_to_centers=anchor_to_centers,
        require_edge_codes=require_edge_codes,
        degree_prune=degree_prune,
        row_chunk=row_chunk,
    )
    cols = tuple(sorted(pattern.vertices))
    perm = [plan.order.index(c) for c in cols]
    return cols, table[:, perm] if table.shape[0] else np.empty((0, len(cols)), np.int64)
