"""Delta-maintained per-partition unit-table cache (§VI-B `fixed`-cost killer).

Both the Nav-join chain steps and the seed listings of a streaming
micro-batch re-list every join unit's full per-partition match table
``M_ac(q, d'_j)`` — the dominant batch-size-independent (`fixed`) term
of the §IV-D scheduler cost model. But a unit table is an *independent
per-partition artifact*: Lemma 3.1's anchor→center rule makes
``M_ac(q, d_j)`` a pure function of partition ``j``'s stored edges, so
it stays byte-identical across batches until ``E_j`` itself changes.
The Alg. 4 candidate sets name exactly which partitions a batch can
dirty (:attr:`~repro.core.storage.UpdateCostReport.dirty_parts`), so
caching unit tables with candidate-driven invalidation is sound —
per-batch listing work shrinks from ``|units| · m`` tables to
``|units| · |dirty|``.

:class:`PartitionUnitCache` is that cache: it maps ``(unit key, anchor,
restricted ord, partition)`` to the *plain* listed table (the expensive
half) and ``(..., cover)`` to the VCBC-compressed form the chain steps
consume. It implements the :class:`ListingProvider` protocol that
:func:`repro.core.navjoin.nav_join_patch` chain steps and the
:meth:`repro.stream.scheduler.SharedDelta.seed_provider` pull through.
Hits, misses and invalidations are counted on the object (the streaming
layer mirrors them into ``stream.scheduler.PROBE``) — cache behavior is
asserted in tests, never assumed.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional, Protocol, Sequence, Tuple

import numpy as np

from .match_engine import list_matches, require_edge_rows_mask
from .pattern import Pattern, R1Unit
from .storage import NPStorage
from .vcbc import CompressedTable, Ragged, compress_table

__all__ = ["ListingProvider", "PartitionUnitCache", "take_groups"]


def _restrict_ord(ord_: Sequence[Tuple[int, int]], vs) -> frozenset:
    """The *set* of ord pairs scoped to a unit's vertices — the part of
    ``ord`` a unit listing can observe (checks are conjunctive, so pair
    order is irrelevant; anything less would alias distinct listings)."""
    vset = set(vs)
    return frozenset((a, b) for a, b in ord_ if a in vset and b in vset)


def take_groups(table: CompressedTable, keep: np.ndarray) -> CompressedTable:
    """Subset a compressed table to the groups flagged in ``keep``.

    Value sets travel untouched (every kept group keeps all its values),
    so this is the compressed twin of filtering plain rows *before*
    compression by any predicate that is constant within a skeleton
    group — e.g. the Nav-join anchor-candidate restriction.
    """
    keep = np.asarray(keep, bool)
    if keep.all():
        return table
    keep_idx = np.nonzero(keep)[0]
    remap = -np.ones(table.n_groups, dtype=np.int64)
    remap[keep_idx] = np.arange(keep_idx.shape[0])
    comp = {}
    for v, r in table.comp.items():
        gids = np.repeat(np.arange(r.n_groups, dtype=np.int64), r.counts())
        sel = keep[gids]
        comp[v] = Ragged.from_group_ids(remap[gids[sel]], r.values[sel],
                                        keep_idx.shape[0])
    return CompressedTable(
        pattern=table.pattern, cover=table.cover,
        skeleton_cols=table.skeleton_cols,
        skeleton=table.skeleton[keep_idx], comp=comp,
    )


class ListingProvider(Protocol):
    """What the Nav-join chain steps require from a listing source.

    ``storage`` names the Φ(d') the tables are listed from — callers
    assert it is the storage they are patching against, so a stale
    provider can never silently serve tables of an older graph.
    """

    storage: NPStorage

    def unit_plain(self, part_idx: int, unit: R1Unit, anchor: int,
                   ord_: Sequence[Tuple[int, int]]) -> Tuple[Tuple[int, ...], np.ndarray]:
        """Full plain ``M_ac(unit, d'_j)`` of one partition."""
        ...

    def unit_compressed(self, part_idx: int, unit: R1Unit,
                        cover: Sequence[int], ord_: Sequence[Tuple[int, int]],
                        anchor_candidates: np.ndarray | None = None) -> CompressedTable:
        """Compressed ``M_ac(unit, d'_j)``, optionally anchor-restricted."""
        ...


@dataclasses.dataclass
class CacheStats:
    """Monotone counters; consumers diff them for per-batch numbers."""

    hits: int = 0
    misses: int = 0
    invalidated_parts: int = 0
    #: LRU evictions under an entry/byte budget (0 when unbudgeted).
    #: Deliberately NOT part of :meth:`snapshot` — existing consumers
    #: unpack the 3-tuple positionally.
    evictions: int = 0

    def snapshot(self) -> Tuple[int, int, int]:
        return (self.hits, self.misses, self.invalidated_parts)


class PartitionUnitCache:
    """Delta-maintained map ``(unit, anchor, ord, partition) → table``.

    Two layers share one invalidation domain:

    - the **plain** layer holds the listed match table per partition —
      the expensive artifact (frontier expansion + edge probes); misses
      here are the only actual re-listings and are what
      :attr:`stats.misses <CacheStats.misses>` counts;
    - the **compressed** layer memoizes the cover-specific VCBC
      regrouping of a plain entry (cheap, but paid once per chain step
      per batch otherwise). It is derived state: invalidating a
      partition drops both layers.

    :meth:`advance` moves the cache to the next watermark's Φ(d'),
    invalidating exactly the partitions the batch dirtied
    (:attr:`~repro.core.storage.UpdateCostReport.dirty_parts` — sound
    because a unit table is a pure function of its partition's edge
    set). Everything a consumer reads afterwards is byte-identical to
    listing directly from the new storage (property-tested).

    An optional memory budget (``max_entries`` live plain entries /
    ``max_bytes`` resident bytes, either or both) bounds the cache with
    LRU eviction over (plain key, partition) units; derived compressed
    entries are evicted with their plain parent. Evictions are counted
    in :attr:`stats.evictions <CacheStats.evictions>` and
    :attr:`resident_bytes` tracks the live footprint — both surface in
    the streaming layer's metrics registry.
    """

    def __init__(self, storage: NPStorage,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        self.storage = storage
        self.stats = CacheStats()
        # Optional memory budget: at most `max_entries` live plain
        # entries and/or `max_bytes` resident bytes (plain + derived
        # compressed tables). Over budget, the least-recently-used
        # (plain key, partition) entry is evicted together with its
        # derived compressed entries — correctness is untouched (an
        # evicted entry is a future miss, re-listed byte-identically),
        # only the §VI-B `fixed`-cost amortization shrinks.
        self.max_entries = None if max_entries is None else max(1, int(max_entries))
        self.max_bytes = None if max_bytes is None else max(0, int(max_bytes))
        self.resident_bytes = 0
        # (unit key, anchor, restricted-ord) → part_idx → (cols, table)
        self._plain: Dict[Tuple, Dict[int, Tuple[Tuple[int, ...], np.ndarray]]] = {}
        # (unit key, anchor, restricted-ord, cover) → part_idx → CompressedTable
        self._comp: Dict[Tuple, Dict[int, CompressedTable]] = {}
        # LRU order + byte accounting over (plain key, part_idx) units.
        self._lru: "OrderedDict[Tuple[Tuple, int], None]" = OrderedDict()
        self._entry_bytes: Dict[Tuple[Tuple, int], int] = {}

    # --------------------------------------------------------------- budget
    @staticmethod
    def _comp_nbytes(t: CompressedTable) -> int:
        n = int(t.skeleton.nbytes)
        for r in t.comp.values():
            n += int(np.asarray(r.offsets).nbytes) + int(np.asarray(r.values).nbytes)
        return n

    def _account(self, lru_key: Tuple[Tuple, int], nbytes: int) -> None:
        self._entry_bytes[lru_key] = self._entry_bytes.get(lru_key, 0) + int(nbytes)
        self.resident_bytes += int(nbytes)

    def _forget(self, lru_key: Tuple[Tuple, int]) -> None:
        """Drop one LRU unit's accounting (entry data handled by caller)."""
        self._lru.pop(lru_key, None)
        self.resident_bytes -= self._entry_bytes.pop(lru_key, 0)

    def _drop_entry(self, lru_key: Tuple[Tuple, int]) -> None:
        """Remove one (plain key, part) entry and its derived compressed
        tables from both layers."""
        pk, part = lru_key
        per_part = self._plain.get(pk)
        if per_part is not None:
            per_part.pop(part, None)
        for ck, cp in self._comp.items():
            if ck[:3] == pk:
                cp.pop(part, None)
        self._forget(lru_key)

    def _over_budget(self) -> bool:
        if self.max_entries is not None and len(self._lru) > self.max_entries:
            return True
        if self.max_bytes is not None and self.resident_bytes > self.max_bytes:
            return True
        return False

    def _evict_over_budget(self) -> None:
        # Never evict the most recently touched entry: a single entry
        # larger than max_bytes would otherwise thrash forever.
        while self._over_budget() and len(self._lru) > 1:
            oldest = next(iter(self._lru))
            self._drop_entry(oldest)
            self.stats.evictions += 1

    # ------------------------------------------------------------ maintenance
    def advance(self, storage: NPStorage, dirty_parts: Sequence[int]) -> int:
        """Rebind to the updated Φ(d'), dropping dirty partitions' entries.

        Returns the number of invalidated partitions. Binding to a
        storage with a different partition count resets the cache (a
        resharding invalidates everything).
        """
        if storage.m != self.storage.m:
            self.clear()
            self.storage = storage
            self.stats.invalidated_parts += storage.m
            return storage.m
        dirty = sorted({int(j) for j in dirty_parts})
        dirty_set = set(dirty)
        for j in dirty:
            for per_part in self._plain.values():
                per_part.pop(j, None)
            for per_part in self._comp.values():
                per_part.pop(j, None)
        if dirty_set:
            for lk in [k for k in self._lru if k[1] in dirty_set]:
                self._forget(lk)
        self.storage = storage
        self.stats.invalidated_parts += len(dirty)
        return len(dirty)

    def clear(self) -> None:
        self._plain.clear()
        self._comp.clear()
        self._lru.clear()
        self._entry_bytes.clear()
        self.resident_bytes = 0

    def entries(self) -> int:
        """Live plain entries (≤ |unit keys| · m) — memory introspection."""
        return sum(len(d) for d in self._plain.values())

    # ------------------------------------------------------------- the tables
    def unit_plain(self, part_idx: int, unit: R1Unit, anchor: int,
                   ord_: Sequence[Tuple[int, int]]) -> Tuple[Tuple[int, ...], np.ndarray]:
        """Cached full ``M_ac(unit, d_j)`` as ``(cols, plain table)``."""
        if anchor is None:
            raise ValueError("unit anchor must lie inside the cover")
        key = (unit.pattern.key(), int(anchor),
               _restrict_ord(ord_, unit.pattern.vertices))
        per_part = self._plain.setdefault(key, {})
        lru_key = (key, part_idx)
        if part_idx not in per_part:
            self.stats.misses += 1
            cols, table = list_matches(
                self.storage.parts[part_idx], unit.pattern, ord_,
                anchor=int(anchor), anchor_to_centers=True,
            )
            per_part[part_idx] = (cols, table)
            self._lru[lru_key] = None
            self._account(lru_key, table.nbytes)
            self._evict_over_budget()
        else:
            self.stats.hits += 1
            self._lru.move_to_end(lru_key)
        return per_part[part_idx]

    def unit_compressed(self, part_idx: int, unit: R1Unit,
                        cover: Sequence[int], ord_: Sequence[Tuple[int, int]],
                        anchor_candidates: np.ndarray | None = None) -> CompressedTable:
        """Cached compressed ``M_ac(unit, d_j)`` under ``cover``; the
        anchor-candidate restriction (which changes every chain step) is
        applied on top as a group filter, never cached."""
        cover_t = tuple(sorted(int(c) for c in cover))
        anchor = unit.anchor_in(cover_t)
        if anchor is None:
            raise ValueError("unit anchor must lie inside the cover")
        key = (unit.pattern.key(), int(anchor),
               _restrict_ord(ord_, unit.pattern.vertices), cover_t)
        per_part = self._comp.setdefault(key, {})
        if part_idx not in per_part:
            cols, table = self.unit_plain(part_idx, unit, anchor, ord_)
            comp = compress_table(unit.pattern, cover_t, cols, table)
            per_part[part_idx] = comp
            # Derived state rides on its plain entry's LRU slot (the
            # unit_plain call above just touched it, so it exists and is
            # most-recent — never evicted by this accounting).
            self._account((key[:3], part_idx), self._comp_nbytes(comp))
            self._evict_over_budget()
        t = per_part[part_idx]
        if anchor_candidates is not None and t.n_groups:
            aidx = t.skeleton_cols.index(anchor)
            t = take_groups(t, np.isin(t.skeleton[:, aidx], anchor_candidates))
        return t

    # ------------------------------------------------------------------ seeds
    def seed_fn(self, cover: Sequence[int], ord_: Sequence[Tuple[int, int]],
                add_codes: np.ndarray):
        """A Nav-join ``seed_fn`` deriving ``M_new(q, d', q)`` from the
        cached full tables: the inserted-edge requirement (§VI-B step 2)
        is a row filter over the cached listing — zero re-listing on
        clean partitions. Byte-identical to listing with
        ``require_edge_codes`` directly (the engine applies that
        restriction as the same post-filter).
        """
        cover_t = tuple(sorted(int(c) for c in cover))
        codes = np.sort(np.asarray(add_codes, np.int64).reshape(-1))

        def fn(unit: R1Unit) -> CompressedTable:
            anchor = unit.anchor_in(cover_t)
            if anchor is None:
                raise ValueError("unit anchor must lie inside the cover")
            pieces = []
            cols: Tuple[int, ...] | None = None
            for pi in range(self.storage.m):
                cols, table = self.unit_plain(pi, unit, anchor, ord_)
                pieces.append(require_edge_rows(cols, table, unit.pattern, codes))
            table = (np.concatenate(pieces, axis=0) if pieces
                     else np.empty((0, unit.pattern.n), np.int64))
            return compress_table(unit.pattern, cover_t, cols, table)

        return fn


def require_edge_rows(cols: Sequence[int], table: np.ndarray,
                      pattern: Pattern, sorted_codes: np.ndarray) -> np.ndarray:
    """Rows mapping ≥1 pattern edge into the (sorted) edge-code set —
    the same :func:`~repro.core.match_engine.require_edge_rows_mask`
    filter the engine applies after a restricted listing, addressed by
    column labels instead of plan-order indices."""
    if not table.shape[0] or not sorted_codes.size:
        return table[:0]
    col_of = {c: j for j, c in enumerate(cols)}
    pairs = [(col_of[a], col_of[b]) for a, b in pattern.edges]
    return table[require_edge_rows_mask(table, pairs, sorted_codes)]
