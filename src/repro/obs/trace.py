"""Low-overhead hierarchical span tracer for the streaming service.

A *span* is a named, timed region with attached counters and child
spans.  The stream stack opens one root span per micro-batch and nests
the stage spans under it::

    batch                      (n_ops, watermark, cache/host counters)
    ├── shared_delta           (decode + Alg.4 candidates, once per batch)
    ├── storage_update         (Φ(d') edge apply; device diag on sharded)
    ├── maintain  ×P           (one per pattern: patch/store counters)
    │   └── materialize        (device→host pull, when matches wanted)
    └── sinks                  (delivery callbacks)

Disabled tracing is the default and costs one attribute read per
``span()`` call: the tracer hands back a process-wide no-op span, so
instrumented code needs no ``if tracer.enabled`` guards and the traced
code path is byte-identical to the un-instrumented one.

Exports: :meth:`Tracer.to_jsonl` (one JSON object per span, flat with
``span_id``/``parent_id`` links) and :meth:`Tracer.to_chrome_trace`
(Chrome trace-event JSON — open in Perfetto via https://ui.perfetto.dev
or ``chrome://tracing``).
"""

from __future__ import annotations

import json
import time
from typing import Dict, Iterator, List, Optional

__all__ = ["Span", "NULL_SPAN", "Tracer"]


class Span:
    """One timed region. Use via ``with tracer.span(name, **attrs):``.

    ``attrs`` are static annotations (pattern name, batch index);
    ``counters`` accumulate via :meth:`add` and are what the span-tree
    tests reconcile against registry deltas.
    """

    __slots__ = ("name", "attrs", "counters", "children",
                 "t0_ns", "dur_ns", "span_id", "parent_id")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 attrs: Dict[str, object]):
        self.name = name
        self.attrs = attrs
        self.counters: Dict[str, float] = {}
        self.children: List["Span"] = []
        self.t0_ns = 0
        self.dur_ns = 0
        self.span_id = span_id
        self.parent_id = parent_id

    # ------------------------------------------------------------ annotation
    def add(self, key: str, n: float = 1.0) -> None:
        """Accumulate a counter on this span."""
        self.counters[key] = self.counters.get(key, 0.0) + n

    def set(self, **attrs: object) -> None:
        self.attrs.update(attrs)

    # ----------------------------------------------------------- introspection
    @property
    def dur_s(self) -> float:
        return self.dur_ns / 1e9

    def child(self, name: str) -> Optional["Span"]:
        for c in self.children:
            if c.name == name:
                return c
        return None

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def skeleton(self) -> tuple:
        """Nested name structure — what the shape tests compare."""
        return (self.name, tuple(c.skeleton() for c in self.children))

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, dur={self.dur_ns / 1e6:.3f}ms, "
                f"children={[c.name for c in self.children]})")


class _NullSpan:
    """No-op stand-in handed out while tracing is disabled.

    A single shared instance; every method is a cheap no-op so call
    sites never branch on ``tracer.enabled``.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def add(self, key: str, n: float = 1.0) -> None:
        pass

    def set(self, **attrs: object) -> None:
        pass


NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context manager binding a live span to the tracer's open stack."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._stack.append(self._span)
        self._span.t0_ns = time.perf_counter_ns()
        return self._span

    def __exit__(self, *exc) -> bool:
        sp = self._span
        sp.dur_ns = time.perf_counter_ns() - sp.t0_ns
        stack = self._tracer._stack
        # Pop to (and including) our span even if an exception skipped
        # inner __exit__s — the tree stays consistent under errors.
        while stack:
            top = stack.pop()
            if top is sp:
                break
        if stack:
            stack[-1].children.append(sp)
        else:
            self._tracer._finish_root(sp)
        return False


class Tracer:
    """Span factory + completed-root store.

    ``enabled=False`` (the default) short-circuits :meth:`span` to the
    shared :data:`NULL_SPAN`.  Completed root spans accumulate in
    :attr:`roots`, bounded by ``max_roots`` (oldest dropped first;
    drops counted in :attr:`dropped_roots`).
    """

    def __init__(self, enabled: bool = False, max_roots: int = 100_000):
        self.enabled = enabled
        self.max_roots = max_roots
        self.roots: List[Span] = []
        self.dropped_roots = 0
        self._stack: List[Span] = []
        self._next_id = 1
        # One wall-clock anchor so perf_counter spans map to epoch time
        # in exports (Chrome traces want a shared timeline).
        self._epoch_ns = time.time_ns()
        self._perf0_ns = time.perf_counter_ns()

    def span(self, name: str, **attrs: object):
        if not self.enabled:
            return NULL_SPAN
        parent_id = self._stack[-1].span_id if self._stack else None
        sp = Span(name, self._next_id, parent_id, attrs)
        self._next_id += 1
        return _SpanCtx(self, sp)

    def _finish_root(self, sp: Span) -> None:
        self.roots.append(sp)
        if len(self.roots) > self.max_roots:
            drop = len(self.roots) - self.max_roots
            del self.roots[:drop]
            self.dropped_roots += drop

    # ------------------------------------------------------------- lifecycle
    def clear(self) -> None:
        self.roots.clear()
        self._stack.clear()
        self.dropped_roots = 0

    def drain(self) -> List[Span]:
        """Return and forget all completed roots."""
        out = self.roots
        self.roots = []
        return out

    # --------------------------------------------------------------- exports
    def _wall_us(self, t_ns: int) -> float:
        return (self._epoch_ns + (t_ns - self._perf0_ns)) / 1e3

    def to_jsonl(self, path: str) -> int:
        """One JSON object per span (depth-first), flat with
        ``span_id``/``parent_id`` links. Returns the span count."""
        n = 0
        with open(path, "w") as f:
            for root in self.roots:
                for sp in root.walk():
                    rec = {
                        "name": sp.name,
                        "span_id": sp.span_id,
                        "parent_id": sp.parent_id,
                        "ts_us": self._wall_us(sp.t0_ns),
                        "dur_us": sp.dur_ns / 1e3,
                        "attrs": sp.attrs,
                        "counters": sp.counters,
                    }
                    f.write(json.dumps(rec) + "\n")
                    n += 1
        return n

    def to_chrome_trace(self, path: str, pid: int = 1, tid: int = 1) -> int:
        """Chrome trace-event export (Perfetto-loadable).

        Complete events (``ph="X"``) with microsecond ``ts``/``dur``;
        span attrs and counters travel in ``args``. Returns the event
        count."""
        events = []
        for root in self.roots:
            for sp in root.walk():
                args = dict(sp.attrs)
                args.update(sp.counters)
                events.append({
                    "name": sp.name,
                    "cat": "stream",
                    "ph": "X",
                    "ts": self._wall_us(sp.t0_ns),
                    "dur": sp.dur_ns / 1e3,
                    "pid": pid,
                    "tid": tid,
                    "args": args,
                })
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return len(events)
