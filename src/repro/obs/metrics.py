"""Typed metrics registry — counters, gauges, fixed-bucket histograms.

The streaming stack previously reported through two ad-hoc channels: the
process-global ``stream.scheduler.PROBE`` dict (clobbered when two
services share a process) and one-off fields on ``BatchMetrics``. This
module replaces both with *named, labeled instruments* owned by a
:class:`MetricsRegistry` — one registry per :class:`~repro.stream.service.ListingService`,
so concurrent services never share counters — exposed as Prometheus
text format (:meth:`MetricsRegistry.to_prometheus`) and JSON snapshots
(:meth:`MetricsRegistry.snapshot`).

Design constraints (this sits on the per-batch hot path):

- instrument lookup is one dict ``get``; updates are one float add —
  no locks, no string formatting until exposition time;
- instruments are created lazily and idempotently: calling
  ``registry.counter("x")`` twice returns the same object (the first
  call's ``help``/``buckets`` win), so call sites don't need a shared
  catalog module;
- exposition is deterministic (sorted instrument names, sorted label
  values) so golden tests can compare exact text.

:class:`ProbeView` is the deprecation shim that keeps the old
``PROBE["key"] += 1`` / ``reset_probe()`` surface alive on top of a
registry (see ``stream/scheduler.py``).
"""

from __future__ import annotations

import json
import math
import time
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ProbeView",
    "DEFAULT_LATENCY_BUCKETS",
]

# Seconds-scale latency buckets: 100µs .. 30s, roughly ×3 per step.
# Fixed (never adaptive) so histograms from different runs are mergeable.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.0003, 0.001, 0.003, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0,
)

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(label_names: Sequence[str], kv: Mapping[str, str]) -> _LabelKey:
    if set(kv) != set(label_names):
        raise ValueError(
            f"labels {sorted(kv)} do not match declared {sorted(label_names)}")
    return tuple((n, str(kv[n])) for n in label_names)


def _fmt_labels(key: _LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{n}="{_escape(v)}"' for n, v in key)
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class _Instrument:
    """Shared shell: a name, help text, label schema, per-labelset cells."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names: Tuple[str, ...] = tuple(labels)

    def _cells(self) -> Iterator[Tuple[_LabelKey, object]]:  # pragma: no cover
        raise NotImplementedError


class Counter(_Instrument):
    """Monotone counter. ``inc(n)`` with n ≥ 0; reads via :attr:`value`."""

    kind = "counter"

    def __init__(self, name: str, help: str = "", labels: Sequence[str] = ()):
        super().__init__(name, help, labels)
        self._vals: Dict[_LabelKey, float] = {}

    def labels(self, **kv: str) -> "_BoundCounter":
        return _BoundCounter(self, _label_key(self.label_names, kv))

    def inc(self, n: float = 1.0) -> None:
        if self.label_names:
            raise ValueError(f"counter {self.name} requires labels()")
        self._inc((), n)

    def _inc(self, key: _LabelKey, n: float) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self._vals[key] = self._vals.get(key, 0.0) + n

    @property
    def value(self) -> float:
        return self._vals.get((), 0.0)

    def value_for(self, **kv: str) -> float:
        return self._vals.get(_label_key(self.label_names, kv), 0.0)

    def _cells(self):
        return iter(sorted(self._vals.items()))


class _BoundCounter:
    __slots__ = ("_c", "_key")

    def __init__(self, c: Counter, key: _LabelKey):
        self._c, self._key = c, key

    def inc(self, n: float = 1.0) -> None:
        self._c._inc(self._key, n)

    @property
    def value(self) -> float:
        return self._c._vals.get(self._key, 0.0)


class Gauge(_Instrument):
    """Point-in-time value; ``set`` / ``inc`` / ``dec``."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels: Sequence[str] = ()):
        super().__init__(name, help, labels)
        self._vals: Dict[_LabelKey, float] = {}

    def labels(self, **kv: str) -> "_BoundGauge":
        return _BoundGauge(self, _label_key(self.label_names, kv))

    def set(self, v: float) -> None:
        if self.label_names:
            raise ValueError(f"gauge {self.name} requires labels()")
        self._vals[()] = float(v)

    def inc(self, n: float = 1.0) -> None:
        if self.label_names:
            raise ValueError(f"gauge {self.name} requires labels()")
        self._vals[()] = self._vals.get((), 0.0) + n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        return self._vals.get((), 0.0)

    def value_for(self, **kv: str) -> float:
        return self._vals.get(_label_key(self.label_names, kv), 0.0)

    def _cells(self):
        return iter(sorted(self._vals.items()))


class _BoundGauge:
    __slots__ = ("_g", "_key")

    def __init__(self, g: Gauge, key: _LabelKey):
        self._g, self._key = g, key

    def set(self, v: float) -> None:
        self._g._vals[self._key] = float(v)

    def inc(self, n: float = 1.0) -> None:
        self._g._vals[self._key] = self._g._vals.get(self._key, 0.0) + n

    @property
    def value(self) -> float:
        return self._g._vals.get(self._key, 0.0)


class _HistCell:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)  # +1 for the implicit +Inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Instrument):
    """Fixed-bucket histogram (cumulative-on-exposition, raw per-bucket
    counts internally). Buckets are ascending upper bounds; +Inf is
    implicit."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, labels)
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram {name} buckets must be ascending")
        self.buckets = bs
        self._cells_by_key: Dict[_LabelKey, _HistCell] = {}

    def labels(self, **kv: str) -> "_BoundHistogram":
        return _BoundHistogram(self, _label_key(self.label_names, kv))

    def observe(self, v: float) -> None:
        if self.label_names:
            raise ValueError(f"histogram {self.name} requires labels()")
        self._observe((), v)

    def _observe(self, key: _LabelKey, v: float) -> None:
        cell = self._cells_by_key.get(key)
        if cell is None:
            cell = self._cells_by_key[key] = _HistCell(len(self.buckets))
        v = float(v)
        # First bucket whose upper bound >= v; linear scan is fine for
        # ~12 buckets and avoids bisect import on the hot path.
        i = 0
        n = len(self.buckets)
        while i < n and v > self.buckets[i]:
            i += 1
        cell.counts[i] += 1
        cell.sum += v
        cell.count += 1

    def cell(self, **kv: str) -> Optional[_HistCell]:
        key = _label_key(self.label_names, kv) if kv else ()
        return self._cells_by_key.get(key)

    def _cells(self):
        return iter(sorted(self._cells_by_key.items()))


class _BoundHistogram:
    __slots__ = ("_h", "_key")

    def __init__(self, h: Histogram, key: _LabelKey):
        self._h, self._key = h, key

    def observe(self, v: float) -> None:
        self._h._observe(self._key, v)


class MetricsRegistry:
    """Named instrument store with lazy, idempotent creation.

    ``registry.counter(name)`` returns the existing instrument when one
    with that name is already registered (first declaration's metadata
    wins); asking for the same name with a *different kind* is a bug and
    raises.
    """

    def __init__(self):
        self._instruments: Dict[str, _Instrument] = {}

    # ------------------------------------------------------------- factories
    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS) -> Histogram:
        inst = self._instruments.get(name)
        if inst is None:
            inst = Histogram(name, help, labels, buckets)
            self._instruments[name] = inst
        elif not isinstance(inst, Histogram):
            raise TypeError(f"{name} is a {inst.kind}, not a histogram")
        return inst

    def _get_or_make(self, cls, name: str, help: str, labels: Sequence[str]):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, labels)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(f"{name} is a {inst.kind}, not a {cls.kind}")
        return inst

    # -------------------------------------------------------------- accessors
    def get(self, name: str) -> Optional[_Instrument]:
        return self._instruments.get(name)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def reset(self) -> None:
        """Drop every instrument. Explicit, whole-registry semantics —
        the per-service replacement for the old ``reset_probe()``."""
        self._instruments.clear()

    # ------------------------------------------------------------- exposition
    def to_prometheus(self) -> str:
        """Prometheus text exposition format, deterministically ordered."""
        out: List[str] = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if inst.help:
                out.append(f"# HELP {name} {inst.help}")
            out.append(f"# TYPE {name} {inst.kind}")
            if isinstance(inst, Histogram):
                for key, cell in inst._cells():
                    cum = 0
                    for ub, c in zip(inst.buckets, cell.counts):
                        cum += c
                        lk = key + (("le", _fmt_value(ub)),)
                        out.append(f"{name}_bucket{_fmt_labels(lk)} {cum}")
                    cum += cell.counts[-1]
                    lk = key + (("le", "+Inf"),)
                    out.append(f"{name}_bucket{_fmt_labels(lk)} {cum}")
                    out.append(f"{name}_sum{_fmt_labels(key)} {_fmt_value(cell.sum)}")
                    out.append(f"{name}_count{_fmt_labels(key)} {cell.count}")
            else:
                for key, v in inst._cells():
                    out.append(f"{name}{_fmt_labels(key)} {_fmt_value(v)}")
        return "\n".join(out) + ("\n" if out else "")

    def snapshot(self) -> Dict[str, dict]:
        """JSON-able snapshot: name → {type, help, values}."""
        snap: Dict[str, dict] = {}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            entry: Dict[str, object] = {"type": inst.kind, "help": inst.help}
            if isinstance(inst, Histogram):
                cells = {}
                for key, cell in inst._cells():
                    cells[_fmt_labels(key) or "{}"] = {
                        "buckets": list(inst.buckets),
                        "counts": list(cell.counts),
                        "sum": cell.sum,
                        "count": cell.count,
                    }
                entry["values"] = cells
            else:
                entry["values"] = {
                    (_fmt_labels(key) or "{}"): v for key, v in inst._cells()
                }
            snap[name] = entry
        return snap

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"generated_unix_s": time.time(),
                       "metrics": self.snapshot()}, f, indent=2, sort_keys=True)

    def save_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_prometheus())


class ProbeView:
    """Dict-shaped deprecation shim over registry counters.

    Preserves the historical ``stream.scheduler.PROBE`` surface —
    ``PROBE["k"] += 1``, ``PROBE["k"]``, ``reset_probe()`` — while the
    actual storage is a :class:`MetricsRegistry` counter per key.  Keys
    are a *fixed* set (reads and writes of unknown keys raise
    ``KeyError``, matching the old literal-dict behavior where every
    consumer indexed the seeded keys).

    Reset semantics are explicit: :meth:`reset` zeroes exactly the
    probe-backed counters of the backing registry and nothing else.
    Note the view is still process-global when reached via
    ``stream.scheduler.PROBE`` — per-service isolation comes from each
    ``ListingService`` owning its *own* registry; the global view only
    aggregates (it is kept for legacy tests/scripts and will be removed
    once callers migrate to ``service.obs.metrics``).
    """

    def __init__(self, registry: MetricsRegistry, keys: Sequence[str],
                 prefix: str = "probe_"):
        self._registry = registry
        self._prefix = prefix
        self._keys = tuple(keys)
        self._counters = {
            k: registry.counter(prefix + k, f"legacy PROBE counter {k!r}")
            for k in self._keys
        }

    def _check(self, key: str) -> str:
        if key not in self._counters:
            raise KeyError(key)
        return key

    def __getitem__(self, key: str) -> int:
        return int(self._counters[self._check(key)].value)

    def __setitem__(self, key: str, value: int) -> None:
        # `PROBE[k] += n` desugars to a read then this write; counters
        # are monotone so only forward writes are representable.
        self._check(key)
        cur = self._counters[key].value
        delta = float(value) - cur
        if delta < 0:
            raise ValueError(
                f"PROBE[{key!r}] is monotone between resets; use reset_probe()")
        if delta:
            self._counters[key]._inc((), delta)

    def _inc(self, key: str, n: int = 1) -> None:
        self._counters[self._check(key)]._inc((), float(n))

    def __iter__(self) -> Iterator[str]:
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def __contains__(self, key: object) -> bool:
        return key in self._counters

    def keys(self):
        return list(self._keys)

    def items(self):
        return [(k, self[k]) for k in self._keys]

    def values(self):
        return [self[k] for k in self._keys]

    def copy(self) -> Dict[str, int]:
        return dict(self.items())

    def reset(self) -> None:
        for c in self._counters.values():
            c._vals.clear()

    def __repr__(self) -> str:  # debugging convenience
        return f"ProbeView({dict(self.items())!r})"
