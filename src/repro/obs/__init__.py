"""`repro.obs` — unified observability for the streaming service.

Three instruments behind one umbrella object:

- :mod:`repro.obs.metrics` — typed metrics registry (counters, gauges,
  fixed-bucket histograms) with Prometheus-text and JSON exposition;
- :mod:`repro.obs.trace` — hierarchical span tracer (batch →
  shared-delta → storage-update → maintain → materialize → sinks) with
  JSONL and Chrome trace-event (Perfetto) export;
- :mod:`repro.obs.jaxprof` — device profiling: compile-vs-execute split
  per jitted SPMD step, XLA cost/memory analysis, optional
  ``jax.profiler`` windows, device→host transfer accounting.

One :class:`Observability` per :class:`~repro.stream.service.ListingService`
(pass ``obs=``); the default is the *cheap* configuration — registry and
step profiling on, span tracing off — so two services in one process
never share counters and the hot path stays unperturbed.
"""

from __future__ import annotations

import os
from typing import Dict, Optional

from .jaxprof import JaxProfiler, ProfiledStep, StepProfile
from .metrics import (
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    ProbeView,
)
from .trace import NULL_SPAN, Span, Tracer

__all__ = [
    "Observability",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "ProbeView",
    "DEFAULT_LATENCY_BUCKETS",
    "Tracer",
    "Span",
    "NULL_SPAN",
    "JaxProfiler",
    "ProfiledStep",
    "StepProfile",
]


class Observability:
    """One service's metrics registry + span tracer + device profiler.

    ``Observability()``        — registry + device profiling on, tracing off
    ``Observability.full()``   — everything on (span tracing included)
    ``Observability.disabled()`` — every channel off (still safe to call)
    """

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 jaxprof: Optional[JaxProfiler] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self.jaxprof = (jaxprof if jaxprof is not None
                        else JaxProfiler(self.metrics, enabled=True))
        #: per-pattern CompiledPlan dumps (latest wins across swaps);
        #: exported as `{prefix}_plans.json`
        self.plans: Dict[str, dict] = {}

    def record_plan(self, name: str, dump: dict) -> None:
        """Remember a pattern's latest :class:`~repro.planner.CompiledPlan`
        dump (``plan.to_json()``) for the export bundle. A plan swap
        re-records under the same name — the export shows the plan the
        service is *currently* executing."""
        self.plans[name] = dump

    @classmethod
    def full(cls) -> "Observability":
        obs = cls(tracer=Tracer(enabled=True))
        return obs

    @classmethod
    def disabled(cls) -> "Observability":
        obs = cls()
        obs.jaxprof.enabled = False
        return obs

    def export(self, dir_path: str, prefix: str = "obs") -> Dict[str, str]:
        """Write every artifact into ``dir_path``; returns name → path.

        Emits a metrics JSON snapshot + Prometheus text always, span
        exports (JSONL + Chrome trace-event JSON for Perfetto) when any
        spans were recorded, and the device-step profile when any step
        ran profiled.
        """
        os.makedirs(dir_path, exist_ok=True)
        out: Dict[str, str] = {}
        p = os.path.join(dir_path, f"{prefix}_metrics.json")
        self.metrics.save_json(p)
        out["metrics_json"] = p
        p = os.path.join(dir_path, f"{prefix}_metrics.prom")
        self.metrics.save_prometheus(p)
        out["metrics_prom"] = p
        if self.tracer.roots:
            p = os.path.join(dir_path, f"{prefix}_trace.jsonl")
            self.tracer.to_jsonl(p)
            out["trace_jsonl"] = p
            p = os.path.join(dir_path, f"{prefix}_trace_chrome.json")
            self.tracer.to_chrome_trace(p)
            out["trace_chrome"] = p
        if self.jaxprof.steps:
            p = os.path.join(dir_path, f"{prefix}_jaxprof.json")
            self.jaxprof.save_json(p)
            out["jaxprof_json"] = p
        if self.plans:
            import json

            p = os.path.join(dir_path, f"{prefix}_plans.json")
            with open(p, "w") as f:
                json.dump(self.plans, f, indent=2, sort_keys=True)
                f.write("\n")
            out["plans_json"] = p
        return out
