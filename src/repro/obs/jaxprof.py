"""Device-profiling hooks for the sharded streaming backend.

``BENCH_stream_service.json`` showed the device maintain path stuck at
~1.8 s/call with no way to say whether that is XLA compilation, steady
dispatch, padded-shape waste, or transfers. This module answers that by
wrapping every jitted SPMD step the backend drives
(:func:`~repro.dist.sharded.make_storage_update_step`, the per-pattern
maintain/list/init/refresh steps) in a :class:`ProfiledStep`:

- the **first** call of each wrapped step is compiled ahead-of-time via
  ``fn.lower(*args).compile()`` so compile time is measured *separately*
  from the first execution (the classic jit first-call conflation);
  recompiles — the backend's ``cap_fallbacks`` candidate-cap fallback
  and ``store_resizes`` rebuilds create a *new* wrapper under the same
  step name — accumulate into the same :class:`StepProfile`;
- each compiled step's ``cost_analysis()`` (flops / bytes accessed) and
  ``memory_analysis()`` (output / temp / argument bytes) are recorded
  once per compile;
- steady-state executions are timed with ``jax.block_until_ready`` so
  the numbers are wall-clock of actual device work, not dispatch;
- optionally, a ``jax.profiler`` trace can be armed for a chosen batch
  window (:meth:`JaxProfiler.arm_capture`) — the service calls
  :meth:`on_batch_start`/:meth:`on_batch_end` around every micro-batch;
- device→host transfer bytes flow through the backend's existing
  ``_pull`` seam into the ``host_transfer_bytes_total`` counter.

Everything is defensive: any AOT/analysis failure falls back to calling
the jitted function directly (first call then *includes* compile time
and is attributed to it — the pre-AOT heuristic), so profiling can
never break the stream.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional

__all__ = ["StepProfile", "ProfiledStep", "JaxProfiler"]


def _cost_dict(compiled) -> Optional[dict]:
    """Flatten ``Compiled.cost_analysis()`` into one {str: float} dict."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return None
    if ca is None:
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    if not isinstance(ca, dict):
        return None
    out = {}
    for k, v in ca.items():
        try:
            out[str(k)] = float(v)
        except (TypeError, ValueError):
            continue
    return out


_MEM_FIELDS = (
    "generated_code_size_in_bytes",
    "argument_size_in_bytes",
    "output_size_in_bytes",
    "alias_size_in_bytes",
    "temp_size_in_bytes",
)


def _memory_dict(compiled) -> Optional[dict]:
    try:
        mem = compiled.memory_analysis()
    except Exception:
        return None
    if mem is None:
        return None
    out = {}
    for f in _MEM_FIELDS:
        v = getattr(mem, f, None)
        if v is not None:
            try:
                out[f] = int(v)
            except (TypeError, ValueError):
                continue
    return out or None


@dataclasses.dataclass
class StepProfile:
    """Accumulated compile/execute accounting for one named step.

    One record per step *name* — recompiles of the same logical step
    (cap fallback, store resize) increment :attr:`compiles` and fold
    their compile time into :attr:`compile_seconds`.
    """

    name: str
    compiles: int = 0
    compile_seconds: float = 0.0
    calls: int = 0
    execute_seconds: float = 0.0
    last_execute_s: float = 0.0
    #: latest compile's XLA cost_analysis / memory_analysis (None when
    #: the runtime doesn't expose them)
    cost: Optional[dict] = None
    memory: Optional[dict] = None
    #: True when AOT lowering failed and the split degraded to the
    #: first-call≈compile heuristic
    heuristic: bool = False
    #: sub-attribution shares for fused steps: {component: share} with
    #: shares summing to 1.0 — e.g. the multi-pattern megastep reports
    #: each pattern's modeled fraction of the fused cost here instead of
    #: emitting per-pattern ghost steps. None for unfused steps.
    subs: Optional[Dict[str, float]] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ProfiledStep:
    """Transparent callable wrapper around one jitted step.

    ``profiler_get`` is a zero-arg closure resolving to the current
    :class:`JaxProfiler` (or None) *at call time* — the backend compiles
    its steps before the service attaches observability, so the binding
    must be late.
    """

    __slots__ = ("name", "fn", "_profiler_get", "_compiled", "_warm", "subs")

    def __init__(self, name: str, fn: Callable,
                 profiler_get: Callable[[], Optional["JaxProfiler"]],
                 subs: Optional[Dict[str, float]] = None):
        self.name = name
        self.fn = fn
        self._profiler_get = profiler_get
        self._compiled = None   # AOT executable once lowered
        self._warm = False      # first profiled call already accounted
        # Normalized {component: share} sub-attribution published into
        # the StepProfile on every profiled call (rebuilt wrappers of
        # the same step may carry fresher shares — latest wins).
        self.subs = dict(subs) if subs else None

    def __call__(self, *args):
        prof = self._profiler_get()
        if prof is None or not prof.enabled:
            return self.fn(*args)
        return prof._call(self, *args)


class JaxProfiler:
    """Per-service device profiler: step records + optional trace window.

    ``enabled=False`` turns every :class:`ProfiledStep` into a plain
    passthrough (zero accounting, no ``block_until_ready``).
    """

    def __init__(self, registry=None, enabled: bool = True,
                 aot: bool = True, collect_analysis: bool = True):
        self.registry = registry
        self.enabled = enabled
        self.aot = aot
        self.collect_analysis = collect_analysis
        self.steps: Dict[str, StepProfile] = {}
        # jax.profiler window capture state
        self._capture_logdir: Optional[str] = None
        self._capture_start = 0
        self._capture_len = 0
        self._capturing = False
        self.captured_dirs: List[str] = []

    # ----------------------------------------------------------- step timing
    def _record(self, name: str, kind: str, seconds: float) -> None:
        if self.registry is None:
            return
        self.registry.counter(
            f"jax_{kind}_seconds_total", f"total {kind} seconds per jitted step",
            labels=("step",)).labels(step=name).inc(seconds)
        self.registry.counter(
            f"jax_{kind}s_total" if kind == "compile" else "jax_execute_calls_total",
            f"{kind} count per jitted step",
            labels=("step",)).labels(step=name).inc()

    def _record_analysis(self, name: str, rec: StepProfile) -> None:
        if self.registry is None:
            return
        if rec.cost:
            flops = rec.cost.get("flops")
            if flops is not None:
                self.registry.gauge("jax_step_flops",
                                    "XLA cost_analysis flops of the latest compile",
                                    labels=("step",)).labels(step=name).set(flops)
        if rec.memory:
            for f in ("output_size_in_bytes", "temp_size_in_bytes"):
                v = rec.memory.get(f)
                if v is not None:
                    self.registry.gauge(
                        f"jax_step_{f}",
                        f"XLA memory_analysis {f} of the latest compile",
                        labels=("step",)).labels(step=name).set(v)

    def _compile(self, step: ProfiledStep, rec: StepProfile, args) -> None:
        """AOT-lower the step so compile time is isolated from execution."""
        t0 = time.perf_counter()
        try:
            compiled = step.fn.lower(*args).compile()
        except Exception:
            step._compiled = None
            rec.heuristic = True
            return
        dt = time.perf_counter() - t0
        step._compiled = compiled
        rec.compiles += 1
        rec.compile_seconds += dt
        self._record(step.name, "compile", dt)
        if self.collect_analysis:
            cost = _cost_dict(compiled)
            memory = _memory_dict(compiled)
            if cost is not None:
                rec.cost = cost
            if memory is not None:
                rec.memory = memory
            self._record_analysis(step.name, rec)

    def _call(self, step: ProfiledStep, *args):
        import jax

        rec = self.steps.get(step.name)
        if rec is None:
            rec = self.steps[step.name] = StepProfile(step.name)
        if step.subs is not None:
            rec.subs = dict(step.subs)
        if not step._warm:
            step._warm = True
            if self.aot:
                self._compile(step, rec, args)
            else:
                rec.heuristic = True
            if step._compiled is None:
                # Heuristic split: the first direct call pays compile +
                # one execution; attribute it wholly to compile (upper
                # bound, flagged via `heuristic`).
                t0 = time.perf_counter()
                out = jax.block_until_ready(step.fn(*args))
                dt = time.perf_counter() - t0
                rec.compiles += 1
                rec.compile_seconds += dt
                self._record(step.name, "compile", dt)
                return out
        fn = step._compiled if step._compiled is not None else step.fn
        t0 = time.perf_counter()
        try:
            out = fn(*args)
        except Exception:
            if fn is step.fn:
                raise
            # AOT executable rejected the inputs (e.g. sharding/layout
            # drift) — degrade to the jitted path permanently.
            step._compiled = None
            rec.heuristic = True
            out = step.fn(*args)
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        rec.calls += 1
        rec.execute_seconds += dt
        rec.last_execute_s = dt
        self._record(step.name, "execute", dt)
        return out

    # ------------------------------------------------------- capture windows
    def arm_capture(self, logdir: str, start_batch: int = 0,
                    n_batches: int = 1) -> None:
        """Capture a ``jax.profiler`` trace for batches
        ``[start_batch, start_batch + n_batches)`` of the next run."""
        self._capture_logdir = logdir
        self._capture_start = int(start_batch)
        self._capture_len = max(1, int(n_batches))

    def on_batch_start(self, batch_index: int) -> None:
        if (self._capture_logdir is None or self._capturing
                or batch_index != self._capture_start):
            return
        try:
            import jax
            jax.profiler.start_trace(self._capture_logdir)
            self._capturing = True
        except Exception:
            self._capture_logdir = None

    def on_batch_end(self, batch_index: int) -> None:
        if not self._capturing:
            return
        if batch_index >= self._capture_start + self._capture_len - 1:
            try:
                import jax
                jax.profiler.stop_trace()
                self.captured_dirs.append(self._capture_logdir)
            except Exception:
                pass
            self._capturing = False
            self._capture_logdir = None

    # --------------------------------------------------------------- exports
    def snapshot(self) -> dict:
        return {
            "steps": {name: rec.as_dict() for name, rec in sorted(self.steps.items())},
            "captured_dirs": list(self.captured_dirs),
        }

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
