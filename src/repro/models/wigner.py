"""Wigner-D rotations of real spherical-harmonic coefficients (eSCN).

EquiformerV2's eSCN trick rotates every edge's irrep features into an
edge-aligned frame, where SO(3) convolutions collapse to SO(2) per-m
mixing. We need, per edge, the block-diagonal matrix ``M(R)`` acting on
real-SH coefficient vectors, where ``R`` maps the edge direction onto ŷ.

Rather than juggling phase conventions, the constant ingredients are
*fit numerically* (exactly — SH are polynomials) against a direct real-SH
evaluator:

    M(R) per degree l is defined by  sh_l(R·u) = M_l(R) · sh_l(u)  ∀u,

which makes ``M`` a homomorphism: M(R₁R₂) = M(R₁)M(R₂). Z-rotations are
analytic (sparse cos/sin mixing of (m, −m) pairs); the only numeric
constant is ``C_l = M_l(B)`` for the fixed axis-swap rotation B (ẑ→x̂),
giving arbitrary x-rotations via conjugation:

    M(Rx(θ)) = C · M(Rz(θ)) · Cᵀ.

Coefficient vectors transform by exactly ``M(R)`` (real SH are an
orthonormal basis), so the per-edge rotation is

    D_edge = M(Rx(ψ)) · M(Rz(φ)),   R_edge · v = ŷ.

Validated in tests: orthogonality, homomorphism, l=1 ≅ (y, z, x)
coordinate rotation, and ``D_edge``-alignment of random directions.
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["sh_real", "sh_basis_size", "rot_z_real", "axis_swap_matrix", "edge_rotation"]


def sh_basis_size(l_max: int) -> int:
    return (l_max + 1) ** 2


# ---------------------------------------------------------------------------
# Real spherical harmonics (numpy, exact reference)
# ---------------------------------------------------------------------------

def _legendre_all(l_max: int, x: np.ndarray) -> np.ndarray:
    """Associated Legendre P_l^m(x) (with Condon–Shortley) for 0≤m≤l≤l_max."""
    n = x.shape[0]
    p = np.zeros((l_max + 1, l_max + 1, n))
    p[0, 0] = 1.0
    somx2 = np.sqrt(np.maximum(1.0 - x * x, 0.0))
    for m in range(1, l_max + 1):
        p[m, m] = -(2 * m - 1) * somx2 * p[m - 1, m - 1]
    for m in range(0, l_max):
        p[m + 1, m] = (2 * m + 1) * x * p[m, m]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            p[l, m] = ((2 * l - 1) * x * p[l - 1, m] - (l + m - 1) * p[l - 2, m]) / (l - m)
    return p


def sh_real(l_max: int, dirs: np.ndarray) -> np.ndarray:
    """Real orthonormal SH Y_{lm}(u) for unit vectors u: [N, (l_max+1)²].

    Basis order per l: m = −l..l; Y_{1,−1} ∝ y, Y_{1,0} ∝ z, Y_{1,1} ∝ x.
    """
    u = dirs / np.linalg.norm(dirs, axis=1, keepdims=True)
    x, y, z = u[:, 0], u[:, 1], u[:, 2]
    phi = np.arctan2(y, x)
    p = _legendre_all(l_max, z)
    out = np.zeros((u.shape[0], sh_basis_size(l_max)))
    off = 0
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            norm = math.sqrt(
                (2 * l + 1) / (4 * math.pi) * math.factorial(l - am) / math.factorial(l + am)
            )
            if m == 0:
                val = norm * p[l, 0]
            elif m > 0:
                val = math.sqrt(2) * norm * p[l, am] * np.cos(am * phi)
            else:
                val = math.sqrt(2) * norm * p[l, am] * np.sin(am * phi)
            out[:, off + m + l] = val
        off += 2 * l + 1
    return out


def _fit_block(l: int, rot: np.ndarray) -> np.ndarray:
    """M_l(R) via exact least squares: sh_l(R u) = M_l sh_l(u)."""
    rng = np.random.default_rng(1234 + l)
    u = rng.normal(size=(max(64, 8 * (2 * l + 1) ** 2), 3))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    lo = l * l
    hi = (l + 1) ** 2
    a = sh_real(l, u)[:, lo:hi]
    b = sh_real(l, u @ rot.T)[:, lo:hi]
    m, res, _, _ = np.linalg.lstsq(a, b, rcond=None)
    m = m.T
    err = np.abs(a @ m.T - b).max()
    assert err < 1e-8, f"Wigner fit failed for l={l}: {err}"
    return m


_B = np.array([[0.0, 0.0, 1.0], [0.0, 1.0, 0.0], [-1.0, 0.0, 0.0]])  # ẑ → x̂


@lru_cache(maxsize=16)
def axis_swap_matrix(l: int) -> np.ndarray:
    """C_l = M_l(B) with B·ẑ = x̂ (constant, orthogonal)."""
    return _fit_block(l, _B)


def rot_z_real(l: int, theta: jax.Array) -> jax.Array:
    """M_l(Rz(θ)) analytic: acts on (m, −m) pairs. theta: [...]."""
    dim = 2 * l + 1
    out = jnp.zeros(theta.shape + (dim, dim), jnp.float32)
    out = out.at[..., l, l].set(1.0)
    for m in range(1, l + 1):
        c = jnp.cos(m * theta)
        s = jnp.sin(m * theta)
        # φ → φ + θ: cos(m(φ+θ)) = cos·cos − sin·sin ; sin(m(φ+θ)) = …
        out = out.at[..., l + m, l + m].set(c)
        out = out.at[..., l - m, l - m].set(c)
        out = out.at[..., l + m, l - m].set(-s)
        out = out.at[..., l - m, l + m].set(s)
    return out


def edge_rotation(l_max: int, directions: jax.Array) -> jax.Array:
    """Per-edge block-diagonal D with D·sh(v) = sh(ŷ): [E, dim, dim].

    R = Rx(ψ)·Rz(φ): Rz(φ) brings v into the y–z plane (y ≥ 0), Rx(ψ)
    rotates it onto ŷ. M(Rx(ψ)) = C·M(Rz(ψ))·Cᵀ with the constant C.
    """
    e = directions.shape[0]
    dim = sh_basis_size(l_max)
    v = directions.astype(jnp.float32)
    r = jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-12
    x, y, z = (v / r)[..., 0], (v / r)[..., 1], (v / r)[..., 2]
    # Rz(φ)·v zeroes the x-component and leaves y' = √(x²+y²) ≥ 0:
    phi = jnp.arctan2(x, y)
    y1 = jnp.sin(phi) * x + jnp.cos(phi) * y  # = sqrt(x²+y²) ≥ 0
    # Rx(ψ) maps (0, y1, z) → ŷ: ψ = atan2(-z, y1) with Rx as in _B-frame.
    psi = jnp.arctan2(-z, y1)

    out = jnp.zeros((e, dim, dim), jnp.float32)
    off = 0
    for l in range(l_max + 1):
        c = jnp.asarray(axis_swap_matrix(l), jnp.float32)
        za = rot_z_real(l, phi)
        zb = rot_z_real(l, psi)
        block = jnp.einsum("ij,ejk,kl,elm->eim", c, zb, c.T, za)
        out = jax.lax.dynamic_update_slice(out, block, (0, off, off))
        off += 2 * l + 1
    return out
